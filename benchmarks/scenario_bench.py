"""Scenario-diversity benchmark: heterogeneity, traces, energy, policy.

Three committed row families land in ``BENCH_scenarios.json`` (all exactly
deterministic: virtual clock, seeded everything, BLAS-free autotuner):

* **policy rows** — a heterogeneous fleet (``HETERO_PROFILES``: laptop /
  phone / IoT device tiers mixed in one fleet) whose draft hardness drifts
  mid-run, served three ways per paper scenario: static chain, static tree,
  and the adaptive per-session policy controller.  The ``summary`` row
  counts the scenarios where adaptive matches-or-beats the best static
  policy on tokens/s — the acceptance gate is ≥3 of 4.
* **energy rows** — the paper's §5.3 energy claim, two-sided: edge joules
  (idle + decode + radio) AND cloud verifier joules, per 100 accepted
  tokens.  ``energy_reduction_pct`` of PipeSD vs the vanilla SD baseline
  must land in the paper's 14.3–25.3% band (asserted in the test suite);
  runs use ``autotune=False`` so the row is bit-exact across hosts.
* **trace rows** — every bundled network trace (4G drive / 5G urban /
  WiFi café) compiled to a ``FaultScenario`` and replayed on the oracle
  fleet.  ``conformant`` asserts the robustness claim: each session's
  committed stream is bit-identical to the fault-free oracle stream.

Harness entry is :func:`scenarios` (wired into ``benchmarks.run`` and the
CI bench-diff regen map).
"""

from __future__ import annotations

from typing import Dict, List, Optional, Tuple

from repro.core.pipeline import RunStats
from repro.runtime.faults import FaultScenario
from repro.runtime.simclock import VirtualClock
from repro.runtime.traces import TRACE_MATRIX, trace_by_name

from .common import csv_row, run_method
from .fleet_bench import HETERO_PROFILES, run_chaos, run_fleet

# Hardness drift: the stream starts easy (chain-friendly: long accepted
# chains) and turns hard mid-run (tree-friendly: branching recovers
# tokens/NAV).  A static policy can only win one half.
DRIFT_SCHEDULE: Tuple[Tuple[int, float], ...] = ((0, 0.05), (30, 0.55))

# The committed policy sweep: paper scenarios 1-3 are the static device
# tiers; "scenario 4" is the fluctuating link, realised here as the bundled
# 4G drive trace replayed on every session's channel.
POLICY_SCENARIOS: Tuple[Tuple[str, int, Optional[str]], ...] = (
    ("scen1", 1, None),
    ("scen2", 2, None),
    ("scen3", 3, None),
    ("scen4_trace", 1, "4g_drive"),
)

POLICIES = ("chain", "tree", "adaptive")

# "Adaptive wins" means matches-or-beats the best static policy; the slack
# absorbs the one round of probing the controller spends before locking on.
WIN_SLACK = 0.995


def _policy_run(scen: int, policy: str, trace: Optional[str], seed: int = 7) -> dict:
    faults: Optional[FaultScenario] = None
    if trace is not None:
        faults = TRACE_MATRIX[[t.name for t in TRACE_MATRIX].index(f"trace:{trace}")]
        assert trace_by_name(trace).name == trace
    kwargs = dict(
        mode="batched",
        n_sessions=6,
        tokens_per_session=60,
        scen=scen,
        seed=seed,
        ts=1.0,
        clock=VirtualClock(),
        profiles=HETERO_PROFILES,
        p_hard_schedule=DRIFT_SCHEDULE,
        faults=faults,
        nav_timeout=1.0,
        backoff_init=0.1,
        local_gamma=8.0,
    )
    if policy == "adaptive":
        return run_fleet(variant="chain", policy="adaptive", **kwargs)
    return run_fleet(variant=policy, **kwargs)


def policy_bench() -> Dict[str, Dict[str, dict]]:
    """{scenario: {policy: report}} for the committed policy sweep."""
    out: Dict[str, Dict[str, dict]] = {}
    for label, scen, trace in POLICY_SCENARIOS:
        out[label] = {p: _policy_run(scen, p, trace) for p in POLICIES}
    return out


def _policy_rows(reports: Dict[str, Dict[str, dict]]) -> Tuple[list, List[str]]:
    rows, lines = [], []
    wins = 0
    for label, by_policy in reports.items():
        tps = {}
        for policy, rep in by_policy.items():
            st: RunStats = rep["stats"]
            tps[policy] = st.accepted_tokens / max(st.wall_time, 1e-9)
            row = dict(
                family="policy",
                scenario=label,
                policy=policy,
                tokens_per_s=tps[policy],
                tokens_per_nav=st.tokens_per_nav,
                failovers=st.failovers,
                fallback_tokens=st.fallback_tokens,
                mode_switches=rep.get("policy_mode_switches", 0),
                retunes=rep.get("policy_retunes", 0),
                gamma_spread=st.gamma_spread,
                beta_spread=st.beta_spread,
            )
            rows.append(row)
            derived = (
                f"tokens_per_s={tps[policy]:.2f};tokens_per_nav={st.tokens_per_nav:.2f};"
                f"failovers={st.failovers};fallback={st.fallback_tokens};"
                f"switches={row['mode_switches']};retunes={row['retunes']}"
            )
            lines.append(csv_row(f"scenarios/{label}/{policy}", 1e6 / tps[policy], derived))
        best_static = max(tps["chain"], tps["tree"])
        if tps["adaptive"] >= best_static * WIN_SLACK:
            wins += 1
    rows.append(
        dict(
            family="policy",
            scenario="summary",
            policy="adaptive",
            adaptive_wins=wins,
            n_scenarios=len(reports),
        )
    )
    lines.append(
        csv_row("scenarios/summary/adaptive_wins", 0.0, f"wins={wins}/{len(reports)}")
    )
    return rows, lines


def energy_bench(n_tokens: int = 400, seed: int = 11) -> Dict[str, dict]:
    """Per-scenario two-sided energy accounting: vanilla SD vs PipeSD.

    Both methods run the deterministic sim engine with autotuning OFF, so
    every field (including the headline ``energy_reduction_pct``) is exact
    across hosts — the CI bench-diff gates it with zero tolerance.
    """
    out: Dict[str, dict] = {}
    for scen in (1, 2, 3, 4):
        _, van, _ = run_method("vanilla", scen=scen, n_tokens=n_tokens, seed=seed, autotune=False)
        _, pip, _ = run_method("pipesd", scen=scen, n_tokens=n_tokens, seed=seed, autotune=False)
        reduction = (1.0 - pip.energy_per_100_tokens / van.energy_per_100_tokens) * 100.0
        out[f"scen{scen}"] = dict(
            vanilla=van,
            pipesd=pip,
            speedup=van.tpt / pip.tpt,
            energy_reduction_pct=reduction,
        )
    return out


def _energy_rows(reports: Dict[str, dict]) -> Tuple[list, List[str]]:
    rows, lines = [], []
    for label, rep in reports.items():
        van: RunStats = rep["vanilla"]
        pip: RunStats = rep["pipesd"]
        row = dict(
            family="energy",
            scenario=label,
            speedup=rep["speedup"],
            energy_reduction_pct=rep["energy_reduction_pct"],
            vanilla_ecs_total_j=van.energy_per_100_tokens,
            pipesd_ecs_total_j=pip.energy_per_100_tokens,
            pipesd_ecs_edge_j=pip.ecs_edge,
            pipesd_ecs_cloud_j=pip.ecs_cloud,
        )
        rows.append(row)
        derived = (
            f"reduction={rep['energy_reduction_pct']:.1f}%;speedup={rep['speedup']:.2f};"
            f"ecs_total={pip.energy_per_100_tokens:.1f}J;ecs_edge={pip.ecs_edge:.1f}J;"
            f"ecs_cloud={pip.ecs_cloud:.1f}J"
        )
        lines.append(csv_row(f"scenarios/energy/{label}", 0.0, derived))
    return rows, lines


def _trace_rows(seed: int = 0) -> Tuple[list, List[str]]:
    reports = run_chaos(scenarios=TRACE_MATRIX, seed=seed)
    rows, lines = [], []
    for name, rep in reports.items():
        st: RunStats = rep["stats"]
        row = dict(
            family="trace",
            scenario=name,
            conformant=rep["conformant"],
            failovers=st.failovers,
            fallback_tokens=st.fallback_tokens,
            tokens_per_s=st.accepted_tokens / max(st.wall_time, 1e-9),
        )
        rows.append(row)
        derived = (
            f"conformant={rep['conformant']};failovers={st.failovers};"
            f"fallback={st.fallback_tokens};wall={st.wall_time:.1f}s"
        )
        lines.append(csv_row(f"scenarios/{name.replace(':', '/')}", 0.0, derived))
    return rows, lines


def scenarios() -> Tuple[list, List[str]]:
    """Harness entry (benchmarks.run / CI bench-diff regen).

    Returns the full committed row set: policy sweep + summary, energy
    accounting, and trace conformance.
    """
    rows, lines = _policy_rows(policy_bench())
    erows, elines = _energy_rows(energy_bench())
    trows, tlines = _trace_rows()
    return rows + erows + trows, lines + elines + tlines
