"""Benchmarks reproducing every paper table/figure (DESIGN.md §9 index).

Each ``table_*``/``fig_*`` function returns (rows, csv_lines) where csv lines
follow the harness format ``name,us_per_call,derived``: us_per_call is the
simulated TPT in µs and ``derived`` packs the table-specific values.
"""

from __future__ import annotations

import time
from typing import List, Tuple

import numpy as np

from repro.core.autotuner import BOAutotuner, grid_search, random_search
from repro.core.pipeline import ChannelModel, CloudModel, EdgeModel, PipelineEngine, SyntheticSource, make_framework
from repro.core.scheduler import CommParams, dp_schedule, greedy_schedule, immediate_schedule, no_early_upload_schedule

from .common import DATASETS, METHODS, csv_row, run_method


def table1_tpt() -> Tuple[list, List[str]]:
    """Table 1: average TPT across 4 scenarios × 2 datasets × 4 methods."""
    rows, lines = [], []
    for scen in (1, 2, 3, 4):
        for ds in ("humaneval", "gsm8k"):
            tpts = {}
            for m in METHODS:
                # PipeSD runs with the BO autotuner (the paper's Table-1
                # configuration); baselines use their per-task best settings.
                _, st, _ = run_method(m, ds, scen, n_tokens=1000, autotune=(m == "pipesd"))
                tpts[m] = st.tpt * 1e3
            sp = {f"S_t{i+1}": tpts[b] / tpts["pipesd"] for i, b in enumerate(("vanilla", "hsl", "edgellm"))}
            row = dict(scenario=scen, dataset=ds, **{m: round(tpts[m], 1) for m in METHODS},
                       **{k: round(v, 2) for k, v in sp.items()})
            rows.append(row)
            lines.append(csv_row(
                f"table1/scen{scen}/{ds}", tpts["pipesd"] * 1e3,
                f"vanilla={tpts['vanilla']:.0f}ms;hsl={tpts['hsl']:.0f}ms;edgellm={tpts['edgellm']:.0f}ms;"
                f"pipesd={tpts['pipesd']:.0f}ms;St1={sp['S_t1']:.2f};St2={sp['S_t2']:.2f};St3={sp['S_t3']:.2f}",
            ))
    return rows, lines


def table2_ecs() -> Tuple[list, List[str]]:
    """Table 2: cloud energy per 100 accepted tokens, Scenario 1."""
    rows, lines = [], []
    for ds in ("humaneval", "gsm8k"):
        ecs = {}
        for m in METHODS:
            _, st, _ = run_method(m, ds, 1, n_tokens=1000, autotune=False)
            ecs[m] = st.ecs_cloud
        red = {f"P_e{i+1}": 100 * (1 - ecs["pipesd"] / ecs[b]) for i, b in enumerate(("vanilla", "hsl", "edgellm"))}
        rows.append(dict(dataset=ds, **{m: round(ecs[m], 1) for m in METHODS}, **{k: round(v, 1) for k, v in red.items()}))
        lines.append(csv_row(
            f"table2/{ds}", ecs["pipesd"] * 1e6 / 1e6,
            ";".join(f"{m}={ecs[m]:.1f}J" for m in METHODS) + ";" + ";".join(f"{k}={v:.1f}%" for k, v in red.items()),
        ))
    return rows, lines


def table3_bo() -> Tuple[list, List[str]]:
    """Table 3: BO vs grid vs random search for (R1, R2)."""
    rows, lines = [], []
    for ds in ("humaneval", "gsm8k"):

        def measure(r1, r2, _ds=ds):
            _, st, _ = run_method("pipesd", _ds, 1, n_tokens=150, autotune=False,
                                  trigger_kw=dict(r1=r1, r2=r2))
            return st.tpt

        bo = BOAutotuner(seed=0).minimize(measure, 16)
        gs = grid_search(measure)
        rs = random_search(measure, n_trials=16, seed=0)
        # Evaluate each winner on a long run.
        finals = {}
        for name, obs in (("bo", bo), ("grid", gs), ("random", rs)):
            _, st, _ = run_method("pipesd", ds, 1, n_tokens=1000, autotune=False,
                                  trigger_kw=dict(r1=obs.x[0], r2=obs.x[1]))
            finals[name] = st.tpt * 1e3
        rows.append(dict(dataset=ds, **{k: round(v, 1) for k, v in finals.items()}))
        lines.append(csv_row(f"table3/{ds}", finals["bo"] * 1e3,
                             f"bo={finals['bo']:.0f}ms;grid={finals['grid']:.0f}ms;random={finals['random']:.0f}ms"))
    return rows, lines


def table4_fixed_thresholds() -> Tuple[list, List[str]]:
    """Table 4: BO vs fixed (R1,R2) grid on HumanEval, Scenario 1."""
    grid = [(a, b) for a in (0.3, 0.6, 0.9) for b in (0.3, 0.6, 0.9)]
    eng, st, _ = run_method("pipesd", "humaneval", 1, n_tokens=800)  # autotuned
    results = {"bo": st.tpt * 1e3}
    for r1, r2 in grid:
        _, s2, _ = run_method("pipesd", "humaneval", 1, n_tokens=800, autotune=False,
                              trigger_kw=dict(r1=r1, r2=r2))
        results[f"({r1},{r2})"] = s2.tpt * 1e3
    rows = [dict(config=k, tpt_ms=round(v, 1)) for k, v in results.items()]
    best_fixed = min(v for k, v in results.items() if k != "bo")
    lines = [csv_row("table4/bo_vs_fixed", results["bo"] * 1e3,
                     f"bo={results['bo']:.0f}ms;best_fixed={best_fixed:.0f}ms;" +
                     ";".join(f"{k}={v:.0f}" for k, v in results.items() if k != "bo"))]
    return rows, lines


def table5_overhead() -> Tuple[list, List[str]]:
    """Table 5: control-plane overhead (% of wall time, first 1000 rounds)."""
    rows, lines = [], []
    for ds in ("humaneval", "gsm8k"):
        eng, st, _ = run_method("pipesd", ds, 1, n_tokens=3000)
        s = st.summary()
        rows.append(dict(dataset=ds, bo=round(100 * s["overhead_bo"], 3),
                         dp=round(100 * s["overhead_dp"], 4),
                         measure=round(100 * s["overhead_measure"], 3)))
        lines.append(csv_row(f"table5/{ds}", st.t_bo * 1e6 / max(st.bo_runs, 1),
                             f"bo={100*s['overhead_bo']:.2f}%;dp={100*s['overhead_dp']:.4f}%;"
                             f"measure={100*s['overhead_measure']:.3f}%"))
    return rows, lines


def table6_ablation() -> Tuple[list, List[str]]:
    """Table 6: mechanism ablations on HumanEval, Scenario 1."""
    methods = ["vanilla", "pipesd_no_pipeline", "pipesd_fixed", "pipesd_token", "pipesd_sequence", "pipesd"]
    tpts = {}
    for m in methods:
        _, st, _ = run_method(m, "humaneval", 1, n_tokens=1000, autotune=False)
        tpts[m] = st.tpt * 1e3
    rows = [dict(method=m, tpt_ms=round(tpts[m], 1), speedup=round(tpts["vanilla"] / tpts[m], 2)) for m in methods]
    lines = [csv_row("table6/ablation", tpts["pipesd"] * 1e3,
                     ";".join(f"{m}={tpts[m]:.0f}ms" for m in methods))]
    return rows, lines


def table7_stats() -> Tuple[list, List[str]]:
    """Table 7: verification frequency / draft length / acceptance rate."""
    rows, lines = [], []
    for m in ("hsl", "edgellm", "pipesd"):
        _, st, _ = run_method(m, "humaneval", 1, n_tokens=2000, autotune=False)
        rows.append(dict(method=m, freq=round(st.verification_frequency, 4),
                         draft_len=round(st.mean_draft_length, 2),
                         acceptance=round(st.acceptance_rate, 4)))
        lines.append(csv_row(f"table7/{m}", st.tpt * 1e6,
                             f"freq={st.verification_frequency:.4f};len={st.mean_draft_length:.2f};"
                             f"acc={st.acceptance_rate:.4f}"))
    return rows, lines


def fig5_bandwidth() -> Tuple[list, List[str]]:
    """Fig. 5: TPT vs uplink bandwidth (10/20/40/80 Mbps), HumanEval."""
    rows, lines = [], []
    for mbps in (10, 20, 40, 80):
        tpts = {}
        for m in METHODS:
            edge = EdgeModel()
            ch = ChannelModel(beta_up=0.05 * 20.0 / mbps)
            eng = PipelineEngine(make_framework(m, autotune=False), ch, CloudModel(), edge,
                                 SyntheticSource(**DATASETS["humaneval"]), seed=7)
            tpts[m] = eng.run(800).tpt * 1e3
        rows.append(dict(mbps=mbps, **{m: round(v, 1) for m, v in tpts.items()}))
        lines.append(csv_row(f"fig5/{mbps}mbps", tpts["pipesd"] * 1e3,
                             ";".join(f"{m}={tpts[m]:.0f}ms" for m in METHODS)))
    return rows, lines


def fig6_params() -> Tuple[list, List[str]]:
    """Fig. 6: α/β linear fit quality + γ stability across prefix length."""
    from repro.core.monitor import linear_fit_alpha_beta

    rng = np.random.default_rng(0)
    alpha, beta = 0.02, 0.05
    sizes = list(rng.integers(1, 9, 120))
    times = [alpha + beta * s + rng.normal(0, 3e-4) for s in sizes]
    ah, bh = linear_fit_alpha_beta(sizes, times)
    rows = [dict(alpha_true=alpha, alpha_est=round(ah, 4), beta_true=beta, beta_est=round(bh, 4))]
    lines = [csv_row("fig6/alpha_beta_fit", bh * 1e6, f"alpha_err={abs(ah-alpha)/alpha:.3%};beta_err={abs(bh-beta)/beta:.3%}")]
    return rows, lines


def tableA2_policies() -> Tuple[list, List[str]]:
    """Table A.2: DP vs greedy / immediate / no-early-upload across (α, β)."""
    rows, lines = [], []
    for alpha_ms, beta_ms in ((20, 72), (100, 72), (200, 72), (20, 48), (100, 48), (200, 48)):
        p = CommParams(alpha_ms / 1e3, beta_ms / 1e3, 0.1)
        n = 20
        d = dp_schedule(n, p).makespan
        res = dict(
            dp_vs_greedy=greedy_schedule(n, p).makespan / d,
            dp_vs_immediate=immediate_schedule(n, p).makespan / d,
            dp_vs_noearly=no_early_upload_schedule(n, p).makespan / d,
        )
        rows.append(dict(alpha=alpha_ms, beta=beta_ms, **{k: round(v, 2) for k, v in res.items()}))
        lines.append(csv_row(f"tableA2/a{alpha_ms}b{beta_ms}", d * 1e6,
                             ";".join(f"{k}={v:.2f}x" for k, v in res.items())))
    return rows, lines


def tableA3_multiclient() -> Tuple[list, List[str]]:
    """Table A.3: one-to-many serving (2/4/8 clients) under fluctuating bw."""
    import threading

    from repro.runtime import Channel, ChannelConfig, CloudVerifier, EdgeClient, EdgeConfig, SyntheticBackend

    rows, lines = [], []
    ts = 0.01
    for n_clients in (2, 4, 8):
        per_method = {}
        for method, window, r2 in (("vanilla", 6, 0.0), ("pipesd", 16, 0.6)):
            server = CloudVerifier(SyntheticBackend(time_scale=ts, seed=1), batch_window=0.002 if method == "pipesd" else 0.0)
            server.start()
            clients = []
            for sid in range(n_clients):
                up = Channel(ChannelConfig(alpha=0.02, beta=0.002, time_scale=ts))
                dn = Channel(ChannelConfig(alpha=0.01, beta=0.0005, time_scale=ts))
                server.attach(sid, up, dn)
                cfg = EdgeConfig(time_scale=ts, gamma=0.02, window=window, r2=r2,
                                 r1=0.9 if method == "pipesd" else 0.0)
                clients.append(EdgeClient(sid, up, dn, cfg))
            res = {}
            th = [threading.Thread(target=lambda c=c: res.update({c.session: c.run(60)})) for c in clients]
            [t.start() for t in th]
            [t.join(timeout=120) for t in th]
            server.stop()
            total_tokens = sum(r["accepted_tokens"] for r in res.values())
            total_time = max(r["wall_time"] for r in res.values()) / ts  # de-scaled
            per_method[method] = total_time / total_tokens * 1e3  # ms/token fleet-wide
        red = 100 * (1 - per_method["pipesd"] / per_method["vanilla"])
        rows.append(dict(clients=n_clients, vanilla=round(per_method["vanilla"], 2),
                         pipesd=round(per_method["pipesd"], 2), reduction_pct=round(red, 1)))
        lines.append(csv_row(f"tableA3/{n_clients}clients", per_method["pipesd"] * 1e3,
                             f"vanilla={per_method['vanilla']:.2f}ms;pipesd={per_method['pipesd']:.2f}ms;red={red:.1f}%"))
    return rows, lines


ALL_TABLES = {
    "table1_tpt": table1_tpt,
    "table2_ecs": table2_ecs,
    "table3_bo": table3_bo,
    "table4_fixed": table4_fixed_thresholds,
    "table5_overhead": table5_overhead,
    "table6_ablation": table6_ablation,
    "table7_stats": table7_stats,
    "fig5_bandwidth": fig5_bandwidth,
    "fig6_params": fig6_params,
    "tableA2_policies": tableA2_policies,
    "tableA3_multiclient": tableA3_multiclient,
}
