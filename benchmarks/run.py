# One function per paper table. Print ``name,us_per_call,derived`` CSV.
"""Benchmark harness: reproduces every paper table/figure + the roofline and
the multi-edge fleet serving benchmark.

    PYTHONPATH=src python -m benchmarks.run              # all benchmarks
    PYTHONPATH=src python -m benchmarks.run table1_tpt   # one benchmark
    PYTHONPATH=src python -m benchmarks.run fleet        # fleet serving only
"""

from __future__ import annotations

import sys
import time


def main() -> None:
    from .common import write_bench_json
    from .fleet_bench import chaos, fleet, fleet_committed, router
    from .kernel_bench import kernels
    from .roofline_bench import roofline
    from .scenario_bench import scenarios
    from .tables import ALL_TABLES

    extras = {
        "roofline": roofline,
        "fleet": fleet,
        "chaos": chaos,
        "router": router,
        "fleet_committed": fleet_committed,
        "kernels": kernels,
        "scenarios": scenarios,
    }
    # Deterministic benches whose rows are committed as BENCH_<area>.json
    # (the fleet rows run on a virtual clock — router sweep + traced
    # overhead gate + chaos matrix + codec frame sizes; the kernel rows are
    # pool accounting + a roofline traffic model: same rows on every host;
    # the scenario sweep is virtual-clock + BLAS-free BO: same rows
    # everywhere).  ``host_``-prefixed fields are informational wall time.
    committed = {"fleet_committed": "fleet", "kernels": "kernels", "scenarios": "scenarios"}
    wanted = sys.argv[1:] or list(ALL_TABLES) + list(extras)
    print("name,us_per_call,derived")
    t_start = time.time()
    for name in wanted:
        fn = ALL_TABLES.get(name, extras.get(name))
        if fn is None:
            print(f"# unknown benchmark {name!r}", file=sys.stderr)
            continue
        t0 = time.time()
        try:
            rows, lines = fn()
        except Exception as e:  # noqa: BLE001 — keep the harness running
            print(f"{name},0.0,ERROR:{type(e).__name__}:{e}")
            continue
        for line in lines:
            print(line, flush=True)
        if name in committed:
            path = write_bench_json(committed[name], rows)
            print(f"# {name}: wrote {path.name}", file=sys.stderr)
        print(f"# {name}: {len(rows)} rows in {time.time()-t0:.1f}s", file=sys.stderr)
    print(f"# total {time.time()-t_start:.1f}s", file=sys.stderr)


if __name__ == "__main__":
    main()
