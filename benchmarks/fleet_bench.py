"""Fleet load benchmark: N edge sessions against one continuous-batched verifier.

Drives N ≥ 8 threaded ``EdgeClient``s with Poisson arrivals through the live
``CloudVerifier`` across the paper's four scenarios (§5.1 / App. G.2), in two
serving modes:

* ``per_session`` — every NAV request is its own backend call (the seed
  behaviour: ``batch_window = 0``, ``max_batch = 1``);
* ``batched``     — continuous batching: requests coalescing within
  ``batch_window`` share ONE padded verify whose cost scales with the
  longest draft, not the sum (beyond-paper optimization #5).

Reported per (scenario, mode): per-session TPT (mean/worst), verifier batch
occupancy, mean queue depth, and p50/p99 NAV round-trip latency — all
de-scaled to simulated seconds and funneled through ``core.pipeline.RunStats``.

    PYTHONPATH=src python -m benchmarks.fleet_bench            # quick compare
    PYTHONPATH=src python benchmarks/fleet_bench.py            # same
    PYTHONPATH=src python -m benchmarks.run fleet              # harness CSV
"""

from __future__ import annotations

import sys
import threading
import time
from pathlib import Path
from typing import Dict, List, Tuple

_ROOT = Path(__file__).resolve().parent.parent
for _p in (str(_ROOT), str(_ROOT / "src")):
    if _p not in sys.path:
        sys.path.insert(0, _p)

import numpy as np

from benchmarks.common import csv_row, scenario
from repro.core.pipeline import RunStats
from repro.runtime import (
    Channel,
    ChannelConfig,
    CloudVerifier,
    EdgeClient,
    EdgeConfig,
    SyntheticBackend,
)

TS = 0.01  # run the timing model 100× faster than real time
MODES = ("per_session", "batched")


def run_fleet(
    n_sessions: int = 8,
    mode: str = "batched",
    scen: int = 1,
    tokens_per_session: int = 60,
    arrival_rate: float = 2.0,  # Poisson session arrivals [1/simulated-s]
    seed: int = 0,
    ts: float = TS,
) -> dict:
    """Serve ``n_sessions`` Poisson-arriving edge clients; returns a report.

    The report carries a ``RunStats`` with the fleet's NAV latencies and the
    verifier's batch/queue series, plus per-session TPT (simulated seconds
    per accepted token, §5.1 Metrics).
    """
    if mode not in MODES:
        raise ValueError(f"mode must be one of {MODES}")
    edge, channel = scenario(scen)
    # Fleet tier: faster drafts + short windows. The verifier becomes the
    # contended resource (the regime §3.2's utilization argument targets):
    # per-session serving saturates at ~9 NAV/s while batching absorbs it.
    gamma = edge.effective_gamma() * 0.1
    backend = SyntheticBackend(time_scale=ts, seed=seed)
    server = CloudVerifier(
        backend,
        batch_window=(backend.verify_time * ts if mode == "batched" else 0.0),
        max_batch=(64 if mode == "batched" else 1),
    )
    rng = np.random.default_rng(seed)
    arrivals = np.cumsum(rng.exponential(1.0 / arrival_rate, n_sessions))
    clients: List[EdgeClient] = []
    for sid in range(n_sessions):
        up = Channel(ChannelConfig(alpha=channel.alpha_up, beta=channel.beta_up, time_scale=ts))
        dn = Channel(ChannelConfig(alpha=channel.alpha_dn, beta=channel.beta_dn, time_scale=ts))
        server.attach(sid, up, dn)
        clients.append(
            EdgeClient(
                sid, up, dn, EdgeConfig(time_scale=ts, gamma=gamma, window=8, nav_timeout=8.0)
            )
        )
    server.start()
    results: Dict[int, dict] = {}

    def _drive(c: EdgeClient, start_s: float) -> None:
        time.sleep(start_s * ts)  # Poisson arrival (scaled)
        results[c.session] = c.run(tokens_per_session)

    threads = [
        threading.Thread(target=_drive, args=(c, float(arrivals[i])), daemon=True)
        for i, c in enumerate(clients)
    ]
    t0 = time.monotonic()
    [t.start() for t in threads]
    [t.join(timeout=600) for t in threads]
    wall = time.monotonic() - t0
    server.stop()

    load = server.load_summary()
    stats = RunStats(
        accepted_tokens=sum(r["accepted_tokens"] for r in results.values()),
        nav_calls=load["nav_calls"],
        rounds=sum(r["rounds"] for r in results.values()),
        wall_time=wall / ts,  # de-scaled simulated seconds
        verifier_batches=load["verifier_batches"],
        verifier_queue_depths=load["verifier_queue_depths"],
        nav_latencies=[lat / ts for r in results.values() for lat in r["nav_latencies"]],
    )
    per_session_tpt = {
        sid: r["wall_time"] / ts / max(r["accepted_tokens"], 1) for sid, r in results.items()
    }
    return dict(
        mode=mode,
        scenario=scen,
        n_sessions=n_sessions,
        stats=stats,
        per_session_tpt=per_session_tpt,
        failovers=sum(r["failovers"] for r in results.values()),
        server=load,
    )


def _report_lines(rep: dict) -> List[str]:
    st: RunStats = rep["stats"]
    p50, p99 = st.nav_latency_quantiles()
    tpts = list(rep["per_session_tpt"].values())
    return [
        f"  mode={rep['mode']:<12} sessions={rep['n_sessions']}"
        f" occupancy={st.verifier_batch_occupancy:.2f}"
        f" queue_depth={st.mean_queue_depth:.2f}",
        f"    per-session TPT mean={np.mean(tpts)*1e3:.1f}ms worst={np.max(tpts)*1e3:.1f}ms"
        f" | NAV latency p50={p50*1e3:.1f}ms p99={p99*1e3:.1f}ms"
        f" | backend calls={rep['server']['batched_calls']}"
        f" nav={st.nav_calls} failovers={rep['failovers']}",
    ]


def fleet(scenarios=(1, 2, 3, 4), n_sessions: int = 8) -> Tuple[list, List[str]]:
    """Harness entry (benchmarks.run): CSV rows per (scenario, mode)."""
    rows, lines = [], []
    for scen in scenarios:
        for mode in MODES:
            rep = run_fleet(n_sessions=n_sessions, mode=mode, scen=scen)
            st: RunStats = rep["stats"]
            p50, p99 = st.nav_latency_quantiles()
            tpts = list(rep["per_session_tpt"].values())
            rows.append(
                dict(
                    scenario=scen,
                    mode=mode,
                    occupancy=st.verifier_batch_occupancy,
                    tpt_ms=float(np.mean(tpts)) * 1e3,
                    nav_p50_ms=p50 * 1e3,
                    nav_p99_ms=p99 * 1e3,
                )
            )
            lines.append(
                csv_row(
                    f"fleet/scen{scen}/{mode}",
                    float(np.mean(tpts)) * 1e6,
                    f"occupancy={st.verifier_batch_occupancy:.2f};queue={st.mean_queue_depth:.2f};"
                    f"nav_p50={p50*1e3:.1f}ms;nav_p99={p99*1e3:.1f}ms;failovers={rep['failovers']}",
                )
            )
    return rows, lines


def main() -> None:
    try:
        n = int(sys.argv[1]) if len(sys.argv) > 1 else 8
    except ValueError:
        sys.exit(f"usage: fleet_bench.py [n_sessions]  (got {sys.argv[1]!r})")
    print(f"=== fleet serving, {n} edge sessions, Poisson arrivals, scenario 1 ===")
    reports = {mode: run_fleet(n_sessions=n, mode=mode, scen=1) for mode in MODES}
    for mode in MODES:
        for line in _report_lines(reports[mode]):
            print(line)
    occ = reports["batched"]["stats"].verifier_batch_occupancy
    p99_solo = reports["per_session"]["stats"].nav_latency_quantiles()[1]
    p99_batch = reports["batched"]["stats"].nav_latency_quantiles()[1]
    print(
        f"batched verifier occupancy {occ:.2f} (>1 amortizes the target forward);"
        f" p99 NAV {p99_solo*1e3:.1f}ms -> {p99_batch*1e3:.1f}ms"
    )


if __name__ == "__main__":
    main()
