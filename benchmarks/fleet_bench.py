"""Fleet load benchmark: N edge sessions against one continuous-batched verifier.

Drives N ≥ 8 threaded ``EdgeClient``s with Poisson arrivals through the live
``CloudVerifier`` across the paper's four scenarios (§5.1 / App. G.2), in two
serving modes:

* ``per_session`` — every NAV request is its own backend call (the seed
  behaviour: ``batch_window = 0``, ``max_batch = 1``);
* ``batched``     — continuous batching: requests coalescing within
  ``batch_window`` share ONE padded verify whose cost scales with the
  longest draft, not the sum (beyond-paper optimization #5).

and two speculation variants:

* ``chain`` — linear drafts (the PipeSD default);
* ``tree``  — top-k branching draft trees verified by batched tree-NAV; the
  hedge across siblings raises accepted-tokens-per-NAV exactly where chains
  stall (hard/low-confidence token streams), at the price of more verified
  nodes per call.

Reported per (scenario, mode, variant): per-session TPT (mean/worst),
accepted-tokens-per-NAV, verifier batch occupancy, mean queue depth, and
p50/p99 NAV round-trip latency — all de-scaled to simulated seconds and
funneled through ``core.pipeline.RunStats``.

    PYTHONPATH=src python -m benchmarks.fleet_bench            # quick compare
    PYTHONPATH=src python benchmarks/fleet_bench.py            # same
    PYTHONPATH=src python -m benchmarks.run fleet              # harness CSV
"""

from __future__ import annotations

import sys
import threading
import time
from pathlib import Path
from typing import Dict, List, Tuple

_ROOT = Path(__file__).resolve().parent.parent
for _p in (str(_ROOT), str(_ROOT / "src")):
    if _p not in sys.path:
        sys.path.insert(0, _p)

import numpy as np

from benchmarks.common import csv_row, scenario
from repro.core.pipeline import RunStats
from repro.runtime import (
    Channel,
    ChannelConfig,
    CloudVerifier,
    EdgeClient,
    EdgeConfig,
    SyntheticBackend,
    SyntheticDraft,
)

TS = 0.01  # run the timing model 100× faster than real time
MODES = ("per_session", "batched")
VARIANTS = ("chain", "tree")


def run_fleet(
    n_sessions: int = 8,
    mode: str = "batched",
    scen: int = 1,
    tokens_per_session: int = 60,
    arrival_rate: float = 2.0,  # Poisson session arrivals [1/simulated-s]
    seed: int = 0,
    ts: float = TS,
    variant: str = "chain",
    p_hard: float = 0.15,
) -> dict:
    """Serve ``n_sessions`` Poisson-arriving edge clients; returns a report.

    The report carries a ``RunStats`` with the fleet's NAV latencies and the
    verifier's batch/queue series, plus per-session TPT (simulated seconds
    per accepted token, §5.1 Metrics).  ``variant='tree'`` switches every
    client to tree drafting (width 2, node budget 16 vs the chain's window
    8 — same max depth, so the tree spends extra nodes on sibling hedges).
    ``p_hard`` sets the fleet's share of hard tokens; the default matches
    the historical chain baseline (so batched-vs-per_session rows stay
    comparable across commits), while ``compare_tree`` raises it into the
    low-acceptance regime where hedging pays.
    """
    if mode not in MODES:
        raise ValueError(f"mode must be one of {MODES}")
    if variant not in VARIANTS:
        raise ValueError(f"variant must be one of {VARIANTS}")
    edge, channel = scenario(scen)
    # Fleet tier: faster drafts + short windows. The verifier becomes the
    # contended resource (the regime §3.2's utilization argument targets):
    # per-session serving saturates at ~9 NAV/s while batching absorbs it.
    gamma = edge.effective_gamma() * 0.1
    backend = SyntheticBackend(time_scale=ts, seed=seed)
    server = CloudVerifier(
        backend,
        batch_window=(backend.verify_time * ts if mode == "batched" else 0.0),
        max_batch=(64 if mode == "batched" else 1),
    )
    rng = np.random.default_rng(seed)
    arrivals = np.cumsum(rng.exponential(1.0 / arrival_rate, n_sessions))
    clients: List[EdgeClient] = []
    for sid in range(n_sessions):
        up = Channel(ChannelConfig(alpha=channel.alpha_up, beta=channel.beta_up, time_scale=ts))
        dn = Channel(ChannelConfig(alpha=channel.alpha_dn, beta=channel.beta_dn, time_scale=ts))
        server.attach(sid, up, dn)
        cfg = EdgeConfig(time_scale=ts, gamma=gamma, window=8, nav_timeout=8.0)
        if variant == "tree":
            cfg = EdgeConfig(
                time_scale=ts, gamma=gamma, window=16, nav_timeout=8.0,
                variant="tree", tree_width=2, tree_depth=8,
            )
        clients.append(
            EdgeClient(sid, up, dn, cfg, draft=SyntheticDraft(seed=sid, p_hard=p_hard))
        )
    server.start()
    results: Dict[int, dict] = {}

    def _drive(c: EdgeClient, start_s: float) -> None:
        time.sleep(start_s * ts)  # Poisson arrival (scaled)
        results[c.session] = c.run(tokens_per_session)

    threads = [
        threading.Thread(target=_drive, args=(c, float(arrivals[i])), daemon=True)
        for i, c in enumerate(clients)
    ]
    t0 = time.monotonic()
    [t.start() for t in threads]
    [t.join(timeout=600) for t in threads]
    wall = time.monotonic() - t0
    server.stop()

    load = server.load_summary()
    stats = RunStats(
        accepted_tokens=sum(r["accepted_tokens"] for r in results.values()),
        nav_calls=load["nav_calls"],
        rounds=sum(r["rounds"] for r in results.values()),
        wall_time=wall / ts,  # de-scaled simulated seconds
        verifier_batches=load["verifier_batches"],
        verifier_queue_depths=load["verifier_queue_depths"],
        nav_latencies=[lat / ts for r in results.values() for lat in r["nav_latencies"]],
    )
    per_session_tpt = {
        sid: r["wall_time"] / ts / max(r["accepted_tokens"], 1) for sid, r in results.items()
    }
    return dict(
        mode=mode,
        variant=variant,
        scenario=scen,
        n_sessions=n_sessions,
        stats=stats,
        per_session_tpt=per_session_tpt,
        failovers=sum(r["failovers"] for r in results.values()),
        server=load,
    )


def _report_lines(rep: dict) -> List[str]:
    st: RunStats = rep["stats"]
    p50, p99 = st.nav_latency_quantiles()
    tpts = list(rep["per_session_tpt"].values())
    return [
        f"  mode={rep['mode']:<12} variant={rep['variant']:<6} sessions={rep['n_sessions']}"
        f" occupancy={st.verifier_batch_occupancy:.2f}"
        f" queue_depth={st.mean_queue_depth:.2f}",
        f"    per-session TPT mean={np.mean(tpts)*1e3:.1f}ms worst={np.max(tpts)*1e3:.1f}ms"
        f" | tokens/NAV={st.tokens_per_nav:.2f}"
        f" | NAV latency p50={p50*1e3:.1f}ms p99={p99*1e3:.1f}ms"
        f" | backend calls={rep['server']['batched_calls']}"
        f" nav={st.nav_calls} failovers={rep['failovers']}",
    ]


def compare_tree(
    scenarios=(1, 2, 3, 4), n_sessions: int = 8, mode: str = "batched", p_hard: float = 0.35
) -> dict:
    """Chain-vs-tree accepted-tokens-per-NAV across the paper's scenarios.

    Returns {scenario: {variant: report}}; both variants see the SAME hard
    confidence stream (``p_hard``) — the regime where sibling hedges rescue
    rounds a chain would end at the first rejection, so the tree variant
    should win tokens/NAV.
    """
    out: Dict[int, dict] = {}
    for scen in scenarios:
        out[scen] = {
            v: run_fleet(n_sessions=n_sessions, mode=mode, scen=scen, variant=v, p_hard=p_hard)
            for v in VARIANTS
        }
    return out


def _row(rep: dict, **extra) -> Tuple[dict, str]:
    st: RunStats = rep["stats"]
    p50, p99 = st.nav_latency_quantiles()
    tpts = list(rep["per_session_tpt"].values())
    row = dict(
        scenario=rep["scenario"],
        mode=rep["mode"],
        variant=rep["variant"],
        occupancy=st.verifier_batch_occupancy,
        tpt_ms=float(np.mean(tpts)) * 1e3,
        tokens_per_nav=st.tokens_per_nav,
        nav_p50_ms=p50 * 1e3,
        nav_p99_ms=p99 * 1e3,
        **extra,
    )
    derived = (
        f"occupancy={st.verifier_batch_occupancy:.2f};queue={st.mean_queue_depth:.2f};"
        f"tokens_per_nav={st.tokens_per_nav:.2f};"
        f"nav_p50={p50*1e3:.1f}ms;nav_p99={p99*1e3:.1f}ms;failovers={rep['failovers']}"
    )
    return row, derived


def fleet(scenarios=(1, 2, 3, 4), n_sessions: int = 8) -> Tuple[list, List[str]]:
    """Harness entry (benchmarks.run): CSV rows per scenario.

    Two row families: the historical batched-vs-per_session chain rows
    (``fleet/scenN/{mode}``, unchanged stream statistics so they stay
    comparable across commits) and the chain-vs-tree speculation comparison
    on a hard stream (``fleet/scenN/cmp/{variant}``).
    """
    rows, lines = [], []
    for scen in scenarios:
        for mode in MODES:
            rep = run_fleet(n_sessions=n_sessions, mode=mode, scen=scen)
            row, derived = _row(rep)
            rows.append(row)
            lines.append(csv_row(f"fleet/scen{scen}/{mode}", row["tpt_ms"] * 1e3, derived))
        for variant, rep in compare_tree(scenarios=(scen,), n_sessions=n_sessions)[scen].items():
            row, derived = _row(rep, p_hard=0.35)
            rows.append(row)
            lines.append(csv_row(f"fleet/scen{scen}/cmp/{variant}", row["tpt_ms"] * 1e3, derived))
    return rows, lines


def main() -> None:
    try:
        n = int(sys.argv[1]) if len(sys.argv) > 1 else 8
    except ValueError:
        sys.exit(f"usage: fleet_bench.py [n_sessions]  (got {sys.argv[1]!r})")
    print(f"=== fleet serving, {n} edge sessions, Poisson arrivals, scenario 1 ===")
    reports = {mode: run_fleet(n_sessions=n, mode=mode, scen=1) for mode in MODES}
    for mode in MODES:
        for line in _report_lines(reports[mode]):
            print(line)
    occ = reports["batched"]["stats"].verifier_batch_occupancy
    p99_solo = reports["per_session"]["stats"].nav_latency_quantiles()[1]
    p99_batch = reports["batched"]["stats"].nav_latency_quantiles()[1]
    print(
        f"batched verifier occupancy {occ:.2f} (>1 amortizes the target forward);"
        f" p99 NAV {p99_solo*1e3:.1f}ms -> {p99_batch*1e3:.1f}ms"
    )
    print(f"=== chain vs tree speculation, {n} sessions, batched serving ===")
    for scen, reps in compare_tree(n_sessions=n).items():
        for variant in VARIANTS:
            for line in _report_lines(reps[variant]):
                print(f"scen{scen}{line}")
        tc = reps["chain"]["stats"].tokens_per_nav
        tt = reps["tree"]["stats"].tokens_per_nav
        print(f"scen{scen}: tokens/NAV chain={tc:.2f} tree={tt:.2f} ({'tree' if tt > tc else 'chain'} wins)")


if __name__ == "__main__":
    main()
