"""Fleet load benchmark: N edge sessions against one continuous-batched verifier.

Drives N ≥ 8 threaded ``EdgeClient``s with Poisson arrivals through the live
``CloudVerifier`` across the paper's four scenarios (§5.1 / App. G.2), in two
serving modes:

* ``per_session`` — every NAV request is its own backend call (the seed
  behaviour: ``batch_window = 0``, ``max_batch = 1``);
* ``batched``     — continuous batching: requests coalescing within
  ``batch_window`` share ONE padded verify whose cost scales with the
  longest draft, not the sum (beyond-paper optimization #5).

and two speculation variants:

* ``chain`` — linear drafts (the PipeSD default);
* ``tree``  — top-k branching draft trees verified by batched tree-NAV; the
  hedge across siblings raises accepted-tokens-per-NAV exactly where chains
  stall (hard/low-confidence token streams), at the price of more verified
  nodes per call.

and two verifier KV layouts at a FIXED block-pool byte budget (``kv=``):

* ``flat``  — every session reserves ``KV_FLAT_MAX_LEN`` contiguous token
  slots up front (the flat ``KVCache`` behaviour, expressed inside the pool
  accounting): admission stops when reservations exhaust the budget;
* ``paged`` — on-demand pages + copy-on-write sharing of a
  ``KV_SHARED_PREFIX``-token system prompt (``models/paged_kv.py``): the
  same budget serves strictly more concurrent sessions because resident
  bytes track *actual* prefix lengths, with per-session TPT within a few
  percent of flat (the pool is bookkeeping, not compute).

Reported per (scenario, mode, variant): per-session TPT (mean/worst),
accepted-tokens-per-NAV, verifier batch occupancy, mean queue depth, and
p50/p99 NAV round-trip latency — plus, for KV runs, resident KV bytes per
session and the max concurrent resident sessions — all de-scaled to
simulated seconds and funneled through ``core.pipeline.RunStats``.

    PYTHONPATH=src python -m benchmarks.fleet_bench            # quick compare
    PYTHONPATH=src python benchmarks/fleet_bench.py            # same
    PYTHONPATH=src python -m benchmarks.run fleet              # harness CSV
"""

from __future__ import annotations

import sys
from pathlib import Path
from typing import Dict, List, Optional, Sequence, Tuple

_ROOT = Path(__file__).resolve().parent.parent
for _p in (str(_ROOT), str(_ROOT / "src")):
    if _p not in sys.path:
        sys.path.insert(0, _p)

import numpy as np

from benchmarks.common import csv_row, scenario
from repro.core.pipeline import CloudModel, RunStats
from repro.core.policy import AdaptivePolicyController, PolicyDecision
from repro.models.paged_kv import BlockPoolExhausted, PagedKVPool
from repro.runtime import (
    FAULT_MATRIX,
    Channel,
    ChannelConfig,
    CloudVerifier,
    DraftFragment,
    EdgeClient,
    EdgeConfig,
    FaultScenario,
    LinkFaults,
    LocalVerifier,
    NavRequest,
    NavResult,
    OracleBackend,
    OracleDraft,
    OracleStream,
    Router,
    SyntheticBackend,
    SyntheticDraft,
    SystemClock,
    VirtualClock,
    decode,
    encode,
)

TS = 0.01  # run the timing model 100× faster than real time
MODES = ("per_session", "batched")
VARIANTS = ("chain", "tree")

# Verifier KV geometry for the paged-vs-flat comparison: a 7B-class target
# (32 layers x 8 KV heads x 128 head_dim, bf16 k+v = 128 KiB/token) paged in
# 16-token blocks.  Flat mode reserves KV_FLAT_MAX_LEN slots per session up
# front; paged mode shares a KV_SHARED_PREFIX-token system prompt CoW.
KV_BYTES_PER_TOKEN = 2 * 32 * 8 * 128 * 2
KV_BLOCK_TOKENS = 16
KV_SHARED_PREFIX = 256
KV_FLAT_MAX_LEN = 512
KV_MODES = ("flat", "paged")


from dataclasses import dataclass  # noqa: E402  (after sys.path setup)


@dataclass(frozen=True)
class SessionProfile:
    """Per-session heterogeneity: device speed, link quality, workload mix.

    Scales are applied to the fleet's baseline draft γ, the scenario
    channel's (α, β), and the offline local-decode multiplier; ``p_hard``
    overrides the fleet default.  ``run_fleet(profiles=...)`` assigns
    profile ``sid % len(profiles)`` to session ``sid`` round-robin.
    """

    name: str
    gamma_scale: float = 1.0
    alpha_scale: float = 1.0
    beta_scale: float = 1.0
    local_gamma_scale: float = 1.0
    p_hard: Optional[float] = None


# The paper's device tiers as a mixed fleet: laptop on WiFi (Scenario 1's
# 5.1 GHz baseline), phone on 5G (2.5 GHz device, faster link), IoT board
# on 4G (1.2 GHz device, slow lossy link, harder on-device draft mix).
HETERO_PROFILES: Tuple[SessionProfile, ...] = (
    SessionProfile("laptop_wifi"),
    SessionProfile("phone_5g", gamma_scale=5.1 / 2.5, alpha_scale=0.6, beta_scale=0.5),
    SessionProfile("iot_4g", gamma_scale=5.1 / 1.2, alpha_scale=1.5, beta_scale=3.0, p_hard=0.22),
)


def _sharded_spec_backend(shards: int, seed: int):
    """The real sharded fused verifier for the fleet harness.

    A tensor-mode ``PagedKVPool`` (pages partitioned per shard on the head
    axis) plus a seeded deterministic target (queries + LM head) behind
    ``ShardedSpecVerifyBackend`` — the same geometry the serve launcher's
    ``--backend spec`` uses, sized for the fleet's session counts.  The
    returned backend carries the synthetic ``verify_time`` so the batched
    coalescing window is identical to the simulated backends'.
    """
    import jax

    from repro.runtime import ShardedSpecVerifyBackend

    H, hd, bs, V = 2, 8, 4, 256
    pool = PagedKVPool(
        num_blocks=1024, block_size=bs, n_layers=1, n_kv_heads=H, head_dim=hd
    )
    key = jax.random.PRNGKey(seed)
    w = np.asarray(jax.random.normal(jax.random.fold_in(key, 77), (H * hd, V)) * 4, np.float32)

    def query_fn(session, tokens):
        k = jax.random.fold_in(jax.random.fold_in(key, 88), session * 131 + len(tokens))
        return np.asarray(jax.random.normal(k, (len(tokens) + 1, H, hd)), np.float32)

    backend = ShardedSpecVerifyBackend(
        shards=shards, kv_pool=pool, query_fn=query_fn, lm_head=w,
        impl="ref", block_v=256,
    )
    backend.verify_time = 0.080  # align the coalescing window with SyntheticBackend
    return backend, pool


def run_fleet(
    n_sessions: int = 8,
    mode: str = "batched",
    scen: int = 1,
    tokens_per_session: int = 60,
    arrival_rate: float = 2.0,  # Poisson session arrivals [1/simulated-s]
    seed: int = 0,
    ts: float = TS,
    variant: str = "chain",
    p_hard: float = 0.15,
    kv: Optional[str] = None,
    kv_budget_bytes: Optional[int] = None,
    clock=None,
    faults: Optional[FaultScenario] = None,
    oracle: bool = False,
    nav_timeout: float = 8.0,
    backoff_init: float = 0.5,
    local_gamma: Optional[float] = None,
    shards: Optional[int] = None,
    profiles: Optional[Sequence[SessionProfile]] = None,
    policy: Optional[str] = None,
    p_hard_schedule: Optional[Tuple[Tuple[int, float], ...]] = None,
) -> dict:
    """Serve ``n_sessions`` Poisson-arriving edge clients; returns a report.

    The report carries a ``RunStats`` with the fleet's NAV latencies and the
    verifier's batch/queue series, plus per-session TPT (simulated seconds
    per accepted token, §5.1 Metrics).  ``variant='tree'`` switches every
    client to tree drafting (width 2, node budget 16 vs the chain's window
    8 — same max depth, so the tree spends extra nodes on sibling hedges).
    ``p_hard`` sets the fleet's share of hard tokens; the default matches
    the historical chain baseline (so batched-vs-per_session rows stay
    comparable across commits), while ``compare_tree`` raises it into the
    low-acceptance regime where hedging pays.

    ``kv='flat'|'paged'`` runs the verifier against a ``PagedKVPool`` sized
    at ``kv_budget_bytes``: flat mode reserves ``KV_FLAT_MAX_LEN`` tokens
    per session up front (sessions beyond the budget are REFUSED at attach —
    the report's ``n_attached`` drops below ``n_sessions``), paged mode
    allocates on demand with a CoW-shared ``KV_SHARED_PREFIX``.

    ``clock`` selects the time base: the default ``SystemClock`` measures
    wall time (historical behaviour, host-scheduler noisy); a
    ``VirtualClock`` runs the identical serving code on deterministic
    discrete-event time — bit-reproducible from ``seed``, simulated seconds
    exact, host cost near zero.  ``faults`` attaches a declarative
    ``FaultScenario`` to every client's link, and ``oracle=True`` swaps in
    the deterministic oracle draft/verifier pair so the chaos harness can
    assert the committed streams are fault-invariant.

    ``shards=N`` swaps in the REAL sharded fused verifier
    (``ShardedSpecVerifyBackend`` over an N-device host mesh, with a
    tensor-mode paged KV pool partitioned on the head axis) instead of the
    simulated backend — the dispatcher, clients, and the rest of the
    harness run unchanged, so committed streams at different shard counts
    must be identical (the dispatcher-obliviousness check in
    ``tests/test_sharded_verify.py``).  Chain variant only.

    ``profiles=`` makes the fleet heterogeneous: session ``sid`` takes
    ``profiles[sid % len(profiles)]``, scaling its draft γ, link (α, β),
    and hard-token mix (``HETERO_PROFILES`` is the paper's device tiers as
    one mixed fleet).  ``policy='adaptive'`` attaches a per-session
    ``AdaptivePolicyController`` (chain/tree/local + BO retunes on drift);
    ``p_hard_schedule`` makes every synthetic draft's hardness drift
    mid-run (deterministic), the regime the adaptive policy targets.
    """
    if mode not in MODES:
        raise ValueError(f"mode must be one of {MODES}")
    if variant not in VARIANTS:
        raise ValueError(f"variant must be one of {VARIANTS}")
    if policy not in (None, "adaptive"):
        raise ValueError(f"policy must be None or 'adaptive', got {policy!r}")
    if policy is not None and oracle:
        raise ValueError("policy= is a synthetic-fleet knob (oracle fleets pin the variant)")
    if kv is not None and kv not in KV_MODES:
        raise ValueError(f"kv must be one of {KV_MODES}")
    if oracle and variant == "tree":
        raise ValueError("oracle=True supports only variant='chain' (OracleBackend has no tree verify path)")
    clock = clock or SystemClock()
    edge, channel = scenario(scen)
    # Fleet tier: faster drafts + short windows. The verifier becomes the
    # contended resource (the regime §3.2's utilization argument targets):
    # per-session serving saturates at ~9 NAV/s while batching absorbs it.
    gamma = edge.effective_gamma() * 0.1
    kv_kwargs = {}
    if shards is not None:
        if variant != "chain":
            raise ValueError("shards= supports only variant='chain'")
        if oracle or kv is not None:
            raise ValueError("shards= brings its own tensor-mode pool (no oracle/kv)")
        backend, shard_pool = _sharded_spec_backend(shards, seed)
        kv_kwargs = dict(kv_pool=shard_pool)
    elif oracle:
        backend = OracleBackend(time_scale=ts, seed=seed, clock=clock)
    else:
        backend = SyntheticBackend(time_scale=ts, seed=seed, clock=clock)
    if kv is not None:
        budget = kv_budget_bytes or (256 * KV_BLOCK_TOKENS * KV_BYTES_PER_TOKEN)
        pool = PagedKVPool(
            max(budget // (KV_BLOCK_TOKENS * KV_BYTES_PER_TOKEN), 1),
            KV_BLOCK_TOKENS,
            bytes_per_token=KV_BYTES_PER_TOKEN,
        )
        kv_kwargs = dict(kv_pool=pool)
        if kv == "flat":
            kv_kwargs["kv_flat_reserve"] = KV_FLAT_MAX_LEN
        else:
            kv_kwargs["kv_shared_prefix"] = KV_SHARED_PREFIX
    server = CloudVerifier(
        backend,
        batch_window=(backend.verify_time * ts if mode == "batched" else 0.0),
        max_batch=(64 if mode == "batched" else 1),
        clock=clock,
        **kv_kwargs,
    )
    rng = np.random.default_rng(seed)
    arrivals = np.cumsum(rng.exponential(1.0 / arrival_rate, n_sessions))
    clients: List[EdgeClient] = []
    session_gammas: List[float] = []
    session_betas: List[float] = []
    for sid in range(n_sessions):
        prof = profiles[sid % len(profiles)] if profiles else SessionProfile("uniform")
        gamma_s = gamma * prof.gamma_scale
        beta_up_s = channel.beta_up * prof.beta_scale
        lf = (lambda d: LinkFaults(faults, d, seed=seed * 1009 + sid, time_scale=ts)) if faults else (lambda d: None)
        up = Channel(
            ChannelConfig(alpha=channel.alpha_up * prof.alpha_scale, beta=beta_up_s, time_scale=ts),
            f"up{sid}", clock=clock, faults=lf("up"),
        )
        dn = Channel(
            ChannelConfig(alpha=channel.alpha_dn * prof.alpha_scale, beta=channel.beta_dn * prof.beta_scale, time_scale=ts),
            f"dn{sid}", clock=clock, faults=lf("dn"),
        )
        try:
            server.attach(sid, up, dn)
        except BlockPoolExhausted:
            break  # flat reservation refused: the budget is full
        lg = gamma_s * local_gamma * prof.local_gamma_scale if local_gamma is not None else None
        cfg = EdgeConfig(
            time_scale=ts, gamma=gamma_s, local_gamma=lg, window=8,
            nav_timeout=nav_timeout, backoff_init=backoff_init,
        )
        if variant == "tree":
            cfg = EdgeConfig(
                time_scale=ts, gamma=gamma_s, local_gamma=lg, window=16,
                nav_timeout=nav_timeout, backoff_init=backoff_init,
                variant="tree", tree_width=2, tree_depth=8,
            )
        # Oracle fleets share ONE target stream (same prompt, same truth) so
        # the chaos harness can diff committed streams across scenarios.
        p_hard_s = prof.p_hard if prof.p_hard is not None else p_hard
        draft = (
            OracleDraft(seed=seed)
            if oracle
            else SyntheticDraft(seed=sid, p_hard=p_hard_s, p_hard_schedule=p_hard_schedule)
        )
        controller = None
        if policy == "adaptive":
            controller = AdaptivePolicyController(
                base=PolicyDecision(
                    mode=cfg.variant, r1=cfg.r1, r2=cfg.r2,
                    tree_width=cfg.tree_width, tree_depth=cfg.tree_depth,
                    window=cfg.window,
                ),
                seed=seed * 31 + sid,
                session=sid,
            )
        session_gammas.append(gamma_s)
        session_betas.append(beta_up_s)
        clients.append(EdgeClient(sid, up, dn, cfg, draft=draft, policy=controller))
    server.start()
    results: Dict[int, dict] = {}
    streams: Dict[int, List[int]] = {}

    def _drive(c: EdgeClient, start_s: float) -> None:
        clock.sleep(start_s * ts)  # Poisson arrival (scaled)
        results[c.session] = c.run(tokens_per_session)
        streams[c.session] = list(c.tokens)

    def _serve() -> float:
        handles = [
            clock.spawn(
                (lambda c=c, s=float(arrivals[i]): _drive(c, s)),
                name=f"drive-{c.session}",
            )
            for i, c in enumerate(clients)
        ]
        t0 = clock.monotonic()
        for h in handles:
            h.join(timeout=600 if not getattr(clock, "virtual", False) else None)
        wall_ = clock.monotonic() - t0
        server.stop()
        return wall_

    wall = clock.run(_serve)

    load = server.load_summary()
    # Paper's two-sided energy model (§5.3): edge joules from the per-client
    # decode/upload busy times, cloud joules from verifier busy time.  All
    # times are de-scaled back to unscaled model seconds first.
    edge_joules = sum(
        edge.edge_energy(
            r.get("draft_time_s", 0.0),
            r.get("tx_time_s", 0.0),
            r["wall_time"] / ts,
        )
        for r in results.values()
    )
    cloud = CloudModel()
    cloud_joules = (cloud.p_active - cloud.p_idle) * load.get("verify_busy_time", 0.0) / ts
    stats = RunStats(
        accepted_tokens=sum(r["accepted_tokens"] for r in results.values()),
        nav_calls=load["nav_calls"],
        rounds=sum(r["rounds"] for r in results.values()),
        wall_time=wall / ts,  # de-scaled simulated seconds
        verifier_batches=load["verifier_batches"],
        verifier_queue_depths=load["verifier_queue_depths"],
        nav_latencies=[lat / ts for r in results.values() for lat in r["nav_latencies"]],
        kv_resident_bytes=load.get("kv_bytes_series", []),
        kv_resident_sessions=load.get("kv_sessions_series", []),
        kv_cap_hits=load.get("kv_cap_hits", 0),
        failovers=sum(r["failovers"] for r in results.values()),
        fallback_tokens=sum(r["fallback_tokens"] for r in results.values()),
        lost_draft_tokens=sum(r["lost_draft_tokens"] for r in results.values()),
        recovery_latencies=[
            lat / ts for r in results.values() for lat in r["recovery_latencies"]
        ],
        cloud_energy=cloud_joules,
        edge_energy=edge_joules,
        session_gammas=session_gammas[: len(clients)],
        session_betas=session_betas[: len(clients)],
    )
    per_session_tpt = {
        sid: r["wall_time"] / ts / max(r["accepted_tokens"], 1) for sid, r in results.items()
    }
    # Client sessions concurrently holding pages (the shared-prefix owner is
    # pool-resident but not a client).
    kv_max_clients = load.get("kv_max_resident_sessions", 0)
    if kv == "paged" and KV_SHARED_PREFIX > 0:
        kv_max_clients = max(kv_max_clients - 1, 0)
    return dict(
        mode=mode,
        variant=variant,
        kv=kv,
        scenario=scen,
        n_sessions=n_sessions,
        n_attached=len(clients),
        kv_max_clients=kv_max_clients,
        stats=stats,
        per_session_tpt=per_session_tpt,
        failovers=stats.failovers,
        streams=streams,
        server=load,
        policy_mode_switches=sum(r.get("policy_mode_switches", 0) for r in results.values()),
        policy_retunes=sum(r.get("policy_retunes", 0) for r in results.values()),
    )


def _report_lines(rep: dict) -> List[str]:
    st: RunStats = rep["stats"]
    p50, p99 = st.nav_latency_quantiles()
    tpts = list(rep["per_session_tpt"].values()) or [float("nan")]
    lines = [
        f"  mode={rep['mode']:<12} variant={rep['variant']:<6} sessions={rep['n_sessions']}"
        f" occupancy={st.verifier_batch_occupancy:.2f}"
        f" queue_depth={st.mean_queue_depth:.2f}",
        f"    per-session TPT mean={np.mean(tpts)*1e3:.1f}ms worst={np.max(tpts)*1e3:.1f}ms"
        f" | tokens/NAV={st.tokens_per_nav:.2f}"
        f" | NAV latency p50={p50*1e3:.1f}ms p99={p99*1e3:.1f}ms"
        f" | backend calls={rep['server']['batched_calls']}"
        f" nav={st.nav_calls} failovers={rep['failovers']}",
    ]
    if rep.get("kv"):
        lines.append(
            f"    kv={rep['kv']:<5} attached={rep['n_attached']}/{rep['n_sessions']}"
            f" max_resident={rep['kv_max_clients']}"
            f" | resident mean={st.mean_kv_resident_bytes/2**20:.0f}MiB"
            f" peak={st.peak_kv_resident_bytes/2**20:.0f}MiB"
            f" per-session={st.kv_bytes_per_session/2**20:.1f}MiB"
            f" | shared_blocks={rep['server'].get('kv_shared_blocks', 0)}"
            f" cow={rep['server'].get('kv_cow_copies', 0)}"
            f" evictions={rep['server'].get('kv_evictions', 0)}"
            f" parked={rep['server'].get('kv_parked', 0)}"
        )
    return lines


def compare_kv(
    n_sessions: int = 16,
    scen: int = 1,
    kv_budget_bytes: Optional[int] = None,
    tokens_per_session: int = 60,
) -> dict:
    """Paged vs flat verifier KV at one fixed block-pool byte budget.

    Three runs: ``flat`` (attaches only as many sessions as ``max_len``
    reservations fit the budget), ``paged`` with the SAME offered fleet
    (serves strictly more concurrent sessions from the same bytes), and
    ``paged_matched`` at flat's session count — the apples-to-apples TPT
    comparison.  Wall-clock TPT from the threaded runtime is noisy (host
    scheduler jitter swamps single runs), so the robust parity evidence is
    the measured **bookkeeping share**: the pool's total mutation host-time
    (``kv_op_seconds``) as a fraction of serving wall time bounds the TPT
    cost paging can add, and stays far under 5% (the deterministic
    simulation engine shows exact parity — ``tests/test_paged_kv.py``).
    Returns ``{name: report}`` plus the budget and per-run overhead bounds.
    """
    budget = kv_budget_bytes or (
        (n_sessions // 2) * (KV_FLAT_MAX_LEN // KV_BLOCK_TOKENS)
        * KV_BLOCK_TOKENS * KV_BYTES_PER_TOKEN
    )
    common = dict(
        scen=scen, mode="batched", kv_budget_bytes=budget, tokens_per_session=tokens_per_session
    )
    flat = run_fleet(n_sessions=n_sessions, kv="flat", **common)
    paged = run_fleet(n_sessions=n_sessions, kv="paged", **common)
    # A budget below one flat reservation admits zero sessions; the matched
    # paged run still needs >= 1 client to produce a well-formed report.
    matched = run_fleet(n_sessions=max(flat["n_attached"], 1), kv="paged", **common)
    out = dict(flat=flat, paged=paged, paged_matched=matched, kv_budget_bytes=budget)
    for name in ("flat", "paged", "paged_matched"):
        rep = out[name]
        host_wall = rep["stats"].wall_time * TS  # de-scaled back to host seconds
        rep["kv_overhead_frac"] = rep["server"].get("kv_op_seconds", 0.0) / max(host_wall, 1e-9)
    return out


def compare_tree(
    scenarios=(1, 2, 3, 4), n_sessions: int = 8, mode: str = "batched", p_hard: float = 0.35
) -> dict:
    """Chain-vs-tree accepted-tokens-per-NAV across the paper's scenarios.

    Returns {scenario: {variant: report}}; both variants see the SAME hard
    confidence stream (``p_hard``) — the regime where sibling hedges rescue
    rounds a chain would end at the first rejection, so the tree variant
    should win tokens/NAV.
    """
    out: Dict[int, dict] = {}
    for scen in scenarios:
        out[scen] = {
            v: run_fleet(n_sessions=n_sessions, mode=mode, scen=scen, variant=v, p_hard=p_hard)
            for v in VARIANTS
        }
    return out


def run_chaos(
    scenarios: Tuple[FaultScenario, ...] = FAULT_MATRIX,
    n_sessions: int = 4,
    tokens_per_session: int = 120,
    seed: int = 0,
    scen: int = 1,
) -> dict:
    """Chaos mode: the oracle fleet under every fault scenario, virtually.

    Each scenario serves ``n_sessions`` oracle clients on a fresh
    ``VirtualClock`` with the scenario's faults on every link, and reports
    offline-robustness metrics in exact simulated seconds: failovers,
    fallback share, **recovery latency** (failover → next verified round)
    and **tokens lost per outage** (drafted tokens whose round was abandoned,
    divided by the scenario's outage windows).  ``conformant`` asserts the
    paper's robustness claim end-to-end: every session's committed stream is
    bit-identical to the oracle (≡ the fault-free stream).  Runs are
    bit-reproducible from ``seed`` — the CI chaos job diffs two of them.
    """
    oracle_ref = OracleStream(seed)
    out: Dict[str, dict] = {}
    for fs in scenarios:
        rep = run_fleet(
            n_sessions=n_sessions,
            mode="batched",
            scen=scen,
            tokens_per_session=tokens_per_session,
            seed=seed,
            ts=1.0,  # virtual seconds are free — run the model at true scale
            clock=VirtualClock(),
            faults=fs,
            oracle=True,
            nav_timeout=1.0,
            backoff_init=0.1,
            local_gamma=8.0,  # offline full-model decode is ~8x slower
        )
        st: RunStats = rep["stats"]
        n_outages = len(fs.outage_windows("up")) + len(fs.outage_windows("dn"))
        rep["scenario_name"] = fs.name
        rep["conformant"] = all(
            stream == oracle_ref.prefix(len(stream)) and len(stream) >= tokens_per_session
            for stream in rep["streams"].values()
        )
        # Per-outage attribution only makes sense when the scenario HAS
        # outage windows; lossy-but-outage-free scenarios report 0 here and
        # their abandoned drafts via ``lost_draft_tokens`` directly.
        rep["n_outages"] = n_outages
        rep["tokens_lost_per_outage"] = (
            st.lost_draft_tokens / n_outages if n_outages else 0.0
        )
        rep["recovery_latency_s"] = st.mean_recovery_latency
        out[fs.name] = rep
    return out


def _chaos_lines(reports: dict) -> List[str]:
    lines = []
    for name, rep in reports.items():
        st: RunStats = rep["stats"]
        lost = (
            f" lost/outage={rep['tokens_lost_per_outage']:.0f}"
            if rep["n_outages"]
            else f" lost_drafts={st.lost_draft_tokens}"
        )
        lines.append(
            f"  {name:<18} conformant={rep['conformant']}"
            f" failovers={st.failovers}"
            f" fallback={st.fallback_fraction*100:.0f}%"
            f" recovery={st.mean_recovery_latency*1e3:.0f}ms"
            + lost
            + f" navs={st.nav_calls} wall={st.wall_time:.1f}s"
        )
    return lines


def chaos(n_sessions: int = 4, seed: int = 0) -> Tuple[list, List[str]]:
    """Harness entry (benchmarks.run): one CSV row per fault scenario.

    Deterministic by construction (virtual clock + seeded everything): two
    invocations with the same arguments emit byte-identical rows, which is
    exactly what the CI chaos job diffs.
    """
    reports = run_chaos(n_sessions=n_sessions, seed=seed)
    rows, lines = [], []
    for name, rep in reports.items():
        st: RunStats = rep["stats"]
        row = dict(
            scenario_name=name,
            conformant=rep["conformant"],
            failovers=st.failovers,
            fallback_fraction=st.fallback_fraction,
            recovery_latency_s=st.mean_recovery_latency,
            lost_draft_tokens=st.lost_draft_tokens,
            n_outages=rep["n_outages"],
            tokens_lost_per_outage=rep["tokens_lost_per_outage"],
            wall_time_s=st.wall_time,
        )
        rows.append(row)
        derived = (
            f"conformant={rep['conformant']};failovers={st.failovers};"
            f"fallback_pct={st.fallback_fraction*100:.1f};"
            f"recovery_ms={st.mean_recovery_latency*1e3:.1f};"
            f"lost_drafts={st.lost_draft_tokens};"
            f"lost_per_outage={rep['tokens_lost_per_outage']:.1f};"
            f"navs={st.nav_calls};wall_s={st.wall_time:.3f}"
        )
        lines.append(csv_row(f"chaos/{name}", st.wall_time * 1e6, derived))
    return rows, lines


def codec_bench(n_iters: int = 50_000) -> Tuple[list, List[str]]:
    """Wire-codec overhead: encode+decode round-trip cost per message.

    Times the three messages that dominate serving traffic (a 16-token
    ``DraftFragment``, a ``NavRequest``, a ``NavResult``) and reports
    ns/message plus frame bytes.  The sanity bound the row exists to check:
    codec time per *drafted token* must sit orders of magnitude below the
    link's per-token serialization cost (Hockney β = 2 ms at the paper's
    operating point), i.e. framing is never the serving bottleneck.
    """
    import time

    msgs = {
        "draft16": DraftFragment(
            session=1, seq=7, round=3,
            tokens=tuple(range(1000, 1016)), confs=tuple(0.5 + 0.03 * i for i in range(16)),
        ),
        "nav_request": NavRequest(session=1, seq=8, round=3, n_tokens=16, deadline=1.25, pos=640),
        "nav_result": NavResult(session=1, seq=8, n_accepted=12, correction=31337, n_drafted=16),
    }
    rows, lines = [], []
    for name, msg in msgs.items():
        frame = encode(msg)
        assert decode(frame) == msg  # round-trip exact, every run
        t0 = time.perf_counter()
        for _ in range(n_iters):
            decode(encode(msg))
        dt = time.perf_counter() - t0
        ns_per_msg = dt / n_iters * 1e9
        # ``host_`` prefix: wall-time measurement, host-noisy by nature —
        # bench_diff treats it as informational (skipped in comparisons).
        row = dict(message=name, host_ns_per_msg=ns_per_msg, frame_bytes=len(frame))
        rows.append(row)
        derived = f"ns_per_msg={ns_per_msg:.0f};frame_bytes={len(frame)};iters={n_iters}"
        lines.append(csv_row(f"fleet/codec/{name}", ns_per_msg * 1e-3, derived))
    return rows, lines


# --------------------------------------------------------------------------- #
# Router scaling: N verifiers behind the control plane
# --------------------------------------------------------------------------- #


class _MeteredChannel(Channel):
    """A ``Channel`` that counts encoded wire bytes on send."""

    def __init__(self, *args, **kwargs) -> None:
        super().__init__(*args, **kwargs)
        self.bytes_sent = 0

    def send(self, msg) -> None:
        self.bytes_sent += len(encode(msg))
        super().send(msg)


def run_router_fleet(
    n_verifiers: int,
    n_sessions: int = 16,
    tokens_per_session: int = 60,
    seed: int = 0,
    traced: bool = False,
) -> dict:
    """Serve an oracle fleet through the ``Router`` over ``n_verifiers``.

    The regime is deliberately verifier-bound: per-session serving
    (``batch_window = 0``) with a verify cost that dominates the round, a
    fast edge draft, and enough sessions to saturate the largest fleet —
    so aggregate throughput scales ~linearly with fleet size and the bench
    measures the control plane's placement spread, not batching effects.

    Everything runs on one ``VirtualClock``: the report is bit-reproducible
    from ``seed`` and throughput is exact simulated tokens/second.  Every
    committed stream is asserted against the oracle before reporting —
    a routed fleet that scales but mis-commits would fail here, not in CI.

    ``traced=True`` attaches a ``repro.obs`` span tracer + metric registry
    (on the SAME virtual clock) to every verifier, client, and the router.
    Because tracing only *reads* the virtual clock, a traced run's committed
    rows are bit-identical to the untraced run — the ``router/x1_traced``
    row in ``BENCH_fleet.json`` is that overhead gate, committed.  The
    report gains ``n_spans`` plus private ``_tracer``/``_metrics`` handles
    (underscored: stripped before rows are written).
    """
    clock = VirtualClock()
    tracer = metrics = None
    if traced:
        from repro.obs.metrics import MetricRegistry
        from repro.obs.trace import Tracer

        tracer = Tracer(clock=clock)
        metrics = MetricRegistry(clock=clock)
    oracle_ref = OracleStream(seed)
    fleet = []
    for vid in range(n_verifiers):
        backend = OracleBackend(
            seed=seed, verify_time=0.06, verify_time_per_token=0.002, clock=clock
        )
        cv = CloudVerifier(
            backend, batch_window=0.0, max_batch=1, clock=clock,
            tracer=tracer, metrics=metrics, verifier_id=vid,
        )
        cv.start()
        fleet.append(LocalVerifier(vid, cv, clock=clock))
    router = Router(fleet, clock=clock, control_interval=1.0, tracer=tracer)
    link = ChannelConfig(alpha=0.005, beta=0.0005)
    clients: List[EdgeClient] = []
    channels: List[_MeteredChannel] = []
    for sid in range(n_sessions):
        up = _MeteredChannel(link, f"up{sid}", clock=clock)
        dn = _MeteredChannel(link, f"dn{sid}", clock=clock)
        channels.extend((up, dn))
        router.attach(sid, up, dn)
        cfg = EdgeConfig(gamma=0.004, window=8, nav_timeout=30.0)
        clients.append(
            EdgeClient(sid, up, dn, cfg, draft=OracleDraft(seed=seed), tracer=tracer)
        )
    results: Dict[int, dict] = {}
    streams: Dict[int, List[int]] = {}

    def _drive(c: EdgeClient) -> None:
        results[c.session] = c.run(tokens_per_session)
        streams[c.session] = list(c.tokens)

    def _serve() -> float:
        router.start()
        handles = [
            clock.spawn((lambda c=c: _drive(c)), name=f"drive-{c.session}")
            for c in clients
        ]
        t0 = clock.monotonic()
        for h in handles:
            h.join()
        wall_ = clock.monotonic() - t0
        router.stop()
        for vc in fleet:
            vc.stop()
        return wall_

    wall = clock.run(_serve)

    for sid, stream in streams.items():
        assert len(stream) >= tokens_per_session and stream == oracle_ref.prefix(
            len(stream)
        ), f"routed session {sid} diverged from the oracle"
    placed: Dict[int, int] = {vid: 0 for vid in range(n_verifiers)}
    for sid in range(n_sessions):
        placed[router.sessions[sid].verifier] += 1
    accepted = sum(r["accepted_tokens"] for r in results.values())
    navs = sum(r["rounds"] for r in results.values())
    lats = sorted(lat for r in results.values() for lat in r["nav_latencies"])
    p50 = lats[len(lats) // 2] if lats else float("nan")
    p99 = lats[min(len(lats) - 1, int(len(lats) * 0.99))] if lats else float("nan")
    rep = dict(
        n_verifiers=n_verifiers,
        n_sessions=n_sessions,
        tokens_per_s=accepted / wall,
        tokens_per_nav=accepted / max(navs, 1),
        nav_p50_ms=p50 * 1e3,
        nav_p99_ms=p99 * 1e3,
        bytes_per_session=sum(ch.bytes_sent for ch in channels) / n_sessions,
        placement=placed,
        spread=max(placed.values()) - min(placed.values()),
        failovers=sum(r["failovers"] for r in results.values()),
        wall_s=wall,
        router_stats=dict(router.stats),
    )
    if traced:
        rep["n_spans"] = len(tracer)
        rep["_tracer"] = tracer
        rep["_metrics"] = metrics
    return rep


def router_bench(verifier_counts: Tuple[int, ...] = (1, 2, 4)) -> Dict[int, dict]:
    """Router scaling sweep: ``{n_verifiers: report}`` with speedups vs x1.

    The acceptance bar (ISSUE / CI): >= 1.7x aggregate throughput at 2
    verifiers and >= 3x at 4, in the verifier-bound regime above.
    """
    out: Dict[int, dict] = {}
    for n in verifier_counts:
        out[n] = run_router_fleet(n)
    base = out[min(out)]["tokens_per_s"]
    for rep in out.values():
        rep["speedup"] = rep["tokens_per_s"] / base
    return out


def _router_lines(reports: Dict[int, dict]) -> List[str]:
    lines = []
    for n, rep in sorted(reports.items()):
        lines.append(
            f"  x{n} verifiers: {rep['tokens_per_s']:.1f} tok/s"
            f" ({rep['speedup']:.2f}x) spread={rep['spread']}"
            f" tokens/NAV={rep['tokens_per_nav']:.2f}"
            f" nav p50={rep['nav_p50_ms']:.1f}ms p99={rep['nav_p99_ms']:.1f}ms"
            f" bytes/session={rep['bytes_per_session']:.0f}"
            f" failovers={rep['failovers']}"
        )
    return lines


def router(verifier_counts: Tuple[int, ...] = (1, 2, 4)) -> Tuple[list, List[str]]:
    """Harness entry (benchmarks.run): one CSV row per fleet size.

    ``us_per_call`` is microseconds per committed token (1e6 / tokens/s), so
    smaller is better and the x1 -> x4 drop IS the scaling claim.  Rows are
    deterministic (virtual clock, oracle fleet): this is what lands in
    ``BENCH_fleet.json``.
    """
    reports = router_bench(verifier_counts)
    rows, lines = [], []
    for n, rep in sorted(reports.items()):
        row = dict(
            n_verifiers=n,
            n_sessions=rep["n_sessions"],
            tokens_per_s=rep["tokens_per_s"],
            speedup=rep["speedup"],
            tokens_per_nav=rep["tokens_per_nav"],
            nav_p50_ms=rep["nav_p50_ms"],
            nav_p99_ms=rep["nav_p99_ms"],
            bytes_per_session=rep["bytes_per_session"],
            placement_spread=rep["spread"],
            failovers=rep["failovers"],
        )
        rows.append(row)
        derived = (
            f"tokens_per_s={rep['tokens_per_s']:.1f};speedup={rep['speedup']:.2f};"
            f"spread={rep['spread']};tokens_per_nav={rep['tokens_per_nav']:.2f};"
            f"nav_p50_ms={rep['nav_p50_ms']:.1f};nav_p99_ms={rep['nav_p99_ms']:.1f};"
            f"bytes_per_session={rep['bytes_per_session']:.0f};"
            f"failovers={rep['failovers']}"
        )
        lines.append(csv_row(f"fleet/router/x{n}", 1e6 / rep["tokens_per_s"], derived))
    return rows, lines


def export_fleet_trace(seed: int = 0) -> str:
    """Chrome-trace JSON of a seeded, traced router fleet run.

    The export is a pure function of ``seed``: spans are stamped off the
    run's ``VirtualClock`` and serialized with sorted keys, so two calls
    with the same seed return byte-identical JSON on any host — the CI
    obs-smoke job diffs exactly that.
    """
    rep = run_router_fleet(2, n_sessions=8, tokens_per_session=30, seed=seed, traced=True)
    return rep["_tracer"].export_chrome_trace()


def fleet_committed() -> Tuple[list, List[str]]:
    """Harness entry (benchmarks.run): every row family of BENCH_fleet.json.

    Four families, all deterministic except where marked:

    * ``router/*`` — the scaling sweep (``router()`` rows, unchanged);
    * ``router/x1_traced`` — the tracing overhead gate: the SAME x1 run
      with the full obs stack attached.  Tracing only reads the virtual
      clock, so ``tokens_per_s`` must equal the untraced x1 row exactly
      (``overhead_pct == 0.0`` committed — far inside the <2% budget);
    * ``chaos/*`` — per-fault-scenario recovery/fallback counters
      (recovery latency, lost drafts, failovers: the chaos contract);
    * ``codec/*`` — frame sizes (exact) + ``host_ns_per_msg`` (wall-time,
      informational: bench_diff skips ``host_``-prefixed fields).
    """
    rows, lines = router()
    untraced = next(r["tokens_per_s"] for r in rows if r["n_verifiers"] == 1)
    rep = run_router_fleet(1, traced=True)
    overhead_pct = (untraced - rep["tokens_per_s"]) / untraced * 100.0
    rows.append(
        dict(
            name="router/x1_traced",
            tokens_per_s=rep["tokens_per_s"],
            tokens_per_nav=rep["tokens_per_nav"],
            nav_p50_ms=rep["nav_p50_ms"],
            nav_p99_ms=rep["nav_p99_ms"],
            n_spans=rep["n_spans"],
            overhead_pct=overhead_pct,
        )
    )
    lines.append(
        csv_row(
            "fleet/router/x1_traced",
            1e6 / rep["tokens_per_s"],
            f"tokens_per_s={rep['tokens_per_s']:.1f};n_spans={rep['n_spans']};"
            f"overhead_pct={overhead_pct:.3f}",
        )
    )
    chaos_rows, chaos_lines = chaos()
    rows.extend(chaos_rows)
    lines.extend(chaos_lines)
    codec_rows, codec_lines = codec_bench()
    rows.extend(codec_rows)
    lines.extend(codec_lines)
    return rows, lines


def _row(rep: dict, **extra) -> Tuple[dict, str]:
    st: RunStats = rep["stats"]
    p50, p99 = st.nav_latency_quantiles()
    tpts = list(rep["per_session_tpt"].values()) or [float("nan")]
    row = dict(
        scenario=rep["scenario"],
        mode=rep["mode"],
        variant=rep["variant"],
        occupancy=st.verifier_batch_occupancy,
        tpt_ms=float(np.mean(tpts)) * 1e3,
        tokens_per_nav=st.tokens_per_nav,
        nav_p50_ms=p50 * 1e3,
        nav_p99_ms=p99 * 1e3,
        **extra,
    )
    derived = (
        f"occupancy={st.verifier_batch_occupancy:.2f};queue={st.mean_queue_depth:.2f};"
        f"tokens_per_nav={st.tokens_per_nav:.2f};"
        f"nav_p50={p50*1e3:.1f}ms;nav_p99={p99*1e3:.1f}ms;failovers={rep['failovers']}"
    )
    return row, derived


def fleet(scenarios=(1, 2, 3, 4), n_sessions: int = 8) -> Tuple[list, List[str]]:
    """Harness entry (benchmarks.run): CSV rows per scenario.

    Three row families: the historical batched-vs-per_session chain rows
    (``fleet/scenN/{mode}``, unchanged stream statistics so they stay
    comparable across commits), the chain-vs-tree speculation comparison
    on a hard stream (``fleet/scenN/cmp/{variant}``), and the paged-vs-flat
    verifier-KV comparison at a fixed pool budget (``fleet/kv/{layout}``,
    scenario 1).
    """
    rows, lines = [], []
    for scen in scenarios:
        for mode in MODES:
            rep = run_fleet(n_sessions=n_sessions, mode=mode, scen=scen)
            row, derived = _row(rep)
            rows.append(row)
            lines.append(csv_row(f"fleet/scen{scen}/{mode}", row["tpt_ms"] * 1e3, derived))
        for variant, rep in compare_tree(scenarios=(scen,), n_sessions=n_sessions)[scen].items():
            row, derived = _row(rep, p_hard=0.35)
            rows.append(row)
            lines.append(csv_row(f"fleet/scen{scen}/cmp/{variant}", row["tpt_ms"] * 1e3, derived))
    kv_reps = compare_kv(n_sessions=2 * n_sessions)
    for name in ("flat", "paged", "paged_matched"):
        rep = kv_reps[name]
        st: RunStats = rep["stats"]
        row, derived = _row(rep)
        row.update(
            kv=name,
            kv_max_clients=rep["kv_max_clients"],
            kv_bytes_per_session=st.kv_bytes_per_session,
            kv_peak_bytes=st.peak_kv_resident_bytes,
        )
        rows.append(row)
        derived += (
            f";kv_max_clients={rep['kv_max_clients']};attached={rep['n_attached']}"
            f";kv_per_session_mib={st.kv_bytes_per_session/2**20:.1f}"
            f";kv_peak_mib={st.peak_kv_resident_bytes/2**20:.0f}"
            f";kv_overhead_pct={rep['kv_overhead_frac']*100:.2f}"
        )
        lines.append(csv_row(f"fleet/kv/{name}", row["tpt_ms"] * 1e3, derived))
    codec_rows, codec_lines = codec_bench()
    rows.extend(codec_rows)
    lines.extend(codec_lines)
    return rows, lines


def main() -> None:
    if len(sys.argv) > 1 and sys.argv[1] == "router":
        # Deterministic router-scaling report (virtual clock, oracle fleet).
        print("=== router scaling, 16 oracle sessions, per-session serving ===")
        for line in _router_lines(router_bench()):
            print(line)
        return
    if len(sys.argv) > 1 and sys.argv[1] == "trace":
        # Seeded Chrome-trace export (virtual clock): byte-identical across
        # runs/hosts for a given seed — the CI obs-smoke job diffs two of
        # these.  Usage: fleet_bench.py trace OUT.json [seed]
        if len(sys.argv) < 3:
            sys.exit("usage: fleet_bench.py trace OUT.json [seed]")
        try:
            seed = int(sys.argv[3]) if len(sys.argv) > 3 else 0
        except ValueError:
            sys.exit(f"usage: fleet_bench.py trace OUT.json [seed]  (got {sys.argv[3]!r})")
        blob = export_fleet_trace(seed=seed)
        Path(sys.argv[2]).write_text(blob)
        print(f"TRACE {sys.argv[2]} {len(blob)} bytes seed={seed}")
        return
    if len(sys.argv) > 1 and sys.argv[1] == "chaos":
        # Deterministic chaos report (virtual clock): every printed value is
        # a pure function of the seed, so CI diffs two runs byte-for-byte.
        try:
            seed = int(sys.argv[2]) if len(sys.argv) > 2 else 0
        except ValueError:
            sys.exit(f"usage: fleet_bench.py [chaos [seed] | n_sessions]  (got {sys.argv[2]!r})")
        print(f"=== chaos matrix, oracle fleet, virtual clock, seed {seed} ===")
        for line in _chaos_lines(run_chaos(seed=seed)):
            print(line)
        return
    try:
        n = int(sys.argv[1]) if len(sys.argv) > 1 else 8
    except ValueError:
        sys.exit(f"usage: fleet_bench.py [chaos [seed] | n_sessions]  (got {sys.argv[1]!r})")
    print(f"=== fleet serving, {n} edge sessions, Poisson arrivals, scenario 1 ===")
    reports = {mode: run_fleet(n_sessions=n, mode=mode, scen=1) for mode in MODES}
    for mode in MODES:
        for line in _report_lines(reports[mode]):
            print(line)
    occ = reports["batched"]["stats"].verifier_batch_occupancy
    p99_solo = reports["per_session"]["stats"].nav_latency_quantiles()[1]
    p99_batch = reports["batched"]["stats"].nav_latency_quantiles()[1]
    print(
        f"batched verifier occupancy {occ:.2f} (>1 amortizes the target forward);"
        f" p99 NAV {p99_solo*1e3:.1f}ms -> {p99_batch*1e3:.1f}ms"
    )
    print(f"=== chain vs tree speculation, {n} sessions, batched serving ===")
    for scen, reps in compare_tree(n_sessions=n).items():
        for variant in VARIANTS:
            for line in _report_lines(reps[variant]):
                print(f"scen{scen}{line}")
        tc = reps["chain"]["stats"].tokens_per_nav
        tt = reps["tree"]["stats"].tokens_per_nav
        print(f"scen{scen}: tokens/NAV chain={tc:.2f} tree={tt:.2f} ({'tree' if tt > tc else 'chain'} wins)")
    kv_reps = compare_kv(n_sessions=2 * n)
    budget = kv_reps["kv_budget_bytes"]
    print(f"=== paged vs flat verifier KV, {2*n} offered sessions, {budget/2**20:.0f}MiB pool ===")
    for name in ("flat", "paged", "paged_matched"):
        for line in _report_lines(kv_reps[name]):
            print(f"{name:<14}{line}")
    flat_cap = kv_reps["flat"]["n_attached"]
    paged_cap = kv_reps["paged"]["kv_max_clients"]
    tpt_flat = float(np.mean(list(kv_reps["flat"]["per_session_tpt"].values())))
    tpt_match = float(np.mean(list(kv_reps["paged_matched"]["per_session_tpt"].values())))
    print(
        f"same {budget/2**20:.0f}MiB budget: flat serves {flat_cap} sessions, paged serves"
        f" {paged_cap} ({'paged' if paged_cap > flat_cap else 'flat'} wins);"
        f" matched-load TPT {tpt_flat*1e3:.0f}ms vs {tpt_match*1e3:.0f}ms"
        f" (wall-clock, scheduler-noisy); pool bookkeeping"
        f" {kv_reps['paged_matched']['kv_overhead_frac']*100:.2f}% of serving time"
        f" bounds the paging TPT cost (sim parity is exact)"
    )
    codec_rows, _ = codec_bench(n_iters=20_000)
    print("=== wire-codec overhead (encode+decode round trip) ===")
    for row in codec_rows:
        ns = row["host_ns_per_msg"]
        per_tok_ns = ns / 16 if row["message"] == "draft16" else ns
        print(
            f"  {row['message']:<12} {ns:>8.0f} ns/msg"
            f" {row['frame_bytes']:>4d} B/frame"
            f"  ({per_tok_ns/2e6*100:.4f}% of the 2ms/token link budget)"
        )


if __name__ == "__main__":
    main()
