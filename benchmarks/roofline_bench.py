"""Roofline benchmark: renders the §Roofline table from dry-run artifacts."""

from __future__ import annotations

from pathlib import Path
from typing import List, Tuple

from repro.roofline import format_table, roofline_table

from .common import csv_row

RESULTS_DIR = Path(__file__).parent.parent / "dryrun_results"


def _fused_verify_rows() -> Tuple[list, List[str]]:
    """Single-launch fused verify vs the two-launch composition on the HBM
    roofline — same traffic model as ``kernel_bench``, surfaced here so the
    roofline table shows the launch-count claim next to the dryrun cells."""
    from .kernel_bench import GEOM, LAUNCH_S, _verify_traffic

    from repro.roofline.hw import HBM_BW

    rows, lines = [], []
    base = None
    for variant in ("composed", "fused"):
        m = _verify_traffic(variant)
        t = m["bytes"] / HBM_BW + m["launches"] * LAUNCH_S
        base = base or t
        rows.append(dict(
            arch="v5e", shape=f"spec_verify/{variant}", dominant="memory",
            launches=m["launches"], modeled_us=round(t * 1e6, 3),
            speedup_vs_composed=round(base / t, 4),
        ))
        lines.append(csv_row(
            f"roofline/spec_verify/{variant}", t * 1e6,
            f"launches={m['launches']};B={GEOM['batch']};K={GEOM['k_draft']};"
            f"bytes={m['bytes']};speedup={base / t:.2f}x",
        ))
    return rows, lines


def roofline() -> Tuple[list, List[str]]:
    fv_rows, fv_lines = _fused_verify_rows()
    rows, lines = [], []
    if not RESULTS_DIR.exists():
        return (
            [dict(note="dryrun_results/ missing — run repro.launch.dryrun --all")] + fv_rows,
            [csv_row("roofline/missing", 0.0, "run_dryrun_first")] + fv_lines,
        )
    cells = roofline_table(RESULTS_DIR, mesh="pod")
    for c in cells:
        rows.append(dict(arch=c.arch, shape=c.shape, dominant=c.dominant,
                         compute_ms=round(c.compute_corrected_s * 1e3, 3),
                         memory_ms=round(c.memory_s * 1e3, 3),
                         collective_ms=round(c.collective_s * 1e3, 3),
                         roofline_frac=round(c.roofline_fraction(), 4),
                         useful_ratio=round(c.useful_ratio, 3)))
        lines.append(csv_row(
            f"roofline/{c.arch}/{c.shape}", c.bound_time() * 1e6,
            f"dominant={c.dominant};frac={c.roofline_fraction():.3f};useful={c.useful_ratio:.2f};"
            f"compute={c.compute_corrected_s*1e3:.2f}ms;mem={c.memory_s*1e3:.2f}ms;coll={c.collective_s*1e3:.2f}ms",
        ))
    return rows + fv_rows, lines + fv_lines
