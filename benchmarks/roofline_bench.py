"""Roofline benchmark: renders the §Roofline table from dry-run artifacts."""

from __future__ import annotations

from pathlib import Path
from typing import List, Tuple

from repro.roofline import format_table, roofline_table

from .common import csv_row

RESULTS_DIR = Path(__file__).parent.parent / "dryrun_results"


def roofline() -> Tuple[list, List[str]]:
    rows, lines = [], []
    if not RESULTS_DIR.exists():
        return [dict(note="dryrun_results/ missing — run repro.launch.dryrun --all")], [
            csv_row("roofline/missing", 0.0, "run_dryrun_first")
        ]
    cells = roofline_table(RESULTS_DIR, mesh="pod")
    for c in cells:
        rows.append(dict(arch=c.arch, shape=c.shape, dominant=c.dominant,
                         compute_ms=round(c.compute_corrected_s * 1e3, 3),
                         memory_ms=round(c.memory_s * 1e3, 3),
                         collective_ms=round(c.collective_s * 1e3, 3),
                         roofline_frac=round(c.roofline_fraction(), 4),
                         useful_ratio=round(c.useful_ratio, 3)))
        lines.append(csv_row(
            f"roofline/{c.arch}/{c.shape}", c.bound_time() * 1e6,
            f"dominant={c.dominant};frac={c.roofline_fraction():.3f};useful={c.useful_ratio:.2f};"
            f"compute={c.compute_corrected_s*1e3:.2f}ms;mem={c.memory_s*1e3:.2f}ms;coll={c.collective_s*1e3:.2f}ms",
        ))
    return rows, lines
