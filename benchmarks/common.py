"""Shared benchmark scaffolding: scenarios, datasets, timing helpers.

Scenario constants mirror §5.1: edge CPU 5.1 GHz (laptop) / 2.5 GHz (phone) /
1.2 GHz (IoT); 20 Mbps up / 200 Mbps down (static) or the Scenario-4
fluctuating trace.  "Datasets" select the calibrated confidence statistics:
HumanEval-like (code — high confidence) and GSM8K-like (math — harder).
"""

from __future__ import annotations

import json
import time
from pathlib import Path
from typing import Dict, Optional

from repro.core.pipeline import (
    ChannelModel,
    CloudModel,
    EdgeModel,
    PipelineEngine,
    SyntheticSource,
    make_framework,
    periodic_bandwidth_trace,
)

METHODS = ("vanilla", "hsl", "edgellm", "pipesd")

DATASETS: Dict[str, dict] = {
    # p_hard/kappa calibrated so Table-7-style statistics land in the paper's
    # regime (PipeSD: len≈5, acc≈0.92-0.96; HSL: len≈2.5-3, freq≈0.26-0.30).
    "humaneval": dict(p_hard=0.15, kappa=0.8, seed=42),
    "gsm8k": dict(p_hard=0.22, kappa=0.9, seed=43),
}

# Per-task method parameters, mirroring §5.1 ("N=6 for programming and N=4
# for mathematical reasoning", HSL thresholds 0.99 / 0.7, and PipeSD's
# BO-tuned (R1, R2) per task).
METHOD_PARAMS: Dict[str, Dict[str, dict]] = {
    "humaneval": {
        "vanilla": dict(trigger_kw=dict(n=6)),
        "hsl": dict(trigger_kw=dict(r=0.99)),
        "edgellm": {},
        "pipesd": dict(trigger_kw=dict(r1=0.5, r2=0.5)),
    },
    "gsm8k": {
        "vanilla": dict(trigger_kw=dict(n=4)),
        "hsl": dict(trigger_kw=dict(r=0.7)),
        "edgellm": {},
        "pipesd": dict(trigger_kw=dict(r1=0.3, r2=0.4)),
    },
}


def scenario(idx: int, bw_seed: int = 3):
    """Returns (EdgeModel, ChannelModel) for paper scenarios 1–4."""
    if idx == 1:
        return EdgeModel(), ChannelModel()
    if idx == 2:
        return EdgeModel(simulated_ghz=2.5), ChannelModel()
    if idx == 3:
        return EdgeModel(simulated_ghz=1.2), ChannelModel()
    if idx == 4:
        return EdgeModel(), ChannelModel(bandwidth_trace=periodic_bandwidth_trace(bw_seed))
    raise ValueError(idx)


def run_method(
    method: str,
    dataset: str = "humaneval",
    scen: int = 1,
    n_tokens: int = 1000,
    seed: int = 7,
    autotune: Optional[bool] = None,
    cloud: Optional[CloudModel] = None,
    **fw_overrides,
):
    edge, channel = scenario(scen)
    base = dict(METHOD_PARAMS.get(dataset, {}).get(method, {}))
    base.update(fw_overrides)
    if autotune is not None:
        base["autotune"] = autotune
    spec = make_framework(method, **base)
    eng = PipelineEngine(
        spec, channel, cloud or CloudModel(), edge, SyntheticSource(**DATASETS[dataset]), seed=seed
    )
    t0 = time.perf_counter()
    stats = eng.run(n_tokens)
    host = time.perf_counter() - t0
    return eng, stats, host


def csv_row(name: str, us_per_call: float, derived: str) -> str:
    return f"{name},{us_per_call:.3f},{derived}"


def round_metrics(rows: list, ndigits: int = 6):
    """Round every float in a list of benchmark row dicts to ``ndigits``.

    Committed BENCH_*.json files are diffed across commits; raw floats
    carry ~1-ulp noise from summation order (e.g. virtual-clock quantile
    math emitting ``1007.5000000000074``) that turns every regeneration
    into a spurious diff.  Six digits is far below any tolerance the CI
    bench-diff applies, and far above the noise floor.
    """

    def _round(v):
        if isinstance(v, float):
            return round(v, ndigits)
        if isinstance(v, dict):
            return {k: _round(x) for k, x in v.items()}
        if isinstance(v, (list, tuple)):
            return [_round(x) for x in v]
        return v

    return [_round(r) for r in rows]


def write_bench_json(area: str, rows: list, root: Optional[Path] = None) -> Path:
    """Commit a benchmark's rows as ``BENCH_<area>.json`` at the repo root.

    The file is the stable, diffable record of a deterministic benchmark
    (virtual clock + seeded everything): re-running the bench on any host
    must reproduce it byte-for-byte, which is what makes it safe to commit.
    Floats are rounded (``round_metrics``) so regeneration is noise-free.
    """
    out = (root or Path(__file__).resolve().parent.parent) / f"BENCH_{area}.json"
    payload = {"version": 1, "area": area, "rows": round_metrics(rows)}
    out.write_text(json.dumps(payload, indent=2, sort_keys=True) + "\n")
    return out
