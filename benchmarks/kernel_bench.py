"""Kernel-level benchmark: fused spec-verify vs the two-launch composition.

Three row families, all committed as ``BENCH_kernels.json``:

``kernels/kv/{fp32,int8}``
    Paged-KV residency accounting straight from ``PagedKVPool`` (no model):
    bytes/token and bytes/session at the reference serving geometry.  The
    int8 row must show >= 1.5x lower bytes/session than fp32 — that ratio
    is the headline claim of the quantized pool and the CI bench-diff
    keeps it pinned.

``kernels/verify/{composed,fused,fused_int8}``
    A deterministic HBM-traffic model of one verify round (B sessions,
    K drafts) on the v5e roofline (``repro.roofline.hw.HBM_BW``):

    * composed — two launches (paged decode attention + logits, then the
      accept/reject scan) with the [B, K+1, V] logits tensor making a
      full HBM round trip between them;
    * fused — one launch (``spec_verify_fused``): logits live in VMEM
      tile-by-tile and never touch HBM;
    * fused_int8 — the fused launch reading int8 pages + f32 page params.

    ``tokens_per_s`` and ``bw_frac`` are modeled (bytes / HBM_BW + launch
    overhead), so the rows are bit-reproducible on every host.  The CSV
    additionally reports live interpret-mode wall-clock for the same
    shapes (measured-vs-achievable bandwidth); those lines are diagnostic
    and deliberately NOT part of the committed JSON.

``kernels/shard/spec_verify/{1,2,4}``
    The tensor-parallel fused verify (``repro.sharding.spec_verify``) at
    1/2/4 shards: per-shard HBM + ICI all-gather traffic on the same
    roofline, modeled tokens/s, and the pool's resident bytes per shard.
"""

from __future__ import annotations

import time
from typing import List, Tuple

from .common import csv_row

# Reference serving geometry (paper-scale 7B-ish verifier, one edge fleet).
GEOM = dict(
    n_layers=8, n_kv_heads=8, head_dim=128, block_size=16,
    seq=512, batch=8, k_draft=4, vocab=32000,
)
LAUNCH_S = 5e-6  # fixed per-launch dispatch overhead in the model


def _kv_rows() -> Tuple[list, List[str]]:
    from repro.models.paged_kv import PagedKVPool

    rows, lines = [], []
    per_tok = {}
    for mode in ("fp32", "int8"):
        pool = PagedKVPool(
            num_blocks=64,
            block_size=GEOM["block_size"],
            n_layers=GEOM["n_layers"],
            n_kv_heads=GEOM["n_kv_heads"],
            head_dim=GEOM["head_dim"],
            quantize=None if mode == "fp32" else "int8",
        )
        per_tok[mode] = pool.bytes_per_token
        per_session = pool.bytes_per_token * GEOM["seq"]
        rows.append(dict(
            name=f"kernels/kv/{mode}",
            bytes_per_token=pool.bytes_per_token,
            bytes_per_session=per_session,
        ))
        lines.append(csv_row(
            f"kernels/kv/{mode}", 0.0,
            f"bytes_per_token={pool.bytes_per_token};bytes_per_session={per_session}",
        ))
    ratio = per_tok["fp32"] / per_tok["int8"]
    rows.append(dict(name="kernels/kv/ratio", fp32_over_int8=round(ratio, 4)))
    lines.append(csv_row("kernels/kv/ratio", 0.0, f"fp32_over_int8={ratio:.2f}x"))
    assert ratio >= 1.5, f"int8 pool must cut bytes/session >=1.5x (got {ratio:.2f})"
    return rows, lines


def _verify_traffic(variant: str) -> dict:
    """HBM bytes moved by one verify round, per the kernel's access pattern."""
    L1 = 1  # the verify launch touches one layer's pages (layer-0 serving KV)
    H, hd, bs = GEOM["n_kv_heads"], GEOM["head_dim"], GEOM["block_size"]
    B, K1, V = GEOM["batch"], GEOM["k_draft"] + 1, GEOM["vocab"]
    F = H * hd
    n_pages = -(-GEOM["seq"] // bs)
    kv_elt = 1 + 8 / hd if "int8" in variant else 4  # int8 payload + f32 params
    kv = 2 * L1 * B * n_pages * bs * H * hd * kv_elt  # K and V page streams
    q = B * K1 * F * 4
    w = B * F * V * 4  # LM-head tile stream, no cross-batch reuse in-kernel
    o = B * K1 * F * 4  # attention output
    logits_hbm = 2 * B * K1 * V * 4  # write + read between the two launches
    launches = 1 if variant.startswith("fused") else 2
    if launches == 1:
        total = kv + q + w + 2 * 4 * B * K1  # outputs: n_acc/corr + logp
    else:
        total = kv + q + w + 2 * o + logits_hbm + 2 * 4 * B * K1
    return dict(bytes=int(total), launches=launches)


def _verify_rows() -> Tuple[list, List[str]]:
    from repro.roofline.hw import HBM_BW

    rows, lines = [], []
    B, K1 = GEOM["batch"], GEOM["k_draft"] + 1
    base_time = None
    for variant in ("composed", "fused", "fused_int8"):
        m = _verify_traffic(variant)
        t = m["bytes"] / HBM_BW + m["launches"] * LAUNCH_S
        bw_frac = (m["bytes"] / t) / HBM_BW
        tok_s = B * K1 / t
        if base_time is None:
            base_time = t
        rows.append(dict(
            name=f"kernels/verify/{variant}",
            launches=m["launches"],
            hbm_bytes=m["bytes"],
            modeled_us=round(t * 1e6, 3),
            tokens_per_s=round(tok_s, 1),
            bw_frac=round(bw_frac, 4),
            speedup_vs_composed=round(base_time / t, 4),
        ))
        lines.append(csv_row(
            f"kernels/verify/{variant}", t * 1e6,
            f"launches={m['launches']};bytes={m['bytes']};"
            f"tokens_per_s={tok_s:.0f};bw_frac={bw_frac:.3f};"
            f"speedup={base_time / t:.2f}x",
        ))
    return rows, lines


def _shard_rows() -> Tuple[list, List[str]]:
    """Modeled roofline for the SHARDED fused verify at 1/2/4 shards.

    Per-shard HBM traffic divides along the head axis (KV pages and
    queries; the reference 8 kv heads split 1/2/4 evenly) and the vocab
    axis (LM-head tile stream).  Keeping the ONE-launch contract across
    shards adds two all-gathers on the ICI — attention outputs [B, K1, F]
    after the head split and per-shard logits tiles [B, K1, V/N] after the
    vocab split — modeled as ring traffic at ``ICI_LINK_BW``.  Resident
    bytes/shard comes straight from ``PagedKVPool.resident_bytes_per_shard``
    on the reference serving pool, so the committed rows pin both the
    throughput scaling AND the per-device memory win.
    """
    from repro.models.paged_kv import PagedKVPool
    from repro.roofline.hw import HBM_BW, ICI_LINK_BW

    H, hd, bs = GEOM["n_kv_heads"], GEOM["head_dim"], GEOM["block_size"]
    B, K1, V = GEOM["batch"], GEOM["k_draft"] + 1, GEOM["vocab"]
    F = H * hd
    n_pages = -(-GEOM["seq"] // bs)
    pool = PagedKVPool(
        num_blocks=64, block_size=bs, n_layers=GEOM["n_layers"],
        n_kv_heads=H, head_dim=hd,
    )
    pool.create(0)
    pool.append(0, GEOM["seq"])  # one reference resident session
    rows, lines = [], []
    t1 = None
    prev_tok_s = 0.0
    for n in (1, 2, 4):
        assert pool.shard_axes(n), "reference geometry must split evenly"
        kv = 2 * B * n_pages * bs * H * hd * 4 // n  # local head slice
        q = B * K1 * F * 4 // n
        w = B * F * V * 4 // n  # per-shard vocab tiles
        out = 2 * 4 * B * K1  # replicated n_acc/corr + logp
        hbm = kv + q + w + out
        gather = (B * K1 * F * 4 * (n - 1)) // n  # head all-gather (ring)
        gather += B * K1 * (V // n) * 4 * (n - 1)  # vocab all-gather
        t = hbm / HBM_BW + gather / ICI_LINK_BW + LAUNCH_S  # still ONE launch
        t1 = t if t1 is None else t1
        tok_s = B * K1 / t
        resident = pool.resident_bytes_per_shard(n)
        rows.append(dict(
            name=f"kernels/shard/spec_verify/{n}",
            shards=n,
            launches=1,
            hbm_bytes_per_shard=hbm,
            ici_bytes_per_shard=gather,
            resident_bytes_per_shard=resident,
            modeled_us=round(t * 1e6, 3),
            tokens_per_s=round(tok_s, 1),
            speedup_vs_1shard=round(t1 / t, 4),
        ))
        lines.append(csv_row(
            f"kernels/shard/spec_verify/{n}", t * 1e6,
            f"shards={n};hbm_bytes={hbm};ici_bytes={gather};"
            f"resident_bytes_per_shard={resident};tokens_per_s={tok_s:.0f};"
            f"speedup={t1 / t:.2f}x",
        ))
        assert tok_s > prev_tok_s, "sharding must not lose modeled throughput"
        prev_tok_s = tok_s
    return rows, lines


def _measured_lines() -> List[str]:
    """Live interpret-mode timing: measured vs achievable bandwidth.

    Small geometry (interpret mode is a CPU emulator); the point is the
    measured-GB/s column next to the 819 GB/s roofline, not the absolute
    numbers.  Not committed — wall-clock is host-dependent.
    """
    import jax
    import jax.numpy as jnp
    import numpy as np

    from repro.kernels.decode_attention import paged_decode_attention
    from repro.kernels.spec_verify import fused_target_logits, spec_verify, spec_verify_fused
    from repro.roofline.hw import HBM_BW

    B, K, H, hd, bs, NB, V = 2, 3, 2, 16, 4, 8, 256
    K1, F = K + 1, H * hd
    key = jax.random.PRNGKey(0)
    ks = jax.random.split(key, 6)
    k_pages = jax.random.normal(ks[0], (NB, bs, H, hd), jnp.float32)
    v_pages = jax.random.normal(ks[1], (NB, bs, H, hd), jnp.float32)
    q = jax.random.normal(ks[2], (B, K1, H, hd), jnp.float32)
    w = jax.random.normal(ks[3], (F, V), jnp.float32) * 4
    tables = jnp.asarray([[0, 1], [2, 3]], jnp.int32)
    base = np.asarray([5, 7])
    lengths = jnp.asarray(base[:, None] + np.arange(K1)[None, :], jnp.int32)
    toks = jax.random.randint(ks[4], (B, K), 0, V, jnp.int32)
    nd = jnp.full((B,), K, jnp.int32)

    def _fused():
        return spec_verify_fused(
            q, k_pages, v_pages, w, tables, lengths, toks, nd,
            impl="interpret", block_v=256,
        )

    def _composed():
        o = paged_decode_attention(
            q.reshape(B * K1, H, hd), k_pages, v_pages,
            jnp.repeat(tables, K1, axis=0), lengths.reshape(-1), impl="interpret",
        ).reshape(B, K1, F).astype(jnp.float32)
        logits = fused_target_logits(o, w, block_v=256, v_true=V)
        return spec_verify(logits, toks, nd, impl="interpret", block_v=256)

    na_f, _, _ = _fused()
    na_c, _, _ = _composed()
    np.testing.assert_array_equal(np.asarray(na_f), np.asarray(na_c))

    approx_bytes = (k_pages.nbytes + v_pages.nbytes + q.nbytes + B * w.nbytes)
    lines = []
    for name, fn in (("fused", _fused), ("composed", _composed)):
        t0 = time.perf_counter()
        jax.block_until_ready(fn())
        dt = time.perf_counter() - t0
        gbs = approx_bytes / dt / 1e9
        lines.append(csv_row(
            f"kernels/measured/{name}", dt * 1e6,
            f"interpret;measured_GBps={gbs:.3f};achievable_GBps={HBM_BW / 1e9:.0f};"
            f"frac={gbs / (HBM_BW / 1e9):.2e}",
        ))
    return lines


def kernels() -> Tuple[list, List[str]]:
    """Harness entry (benchmarks.run): committed rows + diagnostic CSV."""
    kv_rows, kv_lines = _kv_rows()
    v_rows, v_lines = _verify_rows()
    s_rows, s_lines = _shard_rows()
    return kv_rows + v_rows + s_rows, kv_lines + v_lines + s_lines + _measured_lines()
