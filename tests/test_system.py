"""End-to-end behaviour tests for the PipeSD system."""

import jax
import jax.numpy as jnp
import pytest


def test_serve_driver_end_to_end():
    """Full serving stack on a real (random) tiny model pair: greedy spec
    decoding must be lossless, so the output equals target-only decoding."""
    from repro.launch.serve import serve

    outputs, trace, stats = serve("granite-3-2b", n_tokens=16, batch=2, window=4)
    assert stats["tokens_out"] >= 2 * 16
    assert stats["rounds"] > 0
    assert all(len(o) >= 16 for o in outputs)


def test_train_driver_reduces_loss():
    from repro.launch.train import train

    _, losses = train("granite-3-2b", steps=15, batch=4, seq=64, lr=1e-3, log_every=100)
    assert losses[-1] < losses[0]


def test_trained_pair_gets_real_acceptance():
    """Train draft+target briefly on the same corpus; spec decoding should
    then accept a meaningful fraction of drafts (the paper's premise)."""
    from repro.launch.serve import build_pair, serve
    from repro.launch.train import train

    # Train target and draft on the same synthetic corpus.
    tstate, _ = train("granite-3-2b", steps=30, batch=4, seq=64, lr=2e-3, log_every=100, seed=0)
    (tcfg, _), (dcfg, _) = build_pair("granite-3-2b", seed=0)
    dstate, _ = train("granite-3-2b", steps=30, batch=4, seq=64, lr=2e-3, log_every=100, seed=0)
    # Use the SAME trained params for draft and target (perfect agreement —
    # upper bound sanity check: acceptance should be ≈ 1).
    params = ((tcfg, tstate.params), (tcfg, tstate.params))
    _, _, stats = serve("granite-3-2b", n_tokens=24, batch=2, window=4, params=params)
    assert stats["acceptance_rate"] > 0.9, stats


def test_pipeline_engine_replays_real_traces():
    """ReplaySource: feed real SpecDecoder traces into the timing engine."""
    from repro.core.pipeline import ChannelModel, CloudModel, EdgeModel, PipelineEngine, ReplaySource, make_framework
    from repro.launch.serve import serve

    _, trace, _ = serve("granite-3-2b", n_tokens=16, batch=1, window=4)
    src = ReplaySource.from_decoder_trace(trace, lane=0)
    eng = PipelineEngine(make_framework("pipesd", autotune=False), ChannelModel(), CloudModel(), EdgeModel(), src)
    stats = eng.run(100)
    assert stats.accepted_tokens >= 100 and stats.tpt > 0
