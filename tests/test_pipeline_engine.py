"""Event-driven pipeline engine: paper-qualitative behaviour + invariants."""

import pytest

from repro.core.pipeline import (
    ChannelModel,
    CloudModel,
    EdgeModel,
    PipelineEngine,
    SyntheticSource,
    make_framework,
    periodic_bandwidth_trace,
)


def _run(name, ghz=None, trace=None, n=600, seed=7, **overrides):
    eng = PipelineEngine(
        make_framework(name, autotune=False, **overrides),
        ChannelModel(bandwidth_trace=trace),
        CloudModel(),
        EdgeModel(simulated_ghz=ghz),
        SyntheticSource(seed=42),
        seed=seed,
    )
    return eng.run(n)


def test_pipesd_beats_all_baselines_scenario1():
    tpts = {n: _run(n).tpt for n in ("vanilla", "hsl", "edgellm", "pipesd")}
    assert tpts["pipesd"] < tpts["vanilla"]
    assert tpts["pipesd"] < tpts["hsl"]
    assert tpts["pipesd"] < tpts["edgellm"]
    # Speedups in the paper's reported range (1.16–2.16×).
    for base in ("vanilla", "hsl", "edgellm"):
        assert 1.0 < tpts[base] / tpts["pipesd"] < 2.5


@pytest.mark.parametrize("ghz", [2.5, 1.2])
def test_pipesd_best_on_slow_edges(ghz):
    tpts = {n: _run(n, ghz=ghz).tpt for n in ("vanilla", "hsl", "edgellm", "pipesd")}
    assert min(tpts, key=tpts.get) == "pipesd"


def test_dynamic_bandwidth_scenario():
    tr = periodic_bandwidth_trace(seed=3)
    tpts = {n: _run(n, trace=tr).tpt for n in ("vanilla", "pipesd")}
    assert tpts["pipesd"] < tpts["vanilla"]


def test_pipeline_ablation_helps():
    """Table 6: full PipeSD beats PipeSD w/o pipeline and w/ fixed trigger."""
    full = _run("pipesd").tpt
    no_pipe = _run("pipesd_no_pipeline").tpt
    fixed = _run("pipesd_fixed").tpt
    assert full < no_pipe
    assert full < fixed


def test_spec_stats_in_paper_regime():
    """Table 7: PipeSD ~5-token drafts, ~0.9+ acceptance, freq ~0.17-0.2."""
    st = _run("pipesd", n=1500)
    assert 3.0 <= st.mean_draft_length <= 8.0
    assert 0.85 <= st.acceptance_rate <= 1.0
    assert 0.10 <= st.verification_frequency <= 0.30
    # HSL: conservative — shorter drafts, more frequent NAV (paper Table 7).
    hsl = _run("hsl", n=1500)
    assert hsl.mean_draft_length < st.mean_draft_length
    assert hsl.verification_frequency > st.verification_frequency


def test_energy_accounting():
    st = _run("pipesd", n=800)
    expected = st.cloud_energy / st.accepted_tokens * 100
    assert st.ecs_cloud == pytest.approx(expected)
    assert st.ecs_cloud > 0


def test_accounting_invariants():
    st = _run("pipesd", n=500)
    assert st.accepted_tokens >= 500
    assert st.accepted_drafts <= st.drafted_tokens
    assert st.nav_calls == st.rounds
    assert st.wall_time > 0
    # Output tokens = accepted drafts + one correction per round.
    assert st.accepted_tokens == st.accepted_drafts + st.rounds


def test_autotuner_improves_or_matches_default():
    default = _run("pipesd", n=800).tpt
    eng = PipelineEngine(
        make_framework("pipesd"),  # autotune on
        ChannelModel(), CloudModel(), EdgeModel(), SyntheticSource(seed=42), seed=7,
    )
    tuned = eng.run(800).tpt
    assert tuned <= default * 1.15  # BO shouldn't be much worse, usually better
    assert eng.tuned_thresholds is not None


# ----------------------------------------------------------------- trees ----


def test_tree_round_accounting_invariants():
    st = _run("tree", n=400)
    assert st.accepted_tokens >= 400
    assert st.nav_calls == st.rounds
    assert st.accepted_tokens == st.accepted_drafts + st.rounds
    # Tree bookkeeping: one node-count and one depth entry per round, depth
    # bounded by the spec's tree_depth and acceptance bounded by depth.
    assert len(st.tree_nodes) == st.rounds == len(st.tree_depths)
    spec = make_framework("tree")
    assert all(1 <= d <= spec.tree_depth for d in st.tree_depths)
    assert all(n >= d for n, d in zip(st.tree_nodes, st.tree_depths))
    assert st.mean_tree_nodes > 0 and st.mean_tree_depth > 0
    assert st.tokens_per_nav == pytest.approx(st.accepted_tokens / st.nav_calls)


def test_tree_raises_tokens_per_nav_on_hard_streams():
    """The tree's reason to exist: on low-acceptance confidence streams the
    sibling hedge commits strictly more tokens per verification call."""
    hard = dict(p_hard=0.4, kappa=1.5, seed=42)
    chain = PipelineEngine(
        make_framework("pipesd", autotune=False),
        ChannelModel(), CloudModel(), EdgeModel(), SyntheticSource(**hard), seed=7,
    ).run(500)
    tree = PipelineEngine(
        make_framework("tree", autotune=False),
        ChannelModel(), CloudModel(), EdgeModel(), SyntheticSource(**hard), seed=7,
    ).run(500)
    assert tree.tokens_per_nav > chain.tokens_per_nav


def test_tree_autotuner_tunes_width_and_depth():
    eng = PipelineEngine(
        make_framework("tree"),  # autotune on → 4-dim search space
        ChannelModel(), CloudModel(), EdgeModel(), SyntheticSource(seed=42), seed=7,
        autotune_samples=6, autotune_tokens_per_sample=12,
    )
    eng.run(120)
    assert eng.tuned_thresholds is not None
    assert 1 <= eng.spec.tree_width <= 4
    assert 2 <= eng.spec.tree_depth <= 10
    # The tuned thresholds are live in the spec the tree rounds read.
    assert eng.spec.trigger_kw["r1"] == pytest.approx(eng.tuned_thresholds[0])
