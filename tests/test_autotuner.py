"""BO autotuner: convergence vs baselines + GP sanity + persistence."""

import numpy as np
import pytest

from repro.core.autotuner import BOAutotuner, grid_search, random_search


def bowl(x, y):
    return (x - 0.62) ** 2 + (y - 0.31) ** 2


def test_bo_beats_random_given_same_budget():
    wins = 0
    for seed in range(6):
        bo = BOAutotuner(seed=seed).minimize(bowl, 16)
        rs = random_search(bowl, n_trials=16, seed=seed)
        wins += bo.y <= rs.y
    assert wins >= 4  # BO should win most seeds on a smooth bowl


def test_bo_near_optimal_16_samples():
    best = BOAutotuner(seed=3).minimize(bowl, 16)
    assert best.y < 0.02  # near the optimum of 0


def test_grid_search_is_16_points():
    calls = []
    grid_search(lambda x, y: calls.append((x, y)) or bowl(x, y))
    assert len(calls) == 16
    xs = sorted({c[0] for c in calls})
    assert len(xs) == 4  # 4×4 grid


def test_observe_rejects_nonfinite():
    bo = BOAutotuner(seed=0)
    with pytest.raises(ValueError):
        bo.observe((0.5, 0.5), float("nan"))


def test_suggest_within_bounds():
    bo = BOAutotuner(seed=1)
    for _ in range(8):
        x = bo.suggest()
        assert all(0.0 <= v <= 1.0 for v in x)
        bo.observe(x, bowl(*x))


def test_state_roundtrip():
    bo = BOAutotuner(seed=0)
    bo.minimize(bowl, 8)
    state = bo.state_dict()
    bo2 = BOAutotuner.from_state_dict(state)
    assert bo2.best().y == bo.best().y
    # Restored tuner keeps improving.
    bo2.minimize(bowl, 4)
    assert bo2.best().y <= bo.best().y
