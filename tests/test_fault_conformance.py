"""Fault-injection conformance suite (offline robustness, deterministically).

The contract, for every scenario in ``runtime.faults.FAULT_MATRIX``:

* the committed token stream is **bit-identical** to the fault-free run (and
  to the oracle ground truth) — speculative decoding against an oracle-true
  verifier is lossless, and the edge's local-decode fallback continues the
  same stream offline;
* two runs with the same seed produce **identical** stats, latencies, fault
  counters, and final virtual time — the whole runtime runs on the virtual
  clock with zero wall-clock dependence (enforced by a grep guard below).
"""

import re
from pathlib import Path

import pytest

from repro.models.paged_kv import PagedKVPool
from repro.runtime import (
    BUNDLED_TRACES,
    FAULT_MATRIX,
    ROUTER_FAULT_MATRIX,
    TRACE_MATRIX,
    Channel,
    ChannelConfig,
    CloudVerifier,
    EdgeClient,
    EdgeConfig,
    FaultScenario,
    FleetFullError,
    LinkFaults,
    LocalVerifier,
    OracleBackend,
    OracleDraft,
    OracleStream,
    Phase,
    Router,
    VirtualClock,
    scenario_by_name,
)
from repro.runtime.protocol import DraftFragment, Heartbeat, NavRequest, Reset

N_TOKENS = 150
SCENARIO_IDS = [s.name for s in FAULT_MATRIX]


def _edge_cfg(**kw):
    base = dict(gamma=0.02, nav_timeout=0.4, backoff_init=0.05, backoff_max=0.4)
    base.update(kw)
    return EdgeConfig(**base)


def run_scenario(
    scenario,
    seed=7,
    n_tokens=N_TOKENS,
    kv_pool_blocks=None,
    kv_shared_prefix=0,
    session_timeout=30.0,
    **edge_kw,
):
    """One seeded virtual-clock serving run; returns (stream, report)."""
    clock = VirtualClock()
    pool = None
    kv_kwargs = {}
    if kv_pool_blocks is not None:
        pool = PagedKVPool(kv_pool_blocks, 16, bytes_per_token=1024)
        kv_kwargs = dict(kv_pool=pool, kv_shared_prefix=kv_shared_prefix)
    server = CloudVerifier(
        OracleBackend(seed=seed, clock=clock),
        batch_window=0.01,
        clock=clock,
        session_timeout=session_timeout,
        **kv_kwargs,
    )
    lf = (lambda d: LinkFaults(scenario, d, seed=seed)) if scenario is not None else (lambda d: None)
    up = Channel(ChannelConfig(alpha=0.02, beta=0.002), "up", clock=clock, faults=lf("up"))
    dn = Channel(ChannelConfig(alpha=0.01, beta=0.0005), "dn", clock=clock, faults=lf("dn"))
    server.attach(0, up, dn)
    client = EdgeClient(0, up, dn, _edge_cfg(**edge_kw), draft=OracleDraft(seed=seed))

    def body():
        server.start()
        stats = client.run(n_tokens)
        server.stop()
        return stats

    stats = clock.run(body)
    report = dict(
        stats=stats,
        server_stats=dict(server.stats),
        up_stats=dict(up.stats),
        dn_stats=dict(dn.stats),
        verifier_batches=server.monitor.verifier_batches(),
        end_time=clock.monotonic(),
        kv_length=(pool.length(0) if pool is not None and 0 in pool.tables else None),
    )
    return list(client.tokens), report


@pytest.fixture(scope="module")
def fault_free():
    stream, report = run_scenario(None)
    assert stream == OracleStream(7).prefix(len(stream))  # oracle ground truth
    assert report["stats"]["failovers"] == 0
    return stream, report


@pytest.mark.parametrize("scenario", FAULT_MATRIX, ids=SCENARIO_IDS)
def test_stream_bit_identical_to_fault_free(scenario, fault_free):
    """Every matrix scenario recovers: same committed tokens as no faults."""
    ref_stream, ref_report = fault_free
    stream, report = run_scenario(scenario)
    n = min(len(stream), len(ref_stream))
    assert n >= N_TOKENS
    assert stream[:n] == ref_stream[:n]
    # The faults must actually have fired, or the conformance check above
    # proved nothing about this scenario.
    lossy = any(
        p.outage or p.drop_prob > 0 or p.dup_prob > 0 or p.reorder_prob > 0
        for p in scenario.up + scenario.dn
    )
    degraded = any(p.bandwidth_factor != 1.0 for p in scenario.up + scenario.dn)
    if lossy:
        assert (
            sum(report["up_stats"][k] + report["dn_stats"][k]
                for k in ("dropped", "duplicated", "reordered")) > 0
        )
    elif degraded:  # bandwidth-only: β collapse must be visible in the tail
        assert max(report["stats"]["nav_latencies"]) > max(
            ref_report["stats"]["nav_latencies"]
        )


@pytest.mark.parametrize("scenario", FAULT_MATRIX, ids=SCENARIO_IDS)
def test_seeded_runs_are_bit_reproducible(scenario):
    """Same seed -> identical stream, stats, fault draws, and virtual time."""
    a = run_scenario(scenario, seed=3)
    b = run_scenario(scenario, seed=3)
    assert a == b


def test_outage_scenarios_fail_over_and_recover():
    """The outage windows force NAV-timeout -> local decode -> re-attach."""
    for name in ("dn_outage", "double_outage"):
        stream, report = run_scenario(scenario_by_name(name))
        st = report["stats"]
        assert st["failovers"] >= 1
        assert st["fallback_tokens"] > 0  # offline progress was made
        assert st["recovery_latencies"], name  # ... and the cloud came back
        assert len(st["recovery_times"]) == len(st["recovery_latencies"])
        assert stream == OracleStream(7).prefix(len(stream))


def test_bandwidth_ramp_stretches_nav_latency_without_failover():
    """β degradation slows NAV round-trips but never breaks the session."""
    _, clean = run_scenario(None)
    _, ramp = run_scenario(scenario_by_name("bandwidth_ramp"))
    assert ramp["stats"]["failovers"] == 0
    assert max(ramp["stats"]["nav_latencies"]) > max(clean["stats"]["nav_latencies"])


# --------------------------------------------------------------------------- #
# Legacy ChannelConfig fault branches (drop_prob / outage), previously untested
# --------------------------------------------------------------------------- #


def test_channel_drop_prob_branch_is_seeded_and_lossy():
    """cfg.drop_prob loses messages from the channel's own seeded RNG."""
    clock = VirtualClock()
    ch = Channel(ChannelConfig(alpha=0.01, beta=0.001, drop_prob=0.5, seed=11), clock=clock)

    def body():
        for i in range(40):
            ch.send(Heartbeat(0, seq=i))
        got = []
        while (m := ch.recv(timeout=5.0)) is not None:
            got.append(m.seq)
        return got

    got = clock.run(body)
    assert 0 < len(got) < 40
    assert ch.stats["dropped"] == 40 - len(got)
    assert got == sorted(got)  # survivors still arrive in order
    # Seeded: an identically-built channel drops the same messages.
    clock2 = VirtualClock()
    ch2 = Channel(ChannelConfig(alpha=0.01, beta=0.001, drop_prob=0.5, seed=11), clock=clock2)

    def body2():
        for i in range(40):
            ch2.send(Heartbeat(0, seq=i))
        got = []
        while (m := ch2.recv(timeout=5.0)) is not None:
            got.append(m.seq)
        return got

    assert clock2.run(body2) == got


def test_channel_outage_window_branch():
    """cfg.outage drops exactly the sends whose link slot falls in the window."""
    clock = VirtualClock()
    ch = Channel(ChannelConfig(alpha=0.1, beta=0.0, outage=(0.25, 0.55)), clock=clock)

    def body():
        delivered = []
        for i in range(6):  # link slots start at 0.0, 0.1, ..., 0.5
            ch.send(Heartbeat(0, seq=i))
        while (m := ch.recv(timeout=5.0)) is not None:
            delivered.append(m.seq)
        return delivered

    # Slots 0.3, 0.4, 0.5 fall inside [0.25, 0.55) -> messages 3, 4, 5 lost.
    assert clock.run(body) == [0, 1, 2]
    assert ch.stats["dropped"] == 3


def test_legacy_knobs_compose_with_explicit_fault_schedules():
    """A channel with BOTH an explicit FaultScenario and legacy drop_prob
    gets one composed fault path: either layer can lose a message, and the
    per-layer seeded draws stay independent."""
    clock = VirtualClock()
    scen = FaultScenario("half_drop", up=(Phase(0.0, 100.0, drop_prob=0.5),))
    ch = Channel(
        ChannelConfig(alpha=0.01, beta=0.001, drop_prob=0.5, seed=11),
        "up",
        clock=clock,
        faults=LinkFaults(scen, "up", seed=11),
    )
    from repro.runtime import ComposedLinkFaults

    assert isinstance(ch.faults, ComposedLinkFaults)

    def body():
        for i in range(60):
            ch.send(Heartbeat(0, seq=i))
        got = []
        while (m := ch.recv(timeout=5.0)) is not None:
            got.append(m.seq)
        return got

    got = clock.run(body)
    # Both layers fire: survivors ~25%, strictly fewer than one layer alone.
    assert 0 < len(got) < 30
    assert got == sorted(got)
    assert ch.stats["dropped"] == 60 - len(got)
    # The composed view sums the per-layer counters.
    assert ch.faults.stats["dropped"] == ch.stats["dropped"]


def test_legacy_outage_failover_path_on_virtual_clock():
    """The pre-faults API (ChannelConfig.outage on the downlink) still drives
    NAV timeout -> local decode -> recovery, now deterministically."""
    clock = VirtualClock()
    server = CloudVerifier(OracleBackend(seed=5, clock=clock), clock=clock)
    up = Channel(ChannelConfig(alpha=0.02, beta=0.002), "up", clock=clock)
    dn = Channel(ChannelConfig(alpha=0.01, beta=0.0005, outage=(0.5, 1.6)), "dn", clock=clock)
    server.attach(0, up, dn)
    client = EdgeClient(0, up, dn, _edge_cfg(), draft=OracleDraft(seed=5))

    def body():
        server.start()
        st = client.run(100)
        server.stop()
        return st

    st = clock.run(body)
    assert st["failovers"] >= 1 and st["fallback_tokens"] > 0
    assert st["recovery_latencies"]
    assert client.tokens == OracleStream(5).prefix(len(client.tokens))


# --------------------------------------------------------------------------- #
# Parked-session and paged-KV interactions under faults
# --------------------------------------------------------------------------- #


def test_parked_round_with_lost_drafts_is_abandoned_cleanly():
    """An uplink drop window can deliver a nav_request whose drafts were lost:
    the round parks, the client fails over, and the NEXT round verifies its
    own tokens — the parked request never corrupts the stream."""
    scen = FaultScenario("parked", up=(Phase(0.2, 0.8, drop_prob=0.9),))
    stream, report = run_scenario(scen, seed=13)
    assert stream == OracleStream(13).prefix(len(stream))
    assert report["stats"]["failovers"] >= 1


def test_stale_nav_request_cannot_displace_newer_parked_round():
    """A reorder-delayed nav_request from an abandoned round must not evict
    a newer round's parked request (which would wedge the session)."""
    clock = VirtualClock()
    server = CloudVerifier(OracleBackend(seed=2, clock=clock), clock=clock)
    up = Channel(ChannelConfig(alpha=1e-4, beta=1e-5), "up", clock=clock)
    dn = Channel(ChannelConfig(alpha=1e-4, beta=1e-5), "dn", clock=clock)
    server.attach(0, up, dn)
    oracle = OracleStream(2)

    def body():
        server.start()
        # Round 2 parks: its nav_request arrived but its drafts were lost.
        t2 = oracle.prefix(4)[2:]
        up.send(NavRequest(0, 3, 2, n_tokens=2, pos=2))
        assert dn.recv(timeout=0.3) is None
        # The STALE round-1 request (delayed by reordering; round 1 was
        # abandoned at failover) arrives late. It must be ignored.
        up.send(NavRequest(0, 1, 1, n_tokens=2, pos=0))
        assert dn.recv(timeout=0.3) is None
        # Round 2's drafts finally arrive -> the PARKED round dispatches.
        up.send(DraftFragment(0, 4, 2, tuple(t2), (0.9, 0.9)))
        msg = dn.recv(timeout=5.0)
        server.stop()
        return msg

    msg = clock.run(body)
    assert msg is not None and msg.seq == 3  # round 2 served, round 1 dead
    assert msg.n_accepted == 2  # verified at pos 2, oracle-true


def test_reordered_draft_batches_reassemble_in_seq_order():
    """Draft batches arriving out of order must verify in the CLIENT's draft
    order (fragments keyed by seq), not arrival order — checked with an
    order-sensitive fingerprint backend."""
    from test_runtime import EchoBackend

    clock = VirtualClock()
    server = CloudVerifier(EchoBackend(), clock=clock)
    up = Channel(ChannelConfig(alpha=1e-4, beta=1e-5), "up", clock=clock)
    dn = Channel(ChannelConfig(alpha=1e-4, beta=1e-5), "dn", clock=clock)
    server.attach(0, up, dn)

    def body():
        server.start()
        # Batch seq 2 ([3, 4]) overtakes batch seq 1 ([1, 2]) in transit.
        up.send(DraftFragment(0, 2, 1, (3, 4), (0.9, 0.9)))
        up.send(DraftFragment(0, 1, 1, (1, 2), (0.9, 0.9)))
        up.send(NavRequest(0, 3, 1, n_tokens=4))
        msg = dn.recv(timeout=5.0)
        server.stop()
        return msg

    msg = clock.run(body)
    assert msg is not None and msg.n_drafted == 4
    # Order-sensitive hash: only [1, 2, 3, 4] (draft order) is acceptable.
    assert msg.correction == EchoBackend.fingerprint(0, [1, 2, 3, 4])


def test_inflight_round_does_not_commit_across_reattach_reconcile():
    """A verify still running when the edge's reset reconciles the session
    must not advance the reconciled position when it completes."""
    clock = VirtualClock()
    backend = OracleBackend(seed=4, clock=clock, verify_time=1.0)  # slow verify
    server = CloudVerifier(backend, clock=clock)
    up = Channel(ChannelConfig(alpha=1e-4, beta=1e-5), "up", clock=clock)
    dn = Channel(ChannelConfig(alpha=1e-4, beta=1e-5), "dn", clock=clock)
    server.attach(0, up, dn)
    toks = OracleStream(4).prefix(4)

    def body():
        server.start()
        up.send(DraftFragment(0, 1, 1, tuple(toks), (0.9,) * 4))
        up.send(NavRequest(0, 2, 1, n_tokens=4, pos=0))
        clock.sleep(0.5)  # the 1s verify is now in flight
        # The edge failed over and re-attaches at position 0: round 1 is dead.
        up.send(Reset(0, 3, 1, position=0))
        clock.sleep(2.0)  # let the stale verify finish
        committed = server.sessions[0].kv_committed
        server.stop()
        return committed

    assert clock.run(body) == 0  # the abandoned round never committed


def test_duplicate_messages_never_double_commit():
    """Heavy duplication (draft batches AND nav requests retransmitted) must
    not double-verify a round or desync positions."""
    scen = FaultScenario(
        "dup_heavy",
        up=(Phase(0.0, 20.0, dup_prob=0.8),),
        dn=(Phase(0.0, 20.0, dup_prob=0.8),),
    )
    stream, report = run_scenario(scen, seed=17)
    assert report["up_stats"]["duplicated"] > 0
    assert stream == OracleStream(17).prefix(len(stream))
    # Each server-side verified round commits exactly once: the client's
    # accepted count equals the stream length.
    assert report["stats"]["accepted_tokens"] == len(stream)


def test_outage_reattach_reconciles_paged_kv():
    """After an offline spell the reset carries the edge position; the cloud
    rolls its paged-KV fork back and re-prefills — the pool's final length
    matches the shared prefix + the client's committed stream."""
    stream, report = run_scenario(
        scenario_by_name("double_outage"), seed=7,
        kv_pool_blocks=256, kv_shared_prefix=32,
    )
    assert stream == OracleStream(7).prefix(len(stream))
    assert report["stats"]["failovers"] >= 1
    assert report["kv_length"] is not None
    # The cloud's cache never ends up ahead of what the edge committed
    # (plus the shared prefix and at most one in-flight round's K+1 slots).
    assert report["kv_length"] <= 32 + len(stream) + 17


def test_kv_pressure_under_faults_parks_or_evicts_but_stays_conformant():
    """A pool far too small for the run forces evict/park/re-prefill churn;
    the stream must still be oracle-exact."""
    stream, report = run_scenario(
        scenario_by_name("flaky_everything"), seed=7,
        kv_pool_blocks=6, kv_shared_prefix=16,
    )
    assert stream == OracleStream(7).prefix(len(stream))


def test_dead_session_pages_released_on_timeout():
    """A session that stops heartbeating is dropped at dispatch and its KV
    pages return to the pool (message-level, deterministic timing)."""
    clock = VirtualClock()
    pool = PagedKVPool(32, 16, bytes_per_token=1024)
    server = CloudVerifier(
        OracleBackend(seed=1, clock=clock), clock=clock,
        kv_pool=pool, kv_shared_prefix=16, session_timeout=0.5,
    )
    up = Channel(ChannelConfig(alpha=0.001, beta=0.0), "up", clock=clock)
    dn = Channel(ChannelConfig(alpha=0.001, beta=0.0), "dn", clock=clock)
    server.attach(0, up, dn)
    oracle = OracleStream(1)

    def body():
        # The attach forked the shared prefix: the session holds pages.
        assert 0 in pool.tables and pool.length(0) == 16
        toks = oracle.prefix(4)
        up.send(DraftFragment(0, 1, 1, tuple(toks), (0.9,) * 4))
        up.send(NavRequest(0, 2, 1, n_tokens=4, pos=0))
        clock.sleep(1.0)  # rx queues the round; the session then goes quiet
        server.start()  # first dispatch happens AFTER the session timed out
        clock.sleep(1.0)
        server.stop()

    clock.run(body)
    assert server.stats["dropped_dead_sessions"] == 1
    assert 0 not in pool.tables  # pages reclaimed


# --------------------------------------------------------------------------- #
# Router-layer conformance: control-plane faults never corrupt the stream
# --------------------------------------------------------------------------- #

ROUTER_SCENARIO_IDS = [s.name for s in ROUTER_FAULT_MATRIX]
N_ROUTER_SESSIONS = 2
N_ROUTER_TOKENS = 150


def run_router_scenario(scenario, seed=7, n_tokens=N_ROUTER_TOKENS, verify_time=0.080):
    """One seeded multi-verifier run under a router-fault schedule.

    Returns (per-session streams, report).  The event controller replays the
    scenario's crash/migrate/drain schedule on the virtual clock while every
    client decodes to ``n_tokens``.
    """
    clock = VirtualClock()
    fleet = []
    for vid in range(scenario.n_verifiers):
        pool = PagedKVPool(128, 16, bytes_per_token=1024)
        v = CloudVerifier(
            OracleBackend(seed=seed, clock=clock, verify_time=verify_time),
            batch_window=0.01,
            clock=clock,
            kv_pool=pool,
            kv_shared_prefix=16,
        )
        v.start()
        fleet.append(LocalVerifier(vid, v, clock=clock))
    router = Router(fleet, clock=clock)
    clients = []
    for sid in range(N_ROUTER_SESSIONS):
        up = Channel(ChannelConfig(alpha=0.02, beta=0.002), f"up{sid}", clock=clock)
        dn = Channel(ChannelConfig(alpha=0.01, beta=0.0005), f"dn{sid}", clock=clock)
        router.attach(sid, up, dn)
        clients.append(
            EdgeClient(sid, up, dn, _edge_cfg(), draft=OracleDraft(seed=seed))
        )

    def controller():
        for ev in scenario.events:
            clock.sleep(max(0.0, ev.t - clock.monotonic()))
            if ev.kind == "crash":
                fleet[ev.verifier].crash()
            elif ev.kind == "migrate":
                try:
                    router.migrate(ev.session, dst=(ev.dst if ev.dst >= 0 else None))
                except FleetFullError:
                    pass  # nowhere to go: the session rides out the fault
            elif ev.kind == "drain":
                router.drain_verifier(ev.verifier)

    def body():
        ctl = clock.spawn(controller, name="ctl")
        handles = [
            clock.spawn(lambda c=c: c.run(n_tokens), name=f"cli-{c.session}")
            for c in clients
        ]
        out = []
        for h in handles:
            h.join()
            out.append(h.result())
        ctl.join()
        router.stop()
        for vc in fleet:
            if vc.alive:
                vc.stop()
        return out

    stats = clock.run(body)
    report = dict(
        stats=stats,
        router_stats=dict(router.stats),
        end_time=clock.monotonic(),
    )
    return [list(c.tokens) for c in clients], report


@pytest.fixture(scope="module")
def router_fault_free():
    streams, report = run_router_scenario(ROUTER_FAULT_MATRIX[0])
    for stream in streams:
        assert stream == OracleStream(7).prefix(len(stream))
    assert report["router_stats"]["verifier_crashes"] == 0
    return streams, report


@pytest.mark.parametrize("scenario", ROUTER_FAULT_MATRIX, ids=ROUTER_SCENARIO_IDS)
def test_router_streams_bit_identical_under_control_plane_faults(
    scenario, router_fault_free
):
    """Crash/migrate/drain mid-stream: every session's committed stream stays
    bit-identical to the fault-free run (and the oracle)."""
    ref_streams, _ = router_fault_free
    streams, report = run_router_scenario(scenario)
    for stream, ref in zip(streams, ref_streams):
        n = min(len(stream), len(ref))
        assert n >= N_ROUTER_TOKENS
        assert stream[:n] == ref[:n]
    # The scheduled faults must actually have fired.
    rs = report["router_stats"]
    kinds = {ev.kind for ev in scenario.events}
    if "crash" in kinds:
        assert rs["verifier_crashes"] >= 1
        assert rs["failover_migrations"] >= 1
    if "migrate" in kinds:
        assert rs["migrations"] >= 1
    if "drain" in kinds:
        assert rs["drains"] >= 1


@pytest.mark.parametrize("scenario", ROUTER_FAULT_MATRIX, ids=ROUTER_SCENARIO_IDS)
def test_router_runs_are_bit_reproducible(scenario):
    """Same seed -> identical streams, stats, and virtual end time."""
    a = run_router_scenario(scenario, seed=3)
    b = run_router_scenario(scenario, seed=3)
    assert a == b


def test_migration_during_inflight_nav_is_bit_identical():
    """Migrate while a 1s verify is in flight on the source: the replayed
    round completes on the destination with the same committed stream."""
    scenario = next(s for s in ROUTER_FAULT_MATRIX if s.name == "migrate_midstream")
    streams, report = run_router_scenario(scenario, verify_time=1.0, n_tokens=40)
    for stream in streams:
        assert len(stream) >= 40
        assert stream == OracleStream(7).prefix(len(stream))
    assert report["router_stats"]["migrations"] >= 1


def test_router_restart_midstream_is_bit_identical():
    """Kill the router mid-stream, adopt every live session into a fresh one
    from a snapshot: the committed streams stay oracle-exact."""
    seed = 7
    clock = VirtualClock()
    fleet = []
    for vid in range(2):
        v = CloudVerifier(
            OracleBackend(seed=seed, clock=clock), batch_window=0.01, clock=clock
        )
        v.start()
        fleet.append(LocalVerifier(vid, v, clock=clock))
    router1 = Router(fleet, clock=clock, name="router1")
    clients = []
    for sid in range(N_ROUTER_SESSIONS):
        up = Channel(ChannelConfig(alpha=0.02, beta=0.002), f"up{sid}", clock=clock)
        dn = Channel(ChannelConfig(alpha=0.01, beta=0.0005), f"dn{sid}", clock=clock)
        router1.attach(sid, up, dn)
        clients.append(
            EdgeClient(sid, up, dn, _edge_cfg(), draft=OracleDraft(seed=seed))
        )
    routers = [router1]

    def controller():
        clock.sleep(1.2)
        snap = router1.snapshot()
        router1.stop()  # detaches the fleet; client links stay open
        router2 = Router(fleet, clock=clock, name="router2")
        routers.append(router2)
        for c in clients:
            pos, rnd = snap[c.session]
            router2.adopt(c.session, c.up, c.dn, position=pos, round_id=rnd)

    def body():
        ctl = clock.spawn(controller, name="ctl")
        handles = [
            clock.spawn(lambda c=c: c.run(N_ROUTER_TOKENS), name=f"cli-{c.session}")
            for c in clients
        ]
        for h in handles:
            h.join()
        ctl.join()
        routers[-1].stop()
        for vc in fleet:
            vc.stop()

    clock.run(body)
    assert len(routers) == 2
    for c in clients:
        assert len(c.tokens) >= N_ROUTER_TOKENS
        assert c.tokens == OracleStream(seed).prefix(len(c.tokens))


# --------------------------------------------------------------------------- #
# Trace-driven scenarios: the bundled network traces join the conformance
# matrix — a compiled 4G/5G/WiFi timeline is just another FaultScenario, so
# the same lossless-stream and bit-reproducibility contracts apply.
# --------------------------------------------------------------------------- #

TRACE_IDS = [s.name for s in TRACE_MATRIX]


@pytest.mark.parametrize("scenario", TRACE_MATRIX, ids=TRACE_IDS)
def test_trace_stream_bit_identical_to_fault_free(scenario, fault_free):
    """Every bundled trace recovers: same committed tokens as no faults."""
    ref_stream, _ = fault_free
    stream, report = run_scenario(scenario)
    n = min(len(stream), len(ref_stream))
    assert n >= N_TOKENS
    assert stream[:n] == ref_stream[:n]
    # A trace with an outage window must actually have knocked the link out
    # (failover + offline progress), or conformance proved nothing.
    if scenario.outage_windows("up") or scenario.outage_windows("dn"):
        st = report["stats"]
        assert st["failovers"] >= 1
        assert st["fallback_tokens"] > 0


@pytest.mark.parametrize("scenario", TRACE_MATRIX, ids=TRACE_IDS)
def test_trace_seeded_replays_are_byte_identical(scenario):
    """Same seed -> identical stream, stats, fault draws, and virtual time."""
    a = run_scenario(scenario, seed=3)
    b = run_scenario(scenario, seed=3)
    assert a == b


def test_every_bundled_trace_is_in_the_matrix():
    """TRACE_MATRIX covers the bundled trace set one-to-one."""
    assert TRACE_IDS == [f"trace:{t.name}" for t in BUNDLED_TRACES]
    assert len(set(TRACE_IDS)) == len(TRACE_IDS) == len(BUNDLED_TRACES) >= 3


# --------------------------------------------------------------------------- #
# The no-wall-clock guard: every runtime hot path runs on the injected clock
# --------------------------------------------------------------------------- #


def test_runtime_has_no_wall_clock_reads():
    """Grep guard: outside simclock.py, runtime + obs modules must not touch
    ``time.*`` or spawn/synchronize threads behind the clock's back."""
    src = Path(__file__).parent.parent / "src" / "repro"
    banned = re.compile(
        r"\btime\.(monotonic|sleep|time|perf_counter)\b"
        r"|\bthreading\.(Thread|Condition|Timer)\b"
        r"|^\s*import time\b|^\s*from time\b",
        re.MULTILINE,
    )
    scanned = set()
    offenders = {}
    for sub in ("runtime", "obs"):
        for path in sorted((src / sub).glob("*.py")):
            if path.name == "simclock.py":  # the one place wall time may live
                continue
            scanned.add(f"{sub}/{path.name}")
            hits = banned.findall(path.read_text())
            if hits:
                offenders[f"{sub}/{path.name}"] = hits
    # The control-plane and trace modules must be inside the guard's net.
    assert {
        "runtime/router.py",
        "runtime/placement.py",
        "runtime/scaling.py",
        "runtime/traces.py",
    } <= scanned
    # The observability subsystem claims clock-driven determinism — every
    # module must actually be scanned, not just the ones that exist today.
    assert {
        "obs/__init__.py",
        "obs/trace.py",
        "obs/metrics.py",
        "obs/endpoint.py",
        "obs/dashboard.py",
    } <= scanned
    assert not offenders, f"wall-clock/thread primitives on runtime hot paths: {offenders}"
