"""KV-cache container semantics: rollback metadata + snapshot copying.

``snapshot`` used to copy leaves via ``a + 0``, which type-promotes bool
leaves to int32 and leaves non-array leaves aliased; these tests pin the
fixed dtype-preserving deep-copy behaviour.
"""

import jax.numpy as jnp
import numpy as np

from repro.models.kvcache import (
    KVCache,
    init_kv_cache,
    restore,
    set_lengths,
    snapshot,
)


def test_snapshot_preserves_bool_and_int_dtypes():
    state = {
        "mask": jnp.asarray([True, False, True]),
        "steps": jnp.asarray([3, 5], jnp.int32),
        "acc": jnp.asarray([1.5, 2.5], jnp.bfloat16),
    }
    snap = snapshot(state)
    assert snap["mask"].dtype == jnp.bool_  # `a + 0` promoted this to int32
    assert snap["steps"].dtype == jnp.int32
    assert snap["acc"].dtype == jnp.bfloat16
    for k in state:
        np.testing.assert_array_equal(np.asarray(snap[k]), np.asarray(state[k]))


def test_snapshot_copies_numpy_leaves():
    """Mutable (numpy) leaves must be deep-copied, not aliased: mutating the
    original after the snapshot must not leak into the rollback point."""
    state = {"h": np.zeros((2, 3), np.float32), "flags": np.asarray([True, False])}
    snap = snapshot(state)
    state["h"][0, 0] = 99.0
    state["flags"][0] = False
    assert float(np.asarray(snap["h"])[0, 0]) == 0.0
    assert bool(np.asarray(snap["flags"])[0]) is True
    assert snap["flags"].dtype == jnp.bool_


def test_snapshot_restore_roundtrip_on_kv_cache():
    cache = init_kv_cache(n_layers=2, batch=2, max_len=8, n_kv_heads=2, head_dim=4)
    cache = set_lengths(cache, jnp.asarray([3, 5]))
    snap = snapshot(cache)
    assert isinstance(snap, KVCache)
    assert snap.lengths.dtype == jnp.int32
    restored = restore(snap)
    np.testing.assert_array_equal(np.asarray(restored.lengths), [3, 5])
    assert restored.k.shape == cache.k.shape


def test_set_lengths_is_metadata_only():
    cache = init_kv_cache(n_layers=1, batch=2, max_len=4, n_kv_heads=1, head_dim=2)
    rolled = set_lengths(cache, np.asarray([1, 2], np.int64))
    assert rolled.lengths.dtype == jnp.int32
    assert rolled.k is cache.k and rolled.v is cache.v  # buffers untouched
