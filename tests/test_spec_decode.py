"""Speculative decoding invariants.

The two load-bearing properties:
1. *Greedy losslessness*: spec decoding with greedy NAV emits exactly the
   token sequence the target alone would produce — regardless of draft
   quality (tested with an uncorrelated random draft).
2. *Stochastic exactness*: the rejection-sampling verify preserves the target
   distribution analytically (enumerated over a small vocab).
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.spec_decode import (
    DraftConfig,
    SpecDecoder,
    draft_round,
    verify_greedy,
    verify_stochastic,
)
from repro.models import transformer as T, zoo
from repro.models.config import ModelConfig
from repro.models.kvcache import set_lengths


def _tiny(name, seed, layers=2, d=48):
    return ModelConfig(name=name, family="dense", n_layers=layers, d_model=d, n_heads=4,
                       n_kv_heads=2, d_ff=96, vocab_size=128, head_dim=12, vocab_pad_to=64)


def _greedy_reference(params, cfg, prompt, n_new):
    """Plain target-only greedy decode (the gold sequence)."""
    cache = T.make_cache(cfg, prompt.shape[0], prompt.shape[1] + n_new + 4)
    logits, cache = T.prefill(params, {"tokens": prompt}, cache, cfg)
    tok = jnp.argmax(logits[:, -1, :], -1).astype(jnp.int32)
    out = [tok]
    for _ in range(n_new - 1):
        logits, cache = T.decode(params, tok[:, None], cache, cfg)
        tok = jnp.argmax(logits[:, 0, :], -1).astype(jnp.int32)
        out.append(tok)
    return jnp.stack(out, axis=1)  # [B, n_new]


@pytest.mark.parametrize("window", [3, 6])
def test_greedy_spec_decoding_is_lossless(window):
    key = jax.random.PRNGKey(0)
    tcfg = _tiny("target", 0, layers=2)
    dcfg = _tiny("draft", 1, layers=1)
    tparams = T.init(jax.random.PRNGKey(10), tcfg)
    dparams = T.init(jax.random.PRNGKey(20), dcfg)
    B, P, N = 2, 6, 20
    prompt = jax.random.randint(key, (B, P), 0, 128)
    gold = _greedy_reference(tparams, tcfg, prompt, N)

    def draft_step(params, tok, cache):
        logits, new_cache = T.decode(params, tok[:, None], cache, dcfg)
        return logits[:, 0, :], new_cache

    def target_forward(params, seq, cache):
        return T.decode(params, seq, cache, tcfg)

    dec = SpecDecoder(draft_step, target_forward, dparams, tparams,
                      DraftConfig(window=window, r1=0.0, r2=0.0), set_lengths,
                      greedy_verify=True)
    max_len = P + N + (window + 2) * (N + 2)
    d_cache = T.make_cache(dcfg, B, max_len)
    t_cache = T.make_cache(tcfg, B, max_len)
    outputs, trace = dec.generate(
        prompt, d_cache, t_cache,
        prefill_draft=lambda p, b, c: T.prefill(p, {"tokens": b}, c, dcfg),
        prefill_target=lambda p, b, c: T.prefill(p, {"tokens": b}, c, tcfg),
        max_new_tokens=N, key=key,
    )
    for b in range(B):
        assert outputs[b][:N] == list(np.asarray(gold[b])), f"lane {b} diverged from target-greedy"


def test_verify_greedy_semantics():
    V = 11
    logits = jnp.zeros((1, 4, V)).at[0, 0, 3].set(5.0).at[0, 1, 7].set(5.0).at[0, 2, 2].set(5.0).at[0, 3, 9].set(5.0)
    # Drafts match positions 0,1 then diverge at 2.
    drafts = jnp.array([[3, 7, 5]], dtype=jnp.int32)
    vr = verify_greedy(logits, drafts, jnp.array([3]))
    assert int(vr.n_accepted[0]) == 2
    assert int(vr.correction[0]) == 2  # target's token at the mismatch
    # Full acceptance → bonus from position K.
    drafts2 = jnp.array([[3, 7, 2]], dtype=jnp.int32)
    vr2 = verify_greedy(logits, drafts2, jnp.array([3]))
    assert int(vr2.n_accepted[0]) == 3 and bool(vr2.all_accepted[0])
    assert int(vr2.correction[0]) == 9


def test_verify_greedy_respects_n_drafted():
    logits = jnp.zeros((1, 4, 5)).at[:, :, 1].set(3.0)
    drafts = jnp.array([[1, 1, 1]], dtype=jnp.int32)
    vr = verify_greedy(logits, drafts, jnp.array([2]))  # only 2 drafts valid
    assert int(vr.n_accepted[0]) == 2


def test_stochastic_verify_preserves_target_distribution():
    """Empirical single-step check: output marginal ≈ target distribution.

    With K=1 draft from q and verify against p, the emitted token (accepted
    draft or resampled correction) must be distributed exactly as p.
    """
    key = jax.random.PRNGKey(0)
    V = 8
    p = jnp.array([0.35, 0.05, 0.2, 0.1, 0.02, 0.08, 0.15, 0.05])
    q = jnp.array([0.05, 0.3, 0.1, 0.15, 0.15, 0.05, 0.05, 0.15])
    n = 30_000
    k1, k2, k3 = jax.random.split(key, 3)
    drafts = jax.random.categorical(k1, jnp.log(q)[None, :].repeat(n, 0))[:, None].astype(jnp.int32)
    # target_probs [n, K+1=2, V] (bonus row unused when a rejection occurs).
    tp = jnp.tile(p[None, None, :], (n, 2, 1))
    dp_ = jnp.tile(q[None, None, :], (n, 1, 1))
    vr = verify_stochastic(tp, dp_, drafts, jnp.ones((n,), jnp.int32), k2)
    emitted = jnp.where(vr.n_accepted[:, None] > 0, drafts, vr.correction[:, None])[:, 0]
    counts = np.bincount(np.asarray(emitted), minlength=V) / n
    np.testing.assert_allclose(counts, np.asarray(p), atol=0.012)


def test_draft_round_respects_thresholds():
    """Lanes stop drafting when P(D) ≤ R2; confident lanes hit the cap."""
    V = 16

    def draft_step(params, tok, cache):
        # Deterministic synthetic model: confidence decays with step count.
        step = cache
        logits = jnp.zeros((tok.shape[0], V)).at[:, 3].set(5.0 - step.astype(jnp.float32))
        return logits, cache + 1

    cfg = DraftConfig(window=8, r1=0.0, r2=0.9)
    res = draft_round(draft_step, None, jnp.int32(0), jnp.zeros((2,), jnp.int32), cfg, jax.random.PRNGKey(0))
    # Confidence falls below 0.9 at some step — all lanes trigger then stop.
    assert bool(res.triggered.all())
    assert int(res.n_drafted[0]) < 8
    # With no thresholds the same model drafts the full window.
    cfg2 = DraftConfig(window=8, r1=0.0, r2=0.0)
    res2 = draft_round(draft_step, None, jnp.int32(0), jnp.zeros((2,), jnp.int32), cfg2, jax.random.PRNGKey(0))
    assert int(res2.n_drafted[0]) == 8 and not bool(res2.triggered.any())
