"""Adaptive per-session policy controller: drift retunes, hysteresis, outages.

The contracts (core/policy.py + its EdgeClient integration):

* a step-change in the link β drifts the monitor estimate past the δ-trigger
  and the controller retunes (R1, R2, width, depth) via BO within a bounded
  number of rounds — and the retune actually moves the knobs;
* the chain↔tree mode rule is hysteretic: acceptance must cross distinct
  thresholds to flip, so a stream hovering between them never flaps;
* while the link is out the controller serves local-only rounds, probing the
  cloud every k-th round so recovery is automatic — end-to-end this shows up
  as ``failovers``/``fallback_tokens`` on a trace-driven fleet that still
  commits every session's full stream;
* everything is deterministic from (seed, observation sequence).
"""

import pytest

from repro.core.policy import AdaptivePolicyController, PolicyConfig, PolicyDecision

LINK = dict(alpha=0.02, beta=0.002)


def _feed_steady(c, rounds=12, beta=0.002, tpt=0.05, acc=7):
    for _ in range(rounds):
        c.observe_link(16, LINK["alpha"] + beta * 16)
        c.observe_gamma(0.02)
        c.observe_round(8, acc, tpt=tpt)


def test_decision_rejects_unknown_mode():
    with pytest.raises(ValueError):
        PolicyDecision(mode="warp")


def test_beta_step_change_retunes_within_bounded_rounds():
    """Link drift (5x β) must move the tuned (R1, R2, width, depth)."""
    cfg = PolicyConfig(min_rounds_between_retunes=1, retune_trials=4, retune_tokens=20)
    c = AdaptivePolicyController(base=PolicyDecision(mode="tree"), cfg=cfg, seed=3)
    _feed_steady(c)
    baseline = c.retune()
    retunes0 = c.retunes
    rounds = 0
    for _ in range(c.monitor.window + 3):  # bounded: one monitor window + slack
        rounds += 1
        c.observe_link(16, LINK["alpha"] + 5 * 0.002 * 16)
        c.observe_round(8, 7, tpt=0.09)
        if c.retunes > retunes0:
            break
    assert c.retunes > retunes0, "β step never triggered a retune"
    assert rounds <= c.monitor.window + 3
    assert c.tuned is not None and c.tuned != baseline
    r1, r2, w, d = c.tuned
    assert 0.0 <= r1 <= 1.0 and 0.0 <= r2 <= 1.0
    assert 1 <= w <= 4 and 2 <= d <= 10


def test_retunes_are_rate_limited_by_cooldown():
    cfg = PolicyConfig(min_rounds_between_retunes=10**6, retune_trials=2, retune_tokens=10)
    c = AdaptivePolicyController(cfg=cfg, seed=1)
    _feed_steady(c)
    c.retune()
    n = c.retunes
    for beta in (0.01, 0.05, 0.1):  # ever-wilder drift, all inside the cooldown
        for _ in range(6):
            c.observe_link(16, LINK["alpha"] + beta * 16)
            c.observe_round(8, 7, tpt=0.2)
    assert c.retunes == n


def test_mode_hysteresis_chain_tree_chain():
    c = AdaptivePolicyController(cfg=PolicyConfig(monitor_window=10**6))
    for _ in range(6):
        c.observe_round(8, 8)
    assert c.decide().mode == "chain"
    for _ in range(8):  # acceptance collapses below tree_below
        c.observe_round(8, 2)
    assert c.decide().mode == "tree"
    mid = c.decide().mode  # still between the thresholds: no flap back
    assert mid == "tree"
    for _ in range(12):  # recovers above chain_above
        c.observe_round(8, 8)
    assert c.decide().mode == "chain"
    assert c.mode_switches == 2


def test_offline_probe_cycle_and_recovery():
    c = AdaptivePolicyController(cfg=PolicyConfig(probe_every=3))
    c.observe_round(8, 8)
    c.observe_round(8, 0, failover=True)
    assert c.offline
    assert [c.decide().mode for _ in range(6)] == [
        "local", "local", "chain", "local", "local", "chain"
    ]
    c.observe_round(8, 7)  # a verified round ends the offline spell
    assert not c.offline
    assert c.decide().mode == "chain"


def test_controller_is_deterministic():
    def build():
        cfg = PolicyConfig(min_rounds_between_retunes=1, retune_trials=3, retune_tokens=15)
        c = AdaptivePolicyController(base=PolicyDecision(mode="tree"), cfg=cfg, seed=9, session=2)
        _feed_steady(c)
        c.retune()
        for _ in range(8):
            c.observe_link(16, LINK["alpha"] + 0.012 * 16)
            c.observe_round(8, 5, tpt=0.11)
            c.decide()
        return c

    a, b = build(), build()
    assert a.tuned == b.tuned
    assert a.decisions == b.decisions
    assert a.retunes == b.retunes


# --------------------------------------------------------------------------- #
# End-to-end: adaptive fleet on a trace with an outage window
# --------------------------------------------------------------------------- #


def _trace_fleet(seed=5):
    from benchmarks.fleet_bench import HETERO_PROFILES, run_fleet
    from repro.runtime.simclock import VirtualClock
    from repro.runtime.traces import TRACE_MATRIX

    fs = next(s for s in TRACE_MATRIX if s.name == "trace:4g_drive")
    return run_fleet(
        mode="batched", variant="chain", policy="adaptive",
        profiles=HETERO_PROFILES, n_sessions=4, tokens_per_session=40,
        scen=1, seed=seed, ts=1.0, clock=VirtualClock(), faults=fs,
        nav_timeout=1.0, backoff_init=0.1, local_gamma=8.0,
    )


def test_policy_fleet_falls_back_during_trace_outage_and_recovers():
    rep = _trace_fleet()
    st = rep["stats"]
    assert st.failovers >= 1, "the 4G outage window never knocked a session out"
    assert st.fallback_tokens > 0, "no local-only progress during the outage"
    # Recovery: every session still commits its full stream after the outage.
    assert all(len(s) >= 40 for s in rep["streams"].values())
    assert rep["policy_retunes"] >= 1
    # Heterogeneity is threaded through to the stats.
    assert st.gamma_spread > 1.0 and st.beta_spread > 1.0


def test_policy_fleet_is_bit_reproducible():
    a, b = _trace_fleet(), _trace_fleet()
    assert a["streams"] == b["streams"]
    assert a["stats"].fallback_tokens == b["stats"].fallback_tokens
    assert a["stats"].edge_energy == b["stats"].edge_energy
    assert a["policy_retunes"] == b["policy_retunes"]
    assert a["policy_mode_switches"] == b["policy_mode_switches"]
