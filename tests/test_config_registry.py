"""Registry-wide shard-metadata smoke tests.

Every architecture the registry can serve must (a) carry internally
consistent head/vocab metadata, (b) yield a valid shard plan for the
sharded verifier at any shard count (padding covers non-divisible head
counts), (c) admit per-shard paged-KV layout metadata, and (d) produce
PartitionSpecs from ``sharding/partition.py`` that are constructible as
real ``NamedSharding``s over a live host mesh — for the full configs and
their ``reduced()`` twins alike.
"""

import jax
import numpy as np
import pytest
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.configs import ARCH_IDS, get_config
from repro.models import zoo
from repro.models.paged_kv import PagedKVPool
from repro.sharding import Partitioner, plan_shards

SHARD_COUNTS = (1, 2, 4, 8)


@pytest.mark.parametrize("arch", ARCH_IDS)
@pytest.mark.parametrize("reduced", [False, True])
def test_config_head_metadata_consistent(arch, reduced):
    cfg = get_config(arch, reduced)
    assert cfg.n_heads >= cfg.n_kv_heads >= 1
    assert cfg.n_heads % cfg.n_kv_heads == 0, f"{arch}: GQA ratio must divide"
    assert cfg.head_dim > 0 and cfg.q_dim == cfg.n_heads * cfg.head_dim
    assert cfg.padded_vocab_size >= cfg.vocab_size
    assert cfg.padded_vocab_size % cfg.vocab_pad_to == 0


@pytest.mark.parametrize("arch", ARCH_IDS)
@pytest.mark.parametrize("shards", SHARD_COUNTS)
def test_config_shard_plan_consistent(arch, shards):
    """plan_shards digests every registry config at every shard count."""
    cfg = get_config(arch)
    p = plan_shards(
        shards=shards,
        n_heads=cfg.n_heads,
        n_kv_heads=cfg.n_kv_heads,
        head_dim=cfg.head_dim,
        vocab=cfg.padded_vocab_size,
    )
    assert p.shards == shards
    # Head split: padding makes any head count divisible; no shard is empty.
    assert p.padded_heads % shards == 0 and p.padded_heads >= p.heads
    assert p.heads_per_shard * shards == p.padded_heads
    assert p.padded_heads - p.heads < shards  # minimal padding only
    assert p.even_heads == (cfg.n_heads % shards == 0)
    assert p.even_kv_heads == (cfg.n_kv_heads % shards == 0)
    # Vocab split: per-shard tiles are whole block_v multiples covering Vp.
    assert p.vocab_per_shard % p.block_v == 0
    assert p.launch_vocab == p.vocab_per_shard * shards >= p.padded_vocab >= p.vocab


@pytest.mark.parametrize("arch", ["arctic-480b", "internvl2-76b", "qwen3-moe-30b-a3b"])
def test_big_model_kv_pool_shard_metadata(arch):
    """The headline large configs: per-shard paged-KV layout metadata is
    consistent with the config's kv-head count at every shard count."""
    cfg = get_config(arch)
    pool = PagedKVPool(
        num_blocks=4, block_size=16,
        n_kv_heads=cfg.n_kv_heads, head_dim=cfg.head_dim,
        bytes_per_token=cfg.n_kv_heads * cfg.head_dim * 8,
    )
    for shards in SHARD_COUNTS:
        assert pool.shard_axes(shards) == (cfg.n_kv_heads % shards == 0)
        kspec, _ = pool.shard_spec(shards)
        if shards > 1 and pool.shard_axes(shards):
            assert kspec == P(None, None, None, "model", None)
        else:
            assert kspec == P(None, None, None, None, None)
        per_shard = pool.resident_bytes_per_shard(shards)
        assert per_shard * (shards if pool.shard_axes(shards) else 1) == pool.resident_bytes()


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_partition_specs_constructible_on_host_mesh(arch):
    """Every leaf's spec builds a NamedSharding on a REAL 2x2 host mesh and
    every sharded dim divides its axis size."""
    if jax.device_count() < 4:
        pytest.skip("needs a 4-device host platform (conftest sets XLA_FLAGS)")
    mesh = Mesh(np.array(jax.devices()[:4]).reshape(2, 2), ("data", "model"))
    cfg = get_config(arch)
    part = Partitioner(mesh)
    shapes = jax.eval_shape(lambda: zoo.init(jax.random.PRNGKey(0), cfg))
    specs = part.param_specs(shapes)
    flat_specs = jax.tree_util.tree_leaves(specs, is_leaf=lambda x: isinstance(x, P))
    flat_shapes = jax.tree_util.tree_leaves(shapes)
    assert len(flat_specs) == len(flat_shapes)
    for sp, sh in zip(flat_specs, flat_shapes):
        NamedSharding(mesh, sp)  # must not raise: axes exist on the mesh
        for dim, ax in zip(sh.shape, tuple(sp)):
            if ax is None:
                continue
            axes = ax if isinstance(ax, tuple) else (ax,)
            size = int(np.prod([dict(mesh.shape)[a] for a in axes]))
            assert dim % size == 0, f"{arch}: {sh.shape} vs {sp}"
