"""Network-trace source: synthesis, compilation, and replay determinism.

A :class:`~repro.runtime.traces.NetworkTrace` is a bandwidth/outage timeline;
``compile_trace`` lowers it to a declarative ``FaultScenario`` replayed on
the virtual clock.  The contracts:

* compiled phases tile the trace duration **exactly** — first phase starts
  at 0, consecutive phases abut (no gaps, no overlaps), last phase ends at
  the trace duration, on both directions;
* β multipliers round-trip: each phase's ``bandwidth_factor`` is exactly
  ``ref_mbps / segment_mbps`` for the segment it covers, so the segment
  bandwidth is recoverable from the compiled scenario;
* synthesis is a pure function of (kind, seed): same seed → identical
  trace and identical compilation; different seeds diverge.

Property tests skip (not fail) without hypothesis — see tests/conftest.py.
"""

import dataclasses

import pytest
from hypothesis import given, settings
from hypothesis import strategies as st

from repro.runtime.traces import (
    BUNDLED_TRACES,
    TRACE_KINDS,
    NetworkTrace,
    TraceSegment,
    compile_trace,
    synthesize_trace,
    trace_bandwidth_fn,
    trace_by_name,
)

KINDS = sorted(TRACE_KINDS)

kind_st = st.sampled_from(KINDS)
seed_st = st.integers(min_value=0, max_value=2**31 - 1)
step_st = st.floats(min_value=0.25, max_value=3.0, allow_nan=False, width=32)
duration_st = st.floats(min_value=1.0, max_value=30.0, allow_nan=False, width=32)


# --------------------------------------------------------------------------- #
# Unit tests
# --------------------------------------------------------------------------- #


def test_trace_validation_rejects_malformed_timelines():
    seg = TraceSegment(start=0.0, up_mbps=10.0, dn_mbps=100.0)
    with pytest.raises(ValueError):
        NetworkTrace("x", "4g", 10.0, segments=())  # empty
    with pytest.raises(ValueError):
        NetworkTrace("x", "4g", 10.0, segments=(dataclasses.replace(seg, start=1.0),))
    with pytest.raises(ValueError):
        NetworkTrace(
            "x", "4g", 10.0,
            segments=(seg, dataclasses.replace(seg, start=5.0), dataclasses.replace(seg, start=5.0)),
        )  # non-increasing starts
    with pytest.raises(ValueError):
        NetworkTrace("x", "4g", 10.0, segments=(dataclasses.replace(seg, up_mbps=0.0),))


def test_segment_lookup_and_outage_windows():
    t = trace_by_name("4g_drive")
    assert t.segment_at(0.0) is t.segments[0]
    assert t.segment_at(t.duration + 99.0) is t.segments[-1]
    for lo, hi in t.outage_windows():
        assert 0.0 <= lo < hi <= t.duration
        assert t.segment_at((lo + hi) / 2).outage


def test_bundled_traces_cover_all_kinds():
    assert sorted({t.kind for t in BUNDLED_TRACES}) == KINDS
    # The 4G and WiFi traces carry an outage; the 5G trace does not.
    by_kind = {t.kind: t for t in BUNDLED_TRACES}
    assert by_kind["4g"].outage_windows() and by_kind["wifi"].outage_windows()
    assert not by_kind["5g"].outage_windows()


def test_trace_by_name_unknown():
    with pytest.raises(KeyError):
        trace_by_name("nope")


def test_bandwidth_fn_matches_segments_and_applies_outage_floor():
    t = trace_by_name("wifi_cafe")
    fn = trace_bandwidth_fn(t)
    for seg in t.segments:
        up, dn = fn(seg.start + 1e-6)
        if seg.outage:
            assert up == pytest.approx(seg.up_mbps * 0.01)
            assert dn == pytest.approx(seg.dn_mbps * 0.01)
        else:
            assert (up, dn) == (seg.up_mbps, seg.dn_mbps)


def test_compiled_scenario_carries_outage_and_name():
    fs = compile_trace(trace_by_name("4g_drive"))
    assert fs.name == "trace:4g_drive"
    assert fs.outage_windows("up") and fs.outage_windows("dn")


# --------------------------------------------------------------------------- #
# Property tests
# --------------------------------------------------------------------------- #


@settings(deadline=None, max_examples=60)
@given(kind=kind_st, seed=seed_st, step=step_st, duration=duration_st)
def test_compiled_phases_tile_the_trace_exactly(kind, seed, step, duration):
    """Phase boundaries cover [0, duration) with no gaps and no overlaps."""
    trace = synthesize_trace(kind, seed, duration=duration, step=step)
    fs = compile_trace(trace)
    for direction in ("up", "dn"):
        phases = fs.phases(direction)
        assert phases, direction
        assert phases[0].start == 0.0
        assert phases[-1].end == trace.duration
        for prev, nxt in zip(phases, phases[1:]):
            assert prev.end == nxt.start  # abutting: no gap, no overlap
            assert prev.start < prev.end


@settings(deadline=None, max_examples=60)
@given(kind=kind_st, seed=seed_st, step=step_st)
def test_beta_multipliers_round_trip(kind, seed, step):
    """bandwidth_factor == ref/seg exactly, so seg bandwidth is recoverable."""
    trace = synthesize_trace(kind, seed, step=step)
    fs = compile_trace(trace)
    for direction, ref in (("up", trace.ref_up_mbps), ("dn", trace.ref_dn_mbps)):
        for seg, phase in zip(trace.segments, fs.phases(direction)):
            mbps = seg.up_mbps if direction == "up" else seg.dn_mbps
            assert phase.bandwidth_factor == ref / mbps
            assert ref / phase.bandwidth_factor == pytest.approx(mbps, rel=1e-12)
            assert phase.outage == seg.outage


@settings(deadline=None, max_examples=40)
@given(kind=kind_st, seed=seed_st, step=step_st, duration=duration_st)
def test_same_seed_compilations_are_identical(kind, seed, step, duration):
    """Synthesis + compilation is a pure function of its arguments."""
    a = synthesize_trace(kind, seed, duration=duration, step=step)
    b = synthesize_trace(kind, seed, duration=duration, step=step)
    assert a == b
    assert compile_trace(a) == compile_trace(b)


@settings(deadline=None, max_examples=20)
@given(kind=kind_st, seed=st.integers(min_value=0, max_value=2**20))
def test_different_seeds_diverge(kind, seed):
    a = synthesize_trace(kind, seed)
    b = synthesize_trace(kind, seed + 1)
    assert a.segments != b.segments
