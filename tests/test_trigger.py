"""Dual-threshold NAV trigger + baseline policy semantics."""

import pytest
from hypothesis import given, settings, strategies as st

from repro.core.trigger import (
    DualThresholdTrigger,
    FixedLengthTrigger,
    SequenceThresholdTrigger,
    TokenThresholdTrigger,
    WindowCapTrigger,
    make_trigger,
)

confs = st.lists(st.floats(min_value=1e-6, max_value=1.0), min_size=1, max_size=50)


def test_dual_fires_on_token_threshold():
    t = DualThresholdTrigger(r1=0.0, r2=0.5)
    assert not t.observe(0.9)
    assert t.observe(0.4)


def test_dual_fires_on_sequence_threshold():
    t = DualThresholdTrigger(r1=0.5, r2=0.0)
    assert not t.observe(0.9)  # C1 = 0.9
    assert not t.observe(0.8)  # C1 = 0.72
    assert t.observe(0.6)  # C1* = 0.432 ≤ 0.5 → fire
    # C1 resets to 1 after the trigger (§3.3).
    assert t.c1 == 1.0


@settings(max_examples=50, deadline=None)
@given(cs=confs, r1=st.floats(0, 1), r2=st.floats(0, 1))
def test_dual_trigger_invariant(cs, r1, r2):
    """Between fires, the running product stays above R1 and every conf > R2."""
    t = DualThresholdTrigger(r1=r1, r2=r2)
    prod = 1.0
    for c in cs:
        fired = t.observe(c)
        if fired:
            prod = 1.0
            assert c <= r2 or True  # fired by either rule
        else:
            prod *= c
            assert prod > r1
            assert c > r2


def test_fixed_length():
    t = FixedLengthTrigger(n=3)
    fires = [t.observe(0.9) for _ in range(7)]
    assert fires == [False, False, True, False, False, True, False]


def test_hsl_token_threshold():
    t = TokenThresholdTrigger(r=0.99)
    assert t.observe(0.98) and not t.observe(0.995)


def test_edgellm_dynamic_threshold_moves():
    t = SequenceThresholdTrigger(r1=0.3)
    # Full acceptance halves R1.
    t.on_verify(10, 10)
    assert t.r1 == pytest.approx(0.15)
    # Rejection raises it (divide by rejected fraction, App. G.3 Eq. 7).
    t.on_verify(5, 10)
    assert t.r1 == pytest.approx(0.30)


def test_window_cap_forces_fire():
    t = WindowCapTrigger(DualThresholdTrigger(r1=0.0, r2=0.0), window=4)
    fires = [t.observe(1.0) for _ in range(9)]
    assert fires == [False, False, False, True] * 2 + [False]


def test_sequence_r1_update_all_accepted_vs_zero_accepted():
    """App. G.3 Eq. (7) asymmetry: full acceptance halves R1 (longer drafts);
    a zero-accepted round leaves R1 UNCHANGED (the rejected fraction is 1,
    so the update is the identity) rather than runaway-raising it."""
    t = SequenceThresholdTrigger(r1=0.4)
    t.on_verify(8, 8)  # all accepted
    assert t.r1 == pytest.approx(0.2)
    t.on_verify(0, 8)  # zero accepted: frac = 1 → identity update
    assert t.r1 == pytest.approx(0.2)
    # Partial rejection raises R1 toward 1 (earlier NAV next round)...
    t.on_verify(6, 8)
    assert t.r1 == pytest.approx(0.8)
    # ...but never to/past 1 (that would fire on every token forever).
    for _ in range(50):
        t.on_verify(7, 8)
    assert t.r1 < 1.0
    # And repeated full acceptance respects the runaway-window floor.
    for _ in range(50):
        t.on_verify(8, 8)
    assert t.r1 >= 0.02
    # A degenerate window must not divide by zero.
    t.on_verify(0, 0)


def test_window_cap_force_fires_exactly_at_window():
    """The cap fires at EXACTLY N̂ observations — never at N̂−1, always at N̂,
    and the count restarts after any fire (including inner-policy fires)."""
    inner = DualThresholdTrigger(r1=0.0, r2=0.0)  # never fires on its own
    t = WindowCapTrigger(inner, window=5)
    for round_ in range(3):
        for i in range(1, 5):
            assert not t.observe(1.0), f"fired early at {i} (round {round_})"
        assert t.observe(1.0), f"did not fire at N̂ (round {round_})"
    # An inner fire resets the cap count: 2 observations, inner fire, then a
    # full window must again be needed before the cap forces one.
    t2 = WindowCapTrigger(DualThresholdTrigger(r1=0.0, r2=0.5), window=4)
    assert not t2.observe(0.9)
    assert t2.observe(0.1)  # inner (R2) fire at count 2
    assert [t2.observe(0.9) for _ in range(4)] == [False, False, False, True]


def test_dual_c1_resets_on_fire_for_both_rules():
    """§3.3: C1 resets to 1 on EVERY fire — whether R1 or R2 tripped it —
    and on explicit reset(); a non-firing observe accumulates the product."""
    # R2 (single-token) fire: the tentative C1* must be discarded.
    t = DualThresholdTrigger(r1=0.0, r2=0.5)
    assert not t.observe(0.9)
    assert t.c1 == pytest.approx(0.9)
    assert t.observe(0.4)  # R2 fire
    assert t.c1 == 1.0
    # R1 (sequence) fire.
    t2 = DualThresholdTrigger(r1=0.5, r2=0.0)
    assert not t2.observe(0.8)
    assert t2.observe(0.6)  # C1* = 0.48 ≤ 0.5
    assert t2.c1 == 1.0
    # After the reset the SAME confidence stream is accepted again — the
    # fired round's history must not leak into the next round.
    assert not t2.observe(0.8)
    assert t2.c1 == pytest.approx(0.8)
    t2.reset()
    assert t2.c1 == 1.0


def test_make_trigger_factory():
    for kind, kw in [("dual", dict(r1=0.5, r2=0.5)), ("fixed", dict(n=4)), ("token", dict(r=0.9)), ("sequence", dict(r1=0.3))]:
        t = make_trigger(kind, window=8, **kw)
        assert isinstance(t, WindowCapTrigger)
    with pytest.raises(KeyError):
        make_trigger("nope")
