"""Dual-threshold NAV trigger + baseline policy semantics."""

import pytest
from hypothesis import given, settings, strategies as st

from repro.core.trigger import (
    DualThresholdTrigger,
    FixedLengthTrigger,
    SequenceThresholdTrigger,
    TokenThresholdTrigger,
    WindowCapTrigger,
    make_trigger,
)

confs = st.lists(st.floats(min_value=1e-6, max_value=1.0), min_size=1, max_size=50)


def test_dual_fires_on_token_threshold():
    t = DualThresholdTrigger(r1=0.0, r2=0.5)
    assert not t.observe(0.9)
    assert t.observe(0.4)


def test_dual_fires_on_sequence_threshold():
    t = DualThresholdTrigger(r1=0.5, r2=0.0)
    assert not t.observe(0.9)  # C1 = 0.9
    assert not t.observe(0.8)  # C1 = 0.72
    assert t.observe(0.6)  # C1* = 0.432 ≤ 0.5 → fire
    # C1 resets to 1 after the trigger (§3.3).
    assert t.c1 == 1.0


@settings(max_examples=50, deadline=None)
@given(cs=confs, r1=st.floats(0, 1), r2=st.floats(0, 1))
def test_dual_trigger_invariant(cs, r1, r2):
    """Between fires, the running product stays above R1 and every conf > R2."""
    t = DualThresholdTrigger(r1=r1, r2=r2)
    prod = 1.0
    for c in cs:
        fired = t.observe(c)
        if fired:
            prod = 1.0
            assert c <= r2 or True  # fired by either rule
        else:
            prod *= c
            assert prod > r1
            assert c > r2


def test_fixed_length():
    t = FixedLengthTrigger(n=3)
    fires = [t.observe(0.9) for _ in range(7)]
    assert fires == [False, False, True, False, False, True, False]


def test_hsl_token_threshold():
    t = TokenThresholdTrigger(r=0.99)
    assert t.observe(0.98) and not t.observe(0.995)


def test_edgellm_dynamic_threshold_moves():
    t = SequenceThresholdTrigger(r1=0.3)
    # Full acceptance halves R1.
    t.on_verify(10, 10)
    assert t.r1 == pytest.approx(0.15)
    # Rejection raises it (divide by rejected fraction, App. G.3 Eq. 7).
    t.on_verify(5, 10)
    assert t.r1 == pytest.approx(0.30)


def test_window_cap_forces_fire():
    t = WindowCapTrigger(DualThresholdTrigger(r1=0.0, r2=0.0), window=4)
    fires = [t.observe(1.0) for _ in range(9)]
    assert fires == [False, False, False, True] * 2 + [False]


def test_make_trigger_factory():
    for kind, kw in [("dual", dict(r1=0.5, r2=0.5)), ("fixed", dict(n=4)), ("token", dict(r=0.9)), ("sequence", dict(r1=0.3))]:
        t = make_trigger(kind, window=8, **kw)
        assert isinstance(t, WindowCapTrigger)
    with pytest.raises(KeyError):
        make_trigger("nope")
