"""Two-sided energy accounting: edge joules + cloud joules, end to end.

The paper's ECS metric (§5.2.1) charges BOTH sides of the pipeline: the
edge device (idle draw + draft decode + radio) and the cloud verifier
(power above idle while verifying).  Contracts:

* ``EdgeModel.edge_energy`` is the documented closed form, with DVFS
  scaling on the decode power for emulated slower tiers;
* ``RunStats`` carries ``edge_energy`` alongside ``cloud_energy``;
  ``ecs`` stays the historical cloud-only alias (deprecated), while
  ``energy_per_100_tokens`` is the combined metric;
* the sim engine and the fleet harness both populate the edge side;
* the committed ``BENCH_scenarios.json`` energy rows land inside the
  paper's claimed 14.3–25.3% reduction band, and the adaptive policy
  matches-or-beats the best static policy in ≥3 of 4 scenarios.
"""

import json
from pathlib import Path

import pytest

from repro.core.pipeline import CloudModel, EdgeModel, RunStats

ROOT = Path(__file__).resolve().parent.parent


def test_edge_energy_closed_form():
    m = EdgeModel(p_idle=2.0, p_decode=4.5, p_tx=1.8)
    assert m.edge_energy(10.0, 4.0, 100.0) == pytest.approx(2.0 * 100 + 4.5 * 10 + 1.8 * 4)
    assert m.edge_energy(0.0, 0.0, 0.0) == 0.0
    assert m.edge_energy(-1.0, -1.0, -1.0) == 0.0  # negative times clamp to zero


def test_edge_energy_dvfs_scales_decode_power_only():
    fast = EdgeModel()
    slow = EdgeModel(simulated_ghz=fast.cpu_ghz / 2)
    assert slow.decode_power_scale() == pytest.approx(0.5)
    assert fast.decode_power_scale() == 1.0
    # Same decode time: the slow tier draws half the decode power...
    assert slow.edge_energy(10.0, 0.0, 0.0) == pytest.approx(fast.edge_energy(10.0, 0.0, 0.0) / 2 + 0.0)
    # ...but decodes 2x longer per token, so joules per drafted token match.
    assert slow.decode_power_scale() * slow.effective_gamma() == pytest.approx(
        fast.decode_power_scale() * fast.effective_gamma()
    )
    # Idle and radio are frequency-independent.
    assert slow.edge_energy(0.0, 3.0, 7.0) == fast.edge_energy(0.0, 3.0, 7.0)


def test_runstats_total_energy_and_deprecated_alias():
    st = RunStats(accepted_tokens=200, cloud_energy=50.0, edge_energy=150.0)
    assert st.total_energy == 200.0
    assert st.ecs_cloud == 25.0
    with pytest.warns(DeprecationWarning, match="CLOUD-ONLY"):
        assert st.ecs == 25.0  # deprecated alias: unchanged semantics, warns
    assert st.ecs_edge == 75.0
    assert st.energy_per_100_tokens == 100.0
    s = st.summary()
    assert s["ecs_j"] == pytest.approx(25.0)
    assert s["ecs_edge_j"] == pytest.approx(75.0)
    assert s["ecs_total_j"] == pytest.approx(100.0)


def test_engine_populates_edge_energy():
    from benchmarks.common import run_method

    _, st, _ = run_method("pipesd", n_tokens=120, seed=5, autotune=False)
    assert st.edge_energy > 0 and st.cloud_energy > 0
    assert st.total_energy == pytest.approx(st.edge_energy + st.cloud_energy)
    # The edge side is bounded below by the idle draw over the run.
    assert st.edge_energy >= EdgeModel().p_idle * st.wall_time


def test_fleet_populates_both_energies_and_session_spreads():
    from benchmarks.fleet_bench import HETERO_PROFILES, run_fleet
    from repro.runtime.simclock import VirtualClock

    rep = run_fleet(
        mode="batched", n_sessions=3, tokens_per_session=30, scen=1, seed=2,
        ts=1.0, clock=VirtualClock(), profiles=HETERO_PROFILES,
        nav_timeout=1.0, backoff_init=0.1, local_gamma=8.0,
    )
    st: RunStats = rep["stats"]
    assert st.edge_energy > 0 and st.cloud_energy > 0
    assert len(st.session_gammas) == len(st.session_betas) == 3
    # One session per HETERO profile: the spreads reflect the tier ratios.
    assert st.gamma_spread == pytest.approx(5.1 / 1.2)
    assert st.beta_spread == pytest.approx(3.0 / 0.5)


def test_committed_energy_rows_hit_the_paper_band():
    rows = json.loads((ROOT / "BENCH_scenarios.json").read_text())["rows"]
    energy = [r for r in rows if r.get("family") == "energy"]
    assert len(energy) == 4
    for r in energy:
        assert 14.3 <= r["energy_reduction_pct"] <= 25.3, r
        assert r["speedup"] > 1.0
        assert r["pipesd_ecs_total_j"] == pytest.approx(
            r["pipesd_ecs_edge_j"] + r["pipesd_ecs_cloud_j"], rel=1e-4
        )


def test_committed_adaptive_policy_wins_enough_scenarios():
    rows = json.loads((ROOT / "BENCH_scenarios.json").read_text())["rows"]
    summary = next(r for r in rows if r.get("scenario") == "summary")
    assert summary["adaptive_wins"] >= 3
    assert summary["n_scenarios"] == 4
    traces = [r for r in rows if r.get("family") == "trace"]
    assert traces and all(r["conformant"] for r in traces)


def test_cloud_energy_is_power_delta_times_verify_time():
    c = CloudModel()
    assert c.verify_energy(10) == pytest.approx(
        (c.p_active - c.p_idle) * (c.t_verify + 10 * c.t_verify_per_token)
    )
