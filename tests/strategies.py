"""Shared verify-batch strategies and the cross-path differential harness.

Every spec-verify test family (chain, tree, fused, batched, sharded) draws
its random cases from here so all paths are exercised on the SAME
distribution of shapes: ragged draft lengths, GQA head ratios, non-pow2
vocabularies, ragged block tables, and mixed accept/reject patterns.

Two case shapes exist:

* ``make_rect_case`` — a rectangular [B, K+1] fused-verify geometry (the
  kernel-level contract; ported from the ad-hoc builder that used to live
  in ``test_spec_verify_fused.py``).
* ``make_ragged_case`` — B ragged sessions with per-session draft lengths
  and block tables, materialized over one shared page arena (the serving
  contract of the ``*_batched`` entries).

``assert_paths_agree`` is the differential harness: given one ragged case
it runs every requested verify path — per-session chain composition,
chain-topology tree, per-session fused, one-launch fused-batched, and the
sharded launch at each shard count — and asserts they agree.  Paths that
share a launch geometry must agree BIT-FOR-BIT (``assert_array_equal`` on
the log-probs); integer verdicts (n_accepted, correction) must be equal
across every path unconditionally.
"""

from __future__ import annotations

import dataclasses
from typing import List, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np
from hypothesis import strategies as st

from repro.kernels.decode_attention import paged_decode_attention
from repro.kernels.spec_verify import (
    fused_target_logits,
    spec_verify,
    spec_verify_batched,
    spec_verify_fused,
    spec_verify_fused_batched,
    spec_verify_tree_batched,
)

KEY = jax.random.PRNGKey(23)

# --------------------------------------------------------------------------- #
# Hypothesis strategies
# --------------------------------------------------------------------------- #
# Non-pow2 vocabularies on purpose: padded lanes must stay inert everywhere.
VOCABS = (96, 256, 384)
GQA_RATIOS = (1, 2, 3)


def rect_geometries(max_B: int = 3, max_K: int = 4):
    """Rectangular fused-verify geometries (kwargs for ``make_rect_case``).

    ``H = Hkv * gqa`` and ``P/V`` are derived by the consumer so every drawn
    dict is valid by construction (GQA divides, enough pages for the tables).
    """
    return st.fixed_dictionaries(
        dict(
            B=st.integers(1, max_B),
            K=st.integers(1, max_K),
            Hkv=st.sampled_from([1, 2]),
            gqa=st.sampled_from(list(GQA_RATIOS)),
            bs=st.sampled_from([4, 8]),
            G=st.integers(2, 4),
            seed=st.integers(0, 10_000),
        )
    )


def ragged_geometries(max_sessions: int = 4, max_k: int = 6):
    """Ragged serving-batch geometries (kwargs for ``make_ragged_case``)."""
    return st.fixed_dictionaries(
        dict(
            ks=st.lists(st.integers(1, max_k), min_size=1, max_size=max_sessions),
            Hkv=st.sampled_from([1, 2]),
            gqa=st.sampled_from(list(GQA_RATIOS)),
            bs=st.sampled_from([4, 8]),
            V=st.sampled_from(list(VOCABS)),
            seed=st.integers(0, 10_000),
            accept_bias=st.sampled_from([None, 0.0, 0.7, 1.0]),
        )
    )


# --------------------------------------------------------------------------- #
# Case builders
# --------------------------------------------------------------------------- #
def make_rect_case(B, K, H, Hkv, hd, bs, G, P, V, seed=0, sharp=False):
    """Random queries/pages/LM-head/tables + causal per-position lengths."""
    rng = np.random.default_rng(seed)
    ks = jax.random.split(jax.random.PRNGKey(seed), 4)
    q = jax.random.normal(ks[0], (B, K + 1, H, hd))
    k_pages = jax.random.normal(ks[1], (P, bs, Hkv, hd))
    v_pages = jax.random.normal(ks[2], (P, bs, Hkv, hd))
    scale = 8.0 if sharp else 1.0  # sharp => near-deterministic greedy
    w = jax.random.normal(ks[3], (H * hd, V)) * scale
    tables = np.stack([rng.choice(P, G, replace=False) for _ in range(B)]).astype(np.int32)
    S = G * bs
    # lengths[b, i] = KV visible to position i; last position sees base+K.
    base = rng.integers(1, S - K, size=B)
    lengths = (base[:, None] + np.arange(K + 1)[None, :]).astype(np.int32)
    tokens = rng.integers(0, V, size=(B, K)).astype(np.int32)
    nd = rng.integers(0, K + 1, size=B).astype(np.int32)
    nd[0] = K  # always exercise a full-length row
    return q, k_pages, v_pages, w, jnp.asarray(tables), jnp.asarray(lengths), jnp.asarray(tokens), jnp.asarray(nd)


@dataclasses.dataclass(frozen=True)
class RaggedCase:
    """B ragged sessions over one shared page arena (the serving shape)."""

    q_seq: List[np.ndarray]  # per session [K_i+1, H, hd]
    tok_seq: List[List[int]]
    tables_seq: List[List[int]]
    base: List[int]  # committed KV length per session
    k_pages: jnp.ndarray  # [P, bs, Hkv, hd] (or int8 when quantized)
    v_pages: jnp.ndarray
    w: jnp.ndarray  # [H*hd, V]
    v_true: int
    sentinel_page: int
    quant: Optional[Tuple] = None  # (k_scale, k_zero, v_scale, v_zero)

    @property
    def ks(self) -> List[int]:
        return [len(t) for t in self.tok_seq]


def make_ragged_case(
    ks: Sequence[int],
    *,
    Hkv: int = 2,
    gqa: int = 1,
    hd: int = 8,
    bs: int = 4,
    V: int = 256,
    seed: int = 0,
    sharp: bool = False,
    accept_bias: Optional[float] = None,
    quantize: Optional[str] = None,
) -> RaggedCase:
    """Materialize B ragged sessions with disjoint tables over one arena.

    ``accept_bias`` controls the accept/reject pattern: ``None`` draws
    uniform tokens, a float p replaces each draft with the target's greedy
    token with probability p (1.0 = all-accepted rounds, 0.0 = guaranteed
    first-token rejection under a sharp LM head).
    """
    H = Hkv * gqa
    rng = np.random.default_rng(seed)
    keys = jax.random.split(jax.random.fold_in(KEY, seed), 2 * len(ks) + 3)
    # Upper bound on pages any draw can need; page 0 reserved as sentinel.
    P = sum((k + 9 + bs - 1) // bs for k in ks) + 2
    k_pages = jax.random.normal(keys[-1], (P, bs, Hkv, hd))
    v_pages = jax.random.normal(keys[-2], (P, bs, Hkv, hd))
    scale = 8.0 if sharp else 1.0
    w = jax.random.normal(keys[-3], (H * hd, V)) * scale
    q_seq, tok_seq, tables_seq, base = [], [], [], []
    free = list(range(1, P))
    rng.shuffle(free)
    for s, k in enumerate(ks):
        T = int(rng.integers(k + 2, k + 10))
        G = (T + bs - 1) // bs
        tables_seq.append([free.pop() for _ in range(G)])
        q_seq.append(np.asarray(jax.random.normal(keys[2 * s], (k + 1, H, hd)), np.float32))
        base.append(T - k)
        tok_seq.append(rng.integers(0, V, size=k).tolist())
    quant = None
    if quantize == "int8":
        from repro.models.paged_kv import PagedKVPool

        kq, ksc, kz = PagedKVPool.quantize_kv(k_pages)
        vq, vsc, vz = PagedKVPool.quantize_kv(v_pages)
        k_pages, v_pages, quant = kq, vq, (ksc, kz, vsc, vz)
    case = RaggedCase(q_seq, tok_seq, tables_seq, base, k_pages, v_pages, w, V, 0, quant)
    if accept_bias is not None:
        greedy = [np.argmax(lg, axis=-1) for lg in session_logits(case)]
        mix = rng.random(sum(ks)) < accept_bias
        it = iter(mix)
        case = dataclasses.replace(
            case,
            tok_seq=[
                [int(g[i]) if next(it) else int((g[i] + 1) % V) for i in range(k)]
                for g, k in zip(greedy, ks)
            ],
        )
    return case


def pool_backed_case(case: RaggedCase, num_blocks: int = 64):
    """Rebuild a RaggedCase inside a real ``PagedKVPool`` (same values).

    Returns ``(pool, case2)`` where ``case2`` reads pages from the pool's
    arena: tables are pool-assigned, the sentinel contract is the pool's.
    """
    from repro.kernels.decode_attention.ref import dequantize_pages
    from repro.models.paged_kv import PagedKVPool

    _, bs, Hkv, hd = case.k_pages.shape
    pool = PagedKVPool(
        num_blocks=num_blocks, block_size=int(bs), n_layers=1,
        n_kv_heads=int(Hkv), head_dim=int(hd),
        quantize="int8" if case.quant is not None else None,
    )
    kp, vp = jnp.asarray(case.k_pages), jnp.asarray(case.v_pages)
    if case.quant is not None:
        ksc, kz, vsc, vz = case.quant
        kp = dequantize_pages(kp, ksc, kz)
        vp = dequantize_pages(vp, vsc, vz)
    kp, vp = np.asarray(kp), np.asarray(vp)
    tables_seq = []
    for s, (k, tab) in enumerate(zip(case.ks, case.tables_seq)):
        T = case.base[s] + k
        k_rows = kp[tab].reshape(-1, Hkv, hd)[:T]
        v_rows = vp[tab].reshape(-1, Hkv, hd)[:T]
        pool.create(s)
        pool.write(s, jnp.asarray(k_rows[None]), jnp.asarray(v_rows[None]))
        tables_seq.append(list(pool.table(s)))
    case2 = dataclasses.replace(
        case,
        tables_seq=tables_seq,
        k_pages=pool.k_pages[0],
        v_pages=pool.v_pages[0],
        sentinel_page=pool.sentinel_page,
        quant=(pool.k_scale[0], pool.k_zero[0], pool.v_scale[0], pool.v_zero[0])
        if case.quant is not None
        else None,
    )
    return pool, case2


def ragged_logits_requests(ks, V, seed=0):
    """Per-session logits [K_i+1, V] + drafts with a mix of greedy/random.

    The logits-level (no KV pages) ragged batch for the chain/tree scan
    entries; ported from the ad-hoc builder in ``test_spec_verify_batched``.
    """
    logits_seq, tokens_seq = [], []
    for i, k in enumerate(ks):
        keys = jax.random.split(jax.random.fold_in(KEY, seed * 101 + i), 3)
        lg = jax.random.normal(keys[0], (k + 1, V)) * 3
        greedy = jnp.argmax(lg, -1)[:k]
        rnd = jax.random.randint(keys[1], (k,), 0, V)
        mix = jax.random.bernoulli(keys[2], 0.7, (k,))
        tokens_seq.append(np.asarray(jnp.where(mix, greedy, rnd), np.int32))
        logits_seq.append(np.asarray(lg, np.float32))
    return logits_seq, tokens_seq


def fused_backend(quantize=None, impl="ref", num_blocks=16, shards=None):
    """The serving fused backend over a real pool; sharded when ``shards``.

    One fixed tiny geometry (H=2, hd=8, bs=4, V=256) with seeded LM head and
    queries, so unsharded and sharded backends built here are comparable
    request-for-request.  Returns ``(backend, pool, w, V)``.
    """
    from repro.models.paged_kv import PagedKVPool
    from repro.runtime import ShardedSpecVerifyBackend, SpecVerifyBackend

    H, hd, bs, V = 2, 8, 4, 256
    pool = PagedKVPool(
        num_blocks=num_blocks, block_size=bs, n_layers=1, n_kv_heads=H, head_dim=hd,
        quantize=quantize,
    )
    w = np.asarray(jax.random.normal(jax.random.fold_in(KEY, 77), (H * hd, V)) * 4, np.float32)

    def query_fn(session, tokens):
        k = jax.random.fold_in(jax.random.fold_in(KEY, 88), session * 131 + len(tokens))
        return np.asarray(jax.random.normal(k, (len(tokens) + 1, H, hd)), np.float32)

    kw = dict(kv_pool=pool, query_fn=query_fn, lm_head=w, impl=impl, block_v=256)
    if shards is None:
        backend = SpecVerifyBackend(fused=True, **kw)
    else:
        backend = ShardedSpecVerifyBackend(shards=shards, **kw)
    return backend, pool, w, V


# --------------------------------------------------------------------------- #
# Reference compositions
# --------------------------------------------------------------------------- #
def composed_verify(q, k_pages, v_pages, w, tables, lengths, tokens, nd, *, impl, block_v, quant=None):
    """The unfused two-launch path the fused kernel must reproduce bitwise."""
    logits = composed_logits(
        q, k_pages, v_pages, w, tables, lengths, impl=impl, block_v=block_v, quant=quant
    )
    bv = min(block_v, int(w.shape[1]))
    return spec_verify(logits, tokens, nd, impl=impl, block_v=bv)


def composed_logits(q, k_pages, v_pages, w, tables, lengths, *, impl, block_v, quant=None):
    """Paged attention + blocked LM head: target logits [B, K+1, Vp]."""
    B, K1, H, hd = q.shape
    o = paged_decode_attention(
        q.reshape(B * K1, H, hd),
        k_pages,
        v_pages,
        jnp.repeat(tables, K1, axis=0),
        lengths.reshape(-1),
        impl=impl,
        quant=quant,
    )
    o = o.reshape(B, K1, H * hd).astype(jnp.float32)
    V = w.shape[1]
    bv = min(block_v, V)
    Vp = -(-V // bv) * bv
    wp = jnp.pad(w.astype(jnp.float32), ((0, 0), (0, Vp - V)))
    return fused_target_logits(o, wp, block_v=bv, v_true=V)


def session_logits(case: RaggedCase, *, impl: str = "ref", block_v: int = 256):
    """Per-session target logits [K_i+1, Vp] through the composition."""
    out = []
    for s, k in enumerate(case.ks):
        lengths = jnp.asarray([[case.base[s] + i for i in range(k + 1)]], jnp.int32)
        tab = jnp.asarray([case.tables_seq[s]], jnp.int32)
        lg = composed_logits(
            jnp.asarray(case.q_seq[s])[None], case.k_pages, case.v_pages, case.w,
            tab, lengths, impl=impl, block_v=block_v, quant=case.quant,
        )
        out.append(np.asarray(lg)[0])
    return out


def session_fused(case: RaggedCase, *, impl: str = "ref", block_v: int = 256):
    """Per-session rectangular fused verify (B=1, no batch padding)."""
    out = []
    for s, k in enumerate(case.ks):
        lengths = jnp.asarray([[case.base[s] + i for i in range(k + 1)]], jnp.int32)
        tab = jnp.asarray([case.tables_seq[s]], jnp.int32)
        na, corr, logp = spec_verify_fused(
            jnp.asarray(case.q_seq[s])[None], case.k_pages, case.v_pages, case.w,
            tab, lengths, jnp.asarray([case.tok_seq[s]], jnp.int32),
            jnp.asarray([k], jnp.int32), impl=impl, block_v=block_v, quant=case.quant,
        )
        out.append((int(np.asarray(na)[0, 0]), int(np.asarray(corr)[0, 0]), np.asarray(logp)[0, :k]))
    return out


# --------------------------------------------------------------------------- #
# Assertions
# --------------------------------------------------------------------------- #
def assert_triples_match(got, want, ks=None):
    """Rectangular results bit-for-bit (ragged: only real draft lanes)."""
    na_f, corr_f, logp_f = (np.asarray(x) for x in got)
    na_c, corr_c, logp_c = (np.asarray(x) for x in want)
    np.testing.assert_array_equal(na_f, na_c)
    np.testing.assert_array_equal(corr_f, corr_c)
    if ks is None:
        np.testing.assert_array_equal(logp_f, logp_c)
    else:  # ragged: only real draft lanes are defined
        for i, k in enumerate(ks):
            np.testing.assert_array_equal(logp_f[i, :k], logp_c[i, :k])


def assert_ragged_match(got, want, *, exact_logp=True, label=""):
    """Per-session (na, corr, logp) lists agree; logp bitwise when asked."""
    assert len(got) == len(want), label
    for i, ((na1, c1, lp1), (na2, c2, lp2)) in enumerate(zip(got, want)):
        assert (int(na1), int(c1)) == (int(na2), int(c2)), f"{label} session {i}"
        if exact_logp:
            np.testing.assert_array_equal(np.asarray(lp1), np.asarray(lp2), err_msg=f"{label} session {i}")
        else:
            np.testing.assert_allclose(np.asarray(lp1), np.asarray(lp2), atol=1e-5, err_msg=f"{label} session {i}")


def assert_paths_agree(
    case: RaggedCase,
    *,
    impl: str = "ref",
    block_v: int = 256,
    shards: Sequence[int] = (),
    paths: Sequence[str] = ("chain", "tree", "fused", "batched"),
):
    """The differential harness: every verify path agrees on ``case``.

    The one-launch ``spec_verify_fused_batched`` result is the pivot.  The
    sharded launch (every count in ``shards``) must match it BIT-FOR-BIT —
    identical padding, identical arithmetic.  The per-session fused path and
    the chain/tree scans over composed logits share that launch's values but
    not its padded shapes, so their integer verdicts must be equal and their
    log-probs compared per real lane.

    Returns the pivot (the batched result) so callers can chain asserts.
    """
    ks = case.ks
    pivot = spec_verify_fused_batched(
        case.q_seq, case.tok_seq, case.tables_seq, case.base,
        case.k_pages, case.v_pages, case.w,
        impl=impl, block_v=block_v, pad_page_id=case.sentinel_page, quant=case.quant,
    )
    if "fused" in paths:
        solo = session_fused(case, impl=impl, block_v=block_v)
        assert_ragged_match(pivot, solo, exact_logp=False, label="fused-batched vs per-session fused")
    logits = None
    if "chain" in paths or "tree" in paths:
        logits = session_logits(case, impl=impl, block_v=block_v)
    if "chain" in paths:
        # Per-session composition (B=1): the two-launch chain oracle.  It is
        # bit-exact vs the per-session fused entry by the kernel contract.
        comp = []
        for s, k in enumerate(ks):
            lengths = jnp.asarray([[case.base[s] + i for i in range(k + 1)]], jnp.int32)
            tab = jnp.asarray([case.tables_seq[s]], jnp.int32)
            na, corr, lp = composed_verify(
                jnp.asarray(case.q_seq[s])[None], case.k_pages, case.v_pages, case.w,
                tab, lengths, jnp.asarray([case.tok_seq[s]], jnp.int32),
                jnp.asarray([k], jnp.int32), impl=impl, block_v=block_v, quant=case.quant,
            )
            comp.append((int(np.asarray(na)[0, 0]), int(np.asarray(corr)[0, 0]), np.asarray(lp)[0, :k]))
        if "fused" in paths:
            assert_ragged_match(session_fused(case, impl=impl, block_v=block_v), comp,
                                exact_logp=True, label="per-session fused vs chain composition")
        # One-launch chain scan over the SAME composed logits.
        bv = min(block_v, case.v_true)
        scan = spec_verify_batched(logits, case.tok_seq, impl=impl, block_v=bv)
        assert_ragged_match(scan, comp, exact_logp=False, label="batched chain scan vs composition")
    if "tree" in paths:
        # A chain-topology tree must reduce to chain verify: same verdicts,
        # accepted tokens are exactly the accepted draft prefix.
        parents_seq = [list(range(-1, k - 1)) for k in ks]
        bv = min(block_v, case.v_true)
        tree = spec_verify_tree_batched(logits, case.tok_seq, parents_seq, impl=impl, block_v=bv)
        for s, ((na_t, path_t, corr_t, _lp), (na_p, corr_p, _)) in enumerate(zip(tree, pivot)):
            assert int(na_t) == int(na_p), f"tree vs fused-batched session {s}"
            assert int(corr_t) == int(corr_p), f"tree vs fused-batched session {s}"
            # Chain topology: the accepted root->leaf path is node 0..na-1.
            assert list(path_t) == list(range(int(na_t))), f"tree path session {s}"
    for n in shards:
        from repro.sharding.spec_verify import spec_verify_sharded_batched

        sharded = spec_verify_sharded_batched(
            case.q_seq, case.tok_seq, case.tables_seq, case.base,
            case.k_pages, case.v_pages, case.w,
            shards=n, block_v=block_v, pad_page_id=case.sentinel_page, quant=case.quant,
        )
        assert_ragged_match(sharded, pivot, exact_logp=True, label=f"sharded@{n} vs fused-batched")
    return pivot
