"""Deterministic observability subsystem: traces, metrics, telemetry, dashboard.

The contracts under test (ISSUE 10 acceptance):

* span tracing is clock-driven — under ``VirtualClock`` two seeded runs
  export **byte-identical** Chrome-trace JSON, and a traced router-fleet
  run reports the SAME committed rows as the untraced run (tracing only
  *reads* simulated time, so the overhead gate holds exactly, not just
  within the <2% budget);
* the per-round analyzer (wall / busy / bubble / critical stage) is exact
  on hand-built span timelines;
* the metric registry exposes Prometheus text with deterministic ordering
  and correct counter/gauge/histogram semantics;
* ``TelemetrySnapshot`` matches the verifier's own ground-truth stats, the
  router's fleet aggregate matches the per-verifier sum, and the snapshot
  codec round-trips exactly (hypothesis-covered in test_protocol.py);
* the HTTP endpoint serves ``/metrics`` + ``/snapshot`` on wall time only
  (``VirtualClock`` is rejected), and the dashboard renders a frame from
  the polled payload as a pure function.
"""

import json

import pytest

from repro.obs.dashboard import render_dashboard
from repro.obs.endpoint import (
    SNAPSHOT_COUNTER_FIELDS,
    SNAPSHOT_GAUGE_FIELDS,
    TelemetryEndpoint,
    aggregate_snapshots,
    prometheus_text_from_snapshots,
    snapshot_to_dict,
)
from repro.obs.metrics import LATENCY_BUCKETS, MetricRegistry, absorb_monitor
from repro.obs.trace import (
    NULL_TRACER,
    ROUND_STAGES,
    Span,
    Tracer,
    critical_path,
    round_report,
    session_bubble_fractions,
)
from repro.runtime import (
    Channel,
    ChannelConfig,
    CloudVerifier,
    EdgeClient,
    EdgeConfig,
    LocalVerifier,
    OracleBackend,
    OracleDraft,
    Router,
    TelemetrySnapshot,
    VirtualClock,
    decode,
    encode,
)

# --------------------------------------------------------------------------- #
# Traced fleet fixture: Router + 2 oracle verifiers + N clients, one clock
# --------------------------------------------------------------------------- #


def _run_traced_fleet(seed=0, n_verifiers=2, n_sessions=4, tokens=20):
    """Serve a small traced oracle fleet; capture telemetry pre-shutdown."""
    clock = VirtualClock()
    tracer = Tracer(clock=clock)
    registry = MetricRegistry(clock=clock)
    fleet = []
    for vid in range(n_verifiers):
        backend = OracleBackend(
            seed=seed, verify_time=0.06, verify_time_per_token=0.002, clock=clock
        )
        cv = CloudVerifier(
            backend, batch_window=0.0, max_batch=1, clock=clock,
            tracer=tracer, metrics=registry, verifier_id=vid,
        )
        cv.start()
        fleet.append(LocalVerifier(vid, cv, clock=clock))
    router = Router(fleet, clock=clock, control_interval=1.0, tracer=tracer)
    link = ChannelConfig(alpha=0.005, beta=0.0005)
    clients = []
    for sid in range(n_sessions):
        up = Channel(link, f"up{sid}", clock=clock)
        dn = Channel(link, f"dn{sid}", clock=clock)
        router.attach(sid, up, dn)
        cfg = EdgeConfig(gamma=0.004, window=8, nav_timeout=30.0)
        clients.append(
            EdgeClient(sid, up, dn, cfg, draft=OracleDraft(seed=seed), tracer=tracer)
        )
    results, telem = {}, {}

    def _drive(c):
        results[c.session] = c.run(tokens)

    def _serve():
        router.start()
        handles = [
            clock.spawn((lambda c=c: _drive(c)), name=f"drive-{c.session}")
            for c in clients
        ]
        for h in handles:
            h.join()
        telem["snaps"], telem["agg"] = router.telemetry(seq=7)
        router.stop()
        for vc in fleet:
            vc.stop()

    clock.run(_serve)
    return dict(
        tracer=tracer, registry=registry, fleet=fleet, router=router,
        results=results, snaps=telem["snaps"], agg=telem["agg"],
    )


@pytest.fixture(scope="module")
def traced_fleet():
    return _run_traced_fleet()


# --------------------------------------------------------------------------- #
# Tracing
# --------------------------------------------------------------------------- #


def test_tracer_records_spans_on_the_injected_clock():
    clock = VirtualClock()
    tracer = Tracer(clock=clock)

    def _work():
        with tracer.span("draft", session=3, round=0):
            clock.sleep(0.25)
        tracer.add("upload", 0.25, 0.5, session=3, round=0)

    clock.run(_work)
    spans = tracer.spans()
    assert [s.name for s in spans] == ["draft", "upload"]
    assert spans[0].t0 == 0.0 and spans[0].t1 == 0.25
    assert spans[0].duration == 0.25
    assert spans[0].get("session") == 3 and spans[0].get("missing", -1) == -1


def test_tracer_ring_buffer_bounds_memory():
    tracer = Tracer(clock=VirtualClock(), capacity=4)
    for i in range(10):
        tracer.add("verify", float(i), float(i) + 0.5, round=i)
    spans = tracer.spans()
    assert len(tracer) == 4
    assert [s.get("round") for s in spans] == [6, 7, 8, 9]  # oldest evicted


def test_null_tracer_is_inert_and_clock_free():
    assert NULL_TRACER.enabled is False
    assert NULL_TRACER.clock is None
    with NULL_TRACER.span("draft", session=1):
        pass
    NULL_TRACER.add("verify", 0.0, 1.0)
    assert len(NULL_TRACER) == 0


def test_chrome_export_is_valid_and_deterministic():
    def _build():
        t = Tracer(clock=VirtualClock())
        t.add("draft", 0.0, 0.001, session=1, round=0)
        t.add("verify", 0.002, 0.004, session=1, round=0)
        t.add("frame", 0.001, 0.0015, link="up1", bytes=64)
        return t.export_chrome_trace()

    blob = _build()
    assert blob == _build()  # bit-identical re-render
    doc = json.loads(blob)
    events = doc["traceEvents"]
    assert len(events) == 3 and all(e["ph"] == "X" for e in events)
    draft = next(e for e in events if e["name"] == "draft")
    assert draft["pid"] == 1 and draft["ts"] == 0.0 and draft["dur"] == 1000.0
    frame = next(e for e in events if e["name"] == "frame")
    assert frame["pid"] == 0 and frame["args"] == {"bytes": 64, "link": "up1"}


def test_seeded_fleet_trace_export_is_byte_identical():
    """The headline determinism claim: same seed => same bytes, twice."""
    a = _run_traced_fleet(seed=3, n_sessions=2, tokens=10)
    b = _run_traced_fleet(seed=3, n_sessions=2, tokens=10)
    blob_a = a["tracer"].export_chrome_trace()
    blob_b = b["tracer"].export_chrome_trace()
    assert blob_a == blob_b
    assert len(json.loads(blob_a)["traceEvents"]) == len(a["tracer"])
    c = _run_traced_fleet(seed=4, n_sessions=2, tokens=10)
    assert c["tracer"].export_chrome_trace() != blob_a  # seed actually matters


def test_fleet_spans_cover_every_pipeline_stage(traced_fleet):
    names = {s.name for s in traced_fleet["tracer"].spans()}
    assert set(ROUND_STAGES) <= names, names


# --------------------------------------------------------------------------- #
# Round analyzer: wall / busy / bubble / critical stage
# --------------------------------------------------------------------------- #


def _span(name, t0, t1, session=0, rnd=0):
    return Span(name, t0, t1, (("round", rnd), ("session", session)))


def test_round_report_on_a_gapless_round():
    spans = [
        _span("draft", 0.0, 1.0),
        _span("upload", 1.0, 2.0),
        _span("nav_queue", 2.0, 2.5),
        _span("verify", 2.5, 4.0),
        _span("commit", 4.0, 4.5),
    ]
    (rep,) = round_report(spans)
    assert rep["wall"] == pytest.approx(4.5)
    assert rep["busy"] == pytest.approx(4.5)
    assert rep["bubble_fraction"] == pytest.approx(0.0)
    assert rep["critical_stage"] == "verify"
    assert rep["stage_s"]["nav_queue"] == pytest.approx(0.5)


def test_round_report_measures_bubbles_and_overlap():
    # draft [0,1], verify [2,4]: a 1s hole => bubble 1/4; overlapping spans
    # must not double-count busy time (union, not sum).
    spans = [
        _span("draft", 0.0, 1.0),
        _span("verify", 2.0, 4.0),
        _span("commit", 3.5, 4.0),  # overlaps verify entirely
    ]
    (rep,) = round_report(spans)
    assert rep["wall"] == pytest.approx(4.0)
    assert rep["busy"] == pytest.approx(3.0)
    assert rep["bubble_fraction"] == pytest.approx(0.25)
    assert rep["critical_stage"] == "verify"


def test_round_report_ties_break_in_pipeline_order():
    spans = [_span("draft", 0.0, 1.0), _span("upload", 1.0, 2.0)]
    (rep,) = round_report(spans)
    assert rep["critical_stage"] == "draft"  # equal durations: earliest stage wins


def test_round_report_groups_by_session_and_round():
    spans = [
        _span("draft", 0.0, 1.0, session=1, rnd=0),
        _span("draft", 5.0, 5.5, session=1, rnd=1),
        _span("verify", 0.0, 2.0, session=2, rnd=0),
        Span("frame", 0.0, 1.0, ()),  # not a round stage: ignored
        Span("draft", 0.0, 1.0, (("session", 9),)),  # no round attr: ignored
    ]
    reps = round_report(spans)
    assert [(r["session"], r["round"]) for r in reps] == [(1, 0), (1, 1), (2, 0)]
    assert critical_path(spans, 2, 0) == "verify"
    assert critical_path(spans, 7, 7) is None
    bubbles = session_bubble_fractions(spans)
    assert bubbles[1] == pytest.approx(0.0) and bubbles[2] == pytest.approx(0.0)


def test_fleet_rounds_analyze_cleanly(traced_fleet):
    reps = round_report(traced_fleet["tracer"].spans())
    assert reps, "traced fleet produced no analyzable rounds"
    for rep in reps:
        assert 0.0 <= rep["bubble_fraction"] <= 1.0
        assert rep["critical_stage"] in ROUND_STAGES
        assert rep["busy"] <= rep["wall"] + 1e-12


# --------------------------------------------------------------------------- #
# Metrics registry
# --------------------------------------------------------------------------- #


def test_counter_and_gauge_semantics():
    reg = MetricRegistry(clock=VirtualClock())
    c = reg.counter("navs", "NAV calls")
    c.inc()
    c.inc(2.0)
    c.inc(link="up0")
    assert c.value() == 3.0 and c.value(link="up0") == 1.0
    with pytest.raises(ValueError):
        c.inc(-1.0)  # counters are monotone
    g = reg.gauge("depth", "queue depth")
    g.set(4.0)
    g.inc(-1.0)
    assert g.value() == 3.0
    # Get-or-create: same name returns the SAME metric; kind conflicts raise.
    assert reg.counter("navs") is c
    with pytest.raises(ValueError):
        reg.gauge("navs")


def test_histogram_buckets_and_moments():
    reg = MetricRegistry(clock=VirtualClock())
    h = reg.histogram("lat", "latency", buckets=(0.1, 1.0, 10.0))
    for v in (0.05, 0.5, 0.5, 5.0, 50.0):
        h.observe(v)
    assert h.count() == 5
    assert h.sum() == pytest.approx(56.05)
    # Prometheus semantics: cumulative per-edge counts, +Inf implicit (the
    # 50.0 observation only shows up in count()).
    assert h.bucket_counts() == {0.1: 1, 1.0: 3, 10.0: 4}


def test_prometheus_text_is_deterministic_and_complete():
    reg = MetricRegistry(clock=VirtualClock())
    reg.counter("b_total", "second").inc(2.0)
    reg.counter("a_total", "first").inc(1.0, link="up0")
    reg.histogram("h", "hist", buckets=(1.0,)).observe(0.5)
    text = reg.prometheus_text()
    assert text == reg.prometheus_text()
    lines = text.splitlines()
    # Metric families render in sorted-name order with TYPE headers.
    assert lines.index("# TYPE a_total counter") < lines.index("# TYPE b_total counter")
    assert 'a_total{link="up0"} 1' in text
    assert "b_total 2" in text
    assert 'h_bucket{le="1"} 1' in text and 'h_bucket{le="+Inf"} 1' in text
    assert "h_sum 0.5" in text and "h_count 1" in text


def test_registry_samples_are_clock_stamped():
    clock = VirtualClock()
    reg = MetricRegistry(clock=clock)

    def _work():
        g = reg.gauge("x")
        g.set(1.0)
        clock.sleep(2.0)
        g.set(5.0)

    clock.run(_work)
    assert reg.get("x").samples() == [(0.0, 1.0), (2.0, 5.0)]


def test_absorb_monitor_mirrors_pipeline_monitor(traced_fleet):
    reg = MetricRegistry(clock=VirtualClock())
    absorb_monitor(traced_fleet["fleet"][0].verifier.monitor, reg)
    assert any(n.startswith("monitor_") for n in reg.names())


def test_fleet_registry_mirrors_verifier_stats(traced_fleet):
    reg = traced_fleet["registry"]
    total_navs = sum(
        vc.verifier.stats["nav_calls"] for vc in traced_fleet["fleet"]
    )
    navs = reg.get("verifier_nav_calls")
    assert navs is not None
    assert sum(navs.series().values()) == total_navs


# --------------------------------------------------------------------------- #
# Telemetry snapshots: wire codec, ground truth, fleet aggregation
# --------------------------------------------------------------------------- #


def test_snapshot_matches_verifier_ground_truth(traced_fleet):
    for vc in traced_fleet["fleet"]:
        snap = vc.verifier.telemetry_snapshot(seq=5)
        st = vc.verifier.stats
        assert snap.nav_calls == st["nav_calls"]
        assert snap.tokens_verified == st["tokens_verified"]
        assert snap.accepted_tokens == st["accepted_tokens"]
        assert snap.batched_calls == st["batched_calls"]
        assert snap.verify_busy_time == pytest.approx(st["verify_busy_time"])
        assert snap.verifier == vc.verifier_id and snap.seq == 5
        assert decode(encode(snap)) == snap  # exact through the wire


def test_router_aggregate_matches_per_verifier_sum(traced_fleet):
    snaps, agg = traced_fleet["snaps"], traced_fleet["agg"]
    assert len(snaps) == len(traced_fleet["fleet"])
    assert agg.verifier == -1 and agg.n_verifiers == len(snaps)
    for field in ("nav_calls", "tokens_verified", "accepted_tokens", "queue_depth"):
        assert getattr(agg, field) == sum(getattr(s, field) for s in snaps), field
    # ...and the per-verifier numbers are the fleet's real serving totals.
    assert agg.nav_calls == sum(
        vc.verifier.stats["nav_calls"] for vc in traced_fleet["fleet"]
    )
    # Verifier-side accepted_tokens counts accepted DRAFT tokens; clients
    # additionally commit one correction per NAV round.
    committed = sum(r["accepted_tokens"] for r in traced_fleet["results"].values())
    rounds = sum(r["rounds"] for r in traced_fleet["results"].values())
    assert committed == agg.accepted_tokens + rounds
    assert agg.occupancy == pytest.approx(
        sum(s.occupancy for s in snaps) / len(snaps)
    )
    # Router-side counters ride the extras lanes.
    assert "router_sessions_placed" in dict(zip(agg.names, agg.values))
    assert decode(encode(agg)) == agg


def test_aggregate_snapshots_field_classes_are_exhaustive():
    fields = set(SNAPSHOT_COUNTER_FIELDS) | set(SNAPSHOT_GAUGE_FIELDS)
    numeric = {
        f for f in TelemetrySnapshot.__dataclass_fields__
        if f not in ("session", "seq", "verifier", "n_verifiers", "t", "names", "values")
    }
    assert fields == numeric  # adding a snapshot field must classify it


def test_aggregate_snapshots_sums_and_averages():
    a = TelemetrySnapshot(verifier=0, t=1.0, nav_calls=10, occupancy=2.0,
                          sessions_active=3, names=("lane",), values=(1.0,))
    b = TelemetrySnapshot(verifier=1, t=2.0, nav_calls=5, occupancy=4.0,
                          sessions_active=1, names=("lane",), values=(2.0,))
    agg = aggregate_snapshots([a, b], seq=9)
    assert agg.nav_calls == 15 and agg.sessions_active == 4
    assert agg.occupancy == pytest.approx(3.0)  # mean, not sum
    assert agg.t == 2.0 and agg.seq == 9 and agg.n_verifiers == 2
    assert dict(zip(agg.names, agg.values))["lane"] == 3.0
    d = snapshot_to_dict(agg)
    assert d["nav_calls"] == 15 and d["extras"]["lane"] == 3.0
    assert "names" not in d and "values" not in d


def test_prometheus_text_from_snapshots(traced_fleet):
    snaps, agg = traced_fleet["snaps"], traced_fleet["agg"]
    text = prometheus_text_from_snapshots(snaps, aggregate=agg)
    assert "# TYPE pipesd_nav_calls counter" in text
    for s in snaps:
        assert f'pipesd_nav_calls{{verifier="{s.verifier}"}} {s.nav_calls}' in text
    assert f'pipesd_nav_calls{{verifier="-1"}} {agg.nav_calls}' in text
    assert f"pipesd_n_verifiers {len(snaps)}" in text


# --------------------------------------------------------------------------- #
# HTTP endpoint + dashboard
# --------------------------------------------------------------------------- #


def test_endpoint_serves_metrics_and_snapshot_over_http(traced_fleet):
    import urllib.request

    snaps, agg = traced_fleet["snaps"], traced_fleet["agg"]
    with TelemetryEndpoint(lambda: (snaps, agg), port=0) as ep:
        base = f"http://{ep.host}:{ep.port}"
        with urllib.request.urlopen(f"{base}/metrics", timeout=5) as resp:
            body = resp.read().decode()
            assert resp.headers["Content-Type"].startswith("text/plain")
        assert f'pipesd_nav_calls{{verifier="-1"}} {agg.nav_calls}' in body
        with urllib.request.urlopen(f"{base}/snapshot", timeout=5) as resp:
            payload = json.loads(resp.read().decode())
        assert payload["aggregate"]["nav_calls"] == agg.nav_calls
        assert len(payload["verifiers"]) == len(snaps)
        with pytest.raises(urllib.error.HTTPError):
            urllib.request.urlopen(f"{base}/nope", timeout=5)
    # The dashboard frame is a pure function of that payload.
    frame = render_dashboard(payload)
    assert frame.startswith("PipeSD fleet @ t=")
    assert f"verifiers={len(snaps)}" in frame
    lines = frame.splitlines()
    assert lines[-len(snaps) - 2].split()[:2] == ["vid", "sess"]  # header row
    assert render_dashboard(payload, ansi=True).startswith("\x1b[2J\x1b[H")


def test_endpoint_rejects_virtual_clock():
    with pytest.raises(ValueError, match="wall time"):
        TelemetryEndpoint(lambda: [], clock=VirtualClock())


def test_endpoint_registry_rides_the_metrics_page():
    reg = MetricRegistry(clock=VirtualClock())
    reg.counter("extra_total", "side metric").inc(3.0)
    snap = TelemetrySnapshot(verifier=0, nav_calls=1)
    with TelemetryEndpoint(lambda: snap, registry=reg, port=0) as ep:
        body = ep.render_metrics()
    assert 'pipesd_nav_calls{verifier="0"} 1' in body
    assert "extra_total 3" in body


# --------------------------------------------------------------------------- #
# Overhead gate: traced committed rows == untraced committed rows
# --------------------------------------------------------------------------- #


def test_traced_router_bench_rows_match_untraced_exactly():
    """Tracing must not perturb the committed bench: spans only READ the
    virtual clock, so every reported number is bit-identical — far inside
    the <2% overhead budget the committed ``router/x1_traced`` row gates."""
    from benchmarks.fleet_bench import run_router_fleet

    plain = run_router_fleet(1, n_sessions=4, tokens_per_session=20)
    traced = run_router_fleet(1, n_sessions=4, tokens_per_session=20, traced=True)
    for field in (
        "tokens_per_s", "tokens_per_nav", "nav_p50_ms", "nav_p99_ms",
        "bytes_per_session", "placement", "spread", "failovers", "wall_s",
    ):
        assert plain[field] == traced[field], field
    assert traced["n_spans"] == len(traced["_tracer"]) > 0


def test_committed_overhead_gate_row():
    rows = json.loads(
        (__import__("pathlib").Path(__file__).parent.parent / "BENCH_fleet.json")
        .read_text()
    )["rows"]
    gate = next(r for r in rows if r.get("name") == "router/x1_traced")
    x1 = next(r for r in rows if r.get("n_verifiers") == 1)
    assert gate["overhead_pct"] == 0.0
    assert gate["tokens_per_s"] == x1["tokens_per_s"]
    assert gate["n_spans"] > 0
    # The other committed families rode along: chaos counters + codec sizes.
    assert any("recovery_latency_s" in r for r in rows)
    assert any("host_ns_per_msg" in r for r in rows)


# --------------------------------------------------------------------------- #
# RunStats: summary field contract + metrics export
# --------------------------------------------------------------------------- #

SUMMARY_FIELDS = frozenset({
    "tpt_ms", "ecs_j", "ecs_edge_j", "ecs_total_j", "verification_frequency",
    "mean_draft_length", "acceptance_rate", "rounds", "nav_calls",
    "accepted_tokens", "wall_time_s", "overhead_dp", "overhead_bo",
    "overhead_measure", "verifier_batch_occupancy", "mean_queue_depth",
    "nav_p50_ms", "nav_p99_ms", "tokens_per_nav", "mean_tree_nodes",
    "mean_tree_depth", "kv_resident_mb", "kv_peak_mb",
    "kv_bytes_per_session_mb", "kv_cap_hits", "failovers",
    "fallback_fraction", "lost_draft_tokens", "recovery_latency_s",
})


def test_runstats_summary_field_contract():
    """Downstream consumers (bench CSVs, to_metrics, dashboards) key on
    these names: adding a field is fine ONLY by updating this contract."""
    from repro.core.pipeline import RunStats

    assert set(RunStats().summary()) == SUMMARY_FIELDS


def test_runstats_to_metrics_exports_gauges_and_histograms():
    from repro.core.pipeline import RunStats

    st = RunStats(accepted_tokens=50, rounds=10, nav_calls=10, wall_time=2.0)
    st.nav_latencies.extend([0.01, 0.02, 0.3])
    st.verifier_batches.extend([1, 2, 4])
    reg = MetricRegistry(clock=VirtualClock())
    st.to_metrics(reg)
    assert reg.get("run_accepted_tokens").value() == 50.0
    assert reg.get("run_nav_latency_s").count() == 3
    assert reg.get("run_verifier_batch").sum() == pytest.approx(7.0)
    assert set(SUMMARY_FIELDS) <= {n[len("run_"):] for n in reg.names()}
    assert LATENCY_BUCKETS[0] < 0.01  # the histogram resolves fast NAVs
