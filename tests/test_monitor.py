"""Environment monitor: α/β/γ estimation + δ-rule update triggering."""

import numpy as np
import pytest

from repro.core.monitor import EnvironmentMonitor, linear_fit_alpha_beta


def test_linear_fit_recovers_alpha_beta():
    rng = np.random.default_rng(0)
    a, b = 0.02, 0.005
    sizes = list(rng.integers(1, 9, size=80))
    times = [a + b * s + rng.normal(0, 1e-5) for s in sizes]
    ah, bh = linear_fit_alpha_beta(sizes, times)
    assert ah == pytest.approx(a, rel=0.05)
    assert bh == pytest.approx(b, rel=0.05)


def test_missing_probe_sizes():
    m = EnvironmentMonitor()
    m.observe_batch(3, 0.03)
    m.observe_batch(5, 0.04)
    missing = m.missing_probe_sizes()
    assert 3 not in missing and 5 not in missing and 1 in missing


def test_dp_rerun_triggers_on_big_change():
    m = EnvironmentMonitor(window=10)
    for _ in range(10):
        m.observe_batch(2, 0.02 + 0.005 * 2)
        m.observe_batch(6, 0.02 + 0.005 * 6)
        m.observe_gamma(0.05)
    first = m.should_rerun_dp()
    assert first is not None  # initial commit
    assert m.should_rerun_dp() is None  # stable → no re-run
    # γ shifts by 50% (> δ2=0.2) → re-run.
    for _ in range(10):
        m.observe_gamma(0.075)
    assert m.should_rerun_dp() is not None


def test_bo_rerun_on_tpt_shift():
    m = EnvironmentMonitor(window=5)
    for _ in range(5):
        m.observe_tpt(0.1)
    assert m.should_rerun_bo() is None  # first window = baseline
    for _ in range(5):
        m.observe_tpt(0.2)  # +100% > δ1
    assert m.should_rerun_bo() == pytest.approx(0.2)
    for _ in range(5):
        m.observe_tpt(0.21)  # +5% — below δ1
    assert m.should_rerun_bo() is None
