"""Chunked CE == full-logits CE, values and gradients."""

import jax
import jax.numpy as jnp
import numpy as np

from repro.models.losses import chunked_ce

KEY = jax.random.PRNGKey(11)


def _full_ce(hidden, labels, W):
    logits = (hidden @ W).astype(jnp.float32)
    valid = labels >= 0
    logp = jax.nn.log_softmax(logits, axis=-1)
    nll = -jnp.take_along_axis(logp, jnp.maximum(labels, 0)[..., None], axis=-1)[..., 0]
    return jnp.sum(jnp.where(valid, nll, 0.0)), jnp.sum(valid)


def test_chunked_matches_full():
    B, S, d, V = 2, 40, 16, 50
    ks = jax.random.split(KEY, 3)
    hidden = jax.random.normal(ks[0], (B, S, d))
    W = jax.random.normal(ks[1], (d, V)) * 0.2
    labels = jax.random.randint(ks[2], (B, S), -1, V)

    tot_c, nv_c = chunked_ce(hidden, labels, lambda h: (h @ W).astype(jnp.float32), chunk=16)
    tot_f, nv_f = _full_ce(hidden, labels, W)
    np.testing.assert_allclose(tot_c, tot_f, rtol=1e-5)
    assert int(nv_c) == int(nv_f)


def test_chunked_grads_match():
    B, S, d, V = 2, 32, 8, 30
    ks = jax.random.split(KEY, 3)
    hidden = jax.random.normal(ks[0], (B, S, d))
    W = jax.random.normal(ks[1], (d, V)) * 0.2
    labels = jax.random.randint(ks[2], (B, S), 0, V)

    gc = jax.grad(lambda W: chunked_ce(hidden, labels, lambda h: (h @ W).astype(jnp.float32), chunk=8)[0])(W)
    gf = jax.grad(lambda W: _full_ce(hidden, labels, W)[0])(W)
    np.testing.assert_allclose(np.asarray(gc), np.asarray(gf), atol=1e-4)


def test_ragged_sequence_padding():
    B, S, d, V = 1, 13, 8, 20  # S not divisible by chunk
    ks = jax.random.split(KEY, 3)
    hidden = jax.random.normal(ks[0], (B, S, d))
    W = jax.random.normal(ks[1], (d, V)) * 0.2
    labels = jax.random.randint(ks[2], (B, S), 0, V)
    tot_c, nv_c = chunked_ce(hidden, labels, lambda h: (h @ W).astype(jnp.float32), chunk=8)
    tot_f, nv_f = _full_ce(hidden, labels, W)
    np.testing.assert_allclose(tot_c, tot_f, rtol=1e-5)
    assert int(nv_c) == int(nv_f) == B * S
