"""Bench-diff gating: committed BENCH_*.json files stay reproducible.

The tool (tools/bench_diff.py) is itself part of the contract — exact
comparison for deterministic fields, a ±5% band for timing-like ones —
so its classification logic gets pinned here alongside a live check that
the committed kernel rows regenerate bit-identically.
"""

import json
from pathlib import Path

from tools.bench_diff import diff_rows, is_timing_field, row_key

ROOT = Path(__file__).resolve().parent.parent


def test_timing_field_classification():
    assert is_timing_field("nav_p50_ms")
    assert is_timing_field("modeled_us")
    assert is_timing_field("tokens_per_s")
    assert is_timing_field("speedup")
    assert not is_timing_field("bytes_per_session")
    assert not is_timing_field("launches")
    assert not is_timing_field("failovers")


def test_exact_field_mismatch_is_an_error():
    a = [dict(name="r", bytes_per_session=100, nav_p50_ms=10.0)]
    b = [dict(name="r", bytes_per_session=101, nav_p50_ms=10.0)]
    errs = diff_rows(a, b)
    assert len(errs) == 1 and "bytes_per_session" in errs[0] and "[exact]" in errs[0]


def test_timing_band_allows_small_drift_rejects_large():
    a = [dict(name="r", nav_p50_ms=100.0)]
    assert diff_rows(a, [dict(name="r", nav_p50_ms=104.0)]) == []  # +4% ok
    errs = diff_rows(a, [dict(name="r", nav_p50_ms=106.0)])  # +6% fails
    assert len(errs) == 1 and "nav_p50_ms" in errs[0]


def test_missing_and_extra_rows_reported():
    a = [dict(name="only_committed", x=1)]
    b = [dict(name="only_regen", x=1)]
    errs = diff_rows(a, b)
    assert len(errs) == 2
    assert any("only in committed" in e for e in errs)
    assert any("only in regenerated" in e for e in errs)


def test_row_key_prefers_name_else_non_floats():
    assert row_key(dict(name="a/b", x=1.5)) == "a/b"
    k = row_key(dict(scenario=2, mode="batched", tpt_ms=1.23))
    assert "scenario" in k and "tpt_ms" not in k


def test_round_metrics_strips_float_noise():
    from benchmarks.common import round_metrics

    rows = round_metrics([dict(a=1007.5000000000074, b=[0.1 + 0.2], c=dict(d=3.0000000001))])
    assert rows == [dict(a=1007.5, b=[0.3], c=dict(d=3.0))]


def test_committed_kernel_rows_regenerate_exactly():
    """The deterministic kernel bench reproduces BENCH_kernels.json rows."""
    from benchmarks.common import round_metrics
    from benchmarks.kernel_bench import _kv_rows, _shard_rows, _verify_rows

    committed = json.loads((ROOT / "BENCH_kernels.json").read_text())["rows"]
    regen = round_metrics(_kv_rows()[0] + _verify_rows()[0] + _shard_rows()[0])
    assert diff_rows(committed, regen) == []


def test_committed_kernel_rows_pin_the_claims():
    """The headline numbers gate here: >=1.5x int8 shrink, 1-launch fused."""
    rows = {r.get("name"): r for r in json.loads((ROOT / "BENCH_kernels.json").read_text())["rows"]}
    fp32 = rows["kernels/kv/fp32"]["bytes_per_session"]
    int8 = rows["kernels/kv/int8"]["bytes_per_session"]
    assert fp32 >= 1.5 * int8
    assert rows["kernels/verify/fused"]["launches"] == 1
    assert rows["kernels/verify/composed"]["launches"] == 2
    assert rows["kernels/verify/fused"]["speedup_vs_composed"] >= 1.0


def test_committed_shard_rows_pin_the_scaling_claims():
    """shard/spec_verify rows: present at 1/2/4 shards, still ONE launch,
    resident bytes/shard halve with the mesh, and modeled throughput scales."""
    rows = {r.get("name"): r for r in json.loads((ROOT / "BENCH_kernels.json").read_text())["rows"]}
    shard_rows = [rows[f"kernels/shard/spec_verify/{n}"] for n in (1, 2, 4)]
    for n, r in zip((1, 2, 4), shard_rows):
        assert r["shards"] == n
        assert r["launches"] == 1  # sharding never splits the launch
        assert set(r) >= {
            "hbm_bytes_per_shard", "ici_bytes_per_shard",
            "resident_bytes_per_shard", "modeled_us", "tokens_per_s",
            "speedup_vs_1shard",
        }
    one, two, four = shard_rows
    assert two["resident_bytes_per_shard"] * 2 == one["resident_bytes_per_shard"]
    assert four["resident_bytes_per_shard"] * 4 == one["resident_bytes_per_shard"]
    assert one["tokens_per_s"] < two["tokens_per_s"] < four["tokens_per_s"]
    assert one["speedup_vs_1shard"] == 1.0 and four["speedup_vs_1shard"] > 2.0
    # The shard=1 model must agree with the unsharded fused row's traffic.
    assert one["hbm_bytes_per_shard"] == rows["kernels/verify/fused"]["hbm_bytes"]
    # ICI all-gather traffic is the price of the one-launch contract.
    assert one["ici_bytes_per_shard"] == 0 < two["ici_bytes_per_shard"]


def test_shard_speedup_field_is_timing_banded():
    assert is_timing_field("speedup_vs_1shard")
    assert not is_timing_field("resident_bytes_per_shard")
    assert not is_timing_field("ici_bytes_per_shard")
    assert not is_timing_field("shards")
