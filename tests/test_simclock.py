"""VirtualClock unit tests: event ordering, condition semantics, determinism,
join, actor error propagation, and deadlock diagnostics."""

import threading

import pytest

from repro.runtime.simclock import SYSTEM_CLOCK, SystemClock, VirtualClock


def test_monotonic_starts_at_zero_and_sleep_advances_exactly():
    clock = VirtualClock()
    seen = {}

    def body():
        seen["t0"] = clock.monotonic()
        clock.sleep(1.5)
        seen["t1"] = clock.monotonic()
        clock.sleep(0.25)
        seen["t2"] = clock.monotonic()

    clock.run(body)
    assert seen == {"t0": 0.0, "t1": 1.5, "t2": 1.75}


def test_sleepers_wake_in_deadline_order_with_id_tiebreak():
    clock = VirtualClock()
    order = []

    def sleeper(name, dt):
        def body():
            clock.sleep(dt)
            order.append((name, clock.monotonic()))
        return body

    def main():
        hs = [
            clock.spawn(sleeper("late", 2.0), name="late"),
            clock.spawn(sleeper("early", 1.0), name="early"),
            clock.spawn(sleeper("tie_a", 1.0), name="tie_a"),
        ]
        for h in hs:
            h.join()

    clock.run(main)
    # 'early' spawned before 'tie_a' -> same deadline, registration order wins.
    assert order == [("early", 1.0), ("tie_a", 1.0), ("late", 2.0)]


def test_time_only_advances_when_all_actors_blocked():
    """A busy actor yielding via 0-sleeps never sees time jump past a peer."""
    clock = VirtualClock()
    samples = []

    def busy():
        for _ in range(50):
            samples.append(clock.monotonic())
            clock.sleep(0.0)

    def sleeper():
        clock.sleep(10.0)

    def main():
        h1 = clock.spawn(busy)
        h2 = clock.spawn(sleeper)
        h1.join()
        assert clock.monotonic() == 0.0  # busy work costs no virtual time
        h2.join()
        assert clock.monotonic() == 10.0

    clock.run(main)
    assert samples == [0.0] * 50


def test_condition_notify_wakes_before_timeout():
    clock = VirtualClock()
    cond = clock.condition()
    out = {}

    def waiter():
        with cond:
            notified = cond.wait(timeout=100.0)
        out["notified"] = notified
        out["t"] = clock.monotonic()

    def main():
        h = clock.spawn(waiter)
        clock.sleep(2.0)
        with cond:
            cond.notify_all()
        h.join()

    clock.run(main)
    assert out == {"notified": True, "t": 2.0}


def test_condition_timeout_fires_at_exact_virtual_deadline():
    clock = VirtualClock()
    cond = clock.condition()
    out = {}

    def waiter():
        with cond:
            out["notified"] = cond.wait(timeout=3.25)
        out["t"] = clock.monotonic()

    def main():
        clock.spawn(waiter).join()

    clock.run(main)
    assert out == {"notified": False, "t": 3.25}


def test_condition_notify_one_wakes_in_wait_order():
    clock = VirtualClock()
    cond = clock.condition()
    woken = []

    def waiter(name):
        def body():
            with cond:
                cond.wait(timeout=50.0)
            woken.append((name, clock.monotonic()))
        return body

    def main():
        ha = clock.spawn(waiter("a"))
        hb = clock.spawn(waiter("b"))
        clock.sleep(1.0)
        with cond:
            cond.notify(1)
        clock.sleep(1.0)
        with cond:
            cond.notify(1)
        ha.join()
        hb.join()

    clock.run(main)
    assert woken == [("a", 1.0), ("b", 2.0)]


def test_condition_over_shared_external_lock():
    """Condition built over an existing Lock keeps critical sections exclusive
    (the CloudVerifier pattern: ``with self._lock`` and ``self._work`` share)."""
    clock = VirtualClock()
    lock = threading.Lock()
    work = clock.condition(lock)
    items = []
    done = []

    def producer():
        for i in range(3):
            clock.sleep(0.5)
            with lock:
                items.append(i)
            with work:
                work.notify_all()

    def consumer():
        got = []
        while len(got) < 3:
            with work:
                while not items:
                    work.wait(timeout=10.0)
                got.append(items.pop(0))
        done.append(got)

    def main():
        hp = clock.spawn(producer)
        hc = clock.spawn(consumer)
        hp.join()
        hc.join()

    clock.run(main)
    assert done == [[0, 1, 2]]
    assert clock.monotonic() == 1.5


def test_join_timeout_and_result():
    clock = VirtualClock()

    def slow():
        clock.sleep(5.0)
        return 42

    def main():
        h = clock.spawn(slow)
        h.join(timeout=1.0)
        assert not h.done and clock.monotonic() == 1.0
        h.join()
        assert h.done and clock.monotonic() == 5.0
        return h.result()

    assert clock.run(main) == 42


def test_join_timeout_tied_with_target_finish_no_spurious_resume():
    """When a join timeout and the target's finish land on the same virtual
    instant, the joiner must be resumed exactly once — a double-ready would
    make its NEXT blocking call return instantly at the wrong time."""
    clock = VirtualClock()
    # Spawn the target BEFORE run() so it has the lower actor id and is
    # readied (and finishes) ahead of the timed-out joiner at the tie.
    target = clock.spawn(lambda: clock.sleep(5.0), name="target")

    def main():
        target.join(timeout=5.0)  # deadline ties the target's wake exactly
        t_joined = clock.monotonic()
        clock.sleep(3.0)  # a spurious resume would cut this sleep short
        return t_joined, clock.monotonic()

    t_joined, t_end = clock.run(main)
    assert t_joined == 5.0
    assert t_end == 8.0


def test_run_is_deterministic_across_repeats():
    """Same program -> identical event trace, timestamps, and final time."""

    def program():
        clock = VirtualClock()
        trace = []

        def actor(name, period, n):
            def body():
                for i in range(n):
                    clock.sleep(period)
                    trace.append((name, i, clock.monotonic()))
            return body

        def main():
            hs = [
                clock.spawn(actor("a", 0.3, 7)),
                clock.spawn(actor("b", 0.7, 4)),
                clock.spawn(actor("c", 0.21, 9)),
            ]
            for h in hs:
                h.join()

        clock.run(main)
        return trace, clock.monotonic()

    assert program() == program()


def test_main_actor_exception_propagates():
    clock = VirtualClock()

    def main():
        clock.sleep(1.0)
        raise ValueError("boom")

    with pytest.raises(ValueError, match="boom"):
        clock.run(main)


def test_background_actor_exception_surfaces_at_end_of_run():
    clock = VirtualClock()

    def bad():
        clock.sleep(0.5)
        raise KeyError("rx loop crashed")

    def main():
        clock.spawn(bad, name="rx")
        clock.sleep(1.0)

    with pytest.raises(RuntimeError, match="background actor 'rx'"):
        clock.run(main)


def test_deadlock_raises_with_actor_states():
    clock = VirtualClock()
    cond = clock.condition()

    def stuck():
        with cond:
            cond.wait()  # no timeout, nobody will notify

    def main():
        clock.spawn(stuck, name="stuck").join()

    with pytest.raises(RuntimeError, match="deadlock"):
        clock.run(main)


def test_blocking_call_outside_actor_raises():
    clock = VirtualClock()
    with pytest.raises(RuntimeError, match="outside a clock actor"):
        clock.sleep(1.0)


def test_nonblocking_calls_work_outside_run():
    """Setup code may read time / notify before the event loop starts."""
    clock = VirtualClock()
    assert clock.monotonic() == 0.0
    cond = clock.condition()
    with cond:
        cond.notify_all()  # no waiters: a no-op, not an error


def test_system_clock_surface():
    """SystemClock provides the same surface on wall time."""
    clock = SystemClock()
    t0 = clock.monotonic()
    clock.sleep(0.01)
    assert clock.monotonic() >= t0 + 0.009
    cond = clock.condition()
    with cond:
        cond.notify_all()
    out = []
    h = clock.spawn(lambda: out.append(1))
    h.join(timeout=5.0)
    assert out == [1]
    assert clock.run(lambda: 7) == 7
    assert SYSTEM_CLOCK.virtual is False and VirtualClock().virtual is True
