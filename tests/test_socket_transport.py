"""Socket transport: loopback serving, version rejection, process separation.

These tests run the SAME ``CloudVerifier``/``EdgeClient`` code the simulated
runtime uses, but over real localhost TCP sockets carrying encoded protocol
frames — and, for the smoke test, as two genuinely separate OS processes via
``launch/serve.py`` (the paper's client/server testbed shape).
"""

import subprocess
import sys
import time
from pathlib import Path

import pytest

from repro.runtime import (
    PROTOCOL_VERSION,
    CloudVerifier,
    Detach,
    EdgeClient,
    EdgeConfig,
    NavRequest,
    OracleBackend,
    OracleDraft,
    OracleStream,
    ProtocolError,
    SocketListener,
    VirtualClock,
    connect_transport,
)

ROOT = Path(__file__).parent.parent


@pytest.fixture()
def server():
    """A live verifier behind an ephemeral-port listener; closed on teardown."""
    backend = OracleBackend(seed=3, verify_time=0.001, verify_time_per_token=0.0)
    verifier = CloudVerifier(backend, batch_window=0.001)
    listener = SocketListener(
        lambda sid, t: verifier.attach(sid, t, t), host="127.0.0.1", port=0
    )
    verifier.start()
    yield verifier, listener
    listener.close()
    verifier.stop()


def test_loopback_socket_serving_matches_oracle(server):
    """EdgeClient over a real TCP loopback commits the oracle stream."""
    verifier, listener = server
    transport = connect_transport(listener.host, listener.port, session=0)
    client = EdgeClient(
        transport.session, transport, transport,
        EdgeConfig(gamma=0.002, window=8, nav_timeout=5.0),
        draft=OracleDraft(seed=3),
    )
    stats = client.run(32)
    client.seq += 1
    transport.send(Detach(session=transport.session, seq=client.seq))
    transport.close()
    assert stats["failovers"] == 0
    assert client.tokens == OracleStream(3).prefix(len(client.tokens))
    assert verifier.stats["nav_calls"] == stats["rounds"]


def test_attach_rejects_version_mismatch(server):
    """A client speaking the wrong protocol version is refused at attach."""
    _, listener = server
    with pytest.raises(ProtocolError, match="version mismatch"):
        connect_transport(
            listener.host, listener.port, session=0, version=PROTOCOL_VERSION + 1
        )
    assert listener.stats["rejected"] == 1


def test_attach_remaps_colliding_session_ids(server):
    """Two clients proposing the same id get distinct server-side sessions."""
    _, listener = server
    a = connect_transport(listener.host, listener.port, session=5)
    b = connect_transport(listener.host, listener.port, session=5)
    try:
        assert a.session == 5
        assert b.session == 6  # remapped to the next free id
    finally:
        a.close()
        b.close()


def test_deadline_rebases_across_the_socket_boundary():
    """NavRequest deadlines arrive as absolute times on the RECEIVER's clock.

    The wire carries a relative budget; whatever clock-origin skew exists
    between peers, the reconstructed deadline lands ~budget seconds into
    the receiver's future.
    """
    accepted = {}
    listener = SocketListener(lambda sid, t: accepted.update({sid: t}), port=0)
    transport = connect_transport(listener.host, listener.port, session=9)
    try:
        for _ in range(100):  # the accept loop registers asynchronously
            if 9 in accepted:
                break
            time.sleep(0.02)
        srv_side = accepted[9]
        budget = 3.0
        t_send = transport.clock.monotonic()
        transport.send(
            NavRequest(session=9, seq=1, round=1, n_tokens=1, deadline=t_send + budget)
        )
        msg = srv_side.recv(timeout=5.0)
        assert isinstance(msg, NavRequest)
        remaining = msg.deadline - srv_side.clock.monotonic()
        assert 0.0 < remaining <= budget + 0.01
        assert remaining > budget - 1.0  # lost at most the transit latency
    finally:
        transport.close()
        listener.close()


def test_corrupt_frame_closes_the_transport():
    """A post-handshake frame that fails decode() must tear the link down
    (closed=True) instead of silently killing the rx pump and wedging."""
    import socket as socklib

    from repro.runtime import Hello, encode

    accepted = {}
    listener = SocketListener(lambda sid, t: accepted.update({sid: t}), port=0)
    raw = socklib.create_connection((listener.host, listener.port))
    try:
        raw.sendall(encode(Hello(session=1)))
        header = raw.recv(4)  # the Attach reply (length prefix + body)
        raw.recv(int.from_bytes(header, "little"))
        # A well-framed body with an unknown type id: decode() raises.
        raw.sendall((1).to_bytes(4, "little") + b"\xff")
        for _ in range(200):
            if accepted.get(1) is not None and accepted[1].closed:
                break
            time.sleep(0.02)
        assert accepted[1].closed
        assert accepted[1].recv(timeout=0.1) is None  # reads see the dead link
    finally:
        raw.close()
        listener.close()


def test_rx_loop_exits_when_socket_peer_disconnects(server):
    """A disconnected session's receive loop must END (no hot-polling a
    closed transport until shutdown)."""
    verifier, listener = server
    transport = connect_transport(listener.host, listener.port, session=2)
    transport.close()
    # The accept loop registers the session asynchronously — wait for it.
    name = f"rx-{transport.session}"
    for _ in range(200):
        rx = next((t for t in verifier._threads if t.name == name), None)
        if rx is not None:
            break
        time.sleep(0.02)
    assert rx is not None
    rx.join(timeout=5.0)
    assert not rx.is_alive()


def test_socket_transport_refuses_virtual_clock():
    """Real sockets cannot block on virtual time — constructor must reject."""
    with pytest.raises(ValueError, match="VirtualClock"):
        SocketListener(lambda s, t: None, port=0, clock=VirtualClock())


def test_two_process_socket_serving_matches_oracle():
    """launch/serve.py as two OS processes: the streamed tokens == oracle.

    This is the acceptance shape of the socket backend — server and client
    share nothing but the TCP connection and the seed.
    """
    serve = ROOT / "launch" / "serve.py"
    srv = subprocess.Popen(
        [sys.executable, str(serve), "--listen", "127.0.0.1:0", "--sessions", "1",
         "--seed", "11"],
        stdout=subprocess.PIPE, stderr=subprocess.STDOUT, text=True,
    )
    try:
        # The server announces its ephemeral port on the first line.
        line = srv.stdout.readline()
        assert line.startswith("LISTENING "), line
        port = int(line.strip().rsplit(":", 1)[1])
        out = subprocess.run(
            [sys.executable, str(serve), "--connect", f"127.0.0.1:{port}",
             "--tokens", "48", "--seed", "11", "--check-oracle"],
            capture_output=True, text=True, timeout=60,
        )
        assert out.returncode == 0, out.stderr
        stream = [int(x) for x in out.stdout.split()]
        assert stream == OracleStream(11).prefix(48)
        assert srv.wait(timeout=30) == 0
    finally:
        if srv.poll() is None:
            srv.kill()
            srv.wait()
