"""Fused verify == (paged attention ∘ LM head ∘ spec_verify), bit-exact.

The acceptance bar for the one-launch kernel: for every geometry, the fused
launch's integer outputs (n_accepted, correction) must be BIT-EXACT vs the
unfused composition — ``paged_decode_attention`` per query position, the
blocked ``fused_target_logits`` projection, then ``spec_verify`` — with the
same impl on both sides (interpret vs interpret, ref vs ref), and the
float log-probs bitwise equal too (identical values through identical
arithmetic).  The hypothesis sweep covers random ragged batches, tables,
GQA, non-pow2 lengths, and the all-accepted / all-rejected / B=1 edge
cases; the int8 suite pins fused-q8 == composed-q8 plus a bounded error vs
the fp32 pipeline.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings

from strategies import (
    KEY,
    assert_triples_match as _assert_fused_matches,
    composed_verify as _composed,
    make_rect_case as _make_case,
    rect_geometries,
)

from repro.kernels.decode_attention import paged_decode_attention
from repro.kernels.spec_verify import (
    spec_verify_fused,
    spec_verify_fused_batched,
)
from repro.models.paged_kv import PagedKVPool


@pytest.mark.parametrize("impl", ["ref", "interpret"])
@pytest.mark.parametrize(
    "B,K,H,Hkv,hd,bs,G,P,V",
    [
        (2, 3, 2, 2, 16, 8, 4, 16, 512),
        (1, 1, 2, 1, 16, 8, 2, 8, 256),  # B=1, GQA, single draft token
        (3, 4, 4, 2, 8, 4, 8, 32, 384),  # non-pow2 vocab -> padded lanes
    ],
)
def test_fused_bitexact_vs_composition(impl, B, K, H, Hkv, hd, bs, G, P, V):
    q, kp, vp, w, tables, lengths, tokens, nd = _make_case(B, K, H, Hkv, hd, bs, G, P, V)
    fused = spec_verify_fused(
        q, kp, vp, w, tables, lengths, tokens, nd, impl=impl, block_v=256
    )
    composed = _composed(
        q, kp, vp, w, tables, lengths, tokens, nd, impl=impl, block_v=256
    )
    _assert_fused_matches(fused, composed)


@pytest.mark.parametrize("forced", ["accept_all", "reject_all"])
def test_fused_forced_accept_reject_edges(forced):
    """All-accepted and all-rejected drafts round-trip through the fusion."""
    B, K, H, hd, bs, G, P, V = 2, 3, 2, 16, 8, 4, 16, 512
    q, kp, vp, w, tables, lengths, tokens, nd = _make_case(
        B, K, H, H, hd, bs, G, P, V, seed=5, sharp=True
    )
    # Compute the target's actual greedy chain via the composition, then
    # either copy it (all match) or corrupt every position (none match).
    na, corr, _ = _composed(q, kp, vp, w, tables, lengths, tokens, nd, impl="ref", block_v=256)
    o = paged_decode_attention(
        q.reshape(B * (K + 1), H, hd), kp, vp,
        jnp.repeat(tables, K + 1, axis=0), lengths.reshape(-1), impl="ref",
    ).reshape(B, K + 1, H * hd).astype(jnp.float32)
    greedy = np.asarray(jnp.argmax(jnp.dot(o, w.astype(jnp.float32)), axis=-1))
    if forced == "accept_all":
        tokens = jnp.asarray(greedy[:, :K], jnp.int32)
    else:
        tokens = jnp.asarray((greedy[:, :K] + 1) % V, jnp.int32)
    nd = jnp.full((B,), K, jnp.int32)
    fused = spec_verify_fused(q, kp, vp, w, tables, lengths, tokens, nd, impl="interpret", block_v=256)
    composed = _composed(q, kp, vp, w, tables, lengths, tokens, nd, impl="interpret", block_v=256)
    _assert_fused_matches(fused, composed)
    want = K if forced == "accept_all" else 0
    np.testing.assert_array_equal(np.asarray(fused[0]).ravel(), want)


@settings(max_examples=10, deadline=None)
@given(geom=rect_geometries())
def test_property_fused_bitexact(geom):
    """Random geometry sweep: fused == composition bitwise, both impls."""
    B, K, Hkv, gqa, bs, G, seed = (
        geom["B"], geom["K"], geom["Hkv"], geom["gqa"], geom["bs"], geom["G"], geom["seed"]
    )
    H = Hkv * gqa
    hd = 8
    P = max(2 * G, B * G)
    V = 256
    q, kp, vp, w, tables, lengths, tokens, nd = _make_case(
        B, K, H, Hkv, hd, bs, G, P, V, seed=seed
    )
    for impl in ("ref", "interpret"):
        fused = spec_verify_fused(q, kp, vp, w, tables, lengths, tokens, nd, impl=impl, block_v=128)
        composed = _composed(q, kp, vp, w, tables, lengths, tokens, nd, impl=impl, block_v=128)
        _assert_fused_matches(fused, composed)


def test_fused_batched_ragged_from_pool():
    """Serving entry: ragged sessions through a real pool, sentinel padding,
    matching per-session composition results."""
    rng = np.random.default_rng(9)
    H, hd, bs, V = 2, 16, 4, 512
    pool = PagedKVPool(num_blocks=16, block_size=bs, n_layers=1, n_kv_heads=H, head_dim=hd)
    ks = [3, 1, 4]
    q_seq, tok_seq, tables_seq, base = [], [], [], []
    keys = jax.random.split(KEY, 16)
    for s, k in enumerate(ks):
        pool.create(s)
        T = int(rng.integers(k + 2, 12))
        kv = jax.random.normal(keys[2 * s], (1, T, H, hd))
        pool.write(s, kv, kv + 0.5)
        q_seq.append(jax.random.normal(keys[2 * s + 1], (k + 1, H, hd)))
        tok_seq.append(rng.integers(0, V, size=k).tolist())
        tables_seq.append(list(pool.table(s)))
        base.append(T - k)
    w = jax.random.normal(keys[-1], (H * hd, V))
    out = spec_verify_fused_batched(
        q_seq, tok_seq, tables_seq, base,
        pool.k_pages[0], pool.v_pages[0], w,
        impl="interpret", block_v=256, pad_page_id=pool.sentinel_page,
    )
    # Oracle: per-session rectangular fused entry (B=1, no padding).
    for s, k in enumerate(ks):
        lengths = jnp.asarray([[base[s] + i for i in range(k + 1)]], jnp.int32)
        tab = jnp.asarray([tables_seq[s]], jnp.int32)
        na, corr, logp = spec_verify_fused(
            q_seq[s][None], pool.k_pages[0], pool.v_pages[0], w, tab, lengths,
            jnp.asarray([tok_seq[s]], jnp.int32), jnp.asarray([k], jnp.int32),
            impl="interpret", block_v=256,
        )
        assert out[s][0] == int(np.asarray(na)[0, 0])
        assert out[s][1] == int(np.asarray(corr)[0, 0])
        np.testing.assert_allclose(out[s][2], np.asarray(logp)[0, :k], atol=1e-5)


def test_fused_padded_lanes_only_touch_sentinel():
    """A bucketed fused launch must never DMA a page the padded lane does
    not own: poisoning every page NOT in the real sessions' tables (plus
    the sentinel) with NaN leaves the results unchanged."""
    rng = np.random.default_rng(4)
    H, hd, bs, V = 2, 8, 4, 256
    pool = PagedKVPool(num_blocks=8, block_size=bs, n_layers=1, n_kv_heads=H, head_dim=hd)
    keys = jax.random.split(KEY, 4)
    pool.create(0)
    kv = jax.random.normal(keys[0], (1, 6, H, hd))
    pool.write(0, kv, kv)
    # A second, "foreign" session whose pages must never be read.
    pool.create(1)
    foreign = jax.random.normal(keys[1], (1, 8, H, hd))
    pool.write(1, foreign, foreign)
    q_seq = [jax.random.normal(keys[2], (3, H, hd))]
    tok_seq = [rng.integers(0, V, size=2).tolist()]
    tables_seq = [list(pool.table(0))]
    w = jax.random.normal(keys[3], (H * hd, V))
    clean = spec_verify_fused_batched(
        q_seq, tok_seq, tables_seq, [4], pool.k_pages[0], pool.v_pages[0], w,
        impl="interpret", block_v=256, pad_page_id=pool.sentinel_page,
    )
    owned = set(tables_seq[0]) | {pool.sentinel_page}
    kp = np.array(pool.k_pages[0])
    vp = np.array(pool.v_pages[0])
    for p in range(kp.shape[0]):
        if p not in owned:
            kp[p] = np.nan
            vp[p] = np.nan
    poisoned = spec_verify_fused_batched(
        q_seq, tok_seq, tables_seq, [4], jnp.asarray(kp), jnp.asarray(vp), w,
        impl="interpret", block_v=256, pad_page_id=pool.sentinel_page,
    )
    assert clean[0][0] == poisoned[0][0] and clean[0][1] == poisoned[0][1]
    np.testing.assert_array_equal(clean[0][2], poisoned[0][2])
    assert np.all(np.isfinite(poisoned[0][2]))


def test_fused_q8_bitexact_vs_q8_composition_and_bounded_vs_fp32():
    """Int8 fused == int8 composition bitwise; both near the fp32 result."""
    B, K, H, hd, bs, G, P, V = 2, 3, 2, 16, 8, 4, 16, 512
    q, kp, vp, w, tables, lengths, tokens, nd = _make_case(
        B, K, H, H, hd, bs, G, P, V, seed=11, sharp=True
    )
    kq, ksc, kz = PagedKVPool.quantize_kv(kp)
    vq, vsc, vz = PagedKVPool.quantize_kv(vp)
    quant = (ksc, kz, vsc, vz)
    fused = spec_verify_fused(
        q, kq, vq, w, tables, lengths, tokens, nd,
        impl="interpret", block_v=256, quant=quant,
    )
    composed = _composed(
        q, kq, vq, w, tables, lengths, tokens, nd,
        impl="interpret", block_v=256, quant=quant,
    )
    _assert_fused_matches(fused, composed)
    # Sharp LM head => quantization noise cannot flip the greedy argmax, so
    # the integer outputs match the fp32 pipeline; logp drift stays small.
    fp32 = _composed(q, kp, vp, w, tables, lengths, tokens, nd, impl="interpret", block_v=256)
    np.testing.assert_array_equal(np.asarray(fused[0]), np.asarray(fp32[0]))
    np.testing.assert_array_equal(np.asarray(fused[1]), np.asarray(fp32[1]))
