import os
import sys
import types

# Tests see the default single CPU device (the dry-run sets its own flag in a
# subprocess); keep allocator behaviour deterministic.
os.environ.setdefault("JAX_PLATFORMS", "cpu")

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

# --------------------------------------------------------------------------- #
# Optional-hypothesis shim: property tests must SKIP (with a clear reason),
# never fail collection, in environments without hypothesis installed.
# Install the real thing with `pip install -e .[test]` (see pyproject.toml).
# --------------------------------------------------------------------------- #
try:
    import hypothesis  # noqa: F401
except ModuleNotFoundError:
    import pytest

    _SKIP_REASON = "hypothesis not installed — `pip install -e .[test]` enables property tests"

    class _AnyStrategy:
        """Stand-in for strategy objects: absorbs any call/attribute chain."""

        def __call__(self, *args, **kwargs):
            return self

        def __getattr__(self, name):
            return self

        def __or__(self, other):  # `st.none() | ints` composition
            return self

        __ror__ = __or__

        def __repr__(self):  # pragma: no cover - debugging nicety
            return "<hypothesis stub strategy>"

    def _given(*_args, **_kwargs):
        def decorate(fn):
            # Zero-arg placeholder: the strategy kwargs must not be mistaken
            # for pytest fixtures, and the skip must fire before setup.
            def _skipped_property_test():  # pragma: no cover - always skipped
                pass

            _skipped_property_test.__name__ = getattr(fn, "__name__", "property_test")
            _skipped_property_test.__doc__ = fn.__doc__
            return pytest.mark.skip(reason=_SKIP_REASON)(_skipped_property_test)

        return decorate

    def _settings(*_args, **_kwargs):
        return lambda fn: fn

    _stub = types.ModuleType("hypothesis")
    _stub.__doc__ = "Stub installed by tests/conftest.py; property tests are skipped."
    _stub.given = _given
    _stub.settings = _settings
    _stub.assume = lambda *a, **k: True
    _stub.example = _settings
    _stub.HealthCheck = _AnyStrategy()

    _strategies = types.ModuleType("hypothesis.strategies")
    _strategies.__getattr__ = lambda name: _AnyStrategy()
    _stub.strategies = _strategies

    sys.modules["hypothesis"] = _stub
    sys.modules["hypothesis.strategies"] = _strategies
