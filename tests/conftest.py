import os
import sys

# Tests see the default single CPU device (the dry-run sets its own flag in a
# subprocess); keep allocator behaviour deterministic.
os.environ.setdefault("JAX_PLATFORMS", "cpu")

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))
