import os
import sys
import types

# Tests run over a 4-way CPU host mesh: the sharded-verifier differential
# suites (test_sharded_verify.py, test_partition.py) need real multi-device
# shardings, and everything else is device-count agnostic (single-device
# computations land on device 0).  Respect an explicit user override; the
# dry-run still sets its own flag in a subprocess.
os.environ.setdefault("JAX_PLATFORMS", "cpu")
if "xla_force_host_platform_device_count" not in os.environ.get("XLA_FLAGS", ""):
    os.environ["XLA_FLAGS"] = (
        os.environ.get("XLA_FLAGS", "") + " --xla_force_host_platform_device_count=4"
    ).strip()

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

# --------------------------------------------------------------------------- #
# Optional-hypothesis shim: property tests must SKIP (with a clear reason),
# never fail collection, in environments without hypothesis installed.
# Install the real thing with `pip install -e .[test]` (see pyproject.toml).
# --------------------------------------------------------------------------- #
try:
    import hypothesis  # noqa: F401
except ModuleNotFoundError:
    import pytest

    _SKIP_REASON = "hypothesis not installed — `pip install -e .[test]` enables property tests"

    class _AnyStrategy:
        """Stand-in for strategy objects: absorbs any call/attribute chain."""

        def __call__(self, *args, **kwargs):
            return self

        def __getattr__(self, name):
            return self

        def __or__(self, other):  # `st.none() | ints` composition
            return self

        __ror__ = __or__

        def __repr__(self):  # pragma: no cover - debugging nicety
            return "<hypothesis stub strategy>"

    def _given(*_args, **_kwargs):
        def decorate(fn):
            # Zero-arg placeholder: the strategy kwargs must not be mistaken
            # for pytest fixtures, and the skip must fire before setup.
            def _skipped_property_test():  # pragma: no cover - always skipped
                pass

            _skipped_property_test.__name__ = getattr(fn, "__name__", "property_test")
            _skipped_property_test.__doc__ = fn.__doc__
            return pytest.mark.skip(reason=_SKIP_REASON)(_skipped_property_test)

        return decorate

    def _settings(*_args, **_kwargs):
        return lambda fn: fn

    _stub = types.ModuleType("hypothesis")
    _stub.__doc__ = "Stub installed by tests/conftest.py; property tests are skipped."
    _stub.given = _given
    _stub.settings = _settings
    _stub.assume = lambda *a, **k: True
    _stub.example = _settings
    _stub.HealthCheck = _AnyStrategy()

    _strategies = types.ModuleType("hypothesis.strategies")
    _strategies.__getattr__ = lambda name: _AnyStrategy()
    _stub.strategies = _strategies

    sys.modules["hypothesis"] = _stub
    sys.modules["hypothesis.strategies"] = _strategies
