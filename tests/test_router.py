"""Multi-verifier control plane: placement/scaling units + router integration.

Three layers, mirroring the control plane's structure:

* **placement policy** (pure): least-loaded selection, KV-budget tiebreaks,
  admission refusal, drain exclusion — plus a hypothesis property that
  placement NEVER admits a session onto a verifier without the required
  free-block budget, under random arrival/departure sequences;
* **scaling policy** (pure): threshold triggers, cooldown gating, bounds;
* **router integration** on the virtual clock: spreading, live migration
  mid-NAV, crash failover, drain, restart/adopt, client re-attach, and
  autoscaling — every run asserting the committed stream stays oracle-exact
  (the conformance suite extends these to the full fault matrix).
"""

import subprocess
import sys
from pathlib import Path

import pytest
from hypothesis import given, settings
from hypothesis import strategies as st

from repro.models.paged_kv import PagedKVPool
from repro.runtime import (
    AutoScaler,
    Channel,
    ChannelConfig,
    CloudVerifier,
    EdgeClient,
    EdgeConfig,
    FleetFullError,
    LeastLoadedPlacement,
    LocalVerifier,
    OracleBackend,
    OracleDraft,
    OracleStream,
    Router,
    ScalingConfig,
    VerifierDraining,
    VerifierLoad,
    VirtualClock,
)

ROOT = Path(__file__).parent.parent


# --------------------------------------------------------------------------- #
# Placement policy (pure)
# --------------------------------------------------------------------------- #


def test_least_loaded_prefers_fewest_sessions():
    policy = LeastLoadedPlacement()
    loads = [
        VerifierLoad(verifier=0, sessions=3),
        VerifierLoad(verifier=1, sessions=1),
        VerifierLoad(verifier=2, sessions=2),
    ]
    assert policy.place(loads) == 1


def test_queue_depth_breaks_session_ties():
    policy = LeastLoadedPlacement()
    loads = [
        VerifierLoad(verifier=0, sessions=2, queue_depth=5.0),
        VerifierLoad(verifier=1, sessions=2, queue_depth=1.0),
    ]
    assert policy.place(loads) == 1


def test_kv_free_blocks_break_remaining_ties():
    policy = LeastLoadedPlacement()
    loads = [
        VerifierLoad(verifier=0, sessions=2, free_blocks=4, capacity_blocks=32),
        VerifierLoad(verifier=1, sessions=2, free_blocks=20, capacity_blocks=32),
    ]
    assert policy.place(loads, need_blocks=2) == 1


def test_admission_refused_without_kv_budget():
    policy = LeastLoadedPlacement()
    loads = [
        VerifierLoad(verifier=0, sessions=0, free_blocks=1, capacity_blocks=8),
        VerifierLoad(verifier=1, sessions=0, free_blocks=0, capacity_blocks=8),
    ]
    assert policy.place(loads, need_blocks=2) is None
    assert policy.place(loads, need_blocks=1) == 0


def test_draining_and_dead_verifiers_never_admit():
    policy = LeastLoadedPlacement()
    loads = [
        VerifierLoad(verifier=0, sessions=0, draining=True),
        VerifierLoad(verifier=1, sessions=9),
        VerifierLoad(verifier=2, sessions=0, alive=False),
    ]
    assert policy.place(loads) == 1  # busiest, but the only admissible one
    assert policy.place([loads[0], loads[2]]) is None


def test_unbounded_verifiers_ignore_block_budget():
    policy = LeastLoadedPlacement()
    loads = [VerifierLoad(verifier=0, sessions=5, free_blocks=None)]
    assert policy.place(loads, need_blocks=10_000) == 0


@settings(deadline=None, max_examples=80)
@given(data=st.data())
def test_placement_never_exceeds_free_block_budget(data):
    """Property: under random arrivals/departures, a placed session always
    lands on a verifier whose free-block budget covers it, and no verifier's
    modelled free count ever goes negative."""
    policy = LeastLoadedPlacement()
    n_verifiers = data.draw(st.integers(1, 5), label="n_verifiers")
    capacity = data.draw(st.integers(1, 24), label="capacity")
    need = data.draw(st.integers(1, 6), label="need_blocks")
    free = {v: capacity for v in range(n_verifiers)}
    sessions = {v: 0 for v in range(n_verifiers)}
    placed = []  # list of verifier ids, one per live session
    steps = data.draw(
        st.lists(st.sampled_from(["arrive", "depart"]), max_size=40),
        label="steps",
    )
    for step in steps:
        if step == "arrive":
            loads = [
                VerifierLoad(
                    verifier=v,
                    sessions=sessions[v],
                    free_blocks=free[v],
                    capacity_blocks=capacity,
                )
                for v in range(n_verifiers)
            ]
            vid = policy.place(loads, need_blocks=need)
            if vid is None:
                # Refusal must mean NO verifier had the budget.
                assert all(free[v] < need for v in range(n_verifiers))
                continue
            assert free[vid] >= need  # the budget invariant
            free[vid] -= need
            sessions[vid] += 1
            placed.append(vid)
        elif placed:
            vid = placed.pop(data.draw(st.integers(0, len(placed) - 1)))
            free[vid] += need
            sessions[vid] -= 1
        assert all(f >= 0 for f in free.values())


# --------------------------------------------------------------------------- #
# Scaling policy (pure)
# --------------------------------------------------------------------------- #


def _scaler(**kw):
    base = dict(min_verifiers=1, max_verifiers=4, sessions_high=4.0,
                queue_high=3.0, cooldown=1.0)
    base.update(kw)
    return AutoScaler(ScalingConfig(**base))


def test_scaler_scales_up_on_queue_depth():
    s = _scaler()
    loads = [VerifierLoad(verifier=0, sessions=2, queue_depth=5.0)]
    assert s.decide(loads, now=0.0).action == "up"


def test_scaler_scales_up_on_occupancy():
    s = _scaler()
    loads = [VerifierLoad(verifier=0, sessions=9)]
    assert s.decide(loads, now=0.0).action == "up"


def test_scaler_cooldown_gates_consecutive_decisions():
    s = _scaler(cooldown=2.0)
    loads = [VerifierLoad(verifier=0, sessions=9)]
    assert s.decide(loads, now=0.0).action == "up"
    assert s.decide(loads, now=1.0).action == "hold"  # inside the cooldown
    assert s.decide(loads, now=2.5).action == "up"


def test_scaler_scales_down_draining_least_loaded():
    s = _scaler()
    loads = [
        VerifierLoad(verifier=0, sessions=1),
        VerifierLoad(verifier=1, sessions=0),
    ]
    d = s.decide(loads, now=0.0)
    assert d.action == "down" and d.drain == 1


def test_scaler_respects_min_and_max_bounds():
    s = _scaler(max_verifiers=1)
    assert s.decide([VerifierLoad(verifier=0, sessions=50)], now=0.0).action == "hold"
    s = _scaler(min_verifiers=1)
    assert s.decide([VerifierLoad(verifier=0, sessions=0)], now=0.0).action == "hold"


# --------------------------------------------------------------------------- #
# Router integration on the virtual clock
# --------------------------------------------------------------------------- #

SEED = 7


def _make_fleet(clock, n, seed=SEED, verify_time=0.080, pool_blocks=128):
    """N oracle verifiers with small paged pools, wrapped as fleet members."""
    members = []
    for vid in range(n):
        pool = PagedKVPool(pool_blocks, 16, bytes_per_token=1024)
        v = CloudVerifier(
            OracleBackend(seed=seed, clock=clock, verify_time=verify_time),
            batch_window=0.01,
            clock=clock,
            kv_pool=pool,
            kv_shared_prefix=16,
        )
        v.start()
        members.append(LocalVerifier(vid, v, clock=clock))
    return members


def _make_client(clock, router, sid, seed=SEED, **cfg_kw):
    """One edge client attached through the router over faultless channels."""
    up = Channel(ChannelConfig(alpha=0.02, beta=0.002), f"up{sid}", clock=clock)
    dn = Channel(ChannelConfig(alpha=0.01, beta=0.0005), f"dn{sid}", clock=clock)
    router.attach(sid, up, dn)
    base = dict(gamma=0.02, nav_timeout=5.0, backoff_init=0.05, backoff_max=0.4)
    base.update(cfg_kw)
    return EdgeClient(sid, up, dn, EdgeConfig(**base), draft=OracleDraft(seed=seed))


def _run_clients(clock, clients, n_tokens, teardown):
    """Drive every client to ``n_tokens`` accepted; returns their stats."""
    def body():
        handles = [
            clock.spawn(lambda c=c: c.run(n_tokens), name=f"cli-{c.session}")
            for c in clients
        ]
        out = [(h.join(), h.result())[1] for h in handles]
        teardown()
        return out

    return clock.run(body)


def test_router_spreads_sessions_and_serves_oracle_streams():
    clock = VirtualClock()
    fleet = _make_fleet(clock, 2)
    router = Router(fleet, clock=clock)
    clients = [_make_client(clock, router, sid) for sid in range(4)]

    def teardown():
        router.stop()
        for vc in fleet:
            vc.stop()

    stats = _run_clients(clock, clients, 60, teardown)
    # Least-loaded placement spreads 4 sessions 2/2.
    placed = [rs.verifier for rs in router.sessions.values()]
    assert sorted(placed) == [0, 0, 1, 1]
    for c, st_ in zip(clients, stats):
        assert st_["failovers"] == 0
        assert st_["routes_seen"] >= 1  # the placement announcement arrived
        assert c.tokens == OracleStream(SEED).prefix(len(c.tokens))


def test_router_admission_refusal_when_fleet_full():
    clock = VirtualClock()
    fleet = _make_fleet(clock, 2, pool_blocks=8)
    router = Router(fleet, clock=clock, need_blocks=10_000)

    def body():
        up = Channel(ChannelConfig(), "up", clock=clock)
        dn = Channel(ChannelConfig(), "dn", clock=clock)
        with pytest.raises(FleetFullError):
            router.attach(0, up, dn)
        router.stop()
        for vc in fleet:
            vc.stop()

    clock.run(body)
    assert router.stats["admission_refusals"] == 1
    assert router.stats["sessions_placed"] == 0


def test_live_migration_during_inflight_nav_round():
    """Migrate while the source verifier is mid-verify: the replayed round
    completes on the destination and the stream stays oracle-exact."""
    clock = VirtualClock()
    fleet = _make_fleet(clock, 2, verify_time=1.0)  # slow verify
    router = Router(fleet, clock=clock)
    client = _make_client(clock, router, 0, nav_timeout=10.0)

    def teardown():
        router.stop()
        for vc in fleet:
            vc.stop()

    def events():
        clock.sleep(0.8)  # round 1's NAV is now in flight on verifier 0
        assert router.migrate(0, dst=1) == 1

    def body():
        ev = clock.spawn(events, name="events")
        h = clock.spawn(lambda: client.run(40), name="cli")
        h.join()
        st_ = h.result()
        ev.join()
        # Before teardown: the source dropped the session, the dst serves it.
        assert 0 not in fleet[0].verifier.sessions
        assert 0 in fleet[1].verifier.sessions
        teardown()
        return st_

    st_ = clock.run(body)
    assert router.stats["migrations"] == 1
    assert st_["migrations_seen"] >= 1
    assert st_["failovers"] == 0  # the replay beat the NAV timeout
    assert router.sessions[0].verifier == 1
    assert client.tokens == OracleStream(SEED).prefix(len(client.tokens))


def test_verifier_crash_fails_sessions_over():
    clock = VirtualClock()
    fleet = _make_fleet(clock, 2)
    router = Router(fleet, clock=clock)
    clients = [_make_client(clock, router, sid) for sid in range(2)]
    crashed = [rs.verifier for rs in router.sessions.values()][0]

    def teardown():
        router.stop()
        for vc in fleet:
            if vc.alive:
                vc.stop()

    def events():
        clock.sleep(1.1)
        fleet[crashed].crash()

    def body():
        ev = clock.spawn(events, name="events")
        handles = [clock.spawn(lambda c=c: c.run(60), name=f"cli-{c.session}") for c in clients]
        out = [(h.join(), h.result())[1] for h in handles]
        ev.join()
        teardown()
        return out

    clock.run(body)
    assert router.stats["verifier_crashes"] == 1
    assert router.stats["failover_migrations"] >= 1
    survivor = 1 - crashed
    for c in clients:
        assert c.tokens == OracleStream(SEED).prefix(len(c.tokens))
        assert router.sessions[c.session].verifier == survivor


def test_drain_migrates_sessions_and_refuses_new_placements():
    clock = VirtualClock()
    fleet = _make_fleet(clock, 2)
    router = Router(fleet, clock=clock)
    clients = [_make_client(clock, router, sid) for sid in range(2)]

    def teardown():
        router.stop()
        for vc in fleet:
            vc.stop()

    def events():
        clock.sleep(1.0)
        moved = router.drain_verifier(0)
        assert moved == 1  # its one session went to verifier 1
        # A drained verifier refuses direct attaches too (server-side drain).
        with pytest.raises(VerifierDraining):
            fleet[0].verifier.attach(99, Channel(ChannelConfig(), clock=clock),
                                     Channel(ChannelConfig(), clock=clock))
        # ... and the router never places on it again.
        c = _make_client(clock, router, 7)
        assert router.sessions[7].verifier == 1
        return c

    def body():
        ev = clock.spawn(events, name="events")
        handles = [clock.spawn(lambda c=c: c.run(60), name=f"cli-{c.session}") for c in clients]
        ev.join()
        late = ev.result()
        h_late = clock.spawn(lambda: late.run(30), name="cli-late")
        for h in handles:
            h.join()
        h_late.join()
        teardown()
        return late

    late = clock.run(body)
    assert router.stats["drains"] == 1 and router.stats["migrations"] == 1
    for c in clients + [late]:
        assert c.tokens == OracleStream(SEED).prefix(len(c.tokens))
        assert router.sessions[c.session].verifier == 1


def test_router_restart_adopts_live_sessions():
    """stop() + snapshot() + a fresh router's adopt(): serving resumes on the
    same client links and the stream stays oracle-exact."""
    clock = VirtualClock()
    fleet = _make_fleet(clock, 2)
    router1 = Router(fleet, clock=clock, name="router1")
    clients = [_make_client(clock, router1, sid, nav_timeout=0.4) for sid in range(2)]
    routers = [router1]

    def events():
        clock.sleep(1.2)
        snap = router1.snapshot()
        router1.stop()  # detaches the fleet; client links stay open
        router2 = Router(fleet, clock=clock, name="router2")
        routers.append(router2)
        for c in clients:
            pos, rnd = snap[c.session]
            router2.adopt(c.session, c.up, c.dn, position=pos, round_id=rnd)

    def body():
        ev = clock.spawn(events, name="events")
        handles = [clock.spawn(lambda c=c: c.run(80), name=f"cli-{c.session}") for c in clients]
        out = [(h.join(), h.result())[1] for h in handles]
        ev.join()
        routers[-1].stop()
        for vc in fleet:
            vc.stop()
        return out

    clock.run(body)
    assert len(routers) == 2
    assert routers[1].stats["sessions_placed"] == 2
    for c in clients:
        assert c.tokens == OracleStream(SEED).prefix(len(c.tokens))


def test_client_reconnect_reattaches_to_new_verifier():
    """A severed client link + the reconnect hook: the client re-dials a
    fresh verifier, announces its position via Reset, and the stream stays
    oracle-exact across the re-attach."""
    clock = VirtualClock()
    fleet = _make_fleet(clock, 2)
    va, vb = fleet[0].verifier, fleet[1].verifier

    up = Channel(ChannelConfig(alpha=0.02, beta=0.002), "up", clock=clock)
    dn = Channel(ChannelConfig(alpha=0.01, beta=0.0005), "dn", clock=clock)
    va.attach(0, up, dn)

    def reconnect():
        nu = Channel(ChannelConfig(alpha=0.02, beta=0.002), "up2", clock=clock)
        nd = Channel(ChannelConfig(alpha=0.01, beta=0.0005), "dn2", clock=clock)
        vb.attach(0, nu, nd)
        return nu, nd

    client = EdgeClient(
        0, up, dn,
        EdgeConfig(gamma=0.02, nav_timeout=0.4, backoff_init=0.05, backoff_max=0.4),
        draft=OracleDraft(seed=SEED),
        reconnect=reconnect,
    )

    def events():
        clock.sleep(1.0)
        up.close()  # verifier A's host died: both directions sever
        dn.close()

    def body():
        ev = clock.spawn(events, name="events")
        st_ = client.run(80)
        ev.join()
        for vc in fleet:
            vc.stop()
        return st_

    st_ = clock.run(body)
    assert st_["reattaches"] == 1
    assert st_["failovers"] >= 1
    assert client.tokens == OracleStream(SEED).prefix(len(client.tokens))
    assert 0 in vb.sessions  # serving moved to the new verifier


def test_autoscaler_grows_fleet_under_load():
    clock = VirtualClock()
    fleet = _make_fleet(clock, 1)
    spawned = []

    def make_verifier(vid):
        vc = _make_fleet(clock, 1, verify_time=0.080)[0]
        vc.verifier_id = vid
        spawned.append(vc)
        return vc

    router = Router(
        fleet,
        clock=clock,
        scaler=AutoScaler(ScalingConfig(
            min_verifiers=1, max_verifiers=3, sessions_high=2.0,
            queue_high=2.0, cooldown=0.5,
            # Loaded enough that shrink never triggers mid-run.
            sessions_low_factor=0.0,
        )),
        make_verifier=make_verifier,
        control_interval=0.25,
    )
    clients = [_make_client(clock, router, sid) for sid in range(6)]

    def body():
        router.start()
        handles = [clock.spawn(lambda c=c: c.run(60), name=f"cli-{c.session}") for c in clients]
        out = [(h.join(), h.result())[1] for h in handles]
        router.stop()
        for vc in fleet + spawned:
            vc.stop()
        return out

    clock.run(body)
    assert router.stats["scale_ups"] >= 1
    assert len(router.fleet) >= 2
    for c in clients:
        assert c.tokens == OracleStream(SEED).prefix(len(c.tokens))


def test_autoscaler_retires_idle_verifier():
    clock = VirtualClock()
    fleet = _make_fleet(clock, 2)
    router = Router(
        fleet,
        clock=clock,
        scaler=AutoScaler(ScalingConfig(
            min_verifiers=1, max_verifiers=2, sessions_high=8.0,
            queue_high=50.0, cooldown=0.5,
        )),
        control_interval=0.25,
    )
    client = _make_client(clock, router, 0)

    def body():
        router.start()
        st_ = client.run(60)
        router.stop()
        for vc in fleet:
            vc.stop()
        return st_

    clock.run(body)
    assert router.stats["scale_downs"] == 1
    assert len(router.fleet) == 1  # the idle member was drained and retired
    assert router.sessions[0].verifier in router.fleet
    assert client.tokens == OracleStream(SEED).prefix(len(client.tokens))


# --------------------------------------------------------------------------- #
# Two-verifier multi-process smoke (the CI router-smoke job's shape)
# --------------------------------------------------------------------------- #


def test_router_two_process_fleet_streams_through_migrations():
    """launch/serve.py as router + 2 verifier processes: 64 tokens streamed
    through forced migrations still match the oracle."""
    serve = ROOT / "launch" / "serve.py"

    def spawn(args):
        return subprocess.Popen(
            [sys.executable, str(serve), *args],
            stdout=subprocess.PIPE, stderr=subprocess.STDOUT, text=True,
        )

    def port_of(proc):
        line = proc.stdout.readline()
        assert line.startswith("LISTENING "), line
        return int(line.strip().rsplit(":", 1)[1])

    v1 = spawn(["--listen", "127.0.0.1:0", "--sessions", "0", "--seed", "11"])
    v2 = spawn(["--listen", "127.0.0.1:0", "--sessions", "0", "--seed", "11"])
    router = None
    try:
        p1, p2 = port_of(v1), port_of(v2)
        router = spawn([
            "--router", "127.0.0.1:0",
            "--verifier", f"127.0.0.1:{p1}", "--verifier", f"127.0.0.1:{p2}",
            "--migrate-every", "0.3", "--sessions", "1", "--seed", "11",
        ])
        rp = port_of(router)
        out = subprocess.run(
            [sys.executable, str(serve), "--connect", f"127.0.0.1:{rp}",
             "--tokens", "64", "--seed", "11", "--check-oracle"],
            capture_output=True, text=True, timeout=120,
        )
        assert out.returncode == 0, out.stderr
        stream = [int(x) for x in out.stdout.split()]
        assert stream == OracleStream(11).prefix(64)
        assert router.wait(timeout=30) == 0
        summary = router.stdout.read()
        assert "ROUTED" in summary, summary
        migrations = int(summary.split("migrations=")[1].split()[0])
        assert migrations >= 1  # the stream really crossed a migration
    finally:
        for proc in (v1, v2, router):
            if proc is not None and proc.poll() is None:
                proc.kill()
                proc.wait()
