"""Dry-run machinery end-to-end on a small CPU mesh (subprocess: the 8-device
host-platform flag must be set before jax initializes, and the main test
process must keep seeing 1 device)."""

import json
import subprocess
import sys
import textwrap
from pathlib import Path

import pytest

SCRIPT = textwrap.dedent(
    """
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    import json, sys
    import jax, jax.numpy as jnp
    from jax.sharding import NamedSharding
    sys.path.insert(0, "src")
    from repro.configs import get_config
    from repro.launch.steps import TrainState, build_train_step, build_decode_step
    from repro.models import zoo
    from repro.optim import adamw
    from repro.sharding.partition import Partitioner
    from repro.launch.dryrun import collective_census, _as_cost_dict

    mesh = jax.make_mesh((2, 4), ("data", "model"))
    cfg = get_config("granite-3-2b", reduced=True)
    part = Partitioner(mesh)
    params_spec = jax.eval_shape(lambda: zoo.init(jax.random.PRNGKey(0), cfg))
    params_sh = jax.tree_util.tree_map(lambda s: NamedSharding(mesh, s), part.param_specs(params_spec))
    batch = {"tokens": jax.ShapeDtypeStruct((8, 64), jnp.int32), "labels": jax.ShapeDtypeStruct((8, 64), jnp.int32)}
    batch_sh = part.batch_shardings(batch)
    opt = adamw(1e-3)
    opt_spec = jax.eval_shape(opt.init, params_spec)
    opt_sh = jax.tree_util.tree_map(lambda s: NamedSharding(mesh, s), part.param_specs(opt_spec))
    state_spec = TrainState(params_spec, opt_spec, jax.ShapeDtypeStruct((), jnp.int32))
    from jax.sharding import PartitionSpec as P
    state_sh = TrainState(params_sh, opt_sh, NamedSharding(mesh, P()))
    step = build_train_step(cfg, opt)
    with mesh:
        compiled = jax.jit(step, in_shardings=(state_sh, batch_sh), out_shardings=(state_sh, None)).lower(state_spec, batch).compile()
        cost = _as_cost_dict(compiled.cost_analysis())
        mem = compiled.memory_analysis()
        hlo = compiled.as_text()
    coll = collective_census(hlo)
    print(json.dumps({
        "flops": float(cost.get("flops", 0)),
        "temp": int(mem.temp_size_in_bytes),
        "collectives": sorted(coll),
        "coll_bytes": int(sum(v["bytes"] for v in coll.values())),
    }))
    """
)


@pytest.mark.slow
def test_dryrun_compiles_on_8_device_mesh():
    out = subprocess.run(
        [sys.executable, "-c", SCRIPT],
        capture_output=True, text=True, timeout=600, cwd=Path(__file__).parent.parent,
    )
    assert out.returncode == 0, out.stderr[-3000:]
    rec = json.loads(out.stdout.strip().splitlines()[-1])
    assert rec["flops"] > 0
    assert rec["coll_bytes"] > 0  # TP/DP must produce collectives
    assert "all-reduce" in rec["collectives"]


@pytest.mark.slow
def test_production_dryrun_cell_has_artifacts():
    """If the background sweep already produced cells, validate their schema."""
    results = Path(__file__).parent.parent / "dryrun_results"
    if not results.exists() or not list(results.glob("*.json")):
        pytest.skip("no dry-run artifacts yet")
    rec = json.loads(sorted(results.glob("*.json"))[0].read_text())
    assert {"arch", "shape", "mesh", "ok"} <= set(rec)
    if rec.get("ok") and not rec.get("skipped"):
        assert rec["per_device_bytes"] > 0
        assert rec["flops_scaled"] > 0
