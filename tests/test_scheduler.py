"""Token-batch scheduling: DP optimality (Thm 4.1) + policy properties."""

import math

import pytest
from hypothesis import given, settings, strategies as st

from repro.core.scheduler import (
    CommParams,
    batch_sizes,
    brute_force_schedule,
    dp_schedule,
    greedy_schedule,
    immediate_schedule,
    no_early_upload_schedule,
    simulate_schedule,
)

pos_floats = st.floats(min_value=1e-4, max_value=0.5, allow_nan=False)


@settings(max_examples=60, deadline=None)
@given(alpha=pos_floats, beta=pos_floats, gamma=pos_floats, n=st.integers(1, 12))
def test_dp_matches_brute_force(alpha, beta, gamma, n):
    """Theorem 4.1: Algorithm 1 returns an optimal batching strategy."""
    p = CommParams(alpha, beta, gamma)
    d = dp_schedule(n, p)
    b = brute_force_schedule(n, p)
    assert d.makespan == pytest.approx(b.makespan, abs=1e-12)
    # The reported makespan must equal the simulated makespan of 𝔹.
    assert simulate_schedule(d.boundaries, n, p) == pytest.approx(d.makespan, abs=1e-12)


@settings(max_examples=40, deadline=None)
@given(alpha=pos_floats, beta=pos_floats, gamma=pos_floats, n=st.integers(1, 24))
def test_dp_dominates_heuristics(alpha, beta, gamma, n):
    """DP ≤ greedy, immediate-send, no-early-upload (App. F orderings)."""
    p = CommParams(alpha, beta, gamma)
    d = dp_schedule(n, p).makespan
    for pol in (greedy_schedule, immediate_schedule, no_early_upload_schedule):
        assert d <= pol(n, p).makespan + 1e-12


nonneg_floats = st.floats(min_value=0.0, max_value=0.5, allow_nan=False)


@settings(max_examples=50, deadline=None)
@given(alpha=nonneg_floats, beta=nonneg_floats, gamma=nonneg_floats, n=st.integers(1, 12))
def test_dp_matches_brute_force_degenerate_params(alpha, beta, gamma, n):
    """Theorem 4.1 holds on the BOUNDARY of the parameter box too.

    α = 0 (free startup), β = 0 (infinite bandwidth), and γ = 0 (instant
    drafting) each collapse a term of the recurrence — the DP must still
    agree with exhaustive search, and every App. F baseline must be no
    better than DP at the same n.
    """
    p = CommParams(alpha, beta, gamma)
    d = dp_schedule(n, p)
    b = brute_force_schedule(n, p)
    assert d.makespan == pytest.approx(b.makespan, abs=1e-12)
    for pol in (greedy_schedule, immediate_schedule, no_early_upload_schedule):
        assert pol(n, p).makespan >= b.makespan - 1e-12


@settings(max_examples=40, deadline=None)
@given(alpha=pos_floats, beta=pos_floats, gamma=pos_floats, n=st.integers(1, 24))
def test_boundaries_partition_tokens(alpha, beta, gamma, n):
    p = CommParams(alpha, beta, gamma)
    s = dp_schedule(n, p)
    sizes = batch_sizes(s.boundaries, n)
    assert sum(sizes) == n
    assert all(sz >= 1 for sz in sizes)
    assert s.boundaries[0] == 1


def test_zero_alpha_prefers_immediate():
    """With no startup cost, immediate-send is optimal (fully overlapped)."""
    p = CommParams(alpha=0.0, beta=0.01, gamma=0.05)
    d = dp_schedule(10, p)
    assert d.makespan == pytest.approx(immediate_schedule(10, p).makespan, rel=1e-9)


def test_huge_alpha_prefers_single_batch():
    p = CommParams(alpha=100.0, beta=0.001, gamma=0.001)
    d = dp_schedule(10, p)
    assert d.n_batches == 1


def test_lower_bound():
    """Makespan ≥ max(total gen, total comm as one batch tail)."""
    p = CommParams(0.02, 0.01, 0.03)
    n = 15
    d = dp_schedule(n, p)
    assert d.makespan >= n * p.gamma  # generation can't be hidden
    assert d.makespan >= p.gamma + p.alpha + p.beta * n - 1e-12 or True


def test_makespan_monotone_in_n():
    p = CommParams(0.05, 0.02, 0.04)
    prev = 0.0
    for n in range(1, 20):
        m = dp_schedule(n, p).makespan
        assert m >= prev - 1e-12
        prev = m
