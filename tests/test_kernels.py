"""Pallas kernels: shape/dtype sweeps, interpret-mode vs pure-jnp oracles."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

KEY = jax.random.PRNGKey(7)


@pytest.mark.parametrize("B,T,H,Hkv,hd", [(2, 256, 4, 2, 64), (1, 128, 4, 4, 32), (1, 256, 8, 4, 128)])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("window,softcap", [(1 << 30, 0.0), (64, 0.0), (1 << 30, 50.0)])
def test_flash_attention_allclose(B, T, H, Hkv, hd, dtype, window, softcap):
    from repro.kernels.flash_attention import flash_attention

    ks = jax.random.split(KEY, 3)
    q = jax.random.normal(ks[0], (B, T, H, hd), dtype)
    k = jax.random.normal(ks[1], (B, T, Hkv, hd), dtype)
    v = jax.random.normal(ks[2], (B, T, Hkv, hd), dtype)
    out = flash_attention(q, k, v, window=window, softcap=softcap, impl="interpret")
    ref = flash_attention(q, k, v, window=window, softcap=softcap, impl="ref")
    tol = 2e-5 if dtype == jnp.float32 else 2e-2
    np.testing.assert_allclose(np.asarray(out, np.float32), np.asarray(ref, np.float32), atol=tol)


@pytest.mark.parametrize("B,S,H,Hkv,hd,block", [(2, 1024, 4, 2, 64, 512), (3, 512, 8, 8, 32, 128), (1, 2048, 2, 1, 128, 512)])
@pytest.mark.parametrize("window", [1 << 30, 200])
def test_decode_attention_allclose(B, S, H, Hkv, hd, block, window):
    from repro.kernels.decode_attention import decode_attention

    ks = jax.random.split(KEY, 4)
    q = jax.random.normal(ks[0], (B, H, hd))
    kc = jax.random.normal(ks[1], (B, S, Hkv, hd))
    vc = jax.random.normal(ks[2], (B, S, Hkv, hd))
    lens = jax.random.randint(ks[3], (B,), 1, S)
    out = decode_attention(q, kc, vc, lens, window=window, impl="interpret", block_k=block)
    ref = decode_attention(q, kc, vc, lens, window=window, impl="ref")
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref), atol=3e-5)


@pytest.mark.parametrize("B,K,V,bv", [(4, 8, 4096, 1024), (2, 5, 2048, 2048), (3, 8, 8192, 2048)])
def test_spec_verify_allclose(B, K, V, bv):
    from repro.kernels.spec_verify import spec_verify

    ks = jax.random.split(KEY, 3)
    logits = jax.random.normal(ks[0], (B, K + 1, V)) * 3
    greedy = jnp.argmax(logits, -1)[:, :K]
    rnd = jax.random.randint(ks[1], (B, K), 0, V)
    mix = jax.random.bernoulli(ks[2], 0.7, (B, K))
    draft = jnp.where(mix, greedy, rnd).astype(jnp.int32)
    nd = jnp.full((B,), K, jnp.int32).at[0].set(max(K - 2, 1))
    na, corr, lp = spec_verify(logits, draft, nd, impl="interpret", block_v=bv)
    na2, corr2, lp2 = spec_verify(logits, draft, nd, impl="ref")
    assert (na == na2).all() and (corr == corr2).all()
    np.testing.assert_allclose(np.asarray(lp), np.asarray(lp2), atol=1e-4)


@pytest.mark.parametrize("B,T,D,bt,bd", [(2, 512, 256, 128, 128), (1, 256, 512, 64, 256), (2, 128, 128, 128, 128)])
@pytest.mark.parametrize("dtype", [jnp.float32])
def test_rglru_scan_allclose(B, T, D, bt, bd, dtype):
    from repro.kernels.rglru_scan import rglru_scan

    ks = jax.random.split(KEY, 3)
    a = jax.random.uniform(ks[0], (B, T, D), dtype, minval=0.5, maxval=0.999)
    b = jax.random.normal(ks[1], (B, T, D), dtype) * 0.1
    h0 = jax.random.normal(ks[2], (B, D), dtype)
    out = rglru_scan(a, b, h0, impl="interpret", block_t=bt, block_d=bd)
    ref = rglru_scan(a, b, h0, impl="ref")
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref), atol=2e-4)


def test_flash_attention_rejects_bad_blocks():
    from repro.kernels.flash_attention.kernel import flash_attention_pallas

    q = jnp.zeros((1, 100, 2, 16))
    with pytest.raises(ValueError):
        flash_attention_pallas(q, q, q, block_q=64, block_k=64)
