"""Paged decode attention == flat decode attention, bit-exact.

The paged ref gathers pages into the flat layout and reuses the flat oracle,
so ref-vs-ref equality is structural; the Pallas kernels stream identical
values in identical order when the page size matches the flat ``block_k``,
so kernel-vs-kernel equality is also exact.  The hypothesis property sweeps
random geometries, block tables, and (non-pow2, down to 1) lengths.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.kernels.decode_attention import decode_attention, paged_decode_attention

KEY = jax.random.PRNGKey(11)


def _make_case(B, H, Hkv, hd, bs, G, P, seed=0):
    """Random pool + per-lane tables, and the equivalent flat cache."""
    rng = np.random.default_rng(seed)
    ks = jax.random.split(jax.random.PRNGKey(seed), 3)
    q = jax.random.normal(ks[0], (B, H, hd))
    k_pages = jax.random.normal(ks[1], (P, bs, Hkv, hd))
    v_pages = jax.random.normal(ks[2], (P, bs, Hkv, hd))
    tables = np.stack([rng.choice(P, G, replace=False) for _ in range(B)]).astype(np.int32)
    S = G * bs
    flat_k = jnp.stack([jnp.asarray(np.asarray(k_pages)[tables[b]].reshape(S, Hkv, hd)) for b in range(B)])
    flat_v = jnp.stack([jnp.asarray(np.asarray(v_pages)[tables[b]].reshape(S, Hkv, hd)) for b in range(B)])
    return q, k_pages, v_pages, tables, flat_k, flat_v


@pytest.mark.parametrize("B,H,Hkv,hd,bs,G,P", [(3, 4, 2, 16, 8, 4, 16), (1, 2, 1, 32, 16, 2, 4), (2, 8, 8, 64, 8, 8, 32)])
@pytest.mark.parametrize("window", [1 << 30, 10])
def test_paged_matches_flat_bitexact(B, H, Hkv, hd, bs, G, P, window):
    q, k_pages, v_pages, tables, flat_k, flat_v = _make_case(B, H, Hkv, hd, bs, G, P)
    S = G * bs
    # Non-pow2 lengths, including the B=1-style degenerate length 1.
    lengths = jnp.asarray([S, max(S // 2 - 3, 1), 1][:B], jnp.int32)

    ref_flat = decode_attention(q, flat_k, flat_v, lengths, window=window, impl="ref")
    ref_paged = paged_decode_attention(q, k_pages, v_pages, tables, lengths, window=window, impl="ref")
    np.testing.assert_array_equal(np.asarray(ref_flat), np.asarray(ref_paged))

    pal_flat = decode_attention(q, flat_k, flat_v, lengths, window=window, impl="interpret", block_k=bs)
    pal_paged = paged_decode_attention(q, k_pages, v_pages, tables, lengths, window=window, impl="interpret")
    np.testing.assert_array_equal(np.asarray(pal_flat), np.asarray(pal_paged))
    np.testing.assert_allclose(np.asarray(pal_paged), np.asarray(ref_paged), atol=3e-5)


def test_ragged_python_tables_and_pool_padding():
    """Ragged per-lane page lists pad like the serving entries (pad id 0)."""
    q, k_pages, v_pages, tables, flat_k, flat_v = _make_case(2, 4, 2, 16, 8, 4, 16, seed=3)
    lengths = jnp.asarray([29, 11], jnp.int32)  # lane 1 only needs 2 pages
    ragged = [list(tables[0]), list(tables[1][:2])]
    out = paged_decode_attention(q, k_pages, v_pages, ragged, lengths, impl="ref")
    ref = decode_attention(q, flat_k, flat_v, lengths, impl="ref")
    np.testing.assert_array_equal(np.asarray(out), np.asarray(ref))


def test_paged_from_pool_tensor_mode():
    """End-to-end: tokens written through PagedKVPool.write, attended paged."""
    from repro.models.paged_kv import PagedKVPool

    B, H, hd, bs = 1, 2, 16, 8
    pool = PagedKVPool(num_blocks=8, block_size=bs, n_layers=1, n_kv_heads=H, head_dim=hd)
    ks = jax.random.split(KEY, 3)
    T = 21  # non-pow2, spans 3 pages
    k = jax.random.normal(ks[0], (1, T, H, hd))
    v = jax.random.normal(ks[1], (1, T, H, hd))
    q = jax.random.normal(ks[2], (B, H, hd))
    pool.create(0)
    pool.write(0, k, v)
    tables = pool.table(0, pad_to=4).reshape(1, -1)
    lengths = jnp.asarray([pool.length(0)], jnp.int32)
    out = paged_decode_attention(q, pool.k_pages[0], pool.v_pages[0], tables, lengths, impl="interpret")
    # Flat oracle over the contiguous original tensors.
    S = 4 * bs
    flat_k = jnp.zeros((1, S, H, hd)).at[:, :T].set(k)
    flat_v = jnp.zeros((1, S, H, hd)).at[:, :T].set(v)
    ref = decode_attention(q, flat_k, flat_v, lengths, impl="ref")
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref), atol=3e-5)


@settings(max_examples=12, deadline=None)
@given(
    B=st.integers(1, 3),
    Hkv=st.sampled_from([1, 2]),
    gqa=st.sampled_from([1, 2]),
    bs=st.sampled_from([4, 8]),
    G=st.integers(1, 4),
    data=st.data(),
)
def test_property_paged_equals_flat(B, Hkv, gqa, bs, G, data):
    """Random block tables (with reuse across lanes) keep paged == flat."""
    H, hd = Hkv * gqa, 8
    P = 2 * G + 1
    rng = np.random.default_rng(data.draw(st.integers(0, 2**31 - 1), label="seed"))
    ks = jax.random.split(jax.random.PRNGKey(int(rng.integers(2**31))), 3)
    q = jax.random.normal(ks[0], (B, H, hd))
    k_pages = jax.random.normal(ks[1], (P, bs, Hkv, hd))
    v_pages = jax.random.normal(ks[2], (P, bs, Hkv, hd))
    # Page reuse across lanes models prefix sharing (same physical pages).
    tables = rng.integers(0, P, size=(B, G)).astype(np.int32)
    S = G * bs
    lengths = jnp.asarray(rng.integers(1, S + 1, size=B), jnp.int32)
    flat_k = jnp.stack([jnp.asarray(np.asarray(k_pages)[tables[b]].reshape(S, Hkv, hd)) for b in range(B)])
    flat_v = jnp.stack([jnp.asarray(np.asarray(v_pages)[tables[b]].reshape(S, Hkv, hd)) for b in range(B)])

    ref_flat = decode_attention(q, flat_k, flat_v, lengths, impl="ref")
    ref_paged = paged_decode_attention(q, k_pages, v_pages, tables, lengths, impl="ref")
    np.testing.assert_array_equal(np.asarray(ref_flat), np.asarray(ref_paged))
    pal_paged = paged_decode_attention(q, k_pages, v_pages, tables, lengths, impl="interpret")
    np.testing.assert_allclose(np.asarray(pal_paged), np.asarray(ref_paged), atol=3e-5)


# ---------------------------------------------------------- int8 pages --


def _quantize_pages(pages):
    """Pool-style affine int8 quantization of [P, bs, Hkv, hd] pages."""
    from repro.models.paged_kv import PagedKVPool

    return PagedKVPool.quantize_kv(pages)


def _q8_error_bound(k_pages, v_pages):
    """Documented output bound: attention output is a convex combination of
    dequantized V rows (each within v_scale/2 per element) with weights from
    scores perturbed by the K error — in practice well under the max V range
    step; we pin a conservative multiple of the worst per-element V error
    plus a score-perturbation term."""
    kr = float(jnp.max(jnp.max(k_pages, -1) - jnp.min(k_pages, -1)))
    vr = float(jnp.max(jnp.max(v_pages, -1) - jnp.min(v_pages, -1)))
    vmax = float(jnp.max(jnp.abs(v_pages)))
    return vr / 510.0 + 2.0 * vmax * kr / 510.0


@pytest.mark.parametrize("B,H,Hkv,hd,bs,G,P", [(3, 4, 2, 16, 8, 4, 16), (1, 2, 1, 32, 16, 2, 4)])
def test_q8_paged_within_bound_of_fp32(B, H, Hkv, hd, bs, G, P):
    """Int8 paged attention tracks the fp32 paged oracle within the bound,
    and the q8 kernel is bit-exact vs the q8 ref (same dequant arithmetic)."""
    q, k_pages, v_pages, tables, flat_k, flat_v = _make_case(B, H, Hkv, hd, bs, G, P)
    S = G * bs
    lengths = jnp.asarray([S, max(S // 2 - 3, 1), 1][:B], jnp.int32)
    kq, ks, kz = _quantize_pages(k_pages)
    vq, vs, vz = _quantize_pages(v_pages)
    quant = (ks, kz, vs, vz)

    fp32 = paged_decode_attention(q, k_pages, v_pages, tables, lengths, impl="ref")
    q8_ref = paged_decode_attention(q, kq, vq, tables, lengths, impl="ref", quant=quant)
    q8_pal = paged_decode_attention(q, kq, vq, tables, lengths, impl="interpret", quant=quant)

    np.testing.assert_allclose(np.asarray(q8_pal), np.asarray(q8_ref), atol=3e-5)
    bound = _q8_error_bound(k_pages, v_pages)
    assert float(jnp.max(jnp.abs(q8_ref - fp32))) <= bound


def test_q8_from_pool_end_to_end():
    """quantize='int8' pool: write fp32, attend through int8 pages + params."""
    from repro.models.paged_kv import PagedKVPool

    B, H, hd, bs = 1, 2, 16, 8
    pool = PagedKVPool(
        num_blocks=8, block_size=bs, n_layers=1, n_kv_heads=H, head_dim=hd,
        quantize="int8",
    )
    ks = jax.random.split(KEY, 3)
    T = 21
    k = jax.random.normal(ks[0], (1, T, H, hd))
    v = jax.random.normal(ks[1], (1, T, H, hd))
    q = jax.random.normal(ks[2], (B, H, hd))
    pool.create(0)
    pool.write(0, k, v)
    tables = pool.table(0, pad_to=4).reshape(1, -1)
    lengths = jnp.asarray([pool.length(0)], jnp.int32)
    quant = (pool.k_scale[0], pool.k_zero[0], pool.v_scale[0], pool.v_zero[0])
    out = paged_decode_attention(
        q, pool.k_pages[0], pool.v_pages[0], tables, lengths,
        impl="interpret", quant=quant,
    )
    S = 4 * bs
    flat_k = jnp.zeros((1, S, H, hd)).at[:, :T].set(k)
    flat_v = jnp.zeros((1, S, H, hd)).at[:, :T].set(v)
    ref = decode_attention(q, flat_k, flat_v, lengths, impl="ref")
    bound = _q8_error_bound(k, v) + 3e-5
    assert float(jnp.max(jnp.abs(out - ref))) <= bound
    # The int8 pool halves (better) bytes/token vs an fp32 pool.
    fp32_bytes = 2 * 1 * H * hd * 4
    assert pool.bytes_per_token * 1.5 <= fp32_bytes


@settings(max_examples=8, deadline=None)
@given(
    B=st.integers(1, 3),
    Hkv=st.sampled_from([1, 2]),
    gqa=st.sampled_from([1, 2]),
    bs=st.sampled_from([4, 8]),
    G=st.integers(1, 3),
    data=st.data(),
)
def test_property_q8_tracks_fp32(B, Hkv, gqa, bs, G, data):
    """Random geometry sweep: q8 kernel == q8 ref bit-for-bit on GQA too,
    and both stay within the documented bound of the fp32 oracle."""
    H = Hkv * gqa
    hd = 16
    P = max(2 * B * G, 4)
    q, k_pages, v_pages, tables, _, _ = _make_case(B, H, Hkv, hd, bs, G, P, seed=B * 7 + G)
    lengths = jnp.asarray(
        [data.draw(st.integers(1, G * bs), label=f"len{b}") for b in range(B)], jnp.int32
    )
    kq, ks, kz = _quantize_pages(k_pages)
    vq, vs, vz = _quantize_pages(v_pages)
    quant = (ks, kz, vs, vz)
    fp32 = paged_decode_attention(q, k_pages, v_pages, tables, lengths, impl="ref")
    q8_ref = paged_decode_attention(q, kq, vq, tables, lengths, impl="ref", quant=quant)
    q8_pal = paged_decode_attention(q, kq, vq, tables, lengths, impl="interpret", quant=quant)
    np.testing.assert_allclose(np.asarray(q8_pal), np.asarray(q8_ref), atol=3e-5)
    assert float(jnp.max(jnp.abs(q8_ref - fp32))) <= _q8_error_bound(k_pages, v_pages)
