"""Hypothesis property tests for Channel invariants on the virtual clock.

Fault-free channels must guarantee, for ANY message sequence:

* FIFO delivery per link (receive order == send order);
* link serialization: batch i's delivery time is
  ``max(send_i, deliver_{i-1}) + cost_i`` — the next batch departs only
  after the previous one frees the link;
* Hockney delay exactness: ``cost_i == (α + β·n_i) · time_scale`` to float
  precision, measured on virtual timestamps (no wall-clock noise).

Skipped (not failed) when hypothesis is missing — see tests/conftest.py.
"""

from hypothesis import given, settings
from hypothesis import strategies as st

from repro.runtime import Channel, ChannelConfig, VirtualClock
from repro.runtime.protocol import DraftFragment

MSGS = st.lists(
    st.tuples(
        st.integers(min_value=0, max_value=64),  # n_tokens
        st.floats(min_value=0.0, max_value=0.5, allow_nan=False, width=32),  # send gap [s]
    ),
    min_size=1,
    max_size=24,
)


@settings(deadline=None, max_examples=60)
@given(msgs=MSGS, alpha=st.floats(0.001, 0.1, width=32), beta=st.floats(0.0, 0.01, width=32))
def test_fifo_serialization_and_hockney_exactness(msgs, alpha, beta):
    clock = VirtualClock()
    ch = Channel(ChannelConfig(alpha=alpha, beta=beta), clock=clock)

    def receiver():
        # Always parked in recv before the next delivery, so the observed
        # timestamp IS the delivery time (not the pickup time).
        out = []
        for _ in msgs:
            m = ch.recv(timeout=1e6)
            assert m is not None
            out.append((m.seq, clock.monotonic()))
        return out

    def body():
        rx = clock.spawn(receiver, name="rx")
        sends = []  # (seq, send time, n_tokens)
        for seq, (n, gap) in enumerate(msgs):
            clock.sleep(gap)
            ch.send(DraftFragment(0, seq, 0, (0,) * n, (0.5,) * n))
            sends.append((seq, clock.monotonic(), n))
        rx.join()
        return sends, rx.result()

    sends, recvs = clock.run(body)

    # FIFO per link: delivery order is exactly send order.
    assert [seq for seq, _ in recvs] == [seq for seq, _, _ in sends]

    # Serialization + Hockney exactness: replay the link model on the
    # virtual timestamps and demand equality to float tolerance.
    link_free = 0.0
    for (seq, t_send, n), (_, t_recv) in zip(sends, recvs):
        cost = alpha + beta * n
        expect = max(t_send, link_free) + cost
        link_free = expect
        assert abs(t_recv - expect) < 1e-9, (seq, t_recv, expect)


@settings(deadline=None, max_examples=40)
@given(msgs=MSGS, scale=st.sampled_from([0.01, 0.25, 1.0, 3.0]))
def test_time_scale_scales_every_delay(msgs, scale):
    """All delivery delays stretch by exactly ``time_scale``."""
    alpha, beta = 0.02, 0.002

    def deliveries(ts):
        clock = VirtualClock()
        ch = Channel(ChannelConfig(alpha=alpha, beta=beta, time_scale=ts), clock=clock)

        def body():
            for seq, (n, _) in enumerate(msgs):
                ch.send(DraftFragment(0, seq, 0, (0,) * n, (0.5,) * n))
            out = []
            for _ in msgs:
                ch.recv(timeout=1e6)
                out.append(clock.monotonic())
            return out

        return clock.run(body)

    base = deliveries(1.0)
    scaled = deliveries(scale)
    for t1, ts_ in zip(base, scaled):
        assert abs(ts_ - t1 * scale) < 1e-9


@settings(deadline=None, max_examples=40)
@given(
    msgs=MSGS,
    drop_seed=st.integers(min_value=0, max_value=2**31),
    drop_prob=st.floats(0.1, 0.9),
)
def test_lossy_channel_preserves_order_of_survivors(msgs, drop_seed, drop_prob):
    """drop_prob loses messages but never reorders the survivors."""
    clock = VirtualClock()
    ch = Channel(
        ChannelConfig(alpha=0.01, beta=0.001, drop_prob=drop_prob, seed=drop_seed),
        clock=clock,
    )

    def body():
        for seq, (n, _) in enumerate(msgs):
            ch.send(DraftFragment(0, seq, 0, (0,) * n, (0.5,) * n))
        got = []
        while (m := ch.recv(timeout=10.0)) is not None:
            got.append(m.seq)
        return got

    got = clock.run(body)
    assert got == sorted(got)
    assert len(got) + ch.stats["dropped"] == len(msgs)
