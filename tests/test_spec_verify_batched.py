"""Batched ragged NAV verification: parity with the per-session path.

The continuous-batching server pads B ragged sessions into one launch
(``spec_verify_batched``); these tests pin down that the padded batched
results are identical to verifying each session alone — i.e. padding rows
and padded positions are inert and nothing leaks across sessions.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from strategies import fused_backend, ragged_logits_requests as _ragged_requests

from repro.kernels.spec_verify import (
    spec_verify,
    spec_verify_batched,
    spec_verify_ragged_ref,
)

KEY = jax.random.PRNGKey(11)


@pytest.mark.parametrize("impl", ["ref", "interpret"])
@pytest.mark.parametrize("ks", [[3], [5, 2], [1, 8, 4, 6, 2]])
def test_batched_matches_per_session(impl, ks):
    V = 2048
    logits_seq, tokens_seq = _ragged_requests(ks, V)
    batched = spec_verify_batched(logits_seq, tokens_seq, impl=impl, block_v=1024)
    # Oracle 1: per-session ragged ref (no padding at all).
    oracle = spec_verify_ragged_ref(logits_seq, tokens_seq)
    # Oracle 2: one unbatched spec_verify call per session through `impl`.
    for i, (lg, tk, k) in enumerate(zip(logits_seq, tokens_seq, ks)):
        na1, corr1, lp1 = batched[i]
        na2, corr2, lp2 = oracle[i]
        assert (na1, corr1) == (na2, corr2), f"session {i}"
        np.testing.assert_allclose(lp1, lp2, atol=1e-4)
        na3, corr3, lp3 = spec_verify(
            jnp.asarray(lg)[None],
            jnp.asarray(tk)[None],
            jnp.asarray([k], jnp.int32),
            impl=impl,
            block_v=1024,
        )
        assert na1 == int(na3[0, 0]) and corr1 == int(corr3[0, 0]), f"session {i}"
        np.testing.assert_allclose(lp1, np.asarray(lp3)[0, :k], atol=1e-4)


def test_batched_ref_is_bit_identical_across_batch_shapes():
    """Padding rows must not perturb a session's outputs at all (ref path)."""
    V = 1024
    logits_seq, tokens_seq = _ragged_requests([4, 7, 2], V, seed=3)
    alone = [
        spec_verify_batched([lg], [tk], impl="ref")[0]
        for lg, tk in zip(logits_seq, tokens_seq)
    ]
    together = spec_verify_batched(logits_seq, tokens_seq, impl="ref")
    for (na1, c1, lp1), (na2, c2, lp2) in zip(alone, together):
        assert (na1, c1) == (na2, c2)
        np.testing.assert_array_equal(lp1, lp2)  # bit-identical


@pytest.mark.parametrize("impl", ["ref", "interpret"])
def test_batched_pads_non_divisible_vocab(impl):
    """V not divisible by block_v: padded -inf lanes must be inert."""
    V = 1500  # not a multiple of any pow2 block
    logits_seq, tokens_seq = _ragged_requests([4, 2], V, seed=5)
    batched = spec_verify_batched(logits_seq, tokens_seq, impl=impl, block_v=1024)
    oracle = spec_verify_ragged_ref(logits_seq, tokens_seq)
    for i, ((na1, c1, lp1), (na2, c2, lp2)) in enumerate(zip(batched, oracle)):
        assert (na1, c1) == (na2, c2), f"session {i}"
        np.testing.assert_allclose(lp1, lp2, atol=1e-4)


@pytest.mark.parametrize("impl", ["ref", "interpret"])
@pytest.mark.parametrize("V", [96, 130, 1500, 3000])
def test_batched_non_pow2_vocabs(impl, V):
    """Vocab padding must stay inert across block-split shapes: V smaller
    than one block, barely over a block, and multi-block with a remainder."""
    logits_seq, tokens_seq = _ragged_requests([3, 5], V, seed=V)
    batched = spec_verify_batched(logits_seq, tokens_seq, impl=impl, block_v=128)
    oracle = spec_verify_ragged_ref(logits_seq, tokens_seq)
    for i, ((na1, c1, lp1), (na2, c2, lp2)) in enumerate(zip(batched, oracle)):
        assert (na1, c1) == (na2, c2), f"V={V} session {i}"
        np.testing.assert_allclose(lp1, lp2, atol=1e-4)


@pytest.mark.parametrize("impl", ["ref", "interpret"])
def test_batched_single_session(impl):
    """B=1: bucketing still pads the batch row dim — the pad row (zero
    logits, n_drafted=0) must not perturb the one real session."""
    logits_seq, tokens_seq = _ragged_requests([5], 512, seed=9)
    (na, corr, lp), = spec_verify_batched(logits_seq, tokens_seq, impl=impl, block_v=256)
    (na2, corr2, lp2), = spec_verify_ragged_ref(logits_seq, tokens_seq)
    assert (na, corr) == (na2, corr2)
    np.testing.assert_allclose(lp, lp2, atol=1e-4)


@pytest.mark.parametrize("impl", ["ref", "interpret"])
def test_batched_all_rejected_round(impl):
    """Every draft wrong: n_accepted = 0 and the correction is the target's
    greedy token at position 0 for every session."""
    V = 256
    logits_seq, tokens_seq = [], []
    for i, k in enumerate([4, 1, 7]):
        lg = np.asarray(jax.random.normal(jax.random.fold_in(KEY, 50 + i), (k + 1, V)) * 3, np.float32)
        greedy = np.argmax(lg, -1)
        tokens_seq.append(np.asarray([(g + 1) % V for g in greedy[:k]], np.int32))  # never match
        logits_seq.append(lg)
    out = spec_verify_batched(logits_seq, tokens_seq, impl=impl, block_v=128)
    for (na, corr, lp), lg in zip(out, logits_seq):
        assert na == 0
        assert corr == int(np.argmax(lg[0]))


@pytest.mark.parametrize("impl", ["ref", "interpret"])
def test_batched_all_accepted_round(impl):
    """Every draft matches the target's greedy choice: n_accepted = K_i and
    the correction is the BONUS token (greedy of the extra row)."""
    V = 256
    ks = [2, 6, 3]
    logits_seq, tokens_seq = [], []
    for i, k in enumerate(ks):
        lg = np.asarray(jax.random.normal(jax.random.fold_in(KEY, 80 + i), (k + 1, V)) * 3, np.float32)
        logits_seq.append(lg)
        tokens_seq.append(np.argmax(lg, -1)[:k].astype(np.int32))
    out = spec_verify_batched(logits_seq, tokens_seq, impl=impl, block_v=128)
    for (na, corr, lp), lg, k in zip(out, logits_seq, ks):
        assert na == k
        assert corr == int(np.argmax(lg[k]))


def test_batched_rejects_bad_inputs():
    lg = np.zeros((4, 64), np.float32)
    with pytest.raises(ValueError):
        spec_verify_batched([], [])
    with pytest.raises(ValueError):
        spec_verify_batched([lg], [[1, 2]])  # K_i mismatch: 3+1 rows needed
    with pytest.raises(ValueError):
        spec_verify_batched([lg, np.zeros((4, 128), np.float32)], [[1, 2, 3], [1, 2, 3]])


def test_spec_verify_backend_no_cross_session_leakage():
    """The server's kernel-backed backend: batched call == per-session calls."""
    from repro.runtime import SpecVerifyBackend

    V = 512

    def logits_fn(session, tokens):
        rng = np.random.default_rng(1000 + session)
        return rng.standard_normal((len(tokens) + 1, V)).astype(np.float32) * 2

    backend = SpecVerifyBackend(logits_fn, impl="ref")
    reqs = [
        (0, [3, 99, 7], [0.9] * 3),
        (1, [5], [0.9]),
        (2, [1, 2, 3, 4, 5, 6], [0.9] * 6),
    ]
    batched = backend.verify_batch(reqs)
    solo = [backend.verify(s, t, c) for (s, t, c) in reqs]
    assert batched == solo


@pytest.mark.parametrize("impl", ["ref", "interpret"])
def test_batched_paged_target_forward_parity(impl):
    """``batched_logits_fn`` + block tables == precomputed per-session logits.

    The paged dispatch hands the entry ONE padded batch (tokens, n_drafted,
    pow2-bucketed block tables) and gets logits back from a single target
    forward; results must match feeding the same logits per session.
    """
    ks = [3, 5, 1]
    V = 512
    logits_seq, tokens_seq = _ragged_requests(ks, V, seed=7)
    tables_seq = [[4, 9], [2], [7, 1, 3]]  # ragged KV block tables
    seen = {}

    def batched_logits_fn(tokens, nd, tables):
        # Padded shapes carry the same pow2 bucketing as the logits batch.
        assert tokens.shape == (4, 8) and nd.shape == (4,)
        assert tables.shape == (4, 4) and tables.dtype == np.int32
        np.testing.assert_array_equal(tables[0, :2], [4, 9])
        np.testing.assert_array_equal(tables[2], [7, 1, 3, 0])  # pad id 0
        np.testing.assert_array_equal(tables[3], 0)  # pad row
        seen["called"] = True
        out = np.zeros((tokens.shape[0], tokens.shape[1] + 1, V), np.float32)
        for i, k in enumerate(ks):
            out[i, : k + 1] = logits_seq[i]
        return out

    paged = spec_verify_batched(
        None,
        tokens_seq,
        impl=impl,
        block_v=256,
        block_tables_seq=tables_seq,
        batched_logits_fn=batched_logits_fn,
    )
    assert seen.get("called")
    plain = spec_verify_batched(logits_seq, tokens_seq, impl=impl, block_v=256)
    for i in range(len(ks)):
        assert paged[i][0] == plain[i][0] and paged[i][1] == plain[i][1]
        np.testing.assert_allclose(paged[i][2], plain[i][2], atol=1e-4)
    with pytest.raises(ValueError):
        spec_verify_batched(logits_seq, tokens_seq, batched_logits_fn=batched_logits_fn)


def test_spec_verify_backend_paged_batched_forward():
    """SpecVerifyBackend with a kv_pool threads block tables into ONE
    batched forward and matches the per-session logits path."""
    from repro.models.paged_kv import PagedKVPool
    from repro.runtime import SpecVerifyBackend

    V = 256
    rngs = {s: np.random.default_rng(500 + s) for s in range(3)}
    cache = {}

    def logits_for(session, n):
        # Deterministic per (session, draft length): both paths agree.
        key = (session, n)
        if key not in cache:
            cache[key] = rngs[session].standard_normal((n + 1, V)).astype(np.float32) * 2
        return cache[key]

    pool = PagedKVPool(num_blocks=16, block_size=4)
    reqs = [(0, [3, 9, 7], [0.9] * 3), (1, [5], [0.9]), (2, [1, 2, 3, 4], [0.9] * 4)]
    for s, toks, _ in reqs:
        pool.create(s)
        pool.append(s, 5 + s)  # distinct table sizes

    def batched_logits_fn(tokens, nd, tables):
        assert tables is not None and tables.shape[0] == tokens.shape[0]
        out = np.zeros((tokens.shape[0], tokens.shape[1] + 1, V), np.float32)
        for i, (s, toks, _) in enumerate(reqs):
            out[i, : len(toks) + 1] = logits_for(s, len(toks))
        return out

    paged_backend = SpecVerifyBackend(
        kv_pool=pool, batched_logits_fn=batched_logits_fn, impl="ref"
    )
    plain_backend = SpecVerifyBackend(lambda s, t: logits_for(s, len(t)), impl="ref")
    assert paged_backend.verify_batch(reqs) == plain_backend.verify_batch(reqs)


def test_tree_batched_paged_target_forward_parity():
    """Tree entry: batched paged forward == precomputed per-session logits."""
    from repro.kernels.spec_verify import spec_verify_tree_batched

    V = 256
    tokens_seq = [[3, 9, 7], [5, 1]]
    parents_seq = [[-1, 0, 0], [-1, -1]]
    logits_seq = [
        np.asarray(jax.random.normal(jax.random.fold_in(KEY, 33 + i), (len(t) + 1, V)) * 3, np.float32)
        for i, t in enumerate(tokens_seq)
    ]
    tables_seq = [[2, 8], [5]]

    def batched_logits_fn(tokens, parents, nn, tables):
        assert tokens.shape == parents.shape == (2, 4) and tables.shape == (2, 2)
        assert parents[0, 3] == -1  # pad nodes carry -1
        out = np.zeros((tokens.shape[0], tokens.shape[1] + 1, V), np.float32)
        for i, t in enumerate(tokens_seq):
            out[i, : len(t) + 1] = logits_seq[i]
        return out

    paged = spec_verify_tree_batched(
        None, tokens_seq, parents_seq,
        impl="ref", block_tables_seq=tables_seq, batched_logits_fn=batched_logits_fn,
    )
    plain = spec_verify_tree_batched(logits_seq, tokens_seq, parents_seq, impl="ref")
    for p, q in zip(paged, plain):
        assert p[0] == q[0] and p[1] == q[1] and p[2] == q[2]
        np.testing.assert_allclose(p[3], q[3], atol=1e-4)


def test_spec_verify_backend_paged_tree_forward():
    """A paged-forward-only backend must serve tree requests through
    batched_tree_logits_fn (and raise clearly when it lacks one)."""
    from repro.models.paged_kv import PagedKVPool
    from repro.runtime import SpecVerifyBackend

    V = 128
    tokens, parents = [7, 9, 3], [-1, 0, 0]
    lg = np.asarray(jax.random.normal(jax.random.fold_in(KEY, 55), (4, V)) * 3, np.float32)

    def batched_tree_logits_fn(toks, pars, nn, tables):
        assert tables is not None
        out = np.zeros((toks.shape[0], toks.shape[1] + 1, V), np.float32)
        out[0, :4] = lg
        return out

    pool = PagedKVPool(num_blocks=8, block_size=4)
    pool.create(0)
    pool.append(0, 6)
    backend = SpecVerifyBackend(
        kv_pool=pool,
        batched_logits_fn=lambda t, n, b: np.zeros((t.shape[0], t.shape[1] + 1, V), np.float32),
        batched_tree_logits_fn=batched_tree_logits_fn,
    )
    got = backend.verify_tree_batch([(0, tokens, [0.9] * 3, parents)])
    from repro.kernels.spec_verify import spec_verify_tree_batched

    (want,) = spec_verify_tree_batched([lg], [tokens], [parents], impl="ref")
    assert got[0] == (int(want[0]), int(want[2]), list(want[1]))

    chain_only = SpecVerifyBackend(
        kv_pool=pool,
        batched_logits_fn=lambda t, n, b: np.zeros((t.shape[0], t.shape[1] + 1, V), np.float32),
    )
    with pytest.raises(ValueError, match="tree requests need"):
        chain_only.verify_tree_batch([(0, tokens, [0.9] * 3, parents)])


# Shared with the sharded differential suite (tests/strategies.py) so the
# unsharded and sharded backends stay comparable request-for-request.
_fused_backend = fused_backend


def test_fused_backend_one_launch_matches_composition():
    """fused=True backend == the unfused paged-attention + verify pipeline,
    with batched == per-session (no cross-session leakage through padding)."""
    from repro.kernels.decode_attention import paged_decode_attention
    from repro.kernels.spec_verify import fused_target_logits, spec_verify

    backend, pool, w, V = _fused_backend()
    reqs = [(0, [3, 9, 7], [0.9] * 3), (1, [5], [0.9]), (2, [1, 2, 3, 4], [0.9] * 4)]
    for s, toks, _ in reqs:
        pool.create(s)
        pool.append(s, 5 + s + len(toks) + 1)  # dispatcher-style metadata append
    batched = backend.verify_batch(reqs)
    solo = [backend.verify(s, t, c) for (s, t, c) in reqs]
    assert batched == solo
    # Unfused oracle per session over the SAME materialized pages.
    for (s, toks, _), got in zip(reqs, batched):
        K1 = len(toks) + 1
        q = jnp.asarray(backend.query_fn(s, toks))[None]  # [1, K1, H, hd]
        base = pool.length(s) - len(toks)
        lengths = jnp.asarray([[base + i for i in range(K1)]], jnp.int32)
        tab = jnp.asarray([list(pool.table(s))], jnp.int32)
        o = paged_decode_attention(
            q.reshape(K1, *q.shape[2:]), pool.k_pages[0], pool.v_pages[0],
            jnp.repeat(tab, K1, axis=0), lengths.reshape(-1), impl="ref",
        ).reshape(1, K1, -1).astype(jnp.float32)
        logits = fused_target_logits(o, jnp.asarray(w), block_v=256, v_true=V)
        na, corr, _ = spec_verify(
            logits, jnp.asarray([toks], jnp.int32), jnp.asarray([len(toks)], jnp.int32),
            impl="ref", block_v=256,
        )
        assert got == (int(np.asarray(na)[0, 0]), int(np.asarray(corr)[0, 0]))


def test_fused_backend_int8_pool_auto_quant():
    """An int8 pool flows its quant params into the fused launch, and the
    integer verdicts track the fp32 pool on the same inputs."""
    fp32, pool32, _, _ = _fused_backend()
    q8, pool8, _, _ = _fused_backend(quantize="int8")
    reqs = [(0, [3, 9, 7], [0.9] * 3), (1, [5], [0.9])]
    for s, toks, _ in reqs:
        for p in (pool32, pool8):
            p.create(s)
            p.append(s, 5 + s + len(toks) + 1)
    assert pool8.k_pages.dtype == jnp.int8
    got32, got8 = fp32.verify_batch(reqs), q8.verify_batch(reqs)
    assert got32 == got8  # sharp LM head: int8 noise can't flip the argmax
    # And the quantized pool is genuinely smaller.
    assert pool8.bytes_per_token * 1.5 <= pool32.bytes_per_token


def _materialized_k(pool, session):
    """Gather the session's K tensors [L, length, H, hd] through its table."""
    tab = pool.table(session)
    kp = np.asarray(pool.k_pages)
    cols = [
        kp[:, int(tab[t // pool.block_size]), t % pool.block_size]
        for t in range(pool.length(session))
    ]
    return np.stack(cols, axis=1)


def test_fused_backend_refills_recycled_pages_after_rollback():
    """REVIEW regression: a rollback that drops a trailing page, followed by
    a foreign session recycling (and dirtying) that page, must not leave the
    regrown slots holding the foreign data — ensure_kv refills from the
    pool's watermark, not a stale backend-side counter."""
    backend, pool, _, _ = _fused_backend()
    H, hd = pool.n_kv_heads, pool.head_dim
    pool.create(0)
    pool.append(0, 9)  # dispatcher-style metadata append: pages [p0, p1, p2]
    backend.ensure_kv(0)
    pool.rollback(0, 6)  # commit 6 -> the trailing page is freed
    pool.create(99)  # a foreign session recycles that page...
    pool.append(99, pool.block_size)
    junk = jnp.full((1, pool.block_size, H, hd), 7.5)
    pool.fill(99, 0, junk, -junk)  # ...and dirties it
    pool.release(99)
    pool.append(0, 3)  # regrow to 9: the dirty page comes back
    backend.ensure_kv(0)
    k, _ = backend.kv_fn(0, 0, 9)
    np.testing.assert_array_equal(_materialized_k(pool, 0), np.asarray(k))


def test_fused_backend_rematerializes_after_eviction():
    """An evicted-then-resumed session re-prefills every slot: its old pages
    may have been handed to (and written by) anyone in between."""
    backend, pool, _, _ = _fused_backend()
    H, hd = pool.n_kv_heads, pool.head_dim
    pool.create(0)
    pool.append(0, 6)
    backend.ensure_kv(0)
    pool.evict(0)  # pool-pressure reclaim
    pool.create(1)  # the pages are recycled and dirtied
    pool.append(1, 8)
    junk = jnp.full((1, 8, H, hd), -3.25)
    pool.fill(1, 0, junk, junk)
    pool.release(1)
    pool.append(0, 6)  # comeback re-prefill (the dispatcher's _kv_secure)
    backend.ensure_kv(0)
    k, _ = backend.kv_fn(0, 0, 6)
    np.testing.assert_array_equal(_materialized_k(pool, 0), np.asarray(k))


def test_fused_backend_reused_session_id_refills_from_scratch():
    """The watermark dies with the table: a reused session id must be fully
    re-materialized, not inherit the dead session's fill state."""
    from repro.models.paged_kv import PagedKVPool
    from repro.runtime import SpecVerifyBackend

    H, hd, V = 2, 8, 256
    pool = PagedKVPool(num_blocks=16, block_size=4, n_layers=1, n_kv_heads=H, head_dim=hd)
    calls = []

    def kv_fn(session, start, count):
        calls.append((session, start, count))
        x = np.full((1, count, H, hd), float(session + 1), np.float32)
        return x, x

    backend = SpecVerifyBackend(
        fused=True, kv_pool=pool, kv_fn=kv_fn, lm_head=np.ones((H * hd, V), np.float32),
        query_fn=lambda s, t: np.zeros((len(t) + 1, H, hd), np.float32),
    )
    pool.create(7)
    pool.append(7, 8)
    backend.ensure_kv(7)
    pool.release(7)  # session died (timeout / detach)
    pool.create(7)  # same id, new life
    pool.append(7, 8)
    assert pool.filled(7) == 0
    backend.ensure_kv(7)
    assert calls == [(7, 0, 8), (7, 0, 8)]


def test_unfused_paged_backend_pads_tables_with_sentinel():
    """Satellite regression: the batched paged forward pads ragged tables
    with the pool's sentinel page, never page 0 (a live page)."""
    from repro.models.paged_kv import PagedKVPool
    from repro.runtime import SpecVerifyBackend

    V = 128
    pool = PagedKVPool(num_blocks=8, block_size=4)
    seen = {}

    def batched_logits_fn(tokens, nd, tables):
        seen["tables"] = np.array(tables)
        return np.zeros((tokens.shape[0], tokens.shape[1] + 1, V), np.float32)

    backend = SpecVerifyBackend(kv_pool=pool, batched_logits_fn=batched_logits_fn, impl="ref")
    pool.create(0)
    pool.append(0, 6)  # pages [0, 1]
    backend.verify_batch([(0, [1, 2, 3], [0.9] * 3)])
    tables = seen["tables"]
    assert tables.shape[1] >= 2
    np.testing.assert_array_equal(tables[0, 2:], pool.sentinel_page)
    assert (tables[1:] == pool.sentinel_page).all()  # pad rows too


def test_fused_backend_full_serve_round_trip():
    """EdgeClient -> CloudVerifier with the fused single-launch backend over a
    shared paged pool (the dispatcher's _kv_secure owns session lifecycle),
    on the virtual clock: streams commit, and fp32 runs are bit-reproducible.
    The int8 pool serves the same flow through the quantized fused launch."""
    from repro.models.paged_kv import PagedKVPool
    from repro.runtime import SpecVerifyBackend
    from repro.runtime.client import EdgeClient, EdgeConfig
    from repro.runtime.server import CloudVerifier
    from repro.runtime.simclock import VirtualClock
    from repro.runtime.transport import Channel, ChannelConfig

    H, hd, V = 2, 16, 512
    w = np.asarray(jax.random.normal(jax.random.PRNGKey(1), (H * hd, V)) * 6, np.float32)

    def query_fn(session, tokens):
        k = jax.random.fold_in(jax.random.PRNGKey(2), session * 997 + len(tokens))
        return np.asarray(jax.random.normal(k, (len(tokens) + 1, H, hd)), np.float32)

    def once(quantize):
        clock = VirtualClock()
        pool = PagedKVPool(num_blocks=256, block_size=8, n_layers=1, n_kv_heads=H,
                           head_dim=hd, quantize=quantize)
        backend = SpecVerifyBackend(fused=True, kv_pool=pool, query_fn=query_fn,
                                    lm_head=w, impl="ref", block_v=512)
        server = CloudVerifier(backend, kv_pool=pool, clock=clock)
        up = Channel(ChannelConfig(alpha=0.02, beta=0.002), "up0", clock=clock)
        dn = Channel(ChannelConfig(alpha=0.01, beta=0.0005), "dn0", clock=clock)
        server.attach(0, up, dn)
        c = EdgeClient(0, up, dn, EdgeConfig(gamma=0.02, nav_timeout=3.0))

        def body():
            server.start()
            st = c.run(48)
            server.stop()
            return st

        st = clock.run(body)
        return list(c.tokens), st["accepted_tokens"], st["rounds"]

    run_a, run_b = once(None), once(None)
    assert run_a == run_b  # virtual clock + deterministic fused verify
    tokens, accepted, _rounds = run_a
    assert accepted >= 48 and len(tokens) == accepted
    tokens8, accepted8, _ = once("int8")
    assert accepted8 >= 48 and len(tokens8) == accepted8


def test_fused_serve_shared_prefix_materialized_once_and_stays_shared():
    """CloudVerifier materializes the shared system prefix ONCE on its owner
    before any fork: serving sessions inherit the watermark, their fills
    never touch (and so never CoW-copy) the shared prefix pages, and the
    prefix-sharing memory win survives the fused tensor path."""
    from repro.models.paged_kv import PagedKVPool
    from repro.runtime import SpecVerifyBackend
    from repro.runtime.client import EdgeClient, EdgeConfig
    from repro.runtime.server import CloudVerifier
    from repro.runtime.simclock import VirtualClock
    from repro.runtime.transport import Channel, ChannelConfig

    H, hd, V = 2, 16, 512
    w = np.asarray(jax.random.normal(jax.random.PRNGKey(3), (H * hd, V)) * 6, np.float32)

    def query_fn(session, tokens):
        k = jax.random.fold_in(jax.random.PRNGKey(4), session * 997 + len(tokens))
        return np.asarray(jax.random.normal(k, (len(tokens) + 1, H, hd)), np.float32)

    clock = VirtualClock()
    pool = PagedKVPool(num_blocks=256, block_size=8, n_layers=1, n_kv_heads=H, head_dim=hd)
    backend = SpecVerifyBackend(
        fused=True, kv_pool=pool, query_fn=query_fn, lm_head=w, impl="ref", block_v=512
    )
    server = CloudVerifier(backend, kv_pool=pool, kv_shared_prefix=32, clock=clock)
    assert pool.filled(CloudVerifier.KV_PREFIX_SESSION) == 32  # filled at init
    clients = []
    for s in range(2):
        up = Channel(ChannelConfig(alpha=0.02, beta=0.002), f"up{s}", clock=clock)
        dn = Channel(ChannelConfig(alpha=0.01, beta=0.0005), f"dn{s}", clock=clock)
        server.attach(s, up, dn)
        clients.append(EdgeClient(s, up, dn, EdgeConfig(gamma=0.02, nav_timeout=3.0)))
        assert pool.filled(s) == 32  # forked: watermark inherited, no refill

    def body():
        server.start()
        stats = [c.run(24) for c in clients]
        server.stop()
        return stats

    st0, st1 = clock.run(body)
    assert st0["accepted_tokens"] >= 24 and st1["accepted_tokens"] >= 24
    # All 4 (page-aligned) prefix pages are still shared by owner + sessions.
    prefix_pages = pool.tables[CloudVerifier.KV_PREFIX_SESSION].blocks
    assert len(prefix_pages) == 4
    assert all(int(pool.refcounts[p]) == 3 for p in prefix_pages)
    assert pool.stats["cow_copies"] == 0  # nothing ever wrote a shared page
