"""Batched ragged NAV verification: parity with the per-session path.

The continuous-batching server pads B ragged sessions into one launch
(``spec_verify_batched``); these tests pin down that the padded batched
results are identical to verifying each session alone — i.e. padding rows
and padded positions are inert and nothing leaks across sessions.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels.spec_verify import (
    spec_verify,
    spec_verify_batched,
    spec_verify_ragged_ref,
)

KEY = jax.random.PRNGKey(11)


def _ragged_requests(ks, V, seed=0):
    """Per-session logits [K_i+1, V] + drafts with a mix of greedy/random."""
    logits_seq, tokens_seq = [], []
    for i, k in enumerate(ks):
        keys = jax.random.split(jax.random.fold_in(KEY, seed * 101 + i), 3)
        lg = jax.random.normal(keys[0], (k + 1, V)) * 3
        greedy = jnp.argmax(lg, -1)[:k]
        rnd = jax.random.randint(keys[1], (k,), 0, V)
        mix = jax.random.bernoulli(keys[2], 0.7, (k,))
        tokens_seq.append(np.asarray(jnp.where(mix, greedy, rnd), np.int32))
        logits_seq.append(np.asarray(lg, np.float32))
    return logits_seq, tokens_seq


@pytest.mark.parametrize("impl", ["ref", "interpret"])
@pytest.mark.parametrize("ks", [[3], [5, 2], [1, 8, 4, 6, 2]])
def test_batched_matches_per_session(impl, ks):
    V = 2048
    logits_seq, tokens_seq = _ragged_requests(ks, V)
    batched = spec_verify_batched(logits_seq, tokens_seq, impl=impl, block_v=1024)
    # Oracle 1: per-session ragged ref (no padding at all).
    oracle = spec_verify_ragged_ref(logits_seq, tokens_seq)
    # Oracle 2: one unbatched spec_verify call per session through `impl`.
    for i, (lg, tk, k) in enumerate(zip(logits_seq, tokens_seq, ks)):
        na1, corr1, lp1 = batched[i]
        na2, corr2, lp2 = oracle[i]
        assert (na1, corr1) == (na2, corr2), f"session {i}"
        np.testing.assert_allclose(lp1, lp2, atol=1e-4)
        na3, corr3, lp3 = spec_verify(
            jnp.asarray(lg)[None],
            jnp.asarray(tk)[None],
            jnp.asarray([k], jnp.int32),
            impl=impl,
            block_v=1024,
        )
        assert na1 == int(na3[0, 0]) and corr1 == int(corr3[0, 0]), f"session {i}"
        np.testing.assert_allclose(lp1, np.asarray(lp3)[0, :k], atol=1e-4)


def test_batched_ref_is_bit_identical_across_batch_shapes():
    """Padding rows must not perturb a session's outputs at all (ref path)."""
    V = 1024
    logits_seq, tokens_seq = _ragged_requests([4, 7, 2], V, seed=3)
    alone = [
        spec_verify_batched([lg], [tk], impl="ref")[0]
        for lg, tk in zip(logits_seq, tokens_seq)
    ]
    together = spec_verify_batched(logits_seq, tokens_seq, impl="ref")
    for (na1, c1, lp1), (na2, c2, lp2) in zip(alone, together):
        assert (na1, c1) == (na2, c2)
        np.testing.assert_array_equal(lp1, lp2)  # bit-identical


@pytest.mark.parametrize("impl", ["ref", "interpret"])
def test_batched_pads_non_divisible_vocab(impl):
    """V not divisible by block_v: padded -inf lanes must be inert."""
    V = 1500  # not a multiple of any pow2 block
    logits_seq, tokens_seq = _ragged_requests([4, 2], V, seed=5)
    batched = spec_verify_batched(logits_seq, tokens_seq, impl=impl, block_v=1024)
    oracle = spec_verify_ragged_ref(logits_seq, tokens_seq)
    for i, ((na1, c1, lp1), (na2, c2, lp2)) in enumerate(zip(batched, oracle)):
        assert (na1, c1) == (na2, c2), f"session {i}"
        np.testing.assert_allclose(lp1, lp2, atol=1e-4)


@pytest.mark.parametrize("impl", ["ref", "interpret"])
@pytest.mark.parametrize("V", [96, 130, 1500, 3000])
def test_batched_non_pow2_vocabs(impl, V):
    """Vocab padding must stay inert across block-split shapes: V smaller
    than one block, barely over a block, and multi-block with a remainder."""
    logits_seq, tokens_seq = _ragged_requests([3, 5], V, seed=V)
    batched = spec_verify_batched(logits_seq, tokens_seq, impl=impl, block_v=128)
    oracle = spec_verify_ragged_ref(logits_seq, tokens_seq)
    for i, ((na1, c1, lp1), (na2, c2, lp2)) in enumerate(zip(batched, oracle)):
        assert (na1, c1) == (na2, c2), f"V={V} session {i}"
        np.testing.assert_allclose(lp1, lp2, atol=1e-4)


@pytest.mark.parametrize("impl", ["ref", "interpret"])
def test_batched_single_session(impl):
    """B=1: bucketing still pads the batch row dim — the pad row (zero
    logits, n_drafted=0) must not perturb the one real session."""
    logits_seq, tokens_seq = _ragged_requests([5], 512, seed=9)
    (na, corr, lp), = spec_verify_batched(logits_seq, tokens_seq, impl=impl, block_v=256)
    (na2, corr2, lp2), = spec_verify_ragged_ref(logits_seq, tokens_seq)
    assert (na, corr) == (na2, corr2)
    np.testing.assert_allclose(lp, lp2, atol=1e-4)


@pytest.mark.parametrize("impl", ["ref", "interpret"])
def test_batched_all_rejected_round(impl):
    """Every draft wrong: n_accepted = 0 and the correction is the target's
    greedy token at position 0 for every session."""
    V = 256
    logits_seq, tokens_seq = [], []
    for i, k in enumerate([4, 1, 7]):
        lg = np.asarray(jax.random.normal(jax.random.fold_in(KEY, 50 + i), (k + 1, V)) * 3, np.float32)
        greedy = np.argmax(lg, -1)
        tokens_seq.append(np.asarray([(g + 1) % V for g in greedy[:k]], np.int32))  # never match
        logits_seq.append(lg)
    out = spec_verify_batched(logits_seq, tokens_seq, impl=impl, block_v=128)
    for (na, corr, lp), lg in zip(out, logits_seq):
        assert na == 0
        assert corr == int(np.argmax(lg[0]))


@pytest.mark.parametrize("impl", ["ref", "interpret"])
def test_batched_all_accepted_round(impl):
    """Every draft matches the target's greedy choice: n_accepted = K_i and
    the correction is the BONUS token (greedy of the extra row)."""
    V = 256
    ks = [2, 6, 3]
    logits_seq, tokens_seq = [], []
    for i, k in enumerate(ks):
        lg = np.asarray(jax.random.normal(jax.random.fold_in(KEY, 80 + i), (k + 1, V)) * 3, np.float32)
        logits_seq.append(lg)
        tokens_seq.append(np.argmax(lg, -1)[:k].astype(np.int32))
    out = spec_verify_batched(logits_seq, tokens_seq, impl=impl, block_v=128)
    for (na, corr, lp), lg, k in zip(out, logits_seq, ks):
        assert na == k
        assert corr == int(np.argmax(lg[k]))


def test_batched_rejects_bad_inputs():
    lg = np.zeros((4, 64), np.float32)
    with pytest.raises(ValueError):
        spec_verify_batched([], [])
    with pytest.raises(ValueError):
        spec_verify_batched([lg], [[1, 2]])  # K_i mismatch: 3+1 rows needed
    with pytest.raises(ValueError):
        spec_verify_batched([lg, np.zeros((4, 128), np.float32)], [[1, 2, 3], [1, 2, 3]])


def test_spec_verify_backend_no_cross_session_leakage():
    """The server's kernel-backed backend: batched call == per-session calls."""
    from repro.runtime import SpecVerifyBackend

    V = 512

    def logits_fn(session, tokens):
        rng = np.random.default_rng(1000 + session)
        return rng.standard_normal((len(tokens) + 1, V)).astype(np.float32) * 2

    backend = SpecVerifyBackend(logits_fn, impl="ref")
    reqs = [
        (0, [3, 99, 7], [0.9] * 3),
        (1, [5], [0.9]),
        (2, [1, 2, 3, 4, 5, 6], [0.9] * 6),
    ]
    batched = backend.verify_batch(reqs)
    solo = [backend.verify(s, t, c) for (s, t, c) in reqs]
    assert batched == solo
