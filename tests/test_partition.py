"""Partitioner rules on an abstract 16×16 mesh (no devices needed)."""

import jax
import jax.numpy as jnp
import pytest
from jax.sharding import PartitionSpec as P

from repro.configs import get_config
from repro.models import zoo
from repro.sharding.partition import Partitioner
from repro.sharding.shardctx import abstract_mesh


def _mesh(multi_pod=False):
    if multi_pod:
        return abstract_mesh((2, 16, 16), ("pod", "data", "model"))
    return abstract_mesh((16, 16), ("data", "model"))


def _param_specs(arch, multi_pod=False):
    cfg = get_config(arch)
    part = Partitioner(_mesh(multi_pod))
    spec = jax.eval_shape(lambda: zoo.init(jax.random.PRNGKey(0), cfg))
    return part.param_specs(spec), part, spec


def test_granite_attention_tp_sharding():
    specs, part, shapes = _param_specs("granite-3-2b")
    blk = specs["blocks"]
    assert blk["attn"]["wq"] == P(None, "data", "model")  # [L, d, H·hd]
    assert blk["attn"]["wo"] == P(None, "model", "data")  # row-parallel
    assert blk["mlp"]["w_down"] == P(None, "model", "data")
    assert specs["embed"] == P("model", "data")


def test_moe_expert_sharding():
    specs, part, shapes = _param_specs("qwen3-moe-30b-a3b")
    moe = specs["blocks"]["moe"]
    assert moe["w_gate"] == P(None, "model", None, "data")  # [L, E, d, f]
    assert moe["w_down"] == P(None, "model", "data", None)  # [L, E, f, d]


def test_divisibility_fallbacks_recorded():
    """whisper (20 heads) / minicpm (36 heads): H not divisible by 16 is fine
    because sharding uses the flat H·hd dim — no fallback for attention; the
    partitioner must not crash and must log any replicated dims."""
    for arch in ("whisper-large-v3", "minicpm-2b"):
        specs, part, _ = _param_specs(arch)
        assert isinstance(part.explain(), str)


def test_every_leaf_gets_a_spec_all_archs():
    from repro.configs import ARCH_IDS

    for arch in ARCH_IDS:
        specs, part, shapes = _param_specs(arch)
        n_leaves = len(jax.tree_util.tree_leaves(shapes))
        n_specs = len(jax.tree_util.tree_leaves(specs, is_leaf=lambda x: isinstance(x, P)))
        assert n_leaves == n_specs, arch
        # Sharded dims must divide the axis size.
        mesh = _mesh()
        flat_specs = jax.tree_util.tree_leaves(specs, is_leaf=lambda x: isinstance(x, P))
        flat_shapes = jax.tree_util.tree_leaves(shapes)
        for sp, sh in zip(flat_specs, flat_shapes):
            for dim, ax in zip(sh.shape, tuple(sp)):
                if ax is None:
                    continue
                axes = ax if isinstance(ax, tuple) else (ax,)
                size = 1
                for a in axes:
                    size *= dict(mesh.shape)[a]
                assert dim % size == 0, f"{arch}: {sh.shape} vs {sp}"


def test_cache_specs_flash_decode_layout():
    cfg = get_config("granite-3-2b")
    part = Partitioner(_mesh())
    params = jax.eval_shape(lambda: zoo.init(jax.random.PRNGKey(0), cfg))
    batch = {"tokens": jax.ShapeDtypeStruct((128, 8), jnp.int32)}
    cache = zoo.cache_spec(params, batch, cfg, 32_832)
    specs = part.cache_specs(cache)
    assert specs.k == P(None, "data", "model", None, None)  # S over model


def test_multipod_batch_uses_pod_axis():
    cfg = get_config("granite-3-2b")
    part = Partitioner(_mesh(multi_pod=True))
    batch = {"tokens": jax.ShapeDtypeStruct((256, 4096), jnp.int32)}
    specs = part.batch_specs(batch)
    assert specs["tokens"] == P(("pod", "data"), None)
