"""Partitioner rules on a REAL multi-device host mesh.

The conftest forces a 4-way CPU host platform, so these tests exercise
actual ``Mesh``es over live devices — specs must be constructible as
``NamedSharding``s and params must physically land sharded (shard shapes
halved along sharded dims, one addressable shard per device).  The 16×16
pod-scale divisibility audit keeps running on an abstract mesh (no host
has 256 devices), pinning the paper's full-pod claims.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.configs import get_config
from repro.models import zoo
from repro.sharding.partition import Partitioner, data_axes
from repro.sharding.shardctx import abstract_mesh

requires_mesh = pytest.mark.skipif(
    jax.device_count() < 4,
    reason="needs XLA_FLAGS=--xla_force_host_platform_device_count=4 (set in conftest.py)",
)


def _host_mesh(multi_pod=False):
    devs = np.array(jax.devices()[:4])
    if multi_pod:
        return Mesh(devs.reshape(1, 2, 2), ("pod", "data", "model"))
    return Mesh(devs.reshape(2, 2), ("data", "model"))


def _abstract_mesh(multi_pod=False):
    if multi_pod:
        return abstract_mesh((2, 16, 16), ("pod", "data", "model"))
    return abstract_mesh((16, 16), ("data", "model"))


def _param_specs(arch, multi_pod=False, mesh=None):
    cfg = get_config(arch)
    part = Partitioner(mesh if mesh is not None else _host_mesh(multi_pod))
    spec = jax.eval_shape(lambda: zoo.init(jax.random.PRNGKey(0), cfg))
    return part.param_specs(spec), part, spec


@requires_mesh
def test_granite_attention_tp_sharding():
    specs, part, shapes = _param_specs("granite-3-2b")
    blk = specs["blocks"]
    assert blk["attn"]["wq"] == P(None, "data", "model")  # [L, d, H·hd]
    assert blk["attn"]["wo"] == P(None, "model", "data")  # row-parallel
    assert blk["mlp"]["w_down"] == P(None, "model", "data")
    assert specs["embed"] == P("model", "data")


@requires_mesh
def test_moe_expert_sharding():
    specs, part, shapes = _param_specs("qwen3-moe-30b-a3b")
    moe = specs["blocks"]["moe"]
    assert moe["w_gate"] == P(None, "model", None, "data")  # [L, E, d, f]
    assert moe["w_down"] == P(None, "model", "data", None)  # [L, E, f, d]


@requires_mesh
def test_divisibility_fallbacks_recorded():
    """whisper (20 heads) / minicpm (36 heads): H not divisible is fine
    because sharding uses the flat H·hd dim — no fallback for attention; the
    partitioner must not crash and must log any replicated dims."""
    for arch in ("whisper-large-v3", "minicpm-2b"):
        specs, part, _ = _param_specs(arch)
        assert isinstance(part.explain(), str)


@requires_mesh
def test_params_physically_shard_on_host_mesh():
    """Reduced-config params device_put under the specs: every leaf lands
    with one addressable shard per device, and a tensor-parallel leaf's
    shard shape is halved along its 'model' dim."""
    mesh = _host_mesh()
    cfg = get_config("granite-3-2b", reduced=True)
    part = Partitioner(mesh)
    params = zoo.init(jax.random.PRNGKey(0), cfg)
    shardings = part.param_shardings(params)
    placed = jax.device_put(params, shardings)
    for leaf, sharding in zip(
        jax.tree_util.tree_leaves(placed), jax.tree_util.tree_leaves(shardings)
    ):
        assert len(leaf.addressable_shards) == 4
        assert leaf.sharding.is_equivalent_to(sharding, leaf.ndim)
    wq = placed["blocks"]["attn"]["wq"]  # [L, d, H·hd] under P(None,'data','model')
    full = wq.shape
    shard = wq.addressable_shards[0].data.shape
    assert shard == (full[0], full[1] // 2, full[2] // 2)
    # Round-trip: gathering the shards reproduces the unsharded values.
    host = np.asarray(wq)
    unsharded = np.asarray(zoo.init(jax.random.PRNGKey(0), cfg)["blocks"]["attn"]["wq"])
    np.testing.assert_array_equal(host, unsharded)


@requires_mesh
def test_every_leaf_gets_a_spec_all_archs():
    from repro.configs import ARCH_IDS

    mesh = _host_mesh()
    for arch in ARCH_IDS:
        specs, part, shapes = _param_specs(arch, mesh=mesh)
        n_leaves = len(jax.tree_util.tree_leaves(shapes))
        n_specs = len(jax.tree_util.tree_leaves(specs, is_leaf=lambda x: isinstance(x, P)))
        assert n_leaves == n_specs, arch
        # Every spec must be realizable on the live mesh and divide evenly.
        flat_specs = jax.tree_util.tree_leaves(specs, is_leaf=lambda x: isinstance(x, P))
        flat_shapes = jax.tree_util.tree_leaves(shapes)
        for sp, sh in zip(flat_specs, flat_shapes):
            NamedSharding(mesh, sp)
            for dim, ax in zip(sh.shape, tuple(sp)):
                if ax is None:
                    continue
                axes = ax if isinstance(ax, tuple) else (ax,)
                size = int(np.prod([dict(mesh.shape)[a] for a in axes]))
                assert dim % size == 0, f"{arch}: {sh.shape} vs {sp}"


def test_pod_scale_divisibility_audit():
    """The 16×16 (and 2×16×16) abstract meshes pin the full-pod divisibility
    claims for every arch without needing 256 host devices."""
    from repro.configs import ARCH_IDS

    for multi_pod in (False, True):
        mesh = _abstract_mesh(multi_pod)
        for arch in ARCH_IDS:
            specs, part, shapes = _param_specs(arch, mesh=mesh)
            flat_specs = jax.tree_util.tree_leaves(specs, is_leaf=lambda x: isinstance(x, P))
            flat_shapes = jax.tree_util.tree_leaves(shapes)
            for sp, sh in zip(flat_specs, flat_shapes):
                for dim, ax in zip(sh.shape, tuple(sp)):
                    if ax is None:
                        continue
                    axes = ax if isinstance(ax, tuple) else (ax,)
                    size = int(np.prod([dict(mesh.shape)[a] for a in axes]))
                    assert dim % size == 0, f"{arch}: {sh.shape} vs {sp}"


@requires_mesh
def test_constrain_respects_ambient_mesh_and_divisibility():
    """shardctx.constrain: identity when un-meshed; under `with mesh:` it
    constrains only the dims whose axes exist AND divide, silently dropping
    the rest — the degradation contract model code relies on."""
    from repro.sharding.shardctx import ambient_mesh, axis_size, constrain

    mesh = _host_mesh()
    assert ambient_mesh() is None  # no mesh context → constrain is a no-op
    x = jnp.arange(16.0).reshape(8, 2)
    assert constrain(x, ("data", "model")) is x

    assert axis_size(mesh, None) == 1
    assert axis_size(mesh, "model") == 2
    assert axis_size(mesh, ("data", "model")) == 4

    with mesh:
        assert ambient_mesh() is not None
        # Both dims divide → constrained, values untouched.
        y = jax.jit(lambda a: constrain(a, ("data", "model")))(x)
        np.testing.assert_array_equal(np.asarray(y), np.asarray(x))
        # 'pod' absent here → ('pod','data') degrades to ('data',); dim 7
        # does not divide model=2 → that dim falls back to unconstrained.
        z = jax.jit(lambda a: constrain(a, (("pod", "data"), "model")))(jnp.ones((8, 7)))
        assert z.shape == (8, 7)
        # Nothing constrainable → returns the input unchanged.
        w = jnp.ones((3,))
        assert constrain(w, (None,)) is w


@requires_mesh
def test_cache_specs_flash_decode_layout():
    cfg = get_config("granite-3-2b")
    part = Partitioner(_host_mesh())
    params = jax.eval_shape(lambda: zoo.init(jax.random.PRNGKey(0), cfg))
    batch = {"tokens": jax.ShapeDtypeStruct((128, 8), jnp.int32)}
    cache = zoo.cache_spec(params, batch, cfg, 32_832)
    specs = part.cache_specs(cache)
    assert specs.k == P(None, "data", "model", None, None)  # S over model


@requires_mesh
def test_multipod_batch_uses_pod_axis():
    cfg = get_config("granite-3-2b")
    mesh = _host_mesh(multi_pod=True)
    assert data_axes(mesh) == ("pod", "data")
    part = Partitioner(mesh)
    batch = {"tokens": jax.ShapeDtypeStruct((256, 4096), jnp.int32)}
    specs = part.batch_specs(batch)
    assert specs["tokens"] == P(("pod", "data"), None)
