"""Per-architecture smoke tests: reduced config forward/train step on CPU,
output shapes, no NaNs, prefill/decode consistency with the no-cache oracle."""

import jax
import jax.numpy as jnp
import pytest

from repro.configs import ARCH_IDS, get_config
from repro.models import zoo

KEY = jax.random.PRNGKey(0)


def _batch(cfg, B=2, T=12):
    b = {"tokens": jax.random.randint(KEY, (B, T), 0, cfg.vocab_size),
         "labels": jax.random.randint(KEY, (B, T), 0, cfg.vocab_size)}
    if cfg.family == "audio":
        b["frames"] = jax.random.normal(KEY, (B, cfg.encoder.n_ctx, cfg.d_model))
    if cfg.family == "vlm":
        b["vision_embeds"] = jax.random.normal(KEY, (B, cfg.n_vision_tokens, cfg.d_model))
    return b


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_reduced_forward_and_shapes(arch):
    cfg = get_config(arch, reduced=True)
    params = zoo.init(KEY, cfg)
    b = _batch(cfg)
    logits, aux = zoo.forward(params, b, cfg)
    T_out = b["tokens"].shape[1] + (cfg.n_vision_tokens if cfg.family == "vlm" else 0)
    assert logits.shape == (2, T_out, cfg.padded_vocab_size)
    assert bool(jnp.isfinite(logits).all()), f"{arch}: non-finite logits"
    # Padded vocab slots must be masked out.
    if cfg.padded_vocab_size != cfg.vocab_size:
        assert float(logits[..., cfg.vocab_size :].max()) < -1e20


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_reduced_train_step(arch):
    from repro.launch.steps import TrainState, build_train_step
    from repro.optim import adamw

    cfg = get_config(arch, reduced=True)
    params = zoo.init(KEY, cfg)
    opt = adamw(1e-3)
    state = TrainState(params, opt.init(params), jnp.int32(0))
    step = jax.jit(build_train_step(cfg, opt))
    b = _batch(cfg)
    state, m1 = step(state, b)
    state, m2 = step(state, b)
    assert bool(jnp.isfinite(m1["loss"])) and bool(jnp.isfinite(m2["loss"]))
    assert float(m2["loss"]) < float(m1["loss"]) + 0.5  # not exploding
    assert float(m1["grad_norm"]) > 0.0


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_prefill_decode_match_forward(arch):
    cfg = get_config(arch, reduced=True)
    params = zoo.init(KEY, cfg)
    B, T = 2, 12
    b = _batch(cfg, B, T)
    logits, _ = zoo.forward(params, b, cfg)
    cache = zoo.make_cache(params, b, cfg, 32)
    pre = dict(b)
    pre["tokens"] = b["tokens"][:, : T - 1]
    plog, cache = zoo.prefill(params, pre, cache, cfg)
    dlog, cache = zoo.decode(params, b["tokens"][:, T - 1 :], cache, cfg)
    V = cfg.vocab_size
    off = cfg.n_vision_tokens if cfg.family == "vlm" else 0  # vision prefix
    assert jnp.allclose(plog[:, -1, :V], logits[:, off + T - 2, :V], atol=5e-4), f"{arch} prefill mismatch"
    assert jnp.allclose(dlog[:, 0, :V], logits[:, -1, :V], atol=5e-4), f"{arch} decode mismatch"


@pytest.mark.parametrize("arch", ["granite-3-2b", "qwen3-moe-30b-a3b", "recurrentgemma-2b", "xlstm-350m"])
def test_multi_token_decode_matches(arch):
    """Verify path: decoding K tokens at once == K single-token decodes."""
    cfg = get_config(arch, reduced=True)
    params = zoo.init(KEY, cfg)
    B, T, K = 2, 8, 3
    b = _batch(cfg, B, T + K)
    full, _ = zoo.forward(params, b, cfg)
    cache = zoo.make_cache(params, b, cfg, 32)
    pre = dict(b)
    pre["tokens"] = b["tokens"][:, :T]
    _, cache = zoo.prefill(params, pre, cache, cfg)
    dlog, _ = zoo.decode(params, b["tokens"][:, T : T + K], cache, cfg)
    V = cfg.vocab_size
    assert jnp.allclose(dlog[:, :, :V], full[:, T : T + K, :V], atol=5e-4), f"{arch} NAV-style decode mismatch"


def test_param_counts_match_assignment():
    expected = {
        "whisper-large-v3": (1.5e9, 2.1e9),
        "minicpm-2b": (2.4e9, 3.1e9),
        "gemma3-4b": (3.3e9, 4.5e9),
        "granite-3-2b": (2.2e9, 2.9e9),
        "gemma2-27b": (24e9, 30e9),
        "arctic-480b": (430e9, 520e9),
        "qwen3-moe-30b-a3b": (27e9, 33e9),
        "internvl2-76b": (65e9, 80e9),
        "recurrentgemma-2b": (2.2e9, 3.0e9),
        "xlstm-350m": (0.1e9, 0.5e9),
    }
    for arch, (lo, hi) in expected.items():
        n = get_config(arch).param_count()
        assert lo <= n <= hi, f"{arch}: {n:.3e} outside [{lo:.1e}, {hi:.1e}]"


def test_moe_active_params():
    q = get_config("qwen3-moe-30b-a3b")
    assert q.active_param_count() < 0.2 * q.param_count()
    a = get_config("arctic-480b")
    assert a.active_param_count() < 0.05 * a.param_count()


def test_rglru_custom_vjp_matches_associative_scan():
    """Backward of the linear scan (reverse-scan adjoint) == autodiff oracle."""
    import numpy as np
    from repro.models.rglru import _assoc_linear_scan, _rglru_scan

    key = jax.random.PRNGKey(0)
    B, T, D = 2, 21, 4
    a = jax.random.uniform(key, (B, T, D), minval=0.3, maxval=0.99)
    b = jax.random.normal(jax.random.PRNGKey(1), (B, T, D))
    h0 = jax.random.normal(jax.random.PRNGKey(2), (B, D))
    f1 = lambda a, b, h0: jnp.sum(jnp.sin(_rglru_scan(a, b, h0)))
    f2 = lambda a, b, h0: jnp.sum(jnp.sin(_assoc_linear_scan(a, b, h0)))
    g1 = jax.grad(f1, argnums=(0, 1, 2))(a, b, h0)
    g2 = jax.grad(f2, argnums=(0, 1, 2))(a, b, h0)
    for x, y in zip(g1, g2):
        np.testing.assert_allclose(np.asarray(x), np.asarray(y), atol=1e-5)
