"""Flash-XLA attention (custom VJP): values + gradients vs the naive oracle."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.models.layers import attend, attend_chunked

KEY = jax.random.PRNGKey(3)


def _qkv(B, T, H, hd):
    ks = jax.random.split(KEY, 3)
    return tuple(jax.random.normal(k, (B, T, H, hd)) for k in ks)


def _ref(q, k, v, window, causal, cap):
    B, T = q.shape[:2]
    pos = jnp.broadcast_to(jnp.arange(T)[None], (B, T))
    return attend(q, k, v, pos, pos, jnp.ones((B, T), bool), window, causal, cap)


@pytest.mark.parametrize("window,causal,cap", [(1 << 30, True, 0.0), (48, True, 0.0), (1 << 30, False, 0.0), (1 << 30, True, 30.0), (32, True, 50.0)])
def test_values_and_grads(window, causal, cap):
    B, T, H, hd = 2, 192, 2, 16
    q, k, v = _qkv(B, T, H, hd)

    f_ref = lambda q, k, v: jnp.sum(jnp.cos(_ref(q, k, v, window, causal, cap)))
    f_new = lambda q, k, v: jnp.sum(jnp.cos(attend_chunked(q, k, v, window, causal, cap, q_chunk=64, k_chunk=64)))
    np.testing.assert_allclose(f_ref(q, k, v), f_new(q, k, v), rtol=2e-5)
    g_ref = jax.grad(f_ref, argnums=(0, 1, 2))(q, k, v)
    g_new = jax.grad(f_new, argnums=(0, 1, 2))(q, k, v)
    for a, b in zip(g_ref, g_new):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b), atol=2e-4)


@settings(max_examples=10, deadline=None)
@given(
    bq=st.sampled_from([32, 64, 96]),
    bk=st.sampled_from([32, 64, 96]),
    t_mult=st.integers(2, 3),
    window=st.sampled_from([16, 1 << 30]),
)
def test_block_size_invariance(bq, bk, t_mult, window):
    """Output must not depend on block sizes."""
    T = 192 * t_mult // 2 * 2
    T = 192  # keep runtime bounded; blocks vary
    q, k, v = _qkv(1, T, 2, 16)
    o1 = attend_chunked(q, k, v, window, True, 0.0, q_chunk=bq, k_chunk=bk)
    o2 = _ref(q, k, v, window, True, 0.0)
    np.testing.assert_allclose(np.asarray(o1), np.asarray(o2), atol=2e-5)


def test_traced_window_per_layer():
    """window may be a traced scalar (layer-scan threading)."""
    q, k, v = _qkv(1, 128, 2, 16)

    def f(w):
        return jnp.sum(attend_chunked(q, k, v, w, True, 0.0, q_chunk=64, k_chunk=64))

    out16 = jax.jit(f)(jnp.int32(16))
    ref16 = jnp.sum(_ref(q, k, v, 16, True, 0.0))
    np.testing.assert_allclose(out16, ref16, rtol=1e-5)


def test_ragged_fallback():
    q, k, v = _qkv(1, 100, 2, 16)  # not divisible by chunks
    o = attend_chunked(q, k, v, 1 << 30, True, 0.0, q_chunk=64, k_chunk=64)
    r = _ref(q, k, v, 1 << 30, True, 0.0)
    np.testing.assert_allclose(np.asarray(o), np.asarray(r), atol=2e-5)
