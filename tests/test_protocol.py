"""Wire-protocol unit + property tests: codec exactness, negotiation, guards.

The codec contract: ``decode(encode(m)) == m`` for every message type and
every field value (hypothesis-verified), encoding is deterministic, and
malformed frames raise ``ProtocolError`` instead of producing garbage
messages.  The grep guard enforces the API redesign's end state: no module
outside ``protocol.py`` builds raw stringly-typed messages or pokes at
positional/dict payloads.
"""

import re
from pathlib import Path

import pytest
from hypothesis import given, settings
from hypothesis import strategies as st

from repro.runtime.protocol import (
    MESSAGE_TYPES,
    PROTOCOL_VERSION,
    Attach,
    Detach,
    DraftFragment,
    Drain,
    Heartbeat,
    Hello,
    Migrate,
    NavRequest,
    NavResult,
    ProtocolError,
    Reset,
    Route,
    TelemetryRequest,
    TelemetrySnapshot,
    TreeNavRequest,
    decode,
    encode,
    handshake_reply,
    wire_tokens,
)

I64_MIN, I64_MAX = -(1 << 63), (1 << 63) - 1

# Representative instance per type, exercising defaults and optionals.
EXAMPLES = [
    Hello(session=3),
    Hello(session=I64_MAX, seq=I64_MIN, version=2),
    Attach(session=1),
    Attach(session=9, seq=4, version=3, accepted=False, reason="no — ünïcode reason"),
    DraftFragment(session=0, seq=1, round=2, tokens=(), confs=()),  # empty draft
    DraftFragment(
        session=5, seq=6, round=7,
        tokens=(0, I64_MAX, I64_MIN), confs=(0.0, 1.0, 0.3333333333333333),
        parents=(-1, 0, 1),
    ),
    NavRequest(session=1, seq=2, round=3, n_tokens=4),  # deadline/pos None
    NavRequest(session=1, seq=2, round=3, n_tokens=4, deadline=12.5, pos=640),
    TreeNavRequest(session=1, seq=2, round=3, n_tokens=5, deadline=0.0, pos=0),
    NavResult(session=1, seq=2, n_accepted=3, correction=4, n_drafted=5),
    NavResult(session=1, seq=2, n_accepted=0, correction=4, n_drafted=5, path=()),
    NavResult(session=1, seq=2, n_accepted=2, correction=4, n_drafted=5, path=(0, 3)),
    Reset(session=1, seq=2, round=3, position=0),
    Detach(session=8),
    Heartbeat(session=2, seq=9, t_send=123.456),
    Route(session=4, seq=1, verifier=2),
    Migrate(session=4, seq=2, src=0, dst=3, position=97),
    Drain(verifier=1),  # session defaults to -1: not session-scoped
    TelemetryRequest(seq=3),  # session defaults to -1: control-scoped
    TelemetrySnapshot(
        verifier=2, n_verifiers=4, t=12.5, sessions_active=3, queue_depth=1,
        nav_calls=100, tokens_verified=400, accepted_tokens=300,
        batched_calls=40, occupancy=2.5, verify_busy_time=6.25,
        kv_used_blocks=10, kv_free_blocks=6, kv_resident_bytes=4096,
        kv_resident_sessions=3, kv_cap_hits=1, migrations=2, failovers=1,
        names=("dn_backlog", "ünïcode lane"), values=(2.0, -0.5),
    ),
    TelemetrySnapshot(),  # every default, empty extras lanes
]


@pytest.mark.parametrize("msg", EXAMPLES, ids=lambda m: type(m).__name__)
def test_roundtrip_examples(msg):
    """decode(encode(m)) == m, type included, for curated edge cases."""
    out = decode(encode(msg))
    assert out == msg
    assert type(out) is type(msg)  # TreeNavRequest must not collapse to NavRequest


def test_every_message_type_has_an_example():
    assert {type(m) for m in EXAMPLES} == set(MESSAGE_TYPES)


def test_encoding_is_deterministic():
    """Equal messages produce identical bytes (no timestamps, no interning)."""
    for msg in EXAMPLES:
        assert encode(msg) == encode(msg)


def test_wire_tokens_matches_link_cost_contract():
    """Hockney cost tokens: drafts pay per token, results per accepted (>=1)."""
    assert wire_tokens(DraftFragment(0, 1, 0, (1, 2, 3), (0.5, 0.5, 0.5))) == 3
    assert wire_tokens(DraftFragment(0, 1, 0, (), ())) == 0
    assert wire_tokens(NavResult(0, 1, n_accepted=5, correction=0, n_drafted=6)) == 5
    assert wire_tokens(NavResult(0, 1, n_accepted=0, correction=0, n_drafted=6)) == 1
    for msg in (Hello(0), Attach(0), NavRequest(0, 1, 2, 3), Reset(0, 1, 2, 3),
                Detach(0), Heartbeat(0), Route(0), Migrate(0), Drain(),
                TelemetryRequest(), TelemetrySnapshot()):
        assert wire_tokens(msg) == 1


# --------------------------------------------------------------------------- #
# Hypothesis: round-trip exactness over the full field domains
# --------------------------------------------------------------------------- #

_i64 = st.integers(min_value=I64_MIN, max_value=I64_MAX)
_f64 = st.floats(allow_nan=False)  # NaN breaks ==; every other float is exact
_toks = st.lists(_i64, max_size=12).map(tuple)
_confs = st.lists(_f64, max_size=12).map(tuple)
_opt_f = st.one_of(st.none(), _f64)
_opt_i = st.one_of(st.none(), _i64)
_opt_toks = st.one_of(st.none(), _toks)

_STRATEGIES = {
    Hello: st.builds(Hello, session=_i64, seq=_i64, version=_i64),
    Attach: st.builds(
        Attach, session=_i64, seq=_i64, version=_i64,
        accepted=st.booleans(), reason=st.text(max_size=40),
    ),
    DraftFragment: st.builds(
        DraftFragment, session=_i64, seq=_i64, round=_i64,
        tokens=_toks, confs=_confs, parents=_toks,
    ),
    NavRequest: st.builds(
        NavRequest, session=_i64, seq=_i64, round=_i64,
        n_tokens=_i64, deadline=_opt_f, pos=_opt_i,
    ),
    TreeNavRequest: st.builds(
        TreeNavRequest, session=_i64, seq=_i64, round=_i64,
        n_tokens=_i64, deadline=_opt_f, pos=_opt_i,
    ),
    NavResult: st.builds(
        NavResult, session=_i64, seq=_i64, n_accepted=_i64,
        correction=_i64, n_drafted=_i64, path=_opt_toks,
    ),
    Reset: st.builds(Reset, session=_i64, seq=_i64, round=_i64, position=_i64),
    Detach: st.builds(Detach, session=_i64, seq=_i64),
    Heartbeat: st.builds(Heartbeat, session=_i64, seq=_i64, t_send=_f64),
    Route: st.builds(Route, session=_i64, seq=_i64, verifier=_i64),
    Migrate: st.builds(
        Migrate, session=_i64, seq=_i64, src=_i64, dst=_i64, position=_i64,
    ),
    Drain: st.builds(Drain, session=_i64, seq=_i64, verifier=_i64),
    TelemetryRequest: st.builds(TelemetryRequest, session=_i64, seq=_i64),
    TelemetrySnapshot: st.builds(
        TelemetrySnapshot, session=_i64, seq=_i64, verifier=_i64,
        n_verifiers=_i64, t=_f64, sessions_active=_i64, queue_depth=_i64,
        nav_calls=_i64, tokens_verified=_i64, accepted_tokens=_i64,
        batched_calls=_i64, occupancy=_f64, verify_busy_time=_f64,
        kv_used_blocks=_i64, kv_free_blocks=_i64, kv_resident_bytes=_i64,
        kv_resident_sessions=_i64, kv_cap_hits=_i64, migrations=_i64,
        failovers=_i64,
        names=st.lists(st.text(max_size=20), max_size=6).map(tuple),
        values=st.lists(_f64, max_size=6).map(tuple),
    ),
}


def test_strategy_table_covers_every_type():
    assert set(_STRATEGIES) == set(MESSAGE_TYPES)


@settings(deadline=None, max_examples=60)
@given(data=st.data())
def test_roundtrip_property_every_type(data):
    """decode(encode(m)) == m for arbitrary field values of every type."""
    for cls in MESSAGE_TYPES:
        msg = data.draw(_STRATEGIES[cls], label=cls.__name__)
        frame = encode(msg)
        out = decode(frame)
        assert out == msg and type(out) is cls
        # Frames are internally sized: the length prefix covers the body.
        assert len(frame) == 4 + int.from_bytes(frame[:4], "little")


# --------------------------------------------------------------------------- #
# Malformed frames
# --------------------------------------------------------------------------- #


def test_decode_rejects_malformed_frames():
    frame = encode(Hello(session=1))
    with pytest.raises(ProtocolError):
        decode(frame[:-1])  # truncated
    with pytest.raises(ProtocolError):
        decode(frame + b"\x00")  # length mismatch
    bad_type = frame[:4] + b"\xff" + frame[5:]
    with pytest.raises(ProtocolError):
        decode(bad_type)  # unknown type id
    with pytest.raises(ProtocolError):
        decode(b"\x01")  # shorter than any header
    with pytest.raises(ProtocolError):
        encode(object())  # not a protocol message


def test_decode_rejects_trailing_bytes_inside_frame():
    frame = bytearray(encode(Detach(session=1)))
    # Grow the declared size and pad: decode must flag the trailing bytes.
    frame[0:4] = (int.from_bytes(frame[0:4], "little") + 2).to_bytes(4, "little")
    frame += b"\x00\x00"
    with pytest.raises(ProtocolError):
        decode(bytes(frame))


# --------------------------------------------------------------------------- #
# Version negotiation at attach
# --------------------------------------------------------------------------- #


def test_handshake_accepts_matching_version():
    reply = handshake_reply(Hello(session=4, seq=2))
    assert reply == Attach(session=4, seq=2, version=PROTOCOL_VERSION, accepted=True)


def test_handshake_rejects_version_mismatch():
    reply = handshake_reply(Hello(session=4, version=PROTOCOL_VERSION + 1))
    assert not reply.accepted
    assert reply.version == PROTOCOL_VERSION
    assert f"v{PROTOCOL_VERSION + 1}" in reply.reason and f"v{PROTOCOL_VERSION}" in reply.reason


def test_handshake_can_remap_session_id():
    reply = handshake_reply(Hello(session=0), session=17)
    assert reply.accepted and reply.session == 17


# --------------------------------------------------------------------------- #
# Grep guard: the typed protocol is the ONLY message surface
# --------------------------------------------------------------------------- #


def test_no_raw_message_construction_outside_protocol():
    """No module may construct stringly-typed ``Message(kind, ...)`` blobs or
    poke positional/dict payloads — the typed protocol replaced them."""
    root = Path(__file__).parent.parent
    banned = re.compile(
        r"""\bMessage\(\s*["']"""  # raw Message(kind="...") construction
        r"""|\.payload\["""  # positional/dict payload indexing
        r"""|\.payload\.get\(""",  # dict payload probing
    )
    offenders = {}
    scanned = set()
    for sub in ("src", "tests", "benchmarks", "examples", "launch"):
        for path in sorted((root / sub).rglob("*.py")):
            if path.name == "protocol.py":
                continue
            scanned.add(path.name)
            hits = banned.findall(path.read_text())
            if hits:
                offenders[str(path.relative_to(root))] = hits
    # The control-plane modules must be inside the guard's net.
    assert {"router.py", "placement.py", "scaling.py"} <= scanned
    assert not offenders, f"raw message payloads outside protocol.py: {offenders}"
