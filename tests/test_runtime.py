"""Threaded cloud-edge runtime: e2e sessions, multi-client, failover, hedging."""

import threading
import time

import pytest

from repro.runtime import (
    Channel,
    ChannelConfig,
    CloudVerifier,
    EdgeClient,
    EdgeConfig,
    SyntheticBackend,
)

TS = 0.01  # run the timing model 100× faster than real time


def _mk_client(server, sid, ts=TS, outage=None, nav_timeout=3.0):
    up = Channel(ChannelConfig(alpha=0.02, beta=0.002, time_scale=ts))
    dn = Channel(ChannelConfig(alpha=0.01, beta=0.0005, time_scale=ts, outage=outage))
    server.attach(sid, up, dn)
    return EdgeClient(sid, up, dn, EdgeConfig(time_scale=ts, gamma=0.02, nav_timeout=nav_timeout))


def test_single_session_end_to_end():
    server = CloudVerifier(SyntheticBackend(time_scale=TS))
    server.start()
    c = _mk_client(server, 0)
    stats = c.run(60)
    server.stop()
    assert stats["accepted_tokens"] >= 60
    assert stats["nav_calls"] == stats["rounds"] + stats["failovers"]
    assert server.stats["nav_calls"] >= stats["rounds"]


def test_multi_client_concurrent():
    server = CloudVerifier(SyntheticBackend(time_scale=TS), batch_window=0.002)
    server.start()
    clients = [_mk_client(server, sid) for sid in range(4)]
    res = {}
    ths = [threading.Thread(target=lambda c=c: res.update({c.session: c.run(40)})) for c in clients]
    [t.start() for t in ths]
    [t.join(timeout=60) for t in ths]
    server.stop()
    assert len(res) == 4
    assert all(r["accepted_tokens"] >= 40 for r in res.values())
    # Batched NAV should have amortized some calls.
    assert server.stats["batched_calls"] <= server.stats["nav_calls"]


def test_failover_to_local_decode_and_recovery():
    """Downlink outage → NAV timeout → local decoding → re-attach."""
    server = CloudVerifier(SyntheticBackend(time_scale=TS))
    server.start()
    c = _mk_client(server, 9, outage=(0.0, 0.3), nav_timeout=0.2)
    stats = c.run(50)
    server.stop()
    assert stats["failovers"] >= 1
    assert stats["fallback_tokens"] > 0  # offline progress was made
    assert stats["accepted_tokens"] >= 50


def test_channel_serializes_batches():
    """Two back-to-back sends: second delivery waits for the first (Hockney)."""
    ch = Channel(ChannelConfig(alpha=0.05, beta=0.01, time_scale=1.0))
    from repro.runtime.transport import Message

    t0 = time.monotonic()
    ch.send(Message("a", 0, 1, 10, None))  # 0.05 + 0.1 = 0.15s
    ch.send(Message("b", 0, 2, 10, None))  # completes at 0.30s
    m1 = ch.recv(timeout=2.0)
    m2 = ch.recv(timeout=2.0)
    dt = time.monotonic() - t0
    ch.close()
    assert m1.kind == "a" and m2.kind == "b"
    assert dt >= 0.28  # serialized, not parallel
