"""Threaded cloud-edge runtime: e2e sessions, multi-client, failover, hedging,
continuous-batched NAV (coalescing, session isolation, straggler drop).

All tests run on the deterministic ``VirtualClock`` — the timing model runs
at true scale (``time_scale=1.0``) with zero wall-clock cost, so there are
no ``time.sleep`` calls, no ``time_scale=0.01`` compression hacks, and no
scheduler-jitter flakiness: every assertion on time is exact.
"""

import pytest

from repro.runtime import (
    Channel,
    ChannelConfig,
    CloudVerifier,
    EdgeClient,
    EdgeConfig,
    SyntheticBackend,
    VerifyBackend,
    VirtualClock,
)
from repro.runtime.protocol import DraftFragment, NavRequest, NavResult


class EchoBackend(VerifyBackend):
    """Deterministic: accepts everything, correction = hash(session, tokens).

    Lets tests check that a *batched* verify returns each session exactly the
    result its own tokens imply — any cross-session mixup changes the hash.
    """

    @staticmethod
    def fingerprint(session, tokens):
        h = session + 1
        for t in tokens:
            h = (h * 1000003 + int(t)) % 65536
        return h

    def verify(self, session, tokens, confs):
        return len(tokens), self.fingerprint(session, tokens)


@pytest.fixture()
def clock():
    return VirtualClock()


def _fast_pair(server, sid, clock):
    up = Channel(ChannelConfig(alpha=1e-4, beta=1e-5), f"up{sid}", clock=clock)
    dn = Channel(ChannelConfig(alpha=1e-4, beta=1e-5), f"dn{sid}", clock=clock)
    server.attach(sid, up, dn)
    return up, dn


def _mk_client(server, sid, clock, outage=None, nav_timeout=3.0):
    up = Channel(ChannelConfig(alpha=0.02, beta=0.002), f"up{sid}", clock=clock)
    dn = Channel(ChannelConfig(alpha=0.01, beta=0.0005, outage=outage), f"dn{sid}", clock=clock)
    server.attach(sid, up, dn)
    return EdgeClient(sid, up, dn, EdgeConfig(gamma=0.02, nav_timeout=nav_timeout))


def test_single_session_end_to_end(clock):
    server = CloudVerifier(SyntheticBackend(clock=clock), clock=clock)
    c = _mk_client(server, 0, clock)

    def body():
        server.start()
        stats = c.run(60)
        server.stop()
        return stats

    stats = clock.run(body)
    assert stats["accepted_tokens"] >= 60
    assert stats["nav_calls"] == stats["rounds"] + stats["failovers"]
    assert server.stats["nav_calls"] >= stats["rounds"]
    # The committed stream IS the accepted-token count (drafts + corrections).
    assert len(c.tokens) == stats["accepted_tokens"]


def test_single_session_is_bit_reproducible():
    """Two identically-seeded runs: same stream, same stats, same end time."""

    def once():
        clock = VirtualClock()
        server = CloudVerifier(SyntheticBackend(clock=clock), clock=clock)
        c = _mk_client(server, 0, clock)

        def body():
            server.start()
            st = c.run(60)
            server.stop()
            return st

        st = clock.run(body)
        return list(c.tokens), st, dict(server.stats), clock.monotonic()

    assert once() == once()


def test_multi_client_concurrent(clock):
    server = CloudVerifier(SyntheticBackend(clock=clock), batch_window=0.002, clock=clock)
    clients = [_mk_client(server, sid, clock) for sid in range(4)]

    def body():
        server.start()
        hs = [clock.spawn(lambda c=c: c.run(40), name=f"c{c.session}") for c in clients]
        for h in hs:
            h.join()
        server.stop()
        return {c.session: h.result() for c, h in zip(clients, hs)}

    res = clock.run(body)
    assert len(res) == 4
    assert all(r["accepted_tokens"] >= 40 for r in res.values())
    # Batched NAV should have amortized some calls.
    assert server.stats["batched_calls"] <= server.stats["nav_calls"]


def test_failover_to_local_decode_and_recovery(clock):
    """Downlink outage → NAV timeout → local decoding → re-attach."""
    server = CloudVerifier(SyntheticBackend(clock=clock), clock=clock)
    c = _mk_client(server, 9, clock, outage=(0.0, 1.2), nav_timeout=0.4)

    def body():
        server.start()
        stats = c.run(50)
        server.stop()
        return stats

    stats = clock.run(body)
    assert stats["failovers"] >= 1
    assert stats["fallback_tokens"] > 0  # offline progress was made
    assert stats["accepted_tokens"] >= 50
    assert len(stats["failover_times"]) == stats["failovers"]


def test_batched_nav_coalesces_and_isolates_sessions(clock):
    """Concurrent NAV rounds coalesce into one backend call within
    batch_window, and each session gets exactly its own result back."""
    server = CloudVerifier(EchoBackend(), batch_window=0.08, clock=clock)
    links = {sid: _fast_pair(server, sid, clock) for sid in range(3)}
    sent = {}

    def body():
        for sid, (up, dn) in links.items():
            toks = [100 * sid + j for j in range(sid + 2)]  # ragged lengths 2,3,4
            up.send(DraftFragment(sid, 1, 0, tuple(toks), (0.9,) * len(toks)))
            up.send(NavRequest(sid, 2, 0, n_tokens=len(toks)))
            sent[sid] = toks
        server.start()
        results = {sid: dn.recv(timeout=5.0) for sid, (up, dn) in links.items()}
        server.stop()
        return results

    results = clock.run(body)
    for sid, msg in results.items():
        assert isinstance(msg, NavResult)
        assert msg.n_drafted == len(sent[sid])
        assert msg.n_accepted == len(sent[sid])
        # No cross-session token leakage: correction is this session's hash.
        assert msg.correction == EchoBackend.fingerprint(sid, sent[sid])
    assert server.stats["nav_calls"] == 3
    assert server.stats["batched_calls"] < 3  # coalesced
    assert server.monitor.verifier_occupancy() > 1.0


def test_pending_nav_waits_for_proactive_drafts(clock):
    """A NAV round that outruns its pipelined uploads parks until the
    remaining drafts arrive, then dispatches."""
    server = CloudVerifier(EchoBackend(), clock=clock)
    up, dn = _fast_pair(server, 7, clock)

    def body():
        server.start()
        up.send(DraftFragment(7, 1, 0, (1, 2), (0.9, 0.9)))
        up.send(NavRequest(7, 2, 0, n_tokens=4))
        assert dn.recv(timeout=0.3) is None  # only 2 of 4 tokens buffered
        up.send(DraftFragment(7, 3, 0, (3, 4), (0.9, 0.9)))
        msg = dn.recv(timeout=5.0)
        server.stop()
        return msg

    msg = clock.run(body)
    assert msg is not None
    assert msg.n_drafted == 4
    assert msg.correction == EchoBackend.fingerprint(7, [1, 2, 3, 4])


def test_lost_draft_batch_does_not_desync_next_round(clock):
    """A round with a dropped draft_batch parks forever, but per-round
    buffering means the NEXT round still verifies its own tokens cleanly."""
    server = CloudVerifier(EchoBackend(), clock=clock)
    up, dn = _fast_pair(server, 3, clock)

    def body():
        server.start()
        # Round 1: client drafted 4 tokens but one draft_batch (2 of them) was
        # lost in transit — only [1, 2] arrive, so nav round 1 parks.
        up.send(DraftFragment(3, 1, 1, (1, 2), (0.9, 0.9)))
        up.send(NavRequest(3, 2, 1, n_tokens=4))
        assert dn.recv(timeout=0.3) is None
        # Client failed over; its reset was ALSO lost. Round 2 proceeds anyway.
        up.send(DraftFragment(3, 3, 2, (7, 8, 9), (0.9,) * 3))
        up.send(NavRequest(3, 4, 2, n_tokens=3))
        msg = dn.recv(timeout=5.0)
        server.stop()
        return msg

    msg = clock.run(body)
    assert msg is not None and msg.seq == 4
    assert msg.n_drafted == 3
    # Round 2 verified exactly its own tokens — round 1's leftovers untouched.
    assert msg.correction == EchoBackend.fingerprint(3, [7, 8, 9])


def test_duplicate_nav_request_dispatches_once(clock):
    """A retransmitted nav_request for an already-served round is dropped."""
    server = CloudVerifier(EchoBackend(), clock=clock)
    up, dn = _fast_pair(server, 5, clock)

    def body():
        server.start()
        up.send(DraftFragment(5, 1, 1, (4, 5), (0.9, 0.9)))
        up.send(NavRequest(5, 2, 1, n_tokens=2))
        first = dn.recv(timeout=5.0)
        # The duplicate arrives after the round was already verified.
        up.send(NavRequest(5, 2, 1, n_tokens=2))
        second = dn.recv(timeout=0.5)
        server.stop()
        return first, second

    first, second = clock.run(body)
    assert first is not None and first.n_drafted == 2
    assert second is None  # no double verify
    assert server.stats["nav_calls"] == 1


def test_straggler_requests_are_dropped(clock):
    """Work whose client deadline already passed is dropped, not verified."""
    server = CloudVerifier(EchoBackend(), batch_window=0.02, clock=clock)
    up, dn = _fast_pair(server, 0, clock)

    def body():
        server.start()
        clock.sleep(2.0)  # let virtual time pass so the deadline is in the past
        up.send(DraftFragment(0, 1, 0, (5, 6), (0.9, 0.9)))
        up.send(
            NavRequest(0, 2, 0, n_tokens=2, deadline=clock.monotonic() - 1.0)  # expired
        )
        got = dn.recv(timeout=0.5)
        server.stop()
        return got

    assert clock.run(body) is None  # no reply — client has failed over
    assert server.stats["dropped_stragglers"] == 1
    assert server.stats["nav_calls"] == 0


def test_admission_cap_with_fair_reinsertion(clock):
    """Oversubscribed dispatch admits max_batch and reinserts the rest."""
    server = CloudVerifier(EchoBackend(), batch_window=0.08, max_batch=2, clock=clock)
    links = {sid: _fast_pair(server, sid, clock) for sid in range(4)}

    def body():
        for sid, (up, dn) in links.items():
            up.send(DraftFragment(sid, 1, 0, (sid,), (0.9,)))
            up.send(NavRequest(sid, 2, 0, n_tokens=1))
        clock.sleep(0.3)  # let all four requests queue before dispatch starts
        server.start()
        results = {sid: dn.recv(timeout=5.0) for sid, (up, dn) in links.items()}
        server.stop()
        return results

    results = clock.run(body)
    assert all(m is not None for m in results.values())  # nothing lost
    assert all(
        m.correction == EchoBackend.fingerprint(sid, [sid])
        for sid, m in results.items()
    )
    assert max(server.monitor.verifier_batches()) <= 2  # cap respected
    assert server.stats["nav_calls"] == 4


def test_fleet_bench_smoke():
    """Fleet benchmark end-to-end on the virtual clock: deterministic
    occupancy > 1 under concurrent sessions, zero wall-clock cost."""
    import sys
    from pathlib import Path

    sys.path.insert(0, str(Path(__file__).parent.parent))
    from benchmarks.fleet_bench import run_fleet

    rep = run_fleet(
        n_sessions=4, mode="batched", tokens_per_session=25, ts=1.0,
        clock=VirtualClock(),
    )
    st = rep["stats"]
    assert len(rep["per_session_tpt"]) == 4
    assert st.verifier_batch_occupancy > 1.0
    p50, p99 = st.nav_latency_quantiles()
    assert 0 < p50 <= p99
    # Determinism: an identical virtual run reproduces the stats exactly.
    rep2 = run_fleet(
        n_sessions=4, mode="batched", tokens_per_session=25, ts=1.0,
        clock=VirtualClock(),
    )
    assert rep2["stats"] == st
    assert rep2["per_session_tpt"] == rep["per_session_tpt"]


def test_channel_serializes_batches(clock):
    """Two back-to-back sends: second delivery waits for the first (Hockney),
    with EXACT virtual timings."""
    ch = Channel(ChannelConfig(alpha=0.05, beta=0.01), clock=clock)
    ten = DraftFragment(0, 1, 0, tuple(range(10)), (0.9,) * 10)  # wire cost: 10 tokens

    def body():
        ch.send(ten)  # 0.05 + 0.1 = 0.15s
        ch.send(DraftFragment(0, 2, 0, ten.tokens, ten.confs))  # completes at 0.30s
        m1 = ch.recv(timeout=2.0)
        t1 = clock.monotonic()
        m2 = ch.recv(timeout=2.0)
        t2 = clock.monotonic()
        ch.close()
        return m1, t1, m2, t2

    m1, t1, m2, t2 = clock.run(body)
    assert m1.seq == 1 and m2.seq == 2
    assert t1 == pytest.approx(0.15)  # exact, not >= with slack
    assert t2 == pytest.approx(0.30)  # serialized, not parallel
