"""Threaded cloud-edge runtime: e2e sessions, multi-client, failover, hedging,
continuous-batched NAV (coalescing, session isolation, straggler drop)."""

import threading
import time

import pytest

from repro.runtime import (
    Channel,
    ChannelConfig,
    CloudVerifier,
    EdgeClient,
    EdgeConfig,
    SyntheticBackend,
    VerifyBackend,
)
from repro.runtime.transport import Message

TS = 0.01  # run the timing model 100× faster than real time


class EchoBackend(VerifyBackend):
    """Deterministic: accepts everything, correction = hash(session, tokens).

    Lets tests check that a *batched* verify returns each session exactly the
    result its own tokens imply — any cross-session mixup changes the hash.
    """

    @staticmethod
    def fingerprint(session, tokens):
        h = session + 1
        for t in tokens:
            h = (h * 1000003 + int(t)) % 65536
        return h

    def verify(self, session, tokens, confs):
        return len(tokens), self.fingerprint(session, tokens)


def _fast_pair(server, sid):
    up = Channel(ChannelConfig(alpha=1e-4, beta=1e-5))
    dn = Channel(ChannelConfig(alpha=1e-4, beta=1e-5))
    server.attach(sid, up, dn)
    return up, dn


def _mk_client(server, sid, ts=TS, outage=None, nav_timeout=3.0):
    up = Channel(ChannelConfig(alpha=0.02, beta=0.002, time_scale=ts))
    dn = Channel(ChannelConfig(alpha=0.01, beta=0.0005, time_scale=ts, outage=outage))
    server.attach(sid, up, dn)
    return EdgeClient(sid, up, dn, EdgeConfig(time_scale=ts, gamma=0.02, nav_timeout=nav_timeout))


def test_single_session_end_to_end():
    server = CloudVerifier(SyntheticBackend(time_scale=TS))
    server.start()
    c = _mk_client(server, 0)
    stats = c.run(60)
    server.stop()
    assert stats["accepted_tokens"] >= 60
    assert stats["nav_calls"] == stats["rounds"] + stats["failovers"]
    assert server.stats["nav_calls"] >= stats["rounds"]


def test_multi_client_concurrent():
    server = CloudVerifier(SyntheticBackend(time_scale=TS), batch_window=0.002)
    server.start()
    clients = [_mk_client(server, sid) for sid in range(4)]
    res = {}
    ths = [threading.Thread(target=lambda c=c: res.update({c.session: c.run(40)})) for c in clients]
    [t.start() for t in ths]
    [t.join(timeout=60) for t in ths]
    server.stop()
    assert len(res) == 4
    assert all(r["accepted_tokens"] >= 40 for r in res.values())
    # Batched NAV should have amortized some calls.
    assert server.stats["batched_calls"] <= server.stats["nav_calls"]


def test_failover_to_local_decode_and_recovery():
    """Downlink outage → NAV timeout → local decoding → re-attach."""
    server = CloudVerifier(SyntheticBackend(time_scale=TS))
    server.start()
    c = _mk_client(server, 9, outage=(0.0, 0.3), nav_timeout=0.2)
    stats = c.run(50)
    server.stop()
    assert stats["failovers"] >= 1
    assert stats["fallback_tokens"] > 0  # offline progress was made
    assert stats["accepted_tokens"] >= 50


def test_batched_nav_coalesces_and_isolates_sessions():
    """Concurrent NAV rounds coalesce into one backend call within
    batch_window, and each session gets exactly its own result back."""
    server = CloudVerifier(EchoBackend(), batch_window=0.08)
    links = {sid: _fast_pair(server, sid) for sid in range(3)}
    server.start()
    sent = {}
    for sid, (up, dn) in links.items():
        toks = [100 * sid + j for j in range(sid + 2)]  # ragged lengths 2,3,4
        up.send(Message("draft_batch", sid, 1, len(toks), (toks, [0.9] * len(toks))))
        up.send(Message("nav_request", sid, 2, 1, {"n_tokens": len(toks)}))
        sent[sid] = toks
    results = {sid: dn.recv(timeout=5.0) for sid, (up, dn) in links.items()}
    server.stop()
    for sid, msg in results.items():
        assert msg is not None and msg.kind == "nav_result"
        assert msg.payload["n_drafted"] == len(sent[sid])
        assert msg.payload["n_accepted"] == len(sent[sid])
        # No cross-session token leakage: correction is this session's hash.
        assert msg.payload["correction"] == EchoBackend.fingerprint(sid, sent[sid])
    assert server.stats["nav_calls"] == 3
    assert server.stats["batched_calls"] < 3  # coalesced
    assert server.monitor.verifier_occupancy() > 1.0


def test_pending_nav_waits_for_proactive_drafts():
    """A NAV round that outruns its pipelined uploads parks until the
    remaining drafts arrive, then dispatches."""
    server = CloudVerifier(EchoBackend())
    up, dn = _fast_pair(server, 7)
    server.start()
    up.send(Message("draft_batch", 7, 1, 2, ([1, 2], [0.9, 0.9])))
    up.send(Message("nav_request", 7, 2, 1, {"n_tokens": 4}))
    assert dn.recv(timeout=0.3) is None  # only 2 of 4 tokens buffered
    up.send(Message("draft_batch", 7, 3, 2, ([3, 4], [0.9, 0.9])))
    msg = dn.recv(timeout=5.0)
    server.stop()
    assert msg is not None
    assert msg.payload["n_drafted"] == 4
    assert msg.payload["correction"] == EchoBackend.fingerprint(7, [1, 2, 3, 4])


def test_lost_draft_batch_does_not_desync_next_round():
    """A round with a dropped draft_batch parks forever, but per-round
    buffering means the NEXT round still verifies its own tokens cleanly."""
    server = CloudVerifier(EchoBackend())
    up, dn = _fast_pair(server, 3)
    server.start()
    # Round 1: client drafted 4 tokens but one draft_batch (2 of them) was
    # lost in transit — only [1, 2] arrive, so nav round 1 parks.
    up.send(Message("draft_batch", 3, 1, 2, ([1, 2], [0.9, 0.9], 1)))
    up.send(Message("nav_request", 3, 2, 1, {"n_tokens": 4, "round": 1}))
    assert dn.recv(timeout=0.3) is None
    # Client failed over; its reset was ALSO lost. Round 2 proceeds anyway.
    up.send(Message("draft_batch", 3, 3, 3, ([7, 8, 9], [0.9] * 3, 2)))
    up.send(Message("nav_request", 3, 4, 1, {"n_tokens": 3, "round": 2}))
    msg = dn.recv(timeout=5.0)
    server.stop()
    assert msg is not None and msg.seq == 4
    assert msg.payload["n_drafted"] == 3
    # Round 2 verified exactly its own tokens — round 1's leftovers untouched.
    assert msg.payload["correction"] == EchoBackend.fingerprint(3, [7, 8, 9])


def test_straggler_requests_are_dropped():
    """Work whose client deadline already passed is dropped, not verified."""
    server = CloudVerifier(EchoBackend(), batch_window=0.02)
    up, dn = _fast_pair(server, 0)
    server.start()
    up.send(Message("draft_batch", 0, 1, 2, ([5, 6], [0.9, 0.9])))
    up.send(
        Message(
            "nav_request", 0, 2, 1,
            {"n_tokens": 2, "deadline": time.monotonic() - 1.0},  # already expired
        )
    )
    assert dn.recv(timeout=0.5) is None  # no reply — client has failed over
    server.stop()
    assert server.stats["dropped_stragglers"] == 1
    assert server.stats["nav_calls"] == 0


def test_admission_cap_with_fair_reinsertion():
    """Oversubscribed dispatch admits max_batch and reinserts the rest."""
    server = CloudVerifier(EchoBackend(), batch_window=0.08, max_batch=2)
    links = {sid: _fast_pair(server, sid) for sid in range(4)}
    for sid, (up, dn) in links.items():
        up.send(Message("draft_batch", sid, 1, 1, ([sid], [0.9])))
        up.send(Message("nav_request", sid, 2, 1, {"n_tokens": 1}))
    time.sleep(0.3)  # let all four requests queue before dispatch starts
    server.start()
    results = {sid: dn.recv(timeout=5.0) for sid, (up, dn) in links.items()}
    server.stop()
    assert all(m is not None for m in results.values())  # nothing lost
    assert all(
        m.payload["correction"] == EchoBackend.fingerprint(sid, [sid])
        for sid, m in results.items()
    )
    assert max(server.monitor.verifier_batches()) <= 2  # cap respected
    assert server.stats["nav_calls"] == 4


def test_fleet_bench_smoke():
    """Fleet benchmark end-to-end: occupancy > 1 under concurrent sessions."""
    import sys
    from pathlib import Path

    sys.path.insert(0, str(Path(__file__).parent.parent))
    from benchmarks.fleet_bench import run_fleet

    rep = run_fleet(n_sessions=4, mode="batched", tokens_per_session=25, ts=0.005)
    st = rep["stats"]
    assert len(rep["per_session_tpt"]) == 4
    assert st.verifier_batch_occupancy > 1.0
    p50, p99 = st.nav_latency_quantiles()
    assert 0 < p50 <= p99


def test_channel_serializes_batches():
    """Two back-to-back sends: second delivery waits for the first (Hockney)."""
    ch = Channel(ChannelConfig(alpha=0.05, beta=0.01, time_scale=1.0))
    from repro.runtime.transport import Message

    t0 = time.monotonic()
    ch.send(Message("a", 0, 1, 10, None))  # 0.05 + 0.1 = 0.15s
    ch.send(Message("b", 0, 2, 10, None))  # completes at 0.30s
    m1 = ch.recv(timeout=2.0)
    m2 = ch.recv(timeout=2.0)
    dt = time.monotonic() - t0
    ch.close()
    assert m1.kind == "a" and m2.kind == "b"
    assert dt >= 0.28  # serialized, not parallel
