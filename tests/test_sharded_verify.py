"""Sharded target verifier == the unsharded oracle, bit-for-bit.

The tentpole contract: the tensor-parallel spec-verify launch
(``repro.sharding.spec_verify``) running over a host device mesh must be
``assert_array_equal``-exact vs the unsharded one-launch entry for every
shard count — fp32 and int8 pages, GQA head splits that don't divide
evenly, non-pow2 vocabularies, ragged batches — and the dispatcher-facing
backend (``ShardedSpecVerifyBackend``) must be indistinguishable from the
unsharded fused backend through rollback/evict/CoW-fork traffic.

All random cases come from the shared strategy module (``strategies.py``);
``assert_paths_agree`` is the cross-path differential harness.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from strategies import (
    assert_paths_agree,
    assert_ragged_match,
    assert_triples_match,
    composed_logits,
    make_ragged_case,
    make_rect_case,
    ragged_geometries,
)

from repro.sharding import (
    host_mesh,
    plan_shards,
    sharded_target_logits,
    spec_verify_sharded,
    spec_verify_sharded_batched,
)

requires_mesh = pytest.mark.skipif(
    jax.device_count() < 4,
    reason="needs XLA_FLAGS=--xla_force_host_platform_device_count=4 (set in conftest.py)",
)


# --------------------------------------------------------------------------- #
# Shard planning metadata (pure, no mesh needed)
# --------------------------------------------------------------------------- #
def test_plan_shards_even_split():
    p = plan_shards(shards=4, n_heads=8, n_kv_heads=8, head_dim=16, vocab=1024)
    assert p.even_heads and p.even_kv_heads
    assert p.heads_per_shard == 2 and p.padded_heads == 8
    assert p.launch_vocab == p.vocab_per_shard * 4 >= p.padded_vocab


def test_plan_shards_uneven_heads_pad():
    p = plan_shards(shards=4, n_heads=6, n_kv_heads=3, head_dim=8, vocab=384, block_v=128)
    assert not p.even_heads and not p.even_kv_heads
    assert p.padded_heads == 8 and p.heads_per_shard == 2
    assert p.vocab_per_shard % p.block_v == 0
    assert p.launch_vocab >= p.padded_vocab >= p.vocab


def test_plan_shards_rejects_bad_gqa():
    with pytest.raises(ValueError):
        plan_shards(shards=2, n_heads=5, n_kv_heads=2, head_dim=8, vocab=256)


# --------------------------------------------------------------------------- #
# Rectangular kernel-level exactness
# --------------------------------------------------------------------------- #
@requires_mesh
@pytest.mark.parametrize("shards", [1, 2, 4])
def test_sharded_logits_bitexact_vs_composition(shards):
    """Sharded logits == jitted attention + blocked LM head, per logit."""
    B, K, H, Hkv, hd, bs, G, P, V = 2, 3, 4, 2, 8, 4, 4, 16, 384
    q, kp, vp, w, tables, lengths, tokens, nd = make_rect_case(B, K, H, Hkv, hd, bs, G, P, V)
    mesh = host_mesh(shards)
    got = sharded_target_logits(q, kp, vp, w, tables, lengths, mesh=mesh, block_v=128)
    want = composed_logits(q, kp, vp, w, tables, lengths, impl="ref", block_v=128)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))


@requires_mesh
@pytest.mark.parametrize("shards", [1, 2, 3, 4])
def test_sharded_rect_uneven_gqa_bitexact(shards):
    """H=6/Hkv=3 over 4 shards: padded head lanes stay inert, bit-for-bit."""
    from repro.kernels.spec_verify import spec_verify_fused

    B, K, H, Hkv, hd, bs, G, P, V = 2, 2, 6, 3, 8, 4, 3, 12, 384
    q, kp, vp, w, tables, lengths, tokens, nd = make_rect_case(B, K, H, Hkv, hd, bs, G, P, V, seed=7)
    mesh = host_mesh(shards)
    got = spec_verify_sharded(
        q, kp, vp, w, tables, lengths, tokens, nd, mesh=mesh, block_v=128
    )
    want = spec_verify_fused(
        q, kp, vp, w, tables, lengths, tokens, nd, impl="ref", block_v=128
    )
    assert_triples_match(got, want, ks=np.asarray(nd))


# --------------------------------------------------------------------------- #
# Ragged serving entry: the differential harness
# --------------------------------------------------------------------------- #
@requires_mesh
@pytest.mark.parametrize("quantize", [None, "int8"])
def test_differential_all_paths(quantize):
    """chain / tree / fused / batched / sharded@{1,2,4} agree on one case."""
    case = make_ragged_case([3, 1, 4], Hkv=2, gqa=1, V=256, seed=3, quantize=quantize)
    assert_paths_agree(case, impl="ref", block_v=256, shards=(1, 2, 4))


@requires_mesh
def test_differential_uneven_gqa_nonpow2_vocab():
    """GQA 3-way KV heads + V=384: sharded still bit-matches the pivot."""
    case = make_ragged_case([2, 5], Hkv=3, gqa=2, V=384, seed=11)
    assert_paths_agree(case, impl="ref", block_v=128, shards=(2, 3, 4))


@requires_mesh
@pytest.mark.parametrize("bias,expect", [(1.0, "all"), (0.0, "none")])
def test_differential_forced_accept_reject(bias, expect):
    """Forced accept/reject patterns survive every path unchanged."""
    case = make_ragged_case([3, 2], Hkv=2, gqa=1, V=256, seed=5, sharp=True, accept_bias=bias)
    pivot = assert_paths_agree(case, impl="ref", block_v=256, shards=(1, 2, 4))
    for (na, _corr, _lp), k in zip(pivot, case.ks):
        assert na == (k if expect == "all" else 0)


@requires_mesh
def test_sharded_int8_planes_travel_with_kv():
    """Int8 scale/zero planes shard along the same head axis as their pages:
    the quantized sharded launch == the quantized unsharded launch exactly."""
    from repro.kernels.spec_verify import spec_verify_fused_batched

    case = make_ragged_case([4, 2, 1], Hkv=2, gqa=2, V=256, seed=17, quantize="int8")
    pivot = spec_verify_fused_batched(
        case.q_seq, case.tok_seq, case.tables_seq, case.base,
        case.k_pages, case.v_pages, case.w,
        impl="ref", block_v=256, pad_page_id=case.sentinel_page, quant=case.quant,
    )
    for n in (2, 4):
        got = spec_verify_sharded_batched(
            case.q_seq, case.tok_seq, case.tables_seq, case.base,
            case.k_pages, case.v_pages, case.w,
            shards=n, block_v=256, pad_page_id=case.sentinel_page, quant=case.quant,
        )
        assert_ragged_match(got, pivot, exact_logp=True, label=f"int8 sharded@{n}")


@requires_mesh
@settings(max_examples=8, deadline=None)
@given(geom=ragged_geometries(), shards=st.sampled_from([1, 2, 4]))
def test_property_sharded_differential(geom, shards):
    """Random ragged sweep: the harness holds for any drawn geometry."""
    case = make_ragged_case(**geom)
    assert_paths_agree(case, impl="ref", block_v=128, shards=(shards,))


# --------------------------------------------------------------------------- #
# Backend: dispatcher-oblivious sharding
# --------------------------------------------------------------------------- #
def _twin_backends(shards, quantize=None, num_blocks=32):
    """An unsharded fused backend and a sharded one over twin pools with
    identical seeded contents; any divergence between them is a sharding bug."""
    from strategies import fused_backend

    ref, p_ref, _, _ = fused_backend(quantize, num_blocks=num_blocks)
    sh, p_sh, _, _ = fused_backend(quantize, num_blocks=num_blocks, shards=shards)
    return ref, p_ref, sh, p_sh


@requires_mesh
@pytest.mark.parametrize("shards", [1, 2, 4])
@pytest.mark.parametrize("quantize", [None, "int8"])
def test_backend_matches_unsharded(shards, quantize):
    ref, p_ref, sh, p_sh = _twin_backends(shards, quantize)
    reqs = [(0, [3, 9, 7], [0.9] * 3), (1, [5], [0.9]), (2, [1, 2, 3, 4], [0.9] * 4)]
    for s, toks, _ in reqs:
        for p in (p_ref, p_sh):
            p.create(s)
            p.append(s, 5 + s + len(toks) + 1)
    assert sh.verify_batch(reqs) == ref.verify_batch(reqs)


@requires_mesh
def test_backend_rejects_unfused():
    from repro.runtime import ShardedSpecVerifyBackend

    with pytest.raises(ValueError, match="fused"):
        ShardedSpecVerifyBackend(shards=2, fused=False, lm_head=np.ones((4, 8), np.float32))


@requires_mesh
def test_backend_rollback_recycle_matches_unsharded():
    """Rollback frees a page, a foreign session dirties it, the session
    regrows: per-shard watermarks must refill exactly like the oracle."""
    ref, p_ref, sh, p_sh = _twin_backends(2)
    for backend, pool in ((ref, p_ref), (sh, p_sh)):
        pool.create(0)
        pool.append(0, 9)
        backend.ensure_kv(0)
        pool.rollback(0, 6)  # trailing page freed
        pool.create(99)  # foreign session recycles it...
        pool.append(99, pool.block_size)
        junk = jnp.full((1, pool.block_size, pool.n_kv_heads, pool.head_dim), 7.5)
        pool.fill(99, 0, junk, -junk)  # ...and dirties it
        pool.release(99)
        pool.append(0, 3)  # regrow to 9
    reqs = [(0, [3, 9, 7], [0.9] * 3)]
    assert sh.verify_batch(reqs) == ref.verify_batch(reqs)
    np.testing.assert_array_equal(np.asarray(p_sh.k_pages), np.asarray(p_ref.k_pages))


@requires_mesh
def test_backend_evict_rematerialize_matches_unsharded():
    """Evicted-then-resumed sessions re-prefill; shards stay in lockstep."""
    ref, p_ref, sh, p_sh = _twin_backends(2)
    for backend, pool in ((ref, p_ref), (sh, p_sh)):
        pool.create(0)
        pool.append(0, 6)
        backend.ensure_kv(0)
        pool.evict(0)
        pool.create(1)  # pages recycled + dirtied in between
        pool.append(1, 8)
        junk = jnp.full((1, 8, pool.n_kv_heads, pool.head_dim), -3.25)
        pool.fill(1, 0, junk, junk)
        pool.release(1)
        pool.append(0, 6)  # comeback re-prefill
    reqs = [(0, [1, 2], [0.9] * 2)]
    assert sh.verify_batch(reqs) == ref.verify_batch(reqs)


@requires_mesh
def test_backend_cow_fork_matches_unsharded():
    """CoW-forked sessions share prefix pages; the first divergent write
    copies — identically on both backends, so verdicts stay equal."""
    ref, p_ref, sh, p_sh = _twin_backends(2)
    out = {}
    for name, (backend, pool) in (("ref", (ref, p_ref)), ("sh", (sh, p_sh))):
        pool.create(0)
        pool.append(0, 6)  # one full page + a half-filled shared page
        backend.ensure_kv(0)
        pool.fork(0, 1)  # CoW fork: session 1 shares both pages
        assert pool.filled(1) == 6  # watermark inherited per shard
        pool.append(1, 2)  # grow into the shared half page; fill CoW-copies
        out[name] = backend.verify_batch([(0, [3, 9], [0.9] * 2), (1, [5, 1], [0.9] * 2)])
        assert pool.stats["cow_copies"] >= 1
    assert out["sh"] == out["ref"]


@requires_mesh
def test_serve_round_trip_stream_invariant_under_shards():
    """Full EdgeClient -> CloudVerifier flow on the virtual clock: the
    committed token stream is identical at 1, 2, and 4 shards (the router
    and dispatcher cannot observe the shard count)."""
    from repro.models.paged_kv import PagedKVPool
    from repro.runtime import ShardedSpecVerifyBackend
    from repro.runtime.client import EdgeClient, EdgeConfig
    from repro.runtime.server import CloudVerifier
    from repro.runtime.simclock import VirtualClock
    from repro.runtime.transport import Channel, ChannelConfig

    H, hd, V = 2, 16, 512
    w = np.asarray(jax.random.normal(jax.random.PRNGKey(1), (H * hd, V)) * 6, np.float32)

    def query_fn(session, tokens):
        k = jax.random.fold_in(jax.random.PRNGKey(2), session * 997 + len(tokens))
        return np.asarray(jax.random.normal(k, (len(tokens) + 1, H, hd)), np.float32)

    def once(shards):
        clock = VirtualClock()
        pool = PagedKVPool(num_blocks=256, block_size=8, n_layers=1, n_kv_heads=H, head_dim=hd)
        backend = ShardedSpecVerifyBackend(
            shards=shards, kv_pool=pool, query_fn=query_fn, lm_head=w, impl="ref", block_v=512
        )
        server = CloudVerifier(backend, kv_pool=pool, clock=clock)
        up = Channel(ChannelConfig(alpha=0.02, beta=0.002), "up0", clock=clock)
        dn = Channel(ChannelConfig(alpha=0.01, beta=0.0005), "dn0", clock=clock)
        server.attach(0, up, dn)
        c = EdgeClient(0, up, dn, EdgeConfig(gamma=0.02, nav_timeout=3.0))

        def body():
            server.start()
            stats = c.run(32)
            server.stop()
            return stats

        stats = clock.run(body)
        return list(c.tokens), stats["accepted_tokens"]

    tokens1, acc1 = once(1)
    assert acc1 >= 32 and len(tokens1) == acc1
    for n in (2, 4):
        tokens_n, acc_n = once(n)
        assert (tokens_n, acc_n) == (tokens1, acc1), f"stream diverged at shards={n}"


@requires_mesh
def test_fleet_bench_stream_invariant_under_shards():
    """fleet_bench's sharded tensor backend: committed streams at 1/2/4
    shards are identical — the coalescing dispatcher is shard-oblivious."""
    import pathlib
    import sys

    sys.path.insert(0, str(pathlib.Path(__file__).resolve().parents[1] / "benchmarks"))
    from fleet_bench import run_fleet

    from repro.runtime.simclock import VirtualClock

    def once(shards):
        report = run_fleet(
            n_sessions=3, tokens_per_session=16, clock=VirtualClock(), seed=3, shards=shards
        )
        assert all(len(s) >= 16 for s in report["streams"].values())
        return report["streams"]

    base = once(1)
    for n in (2, 4):
        assert once(n) == base, f"fleet stream diverged at shards={n}"


def test_host_mesh_errors_when_too_few_devices():
    with pytest.raises(RuntimeError, match="xla_force_host_platform_device_count"):
        host_mesh(jax.device_count() + 1)
