"""Optimizers, schedules, gradient compression."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.optim import (
    adafactor,
    adamw,
    apply_updates,
    clip_by_global_norm,
    compress_int8,
    compressed_gradient_transform,
    cosine_schedule,
    decompress_int8,
    init_error_feedback,
    wsd_schedule,
)
from repro.optim.compression import ErrorFeedbackState


@pytest.mark.parametrize("make_opt", [lambda: adamw(0.1), lambda: adafactor(0.5)])
def test_optimizer_descends_quadratic(make_opt):
    opt = make_opt()
    params = {"w": jnp.array([3.0, -2.0]), "m": jnp.ones((4, 4)) * 2}

    def loss(p):
        return jnp.sum(p["w"] ** 2) + jnp.sum(p["m"] ** 2)

    state = opt.init(params)
    l0 = float(loss(params))
    for i in range(60):
        g = jax.grad(loss)(params)
        upd, state = opt.update(g, state, params, jnp.int32(i))
        params = apply_updates(params, upd)
    assert float(loss(params)) < 0.05 * l0


def test_adafactor_state_is_factored():
    opt = adafactor(0.1)
    params = {"big": jnp.zeros((64, 128))}
    st_ = opt.init(params)
    assert st_["big"]["vr"].shape == (64,)
    assert st_["big"]["vc"].shape == (128,)


def test_clip_by_global_norm():
    g = {"a": jnp.ones((10,)) * 100.0}
    clipped, norm = clip_by_global_norm(g, 1.0)
    assert float(norm) == pytest.approx(np.sqrt(10) * 100, rel=1e-5)
    cn = float(jnp.linalg.norm(clipped["a"]))
    assert cn == pytest.approx(1.0, rel=1e-5)


def test_wsd_schedule_phases():
    s = wsd_schedule(1.0, warmup_steps=10, stable_steps=20, decay_steps=10, final_frac=0.01)
    assert float(s(0)) == 0.0
    assert float(s(10)) == pytest.approx(1.0)
    assert float(s(25)) == pytest.approx(1.0)
    assert float(s(40)) == pytest.approx(0.01, rel=1e-3)


def test_cosine_schedule_monotone_decay():
    s = cosine_schedule(1.0, warmup_steps=5, total_steps=50)
    vals = [float(s(i)) for i in range(5, 51, 5)]
    assert all(a >= b - 1e-9 for a, b in zip(vals, vals[1:]))


@settings(max_examples=30, deadline=None)
@given(st.lists(st.floats(-100, 100, allow_nan=False, width=32), min_size=1, max_size=64))
def test_int8_roundtrip_bounded_error(xs):
    x = jnp.array(xs, jnp.float32)
    q, scale = compress_int8(x)
    err = jnp.abs(decompress_int8(q, scale) - x)
    assert float(err.max()) <= float(scale) / 2 + 1e-6


def test_error_feedback_preserves_signal():
    """Sum of dequantized grads + final residual == sum of true grads."""
    rng = np.random.default_rng(0)
    grads_seq = [{"w": jnp.asarray(rng.normal(size=(32,)), jnp.float32)} for _ in range(20)]
    ef = init_error_feedback(grads_seq[0])
    total_true = jnp.zeros((32,))
    total_deq = jnp.zeros((32,))
    for g in grads_seq:
        deq, ef = compressed_gradient_transform(g, ef)
        total_true += g["w"]
        total_deq += deq["w"]
    np.testing.assert_allclose(np.asarray(total_deq + ef.residual["w"]), np.asarray(total_true), atol=1e-4)
