"""Paged KV block pool: refcount/CoW/rollback/eviction invariants.

The pool invariant under every test: for each physical page, its refcount
equals the number of session block tables referencing it, and free + used
== num_blocks.  CoW divergence, rollback page release, LRU reuse order, and
eviction-under-pressure are the behaviours the serving dispatcher builds on.
"""

import jax.numpy as jnp
import numpy as np
import pytest

from repro.models.paged_kv import BlockPoolExhausted, PagedKVPool


def _check_invariants(pool: PagedKVPool) -> None:
    counted = np.zeros(pool.num_blocks, np.int32)
    for t in pool.tables.values():
        for page in t.blocks:
            counted[page] += 1
    np.testing.assert_array_equal(counted, pool.refcounts)
    assert pool.free_blocks + pool.used_blocks == pool.num_blocks
    assert set(pool._free).isdisjoint(
        p for t in pool.tables.values() for p in t.blocks
    )
    # The O(1) resident counter must agree with a full recount.
    assert pool.resident_sessions == sum(1 for t in pool.tables.values() if t.blocks)


def test_refcount_fork_free_invariants():
    pool = PagedKVPool(num_blocks=16, block_size=4)
    pool.create(0)
    pool.append(0, 10)  # 3 pages (one partial)
    _check_invariants(pool)
    pool.fork(0, 1)
    pool.fork(0, 2)
    _check_invariants(pool)
    assert pool.used_blocks == 3  # forks allocate nothing
    assert pool.shared_blocks() == 3
    assert all(pool.refcounts[p] == 3 for p in pool.tables[0].blocks)
    pool.release(1)
    _check_invariants(pool)
    assert pool.used_blocks == 3  # still referenced by 0 and 2
    pool.release(0)
    pool.release(2)
    _check_invariants(pool)
    assert pool.used_blocks == 0 and pool.free_blocks == 16


def test_cow_divergence_after_shared_prefix_write():
    """First append into a shared partial tail page copies it; the parent's
    view of the prefix must be unchanged and full pages stay shared."""
    pool = PagedKVPool(num_blocks=8, block_size=4, n_layers=1, n_kv_heads=1, head_dim=2)
    pool.create(0)
    k0 = jnp.arange(1 * 6 * 1 * 2, dtype=jnp.float32).reshape(1, 6, 1, 2)
    pool.write(0, k0, k0 * 10)  # 6 tokens: one full + one partial page
    pool.fork(0, 1)
    before = np.asarray(pool.k_pages).copy()
    parent_tail = pool.tables[0].blocks[-1]

    k1 = jnp.full((1, 1, 1, 2), 99.0)
    pool.write(1, k1, k1)  # child's first write into the shared tail
    _check_invariants(pool)
    assert pool.stats["cow_copies"] == 1
    assert pool.tables[1].blocks[0] == pool.tables[0].blocks[0]  # full page shared
    child_tail = pool.tables[1].blocks[-1]
    assert child_tail != parent_tail  # tail diverged
    # Parent's pages are untouched by the child's write.
    np.testing.assert_array_equal(np.asarray(pool.k_pages)[:, parent_tail], before[:, parent_tail])
    # Child's copied tail carries the shared prefix slots plus the new token.
    got = np.asarray(pool.k_pages)[0, child_tail]
    np.testing.assert_array_equal(got[:2], np.asarray(k0)[0, 4:6])
    np.testing.assert_array_equal(got[2], np.asarray(k1)[0, 0])


def test_rollback_frees_pages():
    pool = PagedKVPool(num_blocks=8, block_size=4)
    pool.create(0)
    pool.append(0, 13)  # 4 pages
    assert pool.used_blocks == 4
    dropped = pool.rollback(0, 5)  # keep 2 pages
    _check_invariants(pool)
    assert dropped == 2 and pool.used_blocks == 2 and pool.length(0) == 5
    # Rollback across a fork only drops THIS session's references.
    pool.fork(0, 1)
    pool.append(1, 7)  # CoW tail + one new page
    shared_full = pool.tables[0].blocks[0]
    assert pool.rollback(1, 0) == 3
    _check_invariants(pool)
    assert pool.refcounts[shared_full] == 1  # parent still holds it
    assert pool.length(0) == 5  # parent untouched
    with pytest.raises(ValueError):
        pool.rollback(0, 6)  # cannot roll forward


def test_eviction_under_pressure():
    pool = PagedKVPool(num_blocks=4, block_size=4)
    pool.create(0)
    pool.append(0, 8)
    pool.create(1)
    pool.append(1, 8)
    assert pool.free_blocks == 0
    with pytest.raises(BlockPoolExhausted):
        pool.append(1, 4)
    # Session 0 is least-recently touched; exclusion protects it.
    assert pool.evict_lru(exclude=[0, 1]) is None
    assert pool.evict_lru(exclude=[1]) == 0
    _check_invariants(pool)
    assert pool.length(0) == 0 and pool.tables[0].blocks == []
    pool.append(1, 4)  # now backed by the reclaimed pages
    _check_invariants(pool)
    assert pool.stats["evictions"] == 1


def test_flat_reservation_semantics():
    """Reserved (flat-baseline) tables: up-front pages, no CoW, no free."""
    pool = PagedKVPool(num_blocks=8, block_size=4)
    pool.create(0)
    pool.reserve(0, 16)  # 4 pages immediately
    assert pool.used_blocks == 4 and pool.length(0) == 0
    pool.append(0, 10)
    assert pool.used_blocks == 4  # growth consumes the reservation
    assert pool.rollback(0, 2) == 0  # flat caches never return pages
    assert pool.used_blocks == 4
    with pytest.raises(BlockPoolExhausted):
        pool.append(0, 15)  # beyond the reservation
    pool.create(1)
    with pytest.raises(BlockPoolExhausted):
        pool.reserve(1, 32)  # 8 pages > 4 free


def test_lru_free_list_reuse_order():
    pool = PagedKVPool(num_blocks=8, block_size=1)
    pool.create(0)
    pool.append(0, 8)
    pages = list(pool.tables[0].blocks)
    pool.rollback(0, 6)  # frees pages[7] then pages[6]
    pool.rollback(0, 4)  # then pages[5], pages[4]
    pool.create(1)
    pool.append(1, 2)
    # Oldest-freed pages are reused first.
    assert pool.tables[1].blocks == [pages[7], pages[6]]


def test_blocks_needed_counts_cow_copy():
    pool = PagedKVPool(num_blocks=8, block_size=4)
    pool.create(0)
    pool.append(0, 6)
    pool.fork(0, 1)
    # Appending 1 token into the shared partial tail needs the CoW page.
    assert pool.blocks_needed(1, 1) == 1
    assert pool.blocks_needed(1, 2) == 1  # fills the copied tail exactly
    assert pool.blocks_needed(1, 3) == 2  # copy + one fresh page
    free = pool.free_blocks
    pool.append(1, 4)
    assert free - pool.free_blocks == 2


def test_engine_sim_tpt_identical_with_pool():
    """Paged accounting must not perturb the simulated timing model."""
    from repro.core.pipeline import (
        ChannelModel,
        CloudModel,
        EdgeModel,
        PipelineEngine,
        SyntheticSource,
        make_framework,
    )

    def run(pool):
        eng = PipelineEngine(
            make_framework("pipesd", autotune=False),
            ChannelModel(),
            CloudModel(),
            EdgeModel(),
            SyntheticSource(seed=5),
            seed=9,
            kv_pool=pool,
        )
        return eng.run(200)

    base = run(None)
    paged = run(PagedKVPool(num_blocks=256, block_size=16, bytes_per_token=1024))
    assert paged.tpt == base.tpt and paged.rounds == base.rounds
    assert paged.kv_resident_bytes and base.kv_resident_bytes == []
    assert paged.peak_kv_resident_bytes > 0


@pytest.mark.slow
def test_fleet_paged_serves_more_sessions_than_flat():
    """Fixed pool budget: paged admits the whole fleet where flat refuses
    half, with pool bookkeeping far below the 5% TPT-impact bound."""
    from benchmarks.fleet_bench import compare_kv

    reps = compare_kv(n_sessions=8, tokens_per_session=30)
    assert reps["flat"]["n_attached"] == 4  # budget fits 4 max_len reservations
    assert reps["paged"]["n_attached"] == 8
    assert reps["paged"]["kv_max_clients"] > reps["flat"]["n_attached"]
    assert reps["paged"]["failovers"] == 0
    st = reps["paged"]["stats"]
    assert 0 < st.kv_bytes_per_session < reps["flat"]["stats"].kv_bytes_per_session
    assert reps["paged_matched"]["kv_overhead_frac"] < 0.05


# --------------------------------------------------------- sentinel page --


def test_sentinel_page_never_allocated_and_zero_filled():
    """The pad sentinel (id num_blocks) is a real zero page no session owns."""
    pool = PagedKVPool(num_blocks=4, block_size=4, n_layers=1, n_kv_heads=2, head_dim=8)
    assert pool.sentinel_page == pool.num_blocks
    assert pool.k_pages.shape[1] == pool.num_blocks + 1
    for s in range(4):  # exhaust the whole allocatable pool
        pool.create(s)
        pool.append(s, pool.block_size)
    owned = {p for t in pool.tables.values() for p in t.blocks}
    assert pool.sentinel_page not in owned
    assert pool.sentinel_page not in pool._free
    with pytest.raises(BlockPoolExhausted):
        pool.append(0, 1)
    assert bool((pool.k_pages[:, pool.sentinel_page] == 0).all())
    assert bool((pool.v_pages[:, pool.sentinel_page] == 0).all())
    _check_invariants(pool)


def test_table_pads_with_sentinel_by_default():
    pool = PagedKVPool(num_blocks=4, block_size=4, n_layers=1, n_kv_heads=2, head_dim=8)
    pool.create(0)
    pool.append(0, 6)
    tab = pool.table(0, pad_to=4)
    np.testing.assert_array_equal(tab[2:], pool.sentinel_page)
    # Explicit pad_id still honoured (legacy pad-with-0 callers).
    assert pool.table(0, pad_to=4, pad_id=0)[-1] == 0


# ------------------------------------------------- materialized watermark --


def _wm_pool():
    return PagedKVPool(num_blocks=8, block_size=4, n_layers=1, n_kv_heads=1, head_dim=2)


def _tok(n, value=1.0):
    return jnp.full((1, n, 1, 2), value, jnp.float32)


def test_fill_advances_watermark_and_rollback_lowers_it():
    """Regrown slots after a rollback may land in recycled physical pages —
    the watermark must expose them as unmaterialized."""
    pool = _wm_pool()
    pool.create(0)
    pool.append(0, 10)
    assert pool.filled(0) == 0  # metadata append materializes nothing
    pool.fill(0, 0, _tok(10), _tok(10))
    assert pool.filled(0) == 10
    pool.rollback(0, 5)
    assert pool.filled(0) == 5
    pool.append(0, 7)  # regrow to 12, possibly into recycled pages
    assert pool.filled(0) == 5
    pool.fill(0, 5, _tok(7), _tok(7))
    assert pool.filled(0) == 12


def test_fill_gap_does_not_advance_watermark():
    pool = _wm_pool()
    pool.create(0)
    pool.append(0, 8)
    pool.fill(0, 4, _tok(2), _tok(2))  # ahead of the watermark: hole at [0, 4)
    assert pool.filled(0) == 0
    pool.fill(0, 0, _tok(4), _tok(4))  # plug the hole
    assert pool.filled(0) == 4  # conservative: [4, 6) must be refilled


def test_watermark_zeroed_by_evict_and_dies_with_release():
    pool = _wm_pool()
    pool.create(0)
    pool.write(0, _tok(6), _tok(6))  # append + fill -> watermark 6
    assert pool.filled(0) == 6
    pool.evict(0)
    assert pool.filled(0) == 0
    pool.append(0, 6)  # comeback: slots exist but hold recycled content
    assert pool.filled(0) == 0
    pool.release(0)
    pool.create(0)  # reused session id: no inherited watermark
    pool.append(0, 6)
    assert pool.filled(0) == 0


def test_fork_inherits_watermark():
    """A child sees the parent's physical pages, so the parent's
    materialized prefix is materialized for the child too."""
    pool = _wm_pool()
    pool.create(0)
    pool.write(0, _tok(6), _tok(6))
    pool.fork(0, 1)
    assert pool.filled(1) == 6


def test_fill_cow_diverges_shared_pages():
    """fill() through a forked table must never mutate the sibling's view
    (REVIEW: in-place fill corrupted siblings under session-dependent KV)."""
    pool = _wm_pool()
    pool.create(0)
    k0 = jnp.arange(12, dtype=jnp.float32).reshape(1, 6, 1, 2)
    pool.write(0, k0, k0 * 10)
    pool.fork(0, 1)
    before = np.asarray(pool.k_pages).copy()
    parent_pages = list(pool.tables[0].blocks)
    k1 = _tok(6, 99.0)
    pool.fill(1, 0, k1, k1)  # session-dependent overwrite of the shared prefix
    _check_invariants(pool)
    assert pool.stats["cow_copies"] == 2  # both shared pages diverged
    assert all(a != b for a, b in zip(parent_pages, pool.tables[1].blocks))
    for p in parent_pages:  # parent's view is untouched
        np.testing.assert_array_equal(np.asarray(pool.k_pages)[:, p], before[:, p])
    got = np.concatenate(
        [np.asarray(pool.k_pages)[0, pg] for pg in pool.tables[1].blocks]
    )[:6]
    np.testing.assert_array_equal(got, np.asarray(k1)[0])


# ---------------------------------------------------- write dtype boundary --


def test_write_casts_mismatched_dtype_at_boundary():
    """f32 writes into a bf16 pool cast explicitly — no scatter FutureWarning,
    and the byte accounting invariant holds against the real buffers."""
    import warnings

    pool = PagedKVPool(
        num_blocks=4, block_size=4, n_layers=2, n_kv_heads=2, head_dim=8,
        dtype=jnp.bfloat16,
    )
    pool.create(0)
    rng = np.random.default_rng(3)
    k = jnp.asarray(rng.normal(size=(2, 5, 2, 8)), jnp.float32)
    with warnings.catch_warnings():
        warnings.simplefilter("error")
        pool.write(0, k, k + 1)
    assert pool.k_pages.dtype == jnp.bfloat16
    assert pool.tensor_nbytes() == (pool.num_blocks + 1) * pool.bytes_per_block


def test_write_rejects_bad_dtypes():
    pool = PagedKVPool(num_blocks=4, block_size=4, n_layers=1, n_kv_heads=2, head_dim=8)
    pool.create(0)
    k = jnp.zeros((1, 2, 2, 8), jnp.float32)
    with pytest.raises(TypeError, match="floating"):
        pool.write(0, k.astype(jnp.int32), k.astype(jnp.int32))
    with pytest.raises(TypeError, match="mismatch"):
        pool.write(0, k, k.astype(jnp.bfloat16))


# ------------------------------------------------------------ int8 pages --


def _gather_dequant(pages, scale, zero, tab, length, block_size):
    out = []
    for t in range(length):
        pg, sl = int(tab[t // block_size]), t % block_size
        out.append(
            PagedKVPool.dequantize_kv(pages[:, pg, sl], scale[:, pg, sl], zero[:, pg, sl])
        )
    return jnp.stack(out, axis=1)


def test_int8_pool_roundtrip_within_error_bound():
    """Quantize-on-write then dequant stays within (max-min)/510 per element."""
    rng = np.random.default_rng(0)
    pool = PagedKVPool(
        num_blocks=6, block_size=4, n_layers=2, n_kv_heads=2, head_dim=16,
        quantize="int8",
    )
    pool.create(0)
    k = jnp.asarray(4.0 * rng.normal(size=(2, 10, 2, 16)), jnp.float32)
    v = jnp.asarray(rng.normal(size=(2, 10, 2, 16)), jnp.float32)
    pool.write(0, k, v)
    assert pool.k_pages.dtype == jnp.int8
    tab = pool.table(0, pad_to=4)
    for ref, pages, scale, zero in (
        (k, pool.k_pages, pool.k_scale, pool.k_zero),
        (v, pool.v_pages, pool.v_scale, pool.v_zero),
    ):
        hat = _gather_dequant(pages, scale, zero, tab, 10, pool.block_size)
        bound = (jnp.max(ref, -1) - jnp.min(ref, -1)) / 510.0 + 1e-6
        assert bool(jnp.all(jnp.max(jnp.abs(hat - ref), -1) <= bound))
    # int8 accounting: payload + two f32 params per token-head, k and v.
    assert pool.bytes_per_token == 2 * 2 * 2 * (16 + 8)
    assert pool.tensor_nbytes() == (pool.num_blocks + 1) * pool.bytes_per_block


def test_int8_cow_copies_quant_params():
    """CoW divergence must copy scale/zero pages along with the payload."""
    rng = np.random.default_rng(1)
    pool = PagedKVPool(
        num_blocks=8, block_size=4, n_layers=1, n_kv_heads=1, head_dim=8,
        quantize="int8",
    )
    pool.create(0)
    k = jnp.asarray(rng.normal(size=(1, 6, 1, 8)), jnp.float32)
    pool.write(0, k, k)
    pool.fork(0, 1)
    extra = jnp.asarray(rng.normal(size=(1, 1, 1, 8)), jnp.float32)
    pool.write(1, extra, extra)  # CoW-copies the shared tail page
    tab0, tab1 = pool.table(0), pool.table(1)
    assert tab0[1] != tab1[1]
    # Parent's tokens 4..5 readable identically through either table.
    a = _gather_dequant(pool.k_pages, pool.k_scale, pool.k_zero, tab0, 6, 4)
    b = _gather_dequant(pool.k_pages, pool.k_scale, pool.k_zero, tab1, 6, 4)
    np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    _check_invariants(pool)


def test_pool_rejects_unknown_quantize_mode():
    with pytest.raises(ValueError, match="quantize"):
        PagedKVPool(num_blocks=4, block_size=4, quantize="fp4")


# --------------------------------------------------------------------------- #
# Per-shard layout: the partitioned pool behind the sharded verifier
# --------------------------------------------------------------------------- #
def _mesh2():
    import jax

    if jax.device_count() < 2:
        pytest.skip("needs a multi-device host platform (conftest sets XLA_FLAGS)")
    from repro.sharding.shardctx import host_mesh

    return host_mesh(2)


def test_shard_metadata_even_uneven():
    even = PagedKVPool(num_blocks=4, block_size=4, n_layers=1, n_kv_heads=4, head_dim=2)
    assert even.shard_axes(1) and even.shard_axes(2) and even.shard_axes(4)
    assert not even.shard_axes(3)
    kspec, planes = even.shard_spec(2)
    assert tuple(kspec) == (None, None, None, "model", None)
    assert tuple(planes) == (None, None, None, "model")
    # Uneven head counts (and shards=1) replicate.
    assert tuple(even.shard_spec(3)[0]) == (None, None, None, None, None)
    assert tuple(even.shard_spec(1)[0]) == (None, None, None, None, None)
    with pytest.raises(ValueError, match="shards"):
        even.shard_axes(0)
    meta = PagedKVPool(num_blocks=4, block_size=4)  # metadata mode: no heads
    assert not meta.shard_axes(2)


def test_place_on_mesh_partitions_head_axis():
    """Each device holds only its Hkv/shards head slice of every page, the
    sentinel page included — so per-shard sentinel padding stays valid."""
    mesh = _mesh2()
    pool = PagedKVPool(num_blocks=4, block_size=4, n_layers=1, n_kv_heads=2, head_dim=2)
    pool.create(0)
    k = jnp.arange(1 * 6 * 2 * 2, dtype=jnp.float32).reshape(1, 6, 2, 2)
    pool.write(0, k, -k)
    host_before = np.asarray(pool.k_pages)
    spec = pool.place_on_mesh(mesh)
    assert tuple(spec) == (None, None, None, "model", None)
    shards = pool.k_pages.addressable_shards
    assert len(shards) == 2
    for i, sh in enumerate(shards):
        data = np.asarray(sh.data)
        assert data.shape == (1, pool.num_blocks + 1, 4, 1, 2)  # half the heads
        np.testing.assert_array_equal(data[..., 0, :], host_before[..., i, :])
        assert not data[:, pool.sentinel_page].any()  # sentinel zero per shard
    # Values round-trip unchanged through the placement.
    np.testing.assert_array_equal(np.asarray(pool.k_pages), host_before)


def test_place_on_mesh_uneven_heads_replicates():
    mesh = _mesh2()
    pool = PagedKVPool(num_blocks=4, block_size=4, n_layers=1, n_kv_heads=3, head_dim=2)
    spec = pool.place_on_mesh(mesh)
    assert tuple(spec) == (None, None, None, None, None)
    for sh in pool.k_pages.addressable_shards:
        assert sh.data.shape == pool.k_pages.shape  # full copy per device


def test_place_on_mesh_metadata_pool_is_noop():
    mesh = _mesh2()
    pool = PagedKVPool(num_blocks=4, block_size=4)
    assert pool.place_on_mesh(mesh) is not None and pool.k_pages is None


def test_sharded_pool_refcount_cow_rollback_invariants():
    """The metadata machine is untouched by placement: fork/CoW/rollback/
    evict keep every invariant, and fills after placement land sharded."""
    mesh = _mesh2()
    pool = PagedKVPool(num_blocks=8, block_size=4, n_layers=1, n_kv_heads=2, head_dim=2)
    pool.place_on_mesh(mesh)
    pool.create(0)
    k = jnp.ones((1, 6, 2, 2), jnp.float32)
    pool.write(0, k, -k)  # fill through the sharded buffers
    _check_invariants(pool)
    assert pool.filled(0) == 6
    pool.fork(0, 1)
    _check_invariants(pool)
    assert pool.filled(1) == 6  # watermark inherited under placement
    extra = jnp.full((1, 1, 2, 2), 2.0, jnp.float32)
    pool.write(1, extra, extra)  # CoW copy of the shared tail page
    _check_invariants(pool)
    assert pool.stats["cow_copies"] == 1
    assert pool.tables[0].blocks[-1] != pool.tables[1].blocks[-1]
    # Parent prefix readable and intact through the sharded buffers.
    page0 = np.asarray(pool.k_pages)[0, pool.tables[0].blocks[0]]
    np.testing.assert_array_equal(page0, np.ones((4, 2, 2), np.float32))
    n_freed = pool.rollback(0, 2)
    _check_invariants(pool)
    assert n_freed == 1 and pool.filled(0) == 2  # watermark clamped per shard
    pool.evict(1)
    _check_invariants(pool)
    assert pool.filled(1) == 0
    pool.release(0)
    _check_invariants(pool)


def test_resident_bytes_per_shard_tracks_lifecycle():
    """Per-shard footprint = resident_bytes/shards on an even split, and it
    moves with append/rollback exactly like the unsharded accounting."""
    pool = PagedKVPool(num_blocks=8, block_size=4, n_layers=1, n_kv_heads=2, head_dim=2)
    pool.create(0)
    pool.append(0, 10)  # 3 pages
    assert pool.resident_bytes_per_shard(1) == pool.resident_bytes()
    assert pool.resident_bytes_per_shard(2) == pool.resident_bytes() // 2
    before = pool.resident_bytes_per_shard(2)
    pool.rollback(0, 4)  # frees 2 pages
    after = pool.resident_bytes_per_shard(2)
    assert after == before - 2 * pool.bytes_per_block // 2
    # Uneven head counts replicate: each shard carries the full footprint.
    odd = PagedKVPool(num_blocks=8, block_size=4, n_layers=1, n_kv_heads=3, head_dim=2)
    odd.create(0)
    odd.append(0, 4)
    assert odd.resident_bytes_per_shard(2) == odd.resident_bytes()


def test_int8_quant_planes_shard_with_their_pages():
    mesh = _mesh2()
    rng = np.random.default_rng(3)
    pool = PagedKVPool(
        num_blocks=4, block_size=4, n_layers=1, n_kv_heads=2, head_dim=4,
        quantize="int8",
    )
    pool.create(0)
    k = jnp.asarray(rng.normal(size=(1, 6, 2, 4)), jnp.float32)
    pool.write(0, k, -k)
    planes_before = np.asarray(pool.k_scale)
    pool.place_on_mesh(mesh)
    for buf, want_heads in ((pool.k_pages, 1), (pool.k_scale, 1), (pool.v_zero, 1)):
        shards = buf.addressable_shards
        assert len(shards) == 2 and shards[0].data.shape[3] == want_heads
    np.testing.assert_array_equal(np.asarray(pool.k_scale), planes_before)
