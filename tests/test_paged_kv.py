"""Paged KV block pool: refcount/CoW/rollback/eviction invariants.

The pool invariant under every test: for each physical page, its refcount
equals the number of session block tables referencing it, and free + used
== num_blocks.  CoW divergence, rollback page release, LRU reuse order, and
eviction-under-pressure are the behaviours the serving dispatcher builds on.
"""

import jax.numpy as jnp
import numpy as np
import pytest

from repro.models.paged_kv import BlockPoolExhausted, PagedKVPool


def _check_invariants(pool: PagedKVPool) -> None:
    counted = np.zeros(pool.num_blocks, np.int32)
    for t in pool.tables.values():
        for page in t.blocks:
            counted[page] += 1
    np.testing.assert_array_equal(counted, pool.refcounts)
    assert pool.free_blocks + pool.used_blocks == pool.num_blocks
    assert set(pool._free).isdisjoint(
        p for t in pool.tables.values() for p in t.blocks
    )
    # The O(1) resident counter must agree with a full recount.
    assert pool.resident_sessions == sum(1 for t in pool.tables.values() if t.blocks)


def test_refcount_fork_free_invariants():
    pool = PagedKVPool(num_blocks=16, block_size=4)
    pool.create(0)
    pool.append(0, 10)  # 3 pages (one partial)
    _check_invariants(pool)
    pool.fork(0, 1)
    pool.fork(0, 2)
    _check_invariants(pool)
    assert pool.used_blocks == 3  # forks allocate nothing
    assert pool.shared_blocks() == 3
    assert all(pool.refcounts[p] == 3 for p in pool.tables[0].blocks)
    pool.release(1)
    _check_invariants(pool)
    assert pool.used_blocks == 3  # still referenced by 0 and 2
    pool.release(0)
    pool.release(2)
    _check_invariants(pool)
    assert pool.used_blocks == 0 and pool.free_blocks == 16


def test_cow_divergence_after_shared_prefix_write():
    """First append into a shared partial tail page copies it; the parent's
    view of the prefix must be unchanged and full pages stay shared."""
    pool = PagedKVPool(num_blocks=8, block_size=4, n_layers=1, n_kv_heads=1, head_dim=2)
    pool.create(0)
    k0 = jnp.arange(1 * 6 * 1 * 2, dtype=jnp.float32).reshape(1, 6, 1, 2)
    pool.write(0, k0, k0 * 10)  # 6 tokens: one full + one partial page
    pool.fork(0, 1)
    before = np.asarray(pool.k_pages).copy()
    parent_tail = pool.tables[0].blocks[-1]

    k1 = jnp.full((1, 1, 1, 2), 99.0)
    pool.write(1, k1, k1)  # child's first write into the shared tail
    _check_invariants(pool)
    assert pool.stats["cow_copies"] == 1
    assert pool.tables[1].blocks[0] == pool.tables[0].blocks[0]  # full page shared
    child_tail = pool.tables[1].blocks[-1]
    assert child_tail != parent_tail  # tail diverged
    # Parent's pages are untouched by the child's write.
    np.testing.assert_array_equal(np.asarray(pool.k_pages)[:, parent_tail], before[:, parent_tail])
    # Child's copied tail carries the shared prefix slots plus the new token.
    got = np.asarray(pool.k_pages)[0, child_tail]
    np.testing.assert_array_equal(got[:2], np.asarray(k0)[0, 4:6])
    np.testing.assert_array_equal(got[2], np.asarray(k1)[0, 0])


def test_rollback_frees_pages():
    pool = PagedKVPool(num_blocks=8, block_size=4)
    pool.create(0)
    pool.append(0, 13)  # 4 pages
    assert pool.used_blocks == 4
    dropped = pool.rollback(0, 5)  # keep 2 pages
    _check_invariants(pool)
    assert dropped == 2 and pool.used_blocks == 2 and pool.length(0) == 5
    # Rollback across a fork only drops THIS session's references.
    pool.fork(0, 1)
    pool.append(1, 7)  # CoW tail + one new page
    shared_full = pool.tables[0].blocks[0]
    assert pool.rollback(1, 0) == 3
    _check_invariants(pool)
    assert pool.refcounts[shared_full] == 1  # parent still holds it
    assert pool.length(0) == 5  # parent untouched
    with pytest.raises(ValueError):
        pool.rollback(0, 6)  # cannot roll forward


def test_eviction_under_pressure():
    pool = PagedKVPool(num_blocks=4, block_size=4)
    pool.create(0)
    pool.append(0, 8)
    pool.create(1)
    pool.append(1, 8)
    assert pool.free_blocks == 0
    with pytest.raises(BlockPoolExhausted):
        pool.append(1, 4)
    # Session 0 is least-recently touched; exclusion protects it.
    assert pool.evict_lru(exclude=[0, 1]) is None
    assert pool.evict_lru(exclude=[1]) == 0
    _check_invariants(pool)
    assert pool.length(0) == 0 and pool.tables[0].blocks == []
    pool.append(1, 4)  # now backed by the reclaimed pages
    _check_invariants(pool)
    assert pool.stats["evictions"] == 1


def test_flat_reservation_semantics():
    """Reserved (flat-baseline) tables: up-front pages, no CoW, no free."""
    pool = PagedKVPool(num_blocks=8, block_size=4)
    pool.create(0)
    pool.reserve(0, 16)  # 4 pages immediately
    assert pool.used_blocks == 4 and pool.length(0) == 0
    pool.append(0, 10)
    assert pool.used_blocks == 4  # growth consumes the reservation
    assert pool.rollback(0, 2) == 0  # flat caches never return pages
    assert pool.used_blocks == 4
    with pytest.raises(BlockPoolExhausted):
        pool.append(0, 15)  # beyond the reservation
    pool.create(1)
    with pytest.raises(BlockPoolExhausted):
        pool.reserve(1, 32)  # 8 pages > 4 free


def test_lru_free_list_reuse_order():
    pool = PagedKVPool(num_blocks=8, block_size=1)
    pool.create(0)
    pool.append(0, 8)
    pages = list(pool.tables[0].blocks)
    pool.rollback(0, 6)  # frees pages[7] then pages[6]
    pool.rollback(0, 4)  # then pages[5], pages[4]
    pool.create(1)
    pool.append(1, 2)
    # Oldest-freed pages are reused first.
    assert pool.tables[1].blocks == [pages[7], pages[6]]


def test_blocks_needed_counts_cow_copy():
    pool = PagedKVPool(num_blocks=8, block_size=4)
    pool.create(0)
    pool.append(0, 6)
    pool.fork(0, 1)
    # Appending 1 token into the shared partial tail needs the CoW page.
    assert pool.blocks_needed(1, 1) == 1
    assert pool.blocks_needed(1, 2) == 1  # fills the copied tail exactly
    assert pool.blocks_needed(1, 3) == 2  # copy + one fresh page
    free = pool.free_blocks
    pool.append(1, 4)
    assert free - pool.free_blocks == 2


def test_engine_sim_tpt_identical_with_pool():
    """Paged accounting must not perturb the simulated timing model."""
    from repro.core.pipeline import (
        ChannelModel,
        CloudModel,
        EdgeModel,
        PipelineEngine,
        SyntheticSource,
        make_framework,
    )

    def run(pool):
        eng = PipelineEngine(
            make_framework("pipesd", autotune=False),
            ChannelModel(),
            CloudModel(),
            EdgeModel(),
            SyntheticSource(seed=5),
            seed=9,
            kv_pool=pool,
        )
        return eng.run(200)

    base = run(None)
    paged = run(PagedKVPool(num_blocks=256, block_size=16, bytes_per_token=1024))
    assert paged.tpt == base.tpt and paged.rounds == base.rounds
    assert paged.kv_resident_bytes and base.kv_resident_bytes == []
    assert paged.peak_kv_resident_bytes > 0


@pytest.mark.slow
def test_fleet_paged_serves_more_sessions_than_flat():
    """Fixed pool budget: paged admits the whole fleet where flat refuses
    half, with pool bookkeeping far below the 5% TPT-impact bound."""
    from benchmarks.fleet_bench import compare_kv

    reps = compare_kv(n_sessions=8, tokens_per_session=30)
    assert reps["flat"]["n_attached"] == 4  # budget fits 4 max_len reservations
    assert reps["paged"]["n_attached"] == 8
    assert reps["paged"]["kv_max_clients"] > reps["flat"]["n_attached"]
    assert reps["paged"]["failovers"] == 0
    st = reps["paged"]["stats"]
    assert 0 < st.kv_bytes_per_session < reps["flat"]["stats"].kv_bytes_per_session
    assert reps["paged_matched"]["kv_overhead_frac"] < 0.05
