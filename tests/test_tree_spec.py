"""Tree-structured speculation: drafting, tree-NAV verification, serving.

Load-bearing properties:

1. *Kernel parity*: ``spec_verify_tree`` (Pallas, interpret mode) matches the
   pure-JAX ``spec_verify_tree_ref`` bit-exactly on the greedy-NAV integer
   outputs (n_accepted, best node, correction) for random trees, including
   all-accepted / all-rejected rounds, B=1, and non-pow2 vocabs.
2. *Chain reduction*: a width-1 tree is exactly a chain — the tree verifier
   agrees with ``spec_verify_ref`` and the tree drafter with ``draft_round``.
3. *Greedy losslessness*: tree spec decoding emits exactly the target-only
   greedy sequence (the tree generalization of the chain invariant).
4. *Stochastic exactness*: multi-branch rejection sampling preserves the
   target distribution for i.i.d. draft children (SpecInfer-style).
5. *Serving*: tree requests ride the CloudVerifier's continuous-batching
   dispatcher next to chain requests and return accepted paths.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.spec_decode import (
    DraftConfig,
    TreeDraftConfig,
    draft_round,
    replay_path,
    tree_draft_round,
    tree_target_logits,
    tree_verify_stochastic,
)
from repro.kernels.spec_verify import (
    spec_verify_ref,
    spec_verify_tree,
    spec_verify_tree_batched,
    spec_verify_tree_ragged_ref,
    tree_path,
    tree_topology,
)

KEY = jax.random.PRNGKey(7)


# --------------------------------------------------------------------------- #
# Topology helpers
# --------------------------------------------------------------------------- #


def _random_tree(rng, n):
    """Topologically packed random parents (multi-root allowed)."""
    return [int(rng.integers(-1, i)) for i in range(n)]


def test_tree_topology_depths_and_ancestors():
    #       -1 → 0 → 2        (0-rooted chain through 2)
    #       -1 → 1             (second root)
    #        0 → 3             (sibling of 2)
    parents = jnp.asarray([[-1, -1, 0, 0]], jnp.int32)
    prow, depth, anc = tree_topology(parents)
    np.testing.assert_array_equal(np.asarray(prow[0]), [0, 0, 1, 1])
    np.testing.assert_array_equal(np.asarray(depth[0]), [1, 1, 2, 2])
    anc = np.asarray(anc[0])
    assert anc[2].tolist() == [True, False, True, False]  # path of node 2 = {0, 2}
    assert anc[3].tolist() == [True, False, False, True]
    assert anc[1].tolist() == [False, True, False, False]


def test_tree_path_reconstruction():
    parents = [-1, 0, 1, 0, -1]
    assert tree_path(parents, 2) == [0, 1, 2]
    assert tree_path(parents, 4) == [4]
    assert tree_path(parents, -1) == []


# --------------------------------------------------------------------------- #
# Kernel vs pure-JAX ref parity (greedy tree-NAV)
# --------------------------------------------------------------------------- #


def _random_requests(rng, B, max_n, V, match_prob=0.6):
    logits_seq, tokens_seq, parents_seq = [], [], []
    for _ in range(B):
        n = int(rng.integers(1, max_n + 1))
        lg = (rng.standard_normal((n + 1, V)) * 3).astype(np.float32)
        pr = _random_tree(rng, n)
        tk = []
        for i in range(n):
            if rng.random() < match_prob:
                tk.append(int(np.argmax(lg[pr[i] + 1])))  # matches target greedy
            else:
                tk.append(int(rng.integers(0, V)))
        logits_seq.append(lg)
        tokens_seq.append(tk)
        parents_seq.append(pr)
    return logits_seq, tokens_seq, parents_seq


@pytest.mark.parametrize("V", [257, 1024])
def test_tree_kernel_bit_exact_vs_ref(V):
    """Greedy tree-NAV integers must be BIT-EXACT between interpret-mode
    Pallas and the pure-JAX ref; log-probs agree to float tolerance."""
    rng = np.random.default_rng(V)
    for trial in range(6):
        logits_seq, tokens_seq, parents_seq = _random_requests(rng, 3, 9, V)
        ker = spec_verify_tree_batched(
            logits_seq, tokens_seq, parents_seq, impl="interpret", block_v=256
        )
        ref = spec_verify_tree_ragged_ref(logits_seq, tokens_seq, parents_seq)
        for i, ((na, path, corr, lp), (na2, best2, corr2, lp2)) in enumerate(zip(ker, ref)):
            assert na == na2, f"V={V} trial={trial} session={i}"
            assert corr == corr2, f"V={V} trial={trial} session={i}"
            assert (path[-1] if path else -1) == best2
            assert len(path) == na  # the accepted path IS n_accepted long
            np.testing.assert_allclose(lp, lp2, atol=1e-4)


@pytest.mark.parametrize("impl", ["ref", "interpret"])
def test_tree_all_accepted_and_all_rejected(impl):
    V = 128
    rng = np.random.default_rng(3)
    # All-accepted: every node's token is the target greedy at its parent row.
    lg = (rng.standard_normal((5, V)) * 4).astype(np.float32)
    parents = [-1, 0, 1, 2]  # a chain-shaped tree, depth 4
    tokens = [int(np.argmax(lg[p + 1])) for p in parents]
    (na, path, corr, _), = spec_verify_tree_batched([lg], [tokens], [parents], impl=impl)
    assert na == 4 and path == [0, 1, 2, 3]
    assert corr == int(np.argmax(lg[4]))  # bonus from the leaf's own row
    # All-rejected: no token matches → n_acc 0, correction from the anchor.
    tokens_bad = [(t + 1) % V for t in tokens]
    (na, path, corr, _), = spec_verify_tree_batched([lg], [tokens_bad], [parents], impl=impl)
    assert na == 0 and path == []
    assert corr == int(np.argmax(lg[0]))


@pytest.mark.parametrize("impl", ["ref", "interpret"])
def test_tree_single_session_padding_inert(impl):
    """B=1 rides the pow2 bucketing: pad rows/nodes must not perturb it."""
    rng = np.random.default_rng(11)
    logits_seq, tokens_seq, parents_seq = _random_requests(rng, 1, 5, 192)
    (got,) = spec_verify_tree_batched(
        logits_seq, tokens_seq, parents_seq, impl=impl, block_v=64
    )
    (want,) = spec_verify_tree_ragged_ref(logits_seq, tokens_seq, parents_seq)
    assert (got[0], got[2]) == (want[0], want[2])


def test_tree_sibling_tiebreak_prefers_packed_order():
    """Two accepted siblings at the same depth: the verifier must pick the
    SMALLEST packed index (the drafter packs siblings confidence-sorted)."""
    V = 64
    lg = np.full((3, V), -5.0, np.float32)
    lg[0, 7] = 5.0  # anchor greedy = 7
    lg[1, 3] = 5.0
    lg[2, 4] = 5.0
    parents = [-1, -1]
    tokens = [7, 7]  # both siblings match the anchor greedy
    for impl in ("ref", "interpret"):
        (na, path, corr, _), = spec_verify_tree_batched([lg], [tokens], [parents], impl=impl, block_v=64)
        assert na == 1 and path == [0], impl
        assert corr == 3, impl  # correction from node 0's own row


def test_tree_chain_equivalence_with_chain_verifier():
    """A width-1 tree is a chain: tree-NAV == chain NAV on the same logits."""
    rng = np.random.default_rng(5)
    V, K = 301, 6
    lg = (rng.standard_normal((K + 1, V)) * 3).astype(np.float32)
    tokens = [int(np.argmax(lg[i])) for i in range(3)] + [int(rng.integers(0, V)) for _ in range(3)]
    parents = [-1] + list(range(K - 1))
    na_c, corr_c, _ = spec_verify_ref(
        jnp.asarray(lg)[None], jnp.asarray([tokens], jnp.int32), jnp.asarray([K], jnp.int32)
    )
    (na_t, path, corr_t, _), = spec_verify_tree_batched([lg], [tokens], [parents], impl="ref")
    assert na_t == int(na_c[0, 0])
    assert corr_t == int(corr_c[0, 0])
    assert path == list(range(na_t))


def test_tree_batched_rejects_bad_topology():
    lg = np.zeros((3, 64), np.float32)
    with pytest.raises(ValueError):
        spec_verify_tree_batched([lg], [[1, 2]], [[0, 0]])  # parents[0] must be -1
    with pytest.raises(ValueError):
        spec_verify_tree_batched([lg], [[1, 2]], [[-1, 5]])  # forward reference
    with pytest.raises(ValueError):
        spec_verify_tree_batched([lg], [[1, 2]], [[-1]])  # length mismatch


# --------------------------------------------------------------------------- #
# Tree drafting
# --------------------------------------------------------------------------- #


def _decaying_draft_step(vocab=32):
    """Deterministic synthetic draft: peaked logits that flatten with depth.

    The cache is the step count; confidence decays as the tree deepens so
    threshold pruning has something to bite on.
    """

    def step(params, tok, cache):
        k = cache
        sharp = 4.0 - 0.9 * k.astype(jnp.float32)
        logits = jnp.zeros((tok.shape[0], vocab))
        logits = logits.at[:, 3].set(sharp).at[:, 5].set(sharp - 0.3).at[:, 9].set(sharp - 0.6)
        return logits, k + 1

    return step


def test_tree_draft_round_topology_and_packing():
    cfg = TreeDraftConfig(depth=3, width=2, max_nodes=14)
    res = tree_draft_round(_decaying_draft_step(), None, jnp.int32(0), 0, cfg)
    assert 1 <= res.n_nodes <= 14
    for i in range(res.n_nodes):
        assert -1 <= res.parents[i] < i  # topologically packed
    # Level order + conf-sorted siblings: path_conf = parent's × own conf.
    for i in range(res.n_nodes):
        p = int(res.parents[i])
        parent_conf = 1.0 if p < 0 else float(res.path_confs[p])
        np.testing.assert_allclose(res.path_confs[i], parent_conf * res.confs[i], rtol=1e-6)
        assert res.depths[i] == (1 if p < 0 else res.depths[p] + 1)
    # Siblings are confidence-sorted (verifier tie-break prefers low index).
    by_parent = {}
    for i in range(res.n_nodes):
        by_parent.setdefault(int(res.parents[i]), []).append(float(res.confs[i]))
    for sibs in by_parent.values():
        assert sibs == sorted(sibs, reverse=True)


def test_tree_draft_round_prunes_on_r2_and_stops_on_r1():
    # R2 high: only the strongest child survives each expansion.
    cfg = TreeDraftConfig(depth=3, width=3, max_nodes=20, r2=0.45)
    res = tree_draft_round(_decaying_draft_step(), None, jnp.int32(0), 0, cfg)
    assert all(c > 0.45 for c in res.confs.tolist())
    # R1 close to 1: every path fires immediately → a single level.
    cfg2 = TreeDraftConfig(depth=4, width=2, max_nodes=20, r1=0.999999)
    res2 = tree_draft_round(_decaying_draft_step(), None, jnp.int32(0), 0, cfg2)
    assert int(res2.depths.max()) == 1


def test_tree_draft_round_width1_matches_chain_draft_round():
    """width=1, no thresholds → exactly the greedy chain of draft_round."""
    step = _decaying_draft_step()
    cfg_tree = TreeDraftConfig(depth=5, width=1, max_nodes=5)
    res_t = tree_draft_round(step, None, jnp.int32(0), 0, cfg_tree)
    cfg_chain = DraftConfig(window=5, r1=0.0, r2=0.0)
    res_c = draft_round(step, None, jnp.int32(0), jnp.zeros((1,), jnp.int32), cfg_chain, KEY)
    assert res_t.n_nodes == 5
    np.testing.assert_array_equal(res_t.tokens, np.asarray(res_c.tokens[0]))
    np.testing.assert_array_equal(res_t.parents, [-1, 0, 1, 2, 3])
    np.testing.assert_allclose(res_t.confs, np.asarray(res_c.confs[0]), rtol=1e-5)


def test_tree_draft_round_beam_caps_frontier():
    cfg = TreeDraftConfig(depth=3, width=3, max_nodes=30, beam=1)
    res = tree_draft_round(_decaying_draft_step(), None, jnp.int32(0), 0, cfg)
    # With beam=1 only one node per level is expanded: ≤ width new nodes per
    # level and total ≤ width · depth.
    assert res.n_nodes <= 9
    levels = {}
    for i in range(res.n_nodes):
        levels[int(res.depths[i])] = levels.get(int(res.depths[i]), 0) + 1
    assert all(v <= 3 for v in levels.values())


# --------------------------------------------------------------------------- #
# End-to-end greedy losslessness on a tiny transformer
# --------------------------------------------------------------------------- #


def test_tree_spec_decoding_is_lossless():
    from repro.models import transformer as T
    from repro.models.config import ModelConfig
    from repro.models.kvcache import set_lengths

    def _tiny(name, layers):
        return ModelConfig(name=name, family="dense", n_layers=layers, d_model=48,
                           n_heads=4, n_kv_heads=2, d_ff=96, vocab_size=128,
                           head_dim=12, vocab_pad_to=64)

    tcfg, dcfg = _tiny("target", 2), _tiny("draft", 1)
    tparams = T.init(jax.random.PRNGKey(10), tcfg)
    dparams = T.init(jax.random.PRNGKey(20), dcfg)
    P, N_NEW = 6, 12
    prompt = jax.random.randint(KEY, (1, P), 0, 128)

    # Gold: target-only greedy.
    cache = T.make_cache(tcfg, 1, 256)
    logits, cache = T.prefill(tparams, {"tokens": prompt}, cache, tcfg)
    tok = jnp.argmax(logits[:, -1, :], -1).astype(jnp.int32)
    gold = [int(tok[0])]
    for _ in range(N_NEW):
        logits, cache = T.decode(tparams, tok[:, None], cache, tcfg)
        tok = jnp.argmax(logits[:, 0, :], -1).astype(jnp.int32)
        gold.append(int(tok[0]))

    def draft_step(params, tok, cache):
        lg, c = T.decode(params, tok[:, None], cache, dcfg)
        return lg[:, 0, :], c

    def target_forward(params, seq, cache):
        return T.decode(params, seq, cache, tcfg)

    d_cache = T.make_cache(dcfg, 1, 256)
    t_cache = T.make_cache(tcfg, 1, 256)
    _, d_cache = T.prefill(dparams, {"tokens": prompt}, d_cache, dcfg)
    t_logits, t_cache = T.prefill(tparams, {"tokens": prompt}, t_cache, tcfg)
    last = int(jnp.argmax(t_logits[0, -1, :]))
    out = [last]
    cfg = TreeDraftConfig(depth=3, width=2, max_nodes=8)
    t_len = P
    while len(out) < N_NEW + 1:
        dr = tree_draft_round(draft_step, dparams, d_cache, last, cfg)
        lg = tree_target_logits(
            target_forward, tparams, set_lengths(t_cache, jnp.asarray([t_len])),
            last, dr.tokens, dr.parents,
        )
        na, best, corr, _ = spec_verify_tree(
            lg[None], jnp.asarray(dr.tokens)[None], jnp.asarray(dr.parents)[None],
            jnp.asarray([dr.n_nodes]), impl="ref",
        )
        na, best, corr = int(na[0, 0]), int(best[0, 0]), int(corr[0, 0])
        acc = [int(dr.tokens[j]) for j in tree_path(dr.parents, best)]
        out.extend(acc)
        out.append(corr)
        # Roll forward: target replays anchor+accepted path from the prefix,
        # draft replays the accepted path from the anchor cache (tree-reject
        # rollback = discard everything past the committed prefix).
        seq = jnp.asarray([[last] + acc], jnp.int32)
        _, t_cache = target_forward(tparams, seq, set_lengths(t_cache, jnp.asarray([t_len])))
        t_len += 1 + na
        d_cache = replay_path(draft_step, dparams, dr.anchor_cache, acc)
        last = corr
    assert out[: N_NEW + 1] == gold, "tree spec decode diverged from target-greedy"


# --------------------------------------------------------------------------- #
# Stochastic tree verification
# --------------------------------------------------------------------------- #


def test_tree_verify_stochastic_preserves_target_distribution():
    """Single-level tree, k=2 i.i.d. children from q: the emitted token
    (accepted child or residual correction) must be distributed as p."""
    rng = np.random.default_rng(0)
    V = 6
    p = np.array([0.34, 0.06, 0.18, 0.12, 0.05, 0.25])
    q = np.array([0.05, 0.30, 0.10, 0.15, 0.25, 0.15])
    n_trials = 20_000
    counts = np.zeros(V)
    target_probs = np.stack([p, p, p])  # anchor row + one row per child
    draft_probs = np.stack([q, q, q])
    for _ in range(n_trials):
        children = rng.choice(V, size=2, p=q)
        tokens = [int(children[0]), int(children[1])]
        parents = [-1, -1]
        path, corr = tree_verify_stochastic(target_probs, draft_probs, tokens, parents, rng)
        emitted = tokens[path[0]] if path else corr
        counts[emitted] += 1
    np.testing.assert_allclose(counts / n_trials, p, atol=0.015)


def test_tree_verify_stochastic_chain_reduces_to_single_draft():
    """One child drawn from q ≡ classic speculative sampling: marginal = p."""
    rng = np.random.default_rng(1)
    V = 4
    p = np.array([0.45, 0.05, 0.3, 0.2])
    q = np.array([0.1, 0.4, 0.2, 0.3])
    counts = np.zeros(V)
    n_trials = 20_000
    for _ in range(n_trials):
        tok = int(rng.choice(V, p=q))
        path, corr = tree_verify_stochastic(
            np.stack([p, p]), np.stack([q, q]), [tok], [-1], rng
        )
        counts[tok if path else corr] += 1
    np.testing.assert_allclose(counts / n_trials, p, atol=0.015)


# --------------------------------------------------------------------------- #
# Serving: tree requests through the continuous-batching dispatcher
# --------------------------------------------------------------------------- #


def test_cloud_verifier_dispatches_mixed_chain_and_tree():
    from repro.runtime import (
        Channel,
        ChannelConfig,
        CloudVerifier,
        DraftFragment,
        NavRequest,
        SyntheticBackend,
        TreeNavRequest,
    )

    ts = 0.01
    backend = SyntheticBackend(time_scale=ts, seed=0)
    server = CloudVerifier(backend, batch_window=backend.verify_time * ts, max_batch=8)
    links = {}
    for sid in (0, 1):
        up = Channel(ChannelConfig(alpha=0.001, beta=0.0001, time_scale=ts))
        dn = Channel(ChannelConfig(alpha=0.001, beta=0.0001, time_scale=ts))
        server.attach(sid, up, dn)
        links[sid] = (up, dn)
    server.start()
    try:
        # Session 0: chain round. Session 1: tree round with packed parents.
        up0, dn0 = links[0]
        up0.send(DraftFragment(0, 1, 1, (5, 6, 7), (0.99, 0.99, 0.99)))
        up0.send(NavRequest(0, 2, 1, n_tokens=3))
        up1, dn1 = links[1]
        parents = [-1, -1, 0, 1, 2]
        up1.send(DraftFragment(1, 1, 1, (1, 2, 3, 4, 5), (0.99,) * 5, tuple(parents)))
        up1.send(TreeNavRequest(1, 2, 1, n_tokens=5))
        r0 = dn0.recv(timeout=5.0)
        r1 = dn1.recv(timeout=5.0)
    finally:
        server.stop()
    assert r0 is not None and r0.path is None
    assert 0 <= r0.n_accepted <= 3
    assert r1 is not None and r1.path is not None
    path = r1.path
    assert len(path) == r1.n_accepted
    # The path must be a root→leaf chain under the sent parents.
    for a, b in zip(path, path[1:]):
        assert parents[b] == a
    if path:
        assert parents[path[0]] == -1
    assert server.stats["tokens_verified"] == 8


def test_spec_verify_backend_tree_batch_matches_solo():
    """Kernel-backed tree verify: batched call == per-session calls."""
    from repro.runtime import SpecVerifyBackend

    V = 256

    def logits_fn(session, tokens):
        rng = np.random.default_rng(500 + session)
        return (rng.standard_normal((len(tokens) + 1, V)) * 2).astype(np.float32)

    backend = SpecVerifyBackend(logits_fn, impl="ref")
    reqs = [
        (0, [3, 9, 7], [0.9] * 3, [-1, 0, 1]),
        (1, [5, 6], [0.9] * 2, [-1, -1]),
        (2, [1, 2, 3, 4], [0.9] * 4, [-1, 0, 0, 2]),
    ]
    batched = backend.verify_tree_batch(reqs)
    solo = [backend.verify_tree(s, t, c, p) for (s, t, c, p) in reqs]
    assert batched == solo
    for (n_acc, corr, path), (_, _, _, parents) in zip(batched, reqs):
        assert len(path) == n_acc
