"""Data pipeline: determinism, packing, prefetch."""

import numpy as np

from repro.data import ByteTokenizer, DataPipeline, SyntheticCorpus


def test_corpus_deterministic():
    c1 = SyntheticCorpus("code", seed=1)
    c2 = SyntheticCorpus("code", seed=1)
    assert c1.text(50, seed=7) == c2.text(50, seed=7)
    assert c1.text(50, seed=7) != c1.text(50, seed=8)


def test_dialects_differ():
    assert SyntheticCorpus("code", 0).text(30, 0) != SyntheticCorpus("math", 0).text(30, 0)


def test_tokenizer_roundtrip():
    tok = ByteTokenizer()
    s = "def f(x): return x + 1"
    assert tok.decode(tok.encode(s)) == s


def test_pipeline_shapes_and_shift():
    pipe = DataPipeline(SyntheticCorpus("code", 0), ByteTokenizer(), batch_size=3, seq_len=32)
    b = pipe.batch_at(0)
    assert b["tokens"].shape == (3, 32) and b["labels"].shape == (3, 32)
    np.testing.assert_array_equal(b["tokens"][:, 1:], b["labels"][:, :-1])  # next-token shift
    # Deterministic random access (resume support).
    b2 = pipe.batch_at(0)
    np.testing.assert_array_equal(b["tokens"], b2["tokens"])
    pipe.close()


def test_prefetch_iterator():
    pipe = DataPipeline(SyntheticCorpus("math", 0), ByteTokenizer(), batch_size=2, seq_len=16)
    it = iter(pipe)
    batches = [next(it) for _ in range(3)]
    assert all(b["tokens"].shape == (2, 16) for b in batches)
    pipe.close()
