"""Checkpoint manager: roundtrip, keep-k, resume, corruption safety."""

import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint import CheckpointManager, load_pytree, save_pytree


def _tree():
    return {"a": jnp.arange(6).reshape(2, 3), "nested": {"b": jnp.ones((4,)) * 2.5}, "t": (jnp.zeros((2,)),)}


def test_roundtrip(tmp_path):
    t = _tree()
    save_pytree(t, tmp_path / "ck")
    back = load_pytree(tmp_path / "ck", t)
    np.testing.assert_array_equal(np.asarray(back["a"]), np.asarray(t["a"]))
    np.testing.assert_array_equal(np.asarray(back["nested"]["b"]), np.asarray(t["nested"]["b"]))


def test_manager_keep_k_and_latest(tmp_path):
    mgr = CheckpointManager(tmp_path, keep=2)
    for s in (10, 20, 30):
        mgr.save(s, _tree())
    assert mgr.latest_step() == 30
    assert sorted(int(p.name.split("_")[1]) for p in tmp_path.glob("step_*")) == [20, 30]


def test_restore_shape_mismatch_raises(tmp_path):
    save_pytree(_tree(), tmp_path / "ck")
    bad = _tree()
    bad["a"] = jnp.zeros((5, 5))
    with pytest.raises(ValueError):
        load_pytree(tmp_path / "ck", bad)


def test_missing_checkpoint_raises(tmp_path):
    mgr = CheckpointManager(tmp_path)
    with pytest.raises(FileNotFoundError):
        mgr.restore(_tree())


def test_train_resume(tmp_path):
    """launch.train resumes from the latest checkpoint and keeps improving."""
    from repro.launch.train import train

    _, losses1 = train("xlstm-350m", steps=6, batch=2, seq=32, ckpt_dir=str(tmp_path), ckpt_every=3, log_every=100)
    # Second call resumes from step 6 (nothing to do → no new losses) after
    # a simulated crash at step 6; extend to 9 to prove continuation.
    _, losses2 = train("xlstm-350m", steps=9, batch=2, seq=32, ckpt_dir=str(tmp_path), ckpt_every=3, log_every=100)
    assert len(losses2) == 3  # only steps 7..9 ran
