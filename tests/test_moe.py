"""MoE routing: dropless consistency, combine-weight mass, capacity drops."""

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.models import transformer as T
from repro.models.config import ModelConfig, MoEConfig
from repro.models.layers import init_moe, moe_block

KEY = jax.random.PRNGKey(5)


def _cfg(cf=2.0, g=32, E=4, k=2):
    return ModelConfig(
        name="moe-test", family="moe", n_layers=1, d_model=32, n_heads=2, n_kv_heads=2,
        d_ff=0, vocab_size=64, head_dim=16, vocab_pad_to=64,
        moe=MoEConfig(n_experts=E, top_k=k, d_ff_expert=48, capacity_factor=cf, group_size=g),
    )


def test_dropless_grouping_invariance():
    """With cf = E/k (dropless), output is independent of the grouping."""
    cfg1 = _cfg(cf=2.0, g=8)
    cfg2 = _cfg(cf=2.0, g=16)
    p = init_moe(KEY, cfg1)
    x = jax.random.normal(KEY, (2, 16, 32))
    o1, _ = moe_block(p, x, cfg1)
    o2, _ = moe_block(p, x, cfg2)
    np.testing.assert_allclose(np.asarray(o1), np.asarray(o2), atol=1e-5)


def test_capacity_drops_reduce_output_mass():
    """Tiny capacity must drop tokens (outputs zeroed), dropless must not."""
    cfg_tight = _cfg(cf=0.25, g=32)
    cfg_free = _cfg(cf=2.0, g=32)
    p = init_moe(KEY, cfg_tight)
    x = jax.random.normal(KEY, (1, 32, 32))
    o_tight, _ = moe_block(p, x, cfg_tight)
    o_free, _ = moe_block(p, x, cfg_free)
    assert float(jnp.abs(o_tight).sum()) < float(jnp.abs(o_free).sum())


def test_aux_loss_positive_and_bounded():
    cfg = _cfg()
    p = init_moe(KEY, cfg)
    x = jax.random.normal(KEY, (2, 32, 32))
    _, aux = moe_block(p, x, cfg)
    assert 0.0 <= float(aux) < cfg.moe.n_experts * cfg.moe.load_balance_weight * 2


def test_dense_residual_path():
    cfg = dataclasses.replace(
        _cfg(), moe=dataclasses.replace(_cfg().moe, dense_residual=True, d_ff_dense=48)
    )
    p = init_moe(KEY, cfg)
    assert "dense" in p
    x = jax.random.normal(KEY, (1, 8, 32))
    o, _ = moe_block(p, x, cfg)
    assert o.shape == x.shape and bool(jnp.isfinite(o).all())


def test_moe_grads_flow_to_router_and_experts():
    cfg = _cfg()
    p = init_moe(KEY, cfg)
    x = jax.random.normal(KEY, (2, 16, 32))

    def loss(p):
        o, aux = moe_block(p, x, cfg)
        return jnp.sum(o * o) + aux

    g = jax.grad(loss)(p)
    assert float(jnp.linalg.norm(g["router"])) > 0
    assert float(jnp.linalg.norm(g["w_gate"])) > 0
    assert float(jnp.linalg.norm(g["w_down"])) > 0
