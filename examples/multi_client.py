"""Multi-edge serving (App. I / Table A.3) with failure injection.

8 edge clients share one cloud verifier; midway one client's downlink has an
outage window, forcing failover to local decoding and seamless re-attach.

    PYTHONPATH=src python examples/multi_client.py
"""

import sys
import threading
from pathlib import Path

sys.path.insert(0, str(Path(__file__).parent.parent / "src"))

from repro.runtime import (
    Channel,
    ChannelConfig,
    CloudVerifier,
    EdgeClient,
    EdgeConfig,
    SyntheticBackend,
)

TS = 0.02


def main() -> None:
    server = CloudVerifier(SyntheticBackend(time_scale=TS, seed=1), batch_window=0.002)
    server.start()
    clients = []
    for sid in range(8):
        up = Channel(ChannelConfig(alpha=0.02, beta=0.002, time_scale=TS))
        outage = (0.0, 0.4) if sid == 3 else None  # client 3 loses the cloud
        dn = Channel(ChannelConfig(alpha=0.01, beta=0.0005, time_scale=TS, outage=outage))
        server.attach(sid, up, dn)
        clients.append(EdgeClient(sid, up, dn, EdgeConfig(time_scale=TS, gamma=0.02, nav_timeout=0.3)))
    results = {}
    ths = [threading.Thread(target=lambda c=c: results.update({c.session: c.run(100)})) for c in clients]
    [t.start() for t in ths]
    [t.join(timeout=180) for t in ths]
    server.stop()
    for sid in sorted(results):
        r = results[sid]
        flag = "  <-- failover exercised" if r["failovers"] else ""
        print(f"client {sid}: tokens={r['accepted_tokens']} rounds={r['rounds']} "
              f"failovers={r['failovers']} fallback_tokens={r['fallback_tokens']}{flag}")
    load = server.load_summary()
    print(
        f"server: nav_calls={load['nav_calls']} batched_calls={load['batched_calls']}"
        f" occupancy={load['batch_occupancy']:.2f} mean_queue_depth={load['mean_queue_depth']:.2f}"
        f" dropped_stragglers={load['dropped_stragglers']}"
    )


if __name__ == "__main__":
    main()
