"""End-to-end driver: serve a small model with batched requests, cloud-edge.

This is the paper-kind e2e example: a threaded cloud verifier (the "A800")
serves batched NAV requests from edge clients that draft with the
dual-threshold trigger, ship token batches per the DP schedule, autotune
(R1, R2) with BO, and fail over to local decoding if the cloud disappears.

    PYTHONPATH=src python examples/cloud_edge_serve.py
"""

import sys
import threading
from pathlib import Path

sys.path.insert(0, str(Path(__file__).parent.parent / "src"))

from repro.core.autotuner import BOAutotuner
from repro.runtime import (
    Channel,
    ChannelConfig,
    CloudVerifier,
    EdgeClient,
    EdgeConfig,
    SyntheticBackend,
)

TS = 0.02  # run the timing model 50× faster than real time


def run_fleet(n_clients: int, r1: float, r2: float, tokens: int = 120) -> dict:
    server = CloudVerifier(SyntheticBackend(time_scale=TS, seed=1), batch_window=0.002)
    server.start()
    clients = []
    for sid in range(n_clients):
        up = Channel(ChannelConfig(alpha=0.02, beta=0.002, time_scale=TS))
        dn = Channel(ChannelConfig(alpha=0.01, beta=0.0005, time_scale=TS))
        server.attach(sid, up, dn)
        clients.append(EdgeClient(sid, up, dn, EdgeConfig(time_scale=TS, gamma=0.02, r1=r1, r2=r2)))
    results = {}
    ths = [threading.Thread(target=lambda c=c: results.update({c.session: c.run(tokens)})) for c in clients]
    [t.start() for t in ths]
    [t.join(timeout=120) for t in ths]
    server.stop()
    total = sum(r["accepted_tokens"] for r in results.values())
    wall = max(r["wall_time"] for r in results.values()) / TS  # de-scaled seconds
    return dict(tpt_ms=wall / total * 1e3, server=server.stats, clients=results)


def main() -> None:
    print("=== batched cloud-edge serving, 3 clients, default thresholds ===")
    base = run_fleet(3, r1=0.9, r2=0.6)
    print(f"fleet TPT {base['tpt_ms']:.1f} ms/token; server: {base['server']}")

    print("\n=== BO-autotuned thresholds (16 samples on a 1-client probe) ===")
    bo = BOAutotuner(seed=0)
    best = bo.minimize(lambda r1, r2: run_fleet(1, r1, r2, tokens=40)["tpt_ms"], 16)
    print(f"BO chose (R1,R2)=({best.x[0]:.2f},{best.x[1]:.2f}) probe TPT {best.y:.1f} ms")
    tuned = run_fleet(3, *best.x)
    print(f"fleet TPT tuned {tuned['tpt_ms']:.1f} ms/token (vs {base['tpt_ms']:.1f} default)")


if __name__ == "__main__":
    main()
