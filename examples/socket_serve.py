"""Loopback-socket serving: typed wire protocol over real TCP, one process.

The same ``CloudVerifier``/``EdgeClient`` pair that the simulated runtime
drives in-process here talks length-prefixed protocol frames over a real
localhost socket — the paper's client/server testbed shape, without the
second shell (``launch/serve.py`` runs the genuinely two-process version).

Three edge clients attach through the ``Hello``/``Attach`` version
handshake and stream concurrently against one continuous-batching
verifier; each client's committed stream is checked against the shared
deterministic oracle.

    PYTHONPATH=src python examples/socket_serve.py
"""

import sys
from pathlib import Path

sys.path.insert(0, str(Path(__file__).parent.parent / "src"))

from repro.runtime import (
    SYSTEM_CLOCK,
    ChannelConfig,
    CloudVerifier,
    Detach,
    EdgeClient,
    EdgeConfig,
    OracleBackend,
    OracleDraft,
    OracleStream,
    SocketListener,
    connect_transport,
)

N_CLIENTS = 3
TOKENS = 48
SEED = 11


def run_one_client(host: str, port: int, sid: int, results: dict) -> None:
    transport = connect_transport(
        host, port, session=sid, cfg=ChannelConfig(alpha=0.001, beta=0.0001)
    )
    client = EdgeClient(
        transport.session,
        transport,
        transport,
        EdgeConfig(gamma=0.004, window=8, nav_timeout=5.0),
        draft=OracleDraft(seed=SEED),
    )
    stats = client.run(TOKENS)
    client.seq += 1
    transport.send(Detach(session=transport.session, seq=client.seq))
    transport.close()
    results[transport.session] = (list(client.tokens), stats)


def main() -> None:
    backend = OracleBackend(seed=SEED, verify_time=0.002, verify_time_per_token=0.0)
    verifier = CloudVerifier(backend, batch_window=0.002)
    listener = SocketListener(
        lambda sid, t: verifier.attach(sid, t, t), host="127.0.0.1", port=0
    )
    verifier.start()
    print(f"verifier listening on {listener.host}:{listener.port}")

    results: dict = {}
    workers = [
        SYSTEM_CLOCK.spawn(
            lambda sid=sid: run_one_client(listener.host, listener.port, sid, results),
            name=f"edge-{sid}",
        )
        for sid in range(N_CLIENTS)
    ]
    for w in workers:
        w.join(timeout=60.0)
    listener.close()
    verifier.stop()

    # A crashed or hung client thread must fail the run, not shrink the report.
    assert len(results) == N_CLIENTS, (
        f"only {sorted(results)} of {N_CLIENTS} clients completed"
    )
    oracle = OracleStream(SEED)
    for sid in sorted(results):
        stream, stats = results[sid]
        ok = stream == oracle.prefix(len(stream))
        print(
            f"session {sid}: {stats['accepted_tokens']} tokens in"
            f" {stats['rounds']} rounds, {stats['wall_time']:.2f}s —"
            f" stream == oracle: {ok}"
        )
        assert ok, f"session {sid} diverged from the oracle stream"
    s = verifier.stats
    print(
        f"verifier: nav_calls={s['nav_calls']} tokens_verified={s['tokens_verified']}"
        f" batched_calls={s['batched_calls']} (coalescing amortized"
        f" {s['nav_calls'] - s['batched_calls']} calls)"
    )


if __name__ == "__main__":
    main()
