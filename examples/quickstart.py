"""Quickstart: speculative decoding with a tiny trained draft/target pair.

Trains a tiny target and a half-depth draft on the same synthetic corpus
(minutes on CPU), then runs PipeSD-style speculative decoding and reports the
acceptance statistics vs plain autoregressive decoding.

    PYTHONPATH=src python examples/quickstart.py
"""

import sys
from pathlib import Path

sys.path.insert(0, str(Path(__file__).parent.parent / "src"))

import time

import jax

from repro.launch.serve import build_pair, serve
from repro.launch.train import train


def main() -> None:
    print("=== 1. train a tiny target model (synthetic code corpus) ===")
    tstate, tloss = train("granite-3-2b", steps=40, batch=4, seq=64, lr=2e-3, log_every=20, seed=0)
    print(f"target loss: {tloss[0]:.3f} -> {tloss[-1]:.3f}")

    print("=== 2. speculative decoding: draft == target (acceptance upper bound) ===")
    (tcfg, _), (dcfg, _) = build_pair("granite-3-2b", seed=0)
    pair = ((tcfg, tstate.params), (tcfg, tstate.params))
    t0 = time.time()
    _, trace, stats = serve("granite-3-2b", n_tokens=48, batch=2, window=6, params=pair)
    print(f"  rounds={stats['rounds']} mean_draft_len={stats['mean_draft_len']:.2f} "
          f"acceptance={stats['acceptance_rate']:.2%} wall={time.time()-t0:.1f}s")

    print("=== 3. random (untrained) draft: near-zero acceptance, still lossless ===")
    _, _, stats2 = serve("granite-3-2b", n_tokens=24, batch=2, window=4, seed=1)
    print(f"  acceptance={stats2['acceptance_rate']:.2%} (greedy NAV corrects every miss)")

    speedup_proxy = (1 + stats["mean_draft_len"] * stats["acceptance_rate"]) / 1.0
    print(f"\nPipeSD per-round output ≈ {speedup_proxy:.2f} tokens per target forward "
          f"(vs 1.0 autoregressive) — the paper's core speedup mechanism.")


if __name__ == "__main__":
    main()
