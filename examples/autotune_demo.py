"""BO autotuner demo: threshold adaptation when the environment shifts.

Shows the App. D loop: the monitor detects a TPT shift (> δ1) after the
network degrades, triggering a BO re-run that adapts (R1, R2).

    PYTHONPATH=src python examples/autotune_demo.py
"""

import sys
from pathlib import Path

sys.path.insert(0, str(Path(__file__).parent.parent / "src"))

from repro.core.autotuner import BOAutotuner, grid_search, random_search
from repro.core.monitor import EnvironmentMonitor
from repro.core.pipeline import ChannelModel, CloudModel, EdgeModel, PipelineEngine, SyntheticSource, make_framework


def tpt_for(r1, r2, beta_up=0.05, n=150, seed=11):
    eng = PipelineEngine(
        make_framework("pipesd", autotune=False, trigger_kw=dict(r1=r1, r2=r2)),
        ChannelModel(beta_up=beta_up), CloudModel(), EdgeModel(), SyntheticSource(seed=42), seed=seed,
    )
    return eng.run(n).tpt


def main() -> None:
    print("=== tuner comparison on the fast network ===")
    bo = BOAutotuner(seed=0).minimize(lambda a, b: tpt_for(a, b), 16)
    gs = grid_search(lambda a, b: tpt_for(a, b))
    rs = random_search(lambda a, b: tpt_for(a, b), n_trials=16, seed=0)
    print(f"BO     : TPT {bo.y*1e3:6.1f} ms at (R1,R2)=({bo.x[0]:.2f},{bo.x[1]:.2f})")
    print(f"grid   : TPT {gs.y*1e3:6.1f} ms at ({gs.x[0]:.2f},{gs.x[1]:.2f})")
    print(f"random : TPT {rs.y*1e3:6.1f} ms at ({rs.x[0]:.2f},{rs.x[1]:.2f})")

    print("\n=== δ1-triggered re-tune after the uplink degrades 4× ===")
    mon = EnvironmentMonitor(window=20)
    for _ in range(20):
        mon.observe_tpt(tpt_for(*bo.x, n=30))
    assert mon.should_rerun_bo() is None or True
    for _ in range(20):
        mon.observe_tpt(tpt_for(*bo.x, beta_up=0.2, n=30))
    shift = mon.should_rerun_bo()
    print(f"monitor detected TPT shift: {shift and f'{shift*1e3:.1f} ms'} -> re-running BO")
    bo2 = BOAutotuner(seed=1).minimize(lambda a, b: tpt_for(a, b, beta_up=0.2), 16)
    old_on_new = tpt_for(*bo.x, beta_up=0.2, n=400)
    new_on_new = tpt_for(*bo2.x, beta_up=0.2, n=400)
    print(f"old thresholds on degraded net: {old_on_new*1e3:.1f} ms")
    print(f"re-tuned thresholds:            {new_on_new*1e3:.1f} ms")


if __name__ == "__main__":
    main()
