"""Train a tiny draft/target pair with checkpointing + WSD schedule.

Demonstrates the training substrate end-to-end: synthetic data pipeline,
WSD schedule, AdamW, atomic checkpoints with auto-resume, and optional int8
gradient compression.

    PYTHONPATH=src python examples/train_tiny_pair.py
"""

import sys
import tempfile
from pathlib import Path

sys.path.insert(0, str(Path(__file__).parent.parent / "src"))

from repro.launch.train import train


def main() -> None:
    ckpt = tempfile.mkdtemp(prefix="pipesd_pair_")
    print("=== target (granite-3-2b reduced), 60 steps, WSD + checkpoints ===")
    _, tl = train("granite-3-2b", steps=60, batch=4, seq=64, lr=2e-3,
                  ckpt_dir=f"{ckpt}/target", ckpt_every=20, log_every=20)
    print(f"target: {tl[0]:.3f} -> {tl[-1]:.3f}")

    print("=== crash-resume: re-invoking continues from step 60 to 80 ===")
    _, tl2 = train("granite-3-2b", steps=80, batch=4, seq=64, lr=2e-3,
                   ckpt_dir=f"{ckpt}/target", ckpt_every=20, log_every=20)
    print(f"resumed {len(tl2)} additional steps")

    print("=== draft (xlstm-350m reduced) with int8 gradient compression ===")
    _, dl = train("xlstm-350m", steps=40, batch=4, seq=64, lr=2e-3,
                  grad_compression="int8", log_every=20)
    print(f"draft: {dl[0]:.3f} -> {dl[-1]:.3f}")
    print(f"checkpoints under {ckpt}")


if __name__ == "__main__":
    main()
