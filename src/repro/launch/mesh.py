"""Production mesh construction.

A FUNCTION (not a module-level constant) so importing this module never
touches jax device state — device counts are locked on first jax init, and
only ``launch/dryrun.py`` sets the 512-host-device XLA flag.
"""

from __future__ import annotations

import jax

__all__ = ["make_production_mesh", "make_test_mesh"]


def make_production_mesh(*, multi_pod: bool = False):
    """16×16 = 256 chips per pod; 2 pods = 512 chips with a leading 'pod' axis."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_test_mesh(data: int = 2, model: int = 4, pod: int = 0):
    """Small mesh for CPU tests (requires xla_force_host_platform_device_count)."""
    if pod:
        return jax.make_mesh((pod, data, model), ("pod", "data", "model"))
    return jax.make_mesh((data, model), ("data", "model"))
