"""Step-function builders shared by train.py / serve.py / dryrun.py.

``build_train_step(cfg, optimizer)``  → train_step(state, batch) -> (state, metrics)
``build_prefill_step(cfg)``           → prefill(params, batch, cache) -> (logits, cache)
``build_decode_step(cfg)``            → decode(params, tokens, cache) -> (logits, cache)
``build_verify_step(cfg)``            → NAV verify: decode K+1 tokens + fused
                                        greedy acceptance (the paper's cloud op)

All are pure functions of pytrees — pjit-ready; sharding is attached by the
callers via in_shardings/out_shardings from ``repro.sharding.partition``.
"""

from __future__ import annotations

from typing import Any, Dict, NamedTuple, Tuple

import jax
import jax.numpy as jnp

from repro.core.spec_decode import verify_greedy
from repro.models import zoo
from repro.models.config import ModelConfig
from repro.optim import Optimizer, clip_by_global_norm


class TrainState(NamedTuple):
    params: Any
    opt_state: Any
    step: jax.Array


def build_train_step(cfg: ModelConfig, optimizer: Optimizer, clip_norm: float = 1.0):
    def train_step(state: TrainState, batch: Dict[str, jax.Array]) -> Tuple[TrainState, Dict[str, jax.Array]]:
        def loss(p):
            l, metrics = zoo.loss_fn(p, batch, cfg)
            return l, metrics

        (l, metrics), grads = jax.value_and_grad(loss, has_aux=True)(state.params)
        grads, gnorm = clip_by_global_norm(grads, clip_norm)
        updates, new_opt = optimizer.update(grads, state.opt_state, state.params, state.step)
        from repro.optim import apply_updates

        new_params = apply_updates(state.params, updates)
        new_state = TrainState(new_params, new_opt, state.step + 1)
        metrics = dict(metrics, loss=l, grad_norm=gnorm)
        return new_state, metrics

    return train_step


def build_prefill_step(cfg: ModelConfig):
    def prefill_step(params, batch: Dict[str, jax.Array], cache):
        return zoo.prefill(params, batch, cache, cfg)

    return prefill_step


def build_decode_step(cfg: ModelConfig):
    def decode_step(params, tokens: jax.Array, cache):
        return zoo.decode(params, tokens, cache, cfg)

    return decode_step


def build_verify_step(cfg: ModelConfig):
    """Cloud NAV (the paper's serve op): forward K+1 tokens against the cache,
    greedy-verify the K drafts, return (n_accepted, correction, new_cache)."""

    def verify_step(params, seq: jax.Array, n_drafted: jax.Array, cache):
        # seq = [last_accepted, d_1..d_K]  → logits verify d_1..d_K + bonus.
        logits, new_cache = zoo.decode(params, seq, cache, cfg)
        vr = verify_greedy(logits, seq[:, 1:], n_drafted)
        return vr.n_accepted, vr.correction, new_cache

    return verify_step
