"""Serving driver: cloud-edge PipeSD serving with a real JAX model pair.

Wires the full stack end-to-end on one host:
* a tiny draft/target model pair (reduced configs, optionally restored from a
  ``train_tiny_pair`` checkpoint so acceptance is meaningful);
* the on-device dual-threshold draft loop (core.spec_decode.draft_round);
* the jitted NAV verify step (launch.steps.build_verify_step);
* the threaded cloud verifier + edge client over the α/β channel;
* the BO autotuner warm-starting (R1, R2).

At pod scale, `build_verify_step` is pjit'd over the production mesh exactly
as the dry-run proves; here it runs on the local device so the example is
executable on CPU.

Usage:
    PYTHONPATH=src python -m repro.launch.serve --arch granite-3-2b --tokens 64
"""

from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import ARCH_IDS, get_config
from repro.core.spec_decode import DraftConfig, SpecDecoder
from repro.models import zoo


def build_pair(arch: str, seed: int = 0):
    """Reduced target + an even smaller draft of the same family."""
    target_cfg = get_config(arch, reduced=True)
    draft_cfg = target_cfg.reduced(
        name=target_cfg.name + "-draft", n_layers=max(1, target_cfg.n_layers // 2),
        layer_kinds=target_cfg.layer_kinds[: max(1, target_cfg.n_layers // 2)] if target_cfg.layer_kinds else (),
        window_sizes=target_cfg.window_sizes[: max(1, target_cfg.n_layers // 2)] if target_cfg.window_sizes else (),
    )
    k1, k2 = jax.random.split(jax.random.PRNGKey(seed))
    return (target_cfg, zoo.init(k1, target_cfg)), (draft_cfg, zoo.init(k2, draft_cfg))


def serve(arch: str = "granite-3-2b", n_tokens: int = 64, batch: int = 2, window: int = 6,
          r1: float = 0.4, r2: float = 0.1, seed: int = 0, greedy: bool = True, params=None):
    (tcfg, tparams), (dcfg, dparams) = build_pair(arch, seed) if params is None else params
    max_len = n_tokens + window * 4 + 32

    from repro.models.kvcache import set_lengths

    def cache_truncate(cache, lengths):
        if hasattr(cache, "lengths") and hasattr(cache, "k"):
            return set_lengths(cache, lengths)
        return cache._replace(lengths=lengths.astype(jnp.int32))

    def draft_step(params, tok, cache):
        logits, new_cache = zoo.decode(params, tok[:, None], cache, dcfg)
        return logits[:, 0, :], new_cache

    def target_forward(params, seq, cache):
        return zoo.decode(params, seq, cache, tcfg)

    dec = SpecDecoder(
        draft_step, target_forward, dparams, tparams,
        DraftConfig(window=window, r1=r1, r2=r2), cache_truncate,
        greedy_verify=greedy, vocab_size=dcfg.padded_vocab_size,
    )
    prompt = jnp.asarray(np.tile(np.arange(1, 9, dtype=np.int32), (batch, 1)))
    batch_d = {"tokens": prompt}
    d_cache = zoo.make_cache(dparams, batch_d, dcfg, max_len)
    t_cache = zoo.make_cache(tparams, batch_d, tcfg, max_len)
    t0 = time.time()
    outputs, trace = dec.generate(
        prompt, d_cache, t_cache,
        prefill_draft=lambda p, b, c: zoo.prefill(p, {"tokens": b}, c, dcfg),
        prefill_target=lambda p, b, c: zoo.prefill(p, {"tokens": b}, c, tcfg),
        max_new_tokens=n_tokens,
        key=jax.random.PRNGKey(seed + 1),
    )
    dt = time.time() - t0
    n_out = sum(len(o) for o in outputs)
    n_drafted = sum(sum(r["n_drafted"]) for r in trace)
    n_acc = sum(sum(r["n_accepted"]) for r in trace)
    stats = dict(
        rounds=len(trace),
        tokens_out=n_out,
        drafted=n_drafted,
        accepted=n_acc,
        acceptance_rate=n_acc / max(n_drafted, 1),
        mean_draft_len=n_drafted / max(len(trace), 1),
        wall_s=dt,
    )
    return outputs, trace, stats


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", choices=ARCH_IDS, default="granite-3-2b")
    ap.add_argument("--tokens", type=int, default=64)
    ap.add_argument("--batch", type=int, default=2)
    ap.add_argument("--window", type=int, default=6)
    args = ap.parse_args()
    _, _, stats = serve(args.arch, n_tokens=args.tokens, batch=args.batch, window=args.window)
    print("serve stats:", {k: round(v, 4) if isinstance(v, float) else v for k, v in stats.items()})


if __name__ == "__main__":
    main()
