import os

os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Perf-iteration helper: re-run one dry-run cell into an iteration directory
and print the roofline-term delta vs the baseline artifact.

    PYTHONPATH=src python -m repro.launch.hillclimb \
        --arch arctic-480b --shape train_4k --mesh pod --tag it1_attn_reshard
"""

import argparse
import json
from pathlib import Path


def main() -> None:
    from repro.launch.dryrun import run_cell
    from repro.roofline import analyze_cell

    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--shape", required=True)
    ap.add_argument("--mesh", default="pod")
    ap.add_argument("--tag", required=True)
    ap.add_argument("--baseline", default="dryrun_results")
    args = ap.parse_args()

    out_dir = Path(f"perf_iters/{args.tag}")
    out_dir.mkdir(parents=True, exist_ok=True)
    rec = run_cell(args.arch, args.shape, args.mesh, out_dir, force=True)
    base_path = Path(args.baseline) / f"{args.arch}__{args.shape}__{args.mesh}.json"
    base = json.loads(base_path.read_text()) if base_path.exists() else None

    def fmt(r):
        c = analyze_cell(r)
        if c is None:
            return f"FAILED/SKIP: {r.get('error', r.get('skipped'))}"
        return (
            f"compute={c.compute_corrected_s*1e3:8.2f}ms memory={c.memory_s*1e3:8.2f}ms "
            f"collective={c.collective_s*1e3:8.2f}ms dominant={c.dominant:10s} "
            f"RLfrac={c.roofline_fraction():6.1%} GiB/dev={c.per_device_gib:6.2f} fits={c.fits}"
        )

    print(f"cell: {args.arch} × {args.shape} × {args.mesh}")
    if base:
        print(f"  before: {fmt(base)}")
    print(f"  after : {fmt(rec)}")
    if base and rec.get("ok") and base.get("ok") and not rec.get("skipped"):
        cb, ca = analyze_cell(base), analyze_cell(rec)
        if cb and ca:
            for term in ("compute_corrected_s", "memory_s", "collective_s"):
                b, a = getattr(cb, term), getattr(ca, term)
                print(f"  Δ{term:22s}: {b*1e3:8.2f} → {a*1e3:8.2f} ms ({(a-b)/max(b,1e-12):+.1%})")


if __name__ == "__main__":
    main()
