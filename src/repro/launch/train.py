"""Training driver: pjit train loop + checkpoint/restart + WSD schedule.

Works at any scale: ``--arch <id> --reduced`` trains a smoke-size model on
CPU; on a real mesh the same code path shards via the Partitioner.  Features
exercised by tests/examples:

* auto-resume from the latest checkpoint (fault tolerance);
* elastic restart: checkpoints are mesh-agnostic (numpy), re-sharded on load;
* optional int8 gradient compression with error feedback;
* AdamW or Adafactor (+ WSD/cosine schedules).

Usage:
    PYTHONPATH=src python -m repro.launch.train --arch granite-3-2b --reduced \
        --steps 50 --batch 8 --seq 128 --ckpt-dir /tmp/ckpt
"""

from __future__ import annotations

import argparse
import time
from pathlib import Path

import jax
import jax.numpy as jnp
import numpy as np

from repro.checkpoint import CheckpointManager
from repro.configs import ARCH_IDS, get_config
from repro.data import ByteTokenizer, DataPipeline, SyntheticCorpus
from repro.launch.steps import TrainState, build_train_step
from repro.models import zoo
from repro.optim import adafactor, adamw, wsd_schedule


def make_state(cfg, seed: int, optimizer):
    params = zoo.init(jax.random.PRNGKey(seed), cfg)
    opt_state = optimizer.init(params)
    return TrainState(params, opt_state, jnp.int32(0))


def train(
    arch: str,
    reduced: bool = True,
    steps: int = 50,
    batch: int = 8,
    seq: int = 128,
    lr: float = 3e-4,
    optimizer_name: str = "adamw",
    ckpt_dir: str | None = None,
    ckpt_every: int = 25,
    grad_compression: str = "none",
    seed: int = 0,
    log_every: int = 10,
    mesh=None,
):
    cfg = get_config(arch, reduced=reduced)
    sched = wsd_schedule(lr, warmup_steps=max(steps // 10, 1), stable_steps=steps // 2, decay_steps=max(steps // 3, 1))
    opt = adamw(sched) if optimizer_name == "adamw" else adafactor(sched)
    step_fn = build_train_step(cfg, opt)

    if grad_compression == "int8":
        from repro.optim import compressed_gradient_transform, init_error_feedback
        from repro.optim.optimizers import apply_updates, clip_by_global_norm
        from repro.models import zoo as _zoo

        def step_fn(state, batch_):  # noqa: F811 — compressed variant
            def loss(p):
                return _zoo.loss_fn(p, batch_, cfg)

            (l, metrics), grads = jax.value_and_grad(loss, has_aux=True)(state.params)
            grads, gnorm = clip_by_global_norm(grads, 1.0)
            grads, new_ef = compressed_gradient_transform(grads, state.opt_state["ef"])
            updates, new_opt = opt.update(grads, state.opt_state["opt"], state.params, state.step)
            new_params = apply_updates(state.params, updates)
            return TrainState(new_params, {"opt": new_opt, "ef": new_ef}, state.step + 1), dict(
                metrics, loss=l, grad_norm=gnorm
            )

    jit_step = jax.jit(step_fn, donate_argnums=0)

    corpus = SyntheticCorpus(dialect="code", seed=seed)
    tok = ByteTokenizer()
    if cfg.vocab_size < tok.vocab_size:
        raise ValueError(f"{arch} reduced vocab {cfg.vocab_size} < tokenizer {tok.vocab_size}")
    pipe = DataPipeline(corpus, tok, batch_size=batch, seq_len=seq, seed=seed)

    mgr = CheckpointManager(Path(ckpt_dir), keep=2) if ckpt_dir else None
    state = make_state(cfg, seed, opt)
    if grad_compression == "int8":
        from repro.optim import init_error_feedback

        state = TrainState(state.params, {"opt": state.opt_state, "ef": init_error_feedback(state.params)}, state.step)
    start_step = 0
    if mgr is not None and mgr.latest_step() is not None:
        state = mgr.restore(jax.eval_shape(lambda: state))
        start_step = int(state.step)
        print(f"[train] resumed from step {start_step}")

    losses = []
    t0 = time.time()
    for i in range(start_step, steps):
        b = pipe.batch_at(i)
        batch_dev = {k: jnp.asarray(v) for k, v in b.items()}
        if cfg.family == "audio":
            batch_dev["frames"] = jax.random.normal(jax.random.PRNGKey(i), (batch, cfg.encoder.n_ctx, cfg.d_model))
        if cfg.family == "vlm":
            batch_dev["vision_embeds"] = jax.random.normal(jax.random.PRNGKey(i), (batch, cfg.n_vision_tokens, cfg.d_model))
        state, metrics = jit_step(state, batch_dev)
        losses.append(float(metrics["loss"]))
        if (i + 1) % log_every == 0:
            print(f"[train] step {i+1}/{steps} loss={losses[-1]:.4f} ({(time.time()-t0)/max(i+1-start_step,1):.2f}s/step)")
        if mgr is not None and (i + 1) % ckpt_every == 0:
            mgr.save(i + 1, state)
    pipe.close()
    return state, losses


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", choices=ARCH_IDS, default="granite-3-2b")
    ap.add_argument("--reduced", action="store_true", default=True)
    ap.add_argument("--steps", type=int, default=50)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--lr", type=float, default=3e-4)
    ap.add_argument("--optimizer", choices=["adamw", "adafactor"], default="adamw")
    ap.add_argument("--ckpt-dir", default=None)
    ap.add_argument("--grad-compression", choices=["none", "int8"], default="none")
    args = ap.parse_args()
    _, losses = train(
        args.arch,
        reduced=args.reduced,
        steps=args.steps,
        batch=args.batch,
        seq=args.seq,
        lr=args.lr,
        optimizer_name=args.optimizer,
        ckpt_dir=args.ckpt_dir,
        grad_compression=args.grad_compression,
    )
    print(f"final loss {losses[-1]:.4f} (from {losses[0]:.4f})")


if __name__ == "__main__":
    main()
