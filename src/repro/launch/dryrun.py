import os

os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Multi-pod dry-run: lower + compile every (arch × shape × mesh) cell.

For each cell this driver builds ShapeDtypeStruct stand-ins for params,
optimizer state, batch and caches (no allocation), attaches PartitionSpecs
from ``repro.sharding.partition``, and runs ``jax.jit(...).lower().compile()``
against the production mesh — 16×16 (single pod) and 2×16×16 (2 pods).
It records ``memory_analysis()`` (proves the cell fits HBM),
``cost_analysis()`` (FLOPs/bytes for the roofline) and the collective-op
byte census parsed from the optimized HLO, as one JSON per cell under
``--out`` (default dryrun_results/), so the sweep is resumable.

Usage:
    PYTHONPATH=src python -m repro.launch.dryrun --all
    PYTHONPATH=src python -m repro.launch.dryrun --arch granite-3-2b --shape train_4k --mesh pod
"""

import argparse
import dataclasses
import json
import re
import time
import traceback
from pathlib import Path

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.configs import ARCH_IDS, SHAPES, VERIFY_K, applicable, get_config, input_specs
from repro.launch.mesh import make_production_mesh
from repro.launch.steps import TrainState, build_decode_step, build_prefill_step, build_train_step
from repro.models import zoo
from repro.optim import adafactor, adamw
from repro.sharding.partition import Partitioner

V5E_HBM_BYTES = 16 * 1024**3
COLLECTIVE_RE = re.compile(
    r"=\s+(?:\([^)]*\)|((?:[a-z0-9]+)\[[0-9,]*\][^ ]*))\s+"
    r"(all-gather|all-reduce|reduce-scatter|all-to-all|collective-permute)\("
)
SHAPE_RE = re.compile(r"([a-z0-9]+)\[([0-9,]*)\]")
TUPLE_SHAPE_RE = re.compile(r"=\s+\(([^)]*)\)\s+(all-gather|all-reduce|reduce-scatter|all-to-all|collective-permute)\(")

_DTYPE_BYTES = {
    "f64": 8, "f32": 4, "f16": 2, "bf16": 2, "f8e4m3fn": 1, "f8e5m2": 1,
    "s64": 8, "u64": 8, "s32": 4, "u32": 4, "s16": 2, "u16": 2, "s8": 1, "u8": 1, "pred": 1,
}


def _shape_bytes(dtype: str, dims: str) -> int:
    n = 1
    for d in dims.split(","):
        if d:
            n *= int(d)
    return n * _DTYPE_BYTES.get(dtype, 4)


def collective_census(hlo_text: str) -> dict:
    """Sum result bytes of every collective op in the optimized HLO, by type."""
    stats: dict = {}
    for line in hlo_text.splitlines():
        m = COLLECTIVE_RE.search(line)
        if not m:
            continue
        op = m.group(2)
        total = 0
        if m.group(1):
            sm = SHAPE_RE.match(m.group(1))
            if sm:
                total = _shape_bytes(sm.group(1), sm.group(2))
        else:
            tm = TUPLE_SHAPE_RE.search(line)
            if tm:
                for sm in SHAPE_RE.finditer(tm.group(1)):
                    total += _shape_bytes(sm.group(1), sm.group(2))
        rec = stats.setdefault(op, {"bytes": 0, "count": 0})
        rec["bytes"] += total
        rec["count"] += 1
    return stats


def _as_cost_dict(cost) -> dict:
    """Older jax returns [dict] from compiled.cost_analysis(), newer a dict."""
    if isinstance(cost, (list, tuple)):
        return cost[0] if cost else {}
    return cost or {}


def _replicated(mesh, tree):
    return jax.tree_util.tree_map(lambda _: NamedSharding(mesh, P()), tree)


# Probe layer counts per family for scan-body scaling (XLA cost_analysis
# counts a while-loop body once; two probes give the per-layer delta so
# FLOPs/bytes/collectives can be scaled to the real depth).
PROBE_LAYERS = {
    "dense": (1, 2), "moe": (1, 2), "vlm": (1, 2), "audio": (1, 2),
    "hybrid": (3, 6), "ssm": (8, 16),
}


def _with_layers(cfg, n: int):
    kw = dict(n_layers=n)
    if cfg.layer_kinds:
        kw["layer_kinds"] = cfg.layer_kinds[:n]
    if cfg.window_sizes:
        kw["window_sizes"] = cfg.window_sizes[:n]
    if cfg.encoder is not None:
        kw["encoder"] = dataclasses.replace(cfg.encoder, n_layers=n)
    return dataclasses.replace(cfg, **kw)


def build_cell(arch: str, shape_name: str, mesh, dtype_override: str = "bfloat16", cfg=None):
    """Returns (step_fn, arg_specs, in_shardings, out_shardings, donate)."""
    if cfg is None:
        cfg = get_config(arch)
    if dtype_override:
        cfg = dataclasses.replace(cfg, dtype=dtype_override, param_dtype=dtype_override)
    shape = SHAPES[shape_name]
    part = Partitioner(mesh)

    key = jax.random.PRNGKey(0)
    params_spec = jax.eval_shape(lambda: zoo.init(key, cfg))
    params_sh = jax.tree_util.tree_map(lambda s: NamedSharding(mesh, s), part.param_specs(params_spec))
    batch_spec = input_specs(cfg, shape, n_tokens=1 if shape.kind == "decode" else None)
    batch_sh = part.batch_shardings(batch_spec)

    if shape.kind == "train":
        opt = adafactor(1e-4) if cfg.param_count() > 5e10 else adamw(1e-4)
        opt_spec = jax.eval_shape(opt.init, params_spec)
        opt_sh = jax.tree_util.tree_map(lambda s: NamedSharding(mesh, s), part.param_specs(opt_spec))
        state_spec = TrainState(params_spec, opt_spec, jax.ShapeDtypeStruct((), jnp.int32))
        state_sh = TrainState(params_sh, opt_sh, NamedSharding(mesh, P()))
        step = build_train_step(cfg, opt)
        metrics_sh = None  # let the compiler choose (scalars)
        return (
            step,
            (state_spec, batch_spec),
            (state_sh, batch_sh),
            (state_sh, metrics_sh),
            (0,),
            cfg,
            part,
        )

    if shape.kind == "prefill":
        cache_spec = zoo.cache_spec(params_spec, batch_spec, cfg, shape.seq_len)
        cache_sh = part.cache_shardings(cache_spec)
        step = build_prefill_step(cfg)
        return (
            step,
            (params_spec, batch_spec, cache_spec),
            (params_sh, batch_sh, cache_sh),
            (None, cache_sh),
            (2,),
            cfg,
            part,
        )

    # decode: one new token against a seq_len KV cache.
    # The cache is built for a prefill-shaped batch, then the step consumes
    # [B, 1] tokens; max_len has headroom for a draft window.
    proto_batch = {"tokens": jax.ShapeDtypeStruct((shape.global_batch, 8), jnp.int32)}
    if cfg.family == "audio":
        proto_batch["frames"] = jax.ShapeDtypeStruct((shape.global_batch, cfg.encoder.n_ctx, cfg.d_model), jnp.dtype(cfg.dtype))
    cache_spec = zoo.cache_spec(params_spec, proto_batch, cfg, shape.seq_len + 64)
    cache_sh = part.cache_shardings(cache_spec)
    tokens_spec = jax.ShapeDtypeStruct((shape.global_batch, 1), jnp.int32)
    tokens_sh = part.batch_shardings(tokens_spec)
    step = build_decode_step(cfg)
    return (
        step,
        (params_spec, tokens_spec, cache_spec),
        (params_sh, tokens_sh, cache_sh),
        (None, cache_sh),
        (2,),
        cfg,
        part,
    )


def run_cell(arch: str, shape_name: str, mesh_kind: str, out_dir: Path, force: bool = False) -> dict:
    out_path = out_dir / f"{arch}__{shape_name}__{mesh_kind}.json"
    if out_path.exists() and not force:
        return json.loads(out_path.read_text())
    cfg = get_config(arch)
    shape = SHAPES[shape_name]
    skip = applicable(cfg, shape)
    rec = {"arch": arch, "shape": shape_name, "mesh": mesh_kind, "ok": False}
    if skip:
        rec.update(ok=True, skipped=skip)
        out_path.write_text(json.dumps(rec, indent=2))
        return rec
    t0 = time.time()
    try:
        mesh = make_production_mesh(multi_pod=(mesh_kind == "multipod"))
        step, arg_specs, in_sh, out_sh, donate, cfg2, part = build_cell(arch, shape_name, mesh)
        with mesh:
            jitted = jax.jit(step, in_shardings=in_sh, out_shardings=out_sh, donate_argnums=donate)
            lowered = jitted.lower(*arg_specs)
            t_lower = time.time() - t0
            compiled = lowered.compile()
            t_compile = time.time() - t0 - t_lower
            mem = compiled.memory_analysis()
            cost = _as_cost_dict(compiled.cost_analysis())
            hlo = compiled.as_text()
        coll = collective_census(hlo)
        # --- probe compiles: scale scan-body metrics to the real depth ------
        # Probes fully unroll every lax.scan (cost_analysis counts a while
        # body once) so flops/bytes/collectives deltas reflect true per-layer
        # costs; the full compile above provides memory_analysis.
        l1, l2 = PROBE_LAYERS[cfg.family]
        probes = {}
        for lp in (l1, l2):
            pcfg = dataclasses.replace(_with_layers(cfg, lp), scan_unroll=True)
            pstep, pargs, pin, pout, pdon, _, _ = build_cell(arch, shape_name, mesh, cfg=pcfg)
            with mesh:
                pcompiled = jax.jit(pstep, in_shardings=pin, out_shardings=pout, donate_argnums=pdon).lower(*pargs).compile()
                pcost = _as_cost_dict(pcompiled.cost_analysis())
                pcoll = collective_census(pcompiled.as_text())
            probes[lp] = {
                "flops": float(pcost.get("flops", 0.0)),
                "bytes": float(pcost.get("bytes accessed", 0.0)),
                "coll_bytes": sum(v["bytes"] for v in pcoll.values()),
                "coll": pcoll,
            }
        steps_n = (cfg.n_layers - l1) / (l2 - l1)
        flops_scaled = probes[l1]["flops"] + steps_n * (probes[l2]["flops"] - probes[l1]["flops"])
        bytes_scaled = probes[l1]["bytes"] + steps_n * (probes[l2]["bytes"] - probes[l1]["bytes"])
        coll_scaled = probes[l1]["coll_bytes"] + steps_n * (probes[l2]["coll_bytes"] - probes[l1]["coll_bytes"])
        n_dev = mesh.size
        mem_rec = {
            "argument_bytes": int(getattr(mem, "argument_size_in_bytes", 0)),
            "output_bytes": int(getattr(mem, "output_size_in_bytes", 0)),
            "temp_bytes": int(getattr(mem, "temp_size_in_bytes", 0)),
            "alias_bytes": int(getattr(mem, "alias_size_in_bytes", 0)),
            "code_bytes": int(getattr(mem, "generated_code_size_in_bytes", 0)),
        }
        per_dev = mem_rec["argument_bytes"] + mem_rec["output_bytes"] + mem_rec["temp_bytes"] - mem_rec["alias_bytes"]
        flops = float(cost.get("flops", 0.0))
        bytes_acc = float(cost.get("bytes accessed", 0.0))
        rec.update(
            ok=True,
            devices=n_dev,
            lower_s=round(t_lower, 1),
            compile_s=round(t_compile, 1),
            memory=mem_rec,
            per_device_bytes=int(per_dev),
            fits_v5e_16g=bool(per_dev <= V5E_HBM_BYTES),
            flops=flops,
            bytes_accessed=bytes_acc,
            collectives={k: v for k, v in sorted(coll.items())},
            collective_bytes=int(sum(v["bytes"] for v in coll.values())),
            flops_scaled=flops_scaled,
            bytes_scaled=bytes_scaled,
            collective_bytes_scaled=int(max(coll_scaled, 0)),
            probes={str(k): {kk: vv for kk, vv in v.items() if kk != "coll"} for k, v in probes.items()},
            sharding_fallbacks=part.fallbacks,
            model_params=cfg.param_count(),
            active_params=cfg.active_param_count(),
        )
    except Exception as e:  # noqa: BLE001 — record the failure, keep sweeping
        rec.update(ok=False, error=f"{type(e).__name__}: {e}", traceback=traceback.format_exc()[-4000:])
    out_path.write_text(json.dumps(rec, indent=2))
    return rec


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", choices=ARCH_IDS)
    ap.add_argument("--shape", choices=list(SHAPES))
    ap.add_argument("--mesh", choices=["pod", "multipod", "both"], default="both")
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--force", action="store_true")
    ap.add_argument("--out", default="dryrun_results")
    args = ap.parse_args()
    out_dir = Path(args.out)
    out_dir.mkdir(exist_ok=True)
    archs = ARCH_IDS if (args.all or not args.arch) else [args.arch]
    shapes = list(SHAPES) if (args.all or not args.shape) else [args.shape]
    meshes = ["pod", "multipod"] if args.mesh == "both" else [args.mesh]
    n_fail = 0
    for arch in archs:
        for shape in shapes:
            for mesh_kind in meshes:
                rec = run_cell(arch, shape, mesh_kind, out_dir, force=args.force)
                status = "SKIP " + rec.get("skipped", "") if rec.get("skipped") else ("OK" if rec["ok"] else "FAIL")
                extra = ""
                if rec.get("ok") and not rec.get("skipped"):
                    extra = (
                        f" per_dev={rec['per_device_bytes']/2**30:.2f}GiB fits={rec['fits_v5e_16g']}"
                        f" flops={rec['flops_scaled']:.3e} coll={rec['collective_bytes']/2**20:.1f}MiB"
                        f" compile={rec['compile_s']}s"
                    )
                if not rec["ok"]:
                    n_fail += 1
                    extra = " " + rec.get("error", "")[:200]
                print(f"[{arch} × {shape} × {mesh_kind}] {status}{extra}", flush=True)
    print(f"\ndry-run complete; failures: {n_fail}")
    raise SystemExit(1 if n_fail else 0)


if __name__ == "__main__":
    main()
