"""Decoder-only transformer LM covering the dense / MoE / VLM families.

One scanned layer stack handles every attention-kind pattern (full/global +
sliding-window layers) because window size and rope theta are per-layer
*scalars* threaded through the scan — so gemma-2's alternating local:global,
gemma-3's 5:1 pattern and plain llama-likes are all the same code path.

Public surface (all pure functions, jit/pjit-ready):

    init(key, cfg)                          -> params
    forward(params, batch, cfg)             -> (logits, aux)     # train/no-cache
    prefill(params, batch, cache, cfg)      -> (logits, cache)
    decode(params, tokens, cache, cfg)      -> (logits, cache)   # T small
    loss_fn(params, batch, cfg)             -> (loss, metrics)
"""

from __future__ import annotations

import functools
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from .config import GLOBAL_WINDOW, ModelConfig
from .kvcache import KVCache, init_kv_cache
from . import layers as L

Params = Dict[str, Any]


# --------------------------------------------------------------------------- #
# init
# --------------------------------------------------------------------------- #


def init(key: jax.Array, cfg: ModelConfig) -> Params:
    dtype = jnp.dtype(cfg.param_dtype)
    n = cfg.n_layers
    keys = jax.random.split(key, 4)
    lkeys = jax.random.split(keys[0], n)

    def one_block(k):
        k1, k2 = jax.random.split(k)
        blk = {
            "ln1": jnp.zeros((cfg.d_model,), dtype),
            "ln2": jnp.zeros((cfg.d_model,), dtype),
            "attn": L.init_attention(k1, cfg),
        }
        if cfg.moe is not None:
            blk["moe"] = L.init_moe(k2, cfg, dtype=dtype)
        else:
            blk["mlp"] = L.init_mlp(k2, cfg.d_model, cfg.d_ff, gated=True, dtype=dtype)
        return blk

    blocks = jax.tree_util.tree_map(lambda *xs: jnp.stack(xs), *[one_block(k) for k in lkeys])
    params: Params = {
        "embed": L.embed_init(keys[1], (cfg.padded_vocab_size, cfg.d_model), dtype),
        "blocks": blocks,
        "final_norm": jnp.zeros((cfg.d_model,), dtype),
    }
    if not cfg.tie_embeddings:
        params["lm_head"] = L.dense_init(keys[2], (cfg.d_model, cfg.padded_vocab_size), dtype=dtype)
    if cfg.n_vision_tokens:
        # VLM stub frontend: learned projection applied to provided patch embeds.
        params["vision_proj"] = L.dense_init(keys[3], (cfg.d_model, cfg.d_model), dtype=dtype)
    return params


def layer_scalars(cfg: ModelConfig) -> Tuple[jax.Array, jax.Array]:
    """(windows[L], thetas[L]) arrays threaded through the layer scan."""
    windows = np.array(cfg.windows, dtype=np.int32)
    thetas = np.full((cfg.n_layers,), cfg.rope_theta, dtype=np.float32)
    if cfg.rope_theta_global is not None:
        thetas[windows >= GLOBAL_WINDOW] = cfg.rope_theta_global
    return jnp.asarray(windows), jnp.asarray(thetas)


# --------------------------------------------------------------------------- #
# embedding / unembedding
# --------------------------------------------------------------------------- #


def embed_inputs(params: Params, batch: Dict[str, jax.Array], cfg: ModelConfig) -> Tuple[jax.Array, jax.Array]:
    """Token (+ stub modality) embedding. Returns (x [B,T,d], positions [B,T])."""
    from repro.sharding.shardctx import constrain

    tokens = batch["tokens"]
    x = jnp.take(params["embed"], tokens, axis=0).astype(jnp.dtype(cfg.dtype))
    if cfg.n_vision_tokens and "vision_embeds" in batch:
        vis = batch["vision_embeds"].astype(x.dtype) @ params["vision_proj"]
        x = jnp.concatenate([vis, x], axis=1)
    B, T, _ = x.shape
    x = constrain(x, [("pod", "data"), None, None])
    positions = jnp.broadcast_to(jnp.arange(T, dtype=jnp.int32)[None, :], (B, T))
    return x, positions


def unembed(params: Params, x: jax.Array, cfg: ModelConfig) -> jax.Array:
    if cfg.tie_embeddings:
        logits = x @ params["embed"].T
    else:
        logits = x @ params["lm_head"]
    logits = logits.astype(jnp.float32)
    if cfg.logit_softcap:
        logits = L.softcap(logits, cfg.logit_softcap)
    if cfg.padded_vocab_size != cfg.vocab_size:
        pad_mask = jnp.arange(cfg.padded_vocab_size) >= cfg.vocab_size
        logits = jnp.where(pad_mask[None, None, :], -1e30, logits)
    return logits


# --------------------------------------------------------------------------- #
# layer stack
# --------------------------------------------------------------------------- #


def _block(
    blk: Params,
    x: jax.Array,
    positions: jax.Array,
    window: jax.Array,
    theta: jax.Array,
    cfg: ModelConfig,
    kv: Optional[Tuple[jax.Array, jax.Array, jax.Array]],
    attn_impl: str,
) -> Tuple[jax.Array, Optional[Tuple[jax.Array, jax.Array]], jax.Array]:
    """Pre-norm residual block with sequence-parallel residual stream.

    The residual (the scan carry, saved by remat for backward) is constrained
    to sequence-sharding over 'model' — Megatron-SP style.  XLA materializes
    the all-gather at the norm→projection boundary and a reduce-scatter after
    the row-parallel out-projection, same volume as the TP all-reduce it
    replaces, while the saved activation shrinks by the TP width.
    """
    from repro.sharding.shardctx import constrain

    dp = ("pod", "data")
    # Sequence-parallel residual stream (Megatron-SP): the scan carry — the
    # tensor remat saves per layer for backward — is S-sharded over 'model',
    # shrinking saved activations by the TP width; XLA inserts the
    # all-gather/reduce-scatter pair at the norm/projection boundaries.
    seq_parallel = x.shape[1] >= 2048
    sp = [dp, "model", None] if seq_parallel else [dp, None, None]
    x = constrain(x, sp)
    h = L.rms_norm(x, blk["ln1"], cfg.norm_eps)
    h = constrain(h, [dp, None, None])  # gather S for attention
    attn_out, new_kv = L.attention_block(
        blk["attn"], h, positions, cfg, theta, window, kv_cache=kv, attn_impl=attn_impl
    )
    x = x + constrain(attn_out, sp)
    h = L.rms_norm(x, blk["ln2"], cfg.norm_eps)
    h = constrain(h, [dp, None, None])
    if cfg.moe is not None:
        ffn_out, aux = L.moe_block(blk["moe"], h, cfg)
    else:
        ffn_out, aux = L.mlp_block(blk["mlp"], h), jnp.float32(0.0)
    x = x + constrain(ffn_out, sp)
    new_kv_out = None if new_kv is None else (new_kv[0], new_kv[1])
    return x, new_kv_out, aux


def _stack(
    params: Params,
    x: jax.Array,
    positions: jax.Array,
    cfg: ModelConfig,
    cache: Optional[KVCache],
    attn_impl: str = "xla",
) -> Tuple[jax.Array, Optional[KVCache], jax.Array]:
    windows, thetas = layer_scalars(cfg)

    if cache is None:

        def body(carry, xs):
            blk, window, theta = xs
            h, _, aux = _block(blk, carry, positions, window, theta, cfg, None, attn_impl)
            return h, aux

        body_fn = jax.checkpoint(body) if cfg.remat else body
        x, auxs = jax.lax.scan(body_fn, x, (params["blocks"], windows, thetas), unroll=cfg.scan_unroll or 1)
        return x, None, jnp.sum(auxs)

    lengths = cache.lengths

    # The KV cache rides in the scan CARRY (updated in-place per layer via
    # dynamic_update_index) rather than as xs→ys streams: while-loop carries
    # alias their buffers, so the multi-GiB cache exists ONCE instead of
    # being double-buffered (input xs + stacked ys) — perf iteration
    # gemma2-decode/it2, see EXPERIMENTS.md §Perf.
    def body_c(carry, xs):
        x, k_all, v_all, i = carry
        blk, window, theta = xs
        k_l = jax.lax.dynamic_index_in_dim(k_all, i, 0, keepdims=False)
        v_l = jax.lax.dynamic_index_in_dim(v_all, i, 0, keepdims=False)
        h, new_kv, aux = _block(blk, x, positions, window, theta, cfg, (k_l, v_l, lengths), attn_impl)
        k_all = jax.lax.dynamic_update_index_in_dim(k_all, new_kv[0], i, 0)
        v_all = jax.lax.dynamic_update_index_in_dim(v_all, new_kv[1], i, 0)
        return (h, k_all, v_all, i + 1), aux

    (x, new_k, new_v, _), auxs = jax.lax.scan(
        body_c, (x, cache.k, cache.v, jnp.int32(0)), (params["blocks"], windows, thetas),
        unroll=cfg.scan_unroll or 1,
    )
    T = positions.shape[1]
    new_cache = KVCache(new_k, new_v, lengths + T)
    return x, new_cache, jnp.sum(auxs)


# --------------------------------------------------------------------------- #
# public entry points
# --------------------------------------------------------------------------- #


def final_hidden(params: Params, batch: Dict[str, jax.Array], cfg: ModelConfig, attn_impl: str = "xla") -> Tuple[jax.Array, jax.Array]:
    """No-cache forward up to the final norm. Returns (hidden [B,T,d], aux)."""
    from repro.sharding.shardctx import constrain

    x, positions = embed_inputs(params, batch, cfg)
    x, _, aux = _stack(params, x, positions, cfg, None, attn_impl)
    x = constrain(x, [("pod", "data"), None, None])  # gather S for chunked CE
    return L.rms_norm(x, params["final_norm"], cfg.norm_eps), aux


def forward(params: Params, batch: Dict[str, jax.Array], cfg: ModelConfig, attn_impl: str = "xla") -> Tuple[jax.Array, jax.Array]:
    """No-cache forward (training / scoring).  Returns (logits, aux_loss)."""
    x, aux = final_hidden(params, batch, cfg, attn_impl)
    return unembed(params, x, cfg), aux


def make_cache(cfg: ModelConfig, batch: int, max_len: int, dtype=None) -> KVCache:
    return init_kv_cache(cfg.n_layers, batch, max_len, cfg.n_kv_heads, cfg.head_dim, dtype or jnp.dtype(cfg.dtype))


def prefill(params: Params, batch: Dict[str, jax.Array], cache: KVCache, cfg: ModelConfig, attn_impl: str = "xla") -> Tuple[jax.Array, KVCache]:
    """Prompt ingestion through the cache path (cache assumed empty)."""
    x, positions = embed_inputs(params, batch, cfg)
    x, new_cache, _ = _stack(params, x, positions, cfg, cache, attn_impl)
    x = L.rms_norm(x, params["final_norm"], cfg.norm_eps)
    return unembed(params, x, cfg), new_cache


def decode(params: Params, tokens: jax.Array, cache: KVCache, cfg: ModelConfig, attn_impl: str = "xla") -> Tuple[jax.Array, KVCache]:
    """Cached decode of T new tokens (T=1 plain decode; T=K+1 NAV verify)."""
    B, T = tokens.shape
    x = jnp.take(params["embed"], tokens, axis=0).astype(jnp.dtype(cfg.dtype))
    positions = cache.lengths[:, None] + jnp.arange(T, dtype=jnp.int32)[None, :]
    x, new_cache, _ = _stack(params, x, positions, cfg, cache)
    x = L.rms_norm(x, params["final_norm"], cfg.norm_eps)
    return unembed(params, x, cfg), new_cache


def loss_fn(params: Params, batch: Dict[str, jax.Array], cfg: ModelConfig) -> Tuple[jax.Array, Dict[str, jax.Array]]:
    """Next-token cross entropy (labels = batch['labels'], -1 = ignore).

    Uses chunked CE so the full [B,S,V] logits are never live (losses.py).
    """
    from .losses import ce_metrics, chunked_ce

    hidden, aux = final_hidden(params, batch, cfg)
    labels = batch["labels"]
    if cfg.n_vision_tokens and "vision_embeds" in batch:
        hidden = hidden[:, -labels.shape[1] :, :]  # score text positions only
    total, n_valid = chunked_ce(hidden, labels, lambda h: unembed(params, h, cfg), unroll=cfg.scan_unroll)
    ce, metrics = ce_metrics(total, n_valid)
    return ce + aux, dict(metrics, aux=aux)
