"""Chunked cross-entropy: never materializes the full [B, S, V] logits.

At train_4k scales the full logits are O(100 TB) (1M tokens × 262k vocab ×
f32); production frameworks compute the loss in sequence chunks inside a
scan so the live buffer is [B, Sc, V].  The chunk body is rematerialized on
the backward pass (jax.checkpoint), so the backward also never holds more
than one chunk of logits.
"""

from __future__ import annotations

from typing import Callable, Dict, Tuple

import jax
import jax.numpy as jnp

CE_CHUNK = 256


def chunked_ce(
    hidden: jax.Array,  # [B, S, d] final hidden states (already normed)
    labels: jax.Array,  # [B, S] (-1 = ignore)
    unembed_fn: Callable[[jax.Array], jax.Array],  # [B, Sc, d] -> [B, Sc, V] f32
    chunk: int = CE_CHUNK,
    unroll: bool = False,
) -> Tuple[jax.Array, jax.Array]:
    """Returns (sum_nll, n_valid)."""
    B, S, d = hidden.shape
    c = min(chunk, S)
    if S % c:
        pad = c - S % c
        hidden = jnp.pad(hidden, ((0, 0), (0, pad), (0, 0)))
        labels = jnp.pad(labels, ((0, 0), (0, pad)), constant_values=-1)
        S = S + pad
    n = S // c
    hs = hidden.reshape(B, n, c, d).transpose(1, 0, 2, 3)  # [n, B, c, d]
    ls = labels.reshape(B, n, c).transpose(1, 0, 2)

    @jax.checkpoint
    def body(carry, xs):
        from repro.sharding.shardctx import constrain

        h, lab = xs
        logits = unembed_fn(h)  # [B, c, V] f32
        # Pin the chunk logits to (batch, ·, vocab-over-model): at 256k vocab
        # an unsharded f32 chunk is ~4 GiB/device and dominates train memory.
        logits = constrain(logits, [("pod", "data"), None, "model"])
        valid = lab >= 0
        lab_c = jnp.maximum(lab, 0)
        logp = jax.nn.log_softmax(logits, axis=-1)
        nll = -jnp.take_along_axis(logp, lab_c[..., None], axis=-1)[..., 0]
        nll = jnp.where(valid, nll, 0.0)
        s, nv = carry
        return (s + jnp.sum(nll), nv + jnp.sum(valid)), None

    (total, n_valid), _ = jax.lax.scan(body, (jnp.float32(0.0), jnp.int32(0)), (hs, ls), unroll=unroll or 1)
    return total, n_valid


def ce_metrics(total: jax.Array, n_valid: jax.Array) -> Tuple[jax.Array, Dict[str, jax.Array]]:
    ce = total / jnp.maximum(n_valid, 1)
    return ce, {"ce": ce, "n_tokens": n_valid}
