"""Shared neural layers for the model zoo (pure JAX, jit/pjit-friendly).

All functions are stateless: parameters are plain nested dicts of arrays so
they stack cleanly for ``lax.scan`` over layers and map 1:1 onto the
PartitionSpec rules in ``repro.sharding.partition``.

Initialization uses fan-in scaled normals (truncated) per common practice.
"""

from __future__ import annotations

import functools
import math
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from .config import GLOBAL_WINDOW, ModelConfig

Params = Dict[str, Any]


# --------------------------------------------------------------------------- #
# init helpers
# --------------------------------------------------------------------------- #


def dense_init(key, shape, in_axis: int = 0, dtype=jnp.float32) -> jax.Array:
    fan_in = shape[in_axis]
    std = 1.0 / math.sqrt(fan_in)
    return (jax.random.truncated_normal(key, -2.0, 2.0, shape) * std).astype(dtype)


def embed_init(key, shape, dtype=jnp.float32) -> jax.Array:
    return (jax.random.normal(key, shape) * 0.02).astype(dtype)


# --------------------------------------------------------------------------- #
# norms / positional encodings / activations
# --------------------------------------------------------------------------- #


def rms_norm(x: jax.Array, scale: jax.Array, eps: float) -> jax.Array:
    dtype = x.dtype
    x = x.astype(jnp.float32)
    var = jnp.mean(x * x, axis=-1, keepdims=True)
    y = x * jax.lax.rsqrt(var + eps)
    return (y * (1.0 + scale.astype(jnp.float32))).astype(dtype)


def rope(x: jax.Array, positions: jax.Array, theta: jax.Array | float) -> jax.Array:
    """Rotary embedding.  x: [..., T, H, hd]; positions: [..., T] (broadcast).

    ``theta`` may be a traced scalar (per-layer theta inside a layer scan).
    """
    hd = x.shape[-1]
    half = hd // 2
    freq = jnp.exp(-jnp.log(jnp.asarray(theta, jnp.float32)) * (jnp.arange(half, dtype=jnp.float32) / half))
    ang = positions[..., None].astype(jnp.float32) * freq  # [..., T, half]
    sin, cos = jnp.sin(ang)[..., None, :], jnp.cos(ang)[..., None, :]  # [..., T, 1, half]
    x1, x2 = x[..., :half], x[..., half:]
    out = jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)
    return out.astype(x.dtype)


def sinusoidal_positions(n_ctx: int, d_model: int) -> jax.Array:
    """Whisper-style sinusoidal table [n_ctx, d_model]."""
    half = d_model // 2
    freq = jnp.exp(-jnp.log(10_000.0) * jnp.arange(half, dtype=jnp.float32) / (half - 1))
    ang = jnp.arange(n_ctx, dtype=jnp.float32)[:, None] * freq[None, :]
    return jnp.concatenate([jnp.sin(ang), jnp.cos(ang)], axis=-1)


def softcap(x: jax.Array, cap: float) -> jax.Array:
    """Gemma-2 logit soft-capping: cap·tanh(x/cap)."""
    return cap * jnp.tanh(x / cap)


# --------------------------------------------------------------------------- #
# attention
# --------------------------------------------------------------------------- #


def init_attention(key, cfg: ModelConfig, cross: bool = False) -> Params:
    dtype = jnp.dtype(cfg.param_dtype)
    ks = jax.random.split(key, 4)
    d, qd, kvd = cfg.d_model, cfg.q_dim, cfg.kv_dim
    return {
        "wq": dense_init(ks[0], (d, qd), dtype=dtype),
        "wk": dense_init(ks[1], (d, kvd), dtype=dtype),
        "wv": dense_init(ks[2], (d, kvd), dtype=dtype),
        "wo": dense_init(ks[3], (qd, d), in_axis=0, dtype=dtype),
    }


def _expand_kv(k: jax.Array, n_heads: int, n_kv: int) -> jax.Array:
    """GQA: repeat kv heads to match query heads. [..., T, Hkv, hd] -> [..., T, H, hd]."""
    if n_heads == n_kv:
        return k
    rep = n_heads // n_kv
    return jnp.repeat(k, rep, axis=-2)


def attend(
    q: jax.Array,  # [B, Tq, H, hd]
    k: jax.Array,  # [B, Tk, Hkv, hd]  (Hkv may divide H — GQA handled natively)
    v: jax.Array,  # [B, Tk, Hkv, hd]
    q_pos: jax.Array,  # [B, Tq] absolute positions of queries
    k_pos: jax.Array,  # [B, Tk] absolute positions of keys
    kv_valid: jax.Array,  # [B, Tk] bool — key slot holds real data
    window: jax.Array | int,  # sliding window (GLOBAL_WINDOW => full)
    causal: bool = True,
    attn_softcap: float = 0.0,
) -> jax.Array:
    """Masked scaled-dot-product attention with sliding-window + softcap.

    GQA is computed *without expanding* K/V: q reshapes to [B,Tq,Hkv,G,hd]
    and the einsums carry the group dim — on the decode path this reads the
    KV cache once instead of H/Hkv times (2–4× less HBM traffic) and never
    materializes an expanded cache copy.

    ``window`` may be a traced per-layer scalar so one scanned layer stack can
    mix local and global attention (gemma-2/3 patterns).
    """
    B, Tq, H, hd = q.shape
    Hkv = k.shape[2]
    G = H // Hkv
    qg = q.astype(jnp.float32).reshape(B, Tq, Hkv, G, hd)
    scores = jnp.einsum("bqhgd,bkhd->bhgqk", qg, k.astype(jnp.float32))
    scores = scores / math.sqrt(hd)
    if attn_softcap:
        scores = softcap(scores, attn_softcap)
    mask = kv_valid[:, None, None, None, :]  # [B,1,1,1,Tk]
    dist = q_pos[:, None, None, :, None] - k_pos[:, None, None, None, :]
    if causal:
        mask = jnp.logical_and(mask, dist >= 0)
    mask = jnp.logical_and(mask, dist < window)  # window=GLOBAL => no-op
    scores = jnp.where(mask, scores, -1e30)
    probs = jax.nn.softmax(scores, axis=-1)
    out = jnp.einsum("bhgqk,bkhd->bqhgd", probs, v.astype(jnp.float32))
    return out.reshape(B, Tq, H, hd).astype(q.dtype)


Q_CHUNK = 512
K_CHUNK = 1024


@functools.lru_cache(maxsize=None)
def _flash_xla(causal: bool, attn_softcap: float, bq: int, bk: int, unroll: bool):
    """Factory for the custom-VJP flash attention on blocked inputs.

    Forward: online-softmax over k blocks (O(bq·bk) live memory), saving only
    (q, k, v, out, lse).  Backward: FlashAttention-2 style — recomputes P per
    block from the saved LSE and accumulates dq / dk / dv in two block sweeps,
    so no per-block softmax residuals are ever stored (a naive scan VJP saves
    ~nq·nk score blocks ≈ 100 GiB/device at train_4k scale).

    Blocked layouts: q [nq,B,H,bq,hd]; k,v [nk,B,H,bk,hd]; window f32 scalar.
    Returns (out [nq,B,H,bq,hd], lse [nq,B,H,bq]).
    """

    def _mask(qi, ki, s):
        qpos = qi * bq + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 0)
        kpos = ki * bk + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 1)
        dist = (qpos - kpos).astype(jnp.float32)
        return lambda window: (
            jnp.logical_and(dist < window, dist >= 0) if causal else (dist < window)
        )

    def _scores(qblk, kblk, qi, ki, window):
        s = jnp.einsum("bhqd,bhkd->bhqk", qblk, kblk)
        if attn_softcap:
            s = softcap(s, attn_softcap)
        mask = _mask(qi, ki, s)(window)
        return jnp.where(mask[None, None], s, -1e30), mask

    def _needed(qi, ki, window):
        first_q, last_q = qi * bq, qi * bq + bq - 1
        first_k, last_k = ki * bk, ki * bk + bk - 1
        needed = (first_q - last_k) < window
        if causal:
            needed = jnp.logical_and(needed, last_q - first_k >= 0)
        return needed

    def fwd_blocks(qb, kb, vb, window):
        nq, nk = qb.shape[0], kb.shape[0]
        B, H = qb.shape[1], qb.shape[2]
        hd = qb.shape[-1]

        def q_block(_, qi_qblk):
            qi, qblk = qi_qblk

            def k_block(state, ki_kv):
                ki, kblk, vblk = ki_kv
                m, l, acc = state

                def compute(_):
                    s, _ = _scores(qblk, kblk, qi, ki, window)
                    m_new = jnp.maximum(m, jnp.max(s, -1))
                    p_ = jnp.exp(s - m_new[..., None])
                    alpha = jnp.exp(m - m_new)
                    l_new = alpha * l + jnp.sum(p_, -1)
                    acc_new = acc * alpha[..., None] + jnp.einsum("bhqk,bhkd->bhqd", p_, vblk)
                    return m_new, l_new, acc_new

                return jax.lax.cond(_needed(qi, ki, window), compute, lambda _: (m, l, acc), None), None

            m0 = jnp.full((B, H, bq), -1e30, jnp.float32)
            l0 = jnp.zeros((B, H, bq), jnp.float32)
            a0 = jnp.zeros((B, H, bq, hd), jnp.float32)
            (m, l, acc), _ = jax.lax.scan(k_block, (m0, l0, a0), (jnp.arange(nk), kb, vb), unroll=unroll)
            out = acc / jnp.maximum(l, 1e-30)[..., None]
            lse = m + jnp.log(jnp.maximum(l, 1e-30))
            return None, (out, lse)

        _, (out, lse) = jax.lax.scan(q_block, None, (jnp.arange(nq), qb), unroll=unroll)
        return out, lse

    @jax.custom_vjp
    def flash(qb, kb, vb, window):
        return fwd_blocks(qb, kb, vb, window)[0]

    def flash_fwd(qb, kb, vb, window):
        out, lse = fwd_blocks(qb, kb, vb, window)
        return out, (qb, kb, vb, out, lse, window)

    def _p_and_ds(qblk, kblk, qi, ki, window, lse_q, do_blk, vblk, D_q):
        """Recompute P for one block; return (P, dS_raw) in f32."""
        s_capped, mask = _scores(qblk, kblk, qi, ki, window)
        p_ = jnp.exp(s_capped - lse_q[..., None])
        p_ = jnp.where(mask[None, None], p_, 0.0)
        dp = jnp.einsum("bhqd,bhkd->bhqk", do_blk, vblk)
        ds = p_ * (dp - D_q[..., None])
        if attn_softcap:
            # s_capped = cap·tanh(x/cap): dx = ds · (1 − (s_capped/cap)²).
            # Clip first: masked entries hold −1e30 and would otherwise
            # produce inf²·0 = NaN; clipping makes their factor exactly 0.
            sc = jnp.clip(s_capped, -attn_softcap, attn_softcap)
            ds = ds * (1.0 - jnp.square(sc / attn_softcap))
        return p_, ds

    def flash_bwd(res, do):
        qb, kb, vb, out, lse, window = res
        nq, nk = qb.shape[0], kb.shape[0]
        D = jnp.sum(do * out, axis=-1)  # [nq,B,H,bq]

        # Pass A — dq: sweep q blocks, accumulate over k blocks.
        def q_pass(_, xs):
            qi, qblk, do_blk, lse_q, D_q = xs

            def k_in(dq, ki_kv):
                ki, kblk, vblk = ki_kv

                def compute(dq):
                    _, ds = _p_and_ds(qblk, kblk, qi, ki, window, lse_q, do_blk, vblk, D_q)
                    return dq + jnp.einsum("bhqk,bhkd->bhqd", ds, kblk)

                return jax.lax.cond(_needed(qi, ki, window), compute, lambda d: d, dq), None

            dq0 = jnp.zeros_like(qblk)
            dq, _ = jax.lax.scan(k_in, dq0, (jnp.arange(nk), kb, vb), unroll=unroll)
            return None, dq

        _, dqb = jax.lax.scan(q_pass, None, (jnp.arange(nq), qb, do, lse, D), unroll=unroll)

        # Pass B — dk, dv: sweep k blocks, accumulate over q blocks.
        def k_pass(_, xs):
            ki, kblk, vblk = xs

            def q_in(carry, qi_q):
                qi, qblk, do_blk, lse_q, D_q = qi_q
                dk, dv = carry

                def compute(c):
                    dk, dv = c
                    p_, ds = _p_and_ds(qblk, kblk, qi, ki, window, lse_q, do_blk, vblk, D_q)
                    dk = dk + jnp.einsum("bhqk,bhqd->bhkd", ds, qblk)
                    dv = dv + jnp.einsum("bhqk,bhqd->bhkd", p_, do_blk)
                    return dk, dv

                return jax.lax.cond(_needed(qi, ki, window), compute, lambda c: c, (dk, dv)), None

            z = (jnp.zeros_like(kblk), jnp.zeros_like(vblk))
            (dk, dv), _ = jax.lax.scan(q_in, z, (jnp.arange(nq), qb, do, lse, D), unroll=unroll)
            return None, (dk, dv)

        _, (dkb, dvb) = jax.lax.scan(k_pass, None, (jnp.arange(nk), kb, vb), unroll=unroll)
        return dqb, dkb, dvb, jnp.zeros_like(window)

    flash.defvjp(flash_fwd, flash_bwd)
    return flash


def attend_chunked(
    q: jax.Array,  # [B, Tq, H, hd]
    k: jax.Array,  # [B, Tk, H, hd] (GQA-expanded)
    v: jax.Array,
    window: jax.Array | int,
    causal: bool = True,
    attn_softcap: float = 0.0,
    q_chunk: int = Q_CHUNK,
    k_chunk: int = K_CHUNK,
    unroll: bool = False,
) -> jax.Array:
    """Flash attention in pure XLA with a flash backward (see _flash_xla).

    Fully-masked key blocks (causal-future / beyond-window) are skipped with
    ``lax.cond`` so sliding-window layers don't pay quadratic FLOPs.  This is
    the HLO-level mirror of the Pallas kernel in repro.kernels.flash_attention
    — used for sharded train/prefill; the Pallas kernel remains the
    single-chip TPU fast path.
    """
    B, Tq, H, hd = q.shape
    Tk = k.shape[1]
    bq = min(q_chunk, Tq)
    bk = min(k_chunk, Tk)
    if Tq % bq or Tk % bk:
        # fall back to naive for ragged tiny shapes (tests)
        pos_q = jnp.broadcast_to(jnp.arange(Tq)[None], (B, Tq))
        pos_k = jnp.broadcast_to(jnp.arange(Tk)[None], (B, Tk))
        return attend(q, k, v, pos_q, pos_k, jnp.ones((B, Tk), bool), window, causal, attn_softcap)
    nq, nk = Tq // bq, Tk // bk
    scale = 1.0 / math.sqrt(hd)
    qb = (q.astype(jnp.float32) * scale).reshape(B, nq, bq, H, hd).transpose(1, 0, 3, 2, 4)
    kb = k.astype(jnp.float32).reshape(B, nk, bk, H, hd).transpose(1, 0, 3, 2, 4)
    vb = v.astype(jnp.float32).reshape(B, nk, bk, H, hd).transpose(1, 0, 3, 2, 4)
    w = jnp.asarray(window, jnp.float32)
    flash = _flash_xla(causal, float(attn_softcap), bq, bk, unroll)
    outs = flash(qb, kb, vb, w)  # [nq,B,H,bq,hd]
    return outs.transpose(1, 0, 3, 2, 4).reshape(B, Tq, H, hd).astype(q.dtype)


def _cache_insert(cache: jax.Array, new: jax.Array, lengths: jax.Array) -> jax.Array:
    """Insert [B,T,...] entries at per-lane offsets into [B,S,...] cache.

    Formulated as gather+select (pointwise over the cache) rather than a
    per-lane scatter: fuses under XLA and — critically — preserves the cache
    sharding under SPMD (scatters force involuntary rematerialization).
    """
    B, S = cache.shape[:2]
    T = new.shape[1]
    pos = jnp.arange(S, dtype=jnp.int32)[None, :]
    rel = pos - lengths[:, None]
    in_window = jnp.logical_and(rel >= 0, rel < T)
    idx = jnp.clip(rel, 0, T - 1)  # [B, S]
    tail = (None,) * (cache.ndim - 2)
    gathered = jnp.take_along_axis(new.astype(cache.dtype), idx[(...,) + tail], axis=1)
    return jnp.where(in_window[(...,) + tail], gathered, cache)


def attention_block(
    p: Params,
    x: jax.Array,  # [B, T, d]
    positions: jax.Array,  # [B, T]
    cfg: ModelConfig,
    theta: jax.Array | float,
    window: jax.Array | int,
    kv_cache: Optional[Tuple[jax.Array, jax.Array, jax.Array]] = None,
    # (k_cache [B,S,Hkv,hd], v_cache, lengths [B]) — prefill/decode path
    causal: bool = True,
    attn_impl: str = "xla",
) -> Tuple[jax.Array, Optional[Tuple[jax.Array, jax.Array, jax.Array]]]:
    """Self-attention with optional KV cache. Returns (out [B,T,d], new_cache).

    Three regimes:
    * no cache (train/scoring): chunked flash-style attention for large T.
    * cache + large T (prefill): the cache must be empty — attention is pure
      self-attention over the incoming tokens (chunked), and K/V are inserted
      into the cache.  This avoids quadratic attend-over-cache memory.
    * cache + small T (decode/NAV verify): insert K/V, then attend over the
      full cache (flash-decode: the cache's sequence dim may be sharded; the
      softmax over the sharded dim lowers to cheap partial-reduce collectives).

    TP layout (applied via ambient-mesh constraints, no-ops when un-meshed):
    q/k/v are GQA-expanded then head-sharded over 'model' when divisible —
    attention then runs with zero collectives and wo's row-parallel matmul
    contributes the block's single all-reduce.
    """
    from repro.sharding.shardctx import constrain

    B, T, _ = x.shape
    H, Hkv, hd = cfg.n_heads, cfg.n_kv_heads, cfg.head_dim
    dp = ("pod", "data")
    q = (x @ p["wq"]).reshape(B, T, H, hd)
    k = (x @ p["wk"]).reshape(B, T, Hkv, hd)
    v = (x @ p["wv"]).reshape(B, T, Hkv, hd)
    q = rope(q, positions, theta)
    k = rope(k, positions, theta)

    large_t = T >= 1024

    if kv_cache is None or large_t:
        # Self-attention over the incoming tokens.  Layout choice (per mesh):
        #  1. heads divisible by the model axis → head-sharded TP (zero
        #     collectives inside attention, wo row-parallel all-reduce);
        #  2. heads NOT divisible but batch divisible by data×model → reshard
        #     the batch over BOTH axes for the attention region ("DP-for-
        #     attention, TP-for-FFN" hybrid): attention is fully local per
        #     device; entry/exit resharding is an all-to-all of activations —
        #     far cheaper than replicating q/k/v over the model axis
        #     (arctic 56H, minicpm 36H, whisper 20H, griffin 10H);
        #  3. otherwise replicate over model (recorded fallback).
        from repro.sharding.shardctx import ambient_mesh, axis_size

        kk = _expand_kv(k, H, Hkv)
        vv = _expand_kv(v, H, Hkv)
        mesh = ambient_mesh()
        spec = [dp, None, "model", None]
        if mesh is not None:
            names = set(mesh.axis_names)
            msize = axis_size(mesh, tuple(a for a in ("model",) if a in names))
            dp_names = tuple(a for a in dp if a in names)
            dsize = axis_size(mesh, dp_names) if dp_names else 1
            # NOTE (perf log, §Perf arctic/it1 + rgemma/it1): a batch-reshard
            # hybrid ("DP-for-attention" over data×model when H doesn't divide
            # the model axis) was tried here and REFUTED — XLA SPMD lowers the
            # (data)→(data×model) resharding as involuntary full
            # rematerialization (+188 % collective bytes on arctic train_4k).
            # A manual shard_map all_to_all could realize it; until then the
            # divisibility fallback (replicate heads over 'model') stands.
            if False and H % msize != 0 and H >= msize and B % (dsize * msize) == 0:
                spec = [dp_names + ("model",), None, None, None]
        q_c = constrain(q, spec)
        kk = constrain(kk, spec)
        vv = constrain(vv, spec)
        if attn_impl == "pallas" and causal and T % 128 == 0 and isinstance(window, int):
            from repro.kernels.flash_attention import ops as fa_ops

            out = fa_ops.flash_attention(q_c, kk, vv, window=window, softcap=cfg.attn_softcap, impl="pallas")
        elif large_t:
            # Probe compiles (scan_unroll) use coarse chunks: attention FLOPs
            # are chunk-independent, and nq·nk unrolled cond blocks at 32k
            # would explode compile time (64×32 → 4×4).
            qc = max(Q_CHUNK, T // 4) if cfg.scan_unroll else Q_CHUNK
            kc = max(K_CHUNK, T // 4) if cfg.scan_unroll else K_CHUNK
            out = attend_chunked(q_c, kk, vv, window, causal, cfg.attn_softcap,
                                 q_chunk=qc, k_chunk=kc, unroll=cfg.scan_unroll)
        else:
            out = attend(q_c, kk, vv, positions, positions, jnp.ones((B, T), bool), window, causal, cfg.attn_softcap)
        new_cache = None
        if kv_cache is not None:  # prefill: fill the cache (assumed empty)
            k_cache, v_cache, lengths = kv_cache
            k_cache = _cache_insert(k_cache, k, lengths)
            v_cache = _cache_insert(v_cache, v, lengths)
            new_cache = (k_cache, v_cache, lengths + T)
    else:
        k_cache, v_cache, lengths = kv_cache
        S = k_cache.shape[1]
        k_cache = _cache_insert(k_cache, k, lengths)
        v_cache = _cache_insert(v_cache, v, lengths)
        kpos = jnp.arange(S)[None, :].astype(jnp.int32)
        kv_valid = kpos < (lengths[:, None] + T)
        # GQA-native attend: the cache is read once, never expanded to H heads
        # (perf iteration gemma2-decode/it1 — see EXPERIMENTS.md §Perf).
        out = attend(
            q, k_cache.astype(q.dtype), v_cache.astype(q.dtype),
            positions, jnp.broadcast_to(kpos, (B, S)), kv_valid, window, causal, cfg.attn_softcap,
        )
        new_cache = (k_cache, v_cache, lengths + T)
    out = out.reshape(B, T, H * hd) @ p["wo"]
    return out, new_cache


def init_cross_attention(key, cfg: ModelConfig) -> Params:
    return init_attention(key, cfg, cross=True)


def cross_attention_block(
    p: Params,
    x: jax.Array,  # [B, T, d] decoder states
    enc_kv: Tuple[jax.Array, jax.Array],  # precomputed ([B,S,Hkv,hd], [B,S,Hkv,hd])
    cfg: ModelConfig,
) -> jax.Array:
    B, T, _ = x.shape
    H, Hkv, hd = cfg.n_heads, cfg.n_kv_heads, cfg.head_dim
    q = (x @ p["wq"]).reshape(B, T, H, hd)
    k, v = enc_kv
    S = k.shape[1]
    kk = _expand_kv(k, H, Hkv).astype(q.dtype)
    vv = _expand_kv(v, H, Hkv).astype(q.dtype)
    pos_q = jnp.zeros((B, T), jnp.int32)
    pos_k = jnp.zeros((B, S), jnp.int32)
    valid = jnp.ones((B, S), bool)
    out = attend(q, kk, vv, pos_q, pos_k, valid, GLOBAL_WINDOW, causal=False)
    return out.reshape(B, T, H * hd) @ p["wo"]


def encoder_kv(p: Params, enc_out: jax.Array, cfg: ModelConfig) -> Tuple[jax.Array, jax.Array]:
    """Precompute cross-attention K/V from encoder output (cached per request)."""
    B, S, _ = enc_out.shape
    k = (enc_out @ p["wk"]).reshape(B, S, cfg.n_kv_heads, cfg.head_dim)
    v = (enc_out @ p["wv"]).reshape(B, S, cfg.n_kv_heads, cfg.head_dim)
    return k, v


# --------------------------------------------------------------------------- #
# MLPs
# --------------------------------------------------------------------------- #


def init_mlp(key, d_model: int, d_ff: int, gated: bool = True, dtype=jnp.float32) -> Params:
    ks = jax.random.split(key, 3)
    p = {
        "w_up": dense_init(ks[0], (d_model, d_ff), dtype=dtype),
        "w_down": dense_init(ks[1], (d_ff, d_model), dtype=dtype),
    }
    if gated:
        p["w_gate"] = dense_init(ks[2], (d_model, d_ff), dtype=dtype)
    return p


def mlp_block(p: Params, x: jax.Array) -> jax.Array:
    """Gated MLP with Megatron col→row parallel activation constraints.

    The hidden [*, f] is pinned to (data-parallel, …, 'model') so XLA gathers
    the (FSDP-sharded) weights rather than un-sharding the activations — the
    activation tensor is batch·seq-dominant and must stay data-sharded.
    """
    from repro.sharding.shardctx import constrain

    dp = ("pod", "data")
    h_spec = [dp] + [None] * (x.ndim - 2) + ["model"]
    if "w_gate" in p:
        g = constrain(x @ p["w_gate"], h_spec)
        u = constrain(x @ p["w_up"], h_spec)
        h = jax.nn.silu(g) * u  # SwiGLU
    else:
        h = jax.nn.gelu(constrain(x @ p["w_up"], h_spec))
    out = h @ p["w_down"]
    return constrain(out, [dp] + [None] * (x.ndim - 1))


# --------------------------------------------------------------------------- #
# Mixture of Experts
# --------------------------------------------------------------------------- #


def init_moe(key, cfg: ModelConfig, dtype=jnp.float32) -> Params:
    assert cfg.moe is not None
    m = cfg.moe
    ks = jax.random.split(key, 5)
    d, e, f = cfg.d_model, m.n_experts, m.d_ff_expert
    p = {
        "router": dense_init(ks[0], (d, e), dtype=jnp.float32),  # router in fp32
        "w_gate": dense_init(ks[1], (e, d, f), in_axis=1, dtype=dtype),
        "w_up": dense_init(ks[2], (e, d, f), in_axis=1, dtype=dtype),
        "w_down": dense_init(ks[3], (e, f, d), in_axis=1, dtype=dtype),
    }
    if m.dense_residual:
        p["dense"] = init_mlp(ks[4], d, m.d_ff_dense, gated=True, dtype=dtype)
    return p




def moe_block(p: Params, x: jax.Array, cfg: ModelConfig) -> Tuple[jax.Array, jax.Array]:
    """Top-k token-choice MoE with GShard-style capacity dispatch.

    Tokens are split into groups of ``MOE_GROUP_SIZE`` along the sequence dim
    (the group axis inherits the batch's 'data' sharding, so groups process in
    parallel across data shards).  Within a group each token's top-k experts
    get a capacity slot (C = g·k·cf/E) via cumulative position counting, and
    dispatch/combine are one-hot einsums — the classic TPU MoE formulation:
    the [n,E,C,d] expert batch shards over the 'model' (expert) mesh axis with
    static shapes; the combine einsum's expert-sum is the layer's all-reduce
    under SPMD.  Dispatch+combine einsum overhead is 2·k·g·d FLOPs/token
    (≈20 % of expert FLOPs for qwen3's f=768, ≈4 % for arctic) — recorded in
    the roofline's MODEL_FLOPS/HLO_FLOPS ratio.

    Returns (out, aux_load_balance_loss).
    """
    assert cfg.moe is not None
    m = cfg.moe
    B, T, d = x.shape
    E, k = m.n_experts, m.top_k
    g = min(m.group_size, T)
    Tg = (T + g - 1) // g
    pad = Tg * g - T
    if pad:
        x_p = jnp.concatenate([x, jnp.zeros((B, pad, d), x.dtype)], axis=1)
    else:
        x_p = x
    xg = x_p.reshape(B * Tg, g, d)  # [n, g, d] — n sharded over data with B
    C = max(1, int(math.ceil(g * k * m.capacity_factor / E)))

    logits = xg.astype(jnp.float32) @ p["router"].astype(jnp.float32)  # [n,g,E]
    probs = jax.nn.softmax(logits, axis=-1)
    vals, idx = jax.lax.top_k(probs, k)  # [n,g,k]
    vals = vals / jnp.maximum(vals.sum(-1, keepdims=True), 1e-9)
    counts = jnp.zeros((xg.shape[0], E), jnp.int32)
    dispatch = jnp.zeros((xg.shape[0], g, E, C), jnp.float32)
    for j in range(k):  # GShard choice-order capacity assignment (k unrolled)
        onehot_j = jax.nn.one_hot(idx[:, :, j], E, dtype=jnp.int32)  # [n,g,E]
        pos_in_e = jnp.cumsum(onehot_j, axis=1) - 1 + counts[:, None, :]
        pos_j = jnp.sum(pos_in_e * onehot_j, axis=-1)  # [n,g]
        keep = pos_j < C
        slot = jax.nn.one_hot(jnp.where(keep, pos_j, C), C + 1, dtype=jnp.float32)[..., :C]
        dispatch = dispatch + (
            vals[:, :, j, None, None] * onehot_j.astype(jnp.float32)[..., None] * slot[:, :, None, :]
        )
        counts = counts + jnp.sum(onehot_j, axis=1)
    from repro.sharding.shardctx import constrain

    dp = ("pod", "data")
    # Dispatch/combine tensors in the activation dtype: they only carry 0/1
    # routing and top-k combine weights (≤ k terms per sum) — halves the
    # largest MoE transients under bf16 activations (dry-run numerics).
    dispatch16 = dispatch.astype(x.dtype)
    sel = (dispatch > 0).astype(xg.dtype)  # 0/1 routing mask
    sel = constrain(sel, [dp, None, "model", None])
    xe = jnp.einsum("ngd,ngec->necd", xg, sel)  # [n,E,C,d]
    xe = constrain(xe, [dp, "model", None, None])
    hg = jax.nn.silu(jnp.einsum("necd,edf->necf", xe, p["w_gate"]))
    hu = jnp.einsum("necd,edf->necf", xe, p["w_up"])
    hh = constrain(hg * hu, [dp, "model", None, None])
    ye = jnp.einsum("necf,efd->necd", hh, p["w_down"])  # [n,E,C,d]
    ye = constrain(ye, [dp, "model", None, None])
    out = jnp.einsum("necd,ngec->ngd", ye.astype(x.dtype), dispatch16)
    out = constrain(out, [dp, None, None])
    out = out.astype(x.dtype).reshape(B, Tg * g, d)[:, :T, :]
    if m.dense_residual:
        out = out + mlp_block(p["dense"], x)
    # Load-balance aux loss (Switch-style): E · Σ_e f_e · P_e.
    f_e = jnp.mean(jax.nn.one_hot(idx[..., 0], E, dtype=jnp.float32), axis=(0, 1))
    p_e = jnp.mean(probs, axis=(0, 1))
    aux = m.n_experts * jnp.sum(f_e * p_e) * m.load_balance_weight
    return out, aux
