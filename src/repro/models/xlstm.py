"""xLSTM stack (arXiv:2405.04517): mLSTM + sLSTM blocks, 7:1 pattern.

* **mLSTM** — matrix-memory LSTM with exponential gating.  Implemented in the
  *chunkwise-parallel* form (the sub-quadratic TPU-native formulation): the
  sequence is split into chunks of ``cfg.mlstm_chunk``; within a chunk the
  contribution is a masked decay-weighted attention; across chunks a recurrent
  state (C [hd,hd], n [hd], m stabilizer) is carried by ``lax.scan``.  Decode
  uses the same code with chunk = T (T=1), i.e. the pure recurrence.
* **sLSTM** — scalar-memory LSTM with recurrent gate connections (block-
  diagonal per head), necessarily sequential: ``lax.scan`` over time.

State is O(1) in sequence length → this family runs the ``long_500k`` decode
shape.  Stabilizers follow the standard max-trick bookkeeping: stored (C, n)
are *unscaled*; true values are (C·eᵐ, n·eᵐ).
"""

from __future__ import annotations

from typing import Any, Dict, NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp

from .config import ModelConfig
from . import layers as L

Params = Dict[str, Any]


class XLSTMCache(NamedTuple):
    m_C: jax.Array  # [L_m, B, H, hd, hd]
    m_n: jax.Array  # [L_m, B, H, hd]
    m_m: jax.Array  # [L_m, B, H]
    s_c: jax.Array  # [L_s, B, d]
    s_n: jax.Array  # [L_s, B, d]
    s_h: jax.Array  # [L_s, B, d]
    s_m: jax.Array  # [L_s, B, d]
    lengths: jax.Array  # [B]


def _pattern(cfg: ModelConfig) -> Tuple[int, int, int]:
    """(n_groups, m_per_group, s_per_group) for the (m×a, s×b)* pattern."""
    kinds = cfg.kinds
    # Find the group: leading run of 'mlstm' then run of 'slstm'.
    a = 0
    while a < len(kinds) and kinds[a] == "mlstm":
        a += 1
    b = a
    while b < len(kinds) and kinds[b] == "slstm":
        b += 1
    glen = b
    if glen == 0 or len(kinds) % glen != 0:
        raise ValueError(f"{cfg.name}: kinds not a repeating (mlstm*, slstm*) pattern: {kinds}")
    G = len(kinds) // glen
    if tuple(kinds) != tuple(list(kinds[:glen]) * G):
        raise ValueError(f"{cfg.name}: kinds not periodic: {kinds}")
    return G, a, glen - a


# --------------------------------------------------------------------------- #
# mLSTM
# --------------------------------------------------------------------------- #


def init_mlstm(key, cfg: ModelConfig) -> Params:
    dtype = jnp.dtype(cfg.param_dtype)
    d, qd, H = cfg.d_model, cfg.q_dim, cfg.n_heads
    ks = jax.random.split(key, 7)
    return {
        "ln": jnp.zeros((d,), dtype),
        "wq": L.dense_init(ks[0], (d, qd), dtype=dtype),
        "wk": L.dense_init(ks[1], (d, qd), dtype=dtype),
        "wv": L.dense_init(ks[2], (d, qd), dtype=dtype),
        "wi": L.dense_init(ks[3], (d, H), dtype=dtype),
        "wf": L.dense_init(ks[4], (d, H), dtype=dtype),
        "bf": jnp.full((H,), 3.0, dtype),  # forget-gate bias → long memory at init
        "bi": jnp.zeros((H,), dtype),
        "wo": L.dense_init(ks[5], (d, qd), dtype=dtype),
        "w_out": L.dense_init(ks[6], (qd, d), dtype=dtype),
    }


def _mlstm_chunk(q, k, v, logi, logf, state):
    """One chunk of the chunkwise-parallel mLSTM (per head, batched).

    q,k,v: [B,H,c,hd]; logi,logf: [B,H,c]; state (C [B,H,hd,hd], n, m).
    Returns (h [B,H,c,hd], new_state).
    """
    B, H, c, hd = q.shape
    C_prev, n_prev, m_prev = state
    b = jnp.cumsum(logf, axis=-1)  # [B,H,c] inclusive log-decay
    # Pairwise log decay: D[t,s] = b_t − b_s + logi_s for s ≤ t.
    Dlog = b[..., :, None] - b[..., None, :] + logi[..., None, :]
    mask = jnp.tril(jnp.ones((c, c), bool))
    Dlog = jnp.where(mask, Dlog, -jnp.inf)
    m_intra = jnp.max(Dlog, axis=-1)  # [B,H,c]
    m_inter = b + m_prev[..., None]
    m_t = jnp.maximum(m_intra, m_inter)  # per-position stabilizer
    D = jnp.exp(Dlog - m_t[..., None])  # [B,H,c,c]
    scale = 1.0 / jnp.sqrt(jnp.float32(hd))
    scores = jnp.einsum("bhtd,bhsd->bhts", q, k) * scale * D
    intra = jnp.einsum("bhts,bhsd->bhtd", scores, v)
    inter_w = jnp.exp(m_inter - m_t)  # [B,H,c]
    inter = jnp.einsum("bhtd,bhde->bhte", q * scale, C_prev) * inter_w[..., None]
    num = intra + inter
    # n accumulates decay-weighted k (no q term, unlike `scores`).
    n_t = jnp.einsum("bhts,bhsd->bhtd", D, k) + n_prev[..., None, :] * inter_w[..., None]
    denom = jnp.abs(jnp.einsum("bhtd,bhtd->bht", q * scale, n_t))
    denom = jnp.maximum(denom, jnp.exp(-m_t))
    h = num / denom[..., None]
    # State update to chunk end.
    m_new = jnp.maximum(b[..., -1] + m_prev, jnp.max(b[..., -1:] - b + logi, axis=-1))
    w_end = jnp.exp(b[..., -1:] - b + logi - m_new[..., None])  # [B,H,c]
    C_new = C_prev * jnp.exp(b[..., -1] + m_prev - m_new)[..., None, None] + jnp.einsum(
        "bhs,bhsd,bhse->bhde", w_end, k, v
    )
    n_new = n_prev * jnp.exp(b[..., -1] + m_prev - m_new)[..., None] + jnp.einsum("bhs,bhsd->bhd", w_end, k)
    return h, (C_new, n_new, m_new)


def mlstm_block(p: Params, x: jax.Array, cfg: ModelConfig, state=None):
    """Full mLSTM residual block. x: [B,T,d]. Returns (out, new_state)."""
    B, T, d = x.shape
    H, hd = cfg.n_heads, cfg.head_dim
    from repro.sharding.shardctx import constrain

    dp = ("pod", "data")
    c3 = lambda t: constrain(t, [dp, None, None])
    c4 = lambda t: constrain(t, [dp, None, None, None])
    h = L.rms_norm(x, p["ln"], cfg.norm_eps)
    hf = c3(h.astype(jnp.float32))
    # Pin every mixer activation to batch-sharding: the 2-D (data, model)
    # weight sharding would otherwise tempt XLA into un-sharding [B,T,*]
    # f32 activations instead of gathering the (much smaller) weights.
    q = c4((hf @ p["wq"]).reshape(B, T, H, hd)).transpose(0, 2, 1, 3)
    k = c4((hf @ p["wk"]).reshape(B, T, H, hd)).transpose(0, 2, 1, 3)
    v = c4((hf @ p["wv"]).reshape(B, T, H, hd)).transpose(0, 2, 1, 3)
    logi = c3(hf @ p["wi"] + p["bi"]).transpose(0, 2, 1)  # [B,H,T] (ĩ, pre-exp)
    logf = c3(jax.nn.log_sigmoid(hf @ p["wf"] + p["bf"])).transpose(0, 2, 1)
    o = c4(jax.nn.sigmoid(hf @ p["wo"]).reshape(B, T, H, hd)).transpose(0, 2, 1, 3)
    if state is None:
        state = (
            jnp.zeros((B, H, hd, hd), jnp.float32),
            jnp.zeros((B, H, hd), jnp.float32),
            jnp.full((B, H), -jnp.inf, jnp.float32),
        )
    c = min(cfg.mlstm_chunk, T)
    if T % c != 0:  # pad time to a chunk multiple (masked by logi = -inf)
        pad = c - T % c
        q, k, v, o = (jnp.pad(a, ((0, 0), (0, 0), (0, pad), (0, 0))) for a in (q, k, v, o))
        logi = jnp.pad(logi, ((0, 0), (0, 0), (0, pad)), constant_values=-jnp.inf)
        logf = jnp.pad(logf, ((0, 0), (0, 0), (0, pad)))
    nchunk = q.shape[2] // c
    qs = q.reshape(B, H, nchunk, c, hd).transpose(2, 0, 1, 3, 4)
    ks_ = k.reshape(B, H, nchunk, c, hd).transpose(2, 0, 1, 3, 4)
    vs = v.reshape(B, H, nchunk, c, hd).transpose(2, 0, 1, 3, 4)
    lis = logi.reshape(B, H, nchunk, c).transpose(2, 0, 1, 3)
    lfs = logf.reshape(B, H, nchunk, c).transpose(2, 0, 1, 3)

    def chunk_body(st, xs):
        qc, kc, vc, lic, lfc = xs
        hc, st = _mlstm_chunk(qc, kc, vc, lic, lfc, st)
        return st, hc

    new_state, hs = jax.lax.scan(chunk_body, state, (qs, ks_, vs, lis, lfs))  # rolled even in probes: 64 unrolled chunk bodies explode compile; xlstm roofline uses analytic MODEL_FLOPS (see dryrun docs)
    hseq = hs.transpose(1, 2, 0, 3, 4).reshape(B, H, nchunk * c, hd)[:, :, :T, :]
    hseq = (hseq * o[:, :, :T, :]).transpose(0, 2, 1, 3).reshape(B, T, H * hd)
    out = constrain(hseq.astype(x.dtype) @ p["w_out"], [dp, None, None])
    return x + out, new_state


# --------------------------------------------------------------------------- #
# sLSTM
# --------------------------------------------------------------------------- #


def init_slstm(key, cfg: ModelConfig) -> Params:
    dtype = jnp.dtype(cfg.param_dtype)
    d, H = cfg.d_model, cfg.n_heads
    dh = d // H
    ks = jax.random.split(key, 10)
    p: Params = {"ln": jnp.zeros((d,), dtype)}
    for i, g in enumerate(("i", "f", "z", "o")):
        p[f"w{g}"] = L.dense_init(ks[i], (d, d), dtype=dtype)
        p[f"r{g}"] = (jax.random.normal(ks[4 + i], (H, dh, dh)) / jnp.sqrt(dh)).astype(dtype)
        p[f"b{g}"] = (jnp.full((d,), 3.0, dtype) if g == "f" else jnp.zeros((d,), dtype))
    p["w_out"] = L.dense_init(ks[8], (d, d), dtype=dtype)
    return p


def slstm_block(p: Params, x: jax.Array, cfg: ModelConfig, state=None):
    """sLSTM residual block; strictly sequential scan over time."""
    B, T, d = x.shape
    H = cfg.n_heads
    dh = d // H
    from repro.sharding.shardctx import constrain

    dp = ("pod", "data")
    xin = constrain(L.rms_norm(x, p["ln"], cfg.norm_eps).astype(jnp.float32), [dp, None, None])
    # Precompute input contributions for all gates: [B,T,d] each (batch-pinned).
    pre = {g: constrain(xin @ p[f"w{g}"] + p[f"b{g}"], [dp, None, None]) for g in ("i", "f", "z", "o")}
    if state is None:
        z0 = jnp.zeros((B, d), jnp.float32)
        state = (z0, z0 + 1e-6, z0, jnp.full((B, d), -jnp.inf, jnp.float32))
    R = {g: p[f"r{g}"].astype(jnp.float32) for g in ("i", "f", "z", "o")}

    def step(st, xs):
        c, n, h, m = st
        hi = h.reshape(B, H, dh)

        def rec(g):
            return jnp.einsum("bhe,hef->bhf", hi, R[g]).reshape(B, d)

        it = xs["i"] + rec("i")
        ft = jax.nn.log_sigmoid(xs["f"] + rec("f"))
        zt = jnp.tanh(xs["z"] + rec("z"))
        ot = jax.nn.sigmoid(xs["o"] + rec("o"))
        m_new = jnp.maximum(ft + m, it)
        i_s = jnp.exp(it - m_new)
        f_s = jnp.exp(ft + m - m_new)
        c_new = f_s * c + i_s * zt
        n_new = f_s * n + i_s
        h_new = ot * (c_new / jnp.maximum(n_new, 1e-6))
        return (c_new, n_new, h_new, m_new), h_new

    xs_t = {g: pre[g].transpose(1, 0, 2) for g in pre}  # [T,B,d]
    new_state, hs = jax.lax.scan(step, state, xs_t)
    out = hs.transpose(1, 0, 2).astype(x.dtype) @ p["w_out"]
    return x + out, new_state


# --------------------------------------------------------------------------- #
# model assembly
# --------------------------------------------------------------------------- #


def init(key: jax.Array, cfg: ModelConfig) -> Params:
    G, a, b = _pattern(cfg)
    ks = jax.random.split(key, 3)
    stack = lambda blocks: jax.tree_util.tree_map(lambda *xs: jnp.stack(xs), *blocks)
    mkeys = jax.random.split(ks[0], max(G * a, 1))
    skeys = jax.random.split(ks[1], max(G * b, 1))
    params: Params = {
        "embed": L.embed_init(ks[2], (cfg.padded_vocab_size, cfg.d_model), jnp.dtype(cfg.param_dtype)),
        "final_norm": jnp.zeros((cfg.d_model,), jnp.dtype(cfg.param_dtype)),
    }
    if a:
        groups = [stack([init_mlstm(mkeys[g * a + j], cfg) for j in range(a)]) for g in range(G)]
        params["mlstm"] = stack(groups)  # [G, a, ...]
    if b:
        groups = [stack([init_slstm(skeys[g * b + j], cfg) for j in range(b)]) for g in range(G)]
        params["slstm"] = stack(groups)  # [G, b, ...]
    return params


def make_cache(cfg: ModelConfig, batch: int, max_len: int = 0, dtype=None) -> XLSTMCache:
    G, a, b = _pattern(cfg)
    H, hd, d = cfg.n_heads, cfg.head_dim, cfg.d_model
    return XLSTMCache(
        m_C=jnp.zeros((G * a, batch, H, hd, hd), jnp.float32),
        m_n=jnp.zeros((G * a, batch, H, hd), jnp.float32),
        m_m=jnp.full((G * a, batch, H), -jnp.inf, jnp.float32),
        s_c=jnp.zeros((G * b, batch, d), jnp.float32),
        s_n=jnp.zeros((G * b, batch, d), jnp.float32) + 1e-6,
        s_h=jnp.zeros((G * b, batch, d), jnp.float32),
        s_m=jnp.full((G * b, batch, d), -jnp.inf, jnp.float32),
        lengths=jnp.zeros((batch,), jnp.int32),
    )


def _run(params: Params, x: jax.Array, cfg: ModelConfig, cache: Optional[XLSTMCache]):
    G, a, b = _pattern(cfg)

    def group(carry, xs):
        x = carry
        if cache is None:
            # Per-layer remat inside the (checkpointed) group body: a group
            # holds 8 mixer layers whose f32 residuals would otherwise all be
            # live during the group's backward (~50 GiB/device at train_4k).
            m_p, s_p = xs
            for j in range(a):
                pj = jax.tree_util.tree_map(lambda t: t[j], m_p)
                blk = lambda xx, p=pj: mlstm_block(p, xx, cfg, None)[0]
                x = jax.checkpoint(blk)(x) if cfg.remat else blk(x)
            for j in range(b):
                pj = jax.tree_util.tree_map(lambda t: t[j], s_p)
                blk = lambda xx, p=pj: slstm_block(p, xx, cfg, None)[0]
                x = jax.checkpoint(blk)(x) if cfg.remat else blk(x)
            return x, None
        m_p, s_p, mC, mn, mm, sc, sn, sh, sm = xs
        mCo, mno, mmo = [], [], []
        for j in range(a):
            pj = jax.tree_util.tree_map(lambda t: t[j], m_p)
            x, (C2, n2, m2) = mlstm_block(pj, x, cfg, (mC[j], mn[j], mm[j]))
            mCo.append(C2), mno.append(n2), mmo.append(m2)
        sco, sno, sho, smo = [], [], [], []
        for j in range(b):
            pj = jax.tree_util.tree_map(lambda t: t[j], s_p)
            x, (c2, n2, h2, m2) = slstm_block(pj, x, cfg, (sc[j], sn[j], sh[j], sm[j]))
            sco.append(c2), sno.append(n2), sho.append(h2), smo.append(m2)
        ys = (jnp.stack(mCo), jnp.stack(mno), jnp.stack(mmo), jnp.stack(sco), jnp.stack(sno), jnp.stack(sho), jnp.stack(smo))
        return x, ys

    if cache is None:
        body = jax.checkpoint(group) if cfg.remat else group
        x, _ = jax.lax.scan(body, x, (params["mlstm"], params["slstm"]), unroll=cfg.scan_unroll or 1)
        return x, None
    rs = lambda t: t.reshape(G, -1, *t.shape[1:])
    x, ys = jax.lax.scan(
        group,
        x,
        (params["mlstm"], params["slstm"], rs(cache.m_C), rs(cache.m_n), rs(cache.m_m), rs(cache.s_c), rs(cache.s_n), rs(cache.s_h), rs(cache.s_m)),
    )
    fl = lambda t: t.reshape(-1, *t.shape[2:])
    T = x.shape[1]
    new_cache = XLSTMCache(fl(ys[0]), fl(ys[1]), fl(ys[2]), fl(ys[3]), fl(ys[4]), fl(ys[5]), fl(ys[6]), cache.lengths + T)
    return x, new_cache


def final_hidden(params: Params, batch: Dict[str, jax.Array], cfg: ModelConfig):
    tokens = batch["tokens"]
    x = jnp.take(params["embed"], tokens, axis=0).astype(jnp.dtype(cfg.dtype))
    x, _ = _run(params, x, cfg, None)
    return L.rms_norm(x, params["final_norm"], cfg.norm_eps), jnp.float32(0.0)


def forward(params: Params, batch: Dict[str, jax.Array], cfg: ModelConfig):
    from .transformer import unembed

    x, aux = final_hidden(params, batch, cfg)
    return unembed(params, x, cfg), aux


def prefill(params: Params, batch: Dict[str, jax.Array], cache: XLSTMCache, cfg: ModelConfig):
    from .transformer import unembed

    tokens = batch["tokens"]
    x = jnp.take(params["embed"], tokens, axis=0).astype(jnp.dtype(cfg.dtype))
    x, new_cache = _run(params, x, cfg, cache)
    x = L.rms_norm(x, params["final_norm"], cfg.norm_eps)
    return unembed(params, x, cfg), new_cache


def decode(params: Params, tokens: jax.Array, cache: XLSTMCache, cfg: ModelConfig):
    return prefill(params, {"tokens": tokens}, cache, cfg)


def loss_fn(params: Params, batch: Dict[str, jax.Array], cfg: ModelConfig):
    from .losses import ce_metrics, chunked_ce
    from .transformer import unembed

    hidden, _ = final_hidden(params, batch, cfg)
    total, n_valid = chunked_ce(hidden, batch["labels"], lambda h: unembed(params, h, cfg), unroll=cfg.scan_unroll)
    ce, metrics = ce_metrics(total, n_valid)
    return ce, metrics
