"""Whisper-style encoder-decoder (arXiv:2212.04356) for the [audio] arch.

The conv/mel frontend is a STUB per the assignment: ``input_specs`` provides
precomputed frame embeddings [B, n_ctx, d_model] (optionally projected from
``d_frontend``).  The encoder is a bidirectional transformer; the decoder adds
per-layer cross-attention whose K/V are computed once per request from the
encoder output and cached.

Deviations (documented in DESIGN.md): RMSNorm instead of LayerNorm, and
sinusoidal decoder positions instead of whisper's learned 448-position table —
the assigned decode shapes (32k KV) exceed any learned table, and sinusoidal
positions keep the decoder length-agnostic.
"""

from __future__ import annotations

from typing import Any, Dict, NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp

from .config import GLOBAL_WINDOW, ModelConfig
from .kvcache import KVCache, init_kv_cache
from . import layers as L

Params = Dict[str, Any]


class EncDecCache(NamedTuple):
    kv: KVCache  # decoder self-attention cache
    enc_k: jax.Array  # [L, B, S_enc, Hkv, hd] — cross-attention keys
    enc_v: jax.Array  # [L, B, S_enc, Hkv, hd]
    lengths: jax.Array  # [B]


# --------------------------------------------------------------------------- #
# init
# --------------------------------------------------------------------------- #


def _enc_block_init(key, cfg: ModelConfig) -> Params:
    k1, k2 = jax.random.split(key)
    d = cfg.d_model
    return {
        "ln1": jnp.zeros((d,), jnp.dtype(cfg.param_dtype)),
        "ln2": jnp.zeros((d,), jnp.dtype(cfg.param_dtype)),
        "attn": L.init_attention(k1, cfg),
        "mlp": L.init_mlp(k2, d, 4 * d, gated=False, dtype=jnp.dtype(cfg.param_dtype)),
    }


def _dec_block_init(key, cfg: ModelConfig) -> Params:
    k1, k2, k3 = jax.random.split(key, 3)
    d = cfg.d_model
    return {
        "ln1": jnp.zeros((d,), jnp.dtype(cfg.param_dtype)),
        "ln2": jnp.zeros((d,), jnp.dtype(cfg.param_dtype)),
        "ln3": jnp.zeros((d,), jnp.dtype(cfg.param_dtype)),
        "attn": L.init_attention(k1, cfg),
        "xattn": L.init_cross_attention(k2, cfg),
        "mlp": L.init_mlp(k3, d, cfg.d_ff, gated=False, dtype=jnp.dtype(cfg.param_dtype)),
    }


def init(key: jax.Array, cfg: ModelConfig) -> Params:
    assert cfg.encoder is not None
    ks = jax.random.split(key, 5)
    stack = lambda blocks: jax.tree_util.tree_map(lambda *xs: jnp.stack(xs), *blocks)
    enc_keys = jax.random.split(ks[0], cfg.encoder.n_layers)
    dec_keys = jax.random.split(ks[1], cfg.n_layers)
    params: Params = {
        "embed": L.embed_init(ks[2], (cfg.padded_vocab_size, cfg.d_model), jnp.dtype(cfg.param_dtype)),
        "enc_blocks": stack([_enc_block_init(k, cfg) for k in enc_keys]),
        "dec_blocks": stack([_dec_block_init(k, cfg) for k in dec_keys]),
        "enc_norm": jnp.zeros((cfg.d_model,), jnp.dtype(cfg.param_dtype)),
        "final_norm": jnp.zeros((cfg.d_model,), jnp.dtype(cfg.param_dtype)),
    }
    if cfg.encoder.d_frontend:
        params["frontend_proj"] = L.dense_init(ks[3], (cfg.encoder.d_frontend, cfg.d_model), dtype=jnp.dtype(cfg.param_dtype))
    return params


# --------------------------------------------------------------------------- #
# encoder
# --------------------------------------------------------------------------- #


def encode(params: Params, frames: jax.Array, cfg: ModelConfig) -> jax.Array:
    """frames: [B, S_enc, d_model] stub embeddings → encoder states."""
    x = frames.astype(jnp.dtype(cfg.dtype))
    if "frontend_proj" in params:
        x = x @ params["frontend_proj"]
    S = x.shape[1]
    x = x + L.sinusoidal_positions(S, cfg.d_model)[None].astype(x.dtype)
    B = x.shape[0]
    positions = jnp.broadcast_to(jnp.arange(S, dtype=jnp.int32)[None], (B, S))

    def body(carry, blk):
        h = L.rms_norm(carry, blk["ln1"], cfg.norm_eps)
        a, _ = L.attention_block(blk["attn"], h, positions, cfg, cfg.rope_theta, GLOBAL_WINDOW, causal=False)
        x = carry + a
        h = L.rms_norm(x, blk["ln2"], cfg.norm_eps)
        return x + L.mlp_block(blk["mlp"], h), None

    body_fn = jax.checkpoint(body) if cfg.remat else body
    x, _ = jax.lax.scan(body_fn, x, params["enc_blocks"], unroll=cfg.scan_unroll or 1)
    return L.rms_norm(x, params["enc_norm"], cfg.norm_eps)


def build_enc_kv(params: Params, enc_out: jax.Array, cfg: ModelConfig) -> Tuple[jax.Array, jax.Array]:
    """Per-decoder-layer cross K/V, stacked [L, B, S, Hkv, hd] (cached)."""

    def per_layer(blk):
        return L.encoder_kv(blk["xattn"], enc_out, cfg)

    ks, vs = jax.vmap(per_layer, in_axes=(0,))(params["dec_blocks"])
    return ks, vs


# --------------------------------------------------------------------------- #
# decoder
# --------------------------------------------------------------------------- #


def _decoder_stack(params, x, positions, cfg, enc_k, enc_v, cache: Optional[KVCache]):
    lengths = cache.lengths if cache is not None else None

    def body(carry, xs):
        if cache is None:
            blk, ek, ev = xs
            kv = None
        else:
            blk, ek, ev, k_l, v_l = xs
            kv = (k_l, v_l, lengths)
        h = L.rms_norm(carry, blk["ln1"], cfg.norm_eps)
        a, new_kv = L.attention_block(blk["attn"], h, positions, cfg, cfg.rope_theta, GLOBAL_WINDOW, kv_cache=kv)
        x = carry + a
        h = L.rms_norm(x, blk["ln2"], cfg.norm_eps)
        x = x + L.cross_attention_block(blk["xattn"], h, (ek, ev), cfg)
        h = L.rms_norm(x, blk["ln3"], cfg.norm_eps)
        x = x + L.mlp_block(blk["mlp"], h)
        return x, None if new_kv is None else (new_kv[0], new_kv[1])

    if cache is None:
        body_fn = jax.checkpoint(body) if cfg.remat else body
        x, _ = jax.lax.scan(body_fn, x, (params["dec_blocks"], enc_k, enc_v), unroll=cfg.scan_unroll or 1)
        return x, None
    x, (nk, nv) = jax.lax.scan(body, x, (params["dec_blocks"], enc_k, enc_v, cache.k, cache.v), unroll=cfg.scan_unroll or 1)
    T = positions.shape[1]
    return x, KVCache(nk, nv, cache.lengths + T)


def _embed_tokens(params, tokens, positions, cfg):
    x = jnp.take(params["embed"], tokens, axis=0).astype(jnp.dtype(cfg.dtype))
    # Sinusoidal decoder positions, gathered per-lane (supports cached offsets).
    maxpos = jnp.max(positions) + 1
    # Static upper bound: compute table lazily per call length via positions.
    table_dim = cfg.d_model
    half = table_dim // 2
    freq = jnp.exp(-jnp.log(10_000.0) * jnp.arange(half, dtype=jnp.float32) / (half - 1))
    ang = positions[..., None].astype(jnp.float32) * freq
    pos_emb = jnp.concatenate([jnp.sin(ang), jnp.cos(ang)], axis=-1)
    return x + pos_emb.astype(x.dtype)


def final_hidden(params: Params, batch: Dict[str, jax.Array], cfg: ModelConfig) -> Tuple[jax.Array, jax.Array]:
    """Teacher-forced encode+decode up to the final norm."""
    enc_out = encode(params, batch["frames"], cfg)
    enc_k, enc_v = build_enc_kv(params, enc_out, cfg)
    tokens = batch["tokens"]
    B, T = tokens.shape
    positions = jnp.broadcast_to(jnp.arange(T, dtype=jnp.int32)[None], (B, T))
    x = _embed_tokens(params, tokens, positions, cfg)
    x, _ = _decoder_stack(params, x, positions, cfg, enc_k, enc_v, None)
    return L.rms_norm(x, params["final_norm"], cfg.norm_eps), jnp.float32(0.0)


def forward(params: Params, batch: Dict[str, jax.Array], cfg: ModelConfig) -> Tuple[jax.Array, jax.Array]:
    """Teacher-forced training/scoring: encode frames, decode tokens."""
    from .transformer import unembed

    x, aux = final_hidden(params, batch, cfg)
    return unembed(params, x, cfg), aux


def make_cache(params: Params, frames: jax.Array, cfg: ModelConfig, max_len: int) -> EncDecCache:
    """Run the encoder once and build the serving cache."""
    enc_out = encode(params, frames, cfg)
    enc_k, enc_v = build_enc_kv(params, enc_out, cfg)
    B = frames.shape[0]
    kv = init_kv_cache(cfg.n_layers, B, max_len, cfg.n_kv_heads, cfg.head_dim, jnp.dtype(cfg.dtype))
    return EncDecCache(kv, enc_k, enc_v, jnp.zeros((B,), jnp.int32))


def prefill(params: Params, batch: Dict[str, jax.Array], cache: EncDecCache, cfg: ModelConfig):
    from .transformer import unembed

    tokens = batch["tokens"]
    B, T = tokens.shape
    positions = cache.lengths[:, None] + jnp.arange(T, dtype=jnp.int32)[None]
    x = _embed_tokens(params, tokens, positions, cfg)
    x, new_kv = _decoder_stack(params, x, positions, cfg, cache.enc_k, cache.enc_v, cache.kv)
    x = L.rms_norm(x, params["final_norm"], cfg.norm_eps)
    new_cache = EncDecCache(new_kv, cache.enc_k, cache.enc_v, cache.lengths + T)
    return unembed(params, x, cfg), new_cache


def decode(params: Params, tokens: jax.Array, cache: EncDecCache, cfg: ModelConfig):
    return prefill(params, {"tokens": tokens}, cache, cfg)


def loss_fn(params: Params, batch: Dict[str, jax.Array], cfg: ModelConfig):
    from .losses import ce_metrics, chunked_ce
    from .transformer import unembed

    hidden, _ = final_hidden(params, batch, cfg)
    total, n_valid = chunked_ce(hidden, batch["labels"], lambda h: unembed(params, h, cfg), unroll=cfg.scan_unroll)
    ce, metrics = ce_metrics(total, n_valid)
    return ce, metrics
