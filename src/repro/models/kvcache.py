"""Flat KV-cache and recurrent-state containers for serving.

Caches carry *per-lane* lengths so speculative-decoding rollback (truncating
rejected drafts) is a pure metadata update: entries past ``lengths[b]`` are
garbage and get overwritten by subsequent writes.  Layer-stacked leaves make
the caches scan-compatible (the layer dim is the scan axis).

Recurrent architectures (RG-LRU, xLSTM) cannot truncate state by index; they
roll back via round-granular *snapshots* (``snapshot``/``restore``) — the
stateful-draft extension described in DESIGN.md §7.

This is the *flat* layout: one contiguous ``max_len`` buffer per lane, which
is simple and scan-friendly but reserves ``batch x max_len`` slots no matter
how short the live prefixes are.  Multi-session serving instead uses the
*paged* layout (``models/paged_kv.py``): a global block pool with per-session
block tables and copy-on-write prefix sharing, consumed by the paged
decode-attention kernel.  Example of the rollback metadata contract::

    >>> import jax.numpy as jnp
    >>> cache = init_kv_cache(n_layers=1, batch=2, max_len=8, n_kv_heads=1, head_dim=4)
    >>> cache = set_lengths(cache, jnp.asarray([5, 3]))
    >>> [int(x) for x in cache.lengths]   # O(1) truncation, buffers untouched
    [5, 3]
"""

from __future__ import annotations

from typing import Any, NamedTuple, Tuple

import jax
import jax.numpy as jnp

__all__ = ["KVCache", "RecurrentState", "init_kv_cache", "set_lengths", "snapshot", "restore"]


class KVCache(NamedTuple):
    """Flat layer-stacked KV cache with per-lane valid lengths."""

    k: jax.Array  # [L, B, S_max, H_kv, head_dim]
    v: jax.Array  # [L, B, S_max, H_kv, head_dim]
    lengths: jax.Array  # [B] int32 — valid prefix length per lane

    @property
    def max_len(self) -> int:
        """Token capacity reserved per lane (the flat layout's fixed cost)."""
        return self.k.shape[2]


def init_kv_cache(n_layers: int, batch: int, max_len: int, n_kv_heads: int, head_dim: int, dtype=jnp.float32) -> KVCache:
    """Allocate a zeroed flat cache of ``batch x max_len`` token slots."""
    shape = (n_layers, batch, max_len, n_kv_heads, head_dim)
    return KVCache(jnp.zeros(shape, dtype), jnp.zeros(shape, dtype), jnp.zeros((batch,), jnp.int32))


def set_lengths(cache: KVCache, lengths: jax.Array) -> KVCache:
    """Speculative-decoding rollback: O(1) metadata truncation."""
    return cache._replace(lengths=lengths.astype(jnp.int32))


class RecurrentState(NamedTuple):
    """Stacked recurrent state for RG-LRU / xLSTM layers (pytree of arrays)."""

    tensors: Any  # nested dict of [L_kind, B, ...] arrays keyed by kind
    steps: jax.Array  # [B] int32 — tokens absorbed (for position tracking)


def snapshot(state: Any) -> Any:
    """Copy a state pytree (rollback point for stateful drafts).

    Every leaf goes through ``jnp.asarray(...).copy()``: ``a + 0`` would
    promote bool leaves to int32 (and leave non-array leaves aliased), while
    an explicit copy preserves dtype and guarantees a fresh buffer for any
    array-like leaf.
    """
    return jax.tree_util.tree_map(lambda a: jnp.asarray(a).copy(), state)


def restore(snapshot_state: Any) -> Any:
    """Return the rollback point taken by ``snapshot`` (pure functional)."""
    return snapshot_state
