"""Model configuration for the PipeSD model zoo.

One ``ModelConfig`` describes any of the assigned architectures: dense
decoder-only LMs (llama-like, gemma-like with local/global attention and
softcaps), MoE LMs, encoder-decoder (whisper), hybrid recurrent (griffin /
recurrentgemma) and xLSTM stacks.  The config is a frozen dataclass so it can
key jit caches.

Conventions:
* ``layer_kinds`` assigns each layer a mixer kind: 'attn' (full/global),
  'local' (sliding window), 'rglru', 'mlstm', 'slstm'.  Attention-kind layers
  share one stacked parameter group (window/theta become per-layer scalars),
  so dense models always scan a single stacked block.
* vocab sizes are padded to a multiple of ``vocab_pad_to`` for TP sharding
  (standard Megatron/MaxText practice); the tokenizer-visible size stays in
  ``vocab_size`` and padded logits are masked to −inf by the models.
"""

from __future__ import annotations

import math
from dataclasses import dataclass, field, replace
from typing import Optional, Tuple

__all__ = ["ModelConfig", "MoEConfig", "EncoderConfig", "padded_vocab", "GLOBAL_WINDOW"]

# Sentinel window meaning "attend to everything" (global attention).
GLOBAL_WINDOW = 1 << 30


def padded_vocab(vocab_size: int, multiple: int = 256) -> int:
    return ((vocab_size + multiple - 1) // multiple) * multiple


@dataclass(frozen=True)
class MoEConfig:
    n_experts: int
    top_k: int
    d_ff_expert: int
    dense_residual: bool = False  # arctic: parallel dense FFN + MoE
    d_ff_dense: int = 0  # width of the dense residual FFN (arctic: 4864)
    router_noise: float = 0.0
    load_balance_weight: float = 0.01  # aux loss coefficient (training)
    group_size: int = 256  # tokens per dispatch group (GShard grouping)
    capacity_factor: float = 1.25  # C = ceil(g·k·cf/E); cf = E/k ⇒ dropless


@dataclass(frozen=True)
class EncoderConfig:
    """Encoder stack for enc-dec models (whisper): bidirectional attention."""

    n_layers: int
    n_ctx: int  # encoder positions (whisper-large-v3: 1500 frames)
    d_frontend: int = 0  # raw frontend feature dim (0 => stub provides d_model)


@dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str  # 'dense' | 'moe' | 'encdec' | 'hybrid' | 'ssm' | 'vlm' | 'audio'
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab_size: int
    head_dim: int = 128
    layer_kinds: Tuple[str, ...] = ()  # defaults to all-'attn' if empty
    window_sizes: Tuple[int, ...] = ()  # per-layer; defaults to GLOBAL_WINDOW
    rope_theta: float = 10_000.0
    rope_theta_global: Optional[float] = None  # gemma3: 1e6 on global layers
    norm_eps: float = 1e-6
    logit_softcap: float = 0.0  # gemma2: 30.0 (final logits)
    attn_softcap: float = 0.0  # gemma2: 50.0 (attention logits)
    tie_embeddings: bool = True
    moe: Optional[MoEConfig] = None
    encoder: Optional[EncoderConfig] = None
    n_vision_tokens: int = 0  # vlm: stub patch-embedding tokens prepended
    # xLSTM / RG-LRU specifics.
    conv_width: int = 4  # temporal conv in recurrent blocks (griffin)
    d_rnn: Optional[int] = None  # RG-LRU width (griffin: ~d_model)
    mlstm_chunk: int = 64  # chunkwise-parallel mLSTM chunk length
    # Numerics.
    dtype: str = "float32"  # activation dtype
    param_dtype: str = "float32"
    vocab_pad_to: int = 256
    # Serving metadata.
    sub_quadratic: bool = False  # eligible for long_500k decode
    remat: bool = True  # activation checkpointing in train_step
    # Fully unroll lax.scans (dry-run probe compiles only): XLA cost_analysis
    # counts a while-loop body once, so the probe pass unrolls to measure true
    # per-layer FLOPs/bytes/collectives.  Never used for real execution.
    scan_unroll: bool = False

    # ------------------------------------------------------------ derived --
    def __post_init__(self):
        if self.layer_kinds and len(self.layer_kinds) != self.n_layers:
            raise ValueError(
                f"{self.name}: layer_kinds has {len(self.layer_kinds)} entries "
                f"for {self.n_layers} layers"
            )
        if self.window_sizes and len(self.window_sizes) != self.n_layers:
            raise ValueError(f"{self.name}: window_sizes length mismatch")

    @property
    def kinds(self) -> Tuple[str, ...]:
        return self.layer_kinds or tuple(["attn"] * self.n_layers)

    @property
    def windows(self) -> Tuple[int, ...]:
        if self.window_sizes:
            return self.window_sizes
        return tuple([GLOBAL_WINDOW] * self.n_layers)

    @property
    def padded_vocab_size(self) -> int:
        return padded_vocab(self.vocab_size, self.vocab_pad_to)

    @property
    def q_dim(self) -> int:
        return self.n_heads * self.head_dim

    @property
    def kv_dim(self) -> int:
        return self.n_kv_heads * self.head_dim

    def param_count(self) -> int:
        """Approximate parameter count N (for roofline MODEL_FLOPS = 6·N·D)."""
        d, V = self.d_model, self.padded_vocab_size
        n = V * d  # embedding
        if not self.tie_embeddings:
            n += V * d
        attn = d * self.q_dim + 2 * d * self.kv_dim + self.q_dim * d
        ffn_dense = 3 * d * self.d_ff  # gated (SwiGLU-style)
        per_kind = {}
        for kind in set(self.kinds):
            if kind in ("attn", "local"):
                per_kind[kind] = attn + (ffn_dense if self.moe is None else 0)
            elif kind == "rglru":
                dr = self.d_rnn or self.d_model
                per_kind[kind] = 2 * d * dr + dr * d + self.conv_width * dr + 2 * dr + ffn_dense
            elif kind == "mlstm":
                per_kind[kind] = 3 * d * self.q_dim + self.q_dim * d + 3 * self.q_dim + ffn_dense
            elif kind == "slstm":
                per_kind[kind] = 4 * d * d + 4 * d + ffn_dense
        for kind in self.kinds:
            n += per_kind[kind]
            if self.moe is not None and kind in ("attn", "local"):
                n += 3 * d * self.moe.d_ff_expert * self.moe.n_experts
                n += d * self.moe.n_experts  # router
                if self.moe.dense_residual:
                    n += 3 * d * self.moe.d_ff_dense
        if self.encoder is not None:
            enc_ffn = 2 * d * (4 * d)  # whisper uses GELU MLP (non-gated, 4x)
            n += self.encoder.n_layers * (attn + enc_ffn)
            n += self.n_layers * (d * self.kv_dim * 2 + d * self.q_dim + self.q_dim * d)  # cross-attn
        return n

    def active_param_count(self) -> int:
        """Active parameters per token (MoE: top-k experts only)."""
        if self.moe is None:
            return self.param_count()
        full = self.param_count()
        moe_layers = sum(1 for k in self.kinds if k in ("attn", "local"))
        all_experts = 3 * self.d_model * self.moe.d_ff_expert * self.moe.n_experts * moe_layers
        active = 3 * self.d_model * self.moe.d_ff_expert * self.moe.top_k * moe_layers
        return full - all_experts + active

    def reduced(self, **overrides) -> "ModelConfig":
        """A tiny same-family config for CPU smoke tests."""
        kinds = self.kinds
        n_layers = min(self.n_layers, 4)
        # Preserve the kind pattern structure on a prefix basis.
        new_kinds = tuple(kinds[: n_layers]) if len(set(kinds)) > 1 else ()
        if new_kinds and len(set(new_kinds)) == 1:
            new_kinds = ()
        new_windows = tuple(min(w, 64) if w != GLOBAL_WINDOW else w for w in self.windows[:n_layers]) if self.window_sizes else ()
        kw = dict(
            name=self.name + "-reduced",
            n_layers=n_layers,
            d_model=64,
            n_heads=4,
            n_kv_heads=min(self.n_kv_heads, 2) if self.n_kv_heads < self.n_heads else 4,
            d_ff=128,
            head_dim=16,
            vocab_size=512,
            layer_kinds=new_kinds,
            window_sizes=new_windows,
            d_rnn=64 if self.d_rnn else None,
            mlstm_chunk=16,
            # Reduced MoE is DROPLESS (cf = E/k) so forward/prefill/decode agree
            # exactly — required by the spec-decoding consistency tests.
            moe=replace(
                self.moe,
                n_experts=4,
                top_k=min(self.moe.top_k, 2),
                d_ff_expert=64,
                d_ff_dense=64 if self.moe.dense_residual else 0,
                capacity_factor=4.0 / min(self.moe.top_k, 2),
                group_size=64,
            ) if self.moe else None,
            encoder=replace(self.encoder, n_layers=2, n_ctx=32) if self.encoder else None,
            n_vision_tokens=8 if self.n_vision_tokens else 0,
            vocab_pad_to=64,
        )
        kw.update(overrides)
        return replace(self, **kw)
