"""Uniform facade over the model families.

Dispatches init/forward/loss/prefill/decode/cache-construction by
``cfg.family`` so launchers, tests and the dry-run treat every assigned
architecture identically.
"""

from __future__ import annotations

from typing import Any, Dict

import jax
import jax.numpy as jnp

from .config import ModelConfig
from . import encdec, rglru, transformer, xlstm

_FAMILY = {
    "dense": transformer,
    "moe": transformer,
    "vlm": transformer,
    "audio": encdec,
    "hybrid": rglru,
    "ssm": xlstm,
}


def module_for(cfg: ModelConfig):
    try:
        return _FAMILY[cfg.family]
    except KeyError:
        raise KeyError(f"unknown family {cfg.family!r} for {cfg.name}") from None


def init(key: jax.Array, cfg: ModelConfig):
    return module_for(cfg).init(key, cfg)


def forward(params, batch: Dict[str, jax.Array], cfg: ModelConfig):
    return module_for(cfg).forward(params, batch, cfg)


def loss_fn(params, batch: Dict[str, jax.Array], cfg: ModelConfig):
    return module_for(cfg).loss_fn(params, batch, cfg)


def prefill(params, batch: Dict[str, jax.Array], cache, cfg: ModelConfig):
    return module_for(cfg).prefill(params, batch, cache, cfg)


def decode(params, tokens: jax.Array, cache, cfg: ModelConfig):
    return module_for(cfg).decode(params, tokens, cache, cfg)


def make_cache(params, batch: Dict[str, jax.Array], cfg: ModelConfig, max_len: int):
    """Family-uniform cache constructor (encdec needs params+frames)."""
    m = module_for(cfg)
    if cfg.family == "audio":
        return m.make_cache(params, batch["frames"], cfg, max_len)
    if cfg.family == "ssm":
        return m.make_cache(cfg, batch["tokens"].shape[0], max_len)
    return m.make_cache(cfg, batch["tokens"].shape[0], max_len)


def cache_spec(params_spec, batch_spec: Dict[str, Any], cfg: ModelConfig, max_len: int):
    """ShapeDtypeStruct pytree for the cache (dry-run input stand-in)."""
    return jax.eval_shape(lambda p, b: make_cache(p, b, cfg, max_len), params_spec, batch_spec)
