"""Paged KV-cache: a global block pool with copy-on-write prefix sharing.

The flat ``KVCache`` (``models/kvcache.py``) allocates every session a
contiguous ``[L, 1, max_len, Hkv, hd]`` buffer, so verifier memory scales with
``sessions x max_len`` no matter how short the actual prefixes are.  This
module replaces that with the standard production layout (vLLM-style):

* **Physical pages.**  KV storage is a pool of ``num_blocks`` fixed-size
  pages of ``block_size`` token slots each; a page spans all layers
  (``k_pages/v_pages: [L, num_blocks + 1, block_size, Hkv, hd]``; the
  ``+ 1`` is the pad sentinel below).
* **Block tables.**  A session's logical cache is an ordered list of int32
  physical page ids plus a valid ``length``; logical position ``p`` lives in
  page ``table[p // block_size]`` at slot ``p % block_size``.  Attention
  kernels gather through the table (``kernels.decode_attention``'s paged
  entry) instead of assuming contiguity.
* **Copy-on-write prefix sharing.**  ``fork`` gives a child session the
  parent's page ids and bumps refcounts — sessions verified from a common
  system/prompt prefix reference the SAME physical pages.  The first append
  into a shared partial tail page copies just that page (``cow_copies``
  stat); full shared pages stay shared forever.
* **Refcounted free + LRU reuse.**  ``rollback`` (speculative-decoding
  rejection, tree ``replay_path`` anchor restore) releases whole pages past
  the committed length instead of deep-copying buffers; pages return to an
  LRU free list (oldest-freed reused first).  ``evict``/``evict_lru``
  reclaim idle sessions' pages under pool pressure (the victim re-prefills
  on its next round).
* **Sentinel pad page.**  Physical page id ``num_blocks`` (one past the
  allocatable pool) is a dedicated zero-filled page that is NEVER handed to
  a session: ragged block tables pad with it (``table(pad_to=...)``,
  ``sentinel_page``), so a padded lane in a bucketed batched launch can
  only ever DMA the sentinel — never another session's KV pages.  Tensor
  mode sizes the page buffers ``num_blocks + 1`` so the sentinel is a valid
  gather index; it is excluded from the free list, refcounts, and byte
  accounting.
* **Int8 quantized pages** (``quantize='int8'``).  Tensor-mode pages store
  KV as int8 with per-(layer, slot, head) affine parameters
  (``k_scale/k_zero`` etc., float32, shaped ``[L, num_blocks + 1,
  block_size, Hkv]``): ``write`` quantizes each token-head vector over its
  ``head_dim`` range (``x_hat = (q + 128) * scale + zero``, ``scale =
  (max - min) / 255``, ``zero = min``) and the paged attention kernels
  dequantize in-VMEM.  Worst-case per-element error is ``scale / 2 =
  (max - min) / 510``; bytes/token drop from ``2*L*Hkv*hd*4`` (fp32) to
  ``2*L*Hkv*(hd + 8)`` (int8 payload + two float32 parameters per
  token-head).

The pool runs in two modes: **metadata-only** (default — no tensor storage;
used by the serving dispatcher and the simulation engine for admission and
byte accounting) and **tensor mode** (``n_layers > 0`` — real jax page
buffers written through ``write`` and consumed by the paged attention
kernel).

Example (metadata mode; 4-token pages)::

    >>> pool = PagedKVPool(num_blocks=8, block_size=4)
    >>> pool.create(0)
    >>> pool.append(0, 6)        # 6 tokens -> 2 pages (one partial)
    >>> pool.used_blocks
    2
    >>> pool.fork(0, 1)          # CoW prefix share: no new pages
    >>> pool.used_blocks
    2
    >>> pool.append(1, 1)        # first write into the shared tail page
    >>> pool.used_blocks         # ... copies it (CoW divergence)
    3
    >>> pool.rollback(1, 2)      # reject back to 2 tokens: page freed
    1
    >>> pool.used_blocks
    2
    >>> pool.stats["cow_copies"]
    1
"""

from __future__ import annotations

import time
from collections import deque
from dataclasses import dataclass, field
from typing import Deque, Dict, List, Optional, Sequence

import jax
import jax.numpy as jnp
import numpy as np

__all__ = ["BlockPoolExhausted", "BlockTable", "PagedKVPool"]


class BlockPoolExhausted(RuntimeError):
    """Raised when an allocation needs more physical pages than are free."""


@dataclass
class BlockTable:
    """Per-session page list + valid length (the logical->physical map)."""

    blocks: List[int] = field(default_factory=list)
    length: int = 0
    reserved: bool = False  # flat-mode contiguous reservation (no CoW/free)
    last_touch: int = 0  # pool clock at last append/rollback (LRU eviction key)
    # Materialized-KV watermark: positions [0, filled) hold real tensors
    # written through ``fill``/``write``.  Rollback lowers it (content past
    # the kept prefix is dead — and regrown slots may land in RECYCLED
    # physical pages holding another session's data), eviction zeroes it,
    # and it dies with the table on release, so tensor-filling backends can
    # trust it instead of tracking their own (see ``PagedKVPool.filled``).
    filled: int = 0

    def capacity(self, block_size: int) -> int:
        """Token slots currently backed by physical pages."""
        return len(self.blocks) * block_size


class PagedKVPool:
    """Global physical-page pool with per-session block tables.

    Parameters
    ----------
    num_blocks, block_size:
        Pool geometry — ``num_blocks`` pages of ``block_size`` token slots.
    n_layers, n_kv_heads, head_dim, dtype:
        Tensor mode: when ``n_layers > 0``, real page buffers
        ``k_pages/v_pages: [L, num_blocks + 1, block_size, Hkv, hd]`` are
        allocated (the extra page is the zero-filled pad sentinel) and
        ``write`` scatters tokens into them.  ``dtype`` is the storage dtype
        of unquantized pools; writes in any other float dtype are cast at
        the boundary so the page buffers (and the byte accounting derived
        from them) never change dtype behind the pool's back.
    quantize:
        ``'int8'`` stores pages as int8 with per-(layer, slot, head) affine
        scale/zero parameters (quantize-on-``write``, in-kernel dequant);
        ``None`` (default) stores ``dtype`` pages.
    bytes_per_token:
        Byte-accounting override for metadata mode.  Tensor mode derives it
        from the KV geometry (k+v); metadata mode defaults to 1 so
        ``resident_bytes`` counts token slots.
    """

    def __init__(
        self,
        num_blocks: int,
        block_size: int,
        *,
        n_layers: int = 0,
        n_kv_heads: int = 0,
        head_dim: int = 0,
        dtype=jnp.float32,
        quantize: Optional[str] = None,
        bytes_per_token: Optional[int] = None,
        metrics=None,
    ):
        if num_blocks < 1 or block_size < 1:
            raise ValueError("num_blocks and block_size must be >= 1")
        if quantize not in (None, "int8"):
            raise ValueError(f"unsupported quantize mode {quantize!r}")
        self.num_blocks = int(num_blocks)
        self.block_size = int(block_size)
        self.quantize = quantize
        self.refcounts = np.zeros(self.num_blocks, np.int32)
        # LRU free list: freed pages append right, allocation pops left.
        self._free: Deque[int] = deque(range(self.num_blocks))
        self.tables: Dict[int, BlockTable] = {}
        self._clock = 0
        self._resident = 0  # sessions holding >=1 page, maintained incrementally
        self.stats = {"allocs": 0, "frees": 0, "cow_copies": 0, "evictions": 0}
        # Optional repro.obs.metrics.MetricRegistry: op counts are mirrored
        # into ``kv_<op>`` counters as they happen (stats stays the source
        # of truth; the mirror feeds the telemetry endpoint).
        self.metrics = metrics
        # Host seconds spent in metadata mutations (append/rollback/fork/
        # reserve/evict) — the pool's entire latency cost on the serving
        # path, so benchmarks can bound the TPT impact of paging.
        self.op_seconds = 0.0
        self.max_used_blocks = 0
        self.max_resident_sessions = 0
        self.dtype = jnp.dtype(dtype)
        self.n_layers = int(n_layers)
        self.n_kv_heads = int(n_kv_heads)
        self.head_dim = int(head_dim)
        self.k_pages: Optional[jax.Array] = None
        self.v_pages: Optional[jax.Array] = None
        self.k_scale: Optional[jax.Array] = None
        self.k_zero: Optional[jax.Array] = None
        self.v_scale: Optional[jax.Array] = None
        self.v_zero: Optional[jax.Array] = None
        if n_layers > 0:
            # One extra physical page: the zero-filled pad sentinel at id
            # ``num_blocks``, a valid gather target that no session owns.
            shape = (n_layers, self.num_blocks + 1, self.block_size, n_kv_heads, head_dim)
            if self.quantize == "int8":
                self.k_pages = jnp.zeros(shape, jnp.int8)
                self.v_pages = jnp.zeros(shape, jnp.int8)
                pshape = shape[:-1]
                self.k_scale = jnp.zeros(pshape, jnp.float32)
                self.k_zero = jnp.zeros(pshape, jnp.float32)
                self.v_scale = jnp.zeros(pshape, jnp.float32)
                self.v_zero = jnp.zeros(pshape, jnp.float32)
                # int8 payload + (scale, zero) float32 per token-head, k + v.
                self.bytes_per_token = 2 * n_layers * n_kv_heads * (head_dim + 8)
            else:
                self.k_pages = jnp.zeros(shape, self.dtype)
                self.v_pages = jnp.zeros(shape, self.dtype)
                self.bytes_per_token = 2 * n_layers * n_kv_heads * head_dim * self.dtype.itemsize
        else:
            self.bytes_per_token = int(bytes_per_token) if bytes_per_token else 1
        self.bytes_per_block = self.bytes_per_token * self.block_size

    # ------------------------------------------------------------ geometry --
    @property
    def sentinel_page(self) -> int:
        """The zero-filled pad page id (``num_blocks``) — never allocated.

        Ragged block tables pad with this id so padded lanes in a bucketed
        batched launch can never DMA a page owned by a session.  Tensor mode
        sizes the page buffers ``num_blocks + 1`` so it is a valid index;
        external page buffers consumed through sentinel-padded tables must
        match that ``num_blocks + 1`` sizing (see ``table``).
        """
        return self.num_blocks

    @property
    def free_blocks(self) -> int:
        """Pages currently on the free list."""
        return len(self._free)

    @property
    def used_blocks(self) -> int:
        """Distinct pages referenced by at least one session."""
        return self.num_blocks - len(self._free)

    @property
    def resident_sessions(self) -> int:
        """Sessions currently holding at least one page (O(1) counter)."""
        return self._resident

    def blocks_for(self, n_tokens: int) -> int:
        """Pages needed to back ``n_tokens`` from an empty table."""
        return -(-max(int(n_tokens), 0) // self.block_size)

    def blocks_needed(self, session: int, n_tokens: int) -> int:
        """Fresh pages an ``append(session, n_tokens)`` would allocate.

        Counts the CoW copy of a shared partial tail page, so admission
        control can gate on the exact allocation the append will perform.
        """
        t = self._table(session)
        need = self.blocks_for(t.length + n_tokens) - len(t.blocks)
        if n_tokens > 0 and self._tail_is_shared(t):
            need += 1  # the append CoW-copies the shared tail page
        return max(need, 0)

    def can_append(self, session: int, n_tokens: int) -> bool:
        """True iff ``append(session, n_tokens)`` would not exhaust the pool."""
        t = self._table(session)
        if t.reserved:
            return t.length + int(n_tokens) <= t.capacity(self.block_size)
        return self.blocks_needed(session, n_tokens) <= self.free_blocks

    # ---------------------------------------------------------- allocation --
    def _table(self, session: int) -> BlockTable:
        if session not in self.tables:
            raise KeyError(f"unknown session {session}")
        return self.tables[session]

    def _tail_is_shared(self, t: BlockTable) -> bool:
        if t.reserved or not t.blocks or t.length % self.block_size == 0:
            return False  # no partial tail page to write into
        return int(self.refcounts[t.blocks[-1]]) > 1

    def _count(self, op: str) -> None:
        self.stats[op] += 1
        if self.metrics is not None:
            self.metrics.counter(f"kv_{op}", "Paged-KV pool page operations").inc()

    def _alloc_page(self) -> int:
        if not self._free:
            raise BlockPoolExhausted(f"pool of {self.num_blocks} pages exhausted")
        page = self._free.popleft()
        self.refcounts[page] = 1
        self._count("allocs")
        return page

    def _decref(self, page: int) -> None:
        self.refcounts[page] -= 1
        if self.refcounts[page] == 0:
            self._free.append(page)  # LRU: most recently freed goes last
            self._count("frees")

    def _touch(self, t: BlockTable) -> None:
        self._clock += 1
        t.last_touch = self._clock
        self.max_used_blocks = max(self.max_used_blocks, self.used_blocks)
        self.max_resident_sessions = max(self.max_resident_sessions, self.resident_sessions)

    def create(self, session: int) -> None:
        """Register an empty session (no pages held until ``append``)."""
        if session in self.tables:
            raise ValueError(f"session {session} already exists")
        self.tables[session] = BlockTable()

    def fork(self, parent: int, child: int) -> None:
        """Copy-on-write fork: ``child`` shares all of ``parent``'s pages.

        No pages are allocated; every shared page's refcount is bumped.  The
        first append into a shared *partial* tail page copies it (see
        ``append``); full shared pages are never copied.
        """
        t0 = time.perf_counter()
        p = self._table(parent)
        if child in self.tables:
            raise ValueError(f"session {child} already exists")
        # The child sees the parent's physical pages, so whatever prefix the
        # parent materialized is materialized for the child too.
        self.tables[child] = BlockTable(
            blocks=list(p.blocks), length=p.length, filled=p.filled
        )
        for page in p.blocks:
            self.refcounts[page] += 1
        if p.blocks:
            self._resident += 1
        self._touch(self.tables[child])
        self.op_seconds += time.perf_counter() - t0

    def reserve(self, session: int, max_tokens: int) -> None:
        """Flat-mode baseline: contiguously reserve pages for ``max_tokens``.

        Models the flat ``KVCache``'s up-front ``max_len`` allocation inside
        the same pool accounting, so flat-vs-paged residency is an
        apples-to-apples comparison.  Reserved tables never share, CoW, or
        release pages on rollback — exactly the flat cache's behaviour.
        """
        t0 = time.perf_counter()
        t = self._table(session)
        if t.blocks:
            raise ValueError(f"session {session} already holds pages")
        need = self.blocks_for(max_tokens)
        if need > self.free_blocks:
            raise BlockPoolExhausted(
                f"flat reservation of {need} pages exceeds {self.free_blocks} free"
            )
        t.blocks = [self._alloc_page() for _ in range(need)]
        t.reserved = True
        if t.blocks:
            self._resident += 1
        self._touch(t)
        self.op_seconds += time.perf_counter() - t0

    def append(self, session: int, n_tokens: int) -> None:
        """Extend a session by ``n_tokens`` slots, allocating pages on demand.

        If the session's tail page is partial *and* shared (post-``fork``),
        the tail is first copied to a fresh page — copy-on-write divergence:
        the writer pays one page copy, the other holders keep the original.
        Raises ``BlockPoolExhausted`` (leaving the table untouched) when the
        pool cannot back the growth; callers park or evict and retry.
        """
        t0 = time.perf_counter()
        t = self._table(session)
        n_tokens = int(n_tokens)
        if n_tokens <= 0:
            return
        if t.reserved:
            if t.length + n_tokens > t.capacity(self.block_size):
                raise BlockPoolExhausted(
                    f"flat reservation of session {session} overflows at "
                    f"{t.length + n_tokens} tokens"
                )
            t.length += n_tokens
            self._touch(t)
            self.op_seconds += time.perf_counter() - t0
            return
        if self.blocks_needed(session, n_tokens) > self.free_blocks:
            raise BlockPoolExhausted(
                f"append of {n_tokens} tokens needs "
                f"{self.blocks_needed(session, n_tokens)} pages, "
                f"{self.free_blocks} free"
            )
        if self._tail_is_shared(t):
            old = t.blocks[-1]
            new = self._alloc_page()
            self._copy_page(old, new)
            self._count("cow_copies")
            t.blocks[-1] = new
            self._decref(old)
        had_pages = bool(t.blocks)
        while t.capacity(self.block_size) < t.length + n_tokens:
            t.blocks.append(self._alloc_page())
        if not had_pages and t.blocks:
            self._resident += 1
        t.length += n_tokens
        self._touch(t)
        self.op_seconds += time.perf_counter() - t0

    def rollback(self, session: int, new_length: int) -> int:
        """Truncate to ``new_length`` tokens, releasing whole trailing pages.

        The speculative-decoding rejection path: instead of deep-copying
        buffers, pages wholly past the committed prefix are decref'd (and
        freed when unshared).  Returns the number of pages this session
        dropped.  Reserved (flat) tables only move the length — the flat
        cache never returns memory.
        """
        t0 = time.perf_counter()
        t = self._table(session)
        new_length = int(new_length)
        if new_length > t.length:
            raise ValueError(f"rollback to {new_length} > current length {t.length}")
        t.length = new_length
        # Tensors past the kept prefix are dead: the rejected round's KV must
        # never be trusted again, and slots regrown after this rollback may
        # land in recycled physical pages holding another session's data.
        t.filled = min(t.filled, new_length)
        if t.reserved:
            self._touch(t)
            self.op_seconds += time.perf_counter() - t0
            return 0
        keep = self.blocks_for(new_length)
        dropped = t.blocks[keep:]
        t.blocks = t.blocks[:keep]
        for page in reversed(dropped):
            self._decref(page)
        if dropped and not t.blocks:
            self._resident -= 1
        self._touch(t)
        self.op_seconds += time.perf_counter() - t0
        return len(dropped)

    def release(self, session: int) -> None:
        """Drop a session entirely, decref'ing every page it held."""
        t = self._table(session)
        for page in reversed(t.blocks):
            self._decref(page)
        if t.blocks:
            self._resident -= 1
        del self.tables[session]

    def evict(self, session: int) -> int:
        """Reclaim a session's pages under pool pressure (it re-prefills later).

        The session stays registered with ``length = 0`` so its next round
        starts from an empty cache.  Returns the pages released.
        """
        t0 = time.perf_counter()
        t = self._table(session)
        dropped = len(t.blocks)
        for page in reversed(t.blocks):
            self._decref(page)
        if t.blocks:
            self._resident -= 1
        t.blocks = []
        t.length = 0
        t.filled = 0  # every materialized tensor went back with the pages
        t.reserved = False
        self._count("evictions")
        self.op_seconds += time.perf_counter() - t0
        return dropped

    def evict_lru(self, exclude: Sequence[int] = ()) -> Optional[int]:
        """Evict the least-recently-touched page-holding session not excluded.

        Returns the victim's id, or None when every resident session is
        excluded (nothing safe to reclaim).
        """
        skip = set(exclude)
        victims = [
            (t.last_touch, sid)
            for sid, t in self.tables.items()
            if t.blocks and sid not in skip
        ]
        if not victims:
            return None
        _, sid = min(victims)
        self.evict(sid)
        return sid

    # ------------------------------------------------------------- tensors --
    def _copy_page(self, src: int, dst: int) -> None:
        if self.k_pages is not None:
            self.k_pages = self.k_pages.at[:, dst].set(self.k_pages[:, src])
            self.v_pages = self.v_pages.at[:, dst].set(self.v_pages[:, src])
            if self.quantize == "int8":
                self.k_scale = self.k_scale.at[:, dst].set(self.k_scale[:, src])
                self.k_zero = self.k_zero.at[:, dst].set(self.k_zero[:, src])
                self.v_scale = self.v_scale.at[:, dst].set(self.v_scale[:, src])
                self.v_zero = self.v_zero.at[:, dst].set(self.v_zero[:, src])

    @staticmethod
    def quantize_kv(x: jax.Array):
        """Affine-int8 quantize ``x`` over its last axis.

        Returns ``(q int8, scale f32, zero f32)`` with ``scale/zero`` shaped
        like ``x`` minus the last axis: ``x_hat = (q + 128) * scale + zero``,
        ``scale = (max - min) / 255`` (1 when the range is empty) and
        ``zero = min``.  Worst-case per-element error is ``scale / 2``.
        """
        x = x.astype(jnp.float32)
        lo = jnp.min(x, axis=-1)
        hi = jnp.max(x, axis=-1)
        scale = jnp.where(hi > lo, (hi - lo) / 255.0, 1.0)
        q = jnp.round((x - lo[..., None]) / scale[..., None]) - 128.0
        return jnp.clip(q, -128, 127).astype(jnp.int8), scale, lo

    @staticmethod
    def dequantize_kv(q: jax.Array, scale: jax.Array, zero: jax.Array) -> jax.Array:
        """Invert ``quantize_kv``: ``(q + 128) * scale + zero`` in float32."""
        return (q.astype(jnp.float32) + 128.0) * scale[..., None] + zero[..., None]

    def _check_write_dtype(self, k_new: jax.Array, v_new: jax.Array):
        """Validate/cast incoming KV at the pool boundary.

        JAX's scatter would otherwise silently cast mismatched dtypes lane
        by lane (a ``FutureWarning`` today, an error in future releases) —
        and a caller assuming the pages follow the operand dtype would see
        ``resident_bytes`` accounting drift from the true footprint.  The
        pool's storage dtype is authoritative: floats cast here, explicitly;
        anything non-float is rejected.
        """
        if k_new.dtype != v_new.dtype:
            raise TypeError(f"k/v dtype mismatch: {k_new.dtype} vs {v_new.dtype}")
        if not jnp.issubdtype(k_new.dtype, jnp.floating):
            raise TypeError(f"KV writes must be floating point, got {k_new.dtype}")
        want = jnp.float32 if self.quantize == "int8" else self.dtype
        return k_new.astype(want), v_new.astype(want)

    def write(self, session: int, k_new: jax.Array, v_new: jax.Array) -> None:
        """Append ``T`` tokens of KV (``[L, T, Hkv, hd]``) into the pages.

        Tensor mode only.  Handles page allocation + CoW via ``append``;
        tokens scatter into (page, slot) per the block table.  Writes whose
        dtype differs from the pool's storage dtype are cast here, at the
        boundary (see ``_check_write_dtype``); int8 pools quantize each
        token-head vector and store its scale/zero alongside the payload.
        """
        start = self._table(session).length
        self.append(session, k_new.shape[1])
        self.fill(session, start, k_new, v_new)

    def fill(self, session: int, start: int, k_new: jax.Array, v_new: jax.Array) -> None:
        """Write ``T`` tokens of KV into ALREADY-APPENDED slots at ``start``.

        The dispatcher path: ``_kv_secure`` appends a round's page metadata
        before verification, then the backend materializes tensors here
        without double-appending.  Same boundary dtype validation and int8
        quantize-on-write as ``write``.

        A target page shared with another session (refcount > 1, post
        ``fork``) is CoW-copied first, exactly like ``append`` — writing
        through it in place would mutate every sibling's view.  The copy can
        raise ``BlockPoolExhausted``; callers that must not diverge shared
        prefix pages should materialize the prefix on its OWNER before
        forking, so children inherit the ``filled`` watermark and never
        fill shared slots.

        Advances the session's materialized watermark (``filled``) when the
        write extends the contiguous materialized prefix.
        """
        if self.k_pages is None:
            raise RuntimeError("pool was built without tensor storage (n_layers=0)")
        k_new, v_new = self._check_write_dtype(k_new, v_new)
        if self.quantize == "int8":
            k_new, k_sc, k_zp = self.quantize_kv(k_new)
            v_new, v_sc, v_zp = self.quantize_kv(v_new)
        t = self._table(session)
        T = k_new.shape[1]
        if start < 0 or start + T > t.length:
            raise ValueError(
                f"fill [{start}, {start + T}) outside the session's {t.length} slots"
            )
        written = 0
        while written < T:
            pos = start + written
            bi = pos // self.block_size
            page = t.blocks[bi]
            if not t.reserved and int(self.refcounts[page]) > 1:
                new = self._alloc_page()
                self._copy_page(page, new)
                self._count("cow_copies")
                t.blocks[bi] = new
                self._decref(page)
                page = new
            slot = pos % self.block_size
            take = min(self.block_size - slot, T - written)
            ksl = jax.lax.dynamic_slice_in_dim(k_new, written, take, axis=1)
            vsl = jax.lax.dynamic_slice_in_dim(v_new, written, take, axis=1)
            self.k_pages = self.k_pages.at[:, page, slot : slot + take].set(ksl)
            self.v_pages = self.v_pages.at[:, page, slot : slot + take].set(vsl)
            if self.quantize == "int8":
                sl = slice(slot, slot + take)
                for pages, new in (
                    ("k_scale", k_sc), ("k_zero", k_zp), ("v_scale", v_sc), ("v_zero", v_zp),
                ):
                    cut = jax.lax.dynamic_slice_in_dim(new, written, take, axis=1)
                    setattr(self, pages, getattr(self, pages).at[:, page, sl].set(cut))
            written += take
        if start <= t.filled:  # gap-free writes extend the materialized prefix
            t.filled = max(t.filled, start + T)

    # ------------------------------------------------------------- sharding --
    def shard_axes(self, shards: int) -> bool:
        """True iff the pool's KV head axis splits evenly over ``shards``.

        The divisibility gate for the tensor-parallel verifier: an even
        split stores ``Hkv / shards`` heads per device; an uneven one
        replicates the pages (the sharded launch still pads the GQA-expanded
        query heads, so correctness never depends on this answer).
        """
        if shards < 1:
            raise ValueError(f"shards must be >= 1, got {shards}")
        return self.n_kv_heads > 0 and self.n_kv_heads % shards == 0

    def shard_spec(self, shards: int, axis: str = "model"):
        """PartitionSpecs for the page buffers on a 1-D ``(axis,)`` mesh.

        Returns ``(pages_spec, planes_spec)`` — for the
        ``[L, num_blocks + 1, bs, Hkv, hd]`` payload buffers and the int8
        ``[L, num_blocks + 1, bs, Hkv]`` scale/zero planes.  The head axis is
        sharded only when it divides evenly (``shard_axes``); otherwise both
        specs replicate.  Block-table metadata stays host-side and is
        replicated to every device at launch (per-device block tables), so
        the sentinel page — the last page of every buffer — exists in each
        shard's local head slice and the pad contract holds per shard.
        """
        if self.shard_axes(shards) and shards > 1:
            from jax.sharding import PartitionSpec as P

            return P(None, None, None, axis, None), P(None, None, None, axis)
        from jax.sharding import PartitionSpec as P

        return P(None, None, None, None, None), P(None, None, None, None)

    def place_on_mesh(self, mesh, axis: str = "model"):
        """Lay the tensor-mode page buffers out over ``mesh`` (head axis).

        ``device_put``s ``k_pages``/``v_pages`` (and the int8 scale/zero
        planes) with the ``shard_spec`` layout, so each device holds only
        its ``Hkv / shards`` head slice of every physical page — the
        partitioned-pool state the sharded verify launch consumes.  Returns
        the pages spec used.  Metadata mode is a no-op (there is nothing to
        place); uneven head counts replicate, as per ``shard_spec``.
        """
        from jax.sharding import NamedSharding

        shards = int(np.prod(list(mesh.shape.values())))
        pages_spec, planes_spec = self.shard_spec(shards, axis=axis)
        if self.k_pages is None:
            return pages_spec
        pages_sh = NamedSharding(mesh, pages_spec)
        planes_sh = NamedSharding(mesh, planes_spec)
        self.k_pages = jax.device_put(self.k_pages, pages_sh)
        self.v_pages = jax.device_put(self.v_pages, pages_sh)
        if self.quantize == "int8":
            self.k_scale = jax.device_put(self.k_scale, planes_sh)
            self.k_zero = jax.device_put(self.k_zero, planes_sh)
            self.v_scale = jax.device_put(self.v_scale, planes_sh)
            self.v_zero = jax.device_put(self.v_zero, planes_sh)
        return pages_spec

    def resident_bytes_per_shard(self, shards: int) -> int:
        """Bytes of in-use pages RESIDENT ON EACH DEVICE at ``shards`` shards.

        With an even head split every page's payload (and its int8 quant
        planes, which shard with their KV) divides by ``shards``; an uneven
        split replicates, so each shard carries the full footprint.  At
        ``shards=1`` this equals ``resident_bytes()``.
        """
        total = self.resident_bytes()
        if self.shard_axes(shards):
            return total // shards
        return total

    def tensor_nbytes(self) -> int:
        """Actual bytes held by ALL page buffers (payload + quant params).

        Always ``(num_blocks + 1) * bytes_per_block`` in tensor mode — the
        invariant that pins the byte accounting to the real buffer
        footprint (``tests/test_paged_kv.py``).  Metadata mode returns 0.
        """
        bufs = (self.k_pages, self.v_pages, self.k_scale, self.k_zero,
                self.v_scale, self.v_zero)
        return sum(b.nbytes for b in bufs if b is not None)

    # ----------------------------------------------------------- reporting --
    def table(
        self, session: int, pad_to: Optional[int] = None, pad_id: Optional[int] = None
    ) -> np.ndarray:
        """The session's block table as int32, optionally padded to ``pad_to``.

        Pad entries carry ``pad_id``, defaulting to ``sentinel_page`` — the
        zero-filled page no session can own, so padded lanes never prefetch
        another session's KV even before length masking applies (see
        ``docs/kernels.md``).

        The sentinel id is ``num_blocks``, one past the allocatable pool:
        it indexes the pool's own ``num_blocks + 1``-page tensor buffers,
        but any EXTERNAL page buffer gathered through a sentinel-padded
        table (a ``batched_logits_fn`` consumer's arrays, or any buffer
        paired with a metadata-mode pool, which has no tensor storage of
        its own) must likewise be sized ``num_blocks + 1`` with a zeroed
        last page — a strict gather otherwise indexes out of bounds (and
        ``jnp`` indexing silently clamps to the last live page).  Callers
        that cannot resize their buffers must pass an in-range ``pad_id``
        explicitly.
        """
        t = self._table(session)
        ids = t.blocks
        if pad_to is not None:
            if len(ids) > pad_to:
                raise ValueError(f"table of {len(ids)} pages exceeds pad_to={pad_to}")
            fill = self.sentinel_page if pad_id is None else pad_id
            ids = ids + [fill] * (pad_to - len(ids))
        return np.asarray(ids, np.int32)

    def length(self, session: int) -> int:
        """The session's committed token count."""
        return self._table(session).length

    def filled(self, session: int) -> int:
        """Positions ``[0, filled)`` hold materialized tensors (tensor mode).

        The watermark tensor-filling backends must refill from: ``fill``
        advances it, ``rollback`` lowers it past rejected (and possibly
        recycled) slots, ``evict`` zeroes it, and it dies with the table on
        ``release`` — so a reused session id never inherits a dead
        session's watermark.
        """
        return self._table(session).filled

    def shared_blocks(self) -> int:
        """Distinct pages referenced by more than one session."""
        return int(np.sum(self.refcounts > 1))

    def resident_bytes(self) -> int:
        """Bytes backing all distinct in-use pages (sharing counted once)."""
        return self.used_blocks * self.bytes_per_block

    def resident_bytes_for(self, session: int) -> int:
        """Bytes of pages this session references (shared pages counted fully).

        Summing this over sessions exceeds ``resident_bytes()`` exactly by
        the prefix-sharing win.
        """
        return len(self._table(session).blocks) * self.bytes_per_block

    def load_summary(self) -> dict:
        """Point-in-time pool metrics for benchmarks and the serving monitor."""
        n_resident = self.resident_sessions
        return dict(
            kv_used_blocks=self.used_blocks,
            kv_free_blocks=self.free_blocks,
            kv_resident_bytes=self.resident_bytes(),
            kv_bytes_per_session=(self.resident_bytes() / n_resident if n_resident else 0.0),
            kv_shared_blocks=self.shared_blocks(),
            kv_resident_sessions=n_resident,
            kv_max_resident_sessions=self.max_resident_sessions,
            kv_max_used_blocks=self.max_used_blocks,
            kv_cow_copies=self.stats["cow_copies"],
            kv_evictions=self.stats["evictions"],
            kv_op_seconds=self.op_seconds,
        )
