"""Paged KV-cache: a global block pool with copy-on-write prefix sharing.

The flat ``KVCache`` (``models/kvcache.py``) allocates every session a
contiguous ``[L, 1, max_len, Hkv, hd]`` buffer, so verifier memory scales with
``sessions x max_len`` no matter how short the actual prefixes are.  This
module replaces that with the standard production layout (vLLM-style):

* **Physical pages.**  KV storage is a pool of ``num_blocks`` fixed-size
  pages of ``block_size`` token slots each; a page spans all layers
  (``k_pages/v_pages: [L, num_blocks, block_size, Hkv, hd]``).
* **Block tables.**  A session's logical cache is an ordered list of int32
  physical page ids plus a valid ``length``; logical position ``p`` lives in
  page ``table[p // block_size]`` at slot ``p % block_size``.  Attention
  kernels gather through the table (``kernels.decode_attention``'s paged
  entry) instead of assuming contiguity.
* **Copy-on-write prefix sharing.**  ``fork`` gives a child session the
  parent's page ids and bumps refcounts — sessions verified from a common
  system/prompt prefix reference the SAME physical pages.  The first append
  into a shared partial tail page copies just that page (``cow_copies``
  stat); full shared pages stay shared forever.
* **Refcounted free + LRU reuse.**  ``rollback`` (speculative-decoding
  rejection, tree ``replay_path`` anchor restore) releases whole pages past
  the committed length instead of deep-copying buffers; pages return to an
  LRU free list (oldest-freed reused first).  ``evict``/``evict_lru``
  reclaim idle sessions' pages under pool pressure (the victim re-prefills
  on its next round).

The pool runs in two modes: **metadata-only** (default — no tensor storage;
used by the serving dispatcher and the simulation engine for admission and
byte accounting) and **tensor mode** (``n_layers > 0`` — real jax page
buffers written through ``write`` and consumed by the paged attention
kernel).

Example (metadata mode; 4-token pages)::

    >>> pool = PagedKVPool(num_blocks=8, block_size=4)
    >>> pool.create(0)
    >>> pool.append(0, 6)        # 6 tokens -> 2 pages (one partial)
    >>> pool.used_blocks
    2
    >>> pool.fork(0, 1)          # CoW prefix share: no new pages
    >>> pool.used_blocks
    2
    >>> pool.append(1, 1)        # first write into the shared tail page
    >>> pool.used_blocks         # ... copies it (CoW divergence)
    3
    >>> pool.rollback(1, 2)      # reject back to 2 tokens: page freed
    1
    >>> pool.used_blocks
    2
    >>> pool.stats["cow_copies"]
    1
"""

from __future__ import annotations

import time
from collections import deque
from dataclasses import dataclass, field
from typing import Deque, Dict, List, Optional, Sequence

import jax
import jax.numpy as jnp
import numpy as np

__all__ = ["BlockPoolExhausted", "BlockTable", "PagedKVPool"]


class BlockPoolExhausted(RuntimeError):
    """Raised when an allocation needs more physical pages than are free."""


@dataclass
class BlockTable:
    """Per-session page list + valid length (the logical->physical map)."""

    blocks: List[int] = field(default_factory=list)
    length: int = 0
    reserved: bool = False  # flat-mode contiguous reservation (no CoW/free)
    last_touch: int = 0  # pool clock at last append/rollback (LRU eviction key)

    def capacity(self, block_size: int) -> int:
        """Token slots currently backed by physical pages."""
        return len(self.blocks) * block_size


class PagedKVPool:
    """Global physical-page pool with per-session block tables.

    Parameters
    ----------
    num_blocks, block_size:
        Pool geometry — ``num_blocks`` pages of ``block_size`` token slots.
    n_layers, n_kv_heads, head_dim, dtype:
        Tensor mode: when ``n_layers > 0``, real page buffers
        ``k_pages/v_pages: [L, num_blocks, block_size, Hkv, hd]`` are
        allocated and ``write`` scatters tokens into them.
    bytes_per_token:
        Byte-accounting override for metadata mode.  Tensor mode derives it
        from the KV geometry (k+v); metadata mode defaults to 1 so
        ``resident_bytes`` counts token slots.
    """

    def __init__(
        self,
        num_blocks: int,
        block_size: int,
        *,
        n_layers: int = 0,
        n_kv_heads: int = 0,
        head_dim: int = 0,
        dtype=jnp.float32,
        bytes_per_token: Optional[int] = None,
    ):
        if num_blocks < 1 or block_size < 1:
            raise ValueError("num_blocks and block_size must be >= 1")
        self.num_blocks = int(num_blocks)
        self.block_size = int(block_size)
        self.refcounts = np.zeros(self.num_blocks, np.int32)
        # LRU free list: freed pages append right, allocation pops left.
        self._free: Deque[int] = deque(range(self.num_blocks))
        self.tables: Dict[int, BlockTable] = {}
        self._clock = 0
        self._resident = 0  # sessions holding >=1 page, maintained incrementally
        self.stats = {"allocs": 0, "frees": 0, "cow_copies": 0, "evictions": 0}
        # Host seconds spent in metadata mutations (append/rollback/fork/
        # reserve/evict) — the pool's entire latency cost on the serving
        # path, so benchmarks can bound the TPT impact of paging.
        self.op_seconds = 0.0
        self.max_used_blocks = 0
        self.max_resident_sessions = 0
        self.k_pages: Optional[jax.Array] = None
        self.v_pages: Optional[jax.Array] = None
        if n_layers > 0:
            shape = (n_layers, self.num_blocks, self.block_size, n_kv_heads, head_dim)
            self.k_pages = jnp.zeros(shape, dtype)
            self.v_pages = jnp.zeros(shape, dtype)
            itemsize = jnp.dtype(dtype).itemsize
            self.bytes_per_token = 2 * n_layers * n_kv_heads * head_dim * itemsize
        else:
            self.bytes_per_token = int(bytes_per_token) if bytes_per_token else 1
        self.bytes_per_block = self.bytes_per_token * self.block_size

    # ------------------------------------------------------------ geometry --
    @property
    def free_blocks(self) -> int:
        """Pages currently on the free list."""
        return len(self._free)

    @property
    def used_blocks(self) -> int:
        """Distinct pages referenced by at least one session."""
        return self.num_blocks - len(self._free)

    @property
    def resident_sessions(self) -> int:
        """Sessions currently holding at least one page (O(1) counter)."""
        return self._resident

    def blocks_for(self, n_tokens: int) -> int:
        """Pages needed to back ``n_tokens`` from an empty table."""
        return -(-max(int(n_tokens), 0) // self.block_size)

    def blocks_needed(self, session: int, n_tokens: int) -> int:
        """Fresh pages an ``append(session, n_tokens)`` would allocate.

        Counts the CoW copy of a shared partial tail page, so admission
        control can gate on the exact allocation the append will perform.
        """
        t = self._table(session)
        need = self.blocks_for(t.length + n_tokens) - len(t.blocks)
        if n_tokens > 0 and self._tail_is_shared(t):
            need += 1  # the append CoW-copies the shared tail page
        return max(need, 0)

    def can_append(self, session: int, n_tokens: int) -> bool:
        """True iff ``append(session, n_tokens)`` would not exhaust the pool."""
        t = self._table(session)
        if t.reserved:
            return t.length + int(n_tokens) <= t.capacity(self.block_size)
        return self.blocks_needed(session, n_tokens) <= self.free_blocks

    # ---------------------------------------------------------- allocation --
    def _table(self, session: int) -> BlockTable:
        if session not in self.tables:
            raise KeyError(f"unknown session {session}")
        return self.tables[session]

    def _tail_is_shared(self, t: BlockTable) -> bool:
        if t.reserved or not t.blocks or t.length % self.block_size == 0:
            return False  # no partial tail page to write into
        return int(self.refcounts[t.blocks[-1]]) > 1

    def _alloc_page(self) -> int:
        if not self._free:
            raise BlockPoolExhausted(f"pool of {self.num_blocks} pages exhausted")
        page = self._free.popleft()
        self.refcounts[page] = 1
        self.stats["allocs"] += 1
        return page

    def _decref(self, page: int) -> None:
        self.refcounts[page] -= 1
        if self.refcounts[page] == 0:
            self._free.append(page)  # LRU: most recently freed goes last
            self.stats["frees"] += 1

    def _touch(self, t: BlockTable) -> None:
        self._clock += 1
        t.last_touch = self._clock
        self.max_used_blocks = max(self.max_used_blocks, self.used_blocks)
        self.max_resident_sessions = max(self.max_resident_sessions, self.resident_sessions)

    def create(self, session: int) -> None:
        """Register an empty session (no pages held until ``append``)."""
        if session in self.tables:
            raise ValueError(f"session {session} already exists")
        self.tables[session] = BlockTable()

    def fork(self, parent: int, child: int) -> None:
        """Copy-on-write fork: ``child`` shares all of ``parent``'s pages.

        No pages are allocated; every shared page's refcount is bumped.  The
        first append into a shared *partial* tail page copies it (see
        ``append``); full shared pages are never copied.
        """
        t0 = time.perf_counter()
        p = self._table(parent)
        if child in self.tables:
            raise ValueError(f"session {child} already exists")
        self.tables[child] = BlockTable(blocks=list(p.blocks), length=p.length)
        for page in p.blocks:
            self.refcounts[page] += 1
        if p.blocks:
            self._resident += 1
        self._touch(self.tables[child])
        self.op_seconds += time.perf_counter() - t0

    def reserve(self, session: int, max_tokens: int) -> None:
        """Flat-mode baseline: contiguously reserve pages for ``max_tokens``.

        Models the flat ``KVCache``'s up-front ``max_len`` allocation inside
        the same pool accounting, so flat-vs-paged residency is an
        apples-to-apples comparison.  Reserved tables never share, CoW, or
        release pages on rollback — exactly the flat cache's behaviour.
        """
        t0 = time.perf_counter()
        t = self._table(session)
        if t.blocks:
            raise ValueError(f"session {session} already holds pages")
        need = self.blocks_for(max_tokens)
        if need > self.free_blocks:
            raise BlockPoolExhausted(
                f"flat reservation of {need} pages exceeds {self.free_blocks} free"
            )
        t.blocks = [self._alloc_page() for _ in range(need)]
        t.reserved = True
        if t.blocks:
            self._resident += 1
        self._touch(t)
        self.op_seconds += time.perf_counter() - t0

    def append(self, session: int, n_tokens: int) -> None:
        """Extend a session by ``n_tokens`` slots, allocating pages on demand.

        If the session's tail page is partial *and* shared (post-``fork``),
        the tail is first copied to a fresh page — copy-on-write divergence:
        the writer pays one page copy, the other holders keep the original.
        Raises ``BlockPoolExhausted`` (leaving the table untouched) when the
        pool cannot back the growth; callers park or evict and retry.
        """
        t0 = time.perf_counter()
        t = self._table(session)
        n_tokens = int(n_tokens)
        if n_tokens <= 0:
            return
        if t.reserved:
            if t.length + n_tokens > t.capacity(self.block_size):
                raise BlockPoolExhausted(
                    f"flat reservation of session {session} overflows at "
                    f"{t.length + n_tokens} tokens"
                )
            t.length += n_tokens
            self._touch(t)
            self.op_seconds += time.perf_counter() - t0
            return
        if self.blocks_needed(session, n_tokens) > self.free_blocks:
            raise BlockPoolExhausted(
                f"append of {n_tokens} tokens needs "
                f"{self.blocks_needed(session, n_tokens)} pages, "
                f"{self.free_blocks} free"
            )
        if self._tail_is_shared(t):
            old = t.blocks[-1]
            new = self._alloc_page()
            self._copy_page(old, new)
            self.stats["cow_copies"] += 1
            t.blocks[-1] = new
            self._decref(old)
        had_pages = bool(t.blocks)
        while t.capacity(self.block_size) < t.length + n_tokens:
            t.blocks.append(self._alloc_page())
        if not had_pages and t.blocks:
            self._resident += 1
        t.length += n_tokens
        self._touch(t)
        self.op_seconds += time.perf_counter() - t0

    def rollback(self, session: int, new_length: int) -> int:
        """Truncate to ``new_length`` tokens, releasing whole trailing pages.

        The speculative-decoding rejection path: instead of deep-copying
        buffers, pages wholly past the committed prefix are decref'd (and
        freed when unshared).  Returns the number of pages this session
        dropped.  Reserved (flat) tables only move the length — the flat
        cache never returns memory.
        """
        t0 = time.perf_counter()
        t = self._table(session)
        new_length = int(new_length)
        if new_length > t.length:
            raise ValueError(f"rollback to {new_length} > current length {t.length}")
        t.length = new_length
        if t.reserved:
            self._touch(t)
            self.op_seconds += time.perf_counter() - t0
            return 0
        keep = self.blocks_for(new_length)
        dropped = t.blocks[keep:]
        t.blocks = t.blocks[:keep]
        for page in reversed(dropped):
            self._decref(page)
        if dropped and not t.blocks:
            self._resident -= 1
        self._touch(t)
        self.op_seconds += time.perf_counter() - t0
        return len(dropped)

    def release(self, session: int) -> None:
        """Drop a session entirely, decref'ing every page it held."""
        t = self._table(session)
        for page in reversed(t.blocks):
            self._decref(page)
        if t.blocks:
            self._resident -= 1
        del self.tables[session]

    def evict(self, session: int) -> int:
        """Reclaim a session's pages under pool pressure (it re-prefills later).

        The session stays registered with ``length = 0`` so its next round
        starts from an empty cache.  Returns the pages released.
        """
        t0 = time.perf_counter()
        t = self._table(session)
        dropped = len(t.blocks)
        for page in reversed(t.blocks):
            self._decref(page)
        if t.blocks:
            self._resident -= 1
        t.blocks = []
        t.length = 0
        t.reserved = False
        self.stats["evictions"] += 1
        self.op_seconds += time.perf_counter() - t0
        return dropped

    def evict_lru(self, exclude: Sequence[int] = ()) -> Optional[int]:
        """Evict the least-recently-touched page-holding session not excluded.

        Returns the victim's id, or None when every resident session is
        excluded (nothing safe to reclaim).
        """
        skip = set(exclude)
        victims = [
            (t.last_touch, sid)
            for sid, t in self.tables.items()
            if t.blocks and sid not in skip
        ]
        if not victims:
            return None
        _, sid = min(victims)
        self.evict(sid)
        return sid

    # ------------------------------------------------------------- tensors --
    def _copy_page(self, src: int, dst: int) -> None:
        if self.k_pages is not None:
            self.k_pages = self.k_pages.at[:, dst].set(self.k_pages[:, src])
            self.v_pages = self.v_pages.at[:, dst].set(self.v_pages[:, src])

    def write(self, session: int, k_new: jax.Array, v_new: jax.Array) -> None:
        """Append ``T`` tokens of KV (``[L, T, Hkv, hd]``) into the pages.

        Tensor mode only.  Handles page allocation + CoW via ``append``;
        tokens scatter into (page, slot) per the block table.
        """
        if self.k_pages is None:
            raise RuntimeError("pool was built without tensor storage (n_layers=0)")
        t = self._table(session)
        T = k_new.shape[1]
        start = t.length
        self.append(session, T)
        written = 0
        while written < T:
            pos = start + written
            page = t.blocks[pos // self.block_size]
            slot = pos % self.block_size
            take = min(self.block_size - slot, T - written)
            ksl = jax.lax.dynamic_slice_in_dim(k_new, written, take, axis=1)
            vsl = jax.lax.dynamic_slice_in_dim(v_new, written, take, axis=1)
            self.k_pages = self.k_pages.at[:, page, slot : slot + take].set(ksl)
            self.v_pages = self.v_pages.at[:, page, slot : slot + take].set(vsl)
            written += take

    # ----------------------------------------------------------- reporting --
    def table(self, session: int, pad_to: Optional[int] = None, pad_id: int = 0) -> np.ndarray:
        """The session's block table as int32, optionally padded to ``pad_to``.

        Pad entries carry ``pad_id`` (default 0 — a *valid* page index: the
        attention kernels mask pad positions by length, so the gathered
        garbage is inert; see ``docs/kernels.md``).
        """
        t = self._table(session)
        ids = t.blocks
        if pad_to is not None:
            if len(ids) > pad_to:
                raise ValueError(f"table of {len(ids)} pages exceeds pad_to={pad_to}")
            ids = ids + [pad_id] * (pad_to - len(ids))
        return np.asarray(ids, np.int32)

    def length(self, session: int) -> int:
        """The session's committed token count."""
        return self._table(session).length

    def shared_blocks(self) -> int:
        """Distinct pages referenced by more than one session."""
        return int(np.sum(self.refcounts > 1))

    def resident_bytes(self) -> int:
        """Bytes backing all distinct in-use pages (sharing counted once)."""
        return self.used_blocks * self.bytes_per_block

    def resident_bytes_for(self, session: int) -> int:
        """Bytes of pages this session references (shared pages counted fully).

        Summing this over sessions exceeds ``resident_bytes()`` exactly by
        the prefix-sharing win.
        """
        return len(self._table(session).blocks) * self.bytes_per_block

    def load_summary(self) -> dict:
        """Point-in-time pool metrics for benchmarks and the serving monitor."""
        n_resident = self.resident_sessions
        return dict(
            kv_used_blocks=self.used_blocks,
            kv_free_blocks=self.free_blocks,
            kv_resident_bytes=self.resident_bytes(),
            kv_bytes_per_session=(self.resident_bytes() / n_resident if n_resident else 0.0),
            kv_shared_blocks=self.shared_blocks(),
            kv_resident_sessions=n_resident,
            kv_max_resident_sessions=self.max_resident_sessions,
            kv_max_used_blocks=self.max_used_blocks,
            kv_cow_copies=self.stats["cow_copies"],
            kv_evictions=self.stats["evictions"],
            kv_op_seconds=self.op_seconds,
        )
