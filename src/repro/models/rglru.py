"""Griffin-style hybrid model: RG-LRU recurrent blocks + local attention.

Implements recurrentgemma-2b (arXiv:2402.19427): residual blocks in a
(recurrent, recurrent, local-attention) repeating pattern, each followed by a
gated MLP.  The RG-LRU recurrence

    r_t = σ(W_a x_t + b_a)                    (recurrence gate)
    i_t = σ(W_x x_t + b_x)                    (input gate)
    a_t = a^(c·r_t),  a = σ(Λ)  (per-channel), c = 8
    h_t = a_t ⊙ h_{t-1} + √(1 − a_t²) ⊙ (i_t ⊙ x_t)

is evaluated with ``jax.lax.associative_scan`` for train/prefill (O(log T)
depth — the TPU-native substitute for the paper's sequential CUDA scan) and a
single-step update for decode.  This is the *sub-quadratic* family: state is
O(1) in sequence length, so the ``long_500k`` decode shape runs here.

Caches: ``HybridCache`` = KV cache for the attention layers + recurrent
(h, conv) state for the RG-LRU layers.  Speculative rollback restores a
round-start snapshot (see kvcache.snapshot) because recurrent state cannot be
index-truncated.
"""

from __future__ import annotations

from typing import Any, Dict, NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from .config import ModelConfig
from .kvcache import KVCache, init_kv_cache
from . import layers as L

Params = Dict[str, Any]
RGLRU_C = 8.0


class HybridCache(NamedTuple):
    kv: KVCache  # [L_attn, B, S, Hkv, hd] self-attention cache
    rnn_h: jax.Array  # [L_rec, B, d_rnn] RG-LRU hidden state
    conv: jax.Array  # [L_rec, B, W-1, d_rnn] rolling conv inputs
    lengths: jax.Array  # [B] tokens absorbed


def _pattern(cfg: ModelConfig) -> Tuple[int, int]:
    """Returns (n_groups, n_tail_rec) for the (R,R,A) repeating pattern."""
    kinds = cfg.kinds
    n_groups = 0
    i = 0
    while i + 3 <= len(kinds) and kinds[i] == "rglru" and kinds[i + 1] == "rglru" and kinds[i + 2] in ("attn", "local"):
        n_groups += 1
        i += 3
    tail = len(kinds) - i
    if any(k != "rglru" for k in kinds[i:]):
        raise ValueError(f"{cfg.name}: layer_kinds must be (R,R,A)* + R*; got {kinds}")
    return n_groups, tail


# --------------------------------------------------------------------------- #
# RG-LRU block
# --------------------------------------------------------------------------- #


def init_rec_block(key, cfg: ModelConfig) -> Params:
    dtype = jnp.dtype(cfg.param_dtype)
    d, dr, W = cfg.d_model, cfg.d_rnn or cfg.d_model, cfg.conv_width
    ks = jax.random.split(key, 8)
    return {
        "ln1": jnp.zeros((d,), dtype),
        "ln2": jnp.zeros((d,), dtype),
        "w_in": L.dense_init(ks[0], (d, dr), dtype=dtype),  # rnn branch
        "w_gate": L.dense_init(ks[1], (d, dr), dtype=dtype),  # gelu gate branch
        "w_out": L.dense_init(ks[2], (dr, d), dtype=dtype),
        "conv_w": (jax.random.normal(ks[3], (W, dr)) * 0.1).astype(dtype),
        "conv_b": jnp.zeros((dr,), dtype),
        "wa": L.dense_init(ks[4], (dr, dr), dtype=dtype),
        "ba": jnp.zeros((dr,), dtype),
        "wx": L.dense_init(ks[5], (dr, dr), dtype=dtype),
        "bx": jnp.zeros((dr,), dtype),
        # Λ init so a = σ(Λ) ∈ [0.9, 0.999) roughly (long memory).
        "lam": jnp.asarray(np.linspace(2.2, 6.9, dr), dtype),
        "mlp": L.init_mlp(ks[6], d, cfg.d_ff, gated=True, dtype=dtype),
    }


def _assoc_linear_scan(a: jax.Array, b: jax.Array, h0: jax.Array) -> jax.Array:
    """h_t = a_t·h_{t-1} + b_t via associative_scan (forward value only)."""
    b = b.at[:, 0, :].add(a[:, 0, :] * h0)

    def combine(x, y):
        a1, b1 = x
        a2, b2 = y
        return a1 * a2, a2 * b1 + b2

    _, h = jax.lax.associative_scan(combine, (a, b), axis=1)
    return h


@jax.custom_vjp
def _rglru_scan(a: jax.Array, b: jax.Array, h0: jax.Array) -> jax.Array:
    """h_t = a_t·h_{t-1} + b_t over axis 1 (time), given h_0. Returns all h_t.

    Custom VJP: associative_scan's autodiff saves every log₂T combine stage
    (≈12 × [B,T,dr] f32 at train_4k — tens of GiB/device); the linear-scan
    adjoint is itself a *reverse* linear scan, so backward needs only (a, h):

        λ_t = g_t + a_{t+1}·λ_{t+1};   ∂b_t = λ_t;   ∂a_t = λ_t·h_{t-1};
        ∂h₀ = a_1·λ_1.
    """
    return _assoc_linear_scan(a, b, h0)


def _rglru_scan_fwd(a, b, h0):
    h = _assoc_linear_scan(a, b, h0)
    return h, (a, h, h0)


def _rglru_scan_bwd(res, g):
    a, h, h0 = res
    a_next = jnp.concatenate([a[:, 1:, :], jnp.zeros_like(a[:, :1, :])], axis=1)
    lam = jnp.flip(
        _assoc_linear_scan(jnp.flip(a_next, 1), jnp.flip(g, 1), jnp.zeros_like(h0)), 1
    )
    h_prev = jnp.concatenate([h0[:, None, :], h[:, :-1, :]], axis=1)
    da = lam * h_prev
    db = lam
    dh0 = a[:, 0, :] * lam[:, 0, :]
    return da, db, dh0


_rglru_scan.defvjp(_rglru_scan_fwd, _rglru_scan_bwd)


def _conv1d_causal(x: jax.Array, w: jax.Array, b: jax.Array, state: Optional[jax.Array]) -> Tuple[jax.Array, jax.Array]:
    """Depthwise causal conv over time. x: [B,T,dr]; w: [W,dr]. Returns (y, new_state)."""
    W = w.shape[0]
    if state is None:
        state = jnp.zeros((x.shape[0], W - 1, x.shape[2]), x.dtype)
    xp = jnp.concatenate([state, x], axis=1)  # [B, T+W-1, dr]
    y = sum(xp[:, i : i + x.shape[1], :] * w[i][None, None, :] for i in range(W)) + b
    new_state = xp[:, -(W - 1) :, :] if W > 1 else jnp.zeros((x.shape[0], 0, x.shape[2]), x.dtype)
    return y, new_state


def rec_block(
    p: Params, x: jax.Array, cfg: ModelConfig, rnn_h: jax.Array, conv_state: Optional[jax.Array]
) -> Tuple[jax.Array, jax.Array, jax.Array]:
    """One griffin recurrent residual block. Returns (out, new_h, new_conv)."""
    from repro.sharding.shardctx import constrain

    dp = ("pod", "data")
    cdr = lambda t: constrain(t, [dp, None, "model"])  # [B,T,dr]: batch + dr-TP
    h = L.rms_norm(x, p["ln1"], cfg.norm_eps)
    gate = cdr(jax.nn.gelu(h @ p["w_gate"]))
    u = cdr(h @ p["w_in"])
    u, new_conv = _conv1d_causal(u, p["conv_w"], p["conv_b"], conv_state)
    # RG-LRU in fp32 for stability; every [B,T,dr] f32 tensor is pinned to
    # (batch, ·, model) — unpinned, XLA un-shards the batch dim instead of
    # gathering the 2-D-sharded weights (≈2.7 GiB/device per live tensor).
    uf = cdr(u.astype(jnp.float32))
    r = cdr(jax.nn.sigmoid(uf @ p["wa"].astype(jnp.float32) + p["ba"].astype(jnp.float32)))
    i = cdr(jax.nn.sigmoid(uf @ p["wx"].astype(jnp.float32) + p["bx"].astype(jnp.float32)))
    log_a_base = jax.nn.log_sigmoid(p["lam"].astype(jnp.float32))  # log a
    a = jnp.exp(RGLRU_C * r * log_a_base[None, None, :])  # a^(c·r)
    b = jnp.sqrt(jnp.maximum(1.0 - a * a, 1e-9)) * (i * uf)
    hs = cdr(_rglru_scan(cdr(a), cdr(b), rnn_h.astype(jnp.float32)))  # [B,T,dr]
    new_h = hs[:, -1, :]
    y = constrain((hs.astype(x.dtype) * gate) @ p["w_out"], [dp, None, None])
    x = x + y
    h2 = L.rms_norm(x, p["ln2"], cfg.norm_eps)
    return x + L.mlp_block(p["mlp"], h2), new_h, new_conv


# --------------------------------------------------------------------------- #
# attention block (reuses layers.attention_block) + model assembly
# --------------------------------------------------------------------------- #


def init_attn_block(key, cfg: ModelConfig) -> Params:
    k1, k2 = jax.random.split(key)
    return {
        "ln1": jnp.zeros((cfg.d_model,), jnp.dtype(cfg.param_dtype)),
        "ln2": jnp.zeros((cfg.d_model,), jnp.dtype(cfg.param_dtype)),
        "attn": L.init_attention(k1, cfg),
        "mlp": L.init_mlp(k2, cfg.d_model, cfg.d_ff, gated=True, dtype=jnp.dtype(cfg.param_dtype)),
    }


def init(key: jax.Array, cfg: ModelConfig) -> Params:
    G, tail = _pattern(cfg)
    ks = jax.random.split(key, 4)
    rec_keys = jax.random.split(ks[0], max(G * 2 + tail, 1))
    attn_keys = jax.random.split(ks[1], max(G, 1))
    recs = [init_rec_block(k, cfg) for k in rec_keys[: G * 2 + tail]]
    stack = lambda blocks: jax.tree_util.tree_map(lambda *xs: jnp.stack(xs), *blocks)
    params: Params = {
        "embed": L.embed_init(ks[2], (cfg.padded_vocab_size, cfg.d_model), jnp.dtype(cfg.param_dtype)),
        "final_norm": jnp.zeros((cfg.d_model,), jnp.dtype(cfg.param_dtype)),
    }
    if G:
        params["rec_groups"] = stack([stack([recs[2 * g], recs[2 * g + 1]]) for g in range(G)])
        params["attn_groups"] = stack([init_attn_block(k, cfg) for k in attn_keys])
    if tail:
        params["rec_tail"] = stack(recs[G * 2 : G * 2 + tail])
    return params


def make_cache(cfg: ModelConfig, batch: int, max_len: int, dtype=None) -> HybridCache:
    G, tail = _pattern(cfg)
    dtype = dtype or jnp.dtype(cfg.dtype)
    dr = cfg.d_rnn or cfg.d_model
    n_rec = G * 2 + tail
    # Local attention: cache only needs the window, but we keep max_len for
    # simplicity at test scales; the serving path may pass window-sized S.
    kv = init_kv_cache(max(G, 1), batch, max_len, cfg.n_kv_heads, cfg.head_dim, dtype)
    return HybridCache(
        kv=kv,
        rnn_h=jnp.zeros((n_rec, batch, dr), jnp.float32),
        conv=jnp.zeros((n_rec, batch, cfg.conv_width - 1, dr), dtype),
        lengths=jnp.zeros((batch,), jnp.int32),
    )


def _run_stack(
    params: Params,
    x: jax.Array,
    positions: jax.Array,
    cfg: ModelConfig,
    cache: Optional[HybridCache],
) -> Tuple[jax.Array, Optional[HybridCache]]:
    G, tail = _pattern(cfg)
    attn_window = min([w for k, w in zip(cfg.kinds, cfg.windows) if k in ("attn", "local")] or [1 << 30])
    theta = cfg.rope_theta
    n_rec = G * 2 + tail
    lengths = cache.lengths if cache is not None else None

    def group_body(carry, xs):
        from repro.sharding.shardctx import constrain

        # Sequence-parallel group carry: the outer scan's VJP saves one
        # [B,T,d] residual per group — S-sharding it over 'model' shrinks the
        # stacked [G,B,T,d] saves 16x (perf iteration rgemma/it5, §Perf).
        x = carry
        if x.shape[1] >= 2048:
            x = constrain(x, [("pod", "data"), "model", None])
        if cache is None:
            # Per-block remat inside the (checkpointed) group body: without
            # it a whole (R,R,A) group's f32 norm/RG-LRU residuals stay live
            # during the group backward (~20 GiB/device at train_4k).
            rec_p, attn_p = xs
            for j in range(2):
                pj = jax.tree_util.tree_map(lambda a: a[j], rec_p)

                def rec_fn(xx, p=pj):
                    return rec_block(p, xx, cfg, jnp.zeros((xx.shape[0], p["w_in"].shape[1]), jnp.float32), None)[0]

                x = jax.checkpoint(rec_fn)(x) if cfg.remat else rec_fn(x)

            def attn_fn(xx):
                hh = L.rms_norm(xx, attn_p["ln1"], cfg.norm_eps)
                a_out, _ = L.attention_block(attn_p["attn"], hh, positions, cfg, theta, attn_window)
                xx = xx + a_out
                h2 = L.rms_norm(xx, attn_p["ln2"], cfg.norm_eps)
                return xx + L.mlp_block(attn_p["mlp"], h2)

            x = jax.checkpoint(attn_fn)(x) if cfg.remat else attn_fn(x)
            return x, None
        rec_p, attn_p, rnn_h2, conv2, k_l, v_l = xs
        new_hs, new_convs = [], []
        for j in range(2):
            pj = jax.tree_util.tree_map(lambda a: a[j], rec_p)
            x, nh, nc = rec_block(pj, x, cfg, rnn_h2[j], conv2[j])
            new_hs.append(nh)
            new_convs.append(nc)
        hh = L.rms_norm(x, attn_p["ln1"], cfg.norm_eps)
        a_out, new_kv = L.attention_block(attn_p["attn"], hh, positions, cfg, theta, attn_window, kv_cache=(k_l, v_l, lengths))
        x = x + a_out
        h2 = L.rms_norm(x, attn_p["ln2"], cfg.norm_eps)
        x = x + L.mlp_block(attn_p["mlp"], h2)
        return x, (jnp.stack(new_hs), jnp.stack(new_convs), new_kv[0], new_kv[1])

    new_cache = None
    if G:
        if cache is None:
            body = jax.checkpoint(group_body) if cfg.remat else group_body
            x, _ = jax.lax.scan(body, x, (params["rec_groups"], params["attn_groups"]), unroll=cfg.scan_unroll or 1)
        else:
            rnn_h_g = cache.rnn_h[: 2 * G].reshape(G, 2, *cache.rnn_h.shape[1:])
            conv_g = cache.conv[: 2 * G].reshape(G, 2, *cache.conv.shape[1:])
            x, (nh, nc, nk, nv) = jax.lax.scan(
                group_body, x, (params["rec_groups"], params["attn_groups"], rnn_h_g, conv_g, cache.kv.k, cache.kv.v),
                unroll=cfg.scan_unroll or 1,
            )
            new_rnn_h = nh.reshape(2 * G, *cache.rnn_h.shape[1:])
            new_conv = nc.reshape(2 * G, *cache.conv.shape[1:])
    if tail:

        def tail_body(carry, xs):
            x = carry
            if cache is None:
                rec_p = xs
                x, _, _ = rec_block(rec_p, x, cfg, jnp.zeros((x.shape[0], rec_p["w_in"].shape[1]), jnp.float32), None)
                return x, None
            rec_p, h_l, c_l = xs
            x, nh, nc = rec_block(rec_p, x, cfg, h_l, c_l)
            return x, (nh, nc)

        if cache is None:
            x, _ = jax.lax.scan(tail_body, x, params["rec_tail"], unroll=cfg.scan_unroll or 1)
        else:
            x, (th, tc) = jax.lax.scan(tail_body, x, (params["rec_tail"], cache.rnn_h[2 * G :], cache.conv[2 * G :]), unroll=cfg.scan_unroll or 1)
            new_rnn_h = jnp.concatenate([new_rnn_h, th], axis=0) if G else th
            new_conv = jnp.concatenate([new_conv, tc], axis=0) if G else tc
    if cache is not None:
        T = positions.shape[1]
        kv_new = KVCache(nk, nv, cache.kv.lengths + T) if G else cache.kv
        new_cache = HybridCache(kv_new, new_rnn_h, new_conv, cache.lengths + T)
    return x, new_cache


def final_hidden(params: Params, batch: Dict[str, jax.Array], cfg: ModelConfig) -> Tuple[jax.Array, jax.Array]:
    tokens = batch["tokens"]
    x = jnp.take(params["embed"], tokens, axis=0).astype(jnp.dtype(cfg.dtype))
    B, T = tokens.shape
    positions = jnp.broadcast_to(jnp.arange(T, dtype=jnp.int32)[None, :], (B, T))
    x, _ = _run_stack(params, x, positions, cfg, None)
    return L.rms_norm(x, params["final_norm"], cfg.norm_eps), jnp.float32(0.0)


def forward(params: Params, batch: Dict[str, jax.Array], cfg: ModelConfig) -> Tuple[jax.Array, jax.Array]:
    from .transformer import unembed

    x, aux = final_hidden(params, batch, cfg)
    return unembed(params, x, cfg), aux


def prefill(params: Params, batch: Dict[str, jax.Array], cache: HybridCache, cfg: ModelConfig) -> Tuple[jax.Array, HybridCache]:
    from .transformer import unembed

    tokens = batch["tokens"]
    x = jnp.take(params["embed"], tokens, axis=0).astype(jnp.dtype(cfg.dtype))
    B, T = tokens.shape
    positions = cache.lengths[:, None] + jnp.arange(T, dtype=jnp.int32)[None, :]
    x, new_cache = _run_stack(params, x, positions, cfg, cache)
    x = L.rms_norm(x, params["final_norm"], cfg.norm_eps)
    return unembed(params, x, cfg), new_cache


def decode(params: Params, tokens: jax.Array, cache: HybridCache, cfg: ModelConfig) -> Tuple[jax.Array, HybridCache]:
    return prefill(params, {"tokens": tokens}, cache, cfg)


def loss_fn(params: Params, batch: Dict[str, jax.Array], cfg: ModelConfig):
    from .losses import ce_metrics, chunked_ce
    from .transformer import unembed

    hidden, aux = final_hidden(params, batch, cfg)
    total, n_valid = chunked_ce(hidden, batch["labels"], lambda h: unembed(params, h, cfg), unroll=cfg.scan_unroll)
    ce, metrics = ce_metrics(total, n_valid)
    return ce, dict(metrics, aux=aux)
