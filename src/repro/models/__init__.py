"""Model zoo: configs, layers and the four architecture families."""

from .config import EncoderConfig, GLOBAL_WINDOW, ModelConfig, MoEConfig, padded_vocab
from .kvcache import KVCache, init_kv_cache, set_lengths, snapshot
from .paged_kv import BlockPoolExhausted, BlockTable, PagedKVPool
from . import encdec, layers, rglru, transformer, xlstm, zoo

__all__ = [
    "BlockPoolExhausted",
    "BlockTable",
    "EncoderConfig",
    "GLOBAL_WINDOW",
    "KVCache",
    "ModelConfig",
    "MoEConfig",
    "PagedKVPool",
    "encdec",
    "init_kv_cache",
    "layers",
    "padded_vocab",
    "rglru",
    "set_lengths",
    "snapshot",
    "transformer",
    "xlstm",
    "zoo",
]
