"""Token-batch pipeline scheduling (PipeSD §3.2, §4.1, Algorithm 1).

The edge generates draft tokens autoregressively (γ seconds per token) and must
ship them to the cloud over a channel whose per-batch cost is the Hockney model
``α + β·n`` (App. A).  A *batching strategy* is a strictly increasing boundary
sequence  𝔹 = (b_1, …, b_K), b_1 = 1, giving K batches where batch k covers
tokens [b_k, b_{k+1}).  Communication of batch k may start only once (i) batch
k's last token has been generated and (ii) batch k−1's communication finished
(Eqs. 4–5).  The makespan of a speculative round (Eq. 6) is

    T(𝔹) = τ_c^(K) + t_c^(K)

Algorithm 1 computes the optimal 𝔹 by dynamic programming over the recurrence
(App. E, Eq. 7):

    OPT(j) = min_{0 ≤ i < j}  max(OPT(i), γ·j) + α + β·(j − i),     OPT(0) = 0

which is exact because generation of token j finishes at γ·j regardless of the
batching (generation is never blocked by communication).

This module also provides the pipelined baselines of App. F (greedy,
immediate-send, no-early-upload) and a brute-force optimum used by the property
tests to validate Theorem 4.1.
"""

from __future__ import annotations

import itertools
import math
from dataclasses import dataclass, field
from typing import List, Sequence, Tuple

__all__ = [
    "CommParams",
    "Schedule",
    "dp_schedule",
    "greedy_schedule",
    "immediate_schedule",
    "no_early_upload_schedule",
    "brute_force_schedule",
    "simulate_schedule",
    "batch_sizes",
]


@dataclass(frozen=True)
class CommParams:
    """Channel / compute parameters of the pipeline model (Table A.1).

    alpha: startup overhead per transmission [s]
    beta:  per-token transmission time [s]
    gamma: per-token autoregressive generation time on the edge [s]
    """

    alpha: float
    beta: float
    gamma: float

    def __post_init__(self) -> None:
        if self.alpha < 0 or self.beta < 0 or self.gamma < 0:
            raise ValueError(f"CommParams must be non-negative, got {self}")

    def comm_time(self, n_tokens: int) -> float:
        """t_c for a batch of n_tokens (Eq. 2)."""
        return self.alpha + self.beta * n_tokens


@dataclass(frozen=True)
class Schedule:
    """A batching strategy 𝔹 plus its analytic makespan under the model."""

    boundaries: Tuple[int, ...]  # 1-based first-token index of each batch; b_1 == 1
    n_tokens: int
    makespan: float
    policy: str = "dp"

    def __post_init__(self) -> None:
        b = self.boundaries
        if not b or b[0] != 1:
            raise ValueError(f"boundaries must start at 1, got {b}")
        if any(x >= y for x, y in zip(b, b[1:])):
            raise ValueError(f"boundaries must be strictly increasing, got {b}")
        if b[-1] > self.n_tokens:
            raise ValueError(f"last boundary {b[-1]} > n_tokens {self.n_tokens}")

    @property
    def n_batches(self) -> int:
        return len(self.boundaries)


def batch_sizes(boundaries: Sequence[int], n_tokens: int) -> List[int]:
    """Token count of each batch for boundary sequence 𝔹 (Eq. 2's (b_{k+1}−b_k))."""
    ext = list(boundaries) + [n_tokens + 1]
    return [ext[k + 1] - ext[k] for k in range(len(boundaries))]


def simulate_schedule(boundaries: Sequence[int], n_tokens: int, p: CommParams) -> float:
    """Evaluate the makespan T(𝔹) by directly applying Eqs. (2)–(6).

    Used both as the DP's objective oracle in tests and by the pipeline engine
    to timestamp batch events.
    """
    sizes = batch_sizes(boundaries, n_tokens)
    tau_ag_end = 0.0  # generation completion time of current batch
    tau_c_free = 0.0  # time the channel becomes free
    for sz in sizes:
        tau_ag_end += p.gamma * sz  # Eq. (3)–(4): generation is back-to-back
        start = max(tau_c_free, tau_ag_end)  # Eq. (5)
        tau_c_free = start + p.comm_time(sz)  # Eq. (2)
    return tau_c_free  # Eq. (6): completion of last batch's communication


def dp_schedule(n_tokens: int, p: CommParams) -> Schedule:
    """Algorithm 1: O(N̂²) dynamic program returning the optimal 𝔹.

    dp[j] = minimal completion time (generation + communication) of the first
    j tokens; prev[j] = the batch boundary realizing it.
    """
    if n_tokens <= 0:
        raise ValueError(f"n_tokens must be positive, got {n_tokens}")
    INF = float("inf")
    dp = [INF] * (n_tokens + 1)
    prev = [-1] * (n_tokens + 1)
    dp[0] = 0.0
    for j in range(1, n_tokens + 1):
        gen_done = p.gamma * j  # token j's generation completes at γ·j
        best, best_i = INF, -1
        for i in range(j - 1, -1, -1):
            t_c = p.alpha + p.beta * (j - i)  # Eq. (2)
            cand = max(dp[i], gen_done) + t_c  # Eqs. (3)–(5) collapsed (App. E)
            if cand < best:
                best, best_i = cand, i
        dp[j] = best
        prev[j] = best_i
    # Backtrack (Algorithm 1, lines 10-13).
    bounds: List[int] = []
    j = n_tokens
    while j > 0:
        i = prev[j]
        bounds.append(i + 1)
        j = i
    bounds.reverse()
    return Schedule(tuple(bounds), n_tokens, dp[n_tokens], policy="dp")


def brute_force_schedule(n_tokens: int, p: CommParams) -> Schedule:
    """Exhaustive search over all 2^(N−1) batchings. Test oracle for Thm 4.1."""
    if n_tokens > 16:
        raise ValueError("brute force limited to N<=16")
    best: Tuple[float, Tuple[int, ...]] = (float("inf"), (1,))
    interior = list(range(2, n_tokens + 1))
    for r in range(len(interior) + 1):
        for cut in itertools.combinations(interior, r):
            b = (1,) + cut
            t = simulate_schedule(b, n_tokens, p)
            if t < best[0] - 1e-15:
                best = (t, b)
    return Schedule(best[1], n_tokens, best[0], policy="brute")


def immediate_schedule(n_tokens: int, p: CommParams) -> Schedule:
    """App. F *immediate-send*: every token is its own batch."""
    b = tuple(range(1, n_tokens + 1))
    return Schedule(b, n_tokens, simulate_schedule(b, n_tokens, p), policy="immediate")


def no_early_upload_schedule(n_tokens: int, p: CommParams) -> Schedule:
    """App. F *no-early-upload*: generate everything, then one batch."""
    b = (1,)
    return Schedule(b, n_tokens, simulate_schedule(b, n_tokens, p), policy="no_early_upload")


def greedy_schedule(n_tokens: int, p: CommParams) -> Schedule:
    """App. F *greedy*: when the channel goes idle, ship everything accumulated.

    Simulated forward in time: the first token forms the first batch (channel
    idle from t=0, nothing earlier to wait for); afterwards each time the
    channel frees up, all tokens generated since the previous send form the
    next batch (waiting for at least one token if none is pending).
    """
    bounds = [1]
    sent = 0  # tokens shipped so far
    tau_c_free = 0.0
    while sent < n_tokens:
        first_unsent = sent + 1
        gen_done_first = p.gamma * first_unsent
        start_floor = max(tau_c_free, gen_done_first)
        # Everything generated by the time the channel is usable goes in.
        n_ready = min(n_tokens, int(math.floor(start_floor / p.gamma + 1e-9))) if p.gamma > 0 else n_tokens
        n_ready = max(n_ready, first_unsent)
        sz = n_ready - sent
        if sent + sz < n_tokens:
            bounds.append(n_ready + 1)
        start = max(tau_c_free, p.gamma * n_ready)
        tau_c_free = start + p.comm_time(sz)
        sent = n_ready
    return Schedule(tuple(bounds), n_tokens, simulate_schedule(tuple(bounds), n_tokens, p), policy="greedy")


POLICIES = {
    "dp": dp_schedule,
    "greedy": greedy_schedule,
    "immediate": immediate_schedule,
    "no_early_upload": no_early_upload_schedule,
}


def schedule(policy: str, n_tokens: int, p: CommParams) -> Schedule:
    """Dispatch by policy name (used by the pipeline engine and benchmarks)."""
    try:
        fn = POLICIES[policy]
    except KeyError:
        raise KeyError(f"unknown scheduling policy {policy!r}; have {sorted(POLICIES)}") from None
    return fn(n_tokens, p)
