"""JAX speculative decoding with dual-threshold triggering (PipeSD §2.2, §3.3).

This module is model-agnostic: it consumes two callables

    draft_step(params, token[B], cache)  -> (logits[B,V], cache)
    (the target side runs its own forward; see ``verify_greedy`` /
     ``verify_stochastic`` which operate on the target's logits)

and provides:

* ``draft_round``      — on-device ``lax.while_loop`` that autoregressively
  drafts up to ``window`` tokens and *stops early* when the dual-threshold NAV
  trigger fires (C1 ≤ R1 or P(D_n) ≤ R2).  This is the TPU-native adaptation of
  PipeSD's edge loop: the trigger is evaluated in the carry, with no host sync.
* ``verify_greedy``    — the paper's NAV rule: accept the longest prefix that
  matches the target's greedy tokens; the first mismatch is corrected.
* ``verify_stochastic``— Leviathan/Chen exact rejection sampling, preserving
  the target distribution (accept w.p. min(1, p/q); on first reject, resample
  from norm(max(p−q, 0)); on full accept, sample the bonus token).
* ``SpecDecoder``      — host-side orchestration of full generations out of
  jitted rounds, used by tests/examples (the real deployment splits the two
  halves across the edge/cloud runtime in ``repro/runtime``).
* ``tree_draft_round`` — tree-structured drafting (top-k branching under the
  same dual-threshold trigger, applied per root→node path), verified in one
  call by the tree-NAV kernel ``repro.kernels.spec_verify.spec_verify_tree``;
  ``tree_target_logits`` is the per-path replay oracle for the packed tree
  logits and ``tree_verify_stochastic`` the multi-branch exact-sampling
  variant (SpecInfer-style).

All functions are jit-compatible and batched.
"""

from __future__ import annotations

import functools
from dataclasses import dataclass
from typing import Any, Callable, NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp

__all__ = [
    "DraftConfig",
    "DraftResult",
    "VerifyResult",
    "TreeDraftConfig",
    "TreeDraftResult",
    "draft_round",
    "replay_path",
    "tree_draft_round",
    "tree_target_logits",
    "tree_verify_stochastic",
    "verify_greedy",
    "verify_stochastic",
    "SpecDecoder",
    "sample_from_logits",
]


@dataclass(frozen=True)
class DraftConfig:
    """Dual-threshold trigger + window parameters (§3.3)."""

    window: int  # scheduling window N̂ (hard cap on draft length per round)
    r1: float = 0.0  # cumulative sequence confidence threshold (0 disables)
    r2: float = 0.0  # single-token confidence threshold (0 disables)
    temperature: float = 0.0  # 0 => greedy drafting
    store_dists: bool = False  # keep full draft distributions (stochastic NAV)


class DraftResult(NamedTuple):
    tokens: jax.Array  # [B, window] int32, valid up to n_drafted (right-padded)
    confs: jax.Array  # [B, window] f32 draft probability of each chosen token
    n_drafted: jax.Array  # [B] int32 — tokens drafted before/at the trigger
    triggered: jax.Array  # [B] bool — True if the dual threshold fired (vs cap)
    seq_conf: jax.Array  # [B] f32 — C1 at loop exit (pre-reset)
    cache: Any  # draft cache advanced by n_drafted tokens
    dists: Optional[jax.Array]  # [B, window, V] draft distributions (optional)


class VerifyResult(NamedTuple):
    n_accepted: jax.Array  # [B] int32 — accepted draft tokens (0..K)
    correction: jax.Array  # [B] int32 — corrected/bonus token from the target
    all_accepted: jax.Array  # [B] bool


def sample_from_logits(logits: jax.Array, key: jax.Array, temperature: float) -> Tuple[jax.Array, jax.Array, jax.Array]:
    """Sample (or argmax) a token; return (token[B], prob[B], probs[B,V]).

    ``prob`` is the draft model's confidence P(D_n) of the chosen token —
    computed from the *pre-temperature* softmax so confidence semantics match
    the paper regardless of sampling temperature.
    """
    probs = jax.nn.softmax(logits.astype(jnp.float32), axis=-1)
    if temperature and temperature > 0.0:
        tok = jax.random.categorical(key, logits.astype(jnp.float32) / temperature, axis=-1)
    else:
        tok = jnp.argmax(logits, axis=-1)
    tok = tok.astype(jnp.int32)
    conf = jnp.take_along_axis(probs, tok[:, None], axis=-1)[:, 0]
    return tok, conf, probs


def draft_round(
    draft_step: Callable[[Any, jax.Array, Any], Tuple[jax.Array, Any]],
    params: Any,
    cache: Any,
    last_token: jax.Array,  # [B] int32 — last accepted token (round prefix end)
    cfg: DraftConfig,
    key: jax.Array,
    vocab_size: Optional[int] = None,
) -> DraftResult:
    """One speculative round's drafting as a single on-device while_loop.

    The loop carries (cache, token, k, C1, done-mask, buffers).  A batch lane
    stops contributing once its trigger fires; the loop exits when every lane
    is done or the window cap is hit.  Buffers are fixed-size [B, window] so
    the function compiles once per (B, window).
    """
    B = last_token.shape[0]
    W = cfg.window
    if cfg.store_dists and vocab_size is None:
        raise ValueError("store_dists=True requires vocab_size")

    tokens0 = jnp.zeros((B, W), jnp.int32)
    confs0 = jnp.zeros((B, W), jnp.float32)
    dists0 = jnp.zeros((B, W, vocab_size), jnp.float32) if cfg.store_dists else None

    def cond(state):
        k, done = state[2], state[5]
        return jnp.logical_and(k < W, ~jnp.all(done))

    def body(state):
        cache, tok, k, n, c1, done, trig, tokens, confs, dists, key = state
        key, sub = jax.random.split(key)
        logits, new_cache = draft_step(params, tok, cache)
        new_tok, conf, probs = sample_from_logits(logits, sub, cfg.temperature)
        # Dual-threshold evaluation (§3.3): C1* = C1 · P(D_n).
        c1_star = c1 * conf
        fire = jnp.logical_or(c1_star <= cfg.r1, conf <= cfg.r2)
        # Lanes already done are drained: they re-feed their final token, which
        # (on the first drained step) writes that token's KV entry — exactly
        # the entry needed when NAV accepts the full draft.  Extra entries
        # beyond that are truncated by the caller via cache lengths.
        write = ~done
        tokens = tokens.at[:, k].set(jnp.where(write, new_tok, tokens[:, k]))
        confs = confs.at[:, k].set(jnp.where(write, conf, confs[:, k]))
        if dists is not None:
            dists = dists.at[:, k, :].set(jnp.where(write[:, None], probs, dists[:, k, :]))
        n = n + write.astype(jnp.int32)
        new_c1 = jnp.where(write, jnp.where(fire, 1.0, c1_star), c1)
        new_trig = jnp.where(write, jnp.logical_or(trig, fire), trig)
        new_done = jnp.logical_or(done, fire)
        tok = jnp.where(write, new_tok, tok)
        return (new_cache, tok, k + 1, n, new_c1, new_done, new_trig, tokens, confs, dists, key)

    init = (
        cache,
        last_token.astype(jnp.int32),
        jnp.int32(0),
        jnp.zeros((B,), jnp.int32),
        jnp.ones((B,), jnp.float32),
        jnp.zeros((B,), bool),
        jnp.zeros((B,), bool),
        tokens0,
        confs0,
        dists0,
        key,
    )
    cache, tok, k, n, c1, done, trig, tokens, confs, dists, _ = jax.lax.while_loop(cond, body, init)
    # One post-loop feed of each lane's final drafted token: ensures the KV
    # entry for the last draft exists even when NAV later accepts all of it.
    # (Lanes that fired before the last iteration already got this entry from
    # their first drain step; the extra entries written beyond it land past
    # the valid prefix and are dropped when the caller resets cache lengths.)
    _, cache = draft_step(params, tok, cache)
    return DraftResult(tokens, confs, n, trig, c1, cache, dists)


# --------------------------------------------------------------------------- #
# Tree-structured drafting (FlowSpec/DiP-SD-style; verified by tree-NAV)
# --------------------------------------------------------------------------- #


@dataclass(frozen=True)
class TreeDraftConfig:
    """Top-k branching draft tree under the dual-threshold trigger.

    Each expanded node contributes its top-``width`` continuations; a child
    with token confidence P(D) ≤ ``r2`` is pruned (and so are its lower-ranked
    siblings — top-k is confidence-sorted), and a path whose cumulative
    confidence C1 = ∏ P(D) drops to ``r1`` keeps its node but stops expanding
    (the per-path analogue of the chain trigger firing).  ``max_nodes`` caps
    the packed tree size (the scheduling window N̂ generalized to node count);
    ``beam`` optionally caps the frontier per level, keeping only the
    highest-C1 paths.
    """

    depth: int  # max tree depth (levels of draft tokens)
    width: int  # top-k branching factor per expanded node
    max_nodes: int = 0  # total node budget; 0 → width · depth
    r1: float = 0.0  # per-path cumulative confidence threshold (0 disables)
    r2: float = 0.0  # single-token confidence threshold (0 disables)
    beam: int = 0  # frontier cap per level (0 = unbounded)
    store_dists: bool = False  # keep expansion distributions (stochastic NAV)

    def __post_init__(self) -> None:
        if self.depth < 1 or self.width < 1:
            raise ValueError(f"need depth ≥ 1 and width ≥ 1, got {self}")

    @property
    def node_budget(self) -> int:
        return self.max_nodes or self.width * self.depth


class TreeDraftResult(NamedTuple):
    tokens: Any  # np [N] int32 packed node tokens (level order, conf-sorted)
    parents: Any  # np [N] int32, -1 = root level; parents[i] < i
    confs: Any  # np [N] f32 draft probability of each node token
    path_confs: Any  # np [N] f32 cumulative C1 along the root→node path
    depths: Any  # np [N] int32 1-based node depth
    n_nodes: int
    anchor_cache: Any  # draft cache advanced by the anchor token only
    dists: Optional[Any]  # np [N+1, V]: row 0 anchor, row 1+i = node i's
    #   expansion distribution (zeros where a node was never expanded)


def tree_draft_round(
    draft_step: Callable[[Any, jax.Array, Any], Tuple[jax.Array, Any]],
    params: Any,
    cache: Any,
    last_token,  # int or [1] int32 — last accepted token (round prefix end)
    cfg: TreeDraftConfig,
    vocab_size: Optional[int] = None,
) -> TreeDraftResult:
    """Draft one speculative TREE from the committed prefix.

    Host-orchestrated BFS (one ``draft_step`` per expanded node — siblings
    share their parent's output cache, which is safe because caches are
    functional pytrees).  Nodes are appended level by level with siblings in
    descending confidence, so the packed order is topological AND the
    verifier's smallest-index tie-break prefers the higher-ranked sibling.

    The draft cache is NOT advanced past the anchor: after NAV the caller
    replays the accepted path from ``anchor_cache`` (cf. ``replay_path``),
    which is the tree analogue of the chain path's cache-length rollback —
    rejected branches never touch the committed cache.
    """
    import numpy as np

    if cfg.store_dists and vocab_size is None:
        raise ValueError("store_dists=True requires vocab_size")
    tok0 = jnp.asarray(last_token, jnp.int32).reshape(-1)[:1]
    budget = cfg.node_budget
    tokens: list = []
    parents: list = []
    confs: list = []
    pconfs: list = []
    depths: list = []
    dists = np.zeros((budget + 1, vocab_size), np.float32) if cfg.store_dists else None
    anchor_cache = None
    # Frontier entries: (node_idx (-1 = anchor), token [1], pre-cache, C1).
    frontier = [(-1, tok0, cache, 1.0)]
    for level in range(cfg.depth):
        nxt = []
        for pidx, ptok, pcache, pconf in frontier:
            if len(tokens) >= budget:
                break  # budget exhausted: don't pay forwards for dropped kids
            logits, ccache = draft_step(params, ptok, pcache)
            if pidx == -1:
                anchor_cache = ccache
            probs = np.asarray(jax.nn.softmax(jnp.asarray(logits, jnp.float32), axis=-1))[0]
            if dists is not None:
                dists[pidx + 1, :] = probs
            k = min(cfg.width, probs.shape[-1])
            top = np.argpartition(-probs, k - 1)[:k]
            top = top[np.argsort(-probs[top], kind="stable")]
            for t in top:
                conf = float(probs[t])
                if conf <= cfg.r2:
                    break  # conf-sorted: lower-ranked siblings prune too (R2)
                if len(tokens) >= budget:
                    break
                idx = len(tokens)
                cp = pconf * conf
                tokens.append(int(t))
                parents.append(pidx)
                confs.append(conf)
                pconfs.append(cp)
                depths.append(level + 1)
                if cp > cfg.r1 and level + 1 < cfg.depth:
                    nxt.append((idx, jnp.asarray([int(t)], jnp.int32), ccache, cp))
                # cp ≤ r1: the path fired — keep the node, stop expanding it.
        if cfg.beam and len(nxt) > cfg.beam:
            nxt = sorted(nxt, key=lambda e: -e[3])[: cfg.beam]
        frontier = nxt
        if not frontier or len(tokens) >= budget:
            break
    n = len(tokens)
    return TreeDraftResult(
        np.asarray(tokens, np.int32),
        np.asarray(parents, np.int32),
        np.asarray(confs, np.float32),
        np.asarray(pconfs, np.float32),
        np.asarray(depths, np.int32),
        n,
        anchor_cache,
        None if dists is None else dists[: n + 1],
    )


def tree_target_logits(
    target_forward: Callable[[Any, jax.Array, Any], Tuple[jax.Array, Any]],
    params: Any,
    cache: Any,
    last_token,
    tokens,
    parents,
) -> jax.Array:
    """Packed tree logits [N+1, V] via per-path replay (reference oracle).

    Row 0 = target logits after feeding the anchor token; row 1+i = logits
    after feeding the root→i path.  Each replay restarts from a round-start
    ``snapshot`` of the target cache, so rejected branches never contaminate
    it.  A production target computes the same [N+1, V] in ONE forward over
    the packed nodes with ancestor-masked (tree) attention; this oracle is
    the semantics that forward must match.
    """
    from repro.kernels.spec_verify import tree_path
    from repro.models.kvcache import restore, snapshot

    base = snapshot(cache)
    rows = []
    for i in range(-1, len(tokens)):
        path = tree_path(parents, i)
        seq = jnp.asarray([[int(last_token)] + [int(tokens[j]) for j in path]], jnp.int32)
        lg, _ = target_forward(params, seq, restore(base))
        rows.append(lg[0, -1, :])
    return jnp.stack(rows)


def tree_verify_stochastic(
    target_probs,  # np/[N+1, V] — rows as in ``tree_target_logits``
    draft_probs,  # np/[N+1, V] — TreeDraftResult.dists (expansion dists)
    tokens,  # [N] packed node tokens
    parents,  # [N] packed parents (-1 = root level)
    rng,  # np.random.Generator
) -> Tuple[list, int]:
    """Multi-branch exact speculative sampling over a token tree.

    SpecInfer-style verification: walking from the anchor, each accepted
    node's children are tried in packed order, child x accepted w.p.
    min(1, p(x)/q(x)); after each rejection the target residual updates
    p ← norm(max(p − q, 0)).  When every child of the current node is
    rejected (or the node is a leaf), the correction token is sampled from
    the final residual (resp. the node's own target row — the bonus sample).
    With children drawn i.i.d. from q, the emitted marginal equals the
    target distribution exactly; a single-child tree reduces to
    ``verify_stochastic``.  Returns (accepted path node indices, correction).
    """
    import numpy as np

    target_probs = np.asarray(target_probs, np.float64)
    draft_probs = np.asarray(draft_probs, np.float64)
    n = len(tokens)
    children: list = [[] for _ in range(n + 1)]
    for i in range(n):
        children[int(parents[i]) + 1].append(i)
    path: list = []
    row = 0  # anchor
    while True:
        p = target_probs[row].copy()
        accepted = None
        for c in children[row]:
            x = int(tokens[c])
            q = draft_probs[row]
            if q[x] <= 0.0:
                continue  # not a draft-reachable token under q — skip
            if rng.random() < min(1.0, p[x] / q[x]):
                accepted = c
                break
            p = np.maximum(p - q, 0.0)
            s = p.sum()
            if s <= 0.0:  # q covers p exactly — fall back to the target row
                p = target_probs[row].copy()
            else:
                p = p / s
        if accepted is None:
            p = p / max(p.sum(), 1e-30)
            correction = int(rng.choice(len(p), p=p))
            return path, correction
        path.append(accepted)
        row = accepted + 1


def replay_path(
    draft_step: Callable[[Any, jax.Array, Any], Tuple[jax.Array, Any]],
    params: Any,
    cache: Any,
    tokens,
) -> Any:
    """Advance a draft cache through ``tokens`` (accepted-path rollforward)."""
    for t in tokens:
        _, cache = draft_step(params, jnp.asarray([int(t)], jnp.int32), cache)
    return cache


def verify_greedy(target_logits: jax.Array, draft_tokens: jax.Array, n_drafted: jax.Array) -> VerifyResult:
    """Paper-mode NAV: longest prefix matching the target's greedy choice.

    target_logits: [B, K+1, V] — target logits at each draft position plus one
        extra position (the standard "bonus" slot: logits after the last draft
        token, used for the correction when everything is accepted).
        Position i predicts draft token i, i.e. logits at prefix+i.
    draft_tokens:  [B, K]
    n_drafted:     [B] — valid draft lengths (≤ K); positions ≥ n_drafted are
        treated as automatic mismatches so padded lanes never over-accept.
    """
    B, K1, _ = target_logits.shape
    K = K1 - 1
    greedy = jnp.argmax(target_logits[:, :K, :], axis=-1).astype(jnp.int32)  # [B, K]
    pos = jnp.arange(K)[None, :]
    match = jnp.logical_and(greedy == draft_tokens, pos < n_drafted[:, None])
    # n_accepted = length of the all-True prefix.
    n_acc = jnp.sum(jnp.cumprod(match.astype(jnp.int32), axis=-1), axis=-1).astype(jnp.int32)
    all_acc = n_acc >= n_drafted
    # Correction: target's greedy token at the first mismatch; bonus otherwise.
    idx = jnp.minimum(n_acc, K)
    corr_all = jnp.argmax(target_logits, axis=-1).astype(jnp.int32)  # [B, K+1]
    correction = jnp.take_along_axis(corr_all, idx[:, None], axis=-1)[:, 0]
    return VerifyResult(n_acc, correction, all_acc)


def verify_stochastic(
    target_probs: jax.Array,  # [B, K+1, V] — target distributions per position
    draft_probs: jax.Array,  # [B, K, V]   — draft distributions per position
    draft_tokens: jax.Array,  # [B, K]
    n_drafted: jax.Array,  # [B]
    key: jax.Array,
) -> VerifyResult:
    """Exact speculative sampling (Leviathan et al. 2023; Chen et al. 2023).

    Accept draft token x_i with probability min(1, p_i(x_i)/q_i(x_i)).  At the
    first rejection resample from norm(max(p_i − q_i, 0)); if all K drafts are
    accepted, sample the bonus token from p_K.  The output marginal equals the
    target distribution exactly (validated by property test).
    """
    B, K1, V = target_probs.shape
    K = K1 - 1
    k_acc, k_res = jax.random.split(key)
    p_tok = jnp.take_along_axis(target_probs[:, :K, :], draft_tokens[..., None], axis=-1)[..., 0]
    q_tok = jnp.take_along_axis(draft_probs, draft_tokens[..., None], axis=-1)[..., 0]
    u = jax.random.uniform(k_acc, (B, K))
    ratio = p_tok / jnp.maximum(q_tok, 1e-30)
    pos = jnp.arange(K)[None, :]
    accept = jnp.logical_and(u < ratio, pos < n_drafted[:, None])
    n_acc = jnp.sum(jnp.cumprod(accept.astype(jnp.int32), axis=-1), axis=-1).astype(jnp.int32)
    all_acc = n_acc >= n_drafted
    # Residual distribution at the rejection position (per lane).
    idx = jnp.minimum(n_acc, K)
    p_at = jnp.take_along_axis(target_probs, idx[:, None, None], axis=1)[:, 0, :]  # [B, V]
    q_at = jnp.take_along_axis(
        jnp.concatenate([draft_probs, jnp.zeros((B, 1, V), draft_probs.dtype)], axis=1),
        idx[:, None, None],
        axis=1,
    )[:, 0, :]
    residual = jnp.maximum(p_at - q_at, 0.0)
    res_norm = residual / jnp.maximum(residual.sum(-1, keepdims=True), 1e-30)
    # On full accept the "residual" is just p_K (bonus sample from the target).
    dist = jnp.where(all_acc[:, None], p_at, res_norm)
    correction = jax.random.categorical(k_res, jnp.log(jnp.maximum(dist, 1e-30)), axis=-1).astype(jnp.int32)
    return VerifyResult(n_acc, correction, all_acc)


class SpecDecoder:
    """Host-side speculative-decoding orchestration from jitted rounds.

    Drives full generations for tests/examples and produces *round traces*
    (per-round draft length, confidences, acceptance) consumed by the pipeline
    engine and the benchmark suite.  The cloud/edge split of the same logic
    lives in ``repro/runtime`` — this class is the single-process reference.
    """

    def __init__(
        self,
        draft_step: Callable,
        target_forward: Callable,
        draft_params: Any,
        target_params: Any,
        cfg: DraftConfig,
        cache_truncate: Callable[[Any, jax.Array], Any],
        greedy_verify: bool = True,
        vocab_size: Optional[int] = None,
    ):
        self._raw_draft_step = draft_step
        self._vocab_size = vocab_size
        self.cfg = cfg
        self.greedy_verify = greedy_verify
        self.draft_params = draft_params
        self.target_params = target_params
        self.cache_truncate = jax.jit(cache_truncate)
        self.target_forward = jax.jit(target_forward)
        self._rebind()

    def _rebind(self) -> None:
        self._draft_round = jax.jit(
            functools.partial(draft_round, self._raw_draft_step, cfg=self.cfg, vocab_size=self._vocab_size)
        )

    def set_thresholds(self, r1: float, r2: float) -> None:
        """BO-autotuner hook (Parameter Updater, §4.2).

        Thresholds are static under jit, so updates recompile the draft round;
        this only happens on δ₁-triggered autotuner runs (App. D.1), whose cost
        the paper bounds at ≤1.1 % of wall time.
        """
        import dataclasses

        self.cfg = dataclasses.replace(self.cfg, r1=float(r1), r2=float(r2))
        self._rebind()

    def generate(
        self,
        prompt_tokens: jax.Array,  # [B, P]
        draft_cache: Any,
        target_cache: Any,
        prefill_draft: Callable,
        prefill_target: Callable,
        max_new_tokens: int,
        key: jax.Array,
    ):
        """Run full generations; returns (tokens list[B] of python lists, trace).

        The trace records, per speculative round: draft length, acceptance
        count, per-token confidences, and whether the dual threshold (vs the
        window cap) fired — exactly the statistics of Table 7 and the inputs
        the pipeline engine replays for timing.
        """
        import numpy as np

        B, P = prompt_tokens.shape
        _, draft_cache = prefill_draft(self.draft_params, prompt_tokens, draft_cache)
        t_logits, target_cache = prefill_target(self.target_params, prompt_tokens, target_cache)
        last = jnp.argmax(t_logits[:, -1, :], axis=-1).astype(jnp.int32)
        outputs = [[int(t)] for t in jax.device_get(last)]
        # Valid prefix length per lane (tokens whose KV both caches must hold).
        lens = jnp.full((B,), P, jnp.int32)
        trace = []
        while min(len(o) for o in outputs) < max_new_tokens:
            key, k1, k2 = jax.random.split(key, 3)
            dr = self._draft_round(self.draft_params, draft_cache, last, key=k1)
            # NAV: target forward over [last, drafts] → logits for K drafts + bonus.
            seq = jnp.concatenate([last[:, None], dr.tokens], axis=-1)  # [B, K+1]
            t_logits, target_cache = self.target_forward(self.target_params, seq, target_cache)
            if self.greedy_verify:
                vr = verify_greedy(t_logits, dr.tokens, dr.n_drafted)
            else:
                t_probs = jax.nn.softmax(t_logits.astype(jnp.float32), axis=-1)
                vr = verify_stochastic(t_probs, dr.dists, dr.tokens, dr.n_drafted, k2)
            toks, naccs, corrs, ndr, confs, trig = (
                np.asarray(jax.device_get(x))
                for x in (dr.tokens, vr.n_accepted, vr.correction, dr.n_drafted, dr.confs, dr.triggered)
            )
            for b in range(B):
                outputs[b].extend(toks[b, : naccs[b]].tolist())
                outputs[b].append(int(corrs[b]))
            # Roll both caches back to the accepted prefix: the round consumed
            # `last` (1 token) + accepted drafts.  Entries beyond are garbage
            # (rejected drafts / drain steps) and get overwritten.
            lens = lens + 1 + vr.n_accepted
            draft_cache = self.cache_truncate(dr.cache, lens)
            target_cache = self.cache_truncate(target_cache, lens)
            last = vr.correction
            trace.append(
                dict(
                    n_drafted=ndr.tolist(),
                    n_accepted=naccs.tolist(),
                    confs=confs.tolist(),
                    triggered=trig.tolist(),
                )
            )
        return outputs, trace
