"""JAX speculative decoding with dual-threshold triggering (PipeSD §2.2, §3.3).

This module is model-agnostic: it consumes two callables

    draft_step(params, token[B], cache)  -> (logits[B,V], cache)
    (the target side runs its own forward; see ``verify_greedy`` /
     ``verify_stochastic`` which operate on the target's logits)

and provides:

* ``draft_round``      — on-device ``lax.while_loop`` that autoregressively
  drafts up to ``window`` tokens and *stops early* when the dual-threshold NAV
  trigger fires (C1 ≤ R1 or P(D_n) ≤ R2).  This is the TPU-native adaptation of
  PipeSD's edge loop: the trigger is evaluated in the carry, with no host sync.
* ``verify_greedy``    — the paper's NAV rule: accept the longest prefix that
  matches the target's greedy tokens; the first mismatch is corrected.
* ``verify_stochastic``— Leviathan/Chen exact rejection sampling, preserving
  the target distribution (accept w.p. min(1, p/q); on first reject, resample
  from norm(max(p−q, 0)); on full accept, sample the bonus token).
* ``SpecDecoder``      — host-side orchestration of full generations out of
  jitted rounds, used by tests/examples (the real deployment splits the two
  halves across the edge/cloud runtime in ``repro/runtime``).

All functions are jit-compatible and batched.
"""

from __future__ import annotations

import functools
from dataclasses import dataclass
from typing import Any, Callable, NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp

__all__ = [
    "DraftConfig",
    "DraftResult",
    "VerifyResult",
    "draft_round",
    "verify_greedy",
    "verify_stochastic",
    "SpecDecoder",
    "sample_from_logits",
]


@dataclass(frozen=True)
class DraftConfig:
    """Dual-threshold trigger + window parameters (§3.3)."""

    window: int  # scheduling window N̂ (hard cap on draft length per round)
    r1: float = 0.0  # cumulative sequence confidence threshold (0 disables)
    r2: float = 0.0  # single-token confidence threshold (0 disables)
    temperature: float = 0.0  # 0 => greedy drafting
    store_dists: bool = False  # keep full draft distributions (stochastic NAV)


class DraftResult(NamedTuple):
    tokens: jax.Array  # [B, window] int32, valid up to n_drafted (right-padded)
    confs: jax.Array  # [B, window] f32 draft probability of each chosen token
    n_drafted: jax.Array  # [B] int32 — tokens drafted before/at the trigger
    triggered: jax.Array  # [B] bool — True if the dual threshold fired (vs cap)
    seq_conf: jax.Array  # [B] f32 — C1 at loop exit (pre-reset)
    cache: Any  # draft cache advanced by n_drafted tokens
    dists: Optional[jax.Array]  # [B, window, V] draft distributions (optional)


class VerifyResult(NamedTuple):
    n_accepted: jax.Array  # [B] int32 — accepted draft tokens (0..K)
    correction: jax.Array  # [B] int32 — corrected/bonus token from the target
    all_accepted: jax.Array  # [B] bool


def sample_from_logits(logits: jax.Array, key: jax.Array, temperature: float) -> Tuple[jax.Array, jax.Array, jax.Array]:
    """Sample (or argmax) a token; return (token[B], prob[B], probs[B,V]).

    ``prob`` is the draft model's confidence P(D_n) of the chosen token —
    computed from the *pre-temperature* softmax so confidence semantics match
    the paper regardless of sampling temperature.
    """
    probs = jax.nn.softmax(logits.astype(jnp.float32), axis=-1)
    if temperature and temperature > 0.0:
        tok = jax.random.categorical(key, logits.astype(jnp.float32) / temperature, axis=-1)
    else:
        tok = jnp.argmax(logits, axis=-1)
    tok = tok.astype(jnp.int32)
    conf = jnp.take_along_axis(probs, tok[:, None], axis=-1)[:, 0]
    return tok, conf, probs


def draft_round(
    draft_step: Callable[[Any, jax.Array, Any], Tuple[jax.Array, Any]],
    params: Any,
    cache: Any,
    last_token: jax.Array,  # [B] int32 — last accepted token (round prefix end)
    cfg: DraftConfig,
    key: jax.Array,
    vocab_size: Optional[int] = None,
) -> DraftResult:
    """One speculative round's drafting as a single on-device while_loop.

    The loop carries (cache, token, k, C1, done-mask, buffers).  A batch lane
    stops contributing once its trigger fires; the loop exits when every lane
    is done or the window cap is hit.  Buffers are fixed-size [B, window] so
    the function compiles once per (B, window).
    """
    B = last_token.shape[0]
    W = cfg.window
    if cfg.store_dists and vocab_size is None:
        raise ValueError("store_dists=True requires vocab_size")

    tokens0 = jnp.zeros((B, W), jnp.int32)
    confs0 = jnp.zeros((B, W), jnp.float32)
    dists0 = jnp.zeros((B, W, vocab_size), jnp.float32) if cfg.store_dists else None

    def cond(state):
        k, done = state[2], state[5]
        return jnp.logical_and(k < W, ~jnp.all(done))

    def body(state):
        cache, tok, k, n, c1, done, trig, tokens, confs, dists, key = state
        key, sub = jax.random.split(key)
        logits, new_cache = draft_step(params, tok, cache)
        new_tok, conf, probs = sample_from_logits(logits, sub, cfg.temperature)
        # Dual-threshold evaluation (§3.3): C1* = C1 · P(D_n).
        c1_star = c1 * conf
        fire = jnp.logical_or(c1_star <= cfg.r1, conf <= cfg.r2)
        # Lanes already done are drained: they re-feed their final token, which
        # (on the first drained step) writes that token's KV entry — exactly
        # the entry needed when NAV accepts the full draft.  Extra entries
        # beyond that are truncated by the caller via cache lengths.
        write = ~done
        tokens = tokens.at[:, k].set(jnp.where(write, new_tok, tokens[:, k]))
        confs = confs.at[:, k].set(jnp.where(write, conf, confs[:, k]))
        if dists is not None:
            dists = dists.at[:, k, :].set(jnp.where(write[:, None], probs, dists[:, k, :]))
        n = n + write.astype(jnp.int32)
        new_c1 = jnp.where(write, jnp.where(fire, 1.0, c1_star), c1)
        new_trig = jnp.where(write, jnp.logical_or(trig, fire), trig)
        new_done = jnp.logical_or(done, fire)
        tok = jnp.where(write, new_tok, tok)
        return (new_cache, tok, k + 1, n, new_c1, new_done, new_trig, tokens, confs, dists, key)

    init = (
        cache,
        last_token.astype(jnp.int32),
        jnp.int32(0),
        jnp.zeros((B,), jnp.int32),
        jnp.ones((B,), jnp.float32),
        jnp.zeros((B,), bool),
        jnp.zeros((B,), bool),
        tokens0,
        confs0,
        dists0,
        key,
    )
    cache, tok, k, n, c1, done, trig, tokens, confs, dists, _ = jax.lax.while_loop(cond, body, init)
    # One post-loop feed of each lane's final drafted token: ensures the KV
    # entry for the last draft exists even when NAV later accepts all of it.
    # (Lanes that fired before the last iteration already got this entry from
    # their first drain step; the extra entries written beyond it land past
    # the valid prefix and are dropped when the caller resets cache lengths.)
    _, cache = draft_step(params, tok, cache)
    return DraftResult(tokens, confs, n, trig, c1, cache, dists)


def verify_greedy(target_logits: jax.Array, draft_tokens: jax.Array, n_drafted: jax.Array) -> VerifyResult:
    """Paper-mode NAV: longest prefix matching the target's greedy choice.

    target_logits: [B, K+1, V] — target logits at each draft position plus one
        extra position (the standard "bonus" slot: logits after the last draft
        token, used for the correction when everything is accepted).
        Position i predicts draft token i, i.e. logits at prefix+i.
    draft_tokens:  [B, K]
    n_drafted:     [B] — valid draft lengths (≤ K); positions ≥ n_drafted are
        treated as automatic mismatches so padded lanes never over-accept.
    """
    B, K1, _ = target_logits.shape
    K = K1 - 1
    greedy = jnp.argmax(target_logits[:, :K, :], axis=-1).astype(jnp.int32)  # [B, K]
    pos = jnp.arange(K)[None, :]
    match = jnp.logical_and(greedy == draft_tokens, pos < n_drafted[:, None])
    # n_accepted = length of the all-True prefix.
    n_acc = jnp.sum(jnp.cumprod(match.astype(jnp.int32), axis=-1), axis=-1).astype(jnp.int32)
    all_acc = n_acc >= n_drafted
    # Correction: target's greedy token at the first mismatch; bonus otherwise.
    idx = jnp.minimum(n_acc, K)
    corr_all = jnp.argmax(target_logits, axis=-1).astype(jnp.int32)  # [B, K+1]
    correction = jnp.take_along_axis(corr_all, idx[:, None], axis=-1)[:, 0]
    return VerifyResult(n_acc, correction, all_acc)


def verify_stochastic(
    target_probs: jax.Array,  # [B, K+1, V] — target distributions per position
    draft_probs: jax.Array,  # [B, K, V]   — draft distributions per position
    draft_tokens: jax.Array,  # [B, K]
    n_drafted: jax.Array,  # [B]
    key: jax.Array,
) -> VerifyResult:
    """Exact speculative sampling (Leviathan et al. 2023; Chen et al. 2023).

    Accept draft token x_i with probability min(1, p_i(x_i)/q_i(x_i)).  At the
    first rejection resample from norm(max(p_i − q_i, 0)); if all K drafts are
    accepted, sample the bonus token from p_K.  The output marginal equals the
    target distribution exactly (validated by property test).
    """
    B, K1, V = target_probs.shape
    K = K1 - 1
    k_acc, k_res = jax.random.split(key)
    p_tok = jnp.take_along_axis(target_probs[:, :K, :], draft_tokens[..., None], axis=-1)[..., 0]
    q_tok = jnp.take_along_axis(draft_probs, draft_tokens[..., None], axis=-1)[..., 0]
    u = jax.random.uniform(k_acc, (B, K))
    ratio = p_tok / jnp.maximum(q_tok, 1e-30)
    pos = jnp.arange(K)[None, :]
    accept = jnp.logical_and(u < ratio, pos < n_drafted[:, None])
    n_acc = jnp.sum(jnp.cumprod(accept.astype(jnp.int32), axis=-1), axis=-1).astype(jnp.int32)
    all_acc = n_acc >= n_drafted
    # Residual distribution at the rejection position (per lane).
    idx = jnp.minimum(n_acc, K)
    p_at = jnp.take_along_axis(target_probs, idx[:, None, None], axis=1)[:, 0, :]  # [B, V]
    q_at = jnp.take_along_axis(
        jnp.concatenate([draft_probs, jnp.zeros((B, 1, V), draft_probs.dtype)], axis=1),
        idx[:, None, None],
        axis=1,
    )[:, 0, :]
    residual = jnp.maximum(p_at - q_at, 0.0)
    res_norm = residual / jnp.maximum(residual.sum(-1, keepdims=True), 1e-30)
    # On full accept the "residual" is just p_K (bonus sample from the target).
    dist = jnp.where(all_acc[:, None], p_at, res_norm)
    correction = jax.random.categorical(k_res, jnp.log(jnp.maximum(dist, 1e-30)), axis=-1).astype(jnp.int32)
    return VerifyResult(n_acc, correction, all_acc)


class SpecDecoder:
    """Host-side speculative-decoding orchestration from jitted rounds.

    Drives full generations for tests/examples and produces *round traces*
    (per-round draft length, confidences, acceptance) consumed by the pipeline
    engine and the benchmark suite.  The cloud/edge split of the same logic
    lives in ``repro/runtime`` — this class is the single-process reference.
    """

    def __init__(
        self,
        draft_step: Callable,
        target_forward: Callable,
        draft_params: Any,
        target_params: Any,
        cfg: DraftConfig,
        cache_truncate: Callable[[Any, jax.Array], Any],
        greedy_verify: bool = True,
        vocab_size: Optional[int] = None,
    ):
        self._raw_draft_step = draft_step
        self._vocab_size = vocab_size
        self.cfg = cfg
        self.greedy_verify = greedy_verify
        self.draft_params = draft_params
        self.target_params = target_params
        self.cache_truncate = jax.jit(cache_truncate)
        self.target_forward = jax.jit(target_forward)
        self._rebind()

    def _rebind(self) -> None:
        self._draft_round = jax.jit(
            functools.partial(draft_round, self._raw_draft_step, cfg=self.cfg, vocab_size=self._vocab_size)
        )

    def set_thresholds(self, r1: float, r2: float) -> None:
        """BO-autotuner hook (Parameter Updater, §4.2).

        Thresholds are static under jit, so updates recompile the draft round;
        this only happens on δ₁-triggered autotuner runs (App. D.1), whose cost
        the paper bounds at ≤1.1 % of wall time.
        """
        import dataclasses

        self.cfg = dataclasses.replace(self.cfg, r1=float(r1), r2=float(r2))
        self._rebind()

    def generate(
        self,
        prompt_tokens: jax.Array,  # [B, P]
        draft_cache: Any,
        target_cache: Any,
        prefill_draft: Callable,
        prefill_target: Callable,
        max_new_tokens: int,
        key: jax.Array,
    ):
        """Run full generations; returns (tokens list[B] of python lists, trace).

        The trace records, per speculative round: draft length, acceptance
        count, per-token confidences, and whether the dual threshold (vs the
        window cap) fired — exactly the statistics of Table 7 and the inputs
        the pipeline engine replays for timing.
        """
        import numpy as np

        B, P = prompt_tokens.shape
        _, draft_cache = prefill_draft(self.draft_params, prompt_tokens, draft_cache)
        t_logits, target_cache = prefill_target(self.target_params, prompt_tokens, target_cache)
        last = jnp.argmax(t_logits[:, -1, :], axis=-1).astype(jnp.int32)
        outputs = [[int(t)] for t in jax.device_get(last)]
        # Valid prefix length per lane (tokens whose KV both caches must hold).
        lens = jnp.full((B,), P, jnp.int32)
        trace = []
        while min(len(o) for o in outputs) < max_new_tokens:
            key, k1, k2 = jax.random.split(key, 3)
            dr = self._draft_round(self.draft_params, draft_cache, last, key=k1)
            # NAV: target forward over [last, drafts] → logits for K drafts + bonus.
            seq = jnp.concatenate([last[:, None], dr.tokens], axis=-1)  # [B, K+1]
            t_logits, target_cache = self.target_forward(self.target_params, seq, target_cache)
            if self.greedy_verify:
                vr = verify_greedy(t_logits, dr.tokens, dr.n_drafted)
            else:
                t_probs = jax.nn.softmax(t_logits.astype(jnp.float32), axis=-1)
                vr = verify_stochastic(t_probs, dr.dists, dr.tokens, dr.n_drafted, k2)
            toks, naccs, corrs, ndr, confs, trig = (
                np.asarray(jax.device_get(x))
                for x in (dr.tokens, vr.n_accepted, vr.correction, dr.n_drafted, dr.confs, dr.triggered)
            )
            for b in range(B):
                outputs[b].extend(toks[b, : naccs[b]].tolist())
                outputs[b].append(int(corrs[b]))
            # Roll both caches back to the accepted prefix: the round consumed
            # `last` (1 token) + accepted drafts.  Entries beyond are garbage
            # (rejected drafts / drain steps) and get overwritten.
            lens = lens + 1 + vr.n_accepted
            draft_cache = self.cache_truncate(dr.cache, lens)
            target_cache = self.cache_truncate(target_cache, lens)
            last = vr.correction
            trace.append(
                dict(
                    n_drafted=ndr.tolist(),
                    n_accepted=naccs.tolist(),
                    confs=confs.tolist(),
                    triggered=trig.tolist(),
                )
            )
        return outputs, trace
