"""Adaptive per-session serving policy: chain vs tree vs local-only.

PipeSD's Parameter Updater (§4.2) retunes thresholds when the monitored
environment drifts; FlowSpec-style systems additionally switch the
*speculation shape* (pipelined chain vs token tree) as acceptance shifts.
:class:`AdaptivePolicyController` combines both for one serving session:

* **mode** — ``'chain'`` while the sliding-window acceptance rate is
  high (deep chains amortize NAV well), ``'tree'`` once acceptance drops
  below a threshold (branching recovers tokens-per-NAV on hard streams;
  hysteresis avoids flapping), and ``'local'`` while the link is in an
  outage (the edge decodes alone, probing the cloud every few rounds so
  recovery is automatic);
* **knobs** — (R1, R2) and, for trees, (width, depth) are retuned with
  the existing :class:`~repro.core.autotuner.BOAutotuner` against short
  :class:`~repro.core.pipeline.PipelineEngine` probe simulations built
  from the monitor's current (α, β, γ) estimate.  Retunes fire on the
  paper's δ-triggers (App. D): a drifted link/device estimate or a
  drifted TPT window, rate-limited by a cooldown.  A retune only adopts
  the BO winner when it beats the incumbent configuration probed under
  the *same* environment, so a noisy probe can't make the policy worse.

The controller is deterministic given its seed and observation sequence
(the autotuner is BLAS-free), so fleet runs that embed it replay
bit-identically on the virtual clock.

Ownership: the client *feeds* the controller (``observe_link`` /
``observe_gamma`` / ``observe_round``) and *asks* it (``decide``) once
per speculative round; the controller never touches the transport.
"""

from __future__ import annotations

from collections import deque
from dataclasses import dataclass, replace
from typing import Deque, List, Optional, Tuple

from .autotuner import BOAutotuner
from .monitor import EnvironmentMonitor
from .pipeline import ChannelModel, CloudModel, EdgeModel, PipelineEngine, SyntheticSource, make_framework

__all__ = ["PolicyDecision", "PolicyConfig", "AdaptivePolicyController"]

MODES = ("chain", "tree", "local")


@dataclass(frozen=True)
class PolicyDecision:
    """One round's serving configuration for a session."""

    mode: str = "chain"  # 'chain' | 'tree' | 'local'
    r1: float = 0.9  # cumulative-confidence NAV threshold
    r2: float = 0.6  # per-token NAV threshold
    tree_width: int = 2
    tree_depth: int = 8
    window: int = 16  # scheduling window N̂ (cap on a round's drafts)

    def __post_init__(self) -> None:
        if self.mode not in MODES:
            raise ValueError(f"mode must be one of {MODES}, got {self.mode!r}")


@dataclass(frozen=True)
class PolicyConfig:
    """Tunables for :class:`AdaptivePolicyController`."""

    acceptance_window: int = 48  # sliding window of drafted tokens for the mode rule
    tree_below: float = 0.80  # acceptance below this → tree mode
    chain_above: float = 0.88  # acceptance back above this → chain mode (hysteresis)
    probe_every: int = 3  # while offline, attempt the cloud every k-th round
    retune_trials: int = 6  # BO samples per retune (cheap; the paper uses 16 offline)
    retune_tokens: int = 30  # accepted tokens per probe simulation
    min_rounds_between_retunes: int = 6  # cooldown against retune storms
    monitor_window: int = 12  # sliding window of the controller's own monitor


class AdaptivePolicyController:
    """Per-session chain/tree/local policy with BO retuning on drift."""

    def __init__(
        self,
        base: PolicyDecision = PolicyDecision(),
        cfg: PolicyConfig = PolicyConfig(),
        seed: int = 0,
        session: int = 0,
        channel: Optional[ChannelModel] = None,
        cloud: Optional[CloudModel] = None,
        edge: Optional[EdgeModel] = None,
    ):
        self.base = base
        self.cfg = cfg
        self.seed = int(seed)
        self.session = int(session)
        # Fallback probe environment when the monitor has no estimate yet.
        self._channel = channel or ChannelModel()
        self._cloud = cloud or CloudModel()
        self._edge = edge or EdgeModel()
        self.monitor = EnvironmentMonitor(window=cfg.monitor_window)
        self.current = base
        self.retunes = 0
        self.mode_switches = 0
        self.decisions: List[str] = []
        self.tuned: Optional[Tuple[float, float, int, int]] = None
        self._mode = base.mode if base.mode != "local" else "chain"
        self._offline = False
        self._offline_rounds = 0
        self._rounds = 0
        self._last_retune_round = -(10**9)
        self._acc: Deque[Tuple[int, int]] = deque()  # (drafted, accepted) per round

    # -------------------------------------------------------------- intake --
    def observe_link(self, size: int, comm_time: float) -> None:
        """One transmitted batch: size + communication time (unscaled s)."""
        self.monitor.observe_batch(size, comm_time)
        self._maybe_retune_on_drift()

    def observe_gamma(self, gamma: float) -> None:
        """One measured per-token draft time (unscaled s/token)."""
        self.monitor.observe_gamma(gamma)

    def observe_round(
        self,
        drafted: int,
        accepted: int,
        failover: bool = False,
        tpt: Optional[float] = None,
    ) -> None:
        """One speculative round's outcome (or a NAV-timeout failover)."""
        self._rounds += 1
        if failover:
            if not self._offline:
                self._offline_rounds = 0
            self._offline = True
            return
        if self._offline:
            self._offline = False  # a verified round ends the offline spell
        self._acc.append((int(drafted), int(accepted)))
        while sum(d for d, _ in self._acc) > self.cfg.acceptance_window and len(self._acc) > 1:
            self._acc.popleft()
        if tpt is not None and tpt > 0:
            self.monitor.observe_tpt(tpt)
        self._maybe_retune_on_drift()

    # ------------------------------------------------------------- signals --
    def acceptance(self) -> Optional[float]:
        """Sliding-window draft acceptance rate, or None before any round."""
        drafted = sum(d for d, _ in self._acc)
        if drafted <= 0:
            return None
        return sum(a for _, a in self._acc) / drafted

    @property
    def offline(self) -> bool:
        """Whether the controller currently believes the link is down."""
        return self._offline

    # ------------------------------------------------------------- retune --
    def _maybe_retune_on_drift(self) -> None:
        drifted_env = self.monitor.should_rerun_dp()
        drifted_tpt = self.monitor.should_rerun_bo()
        if drifted_env is None and drifted_tpt is None:
            return
        if self._rounds - self._last_retune_round < self.cfg.min_rounds_between_retunes:
            return
        self.retune(drifted_env)

    def retune(self, env: Optional[Tuple[float, float, float]] = None) -> Tuple[float, float, int, int]:
        """Re-run BO over the knobs against the current environment estimate.

        Returns the adopted (R1, R2, width, depth).  The BO winner is only
        adopted when its probed TPT beats the incumbent's probed TPT under
        the same environment.
        """
        alpha, beta, gamma = env or self.monitor.estimate() or (
            self._channel.alpha_up,
            self._channel.beta_up,
            self._edge.effective_gamma(),
        )
        tree = self._mode == "tree"
        acc = self.acceptance()
        # Map observed acceptance onto the probe source's hardness mix.
        p_hard = 0.15 if acc is None else min(0.6, max(0.05, 1.0 - acc))
        channel = replace(self._channel, alpha_up=float(alpha), beta_up=float(beta), bandwidth_trace=None)
        edge = replace(self._edge, gamma=float(gamma), simulated_ghz=None)
        probe_seed = (self.seed * 1000003 + self.session * 8191 + self.retunes) & 0x7FFFFFFF
        spec_name = "tree" if tree else "pipesd"

        def measure(r1: float, r2: float, w: float = 0.0, d: float = 0.0) -> float:
            overrides = dict(trigger_kw=dict(r1=float(r1), r2=float(r2)), autotune=False)
            if tree:
                overrides.update(tree_width=max(1, int(round(w))), tree_depth=max(2, int(round(d))))
            engine = PipelineEngine(
                make_framework(spec_name, **overrides),
                channel,
                self._cloud,
                edge,
                SyntheticSource(p_hard=p_hard, seed=probe_seed),
                window_init=self.current.window,
                seed=probe_seed,
            )
            return engine.run(self.cfg.retune_tokens).tpt

        cur = self.current
        if tree:
            bounds = ((0.0, 1.0), (0.0, 1.0), (1.0, 4.0), (2.0, 10.0))
            incumbent_y = measure(cur.r1, cur.r2, cur.tree_width, cur.tree_depth)
        else:
            bounds = ((0.0, 1.0), (0.0, 1.0))
            incumbent_y = measure(cur.r1, cur.r2)
        bo = BOAutotuner(bounds=bounds, seed=probe_seed)
        best = bo.minimize(measure, n_trials=self.cfg.retune_trials)
        if best.y < incumbent_y:
            if tree:
                r1, r2, w, d = best.x
                self.current = replace(
                    cur, r1=float(r1), r2=float(r2),
                    tree_width=max(1, int(round(w))), tree_depth=max(2, int(round(d))),
                )
            else:
                r1, r2 = best.x
                self.current = replace(cur, r1=float(r1), r2=float(r2))
        self.tuned = (self.current.r1, self.current.r2, self.current.tree_width, self.current.tree_depth)
        self.retunes += 1
        self._last_retune_round = self._rounds
        return self.tuned

    # -------------------------------------------------------------- decide --
    def decide(self) -> PolicyDecision:
        """The configuration for the next round (records mode history)."""
        if self._offline:
            self._offline_rounds += 1
            if self._offline_rounds % self.cfg.probe_every == 0:
                mode = self._mode  # probe round: try the cloud again
            else:
                mode = "local"
        else:
            acc = self.acceptance()
            if acc is not None:
                if self._mode == "chain" and acc < self.cfg.tree_below:
                    self._mode = "tree"
                elif self._mode == "tree" and acc > self.cfg.chain_above:
                    self._mode = "chain"
            mode = self._mode
        if self.decisions and self.decisions[-1] != mode:
            self.mode_switches += 1
        self.decisions.append(mode)
        return replace(self.current, mode=mode)
