"""PipeSD core: the paper's primary contribution as composable modules.

* ``scheduler``  — token-batch pipeline scheduling DP (Alg. 1) + baselines
* ``trigger``    — dual-threshold NAV triggering + baseline policies
* ``autotuner``  — lightweight Bayesian-optimization autotuner for (R1, R2)
* ``spec_decode``— JAX speculative decoding (draft while_loop + NAV verify)
* ``pipeline``   — event-driven cloud-edge pipeline engine
* ``monitor``    — environment monitor / parameter updater
* ``policy``     — adaptive per-session chain/tree/local policy controller
"""

from .autotuner import BOAutotuner, grid_search, random_search
from .monitor import EnvironmentMonitor, linear_fit_alpha_beta
from .policy import AdaptivePolicyController, PolicyConfig, PolicyDecision
from .pipeline import (
    FRAMEWORKS,
    ChannelModel,
    CloudModel,
    EdgeModel,
    FrameworkSpec,
    PipelineEngine,
    ReplaySource,
    RunStats,
    SyntheticSource,
    make_framework,
    periodic_bandwidth_trace,
)
from .scheduler import (
    CommParams,
    Schedule,
    batch_sizes,
    brute_force_schedule,
    dp_schedule,
    greedy_schedule,
    immediate_schedule,
    no_early_upload_schedule,
    simulate_schedule,
)
from .spec_decode import (
    DraftConfig,
    DraftResult,
    SpecDecoder,
    VerifyResult,
    draft_round,
    sample_from_logits,
    verify_greedy,
    verify_stochastic,
)
from .trigger import (
    DualThresholdTrigger,
    FixedLengthTrigger,
    SequenceThresholdTrigger,
    TokenThresholdTrigger,
    TriggerPolicy,
    WindowCapTrigger,
    make_trigger,
)

__all__ = [n for n in dir() if not n.startswith("_")]
