"""Lightweight Bayesian-optimization autotuner for (R1, R2) (PipeSD §3.3, App. C).

Minimizes an unknown objective  F(R1, R2)  (average TPT) over the box (0,1)²
using Gaussian-process regression with a Matérn-5/2 kernel and the Expected
Improvement acquisition function (ξ = 0.1 favouring exploration, App. C.1).
The paper reports near-optimal thresholds within ~16 samples; the benchmarks
reproduce Table 3 (BO vs 4×4 grid search vs 16-point random search).

Implementation is pure numpy (the autotuner is host-side control plane; Table 5
bounds its overhead at ≤1.1 % of wall time).  No scipy dependency in the hot
path — Φ and φ use ``math.erf`` — and no BLAS/LAPACK either: the tiny GP
solves use elementwise Cholesky/substitution so tuner trajectories (and the
committed benchmark rows that depend on them) are bit-reproducible across
hosts with different BLAS builds.
"""

from __future__ import annotations

import math
from dataclasses import dataclass, field
from typing import Callable, List, Optional, Sequence, Tuple

import numpy as np

__all__ = ["BOAutotuner", "grid_search", "random_search", "Observation"]

_SQRT5 = math.sqrt(5.0)


def _matern52(x1: np.ndarray, x2: np.ndarray, length_scale: float) -> np.ndarray:
    """Matérn-5/2 kernel matrix between row-stacks x1 (n,d) and x2 (m,d)."""
    d = np.sqrt(np.maximum(((x1[:, None, :] - x2[None, :, :]) ** 2).sum(-1), 0.0))
    r = d / length_scale
    return (1.0 + _SQRT5 * r + 5.0 / 3.0 * r * r) * np.exp(-_SQRT5 * r)


def _norm_pdf(z: np.ndarray) -> np.ndarray:
    return np.exp(-0.5 * z * z) / math.sqrt(2.0 * math.pi)


def _norm_cdf(z: np.ndarray) -> np.ndarray:
    return 0.5 * (1.0 + np.vectorize(math.erf)(z / math.sqrt(2.0)))


# The GP solves below deliberately avoid ``np.linalg`` (LAPACK) and matrix
# products (BLAS): committed benchmark rows are regenerated on arbitrary
# hosts, and different BLAS builds reorder float reductions enough to flip
# an argmax.  Elementwise numpy with its fixed pairwise-sum reduction is
# bit-stable across builds, and the matrices here are tiny (n ≤ ~20
# observations), so the loops cost microseconds.


def _cholesky(a: np.ndarray) -> np.ndarray:
    """Lower-triangular Cholesky factor of SPD ``a`` (BLAS/LAPACK-free)."""
    n = a.shape[0]
    lower = np.zeros_like(a)
    for i in range(n):
        for j in range(i + 1):
            s = float(a[i, j]) - float((lower[i, :j] * lower[j, :j]).sum())
            if i == j:
                lower[i, j] = math.sqrt(max(s, 1e-300))
            else:
                lower[i, j] = s / lower[j, j]
    return lower


def _solve_lower(lower: np.ndarray, b: np.ndarray) -> np.ndarray:
    """Forward substitution L·x = b for lower-triangular L; b is (n,) or (n, m)."""
    n = lower.shape[0]
    x = np.zeros_like(b, dtype=np.float64)
    for i in range(n):
        acc = (lower[i, :i].reshape(-1, *([1] * (b.ndim - 1))) * x[:i]).sum(axis=0)
        x[i] = (b[i] - acc) / lower[i, i]
    return x


def _solve_upper(upper: np.ndarray, b: np.ndarray) -> np.ndarray:
    """Back substitution U·x = b for upper-triangular U; b is (n,) or (n, m)."""
    n = upper.shape[0]
    x = np.zeros_like(b, dtype=np.float64)
    for i in range(n - 1, -1, -1):
        acc = (upper[i, i + 1 :].reshape(-1, *([1] * (b.ndim - 1))) * x[i + 1 :]).sum(axis=0)
        x[i] = (b[i] - acc) / upper[i, i]
    return x


@dataclass(frozen=True)
class Observation:
    x: Tuple[float, ...]  # (R1, R2)
    y: float  # measured objective (TPT, lower is better)


@dataclass
class BOAutotuner:
    """GP(Matérn-5/2) + EI Bayesian optimizer over a box domain.

    Usage (ask/tell — matches the Parameter Updater in §4.2):

        bo = BOAutotuner(bounds=[(0,1),(0,1)], seed=0)
        for _ in range(16):
            x = bo.suggest()
            y = measure_tpt(*x)
            bo.observe(x, y)
        r1, r2 = bo.best().x
    """

    bounds: Sequence[Tuple[float, float]] = ((0.0, 1.0), (0.0, 1.0))
    seed: int = 0
    xi: float = 0.1  # EI exploration parameter (App. C.1: EI = 0.1)
    length_scale: float = 0.25
    noise: float = 1e-6
    n_candidates: int = 512  # quasi-random acquisition candidates per suggest()
    observations: List[Observation] = field(default_factory=list)
    _rng: np.random.Generator = field(init=False, repr=False)

    def __post_init__(self) -> None:
        self._rng = np.random.default_rng(self.seed)
        self._lo = np.array([b[0] for b in self.bounds])
        self._hi = np.array([b[1] for b in self.bounds])

    # ------------------------------------------------------------------ GP --
    def _fit(self) -> Tuple[np.ndarray, np.ndarray, np.ndarray, float, float]:
        X = np.array([o.x for o in self.observations], dtype=np.float64)
        y = np.array([o.y for o in self.observations], dtype=np.float64)
        mu, sd = float(y.mean()), float(y.std() + 1e-12)
        yn = (y - mu) / sd
        K = _matern52(X, X, self.length_scale) + self.noise * np.eye(len(X))
        L = _cholesky(K + 1e-10 * np.eye(len(X)))
        alpha = _solve_upper(L.T, _solve_lower(L, yn))
        return X, L, alpha, mu, sd

    def _posterior(self, Xq: np.ndarray) -> Tuple[np.ndarray, np.ndarray]:
        """GP posterior mean/std at query points (normalized-y space)."""
        X, L, alpha, _, _ = self._gp
        Ks = _matern52(Xq, X, self.length_scale)
        mean = (Ks * alpha).sum(axis=1)
        v = _solve_lower(L, Ks.T)
        var = np.maximum(1.0 - (v * v).sum(0), 1e-12)
        return mean, np.sqrt(var)

    # ----------------------------------------------------------- ask / tell --
    def suggest(self) -> Tuple[float, ...]:
        """Next (R1,R2) to evaluate: random until 1 obs exists, then argmax EI."""
        if not self.observations:
            # App. C.1: a single random initial sample.
            x = self._rng.uniform(self._lo, self._hi)
            return tuple(float(v) for v in x)
        self._gp = self._fit()
        cand = self._rng.uniform(self._lo, self._hi, size=(self.n_candidates, len(self.bounds)))
        # Always include local perturbations of the incumbent (exploitation).
        inc = np.array(self.best().x)
        local = np.clip(inc + self._rng.normal(0, 0.05, size=(32, len(self.bounds))), self._lo, self._hi)
        cand = np.vstack([cand, local])
        mean, std = self._posterior(cand)
        _, _, _, mu, sd = self._gp
        y_best = (min(o.y for o in self.observations) - mu) / sd
        # EI for MINIMIZATION with exploration margin ξ.
        imp = y_best - mean - self.xi
        z = imp / std
        ei = imp * _norm_cdf(z) + std * _norm_pdf(z)
        return tuple(float(v) for v in cand[int(np.argmax(ei))])

    def observe(self, x: Sequence[float], y: float) -> None:
        if not np.isfinite(y):
            raise ValueError(f"objective must be finite, got {y}")
        self.observations.append(Observation(tuple(float(v) for v in x), float(y)))

    def best(self) -> Observation:
        if not self.observations:
            raise RuntimeError("no observations yet")
        return min(self.observations, key=lambda o: o.y)

    # -------------------------------------------------------------- driver --
    def minimize(self, fn: Callable[..., float], n_trials: int = 16) -> Observation:
        """Run the full ask/measure/tell loop (the paper's 16-sample budget)."""
        for _ in range(n_trials):
            x = self.suggest()
            self.observe(x, fn(*x))
        return self.best()

    # Persistence for serving restarts (fault tolerance): the GP is exactly
    # its observation list, so checkpointing observations checkpoints the tuner.
    def state_dict(self) -> dict:
        return {"observations": [(list(o.x), o.y) for o in self.observations], "seed": self.seed}

    @classmethod
    def from_state_dict(cls, state: dict, **kw) -> "BOAutotuner":
        bo = cls(seed=state.get("seed", 0), **kw)
        for x, y in state["observations"]:
            bo.observe(x, y)
        return bo


def grid_search(fn: Callable[..., float], bounds=((0.0, 1.0), (0.0, 1.0)), n_per_dim: int = 4) -> Observation:
    """App. C.2 baseline: 4×4 uniform grid (16 deterministic samples).

    Grid points are cell centers so endpoints 0/1 (degenerate thresholds) are
    avoided, matching the open search space (0,1)².
    """
    axes = [np.linspace(lo, hi, n_per_dim + 1)[:-1] + (hi - lo) / (2 * n_per_dim) for lo, hi in bounds]
    best: Optional[Observation] = None
    for x0 in axes[0]:
        for x1 in axes[1]:
            y = fn(float(x0), float(x1))
            if best is None or y < best.y:
                best = Observation((float(x0), float(x1)), y)
    assert best is not None
    return best


def random_search(fn: Callable[..., float], bounds=((0.0, 1.0), (0.0, 1.0)), n_trials: int = 16, seed: int = 0) -> Observation:
    """App. C.2 baseline: 16 uniform random samples."""
    rng = np.random.default_rng(seed)
    best: Optional[Observation] = None
    for _ in range(n_trials):
        x = tuple(float(rng.uniform(lo, hi)) for lo, hi in bounds)
        y = fn(*x)
        if best is None or y < best.y:
            best = Observation(x, y)
    assert best is not None
    return best
