"""NAV-triggering policies (PipeSD §3.3 + baselines §5.1 / App. G.3).

A trigger policy watches the stream of draft-token confidences ``P(D_n)`` and
decides *when* the edge should request cloud non-autoregressive verification
(NAV).  All policies share the interface:

    trig = DualThresholdTrigger(r1=..., r2=...)
    for conf in stream:
        if trig.observe(conf):   # True => request NAV now
            ...
    trig.on_verify(n_accepted, window)   # feedback after NAV completes

Policies implemented:

* ``DualThresholdTrigger`` — PipeSD: fire when the cumulative sequence
  confidence C1 = ∏ P(D_n) ≤ R1  **or**  P(D_n) ≤ R2.  C1 resets to 1 on fire.
* ``FixedLengthTrigger``   — Vanilla: fire every N tokens.
* ``TokenThresholdTrigger``— HSL: fire when P(D_n) ≤ R (single signal).
* ``SequenceThresholdTrigger`` — EdgeLLM: fire when C1 ≤ R1 where R1 is
  *dynamically* updated after each NAV per App. G.3 Eq. (7):
      R1 ← 0.5·R1                      if all N̂ tokens accepted
      R1 ← R1 ^ ((N̂−N_correct)/N̂)      otherwise   (raises R1 toward 1)
* ``WindowCapTrigger`` — safety wrapper: force-fire at a max window N̂ (PipeSD
  always carries this bound so a confident stream cannot draft forever).

All policies are pure-python host objects (the control plane); the on-device
mirror of the dual-threshold rule lives in ``core/spec_decode.py``.
"""

from __future__ import annotations

from dataclasses import dataclass, field
from typing import Optional

__all__ = [
    "TriggerPolicy",
    "DualThresholdTrigger",
    "FixedLengthTrigger",
    "TokenThresholdTrigger",
    "SequenceThresholdTrigger",
    "WindowCapTrigger",
    "make_trigger",
]


class TriggerPolicy:
    """Base interface; subclasses override ``observe`` and optionally ``on_verify``."""

    name = "base"

    def observe(self, conf: float) -> bool:  # pragma: no cover - interface
        raise NotImplementedError

    def on_verify(self, n_accepted: int, window: int) -> None:
        """Feedback hook called after each NAV round."""

    def reset(self) -> None:
        """Reset per-round state (called when a new speculative round starts)."""


@dataclass
class DualThresholdTrigger(TriggerPolicy):
    """PipeSD §3.3: joint sequence- and token-confidence triggering."""

    r1: float  # cumulative sequence confidence threshold R1
    r2: float  # single-token confidence threshold R2
    c1: float = field(default=1.0, init=False)  # running ∏ P(D_n)
    name = "dual"

    def __post_init__(self) -> None:
        if not (0.0 <= self.r1 <= 1.0 and 0.0 <= self.r2 <= 1.0):
            raise ValueError(f"thresholds must lie in [0,1], got R1={self.r1}, R2={self.r2}")

    def observe(self, conf: float) -> bool:
        c1_star = self.c1 * conf  # tentative cumulative confidence C1*
        if c1_star <= self.r1 or conf <= self.r2:
            self.c1 = 1.0  # reset on trigger (§3.3)
            return True
        self.c1 = c1_star
        return False

    def reset(self) -> None:
        self.c1 = 1.0

    def set_thresholds(self, r1: float, r2: float) -> None:
        """Hot-update from the BO autotuner (Parameter Updater, §4.2)."""
        self.r1, self.r2 = float(r1), float(r2)


@dataclass
class FixedLengthTrigger(TriggerPolicy):
    """Vanilla speculative decoding: fixed draft length N per round."""

    n: int
    count: int = field(default=0, init=False)
    name = "fixed"

    def observe(self, conf: float) -> bool:
        self.count += 1
        if self.count >= self.n:
            self.count = 0
            return True
        return False

    def reset(self) -> None:
        self.count = 0


@dataclass
class TokenThresholdTrigger(TriggerPolicy):
    """HSL: fire as soon as a single token's confidence ≤ threshold."""

    r: float
    name = "token"

    def observe(self, conf: float) -> bool:
        return conf <= self.r


@dataclass
class SequenceThresholdTrigger(TriggerPolicy):
    """EdgeLLM (adapted, App. G.3): cumulative confidence with dynamic R1."""

    r1: float
    c1: float = field(default=1.0, init=False)
    name = "sequence"

    def observe(self, conf: float) -> bool:
        self.c1 *= conf
        if self.c1 <= self.r1:
            self.c1 = 1.0
            return True
        return False

    def on_verify(self, n_accepted: int, window: int) -> None:
        # App. G.3 Eq. (7): R1 ← 0.5·R1 on full acceptance (longer drafts);
        # R1 ← R1 / ((N̂−N_correct)/N̂) on rejection (raise → earlier NAV).
        if window <= 0:
            return
        if n_accepted >= window:
            self.r1 = max(0.02, 0.5 * self.r1)  # floor avoids runaway windows
        else:
            frac = (window - n_accepted) / window
            self.r1 = min(0.999999, self.r1 / frac)

    def reset(self) -> None:
        self.c1 = 1.0


@dataclass
class WindowCapTrigger(TriggerPolicy):
    """Wraps any policy with a hard window cap N̂ (scheduling window, §3.3)."""

    inner: TriggerPolicy
    window: int
    count: int = field(default=0, init=False)

    @property
    def name(self) -> str:  # type: ignore[override]
        return f"{self.inner.name}+cap{self.window}"

    def observe(self, conf: float) -> bool:
        self.count += 1
        fired = self.inner.observe(conf)
        if self.count >= self.window:
            fired = True
        if fired:
            self.count = 0
            self.inner.reset()
        return fired

    def on_verify(self, n_accepted: int, window: int) -> None:
        self.inner.on_verify(n_accepted, window)

    def reset(self) -> None:
        self.count = 0
        self.inner.reset()

    def set_window(self, window: int) -> None:
        """Dynamic N̂ adjustment (moving average of recent draft lengths, §3.3)."""
        self.window = max(1, int(window))


def make_trigger(kind: str, **kw) -> TriggerPolicy:
    """Factory used by the pipeline engine / benchmarks.

    kinds: 'dual' (r1, r2), 'fixed' (n), 'token' (r), 'sequence' (r1);
    pass window=N to wrap with a cap.
    """
    window = kw.pop("window", None)
    if kind == "dual":
        t: TriggerPolicy = DualThresholdTrigger(r1=kw["r1"], r2=kw["r2"])
    elif kind == "fixed":
        t = FixedLengthTrigger(n=kw["n"])
    elif kind == "token":
        t = TokenThresholdTrigger(r=kw["r"])
    elif kind == "sequence":
        t = SequenceThresholdTrigger(r1=kw["r1"])
    else:
        raise KeyError(f"unknown trigger kind {kind!r}")
    if window is not None:
        return WindowCapTrigger(t, window=window)
    return t
