"""Environment monitor + parameter updater (PipeSD §4.2, App. D).

Continuously estimates the pipeline-model parameters from observations:

* γ  — mean per-token generation time over the last 100 batches (App. D.2);
* α,β — intercept/slope of a linear fit of batch communication time vs batch
  size over the last 100 transmitted batches, bootstrapped by probing batch
  sizes 1..8 (App. D.2 / Fig. 6a);
* TPT — sliding window over the last 100 accepted tokens (App. D.1).

Update triggers (all relative-change tests, thresholds δ₁=δ₂=δ₃=0.2):

* |ΔTPT|/TPT_old > δ₁  → re-run the BO autotuner (new R1,R2);
* |Δγ|/γ_old   > δ₂  or |Δα|/α, |Δβ|/β > δ₃ → re-run the DP scheduler.
"""

from __future__ import annotations

from collections import deque
from dataclasses import dataclass, field
from typing import Any, Deque, List, Optional, Tuple

import numpy as np

__all__ = ["EnvironmentMonitor", "linear_fit_alpha_beta"]


def linear_fit_alpha_beta(sizes: List[int], times: List[float]) -> Tuple[float, float]:
    """Least-squares fit time = α + β·size (Fig. 6a).  Returns (α, β).

    Groups by batch size and averages first (App. D.2), which de-noises
    repeated sizes before the fit.
    """
    if len(sizes) < 2:
        raise ValueError("need at least two observations for the α/β fit")
    by_size: dict = {}
    for s, t in zip(sizes, times):
        by_size.setdefault(int(s), []).append(float(t))
    xs = np.array(sorted(by_size), dtype=np.float64)
    ys = np.array([np.mean(by_size[int(s)]) for s in xs])
    if len(xs) < 2:
        # Single distinct size: attribute everything above zero to β.
        return 0.0, float(ys[0] / max(xs[0], 1.0))
    # Closed-form least squares (β = cov/var) instead of np.polyfit: polyfit
    # routes through LAPACK lstsq, whose float reduction order varies across
    # BLAS builds — the estimates feed retune decisions that committed bench
    # rows replay bit-exactly on arbitrary hosts.
    xm, ym = float(xs.mean()), float(ys.mean())
    dx = xs - xm
    beta = float((dx * (ys - ym)).sum() / (dx * dx).sum())
    alpha = ym - beta * xm
    return float(max(alpha, 0.0)), float(max(beta, 0.0))


@dataclass
class EnvironmentMonitor:
    """Sliding-window estimator with δ-triggered update signals."""

    window: int = 100  # App. D: most recent 100 observations
    delta1: float = 0.2  # TPT relative-change threshold (BO re-run)
    delta2: float = 0.2  # γ relative-change threshold (DP re-run)
    delta3: float = 0.2  # α/β relative-change threshold (DP re-run)
    bootstrap_sizes: Tuple[int, ...] = (1, 2, 3, 4, 5, 6, 7, 8)
    #: Optional ``repro.obs.metrics.MetricRegistry``: when attached, every
    #: observation is mirrored live into typed metrics (the deque series and
    #: their accessors keep working unchanged).
    metrics: Optional[Any] = None

    _batch_sizes: Deque[int] = field(default_factory=deque, init=False)
    _batch_times: Deque[float] = field(default_factory=deque, init=False)
    _gammas: Deque[float] = field(default_factory=deque, init=False)
    _tpts: Deque[float] = field(default_factory=deque, init=False)
    # Serving-side load (continuous-batched verifier, runtime/server.py):
    # admitted batch size + queue depth at each dispatch.
    _verifier_batches: Deque[int] = field(default_factory=deque, init=False)
    _verifier_depths: Deque[int] = field(default_factory=deque, init=False)
    # Paged-KV residency (models/paged_kv.py pool behind the verifier):
    # distinct resident bytes + page-holding sessions at each dispatch.
    _kv_bytes: Deque[float] = field(default_factory=deque, init=False)
    _kv_sessions: Deque[int] = field(default_factory=deque, init=False)
    # Link health (offline robustness, runtime/client.py): run-relative
    # failover times and per-offline-spell recovery latencies.
    _failover_times: Deque[float] = field(default_factory=deque, init=False)
    _recovery_latencies: Deque[float] = field(default_factory=deque, init=False)
    # Last parameters the consumers (DP/BO) were given.
    _committed: Optional[Tuple[float, float, float]] = field(default=None, init=False)
    _committed_tpt: Optional[float] = field(default=None, init=False)

    # ------------------------------------------------------------- intake --
    def observe_batch(self, size: int, comm_time: float) -> None:
        self._batch_sizes.append(int(size))
        self._batch_times.append(float(comm_time))
        while len(self._batch_sizes) > self.window:
            self._batch_sizes.popleft()
            self._batch_times.popleft()
        if self.metrics is not None:
            self.metrics.histogram("monitor_comm_time_s", "Batch comm time").observe(
                float(comm_time), batch=int(size)
            )

    def observe_gamma(self, gamma: float) -> None:
        self._gammas.append(float(gamma))
        while len(self._gammas) > self.window:
            self._gammas.popleft()
        if self.metrics is not None:
            self.metrics.gauge("monitor_gamma_s", "Per-token draft time").set(float(gamma))

    def observe_tpt(self, tpt: float) -> None:
        self._tpts.append(float(tpt))
        while len(self._tpts) > self.window:
            self._tpts.popleft()
        if self.metrics is not None:
            self.metrics.gauge("monitor_tpt_s", "Per-token throughput time").set(float(tpt))

    def observe_verifier_batch(self, batch_size: int, queue_depth: int) -> None:
        """One continuous-batching dispatch: admitted size + depth at admission."""
        self._verifier_batches.append(int(batch_size))
        self._verifier_depths.append(int(queue_depth))
        while len(self._verifier_batches) > self.window:
            self._verifier_batches.popleft()
            self._verifier_depths.popleft()
        if self.metrics is not None:
            self.metrics.histogram(
                "monitor_verifier_batch", "Admitted NAV batch sizes"
            ).observe(float(batch_size))
            self.metrics.histogram(
                "monitor_queue_depth", "Queue depth at admission"
            ).observe(float(queue_depth))

    def observe_kv(self, resident_bytes: float, resident_sessions: int) -> None:
        """One KV-pool sample: distinct resident bytes + page-holding sessions."""
        self._kv_bytes.append(float(resident_bytes))
        self._kv_sessions.append(int(resident_sessions))
        while len(self._kv_bytes) > self.window:
            self._kv_bytes.popleft()
            self._kv_sessions.popleft()
        if self.metrics is not None:
            self.metrics.gauge(
                "monitor_kv_resident_bytes", "Distinct resident KV bytes"
            ).set(float(resident_bytes))
            self.metrics.gauge(
                "monitor_kv_resident_sessions", "Page-holding sessions"
            ).set(float(resident_sessions))

    def observe_failover(self, t: float) -> None:
        """One NAV-timeout failover at run-relative time ``t`` [s]."""
        self._failover_times.append(float(t))
        while len(self._failover_times) > self.window:
            self._failover_times.popleft()
        if self.metrics is not None:
            self.metrics.counter("monitor_failovers", "NAV-timeout failovers").inc()

    def observe_recovery(self, latency: float) -> None:
        """One offline-spell recovery: failover → next verified round [s]."""
        self._recovery_latencies.append(float(latency))
        while len(self._recovery_latencies) > self.window:
            self._recovery_latencies.popleft()
        if self.metrics is not None:
            from repro.obs.metrics import LATENCY_BUCKETS

            self.metrics.histogram(
                "monitor_recovery_latency_s",
                "Offline-spell recovery latency",
                LATENCY_BUCKETS,
            ).observe(float(latency))

    # ----------------------------------------------------------- estimates --
    def missing_probe_sizes(self) -> List[int]:
        """Batch sizes to proactively probe so the fit has ≥8 points (App. D.2)."""
        seen = set(self._batch_sizes)
        return [s for s in self.bootstrap_sizes if s not in seen]

    def estimate(self) -> Optional[Tuple[float, float, float]]:
        """Current (α, β, γ) estimate, or None if insufficient data."""
        if len(set(self._batch_sizes)) < 2 or not self._gammas:
            return None
        alpha, beta = linear_fit_alpha_beta(list(self._batch_sizes), list(self._batch_times))
        gamma = float(np.mean(self._gammas))
        return alpha, beta, gamma

    def estimate_tpt(self) -> Optional[float]:
        if len(self._tpts) < self.window:
            return None  # App. D.1: trigger only once the window is full
        return float(np.mean(self._tpts))

    def verifier_occupancy(self) -> Optional[float]:
        """Mean admitted NAV batch size; >1 means cross-session amortization."""
        if not self._verifier_batches:
            return None
        return float(np.mean(self._verifier_batches))

    def verifier_queue_depth(self) -> Optional[float]:
        if not self._verifier_depths:
            return None
        return float(np.mean(self._verifier_depths))

    def verifier_batches(self) -> List[int]:
        return list(self._verifier_batches)

    def verifier_depths(self) -> List[int]:
        return list(self._verifier_depths)

    def kv_resident_bytes(self) -> Optional[float]:
        """Mean distinct resident KV bytes per dispatch; None when unobserved."""
        if not self._kv_bytes:
            return None
        return float(np.mean(self._kv_bytes))

    def kv_bytes_series(self) -> List[float]:
        return list(self._kv_bytes)

    def kv_sessions_series(self) -> List[int]:
        return list(self._kv_sessions)

    def failover_times(self) -> List[float]:
        """Run-relative failover times [s] within the window."""
        return list(self._failover_times)

    def recovery_latencies(self) -> List[float]:
        """Offline-spell recovery latencies [s] within the window."""
        return list(self._recovery_latencies)

    # ------------------------------------------------------------ triggers --
    @staticmethod
    def _rel_change(new: float, old: float) -> float:
        return abs(new - old) / max(abs(old), 1e-12)

    def should_rerun_dp(self) -> Optional[Tuple[float, float, float]]:
        """Returns new (α,β,γ) if the DP scheduler should be re-run (App. D.2)."""
        est = self.estimate()
        if est is None:
            return None
        if self._committed is None:
            self._committed = est
            return est
        a0, b0, g0 = self._committed
        a1, b1, g1 = est
        if (
            self._rel_change(g1, g0) > self.delta2
            or self._rel_change(a1, a0) > self.delta3
            or self._rel_change(b1, b0) > self.delta3
        ):
            self._committed = est
            return est
        return None

    def should_rerun_bo(self) -> Optional[float]:
        """Returns the new TPT estimate if the BO autotuner should re-run (App. D.1)."""
        tpt = self.estimate_tpt()
        if tpt is None:
            return None
        if self._committed_tpt is None:
            self._committed_tpt = tpt
            return None  # first full window establishes the baseline
        if self._rel_change(tpt, self._committed_tpt) > self.delta1:
            self._committed_tpt = tpt
            return tpt
        return None
