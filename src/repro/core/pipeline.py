"""Event-driven cloud-edge pipeline engine (PipeSD §3, Fig. 3, App. B).

Simulates one (or many — see ``runtime/server.py``) edge device collaborating
with a cloud verifier under the paper's timing model:

* draft generation: γ seconds/token on the edge (scenario-scaled);
* uplink transmission: α + β·n per batch, serialized on the channel, with β
  optionally driven by a time-varying bandwidth trace (Scenario 4);
* cloud NAV: t_verify seconds per verification call (+ queueing when shared);
* downlink result: α_dn + β_dn seconds.

The engine composes four orthogonal policy axes exactly as the paper's
ablations do (Table 6):

    pipeline   : overlap generation & transmission (token-batch schedule from
                 ``core.scheduler`` — 'dp' | 'greedy' | 'immediate' |
                 'no_early_upload')
    trigger    : NAV triggering policy from ``core.trigger``
                 (dual | fixed | token | sequence)
    proactive  : keep drafting/transmitting while NAV is in flight (App. B)
    autotune   : BO autotuner adjusting (R1, R2) online (§3.3); tree
                 frameworks also tune (width, depth)
    tree       : tree-structured speculation — top-k branching drafts under
                 the per-path dual threshold, verified by one tree-NAV call
                 whose cost scales with the packed node count

Confidence/acceptance streams come from a ``TokenSource``: either the
calibrated synthetic model (``SyntheticSource``) or a replay of real traces
produced by ``core.spec_decode.SpecDecoder`` (``ReplaySource``).

Every simulated quantity needed by the paper's tables is accumulated in
``RunStats`` (TPT, ECS, verification frequency, mean draft length, acceptance
rate, control-plane overheads).
"""

from __future__ import annotations

import math
import time as _time
import warnings
from dataclasses import dataclass, field, replace
from typing import Callable, List, Optional, Sequence, Tuple

import numpy as np

from .monitor import EnvironmentMonitor
from .scheduler import CommParams, Schedule, batch_sizes, schedule as make_schedule
from .trigger import TriggerPolicy, WindowCapTrigger, make_trigger

__all__ = [
    "ChannelModel",
    "CloudModel",
    "EdgeModel",
    "FrameworkSpec",
    "SyntheticSource",
    "ReplaySource",
    "RunStats",
    "PipelineEngine",
    "FRAMEWORKS",
    "make_framework",
]


# --------------------------------------------------------------------------- #
# Environment models
# --------------------------------------------------------------------------- #


@dataclass
class ChannelModel:
    """Hockney-model channel with optional dynamic bandwidth (Scenario 4).

    ``beta_up`` is the per-token uplink time at the *reference* bandwidth
    ``ref_up_mbps``; at time t the effective per-token time is
    ``beta_up * ref_up_mbps / up_mbps(t)`` (payload size is constant).
    """

    alpha_up: float = 0.020  # startup overhead [s] (handshake etc., App. A)
    beta_up: float = 0.050  # per-token uplink time at reference bandwidth [s]
    # (the paper's own measured slope is 48–72 ms/token, Table A.2 / Fig. 6a)
    alpha_dn: float = 0.010
    beta_dn: float = 0.0005  # result payload per accepted token [s]
    ref_up_mbps: float = 20.0
    ref_dn_mbps: float = 200.0
    bandwidth_trace: Optional[Callable[[float], Tuple[float, float]]] = None
    # bandwidth_trace(t) -> (uplink_mbps, downlink_mbps)

    def up_cost(self, n_tokens: int, t: float) -> float:
        """Uplink time for one n-token batch starting at simulated time t."""
        beta = self.beta_up
        if self.bandwidth_trace is not None:
            up, _ = self.bandwidth_trace(t)
            beta = self.beta_up * self.ref_up_mbps / max(up, 1e-6)
        return self.alpha_up + beta * n_tokens

    def dn_cost(self, n_tokens: int, t: float) -> float:
        """Downlink time for an n-token NAV result at simulated time t."""
        beta = self.beta_dn
        if self.bandwidth_trace is not None:
            _, dn = self.bandwidth_trace(t)
            beta = self.beta_dn * self.ref_dn_mbps / max(dn, 1e-6)
        return self.alpha_dn + beta * n_tokens

    def effective_beta_up(self, t: float) -> float:
        """Per-token uplink slope at time t (trace-scaled when dynamic)."""
        if self.bandwidth_trace is None:
            return self.beta_up
        up, _ = self.bandwidth_trace(t)
        return self.beta_up * self.ref_up_mbps / max(up, 1e-6)


def periodic_bandwidth_trace(
    seed: int = 0,
    period: float = 20.0,
    up_range: Tuple[float, float] = (10.0, 80.0),
    dn_range: Tuple[float, float] = (150.0, 280.0),
) -> Callable[[float], Tuple[float, float]]:
    """Scenario-4 trace: bandwidths resampled every ``period`` seconds."""
    rng = np.random.default_rng(seed)
    # Pre-draw enough epochs for any realistic simulation horizon.
    ups = rng.uniform(*up_range, size=4096)
    dns = rng.uniform(*dn_range, size=4096)

    def trace(t: float) -> Tuple[float, float]:
        """Return the (uplink, downlink) Mbps in effect at time ``t``."""
        i = min(int(t / period), 4095)
        return float(ups[i]), float(dns[i])

    return trace


@dataclass
class CloudModel:
    """Cloud verifier timing + power (for ECS, Table 2)."""

    t_verify: float = 0.080  # seconds per NAV call (7B target fwd on A800)
    t_verify_per_token: float = 0.004  # marginal per draft token verified
    p_idle: float = 60.0  # GPU idle power [W]
    p_active: float = 200.0  # GPU power while verifying [W] (A800 under NAV load)

    def verify_time(self, n_tokens: int) -> float:
        """Seconds for one NAV call over n drafted tokens."""
        return self.t_verify + self.t_verify_per_token * n_tokens

    def verify_energy(self, n_tokens: int) -> float:
        """Energy *above idle* attributable to one NAV call [J] (§5.2.1 ECS)."""
        return (self.p_active - self.p_idle) * self.verify_time(n_tokens)


@dataclass
class EdgeModel:
    """Edge compute model; Scenarios 2/3 emulate slower devices (App. G.2)."""

    gamma: float = 0.100  # base per-token draft time [s] (1–3B GGUF on laptop CPU)
    cpu_ghz: float = 5.1  # physical device frequency
    simulated_ghz: Optional[float] = None  # e.g. 2.5 (phone) / 1.2 (IoT)
    # Edge power model (§5.2.1 ECS, edge side): the device draws ``p_idle``
    # watts for the whole run, plus ``p_decode`` above idle while the draft
    # model is decoding and ``p_tx`` above idle while the radio transmits.
    # Defaults approximate a laptop-class device; emulated slower tiers
    # (Scenarios 2/3) decode *longer* per token but draw proportionally
    # less decode power (DVFS: dynamic power ≈ ∝ frequency), so joules per
    # drafted token stay device-class comparable while idle joules grow
    # with the slower run — matching the paper's per-scenario ECS ordering.
    p_idle: float = 2.0
    p_decode: float = 4.5
    p_tx: float = 1.8

    def effective_gamma(self) -> float:
        """Per-token draft time, scaled for the emulated device tier."""
        if self.simulated_ghz is None:
            return self.gamma
        # Artificial delay of App. G.2: gamma · (real/sim − 1) extra per token.
        return self.gamma * (self.cpu_ghz / self.simulated_ghz)

    def decode_power_scale(self) -> float:
        """DVFS scale on ``p_decode`` for the emulated device tier."""
        if self.simulated_ghz is None:
            return 1.0
        return self.simulated_ghz / self.cpu_ghz

    def edge_energy(self, decode_time: float, tx_time: float, wall_time: float) -> float:
        """Edge joules for a run: idle baseline + decode + upload increments.

        ``decode_time`` is total draft-decode busy time, ``tx_time`` total
        radio-transmit time, ``wall_time`` the run's duration — all in
        unscaled model seconds.
        """
        return (
            self.p_idle * max(wall_time, 0.0)
            + self.p_decode * self.decode_power_scale() * max(decode_time, 0.0)
            + self.p_tx * max(tx_time, 0.0)
        )


# --------------------------------------------------------------------------- #
# Token sources (confidence + acceptance streams)
# --------------------------------------------------------------------------- #


class TokenSource:
    """Yields (confidence, would_be_accepted) pairs for successive drafts."""

    def next_token(self) -> Tuple[float, bool]:  # pragma: no cover - interface
        """Return the next draft's (confidence, would-be-accepted) pair."""
        raise NotImplementedError

    def reset_round(self) -> None:
        """Called when drafting restarts after a rejection (new context)."""


@dataclass
class SyntheticSource(TokenSource):
    """Calibrated synthetic confidence/acceptance stream.

    Tokens are 'easy' w.p. (1−p_hard) with confidence ~ Beta(a_hi, b_hi), or
    'hard' with confidence ~ Beta(a_lo, b_lo).  Acceptance is drawn with
    P(accept | conf) = conf ** kappa — monotone in confidence, so threshold
    policies behave qualitatively as in the paper.  Defaults reproduce the
    Table-7 regime (mean draft length ≈ 5, acceptance ≈ 0.9–0.96) under the
    dual-threshold trigger.
    """

    p_hard: float = 0.15
    a_hi: float = 150.0
    b_hi: float = 1.0
    a_lo: float = 2.5
    b_lo: float = 2.5
    kappa: float = 0.8
    seed: int = 0
    _rng: np.random.Generator = field(init=False, repr=False)

    def __post_init__(self) -> None:
        self._rng = np.random.default_rng(self.seed)

    def next_token(self) -> Tuple[float, bool]:
        """Draw one (confidence, accepted) sample from the mixture model."""
        if self._rng.random() < self.p_hard:
            conf = float(self._rng.beta(self.a_lo, self.b_lo))
        else:
            conf = float(self._rng.beta(self.a_hi, self.b_hi))
        accept = bool(self._rng.random() < conf**self.kappa)
        return conf, accept


@dataclass
class ReplaySource(TokenSource):
    """Replays (conf, accept) streams captured from real model runs.

    Built from ``SpecDecoder`` traces via ``from_decoder_trace``; loops when
    exhausted so long simulations stay well-defined.
    """

    stream: Sequence[Tuple[float, bool]]
    _i: int = field(default=0, init=False)

    def next_token(self) -> Tuple[float, bool]:
        """Replay the next recorded (confidence, accepted) pair (looping)."""
        conf, acc = self.stream[self._i % len(self.stream)]
        self._i += 1
        return float(conf), bool(acc)

    @classmethod
    def from_decoder_trace(cls, trace: List[dict], lane: int = 0) -> "ReplaySource":
        """Flatten one lane of a ``SpecDecoder`` round trace into a stream."""
        stream: List[Tuple[float, bool]] = []
        for round_rec in trace:
            n_d = round_rec["n_drafted"][lane]
            n_a = round_rec["n_accepted"][lane]
            confs = round_rec["confs"][lane]
            for i in range(n_d):
                stream.append((confs[i], i < n_a))
        if not stream:
            raise ValueError("empty trace")
        return cls(stream)


# --------------------------------------------------------------------------- #
# Framework specifications (method × mechanism matrix, Tables 1/6)
# --------------------------------------------------------------------------- #


@dataclass(frozen=True)
class FrameworkSpec:
    """One method x mechanism configuration from the paper's Tables 1/6."""

    name: str
    trigger_kind: str  # 'dual' | 'fixed' | 'token' | 'sequence'
    trigger_kw: dict
    schedule_policy: str  # 'dp' | 'greedy' | 'immediate' | 'no_early_upload'
    pipeline: bool  # False => compute-first-transmit-later (Fig. 2a)
    proactive: bool  # App. B proactive drafting during NAV
    autotune: bool = False  # BO autotuner on (R1, R2) (+ width/depth for trees)
    # Tree speculation (FlowSpec/DiP-SD-style): draft a top-`tree_width`
    # branching token tree up to `tree_depth` levels (the window N̂ becomes a
    # NODE budget) and verify every root→leaf path in one tree-NAV call.
    tree: bool = False
    tree_width: int = 2
    tree_depth: int = 8


FRAMEWORKS = {
    # §5.1 baselines.
    "vanilla": FrameworkSpec("vanilla", "fixed", dict(n=6), "no_early_upload", False, False),
    "hsl": FrameworkSpec("hsl", "token", dict(r=0.99), "no_early_upload", False, False),
    "edgellm": FrameworkSpec("edgellm", "sequence", dict(r1=0.3), "no_early_upload", False, True),
    # PipeSD full.
    "pipesd": FrameworkSpec("pipesd", "dual", dict(r1=0.9, r2=0.6), "dp", True, True, autotune=True),
    # Tree-structured speculation on top of the full PipeSD stack.
    "tree": FrameworkSpec("tree", "dual", dict(r1=0.9, r2=0.6), "dp", True, True, autotune=True, tree=True),
    # Table 6 ablations.
    "pipesd_no_pipeline": FrameworkSpec("pipesd_no_pipeline", "dual", dict(r1=0.9, r2=0.6), "no_early_upload", False, True),
    "pipesd_fixed": FrameworkSpec("pipesd_fixed", "fixed", dict(n=6), "dp", True, True),
    "pipesd_token": FrameworkSpec("pipesd_token", "token", dict(r=0.99), "dp", True, True),
    "pipesd_sequence": FrameworkSpec("pipesd_sequence", "sequence", dict(r1=0.3), "dp", True, True),
}


def make_framework(name: str, **overrides) -> FrameworkSpec:
    """Look up a named FrameworkSpec, optionally overriding fields."""
    spec = FRAMEWORKS[name]
    return replace(spec, **overrides) if overrides else spec


# --------------------------------------------------------------------------- #
# Statistics
# --------------------------------------------------------------------------- #


@dataclass
class RunStats:
    """Every simulated/served quantity the paper's tables (and the serving
    benchmarks) report, accumulated per run; see ``docs/benchmarks.md``
    for a field-by-field reading guide."""

    accepted_tokens: int = 0  # accepted drafts + corrections (output tokens)
    drafted_tokens: int = 0
    accepted_drafts: int = 0
    nav_calls: int = 0
    rounds: int = 0
    wall_time: float = 0.0  # simulated seconds
    cloud_energy: float = 0.0  # cloud joules above idle (ECS basis)
    edge_energy: float = 0.0  # edge joules: idle baseline + decode + upload
    edge_busy_time: float = 0.0
    channel_busy_time: float = 0.0
    # Per-session heterogeneity (fleet serving): each session's configured
    # draft γ [s/token] and uplink β [s/token] — empty for single-session runs.
    session_gammas: List[float] = field(default_factory=list)
    session_betas: List[float] = field(default_factory=list)
    draft_lengths: List[int] = field(default_factory=list)
    # Control-plane overheads (Table 5): real host seconds spent.
    t_dp: float = 0.0
    t_bo: float = 0.0
    t_measure: float = 0.0
    dp_runs: int = 0
    bo_runs: int = 0
    # Multi-session serving (runtime/server.py continuous batching): per
    # dispatch, the admitted NAV batch size and queue depth at admission;
    # per round, the client-observed NAV round-trip latency [s].
    verifier_batches: List[int] = field(default_factory=list)
    verifier_queue_depths: List[int] = field(default_factory=list)
    nav_latencies: List[float] = field(default_factory=list)
    # Tree speculation: per tree round, the packed node count and the depth
    # actually reached (levels generated before prune/budget stopped it).
    tree_nodes: List[int] = field(default_factory=list)
    tree_depths: List[int] = field(default_factory=list)
    # Paged target KV (models/paged_kv.py): per round (single-session
    # simulation) or per dispatch (fleet serving), the pool's distinct
    # resident bytes and page-holding session count; kv_cap_hits counts
    # rounds whose cache growth the pool could not fully back.
    kv_resident_bytes: List[float] = field(default_factory=list)
    kv_resident_sessions: List[int] = field(default_factory=list)
    kv_cap_hits: int = 0
    # Offline robustness (runtime/faults.py chaos serving): NAV-timeout
    # failovers, tokens decoded locally while offline, drafted tokens whose
    # round had to be abandoned, and per-recovery latency [s] from the first
    # failover of an offline spell to the next verified round.
    failovers: int = 0
    fallback_tokens: int = 0
    lost_draft_tokens: int = 0
    recovery_latencies: List[float] = field(default_factory=list)

    @property
    def tpt(self) -> float:
        """Average generation time per accepted token [s] (§5.1 Metrics)."""
        return self.wall_time / max(self.accepted_tokens, 1)

    @property
    def total_energy(self) -> float:
        """Combined edge + cloud joules for the run."""
        return self.edge_energy + self.cloud_energy

    @property
    def ecs_cloud(self) -> float:
        """Cloud energy per 100 accepted tokens [J] (cloud-only ECS basis).

        The paper's full edge+cloud ECS is :attr:`energy_per_100_tokens`;
        this is the cloud term alone, which the scenario tables break out.
        """
        return self.cloud_energy / max(self.accepted_tokens, 1) * 100.0

    @property
    def ecs(self) -> float:
        """Deprecated alias for :attr:`ecs_cloud` (reads emit a warning).

        Historically ``ecs`` named the *cloud-only* reading of §5.1's ECS
        metric, which is easy to mistake for the paper's full edge+cloud
        number; use :attr:`ecs_cloud` (same value, honest name) or
        :attr:`energy_per_100_tokens`.
        """
        warnings.warn(
            "RunStats.ecs is deprecated: it is the CLOUD-ONLY energy per 100 "
            "tokens; use ecs_cloud (same value) or energy_per_100_tokens "
            "(full edge+cloud ECS)",
            DeprecationWarning,
            stacklevel=2,
        )
        return self.ecs_cloud

    @property
    def ecs_edge(self) -> float:
        """Edge energy per 100 accepted tokens [J] (decode + upload + idle)."""
        return self.edge_energy / max(self.accepted_tokens, 1) * 100.0

    @property
    def energy_per_100_tokens(self) -> float:
        """Full ECS (§5.1 Metrics): edge + cloud joules per 100 accepted tokens."""
        return self.total_energy / max(self.accepted_tokens, 1) * 100.0

    @property
    def gamma_spread(self) -> float:
        """max/min configured session γ — 1.0 for a homogeneous fleet."""
        if not self.session_gammas:
            return 1.0
        return max(self.session_gammas) / max(min(self.session_gammas), 1e-12)

    @property
    def beta_spread(self) -> float:
        """max/min configured session uplink β — 1.0 for a homogeneous fleet."""
        if not self.session_betas:
            return 1.0
        return max(self.session_betas) / max(min(self.session_betas), 1e-12)

    @property
    def verification_frequency(self) -> float:
        """NAV calls per accepted token (Table 7)."""
        return self.nav_calls / max(self.accepted_tokens, 1)

    @property
    def mean_draft_length(self) -> float:
        """Mean drafted tokens (chain) or nodes (tree) per round."""
        return float(np.mean(self.draft_lengths)) if self.draft_lengths else 0.0

    @property
    def acceptance_rate(self) -> float:
        """Accepted drafts / drafted tokens (Table 7)."""
        return self.accepted_drafts / max(self.drafted_tokens, 1)

    @property
    def tokens_per_nav(self) -> float:
        """Mean output tokens committed per NAV call — the quantity tree
        speculation raises (more accepted drafts amortize each verify)."""
        return self.accepted_tokens / max(self.nav_calls, 1)

    @property
    def mean_tree_nodes(self) -> float:
        """Mean packed node count per tree round."""
        return float(np.mean(self.tree_nodes)) if self.tree_nodes else 0.0

    @property
    def mean_tree_depth(self) -> float:
        """Mean tree depth actually drafted per tree round."""
        return float(np.mean(self.tree_depths)) if self.tree_depths else 0.0

    @property
    def verifier_batch_occupancy(self) -> float:
        """Mean admitted NAV batch size; >1 = cross-session amortization."""
        return float(np.mean(self.verifier_batches)) if self.verifier_batches else 0.0

    @property
    def mean_kv_resident_bytes(self) -> float:
        """Mean distinct resident KV bytes across samples (sharing counted once)."""
        return float(np.mean(self.kv_resident_bytes)) if self.kv_resident_bytes else 0.0

    @property
    def peak_kv_resident_bytes(self) -> float:
        """High-water distinct resident KV bytes — the pool size that was needed."""
        return float(np.max(self.kv_resident_bytes)) if self.kv_resident_bytes else 0.0

    @property
    def kv_bytes_per_session(self) -> float:
        """Mean resident KV bytes per page-holding session (prefix sharing
        makes this drop below a flat cache's ``max_len`` footprint)."""
        if not self.kv_resident_bytes or not self.kv_resident_sessions:
            return 0.0
        sessions = float(np.mean(self.kv_resident_sessions))
        return self.mean_kv_resident_bytes / max(sessions, 1e-9)

    @property
    def mean_queue_depth(self) -> float:
        """Mean verifier queue depth observed at admission time."""
        return float(np.mean(self.verifier_queue_depths)) if self.verifier_queue_depths else 0.0

    @property
    def mean_recovery_latency(self) -> float:
        """Mean offline-spell recovery latency [s]; 0 when never offline."""
        return float(np.mean(self.recovery_latencies)) if self.recovery_latencies else 0.0

    @property
    def fallback_fraction(self) -> float:
        """Share of output tokens decoded locally while the cloud was away."""
        return self.fallback_tokens / max(self.accepted_tokens, 1)

    def nav_latency_quantiles(self) -> Tuple[float, float]:
        """(p50, p99) NAV round-trip latency [s]; (0, 0) when unrecorded."""
        if not self.nav_latencies:
            return 0.0, 0.0
        p50, p99 = np.percentile(self.nav_latencies, [50.0, 99.0])
        return float(p50), float(p99)

    def summary(self) -> dict:
        """Flatten the headline metrics into one dict (benchmark CSV rows)."""
        p50, p99 = self.nav_latency_quantiles()
        return dict(
            tpt_ms=self.tpt * 1e3,
            ecs_j=self.ecs_cloud,
            ecs_edge_j=self.ecs_edge,
            ecs_total_j=self.energy_per_100_tokens,
            verification_frequency=self.verification_frequency,
            mean_draft_length=self.mean_draft_length,
            acceptance_rate=self.acceptance_rate,
            rounds=self.rounds,
            nav_calls=self.nav_calls,
            accepted_tokens=self.accepted_tokens,
            wall_time_s=self.wall_time,
            overhead_dp=self.t_dp / max(self.wall_time, 1e-9),
            overhead_bo=self.t_bo / max(self.wall_time, 1e-9),
            overhead_measure=self.t_measure / max(self.wall_time, 1e-9),
            verifier_batch_occupancy=self.verifier_batch_occupancy,
            mean_queue_depth=self.mean_queue_depth,
            nav_p50_ms=p50 * 1e3,
            nav_p99_ms=p99 * 1e3,
            tokens_per_nav=self.tokens_per_nav,
            mean_tree_nodes=self.mean_tree_nodes,
            mean_tree_depth=self.mean_tree_depth,
            kv_resident_mb=self.mean_kv_resident_bytes / 1e6,
            kv_peak_mb=self.peak_kv_resident_bytes / 1e6,
            kv_bytes_per_session_mb=self.kv_bytes_per_session / 1e6,
            kv_cap_hits=self.kv_cap_hits,
            failovers=self.failovers,
            fallback_fraction=self.fallback_fraction,
            lost_draft_tokens=self.lost_draft_tokens,
            recovery_latency_s=self.mean_recovery_latency,
        )

    def to_metrics(self, registry, prefix: str = "run") -> None:
        """Export the finished run into a ``repro.obs`` metric registry.

        Scalar summary fields become gauges ``{prefix}_<name>``; the raw
        NAV-latency and verifier-batch series are replayed into histograms
        so the Prometheus exposition carries their distributions too.
        """
        for name, value in self.summary().items():
            registry.gauge(f"{prefix}_{name}", f"RunStats.summary()['{name}']").set(
                float(value)
            )
        from repro.obs.metrics import LATENCY_BUCKETS

        nav = registry.histogram(
            f"{prefix}_nav_latency_s", "Client NAV round-trip latency", LATENCY_BUCKETS
        )
        for lat in self.nav_latencies:
            nav.observe(float(lat))
        batch = registry.histogram(
            f"{prefix}_verifier_batch", "Admitted NAV batch sizes"
        )
        for b in self.verifier_batches:
            batch.observe(float(b))


# --------------------------------------------------------------------------- #
# The engine
# --------------------------------------------------------------------------- #


class PipelineEngine:
    """Simulates one edge↔cloud session under a FrameworkSpec.

    The per-round timeline follows §3.2 exactly: token i of the round is ready
    at ``t0 + i·γ``; batch k may start uplink at
    ``max(channel_free, ready(last token of k))`` and costs ``α + β·size``;
    NAV starts when the final batch + request arrive; the result lands after
    the verify time + downlink cost.  With ``proactive`` (App. B) the edge
    keeps drafting during NAV and the work is kept iff the round was fully
    accepted and the bonus token matches the first proactive draft.
    """

    def __init__(
        self,
        spec: FrameworkSpec,
        channel: ChannelModel,
        cloud: CloudModel,
        edge: EdgeModel,
        source: TokenSource,
        window_init: int = 20,
        seed: int = 0,
        monitor: Optional[EnvironmentMonitor] = None,
        autotune_samples: int = 16,
        autotune_tokens_per_sample: int = 20,
        kv_pool=None,  # Optional[models.paged_kv.PagedKVPool]
        kv_session: int = 0,
    ):
        self.spec = spec
        self.channel = channel
        self.cloud = cloud
        self.edge = edge
        self.source = source
        self.rng = np.random.default_rng(seed)
        # Paged target-KV accounting (models/paged_kv.py): each round appends
        # its K+1 verified cache positions and rolls back to the committed
        # prefix, so RunStats carries true KV residency instead of the flat
        # cache's constant sessions x max_len footprint.
        self.kv_pool = kv_pool
        self.kv_session = kv_session
        if kv_pool is not None:
            # Deferred so importing the sim engine alone never pulls the
            # whole models package in; cached for the per-round except path.
            from repro.models.paged_kv import BlockPoolExhausted

            self._pool_exhausted = BlockPoolExhausted
            if kv_session not in kv_pool.tables:
                kv_pool.create(kv_session)
            self._kv_committed = kv_pool.length(kv_session)
        self.window = window_init
        self.recent_draft_lens: List[int] = []
        self.monitor = monitor or EnvironmentMonitor()
        self.autotune_samples = autotune_samples
        self.autotune_tokens_per_sample = autotune_tokens_per_sample
        self.trigger = self._make_trigger(spec.trigger_kind, dict(spec.trigger_kw))
        self.stats = RunStats()
        self.tuned_thresholds: Optional[Tuple[float, float]] = None
        self._t = 0.0  # simulation clock
        self._pending_head_start = 0  # proactive tokens carried into next round
        self._schedule_cache: dict = {}

    # ------------------------------------------------------------ helpers --
    def _make_trigger(self, kind: str, kw: dict) -> TriggerPolicy:
        return make_trigger(kind, window=self.window, **kw)

    def _comm_params(self, t: float) -> CommParams:
        return CommParams(
            alpha=self.channel.alpha_up,
            beta=self.channel.effective_beta_up(t),
            gamma=self.edge.effective_gamma(),
        )

    def _kv_round(self, n_drafted: int, n_accepted: int) -> None:
        """Model the verifier-side paged cache for one round.

        Verification writes ``n_drafted + 1`` positions past the committed
        prefix (plus a re-prefill gap if pages were reclaimed); rejection
        rolls back to ``committed + n_accepted + 1``, releasing whole pages.
        A pool too small to back the growth saturates (``kv_cap_hits``) —
        the simulated analogue of the serving dispatcher parking the round.
        """
        pool = self.kv_pool
        if pool is None:
            return
        sid = self.kv_session
        need = self._kv_committed - pool.length(sid) + n_drafted + 1
        try:
            if need > 0:
                pool.append(sid, need)
        except self._pool_exhausted:
            self.stats.kv_cap_hits += 1
        self._kv_committed += n_accepted + 1
        pool.rollback(sid, min(self._kv_committed, pool.length(sid)))
        self.stats.kv_resident_bytes.append(pool.resident_bytes())
        self.stats.kv_resident_sessions.append(pool.resident_sessions)

    def _plan_schedule(self, n_tokens: int, p: CommParams) -> Schedule:
        key = (self.spec.schedule_policy, n_tokens, round(p.alpha, 6), round(p.beta, 6), round(p.gamma, 6))
        if key not in self._schedule_cache:
            t0 = _time.perf_counter()
            self._schedule_cache[key] = make_schedule(self.spec.schedule_policy, n_tokens, p)
            self.stats.t_dp += _time.perf_counter() - t0
            self.stats.dp_runs += 1
        return self._schedule_cache[key]

    # -------------------------------------------------------------- a round --
    def _run_round(self) -> Tuple[int, int, bool]:
        """Simulate one speculative round.

        Returns (n_drafted, n_accepted, full_accept).  Advances the clock to
        the moment the edge receives the NAV result and has rolled back.
        """
        gamma = self.edge.effective_gamma()
        t0 = self._t
        # Proactive head start (App. B): tokens already drafted *and uploaded*
        # during the previous round's NAV — they cost no generation or uplink
        # time this round, but are ordinary drafts for trigger/acceptance.
        head = self._pending_head_start
        self._pending_head_start = 0

        # ---- draft until trigger/cap; record per-token readiness ------------
        confs: List[float] = []
        accepts: List[bool] = []
        n = 0
        fired = False
        while n < self.window:
            conf, acc = self.source.next_token()
            confs.append(conf)
            accepts.append(acc)
            n += 1
            if self.trigger.observe(conf):
                fired = True
                break
        n_new = max(0, n - head)  # tokens actually generated this round
        gen_end = t0 + gamma * n_new
        self.stats.edge_busy_time += gamma * n_new
        self.stats.drafted_tokens += n

        # ---- transmission ----------------------------------------------------
        p = self._comm_params(t0)
        self.monitor.observe_gamma(gamma)
        if n_new == 0:
            comm_end = t0  # everything was drafted+uploaded proactively
        elif not self.spec.pipeline:
            # Fig. 2(a): generate everything, then one upload.
            up = self.channel.up_cost(n_new, gen_end)
            self.monitor.observe_batch(n_new, up)
            comm_end = gen_end + up
            self.stats.channel_busy_time += up
        else:
            # Token-batch pipeline (§3.2): schedule over the *planned* window;
            # on trigger, unsent tokens flush as one batch (§3.3 rule 1).
            plan = self._plan_schedule(max(self.window, 1), p)
            sizes = batch_sizes(plan.boundaries, max(self.window, 1))
            chan_free = t0
            sent = 0
            for sz in sizes:
                if sent >= n_new:
                    break
                take = min(sz, n_new - sent)
                if sent + take >= n_new and fired:
                    take = n_new - sent  # flush remainder on trigger
                ready = t0 + gamma * (sent + take)
                start = max(chan_free, ready)
                cost = self.channel.up_cost(take, start)
                self.monitor.observe_batch(take, cost)
                chan_free = start + cost
                self.stats.channel_busy_time += cost
                sent += take
            comm_end = chan_free

        # ---- cloud NAV -------------------------------------------------------
        nav_time = self.cloud.verify_time(n)
        nav_end = comm_end + nav_time
        self.stats.cloud_energy += self.cloud.verify_energy(n)
        self.stats.nav_calls += 1

        # ---- acceptance ------------------------------------------------------
        n_accepted = 0
        for a in accepts:
            if a:
                n_accepted += 1
            else:
                break
        full = n_accepted >= n
        result_at_edge = nav_end + self.channel.dn_cost(max(n_accepted, 1), nav_end)

        # ---- proactive drafting during NAV (App. B) --------------------------
        kept_proactive = False
        if self.spec.proactive:
            overlap = max(result_at_edge - gen_end, 0.0)
            drafted_ahead = int(overlap / gamma)
            # Keep iff the round fully accepted AND the bonus token matches the
            # first proactive draft — approximated by the acceptance draw of
            # that token (the draft re-predicting the target's bonus token).
            if full and drafted_ahead > 0:
                _, acc = self.source.next_token()
                if acc:
                    self._pending_head_start = min(drafted_ahead, self.window - 1)
                    kept_proactive = True
            # Rejected rounds discard proactive work (overlapped, no latency).

        self._t = result_at_edge
        if not kept_proactive:
            # The draft model must ingest the correction token (one forward
            # pass) before drafting resumes; with kept proactive work this
            # already happened during the NAV overlap.
            self._t += gamma
            self.stats.edge_busy_time += gamma
        self.stats.wall_time = self._t
        self.stats.edge_energy = self.edge.edge_energy(
            self.stats.edge_busy_time, self.stats.channel_busy_time, self.stats.wall_time
        )
        self.stats.rounds += 1
        self.stats.draft_lengths.append(n)
        self.stats.accepted_drafts += n_accepted
        self.stats.accepted_tokens += n_accepted + 1  # + corrected/bonus token
        self._kv_round(n, n_accepted)
        self.trigger.on_verify(n_accepted, n)
        if isinstance(self.trigger, WindowCapTrigger):
            # Dynamic N̂: moving average of the last 100 draft lengths (§3.3).
            self.recent_draft_lens.append(n)
            if len(self.recent_draft_lens) > 100:
                self.recent_draft_lens.pop(0)
            new_window = max(2, int(round(float(np.mean(self.recent_draft_lens)) * 1.5)))
            if new_window != self.window:
                self.window = new_window
                self.trigger.set_window(new_window)
        return n, n_accepted, full

    # --------------------------------------------------------- a tree round --
    def _run_round_tree(self) -> Tuple[int, int, bool]:
        """Simulate one TREE speculative round (FlowSpec/DiP-SD-style).

        Each expanded node costs one draft forward (γ per *expansion*, not per
        node — siblings come from one distribution); a child with conf ≤ R2 is
        pruned and a path whose cumulative C1 drops to R1 stops expanding.
        Levels upload as they complete (the level is the natural token batch),
        the verifier's cost scales with the packed NODE count, and acceptance
        advances a level whenever ANY sibling on the accepted path's frontier
        accepts — the accepted-tokens-per-NAV gain over a chain.

        Returns (n_nodes, n_accepted, accepted-path-reached-the-last-level).
        """
        gamma = self.edge.effective_gamma()
        t0 = self._t
        spec = self.spec
        kw = spec.trigger_kw if spec.trigger_kind == "dual" else {}
        r1, r2 = float(kw.get("r1", 0.0)), float(kw.get("r2", 0.0))
        budget = max(self.window, 1)  # N̂ acts as the node budget
        # Proactive head start (App. B): expansions already computed during
        # the previous round's NAV overlap — they cost no generation time.
        free_expansions = self._pending_head_start
        self._pending_head_start = 0

        # ---- draft the tree level by level --------------------------------
        # Frontier entries: (parent-on-accepted-path AND own-draw-accepted, C1).
        frontier: List[Tuple[bool, float]] = [(True, 1.0)]
        n_nodes = 0
        n_expansions = 0
        n_accepted = 0
        gen_end = t0
        level_batches: List[Tuple[int, float]] = []  # (nodes in level, ready time)
        for _level in range(max(spec.tree_depth, 1)):
            if not frontier or n_nodes >= budget:
                break
            paid = max(0, len(frontier) - free_expansions)
            free_expansions -= len(frontier) - paid
            gen_end += gamma * paid
            n_expansions += paid
            nxt: List[Tuple[bool, float]] = []
            level_nodes = 0
            level_advanced = False
            for acc_parent, pconf in frontier:
                for _w in range(max(spec.tree_width, 1)):
                    conf, acc = self.source.next_token()
                    # R2 prune (except the round's very first node: a round
                    # always ships ≥ 1 draft for the verifier to correct).
                    if conf <= r2 and n_nodes > 0:
                        continue
                    if n_nodes >= budget:
                        break
                    n_nodes += 1
                    level_nodes += 1
                    node_acc = acc_parent and acc
                    if node_acc and not level_advanced:
                        level_advanced = True  # deepest accepted path grows
                    cp = pconf * conf
                    if cp > r1:
                        nxt.append((node_acc, cp))
                    # cp ≤ r1: the path fired — node kept, expansion stops.
            if level_nodes:
                level_batches.append((level_nodes, gen_end))
            if level_advanced:
                n_accepted += 1
            else:
                # No accepted continuation at this level: deeper levels only
                # extend rejected branches — keep drafting (they were already
                # paid for in the real system too) but acceptance is frozen.
                frontier = [(False, cp) for (_a, cp) in nxt]
                continue
            frontier = nxt
        depth_reached = len(level_batches)
        self.stats.edge_busy_time += gamma * n_expansions
        self.stats.drafted_tokens += n_nodes

        # ---- transmission: levels are the token batches --------------------
        self.monitor.observe_gamma(gamma)
        if not spec.pipeline:
            up = self.channel.up_cost(n_nodes, gen_end)
            self.monitor.observe_batch(n_nodes, up)
            comm_end = gen_end + up
            self.stats.channel_busy_time += up
        else:
            chan_free = t0
            for sz, ready in level_batches:
                start = max(chan_free, ready)
                cost = self.channel.up_cost(sz, start)
                self.monitor.observe_batch(sz, cost)
                chan_free = start + cost
                self.stats.channel_busy_time += cost
            comm_end = chan_free

        # ---- cloud tree-NAV (cost scales with the packed node count) -------
        nav_time = self.cloud.verify_time(n_nodes)
        nav_end = comm_end + nav_time
        self.stats.cloud_energy += self.cloud.verify_energy(n_nodes)
        self.stats.nav_calls += 1

        full = n_accepted >= depth_reached and depth_reached > 0
        result_at_edge = nav_end + self.channel.dn_cost(max(n_accepted, 1), nav_end)

        # ---- proactive drafting during NAV (App. B) ------------------------
        # Kept work carries over as FREE EXPANSIONS (the tree analogue of the
        # chain's token head start): the next round's first levels cost no
        # generation time up to the overlap the edge already spent.
        kept_proactive = False
        if spec.proactive:
            overlap = max(result_at_edge - gen_end, 0.0)
            drafted_ahead = int(overlap / gamma)
            if full and drafted_ahead > 0:
                _, acc = self.source.next_token()
                if acc:
                    self._pending_head_start = min(drafted_ahead, budget - 1)
                    kept_proactive = True
        self._t = result_at_edge
        if not kept_proactive:
            self._t += gamma  # ingest the correction token before drafting
            self.stats.edge_busy_time += gamma
        self.stats.wall_time = self._t
        self.stats.edge_energy = self.edge.edge_energy(
            self.stats.edge_busy_time, self.stats.channel_busy_time, self.stats.wall_time
        )
        self.stats.rounds += 1
        self.stats.draft_lengths.append(n_nodes)
        self.stats.tree_nodes.append(n_nodes)
        self.stats.tree_depths.append(depth_reached)
        self.stats.accepted_drafts += n_accepted
        self.stats.accepted_tokens += n_accepted + 1  # + corrected/bonus token
        self._kv_round(n_nodes, n_accepted)
        self.trigger.on_verify(n_accepted, depth_reached)
        return n_nodes, n_accepted, full

    # ---------------------------------------------------------------- runs --
    def run(self, n_accepted_tokens: int = 1000) -> RunStats:
        """Simulate until ≥ n_accepted_tokens are produced (paper: 1000)."""
        if self.spec.autotune:
            self._autotune()
        round_fn = self._run_round_tree if self.spec.tree else self._run_round
        while self.stats.accepted_tokens < n_accepted_tokens:
            round_fn()
        return self.stats

    # ------------------------------------------------------------ autotune --
    def _autotune(self) -> None:
        """BO over (R1, R2): each sample measures TPT over a few rounds (§3.3).

        Tree frameworks widen the search space to (R1, R2, width, depth): the
        branching knobs trade node budget (verify + upload cost) against
        accepted-tokens-per-NAV, so they belong in the same objective.  The
        integer knobs ride the continuous GP via rounding — standard practice
        for small ordinal ranges.
        """
        from .autotuner import BOAutotuner

        t0 = _time.perf_counter()
        tree = self.spec.tree
        bounds = ((0.0, 1.0), (0.0, 1.0)) + (((1.0, 4.0), (2.0, 10.0)) if tree else ())
        bo = BOAutotuner(bounds=bounds, seed=int(self.rng.integers(2**31)))

        def measure(r1: float, r2: float, w: float = 0.0, d: float = 0.0) -> float:
            """Probe one threshold setting: TPT over a few simulated rounds."""
            overrides = dict(trigger_kind="dual", trigger_kw=dict(r1=r1, r2=r2), autotune=False)
            if tree:
                overrides.update(tree_width=int(round(w)), tree_depth=int(round(d)))
            probe = PipelineEngine(
                replace(self.spec, **overrides),
                self.channel,
                self.cloud,
                self.edge,
                self.source,
                window_init=self.window,
                seed=int(self.rng.integers(2**31)),
            )
            probe.run(self.autotune_tokens_per_sample)
            return probe.stats.tpt

        best = bo.minimize(measure, n_trials=self.autotune_samples)
        self.stats.t_bo += _time.perf_counter() - t0
        self.stats.bo_runs += 1
        r1, r2 = best.x[0], best.x[1]
        if tree:
            self.spec = replace(
                self.spec, tree_width=int(round(best.x[2])), tree_depth=int(round(best.x[3]))
            )
        self.trigger = self._make_trigger("dual", dict(r1=r1, r2=r2))
        self.spec = replace(self.spec, trigger_kw=dict(r1=r1, r2=r2))
        self.tuned_thresholds = (r1, r2)
