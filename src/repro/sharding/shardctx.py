"""Ambient-mesh-aware sharding constraints.

``constrain(x, spec_axes)`` applies ``with_sharding_constraint`` only when a
mesh is ambient (inside ``with mesh:`` under jit) AND every requested axis
exists AND the corresponding dim divides evenly — so model code can express
its preferred layout once and still run un-meshed (CPU tests) or on meshes
where a dim doesn't divide (falls back to unconstrained for that dim).
"""

from __future__ import annotations

from typing import Optional, Sequence, Tuple, Union

import numpy as np
import jax
from jax.sharding import PartitionSpec as P

Axis = Union[str, Tuple[str, ...], None]

__all__ = ["constrain", "ambient_mesh", "axis_size", "abstract_mesh", "host_mesh"]


def host_mesh(shards: int, axis: str = "model"):
    """A physical 1-D ``(axis,)`` mesh over the first ``shards`` devices.

    The CPU-mesh entry point for the sharded verifier and its tests: under
    ``XLA_FLAGS=--xla_force_host_platform_device_count=N`` the host platform
    exposes N devices, so a multi-shard ``shard_map`` launch runs (and is
    proven bit-exact) without accelerators.  Raises with the flag spelled
    out when the process has fewer devices than requested — the flag must be
    set BEFORE jax initializes its backends.
    """
    from jax.sharding import Mesh

    if shards < 1:
        raise ValueError(f"shards must be >= 1, got {shards}")
    devices = jax.devices()
    if len(devices) < shards:
        raise RuntimeError(
            f"need {shards} devices for a {shards}-shard mesh but only "
            f"{len(devices)} are visible; set "
            f"XLA_FLAGS=--xla_force_host_platform_device_count={shards} "
            "in the environment before jax initializes"
        )
    return Mesh(np.asarray(devices[:shards]), (axis,))


def abstract_mesh(sizes: Sequence[int], names: Sequence[str]):
    """Version-tolerant ``jax.sharding.AbstractMesh`` constructor.

    Newer jax takes ``AbstractMesh(sizes, names)``; 0.4.x takes one
    ``((name, size), ...)`` shape tuple.  Tests and tools build abstract
    meshes through this helper so either toolchain works.
    """
    from jax.sharding import AbstractMesh

    try:
        return AbstractMesh(tuple(sizes), tuple(names))
    except TypeError:
        return AbstractMesh(tuple(zip(names, sizes)))


def ambient_mesh():
    try:
        m = jax.sharding.get_abstract_mesh()
        if m is not None and not m.empty:
            return m
    except Exception:
        pass
    try:  # physical mesh context (`with mesh:` style)
        import warnings

        with warnings.catch_warnings():
            warnings.simplefilter("ignore", DeprecationWarning)
            from jax._src import mesh as mesh_lib

            m = mesh_lib.thread_resources.env.physical_mesh
        if m is not None and not m.empty:
            return m
    except Exception:
        pass
    return None


def axis_size(mesh, axis: Axis) -> int:
    if axis is None:
        return 1
    names = axis if isinstance(axis, (tuple, list)) else (axis,)
    return int(np.prod([dict(mesh.shape)[n] for n in names]))


def constrain(x: jax.Array, axes: Sequence[Axis]) -> jax.Array:
    """Constrain dims of x to the given mesh axes where possible."""
    mesh = ambient_mesh()
    if mesh is None:
        return x
    names = set(mesh.axis_names)
    spec = []
    for dim, ax in zip(x.shape, axes):
        if ax is None:
            spec.append(None)
            continue
        # Keep only axes present in the ambient mesh (e.g. 'pod' exists only
        # on the multi-pod mesh; ('pod','data') degrades to ('data',)).
        ax_names = tuple(a for a in (ax if isinstance(ax, (tuple, list)) else (ax,)) if a in names)
        if not ax_names:
            spec.append(None)
            continue
        if dim % axis_size(mesh, ax_names) != 0:
            spec.append(None)
            continue
        spec.append(ax_names if len(ax_names) > 1 else ax_names[0])
    if all(s is None for s in spec):
        return x
    return jax.lax.with_sharding_constraint(x, P(*spec))
