from . import partition, shardctx, spec_verify
from .partition import Partitioner, data_axes
from .shardctx import abstract_mesh, ambient_mesh, axis_size, constrain, host_mesh
from .spec_verify import (
    MODEL_AXIS,
    ShardPlan,
    plan_shards,
    sharded_target_logits,
    spec_verify_sharded,
    spec_verify_sharded_batched,
)

__all__ = [
    "MODEL_AXIS",
    "Partitioner",
    "ShardPlan",
    "abstract_mesh",
    "ambient_mesh",
    "axis_size",
    "constrain",
    "data_axes",
    "host_mesh",
    "partition",
    "plan_shards",
    "shardctx",
    "sharded_target_logits",
    "spec_verify",
    "spec_verify_sharded",
    "spec_verify_sharded_batched",
]
