"""Divisibility-aware PartitionSpec rules (DESIGN.md §5).

Axis roles:
* ``model``  — tensor/expert parallel axis (16-way per pod).
* ``data``   — data parallel for activations; second ("FSDP") weight dim so
  large weights shard 2-D and optimizer state is fully sharded.
* ``pod``    — multi-pod data parallelism: batch (and optimizer state) shard
  over pods; weights are replicated across pods.

Every rule is *divisibility-aware*: a tensor dim is sharded over an axis only
if evenly divisible, else that dim falls back to replication and the decision
is recorded (``explain`` output) — e.g. whisper's 20 heads and minicpm's 36
heads are not divisible by 16, so their attention weights shard over the flat
``H·hd`` dim instead (all the assigned configs keep H·hd % 16 == 0), and
kv-head counts below 16 shard over head_dim.
"""

from __future__ import annotations

from typing import Any, Dict, List, Optional, Tuple

import jax
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

__all__ = ["Partitioner", "data_axes", "batch_specs", "cache_specs"]


def data_axes(mesh: Mesh) -> Tuple[str, ...]:
    """The data-parallel axes: ('pod','data') on multi-pod, ('data',) single."""
    return tuple(a for a in ("pod", "data") if a in mesh.axis_names)


def _axis_size(mesh: Mesh, axis) -> int:
    if axis is None:
        return 1
    if isinstance(axis, (tuple, list)):
        return int(np.prod([mesh.shape[a] for a in axis]))
    return mesh.shape[axis]


class Partitioner:
    """Builds PartitionSpec pytrees for params / batches / caches."""

    def __init__(self, mesh: Mesh):
        self.mesh = mesh
        dp = data_axes(mesh)
        # Unwrap singleton so specs read P('data'), not P(('data',)) — older
        # jax PartitionSpec equality does not normalize the two forms.
        self.dp = dp[0] if len(dp) == 1 else dp
        self.fallbacks: List[str] = []  # audit log of replicated dims

    # ------------------------------------------------------------- helpers --
    def _ok(self, size: int, axis) -> bool:
        return size % _axis_size(self.mesh, axis) == 0

    def _dim(self, path: str, size: int, axis):
        """axis if divisible else None (logged)."""
        if axis is None:
            return None
        if self._ok(size, axis):
            return axis
        self.fallbacks.append(f"{path}: dim {size} !% {axis} -> replicated")
        return None

    # --------------------------------------------------------------- rules --
    def _spec_for(self, path: str, shape: Tuple[int, ...]) -> P:
        name = path.split("/")[-1]
        d = lambda i, ax: self._dim(path, shape[i], ax)
        nd = len(shape)
        # Stacked-layer leading dims (blocks are stacked [L, ...] or [G, k, ...]):
        # rules below address the *trailing* dims; leading layer dims replicate.
        def lead(spec_tail: Tuple) -> P:
            return P(*([None] * (nd - len(spec_tail))), *spec_tail)

        if name in ("embed",):  # [V, d]
            return P(d(0, "model"), d(1, "data"))
        if name == "lm_head":  # [d, V]
            return P(d(0, "data"), d(1, "model"))
        if name in ("wq", "wk", "wv", "w_gate", "w_up", "w_in", "wi", "wf", "wo_gate"):
            if name in ("w_gate", "w_up") and nd >= 3 and shape[-3] > 1 and path.find("moe") >= 0:
                # MoE expert weights [*, E, d, f]: experts over model, f over data.
                return lead((self._dim(path, shape[-3], "model"), None, self._dim(path, shape[-1], "data")))
            return lead((self._dim(path, shape[-2], "data"), self._dim(path, shape[-1], "model")))
        if name == "wo":
            if "mlstm" in path or "slstm" in path:  # gate projections [d, *] (col-parallel)
                return lead((self._dim(path, shape[-2], "data"), self._dim(path, shape[-1], "model")))
            # attention out-projection [H·hd, d] (row-parallel)
            return lead((self._dim(path, shape[-2], "model"), self._dim(path, shape[-1], "data")))
        if name in ("w_down",):
            if nd >= 3 and path.find("moe") >= 0:  # [*, E, f, d]
                return lead((self._dim(path, shape[-3], "model"), self._dim(path, shape[-2], "data"), None))
            return lead((self._dim(path, shape[-2], "model"), self._dim(path, shape[-1], "data")))
        if name in ("w_out",):  # [dr|qd|d, d] row-parallel
            return lead((self._dim(path, shape[-2], "model"), self._dim(path, shape[-1], "data")))
        if name in ("router", "frontend_proj", "vision_proj", "wa", "wx", "wz"):
            if nd >= 2:
                return lead((self._dim(path, shape[-2], "data"), self._dim(path, shape[-1], "model")))
        if name in ("wi_s", "wf_s", "wz_s", "wo_s") or (name[0] == "w" and nd >= 2 and path.find("slstm") >= 0):
            return lead((self._dim(path, shape[-2], "data"), self._dim(path, shape[-1], "model")))
        if name.startswith("conv_w"):  # [W, dr]
            return lead((None, self._dim(path, shape[-1], "model")))
        # 1-D vectors (norms, biases, lam) and small tensors: replicate.
        return P(*([None] * nd))

    # --------------------------------------------------------------- public --
    def param_specs(self, params: Any) -> Any:
        def per_leaf(path, leaf):
            pstr = "/".join(str(getattr(k, "key", getattr(k, "idx", k))) for k in path)
            return self._spec_for(pstr, leaf.shape)

        return jax.tree_util.tree_map_with_path(per_leaf, params)

    def param_shardings(self, params: Any) -> Any:
        return jax.tree_util.tree_map(lambda s: NamedSharding(self.mesh, s), self.param_specs(params))

    def batch_specs(self, batch: Any) -> Any:
        dp = self.dp

        def per_leaf(path, leaf):
            nd = len(leaf.shape)
            if leaf.shape and self._ok(leaf.shape[0], dp):
                return P(dp, *([None] * (nd - 1)))
            return P(*([None] * nd))

        return jax.tree_util.tree_map_with_path(per_leaf, batch)

    def batch_shardings(self, batch: Any) -> Any:
        return jax.tree_util.tree_map(lambda s: NamedSharding(self.mesh, s), self.batch_specs(batch))

    def cache_specs(self, cache: Any) -> Any:
        """KV caches [L,B,S,Hkv,hd] / recurrent states [L,B,...]:
        batch over data axes; kv-heads over model when divisible, else head_dim."""
        dp = self.dp

        def per_leaf(path, leaf):
            shape = leaf.shape
            nd = len(shape)
            pstr = "/".join(str(getattr(k, "key", getattr(k, "idx", k))) for k in path)
            if nd == 5:  # [L, B, S, Hkv, hd] — flash-decode layout: shard S
                b_ax = dp if self._ok(shape[1], dp) else None
                s_ax = self._dim(pstr, shape[2], "model")
                return P(None, b_ax, s_ax, None, None)
            if nd >= 2 and self._ok(shape[1], dp):  # [L, B, ...] states
                tail = [None] * (nd - 2)
                if nd >= 3 and self._ok(shape[-1], "model"):
                    tail[-1] = "model"
                return P(None, dp, *tail)
            if nd == 1:  # lengths [B]
                return P(dp) if self._ok(shape[0], dp) else P(None)
            return P(*([None] * nd))

        return jax.tree_util.tree_map_with_path(per_leaf, cache)

    def cache_shardings(self, cache: Any) -> Any:
        return jax.tree_util.tree_map(lambda s: NamedSharding(self.mesh, s), self.cache_specs(cache))

    def explain(self) -> str:
        return "\n".join(self.fallbacks) or "(no replication fallbacks)"
