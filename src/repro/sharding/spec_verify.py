"""Tensor-parallel spec-verify: the fused target forward as ONE sharded launch.

The unsharded fused verify (``kernels.spec_verify.spec_verify_fused``) runs
paged target attention + blocked LM-head projection + the NAV scan in one
launch.  This module shards that SAME launch across a 1-D ``("model",)``
device mesh via ``shard_map`` while keeping the entry signature — the
dispatcher and router never learn the shard count:

* **Attention — head-parallel.**  Queries and the (GQA-expanded) KV pages
  split on the head axis; each shard runs the paged-attention oracle over
  its local heads only.  Per-head attention is independent, so a head slice
  is bitwise identical to the same heads of the full computation, and the
  ``all_gather`` that reassembles ``[B*K1, H, hd]`` is pure concatenation.
  Head counts that don't divide the mesh (GQA ratios, odd H) are zero-padded
  to the next multiple of ``shards``; padded head lanes compute finite
  garbage that is sliced off right after the gather.
* **LM head — vocab-parallel (Megatron column style).**  Each shard holds a
  ``[F, Vs]`` column slice of the LM head (``Vs`` a ``block_v`` multiple)
  and issues the SAME ``jnp.dot([K1, F], [F, block_v])`` tiles as
  ``fused_target_logits`` — full contraction dim, local vocab tiles — so
  every logit is produced by identical arithmetic on one shard.  Padded
  vocab ids are masked to ``-1e30`` with GLOBAL ids before the vocab
  ``all_gather``, preserving the unsharded masking contract.
* **NAV scan — replicated.**  After the gather every shard holds the full
  ``[B, K1, Vp]`` logits and runs ``spec_verify_ref`` redundantly; outputs
  are replicated (``check_rep=False`` + fully-replicated out specs).
* **int8 pages.**  Quantized pools shard the affine ``scale``/``zero``
  planes WITH their KV on the head axis; dequantization is per-element, so
  local dequant of a head slice is bitwise identical to slicing a global
  dequant.
* **Per-device block tables.**  Block tables, lengths, tokens and
  ``n_drafted`` are replicated — every device holds the full table, and the
  sentinel-page padding contract (``pad_block_tables``) holds per shard
  because each shard's page buffer keeps the zero-filled sentinel page in
  its local head slice.

Bit-exactness (``tests/test_sharded_verify.py``): the jitted sharded launch
is ``assert_array_equal``-exact against the jitted unsharded oracle — the
comparison that matters, since XLA's eager-vs-jit fusion already perturbs
attention by ~1 ulp while two jitted programs agree bitwise on a host mesh.
"""

from __future__ import annotations

import functools
from dataclasses import dataclass
from typing import List, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

try:  # moved out of jax.experimental in newer releases
    from jax.shard_map import shard_map  # type: ignore[import]
except Exception:  # pragma: no cover - jax 0.4.x path
    from jax.experimental.shard_map import shard_map

from jax.sharding import Mesh, PartitionSpec as P

from repro.kernels.decode_attention.ref import dequantize_pages, paged_decode_attention_ref
from repro.kernels.spec_verify.ops import _next_pow2, pad_block_tables
from repro.kernels.spec_verify.ref import spec_verify_ref

from .shardctx import host_mesh

__all__ = [
    "MODEL_AXIS",
    "ShardPlan",
    "plan_shards",
    "sharded_target_logits",
    "spec_verify_sharded",
    "spec_verify_sharded_batched",
]

MODEL_AXIS = "model"


# --------------------------------------------------------------------------- #
# Shard planning (padding geometry + divisibility metadata)
# --------------------------------------------------------------------------- #


@dataclass(frozen=True)
class ShardPlan:
    """Padding geometry for one sharded verify launch.

    ``heads`` is the QUERY head count (KV is GQA-expanded to it before the
    head split); ``padded_heads`` is the zero-padded head count actually
    split over the mesh.  ``vocab_per_shard`` is each shard's LM-head column
    width — a ``block_v`` multiple, so the per-shard projection issues the
    same vocab tiles as the unsharded blocked LM head.
    """

    shards: int
    heads: int  # H (query heads; KV expands to this)
    kv_heads: int  # Hkv as stored in the pool
    head_dim: int
    padded_heads: int  # Hp = ceil(H / shards) * shards
    vocab: int  # true vocab V
    padded_vocab: int  # Vp = ceil(V / block_v) * block_v (unsharded padding)
    vocab_per_shard: int  # Vs, a block_v multiple
    block_v: int

    @property
    def heads_per_shard(self) -> int:
        return self.padded_heads // self.shards

    @property
    def launch_vocab(self) -> int:
        """Total LM-head columns in the sharded launch (``shards * Vs``)."""
        return self.shards * self.vocab_per_shard

    @property
    def even_heads(self) -> bool:
        """True iff the query heads split without zero-padded lanes."""
        return self.heads % self.shards == 0

    @property
    def even_kv_heads(self) -> bool:
        """True iff the pool's KV head axis splits without replication."""
        return self.kv_heads % self.shards == 0


def plan_shards(
    *,
    shards: int,
    n_heads: int,
    n_kv_heads: int,
    head_dim: int,
    vocab: int,
    block_v: int = 2048,
) -> ShardPlan:
    """Compute the padding geometry for a ``shards``-way verify launch."""
    if shards < 1:
        raise ValueError(f"shards must be >= 1, got {shards}")
    if n_heads % max(n_kv_heads, 1):
        raise ValueError(f"n_heads={n_heads} not a multiple of n_kv_heads={n_kv_heads}")
    bv = min(block_v, _next_pow2(vocab))
    vp = -(-vocab // bv) * bv
    vs = -(-vp // (shards * bv)) * bv
    hp = -(-n_heads // shards) * shards
    return ShardPlan(
        shards=shards,
        heads=n_heads,
        kv_heads=n_kv_heads,
        head_dim=head_dim,
        padded_heads=hp,
        vocab=vocab,
        padded_vocab=vp,
        vocab_per_shard=vs,
        block_v=bv,
    )


# --------------------------------------------------------------------------- #
# The one sharded launch
# --------------------------------------------------------------------------- #


def _pad_axis(x: jax.Array, axis: int, to: int) -> jax.Array:
    if x.shape[axis] == to:
        return x
    widths = [(0, 0)] * x.ndim
    widths[axis] = (0, to - x.shape[axis])
    return jnp.pad(x, widths)


@functools.lru_cache(maxsize=None)
def _build_launch(
    mesh: Mesh,
    *,
    heads: int,
    head_dim: int,
    v_true: int,
    padded_vocab: int,
    vocab_per_shard: int,
    block_v: int,
    window: int,
    quantized: bool,
    with_scan: bool,
):
    """Jitted shard_map launch, cached per (mesh, static geometry).

    The body mirrors ``spec_verify_fused_ref`` stage for stage: per-shard
    paged attention on the local head slice, head ``all_gather`` + slice to
    the true head count, per-shard ``block_v`` vocab tiles with the FULL
    contraction dim, global-id masking, vocab ``all_gather``, then the
    replicated NAV scan (or the raw logits when ``with_scan`` is False).
    """
    H, hd, Vp, Vs, bv = heads, head_dim, padded_vocab, vocab_per_shard, block_v
    F = H * hd

    def body(q, kp, vp, w, tables, lengths, tokens, nd, *quant):
        B, K1 = q.shape[0], q.shape[1]
        if quantized:
            ks, kz, vs_, vz = quant
            kp = dequantize_pages(kp, ks, kz)
            vp = dequantize_pages(vp, vs_, vz)
        qf = q.reshape(B * K1, q.shape[2], hd)
        tf = jnp.repeat(tables, K1, axis=0)
        lf = lengths.reshape(-1)
        o = paged_decode_attention_ref(qf, kp, vp, tf, lf, window=window)
        o = jax.lax.all_gather(o, MODEL_AXIS, axis=1, tiled=True)
        o = o[:, :H].reshape(B, K1, F).astype(jnp.float32)
        # Same vocab tiles as fused_target_logits, restricted to this
        # shard's LM-head columns — identical per-logit arithmetic.
        tiles = [w[:, j : j + bv] for j in range(0, Vs, bv)]
        rows = [jnp.concatenate([jnp.dot(o[b], t) for t in tiles], axis=-1) for b in range(B)]
        logits = jnp.stack(rows)  # [B, K1, Vs]
        shard = jax.lax.axis_index(MODEL_AXIS)
        ids = shard * Vs + jnp.arange(Vs)[None, None, :]
        logits = jnp.where(ids >= v_true, -1e30, logits)
        logits = jax.lax.all_gather(logits, MODEL_AXIS, axis=2, tiled=True)
        logits = logits[:, :, :Vp]
        if not with_scan:
            return logits
        return spec_verify_ref(logits, tokens, nd)

    head4 = P(None, None, MODEL_AXIS, None)  # [*, *, heads, hd]
    quant_specs = (P(None, None, MODEL_AXIS),) * 4 if quantized else ()
    in_specs = (
        head4,  # q [B, K1, Hp, hd]
        head4,  # k_pages [P, bs, Hp, hd]
        head4,  # v_pages
        P(None, MODEL_AXIS),  # w [F, shards * Vs]
        P(None, None),  # tables (replicated per device)
        P(None, None),  # lengths
        P(None, None),  # tokens
        P(None),  # n_drafted
    ) + quant_specs
    out_specs = (
        P(None, None, None)
        if not with_scan
        else (P(None, None), P(None, None), P(None, None))
    )
    return jax.jit(
        shard_map(body, mesh=mesh, in_specs=in_specs, out_specs=out_specs, check_rep=False)
    )


def _prepare(
    q: jax.Array,
    k_pages: jax.Array,
    v_pages: jax.Array,
    w: jax.Array,
    mesh: Mesh,
    *,
    v_true: Optional[int],
    block_v: int,
    quant,
):
    """GQA-expand, head-pad and vocab-pad the operands for the mesh."""
    shards = int(np.prod(list(mesh.shape.values())))
    H = q.shape[2]
    n_kv = k_pages.shape[2]
    if n_kv != H:  # GQA: expand KV (and quant planes) to the query heads
        k_pages = jnp.repeat(k_pages, H // n_kv, axis=2)
        v_pages = jnp.repeat(v_pages, H // n_kv, axis=2)
        if quant is not None:
            quant = tuple(jnp.repeat(p, H // n_kv, axis=2) for p in quant)
    V = w.shape[1]
    if v_true is None:
        v_true = V
    plan = plan_shards(
        shards=shards, n_heads=H, n_kv_heads=n_kv, head_dim=q.shape[3],
        vocab=V, block_v=block_v,
    )
    q = _pad_axis(q, 2, plan.padded_heads)
    k_pages = _pad_axis(k_pages, 2, plan.padded_heads)
    v_pages = _pad_axis(v_pages, 2, plan.padded_heads)
    if quant is not None:
        # Zero scale/zero planes dequantize padded head lanes to 0.0 — finite
        # garbage sliced off after the head gather, like the fp32 zero pad.
        quant = tuple(_pad_axis(p, 2, plan.padded_heads) for p in quant)
    w = _pad_axis(
        _pad_axis(w.astype(jnp.float32), 1, plan.padded_vocab), 1, plan.launch_vocab
    )
    return q, k_pages, v_pages, w, quant, plan, int(v_true)


def spec_verify_sharded(
    q: jax.Array,  # [B, K+1, H, hd] — per-position queries
    k_pages: jax.Array,  # [P, bs, Hkv, hd] (int8 payload when quant is given)
    v_pages: jax.Array,
    w: jax.Array,  # [H*hd, V] LM head
    block_tables: jax.Array,  # [B, G] i32 physical page ids
    lengths: jax.Array,  # [B, K+1] i32 valid KV length per query position
    draft_tokens: jax.Array,  # [B, K] i32
    n_drafted: jax.Array,  # [B] i32
    *,
    mesh: Mesh,
    v_true: Optional[int] = None,
    block_v: int = 2048,
    window: int = 1 << 30,
    quant=None,  # (k_scale, k_zero, v_scale, v_zero), each [P, bs, Hkv] f32
):
    """Sharded twin of ``spec_verify_fused``: ONE launch across the mesh.

    Same signature and return contract as the unsharded fused entry
    (``(n_accepted [B,1], correction [B,1], logp [B,K])``), plus the mesh.
    Bit-exact against the jitted unsharded oracle for any shard count,
    including head counts that don't divide the mesh and int8 pools.
    """
    q, k_pages, v_pages, w, quant, plan, v_true = _prepare(
        q, k_pages, v_pages, w, mesh, v_true=v_true, block_v=block_v, quant=quant
    )
    fn = _build_launch(
        mesh,
        heads=plan.heads,
        head_dim=plan.head_dim,
        v_true=v_true,
        padded_vocab=plan.padded_vocab,
        vocab_per_shard=plan.vocab_per_shard,
        block_v=plan.block_v,
        window=window,
        quantized=quant is not None,
        with_scan=True,
    )
    args = (q, k_pages, v_pages, w,
            jnp.asarray(block_tables, jnp.int32), jnp.asarray(lengths, jnp.int32),
            jnp.asarray(draft_tokens, jnp.int32), jnp.asarray(n_drafted, jnp.int32))
    if quant is not None:
        args += tuple(quant)
    return fn(*args)


def sharded_target_logits(
    q: jax.Array,  # [B, K+1, H, hd]
    k_pages: jax.Array,
    v_pages: jax.Array,
    w: jax.Array,
    block_tables: jax.Array,
    lengths: jax.Array,
    *,
    mesh: Mesh,
    v_true: Optional[int] = None,
    block_v: int = 2048,
    window: int = 1 << 30,
    quant=None,
) -> jax.Array:
    """Sharded target forward WITHOUT the NAV scan: ``[B, K+1, Vp]`` logits.

    The chain-path building block: wraps the same sharded launch but stops
    after the vocab gather, so callers can feed ``spec_verify_batched``'s
    ``batched_logits_fn`` contract from a tensor-parallel forward.  Padded
    vocab lanes (``>= v_true``) carry ``-1e30``, matching
    ``fused_target_logits``.
    """
    B = q.shape[0]
    q, k_pages, v_pages, w, quant, plan, v_true = _prepare(
        q, k_pages, v_pages, w, mesh, v_true=v_true, block_v=block_v, quant=quant
    )
    fn = _build_launch(
        mesh,
        heads=plan.heads,
        head_dim=plan.head_dim,
        v_true=v_true,
        padded_vocab=plan.padded_vocab,
        vocab_per_shard=plan.vocab_per_shard,
        block_v=plan.block_v,
        window=window,
        quantized=quant is not None,
        with_scan=False,
    )
    K1 = q.shape[1]
    zeros_t = jnp.zeros((B, max(K1 - 1, 1)), jnp.int32)
    args = (q, k_pages, v_pages, w,
            jnp.asarray(block_tables, jnp.int32), jnp.asarray(lengths, jnp.int32),
            zeros_t, jnp.zeros((B,), jnp.int32))
    if quant is not None:
        args += tuple(quant)
    return fn(*args)


def spec_verify_sharded_batched(
    q_seq: Sequence,  # B entries of [K_i+1, H, hd] per-position queries
    tokens_seq: Sequence,  # B entries of length-K_i int sequences
    block_tables_seq: Sequence,  # B ragged KV block tables
    base_lengths: Sequence,  # B ints — KV length visible to query position 0
    k_pages: jax.Array,
    v_pages: jax.Array,
    w: jax.Array,
    *,
    mesh: Optional[Mesh] = None,
    shards: Optional[int] = None,
    block_v: int = 2048,
    bucket: bool = True,
    window: int = 1 << 30,
    pad_page_id: int = 0,
    quant=None,
) -> List[Tuple[int, int, np.ndarray]]:
    """Ragged serving entry for the SHARDED fused verify — one launch.

    The sharded twin of ``spec_verify_fused_batched``: identical pow2
    bucketing, sentinel-page table padding, inert pad rows, and per-session
    unpacking — only the launch underneath runs ``shard_map`` across the
    mesh.  Pass either a prebuilt 1-D ``mesh`` or a ``shards`` count (a host
    mesh over the first ``shards`` devices is built for you).
    """
    if mesh is None:
        if shards is None:
            raise ValueError("pass mesh= or shards=")
        mesh = host_mesh(shards)
    if not (len(q_seq) == len(tokens_seq) == len(block_tables_seq) == len(base_lengths)):
        raise ValueError("need one (queries, tokens, table, base_length) per session")
    if not len(tokens_seq):
        raise ValueError("need at least one session")
    ks = [len(t) for t in tokens_seq]
    for qi, k in zip(q_seq, ks):
        if qi.shape[0] != k + 1:
            raise ValueError(f"queries must be [K_i+1, H, hd]; got {qi.shape} for K_i={k}")
    B, kmax = len(ks), max(max(ks, default=0), 1)
    Bp = _next_pow2(B) if bucket else B
    Kp = _next_pow2(kmax) if bucket else kmax
    H, hd = q_seq[0].shape[1], q_seq[0].shape[2]
    qpad = np.zeros((Bp, Kp + 1, H, hd), np.float32)
    tokens = np.zeros((Bp, Kp), np.int32)
    nd = np.zeros((Bp,), np.int32)
    lengths = np.zeros((Bp, Kp + 1), np.int32)
    for i, (qi, tk, k, base) in enumerate(zip(q_seq, tokens_seq, ks, base_lengths)):
        qpad[i, : k + 1] = np.asarray(qi, np.float32)
        tokens[i, :k] = np.asarray(tk, np.int32)
        nd[i] = k
        lengths[i, : k + 1] = int(base) + np.arange(k + 1)
    tables = pad_block_tables(
        block_tables_seq, batch_pad=Bp, bucket=bucket, pad_id=pad_page_id
    )
    na, corr, logp = spec_verify_sharded(
        jnp.asarray(qpad),
        k_pages,
        v_pages,
        w,
        jnp.asarray(tables),
        jnp.asarray(lengths),
        jnp.asarray(tokens),
        jnp.asarray(nd),
        mesh=mesh,
        block_v=block_v,
        window=window,
        quant=quant,
    )
    na, corr, logp = np.asarray(na), np.asarray(corr), np.asarray(logp)
    return [(int(na[i, 0]), int(corr[i, 0]), logp[i, : ks[i]]) for i in range(B)]
