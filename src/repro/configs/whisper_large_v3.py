"""whisper-large-v3 [audio] — enc-dec, conv frontend stubbed.

32 encoder + 32 decoder layers, d_model=1280, 20 heads (kv=20), d_ff=5120,
vocab 51866.  [arXiv:2212.04356; unverified]
"""

from repro.models.config import EncoderConfig, ModelConfig

CONFIG = ModelConfig(
    name="whisper-large-v3",
    family="audio",
    n_layers=32,
    d_model=1280,
    n_heads=20,
    n_kv_heads=20,
    d_ff=5120,
    vocab_size=51_866,
    head_dim=64,
    encoder=EncoderConfig(n_layers=32, n_ctx=1500),
    tie_embeddings=True,
)

REDUCED = CONFIG.reduced(n_heads=4, n_kv_heads=4, head_dim=16)
