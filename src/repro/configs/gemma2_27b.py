"""gemma2-27b [dense] — alternating local/global attention + logit softcaps.

46L, d_model=4608, 32 heads (GQA kv=16), d_ff=36864, vocab 256000; window
4096 on local layers; attn softcap 50, final-logit softcap 30.
[arXiv:2408.00118; hf]
"""

from repro.models.config import GLOBAL_WINDOW, ModelConfig

_KINDS = tuple("local" if i % 2 == 0 else "attn" for i in range(46))
_WINDOWS = tuple(4096 if k == "local" else GLOBAL_WINDOW for k in _KINDS)

CONFIG = ModelConfig(
    name="gemma2-27b",
    family="dense",
    n_layers=46,
    d_model=4608,
    n_heads=32,
    n_kv_heads=16,
    d_ff=36_864,
    vocab_size=256_000,
    head_dim=128,
    layer_kinds=_KINDS,
    window_sizes=_WINDOWS,
    logit_softcap=30.0,
    attn_softcap=50.0,
    tie_embeddings=True,
)

_RK = ("local", "attn", "local", "attn")
REDUCED = CONFIG.reduced(layer_kinds=_RK, window_sizes=tuple(16 if k == "local" else GLOBAL_WINDOW for k in _RK))
