"""Assigned input-shape grid + ShapeDtypeStruct input specs per (arch, shape).

LM transformer shapes are seq_len × global_batch.  ``decode_*`` / ``long_*``
lower ``serve_step`` (one new token against a KV cache of seq_len), NOT
``train_step``.  ``long_500k`` requires sub-quadratic attention and only runs
for archs with ``cfg.sub_quadratic`` (recurrentgemma, xlstm) — the full-
attention skips are recorded by the dry-run, per DESIGN.md §4.
"""

from __future__ import annotations

from dataclasses import dataclass
from typing import Dict, Optional

import jax
import jax.numpy as jnp

from repro.models.config import ModelConfig

__all__ = ["ShapeSpec", "SHAPES", "input_specs", "applicable", "VERIFY_K"]

VERIFY_K = 8  # draft tokens per NAV verify step (paper-representative serve op)


@dataclass(frozen=True)
class ShapeSpec:
    name: str
    kind: str  # 'train' | 'prefill' | 'decode'
    seq_len: int
    global_batch: int


SHAPES: Dict[str, ShapeSpec] = {
    "train_4k": ShapeSpec("train_4k", "train", 4_096, 256),
    "prefill_32k": ShapeSpec("prefill_32k", "prefill", 32_768, 32),
    "decode_32k": ShapeSpec("decode_32k", "decode", 32_768, 128),
    "long_500k": ShapeSpec("long_500k", "decode", 524_288, 1),
}


def applicable(cfg: ModelConfig, shape: ShapeSpec) -> Optional[str]:
    """None if the (arch, shape) cell runs; else a skip reason string."""
    if shape.name == "long_500k" and not cfg.sub_quadratic:
        return "skip(full-attention: unbounded 500k KV; see DESIGN.md §4)"
    return None


def _i32(shape) -> jax.ShapeDtypeStruct:
    return jax.ShapeDtypeStruct(shape, jnp.int32)


def input_specs(cfg: ModelConfig, shape: ShapeSpec, n_tokens: Optional[int] = None) -> Dict[str, jax.ShapeDtypeStruct]:
    """Model-input stand-ins (weak-type-correct, shardable, no allocation).

    train  : {tokens, labels} (+ modality stubs)
    prefill: {tokens} (+ modality stubs)
    decode : {tokens [B, n_tokens]} — cache specs are built separately
             (n_tokens=1 plain decode; VERIFY_K+1 for the NAV verify step).
    """
    B, S = shape.global_batch, shape.seq_len
    act_dtype = jnp.dtype(cfg.dtype)
    if shape.kind == "train":
        specs = {"tokens": _i32((B, S)), "labels": _i32((B, S))}
    elif shape.kind == "prefill":
        specs = {"tokens": _i32((B, S))}
    else:  # decode
        specs = {"tokens": _i32((B, n_tokens or 1))}
    if cfg.family == "audio" and shape.kind != "decode":
        specs["frames"] = jax.ShapeDtypeStruct((B, cfg.encoder.n_ctx, cfg.d_model), act_dtype)
    if cfg.family == "vlm" and shape.kind != "decode":
        specs["vision_embeds"] = jax.ShapeDtypeStruct((B, cfg.n_vision_tokens, cfg.d_model), act_dtype)
    return specs
