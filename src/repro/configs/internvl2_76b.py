"""internvl2-76b [vlm] — InternLM2-76B backbone; InternViT frontend stubbed.

80L, d_model=8192, 64 heads (GQA kv=8), d_ff=28672, vocab 128256.  The vision
tower is a STUB: ``input_specs`` provides 256 precomputed patch embeddings
prepended to the text sequence.  [arXiv:2404.16821; unverified]
"""

from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="internvl2-76b",
    family="vlm",
    n_layers=80,
    d_model=8192,
    n_heads=64,
    n_kv_heads=8,
    d_ff=28_672,
    vocab_size=128_256,
    head_dim=128,
    n_vision_tokens=256,
    tie_embeddings=False,
    dtype="bfloat16",
    param_dtype="bfloat16",
)

REDUCED = CONFIG.reduced(dtype="float32", param_dtype="float32")
