"""qwen3-moe-30b-a3b [moe] — 128 experts, top-8, fine-grained (d_ff=768).

48L, d_model=2048, 32 heads (GQA kv=4), expert d_ff=768, vocab 151936.
[hf:Qwen/Qwen3-30B-A3B; hf]
"""

from repro.models.config import ModelConfig, MoEConfig

CONFIG = ModelConfig(
    name="qwen3-moe-30b-a3b",
    family="moe",
    n_layers=48,
    d_model=2048,
    n_heads=32,
    n_kv_heads=4,
    d_ff=768,
    vocab_size=151_936,
    head_dim=128,
    moe=MoEConfig(n_experts=128, top_k=8, d_ff_expert=768),
    tie_embeddings=False,
)

REDUCED = CONFIG.reduced()
