"""arctic-480b [moe] — 128 experts top-2 with a parallel dense-FFN residual.

35L, d_model=7168, 56 heads (GQA kv=8), expert d_ff=4864, vocab 32000.
[hf:Snowflake/snowflake-arctic-base; hf]

Scale note (DESIGN.md §5): bf16 params + Adafactor are the default training
numerics for this config so optimizer state fits 16 GB/chip on the 256-chip
pod (AdamW fp32 states would need ~30 GB/chip).
"""

from repro.models.config import ModelConfig, MoEConfig

CONFIG = ModelConfig(
    name="arctic-480b",
    family="moe",
    n_layers=35,
    d_model=7168,
    n_heads=56,
    n_kv_heads=8,
    d_ff=4864,
    vocab_size=32_000,
    head_dim=128,
    moe=MoEConfig(n_experts=128, top_k=2, d_ff_expert=4864, dense_residual=True, d_ff_dense=4864),
    tie_embeddings=False,
    dtype="bfloat16",
    param_dtype="bfloat16",
)

REDUCED = CONFIG.reduced(dtype="float32", param_dtype="float32")
