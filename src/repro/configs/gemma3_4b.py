"""gemma3-4b [dense] — 5:1 local:global attention, 128k context.

34L, d_model=2560, 8 heads (GQA kv=4), d_ff=10240, vocab 262144; sliding
window 1024 on local layers; rope theta 10k local / 1M global.
[hf:google/gemma-3-1b-pt; unverified]
"""

from repro.models.config import GLOBAL_WINDOW, ModelConfig

_KINDS = tuple(("local local local local local attn".split() * 6)[:34])
_WINDOWS = tuple(1024 if k == "local" else GLOBAL_WINDOW for k in _KINDS)

CONFIG = ModelConfig(
    name="gemma3-4b",
    family="dense",
    n_layers=34,
    d_model=2560,
    n_heads=8,
    n_kv_heads=4,
    d_ff=10240,
    vocab_size=262_144,
    head_dim=256,
    layer_kinds=_KINDS,
    window_sizes=_WINDOWS,
    rope_theta=10_000.0,
    rope_theta_global=1_000_000.0,
    tie_embeddings=True,
)

_RK = ("local", "local", "local", "attn")
REDUCED = CONFIG.reduced(layer_kinds=_RK, window_sizes=tuple(16 if k == "local" else GLOBAL_WINDOW for k in _RK))
