"""xlstm-350m [ssm] — mLSTM + sLSTM blocks, 7:1 ratio.

24L, d_model=1024, 4 heads, vocab 50304, head_dim 256, no separate FFN
(d_ff=0; the cells carry their own projections).  O(1) state → runs the
long_500k decode shape.  [arXiv:2405.04517; unverified]
"""

from repro.models.config import ModelConfig

# (mLSTM × 7, sLSTM × 1) × 3 groups = 24 layers.
_KINDS = tuple((["mlstm"] * 7 + ["slstm"]) * 3)

CONFIG = ModelConfig(
    name="xlstm-350m",
    family="ssm",
    n_layers=24,
    d_model=1024,
    n_heads=4,
    n_kv_heads=4,
    d_ff=0,
    vocab_size=50_304,
    head_dim=256,
    layer_kinds=_KINDS,
    mlstm_chunk=64,
    sub_quadratic=True,
    tie_embeddings=True,
)

_RK = tuple((["mlstm"] * 3 + ["slstm"]) * 1)
REDUCED = CONFIG.reduced(n_layers=4, layer_kinds=_RK, d_ff=0, mlstm_chunk=8)
