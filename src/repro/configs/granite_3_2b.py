"""granite-3-2b [dense] — GQA llama-like.

40L, d_model=2048, 32 heads (GQA kv=8), d_ff=8192, vocab 49155.
[hf:ibm-granite/granite-3.0-2b-base; hf]
"""

from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="granite-3-2b",
    family="dense",
    n_layers=40,
    d_model=2048,
    n_heads=32,
    n_kv_heads=8,
    d_ff=8192,
    vocab_size=49_155,
    head_dim=64,
    tie_embeddings=True,
)

REDUCED = CONFIG.reduced()
