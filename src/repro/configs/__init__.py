from .registry import ARCH_IDS, all_configs, get_config
from .shapes import SHAPES, VERIFY_K, ShapeSpec, applicable, input_specs

__all__ = ["ARCH_IDS", "SHAPES", "VERIFY_K", "ShapeSpec", "all_configs", "applicable", "get_config", "input_specs"]
