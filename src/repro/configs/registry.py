"""Architecture registry: ``--arch <id>`` resolution for launchers/benchmarks."""

from __future__ import annotations

from typing import Dict, List

from repro.models.config import ModelConfig

from . import (
    arctic_480b,
    gemma2_27b,
    gemma3_4b,
    granite_3_2b,
    internvl2_76b,
    minicpm_2b,
    qwen3_moe_30b_a3b,
    recurrentgemma_2b,
    whisper_large_v3,
    xlstm_350m,
)

_MODULES = {
    "whisper-large-v3": whisper_large_v3,
    "minicpm-2b": minicpm_2b,
    "gemma3-4b": gemma3_4b,
    "granite-3-2b": granite_3_2b,
    "gemma2-27b": gemma2_27b,
    "arctic-480b": arctic_480b,
    "qwen3-moe-30b-a3b": qwen3_moe_30b_a3b,
    "internvl2-76b": internvl2_76b,
    "recurrentgemma-2b": recurrentgemma_2b,
    "xlstm-350m": xlstm_350m,
}

ARCH_IDS: List[str] = list(_MODULES)


def get_config(arch: str, reduced: bool = False) -> ModelConfig:
    try:
        mod = _MODULES[arch]
    except KeyError:
        raise KeyError(f"unknown arch {arch!r}; available: {ARCH_IDS}") from None
    return mod.REDUCED if reduced else mod.CONFIG


def all_configs(reduced: bool = False) -> Dict[str, ModelConfig]:
    return {a: get_config(a, reduced) for a in ARCH_IDS}
