"""recurrentgemma-2b [hybrid] — Griffin: RG-LRU + local attention, 1 attn : 2 rec.

26L, d_model=2560, 10 heads (MQA kv=1), d_ff=7680, vocab 256000, head_dim 256,
local window 2048, d_rnn=2560.  Sub-quadratic (O(1) state + bounded window) →
runs the long_500k decode shape.  [arXiv:2402.19427; hf]
"""

from repro.models.config import GLOBAL_WINDOW, ModelConfig

# (R, R, A) × 8 groups + 2 tail recurrent layers = 26.
_KINDS = tuple((["rglru", "rglru", "local"] * 8) + ["rglru", "rglru"])
_WINDOWS = tuple(2048 if k == "local" else 0 for k in _KINDS)

CONFIG = ModelConfig(
    name="recurrentgemma-2b",
    family="hybrid",
    n_layers=26,
    d_model=2560,
    n_heads=10,
    n_kv_heads=1,
    d_ff=7680,
    vocab_size=256_000,
    head_dim=256,
    layer_kinds=_KINDS,
    window_sizes=_WINDOWS,
    d_rnn=2560,
    conv_width=4,
    sub_quadratic=True,
    tie_embeddings=True,
)

_RK = ("rglru", "rglru", "local")
REDUCED = CONFIG.reduced(n_layers=3, layer_kinds=_RK, window_sizes=(0, 0, 16), n_kv_heads=1)
