"""minicpm-2b [dense] — llama-like MHA; trained with the WSD schedule.

40L, d_model=2304, 36 heads (kv=36), d_ff=5760, vocab 122753.
[arXiv:2404.06395; hf]  WSD schedule supported in repro.optim.schedules.
"""

from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="minicpm-2b",
    family="dense",
    n_layers=40,
    d_model=2304,
    n_heads=36,
    n_kv_heads=36,
    d_ff=5760,
    vocab_size=122_753,
    head_dim=64,
    tie_embeddings=True,
)

REDUCED = CONFIG.reduced()
