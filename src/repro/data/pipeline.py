"""Data substrate: synthetic corpus, byte tokenizer, packing, prefetch.

The synthetic corpus is a seeded second-order Markov "language" over a small
word inventory with code-like (HumanEval-style) and arithmetic (GSM8K-style)
dialects.  It gives the tiny draft/target pair something learnable so
speculative-decoding acceptance rates are meaningful on CPU, while staying
fully offline and deterministic.
"""

from __future__ import annotations

import queue
import threading
from dataclasses import dataclass, field
from typing import Dict, Iterator, List, Optional

import numpy as np

__all__ = ["SyntheticCorpus", "ByteTokenizer", "DataPipeline"]


_WORDS_CODE = (
    "def return if else for while in range len print import from class self "
    "x y z i j k n fn args val list dict tuple str int append pop not and or"
).split()
_WORDS_MATH = (
    "alice bob has apples oranges gives takes buys sells total price each "
    "then now many how much left sum difference twice half dollars cents"
).split()


@dataclass
class SyntheticCorpus:
    """Deterministic Markov text generator (dialects: 'code' | 'math')."""

    dialect: str = "code"
    seed: int = 0
    order: int = 2
    branch: int = 3  # successors per context — lower = more predictable

    def __post_init__(self) -> None:
        rng = np.random.default_rng(self.seed + (0 if self.dialect == "code" else 1))
        self.words = _WORDS_CODE if self.dialect == "code" else _WORDS_MATH
        V = len(self.words)
        # Sparse transition table: each (w1, w2) context has `branch` successors
        # with geometric-ish probabilities — highly predictable, like real text.
        self._succ = rng.integers(0, V, size=(V, V, self.branch))
        p = np.array([0.7, 0.2, 0.1][: self.branch], dtype=np.float64)
        self._p = p / p.sum()

    def generate(self, n_words: int, seed: int = 0) -> List[str]:
        rng = np.random.default_rng(seed ^ 0x5EED)
        V = len(self.words)
        w1, w2 = rng.integers(0, V), rng.integers(0, V)
        out = []
        for _ in range(n_words):
            nxt = int(rng.choice(self._succ[w1, w2], p=self._p))
            out.append(self.words[nxt])
            w1, w2 = w2, nxt
        return out

    def text(self, n_words: int, seed: int = 0) -> str:
        return " ".join(self.generate(n_words, seed))


class ByteTokenizer:
    """UTF-8 byte tokenizer with a few specials; vocab = 256 + specials."""

    PAD, BOS, EOS = 256, 257, 258
    vocab_size = 259

    def encode(self, text: str, bos: bool = True) -> List[int]:
        ids = list(text.encode("utf-8"))
        return ([self.BOS] if bos else []) + ids

    def decode(self, ids) -> str:
        return bytes(i for i in ids if 0 <= i < 256).decode("utf-8", errors="replace")


@dataclass
class DataPipeline:
    """Packs tokenized documents into fixed [batch, seq+1] training examples
    with background prefetch (double-buffered thread)."""

    corpus: SyntheticCorpus
    tokenizer: ByteTokenizer
    batch_size: int
    seq_len: int
    seed: int = 0
    prefetch: int = 2
    doc_words: int = 64

    def __post_init__(self) -> None:
        self._q: queue.Queue = queue.Queue(maxsize=self.prefetch)
        self._stop = threading.Event()
        self._thread: Optional[threading.Thread] = None
        self._doc_seed = self.seed * 100_003

    def _make_batch(self, step: int) -> Dict[str, np.ndarray]:
        need = self.batch_size * (self.seq_len + 1)
        buf: List[int] = []
        ds = self._doc_seed + step * 7919
        while len(buf) < need:
            text = self.corpus.text(self.doc_words, seed=ds)
            buf.extend(self.tokenizer.encode(text) + [self.tokenizer.EOS])
            ds += 1
        arr = np.array(buf[:need], dtype=np.int32).reshape(self.batch_size, self.seq_len + 1)
        return {"tokens": arr[:, :-1], "labels": arr[:, 1:]}

    def _worker(self) -> None:
        step = 0
        while not self._stop.is_set():
            batch = self._make_batch(step)
            while not self._stop.is_set():
                try:
                    self._q.put(batch, timeout=0.1)
                    break
                except queue.Full:
                    continue
            step += 1

    def __iter__(self) -> Iterator[Dict[str, np.ndarray]]:
        if self._thread is None:
            self._thread = threading.Thread(target=self._worker, daemon=True)
            self._thread.start()
        while True:
            yield self._q.get()

    def batch_at(self, step: int) -> Dict[str, np.ndarray]:
        """Deterministic random access (resume-from-checkpoint support)."""
        return self._make_batch(step)

    def close(self) -> None:
        self._stop.set()
