from .pipeline import ByteTokenizer, DataPipeline, SyntheticCorpus

__all__ = ["ByteTokenizer", "DataPipeline", "SyntheticCorpus"]
