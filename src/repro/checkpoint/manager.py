"""Checkpointing: atomic pytree save/restore with keep-k and auto-resume.

Fault-tolerance contract (DESIGN.md §6):
* writes are atomic (tmp dir + rename) — a killed process never leaves a
  half-written "latest";
* ``latest_step()`` + ``restore()`` give crash-resume in two calls;
* arbitrary pytrees (params, optimizer state, autotuner observations, data
  position) are stored as flattened npz + a structure manifest, so the serving
  control plane (BO state, DP schedule params) checkpoints exactly like model
  state;
* restore is mesh-agnostic: arrays come back as numpy and the caller
  re-shards via ``jax.device_put`` with its current (possibly different-size)
  mesh — elastic re-scaling.
"""

from __future__ import annotations

import json
import os
import shutil
import tempfile
from pathlib import Path
from typing import Any, List, Optional

import jax
import numpy as np

__all__ = ["save_pytree", "load_pytree", "CheckpointManager"]


def _flatten_with_paths(tree: Any):
    flat = jax.tree_util.tree_flatten_with_path(tree)[0]
    out = {}
    for path, leaf in flat:
        key = "/".join(str(getattr(p, "key", getattr(p, "idx", getattr(p, "name", p)))) for p in path)
        out[key] = np.asarray(leaf)
    return out


def save_pytree(tree: Any, directory: Path) -> None:
    directory = Path(directory)
    tmp = Path(tempfile.mkdtemp(dir=directory.parent, prefix=".tmp_ckpt_"))
    try:
        leaves = _flatten_with_paths(tree)
        np.savez(tmp / "arrays.npz", **leaves)
        treedef = jax.tree_util.tree_structure(tree)
        (tmp / "manifest.json").write_text(
            json.dumps({"keys": list(leaves), "treedef": str(treedef)})
        )
        if directory.exists():
            shutil.rmtree(directory)
        os.replace(tmp, directory)  # atomic publish
    finally:
        if tmp.exists():
            shutil.rmtree(tmp, ignore_errors=True)


def load_pytree(directory: Path, like: Any) -> Any:
    """Restore into the structure of ``like`` (shape/dtype template)."""
    directory = Path(directory)
    with np.load(directory / "arrays.npz") as z:
        arrays = {k: z[k] for k in z.files}
    flat_like = jax.tree_util.tree_flatten_with_path(like)
    leaves = []
    for path, leaf in flat_like[0]:
        key = "/".join(str(getattr(p, "key", getattr(p, "idx", getattr(p, "name", p)))) for p in path)
        if key not in arrays:
            raise KeyError(f"checkpoint missing leaf {key!r}")
        arr = arrays[key]
        if hasattr(leaf, "shape") and tuple(arr.shape) != tuple(leaf.shape):
            raise ValueError(f"shape mismatch for {key}: ckpt {arr.shape} vs template {leaf.shape}")
        leaves.append(arr)
    return jax.tree_util.tree_unflatten(flat_like[1], leaves)


class CheckpointManager:
    """Step-indexed checkpoints under ``root/step_<n>`` with keep-last-k."""

    def __init__(self, root: Path, keep: int = 3):
        self.root = Path(root)
        self.root.mkdir(parents=True, exist_ok=True)
        self.keep = keep

    def _step_dirs(self) -> List[int]:
        steps = []
        for p in self.root.glob("step_*"):
            try:
                steps.append(int(p.name.split("_")[1]))
            except (IndexError, ValueError):
                continue
        return sorted(steps)

    def latest_step(self) -> Optional[int]:
        steps = self._step_dirs()
        return steps[-1] if steps else None

    def save(self, step: int, tree: Any) -> None:
        save_pytree(tree, self.root / f"step_{step}")
        for old in self._step_dirs()[: -self.keep]:
            shutil.rmtree(self.root / f"step_{old}", ignore_errors=True)

    def restore(self, like: Any, step: Optional[int] = None) -> Any:
        step = step if step is not None else self.latest_step()
        if step is None:
            raise FileNotFoundError(f"no checkpoints under {self.root}")
        return load_pytree(self.root / f"step_{step}", like)
