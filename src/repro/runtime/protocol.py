"""Typed, versioned wire protocol for the cloud-edge runtime.

Every message that crosses the edge↔cloud link is one of the frozen
dataclasses below — explicit named fields replacing the positional tuples
and stringly-keyed dicts that accreted across the runtime's growth.  The
module also provides a deterministic length-prefixed binary codec
(:func:`encode` / :func:`decode`; struct-packed, no pickle) so the same
typed messages travel over a real socket byte-for-byte reproducibly, and
the :data:`PROTOCOL_VERSION` negotiation used at attach.

Layering
--------

::

    EdgeClient / CloudVerifier          (typed messages, this module)
            |            ^
            v            |
    Transport.send     Transport.recv   (runtime.transport)
            |            |
      InProcTransport: the message OBJECT rides the Hockney-model
          Channel; faults (runtime.faults) act below this line, on
          whole messages — the codec never runs, so the deterministic
          conformance suite is byte-independent of this module;
      SocketTransport: encode() -> length-prefixed frame -> TCP ->
          decode(); the codec IS the wire format.

Message catalogue
-----------------

===============  =============================================================
type             meaning
===============  =============================================================
Hello            client -> server: open a session, propose ``session`` id,
                 carry the client's ``version`` (checked at attach)
Attach           server -> client: accept/reject the Hello; carries the
                 server's version and the final session id
DraftFragment    client -> server: one pipelined upload of drafted tokens
                 (``round``-scoped; ``parents`` packs tree structure)
NavRequest       client -> server: verify the round's first ``n_tokens``
                 buffered drafts (chain speculation)
TreeNavRequest   client -> server: same, but the round's fragments carry a
                 packed token tree (verified by tree-NAV)
NavResult        server -> client: accepted count, correction token, and —
                 for tree rounds — the accepted root→leaf ``path``
Reset            client -> server: re-attach after an offline spell; carries
                 the edge's committed stream ``position`` for KV reconcile
Detach           client -> server: the session is finished; buffered state
                 and KV pages may be reclaimed
Heartbeat        either direction: liveness signal (refreshes the server's
                 ``last_seen`` like any other message)
Route            router -> client: the session was placed on ``verifier``
                 (control plane; informational for the client)
Migrate          router -> client: the session live-migrated ``src`` ->
                 ``dst`` at committed ``position`` (control plane)
Drain            router/admin -> verifier: stop admitting new sessions;
                 existing sessions keep serving until migrated away
TelemetryRequest client/tool -> verifier or router: ask for a telemetry
                 snapshot (``session=-1``: control-scoped, not a session)
TelemetrySnapshot verifier/router -> requester: point-in-time serving
                 metrics for one verifier — or the fleet-wide aggregate
                 when the router answers (``verifier=-1``)
===============  =============================================================

Clock domains
-------------

``NavRequest.deadline`` is an *absolute* timestamp on the clock shared by
client and server.  In-process transports share that clock by construction;
``SocketTransport`` rebases the deadline through a relative time budget at
the send/recv boundary (see ``runtime.transport``), so the field is always
directly comparable to ``clock.monotonic()`` on the receiving side.

Link cost
---------

:func:`wire_tokens` maps each message to the token count the Hockney model
charges for it (``alpha + beta * n``): a draft fragment pays per drafted
token, a NAV result per accepted token, and control messages pay one token.
"""

from __future__ import annotations

import struct
from dataclasses import dataclass, fields
from typing import Dict, Optional, Tuple, Type, Union

__all__ = [
    "PROTOCOL_VERSION",
    "ProtocolError",
    "Hello",
    "Attach",
    "DraftFragment",
    "NavRequest",
    "TreeNavRequest",
    "NavResult",
    "Reset",
    "Detach",
    "Heartbeat",
    "Route",
    "Migrate",
    "Drain",
    "TelemetryRequest",
    "TelemetrySnapshot",
    "MESSAGE_TYPES",
    "ProtocolMessage",
    "encode",
    "decode",
    "wire_tokens",
    "handshake_reply",
]

#: Wire-protocol version carried by ``Hello`` and checked at attach.  Bump on
#: any change to the message set, field layout, or codec byte format.
#: v2: control-plane messages (``Route``/``Migrate``/``Drain``) for the
#: multi-verifier router.
#: v3: observability messages (``TelemetryRequest``/``TelemetrySnapshot``)
#: and the ``ts`` (tuple-of-str) field encoding they introduce.
PROTOCOL_VERSION = 3


class ProtocolError(ValueError):
    """Malformed frame, unknown message type, or failed version negotiation."""


# --------------------------------------------------------------------------- #
# Message types
# --------------------------------------------------------------------------- #


@dataclass(frozen=True)
class Hello:
    """Client -> server: open a session and negotiate the protocol version."""

    session: int
    seq: int = 0
    version: int = PROTOCOL_VERSION


@dataclass(frozen=True)
class Attach:
    """Server -> client: accept or reject a ``Hello`` (version negotiation).

    ``session`` is the *final* session id (the server may remap the client's
    proposal on collision); ``accepted=False`` carries a human-readable
    ``reason`` and the server's own ``version`` so the client can report the
    mismatch precisely.
    """

    session: int
    seq: int = 0
    version: int = PROTOCOL_VERSION
    accepted: bool = True
    reason: str = ""


@dataclass(frozen=True)
class DraftFragment:
    """Client -> server: one pipelined upload of drafted tokens.

    Fragments are scoped to a NAV ``round`` and reassembled server-side in
    ``seq`` order, so reorder-delayed uploads recover the client's draft
    order.  ``parents`` packs tree structure (parent node index per token,
    ``-1`` for roots) and is empty for chain rounds.
    """

    session: int
    seq: int
    round: int
    tokens: Tuple[int, ...]
    confs: Tuple[float, ...]
    parents: Tuple[int, ...] = ()


@dataclass(frozen=True)
class NavRequest:
    """Client -> server: verify the round's first ``n_tokens`` buffered drafts.

    ``deadline`` is the absolute receiver-clock time after which the client
    has failed over (the server drops the work — straggler mitigation);
    ``None`` never expires.  ``pos`` is the committed stream position of the
    round's first draft, consumed by stateless positional verifiers
    (``runtime.oracle.OracleBackend``).
    """

    session: int
    seq: int
    round: int
    n_tokens: int
    deadline: Optional[float] = None
    pos: Optional[int] = None


@dataclass(frozen=True)
class TreeNavRequest(NavRequest):
    """Client -> server: NAV over a packed token tree (same fields as chain).

    The tree structure itself rides the round's ``DraftFragment.parents``
    lanes; this type only switches the verifier onto the tree-NAV path.
    """


@dataclass(frozen=True)
class NavResult:
    """Server -> client: the verdict for one NAV round.

    ``seq`` echoes the request's ``seq`` so the client can discard stale
    replies after a failover.  ``path`` is ``None`` for chain rounds and the
    accepted root→leaf packed-node-index path for tree rounds (possibly
    empty when nothing was accepted).
    """

    session: int
    seq: int
    n_accepted: int
    correction: int
    n_drafted: int
    path: Optional[Tuple[int, ...]] = None


@dataclass(frozen=True)
class Reset:
    """Client -> server: re-attach after an offline spell.

    ``position`` is the edge's committed stream length — authoritative after
    local decoding — which the verifier adopts (rolling its paged-KV fork
    back past it and bumping the session's reset epoch so in-flight rounds
    never commit).
    """

    session: int
    seq: int
    round: int
    position: int


@dataclass(frozen=True)
class Detach:
    """Client -> server: the session is finished; reclaim its state."""

    session: int
    seq: int = 0


@dataclass(frozen=True)
class Heartbeat:
    """Either direction: liveness probe (``t_send`` is the sender's clock)."""

    session: int
    seq: int = 0
    t_send: float = 0.0


@dataclass(frozen=True)
class Route:
    """Router -> client: the session was placed on ``verifier``.

    Control-plane announcement from the multi-verifier router: purely
    informational for the client (the router relays all traffic), but it
    makes placement observable end-to-end and gives operator tooling a
    typed event to log.
    """

    session: int
    seq: int = 0
    verifier: int = 0


@dataclass(frozen=True)
class Migrate:
    """Router -> client: the session live-migrated ``src`` -> ``dst``.

    ``position`` is the committed stream position the router serialized and
    replayed onto the destination verifier (via ``Reset``); the client needs
    no action — stale results are already discarded by ``seq`` — but counts
    these in its stats so migrations are observable at the edge.
    """

    session: int
    seq: int = 0
    src: int = 0
    dst: int = 0
    position: int = 0


@dataclass(frozen=True)
class Drain:
    """Router/admin -> verifier: stop admitting new sessions.

    Existing sessions keep serving until the control plane migrates them
    away; ``verifier`` names the drained instance (``session`` is ``-1``:
    control messages are not session-scoped).
    """

    session: int = -1
    seq: int = 0
    verifier: int = 0


@dataclass(frozen=True)
class TelemetryRequest:
    """Client/tool -> verifier or router: ask for a telemetry snapshot.

    ``session`` is ``-1`` by default (control-scoped, like ``Drain``); the
    router intercepts requests arriving on a session's uplink and answers
    with the fleet-wide aggregate, while a directly-attached verifier
    answers with its own snapshot.  ``seq`` is echoed in the reply so
    pollers can pair request/response.
    """

    session: int = -1
    seq: int = 0


@dataclass(frozen=True)
class TelemetrySnapshot:
    """Verifier/router -> requester: point-in-time serving metrics.

    One verifier's live serving state (or, from the router, the fleet-wide
    aggregate with ``verifier=-1`` and ``n_verifiers`` set): session/queue
    occupancy, NAV throughput counters, paged-KV residency, and
    control-plane counters.  ``t`` is the responder's clock at snapshot
    time.  The ``names``/``values`` lanes carry extra labeled scalars
    (chaos counters, transport stats) without a protocol bump: they are
    parallel tuples — ``names[i]`` labels ``values[i]``.
    """

    session: int = -1
    seq: int = 0
    verifier: int = 0
    n_verifiers: int = 1
    t: float = 0.0
    sessions_active: int = 0
    queue_depth: int = 0
    nav_calls: int = 0
    tokens_verified: int = 0
    accepted_tokens: int = 0
    batched_calls: int = 0
    occupancy: float = 0.0
    verify_busy_time: float = 0.0
    kv_used_blocks: int = 0
    kv_free_blocks: int = 0
    kv_resident_bytes: int = 0
    kv_resident_sessions: int = 0
    kv_cap_hits: int = 0
    migrations: int = 0
    failovers: int = 0
    names: Tuple[str, ...] = ()
    values: Tuple[float, ...] = ()

    def extras(self) -> Dict[str, float]:
        """The ``names``/``values`` lanes zipped into a dict."""
        return dict(zip(self.names, self.values))


#: Every concrete message type, in wire-id order (codec round-trip tests
#: iterate this).  APPEND-ONLY: wire type ids are assigned by enumeration
#: order, so new types go at the end to keep existing ids stable.
MESSAGE_TYPES: Tuple[type, ...] = (
    Hello,
    Attach,
    DraftFragment,
    NavRequest,
    TreeNavRequest,
    NavResult,
    Reset,
    Detach,
    Heartbeat,
    Route,
    Migrate,
    Drain,
    TelemetryRequest,
    TelemetrySnapshot,
)

ProtocolMessage = Union[
    Hello, Attach, DraftFragment, NavRequest, TreeNavRequest, NavResult,
    Reset, Detach, Heartbeat, Route, Migrate, Drain,
    TelemetryRequest, TelemetrySnapshot,
]


def wire_tokens(msg: ProtocolMessage) -> int:
    """Token count the Hockney link model charges for ``msg``.

    Draft fragments pay per drafted token and NAV results per accepted token
    (at least one — the correction always ships); every control message pays
    a single token.  These are exactly the historical per-kind costs, so the
    deterministic conformance timings are unchanged by the typed protocol.
    """
    if isinstance(msg, DraftFragment):
        return len(msg.tokens)
    if isinstance(msg, NavResult):
        return max(msg.n_accepted, 1)
    return 1


# --------------------------------------------------------------------------- #
# Codec: deterministic length-prefixed binary frames (struct-packed, no pickle)
# --------------------------------------------------------------------------- #
#
# Frame layout (all little-endian):
#
#     +----------+---------+------------------------------+
#     | u32 size | u8 type | fields, in declaration order |
#     +----------+---------+------------------------------+
#     '--- size counts everything after the u32 ----------'
#
# Field encodings by spec code:
#     i   int            -> s64
#     f   float          -> f64 (exact round-trip)
#     b   bool           -> u8
#     s   str            -> u32 byte-length + UTF-8 bytes
#     ti  Tuple[int,...]   -> u32 count + s64 * count
#     tf  Tuple[float,...] -> u32 count + f64 * count
#     ts  Tuple[str,...]   -> u32 count + (u32 byte-length + UTF-8) * count
#     oi / of / oti      -> u8 presence flag + encoding of the value
#
# The encoding of a message is a pure function of its field values (no
# timestamps, no randomness, no interning), so equal messages encode to
# equal bytes — the property the determinism benchmarks rely on.

_U32 = struct.Struct("<I")
_S64 = struct.Struct("<q")
_F64 = struct.Struct("<d")
_U8 = struct.Struct("<B")

#: Per-type field spec: (field name, spec code) in wire order.  Kept explicit
#: (rather than introspected from annotations) so the wire format is frozen
#: even if dataclass defaults or typing idioms change.
_FIELD_SPECS: Dict[type, Tuple[Tuple[str, str], ...]] = {
    Hello: (("session", "i"), ("seq", "i"), ("version", "i")),
    Attach: (
        ("session", "i"), ("seq", "i"), ("version", "i"),
        ("accepted", "b"), ("reason", "s"),
    ),
    DraftFragment: (
        ("session", "i"), ("seq", "i"), ("round", "i"),
        ("tokens", "ti"), ("confs", "tf"), ("parents", "ti"),
    ),
    NavRequest: (
        ("session", "i"), ("seq", "i"), ("round", "i"),
        ("n_tokens", "i"), ("deadline", "of"), ("pos", "oi"),
    ),
    TreeNavRequest: (
        ("session", "i"), ("seq", "i"), ("round", "i"),
        ("n_tokens", "i"), ("deadline", "of"), ("pos", "oi"),
    ),
    NavResult: (
        ("session", "i"), ("seq", "i"), ("n_accepted", "i"),
        ("correction", "i"), ("n_drafted", "i"), ("path", "oti"),
    ),
    Reset: (("session", "i"), ("seq", "i"), ("round", "i"), ("position", "i")),
    Detach: (("session", "i"), ("seq", "i")),
    Heartbeat: (("session", "i"), ("seq", "i"), ("t_send", "f")),
    Route: (("session", "i"), ("seq", "i"), ("verifier", "i")),
    Migrate: (
        ("session", "i"), ("seq", "i"), ("src", "i"),
        ("dst", "i"), ("position", "i"),
    ),
    Drain: (("session", "i"), ("seq", "i"), ("verifier", "i")),
    TelemetryRequest: (("session", "i"), ("seq", "i")),
    TelemetrySnapshot: (
        ("session", "i"), ("seq", "i"), ("verifier", "i"), ("n_verifiers", "i"),
        ("t", "f"), ("sessions_active", "i"), ("queue_depth", "i"),
        ("nav_calls", "i"), ("tokens_verified", "i"), ("accepted_tokens", "i"),
        ("batched_calls", "i"), ("occupancy", "f"), ("verify_busy_time", "f"),
        ("kv_used_blocks", "i"), ("kv_free_blocks", "i"),
        ("kv_resident_bytes", "i"), ("kv_resident_sessions", "i"),
        ("kv_cap_hits", "i"), ("migrations", "i"), ("failovers", "i"),
        ("names", "ts"), ("values", "tf"),
    ),
}

_TYPE_IDS: Dict[type, int] = {cls: i for i, cls in enumerate(MESSAGE_TYPES, start=1)}
_ID_TYPES: Dict[int, type] = {i: cls for cls, i in _TYPE_IDS.items()}

# The spec table and the dataclasses must agree field-for-field; checked at
# import so a drifting message definition fails loudly, not as bad bytes.
for _cls, _spec in _FIELD_SPECS.items():
    _declared = tuple(f.name for f in fields(_cls))
    _specced = tuple(name for name, _ in _spec)
    if _declared != _specced:
        raise AssertionError(
            f"protocol spec drift for {_cls.__name__}: "
            f"dataclass fields {_declared} != wire spec {_specced}"
        )


def _pack_value(code: str, value, out: list) -> None:
    if code == "i":
        out.append(_S64.pack(value))
    elif code == "f":
        out.append(_F64.pack(value))
    elif code == "b":
        out.append(_U8.pack(1 if value else 0))
    elif code == "s":
        raw = value.encode("utf-8")
        out.append(_U32.pack(len(raw)))
        out.append(raw)
    elif code == "ti":
        out.append(_U32.pack(len(value)))
        out.append(struct.pack(f"<{len(value)}q", *value))
    elif code == "tf":
        out.append(_U32.pack(len(value)))
        out.append(struct.pack(f"<{len(value)}d", *value))
    elif code == "ts":
        out.append(_U32.pack(len(value)))
        for item in value:
            raw = item.encode("utf-8")
            out.append(_U32.pack(len(raw)))
            out.append(raw)
    elif code.startswith("o"):
        if value is None:
            out.append(_U8.pack(0))
        else:
            out.append(_U8.pack(1))
            _pack_value(code[1:], value, out)
    else:  # pragma: no cover - spec table is static
        raise ProtocolError(f"unknown field spec code {code!r}")


def _unpack_value(code: str, buf: bytes, off: int):
    if code == "i":
        return _S64.unpack_from(buf, off)[0], off + 8
    if code == "f":
        return _F64.unpack_from(buf, off)[0], off + 8
    if code == "b":
        return bool(_U8.unpack_from(buf, off)[0]), off + 1
    if code == "s":
        (n,) = _U32.unpack_from(buf, off)
        off += 4
        return buf[off:off + n].decode("utf-8"), off + n
    if code == "ti":
        (n,) = _U32.unpack_from(buf, off)
        off += 4
        return tuple(struct.unpack_from(f"<{n}q", buf, off)), off + 8 * n
    if code == "tf":
        (n,) = _U32.unpack_from(buf, off)
        off += 4
        return tuple(struct.unpack_from(f"<{n}d", buf, off)), off + 8 * n
    if code == "ts":
        (n,) = _U32.unpack_from(buf, off)
        off += 4
        items = []
        for _ in range(n):
            (m,) = _U32.unpack_from(buf, off)
            off += 4
            if off + m > len(buf):
                raise ProtocolError("truncated string tuple item")
            items.append(buf[off:off + m].decode("utf-8"))
            off += m
        return tuple(items), off
    if code.startswith("o"):
        present = _U8.unpack_from(buf, off)[0]
        off += 1
        if not present:
            return None, off
        return _unpack_value(code[1:], buf, off)
    raise ProtocolError(f"unknown field spec code {code!r}")  # pragma: no cover


def encode(msg: ProtocolMessage) -> bytes:
    """Serialize ``msg`` to one length-prefixed binary frame.

    Deterministic: equal messages produce equal bytes.  Raises
    :class:`ProtocolError` for objects that are not protocol messages.
    """
    spec = _FIELD_SPECS.get(type(msg))
    if spec is None:
        raise ProtocolError(f"not a protocol message: {type(msg).__name__}")
    out: list = [_U8.pack(_TYPE_IDS[type(msg)])]
    try:
        for name, code in spec:
            _pack_value(code, getattr(msg, name), out)
    except struct.error as e:
        raise ProtocolError(f"unencodable field on {type(msg).__name__}: {e}") from e
    body = b"".join(out)
    return _U32.pack(len(body)) + body


def decode(data: bytes) -> ProtocolMessage:
    """Parse one length-prefixed frame back into its typed message.

    The exact inverse of :func:`encode`: ``decode(encode(m)) == m`` for every
    message type.  Raises :class:`ProtocolError` on truncated frames, unknown
    type ids, or trailing bytes.
    """
    if len(data) < 5:
        raise ProtocolError(f"frame too short ({len(data)} bytes)")
    (size,) = _U32.unpack_from(data, 0)
    if len(data) != 4 + size:
        raise ProtocolError(f"frame length mismatch: header says {size}, have {len(data) - 4}")
    type_id = _U8.unpack_from(data, 4)[0]
    cls = _ID_TYPES.get(type_id)
    if cls is None:
        raise ProtocolError(f"unknown message type id {type_id}")
    off = 5
    kwargs = {}
    try:
        for name, code in _FIELD_SPECS[cls]:
            kwargs[name], off = _unpack_value(code, data, off)
    except (struct.error, UnicodeDecodeError) as e:
        raise ProtocolError(f"truncated/corrupt {cls.__name__} frame: {e}") from e
    if off != len(data):
        raise ProtocolError(f"{len(data) - off} trailing bytes after {cls.__name__}")
    return cls(**kwargs)


# --------------------------------------------------------------------------- #
# Version negotiation
# --------------------------------------------------------------------------- #


def handshake_reply(hello: Hello, session: Optional[int] = None) -> Attach:
    """The server's :class:`Attach` reply to a client :class:`Hello`.

    Accepts exactly the server's own :data:`PROTOCOL_VERSION`; anything else
    is rejected with a diagnostic ``reason`` (the transport closes the
    connection after delivering the rejection).  ``session`` overrides the
    client's proposed id (collision remapping); by default the proposal is
    accepted verbatim.
    """
    sid = hello.session if session is None else session
    if hello.version != PROTOCOL_VERSION:
        return Attach(
            session=sid,
            seq=hello.seq,
            version=PROTOCOL_VERSION,
            accepted=False,
            reason=(
                f"protocol version mismatch: client speaks v{hello.version}, "
                f"server speaks v{PROTOCOL_VERSION}"
            ),
        )
    return Attach(session=sid, seq=hello.seq, version=PROTOCOL_VERSION, accepted=True)
