"""Declarative link-fault injection for the cloud-edge runtime.

A :class:`FaultScenario` is a named, fully declarative description of how
the edge↔cloud link degrades over a run: per-direction time *phases* during
which messages are dropped, duplicated, reordered, the link bandwidth
collapses (Hockney β multiplier), or the link is hard-down (outage).  A
:class:`LinkFaults` instance compiles one direction of a scenario for one
channel and is consulted by ``Channel.send`` for every message; all random
decisions come from a dedicated seeded RNG, so under a ``VirtualClock`` a
scenario replays bit-identically from its seed.

Phase times are *virtual seconds relative to channel creation* and are
multiplied by the channel's ``time_scale``, matching how every other delay
in the transport scales.

Example::

    scen = FaultScenario(
        "burst_drop_then_outage",
        up=(Phase(0.5, 2.0, drop_prob=0.4),),
        dn=(Phase(3.0, 4.5, outage=True),),
    )
    up = Channel(cfg_up, clock=clock, faults=LinkFaults(scen, "up", seed=7))
    dn = Channel(cfg_dn, clock=clock, faults=LinkFaults(scen, "dn", seed=7))

The conformance contract (``tests/test_fault_conformance.py``): for every
scenario in :data:`FAULT_MATRIX` the accepted token stream is bit-identical
to the fault-free run — speculative decoding with an oracle-true verifier
is lossless, and the edge's local-decode fallback continues the same stream
offline — and two runs with the same seed produce identical RunStats.
"""

from __future__ import annotations

import math
import random
from dataclasses import dataclass, field
from typing import Optional, Tuple

__all__ = [
    "Phase",
    "FaultScenario",
    "LinkFaults",
    "ComposedLinkFaults",
    "legacy_link_faults",
    "FAULT_MATRIX",
    "scenario_by_name",
]


@dataclass(frozen=True)
class Phase:
    """One time window of link degradation on a single direction.

    ``start``/``end`` are in unscaled link-relative seconds.  Within the
    window each sent message is independently dropped with ``drop_prob``,
    duplicated with ``dup_prob`` (the copy re-traverses the link), delayed
    past later messages with ``reorder_prob`` (an extra ``reorder_jitter``
    seconds of out-of-band delay), and every delivery pays
    ``bandwidth_factor``× the per-token β cost.  ``outage=True`` drops
    everything in the window regardless of probabilities.
    """

    start: float
    end: float
    drop_prob: float = 0.0
    dup_prob: float = 0.0
    reorder_prob: float = 0.0
    reorder_jitter: float = 0.05
    bandwidth_factor: float = 1.0
    outage: bool = False


@dataclass(frozen=True)
class FaultScenario:
    """A named fault schedule: phases for the uplink and the downlink."""

    name: str
    up: Tuple[Phase, ...] = ()
    dn: Tuple[Phase, ...] = ()

    def phases(self, direction: str) -> Tuple[Phase, ...]:
        """The phase tuple for ``direction`` (``'up'`` or ``'dn'``)."""
        if direction not in ("up", "dn"):
            raise ValueError(f"direction must be 'up' or 'dn', got {direction!r}")
        return self.up if direction == "up" else self.dn

    def outage_windows(self, direction: str) -> Tuple[Tuple[float, float], ...]:
        """(start, end) of every hard-outage phase on ``direction``."""
        return tuple((p.start, p.end) for p in self.phases(direction) if p.outage)


class LinkFaults:
    """One direction of a :class:`FaultScenario`, compiled for one channel.

    Holds its own ``random.Random`` seeded from ``(scenario, direction,
    seed)`` so fault draws never perturb — and are never perturbed by —
    any other randomness in the run.
    """

    def __init__(
        self,
        scenario: FaultScenario,
        direction: str,
        seed: int = 0,
        time_scale: float = 1.0,
    ):
        self.scenario = scenario
        self.direction = direction
        self.time_scale = time_scale
        self._phases = scenario.phases(direction)
        self._rng = random.Random(f"{scenario.name}:{direction}:{seed}")
        self.stats = {"dropped": 0, "duplicated": 0, "reordered": 0}

    def _phase_at(self, t_rel: float) -> Optional[Phase]:
        ts = max(self.time_scale, 1e-12)
        for p in self._phases:
            if p.start * ts <= t_rel < p.end * ts:
                return p
        return None

    def beta_factor(self, t_rel: float) -> float:
        """Bandwidth multiplier on the per-token β cost at link time ``t_rel``."""
        p = self._phase_at(t_rel)
        return p.bandwidth_factor if p is not None else 1.0

    def dropped(self, t_rel: float) -> bool:
        """Whether the message entering the link at ``t_rel`` is lost."""
        p = self._phase_at(t_rel)
        if p is None:
            return False
        if p.outage or (p.drop_prob > 0 and self._rng.random() < p.drop_prob):
            self.stats["dropped"] += 1
            return True
        return False

    def duplicated(self, t_rel: float) -> bool:
        """Whether the message is delivered twice (a retransmitted copy)."""
        p = self._phase_at(t_rel)
        if p is not None and p.dup_prob > 0 and self._rng.random() < p.dup_prob:
            self.stats["duplicated"] += 1
            return True
        return False

    def reorder_delay(self, t_rel: float) -> float:
        """Extra out-of-band delivery delay [s]; >0 lets later messages pass."""
        p = self._phase_at(t_rel)
        if p is not None and p.reorder_prob > 0 and self._rng.random() < p.reorder_prob:
            self.stats["reordered"] += 1
            return p.reorder_jitter * max(self.time_scale, 1e-12) * (1.0 + self._rng.random())
        return 0.0


def legacy_link_faults(
    drop_prob: float,
    outage: Optional[Tuple[float, float]],
    seed: int,
    name: str,
) -> Optional["LinkFaults"]:
    """Compile the legacy ``ChannelConfig`` knobs into a :class:`LinkFaults`.

    ``drop_prob``/``outage`` predate the declarative fault layer; compiling
    them into a one-scenario phase schedule gives ``Channel`` a single fault
    path instead of two parallel ones.  The compiled instance reproduces the
    legacy semantics exactly:

    * phase times are *already-scaled* channel-relative seconds (the legacy
      knobs never multiplied by ``time_scale``), hence ``time_scale=1.0``;
    * the outage phase precedes the drop phase, so in-window sends are lost
      without consuming a random draw — the legacy check order;
    * the RNG is seeded from the historical ``channel:{seed}:{name}`` string,
      so seeded runs draw the identical loss sequence they always did.

    Returns ``None`` when neither knob is set (no fault layer at all).
    """
    phases = []
    if outage is not None:
        phases.append(Phase(float(outage[0]), float(outage[1]), outage=True))
    if drop_prob > 0:
        phases.append(Phase(0.0, math.inf, drop_prob=drop_prob))
    if not phases:
        return None
    scen = FaultScenario(f"legacy:{name}", up=tuple(phases))
    lf = LinkFaults(scen, "up", seed=seed, time_scale=1.0)
    lf._rng = random.Random(f"channel:{seed}:{name}")
    return lf


class ComposedLinkFaults:
    """Two fault layers on one channel, consulted in order.

    Used when a channel has BOTH an explicit :class:`LinkFaults` schedule and
    compiled legacy knobs: drop/duplicate checks short-circuit left to right
    (the second layer draws only for messages the first layer passes, exactly
    the historical check order), bandwidth factors multiply, and reorder
    delays add.
    """

    def __init__(self, first, second):
        self.first = first
        self.second = second

    @property
    def stats(self) -> dict:
        """Summed per-layer fault counters."""
        out = dict(self.first.stats)
        for k, v in self.second.stats.items():
            out[k] = out.get(k, 0) + v
        return out

    def beta_factor(self, t_rel: float) -> float:
        """Product of the layers' bandwidth multipliers at ``t_rel``."""
        return self.first.beta_factor(t_rel) * self.second.beta_factor(t_rel)

    def dropped(self, t_rel: float) -> bool:
        """Whether either layer loses the message (first layer checked first)."""
        return self.first.dropped(t_rel) or self.second.dropped(t_rel)

    def duplicated(self, t_rel: float) -> bool:
        """Whether either layer retransmits the message."""
        return self.first.duplicated(t_rel) or self.second.duplicated(t_rel)

    def reorder_delay(self, t_rel: float) -> float:
        """Summed out-of-band reorder delay across the layers."""
        return self.first.reorder_delay(t_rel) + self.second.reorder_delay(t_rel)


# --------------------------------------------------------------------------- #
# The scenario matrix: every named link condition the conformance suite and
# the chaos benchmark exercise.  Windows assume the conformance timebase
# (γ=0.02, window 8-16 → rounds of ~0.2-0.5 virtual seconds, runs of ~5-20 s).
# --------------------------------------------------------------------------- #

FAULT_MATRIX: Tuple[FaultScenario, ...] = (
    FaultScenario("clean"),
    # Random loss on one direction at a time: uplink loss starves the
    # verifier's draft buffers (parked NAV rounds), downlink loss eats
    # results after the work was done (stale-seq discard on the client).
    FaultScenario("up_drop", up=(Phase(0.0, 8.0, drop_prob=0.25),)),
    FaultScenario("dn_drop", dn=(Phase(0.0, 8.0, drop_prob=0.25),)),
    # Retransmission pathologies: duplicated and reordered draft batches and
    # NAV requests must not desync round buffers or double-commit KV.
    FaultScenario(
        "dup_reorder",
        up=(Phase(0.0, 10.0, dup_prob=0.3, reorder_prob=0.3, reorder_jitter=0.08),),
        dn=(Phase(0.0, 10.0, dup_prob=0.2),),
    ),
    # Bandwidth collapse ramp: β degrades 4× then 12× and recovers — NAV
    # round-trips stretch toward the timeout without ever hard-failing.
    FaultScenario(
        "bandwidth_ramp",
        up=(Phase(1.0, 3.0, bandwidth_factor=4.0), Phase(3.0, 5.0, bandwidth_factor=12.0)),
        dn=(Phase(1.0, 5.0, bandwidth_factor=4.0),),
    ),
    # Hard outage on the downlink: the verifier keeps verifying but results
    # never arrive → NAV timeout → local-decode fallback → re-attach.
    FaultScenario("dn_outage", dn=(Phase(0.8, 2.2, outage=True),)),
    # Full link down, twice: both directions out, back-to-back recoveries.
    FaultScenario(
        "double_outage",
        up=(Phase(0.8, 1.6, outage=True), Phase(3.0, 3.8, outage=True)),
        dn=(Phase(0.8, 1.6, outage=True), Phase(3.0, 3.8, outage=True)),
    ),
    # Everything at once: loss + duplication + reordering + a bandwidth
    # collapse + an outage window.
    FaultScenario(
        "flaky_everything",
        up=(
            Phase(0.0, 1.5, drop_prob=0.15, dup_prob=0.15, reorder_prob=0.2),
            Phase(1.5, 2.5, outage=True),
            Phase(2.5, 6.0, drop_prob=0.1, bandwidth_factor=6.0),
        ),
        dn=(
            Phase(0.0, 2.0, drop_prob=0.1, dup_prob=0.1),
            Phase(2.0, 3.0, bandwidth_factor=8.0),
        ),
    ),
)


def scenario_by_name(name: str) -> FaultScenario:
    """Look up a :data:`FAULT_MATRIX` scenario by its name."""
    for s in FAULT_MATRIX:
        if s.name == name:
            return s
    raise KeyError(f"unknown fault scenario {name!r}; have {[s.name for s in FAULT_MATRIX]}")
