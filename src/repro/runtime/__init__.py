from .simclock import SYSTEM_CLOCK, ActorHandle, SystemClock, VirtualClock
from .transport import Channel, ChannelConfig, Message, make_link
from .faults import FAULT_MATRIX, FaultScenario, LinkFaults, Phase, scenario_by_name
from .server import CloudVerifier, VerifyBackend, SyntheticBackend, SpecVerifyBackend
from .client import EdgeClient, EdgeConfig, SyntheticDraft
from .oracle import OracleBackend, OracleDraft, OracleStream

__all__ = [
    "ActorHandle",
    "Channel",
    "ChannelConfig",
    "CloudVerifier",
    "EdgeClient",
    "EdgeConfig",
    "FAULT_MATRIX",
    "FaultScenario",
    "LinkFaults",
    "Message",
    "OracleBackend",
    "OracleDraft",
    "OracleStream",
    "Phase",
    "SpecVerifyBackend",
    "SYSTEM_CLOCK",
    "SyntheticBackend",
    "SyntheticDraft",
    "SystemClock",
    "VerifyBackend",
    "VirtualClock",
    "make_link",
    "scenario_by_name",
]
