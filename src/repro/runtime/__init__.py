from .transport import Channel, ChannelConfig, Message
from .server import CloudVerifier, VerifyBackend, SyntheticBackend
from .client import EdgeClient, EdgeConfig, SyntheticDraft

__all__ = [
    "Channel",
    "ChannelConfig",
    "CloudVerifier",
    "EdgeClient",
    "EdgeConfig",
    "Message",
    "SyntheticBackend",
    "SyntheticDraft",
    "VerifyBackend",
]
