from .transport import Channel, ChannelConfig, Message
from .server import CloudVerifier, VerifyBackend, SyntheticBackend, SpecVerifyBackend
from .client import EdgeClient, EdgeConfig, SyntheticDraft

__all__ = [
    "Channel",
    "ChannelConfig",
    "CloudVerifier",
    "EdgeClient",
    "EdgeConfig",
    "Message",
    "SpecVerifyBackend",
    "SyntheticBackend",
    "SyntheticDraft",
    "VerifyBackend",
]
