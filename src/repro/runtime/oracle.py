"""Deterministic oracle target stream for fault-conformance testing.

The conformance suite needs a ground truth that makes "the fault run
produced the *right* tokens" a checkable, bit-exact statement.  These
components model PipeSD's offline-robustness setting faithfully:

* :class:`OracleStream` — the target model's greedy output: token at
  position ``p`` is a pure hash of ``(seed, p)``.  This is what a correct
  run must emit, faults or no faults.
* :class:`OracleDraft` — the edge draft model: at each position it proposes
  the oracle token with probability ``p_draft`` (high confidence) or a
  guaranteed-wrong token (low confidence).  The proposal is a pure function
  of the position, so redrafting after a failover replays identically.
  ``local_decode`` models the paper's offline mode — the edge pipeline runs
  the *full* model locally (slower, but the same greedy stream), so an
  outage never forks the output.
* :class:`OracleBackend` — the cloud verifier: stateless and *positional*
  (it consumes the round's start position carried by the typed
  ``protocol.NavRequest.pos`` field), it accepts the longest draft prefix
  matching the oracle and corrects with the true next token.  Because
  acceptance depends only on (position, token), no amount of message loss,
  duplication, reordering, or re-attachment can desynchronize it —
  corrupted rounds just accept less.

Together these give the lossless-speculative-decoding invariant the suite
asserts: **the accepted token stream equals ``OracleStream`` exactly, for
every fault scenario, bit-identical to the fault-free run.**
"""

from __future__ import annotations

from dataclasses import dataclass
from typing import List, Optional, Sequence, Tuple

from .server import VerifyBackend
from .simclock import SYSTEM_CLOCK

__all__ = ["OracleStream", "OracleDraft", "OracleBackend"]

_MASK64 = (1 << 64) - 1


def _mix(seed: int, pos: int, salt: int) -> int:
    """SplitMix64-style stable hash of (seed, pos, salt) — no PYTHONHASHSEED."""
    x = (seed * 0x9E3779B97F4A7C15 + pos * 0xBF58476D1CE4E5B9 + salt * 0x94D049BB133111EB) & _MASK64
    x ^= x >> 30
    x = (x * 0xBF58476D1CE4E5B9) & _MASK64
    x ^= x >> 27
    x = (x * 0x94D049BB133111EB) & _MASK64
    x ^= x >> 31
    return x


def _unit(seed: int, pos: int, salt: int) -> float:
    """Uniform [0, 1) draw, a pure function of (seed, pos, salt)."""
    return _mix(seed, pos, salt) / float(1 << 64)


@dataclass(frozen=True)
class OracleStream:
    """The target model's deterministic greedy token stream."""

    seed: int = 0
    vocab: int = 1 << 16

    def token(self, pos: int) -> int:
        """The unique correct token at position ``pos``."""
        return _mix(self.seed, pos, 1) % self.vocab

    def prefix(self, n: int) -> List[int]:
        """The first ``n`` tokens of the stream."""
        return [self.token(p) for p in range(n)]


class OracleDraft:
    """Edge draft model over an :class:`OracleStream` (seekable, replayable).

    Implements the ``EdgeClient`` draft protocol: ``next()`` proposes
    ``(token, confidence)`` and advances the position; ``seek(pos)`` rewinds
    to the client's committed position (called at round start and after
    verification); ``local_decode()`` emits the oracle token itself — the
    offline full-model fallback.
    """

    def __init__(self, seed: int = 0, p_draft: float = 0.8, vocab: int = 1 << 16):
        self.stream = OracleStream(seed, vocab)
        self.seed = seed
        self.p_draft = p_draft
        self.pos = 0

    def seek(self, pos: int) -> None:
        """Reset the draft position to the client's committed stream length."""
        self.pos = int(pos)

    def next(self) -> Tuple[int, float]:
        """Draft the next token: oracle-correct w.p. ``p_draft``, else wrong."""
        p = self.pos
        correct = _unit(self.seed, p, 2) < self.p_draft
        tok = self.stream.token(p)
        if correct:
            conf = 0.82 + 0.17 * _unit(self.seed, p, 3)
        else:
            tok = (tok + 1 + _mix(self.seed, p, 4) % (self.stream.vocab - 1)) % self.stream.vocab
            conf = 0.15 + 0.5 * _unit(self.seed, p, 5)
        self.pos = p + 1
        return int(tok), float(conf)

    def local_decode(self) -> int:
        """Offline fallback: the edge runs the full model → the oracle token."""
        tok = self.stream.token(self.pos)
        self.pos += 1
        return int(tok)


class OracleBackend(VerifyBackend):
    """Stateless positional verifier over an :class:`OracleStream`.

    The server passes ``(session, tokens, confs, pos)`` through
    ``verify_batch_pos`` (``pos`` rides ``protocol.NavRequest``), so
    verification is a pure function — immune to duplicated or replayed
    requests.  The
    simulated target-forward cost matches ``SyntheticBackend``: one padded
    pass per batch whose time scales with the longest draft.
    """

    #: Marks the positional protocol for ``CloudVerifier``.
    positional = True

    def __init__(
        self,
        seed: int = 0,
        verify_time: float = 0.080,
        verify_time_per_token: float = 0.004,
        time_scale: float = 1.0,
        clock=None,
        vocab: int = 1 << 16,
    ):
        self.stream = OracleStream(seed, vocab)
        self.verify_time = verify_time
        self.verify_time_per_token = verify_time_per_token
        self.time_scale = time_scale
        self.clock = clock or SYSTEM_CLOCK

    def _verify_one(self, tokens: Sequence[int], pos: int) -> Tuple[int, int]:
        n_acc = 0
        for i, t in enumerate(tokens):
            if int(t) != self.stream.token(pos + i):
                break
            n_acc += 1
        correction = self.stream.token(pos + n_acc)
        return n_acc, correction

    def verify(self, session: int, tokens: List[int], confs: List[float]):
        """Unsupported without a position — use the positional batch path."""
        raise NotImplementedError("OracleBackend is positional; use verify_batch_pos")

    def verify_batch_pos(
        self, requests: Sequence[Tuple[int, List[int], List[float], Optional[int]]]
    ):
        """One padded oracle pass: ``[(session, tokens, confs, pos)] -> [(n_acc, corr)]``."""
        if not requests:
            return []
        max_len = max(len(t) for (_, t, _, _) in requests)
        self.clock.sleep(
            (self.verify_time + self.verify_time_per_token * max_len) * self.time_scale
        )
        out = []
        for (_, tokens, _, pos) in requests:
            if pos is None:
                raise ValueError("OracleBackend needs the NAV request to carry 'pos'")
            out.append(self._verify_one(tokens, int(pos)))
        return out
