"""Edge client: drafting + transmission control + failover (§4.2, DESIGN §6).

Runs the full PipeSD edge stack against a live ``CloudVerifier``:
* drafts tokens (pluggable: ``SyntheticDraft``, ``runtime.oracle.OracleDraft``,
  or a real tiny JAX model);
* dual-threshold NAV triggering (core.trigger) with window cap;
* token-batch pipeline transmission from the DP schedule (core.scheduler);
* environment monitor feeding the parameter updater (δ-rules, App. D);
* **failover**: if a NAV result misses its deadline the client falls back to
  local autoregressive decoding (the paper's offline-robustness mode), keeps
  generating, and re-probes the cloud with exponential backoff; the re-probe
  carries the client's committed stream position so the verifier can
  reconcile its paged-KV state (re-attach);
* **tree speculation** (``variant='tree'``): top-k branching draft trees with
  per-path dual-threshold pruning, shipped level-by-level with packed
  parents and verified by the server's batched tree-NAV path.

All timing goes through the clock inherited from the uplink channel (or an
explicit ``clock=``): ``SystemClock`` for wall-clock serving, ``VirtualClock``
for deterministic discrete-event runs (``runtime.simclock``).

Beyond counters, the client records the actual **accepted token stream**
(``self.tokens``: accepted drafts + corrections + local-decode fallback, in
commit order) — the quantity the fault-conformance suite asserts is
bit-identical with and without link faults.

Draft-model protocol: ``next() -> (token, conf)`` is required; ``seek(pos)``
(rewind to the committed stream position — called at round start and before
fallback) and ``local_decode() -> token`` (offline full-model fallback) are
optional and default to the stateless legacy behaviour.
"""

from __future__ import annotations

from dataclasses import dataclass, replace
from typing import Any, Callable, Dict, List, Optional, Tuple

import numpy as np

from repro.core.monitor import EnvironmentMonitor
from repro.core.scheduler import CommParams, batch_sizes, dp_schedule
from repro.core.trigger import make_trigger
from repro.obs.trace import NULL_TRACER
from .protocol import (
    DraftFragment,
    Migrate,
    NavRequest,
    NavResult,
    Reset,
    Route,
    TreeNavRequest,
)
from .simclock import SYSTEM_CLOCK
from .transport import Transport

__all__ = ["EdgeConfig", "SyntheticDraft", "EdgeClient"]


@dataclass
class EdgeConfig:
    window: int = 16
    r1: float = 0.9
    r2: float = 0.6
    gamma: float = 0.020  # per-token draft time [s] (scaled)
    # Offline full-model decode time per token [s]; None = gamma (legacy).
    # The paper's offline mode runs the whole pipeline on the edge, so real
    # deployments set this several times gamma.
    local_gamma: Optional[float] = None
    time_scale: float = 1.0
    nav_timeout: float = 2.0  # seconds before failover
    backoff_init: float = 0.5
    backoff_max: float = 8.0
    # Tree speculation: variant='tree' drafts a top-k branching token tree
    # (width children per expanded node, up to tree_depth levels, `window`
    # acting as the node budget) and requests tree-NAV from the verifier.
    variant: str = "chain"  # 'chain' | 'tree'
    tree_width: int = 2
    tree_depth: int = 8


@dataclass
class SyntheticDraft:
    """Synthetic draft model: emits (token, confidence) with dialect stats.

    ``p_hard_schedule`` makes the stream drift deterministically: each
    ``(from_nth_draft, p_hard)`` step raises/lowers the hard-token mix
    once that many tokens have been drafted — the workload analogue of a
    prompt moving from boilerplate into hard reasoning, which is what the
    adaptive policy benchmarks use to force a mid-run mode switch.
    """

    seed: int = 0
    p_hard: float = 0.15
    p_hard_schedule: Optional[Tuple[Tuple[int, float], ...]] = None

    def __post_init__(self) -> None:
        self._rng = np.random.default_rng(self.seed)
        self._count = 0

    def next(self) -> Tuple[int, float]:
        p = self.p_hard
        if self.p_hard_schedule:
            for start, ph in self.p_hard_schedule:
                if self._count >= start:
                    p = ph
        self._count += 1
        hard = self._rng.random() < p
        conf = float(self._rng.beta(2.5, 2.5) if hard else self._rng.beta(150, 1))
        return int(self._rng.integers(0, 1 << 16)), conf


class EdgeClient:
    def __init__(
        self,
        session: int,
        uplink: Transport,
        downlink: Transport,
        cfg: EdgeConfig,
        draft=None,
        clock=None,
        reconnect: Optional[Callable[[], Any]] = None,
        policy=None,  # Optional[core.policy.AdaptivePolicyController]
        tracer=None,
    ):
        self.session = session
        self.up = uplink
        self.dn = downlink
        # Span tracing (repro.obs.trace): draft/upload/commit stages per
        # round; the shared NULL_TRACER makes instrumentation free when off.
        self.tracer = tracer if tracer is not None else NULL_TRACER
        # An adaptive policy mutates its client's config per round (variant,
        # thresholds, window), so give this client a private copy.
        self.policy = policy
        self.cfg = replace(cfg) if policy is not None else cfg
        # Optional re-dial hook: called when the links are permanently closed
        # (router/verifier gone) before a cloud re-probe.  Returns a duplex
        # transport or an (uplink, downlink) pair to a live control plane.
        self.reconnect = reconnect
        self.clock = clock or getattr(uplink, "clock", None) or SYSTEM_CLOCK
        self.draft = draft or SyntheticDraft(seed=session)
        self.trigger = make_trigger("dual", r1=cfg.r1, r2=cfg.r2, window=cfg.window)
        self.monitor = EnvironmentMonitor()
        self.seq = 0
        self.round = 0  # NAV round id — keys the server's per-round buffers
        # The committed output stream: accepted drafts + corrections +
        # locally-decoded fallback tokens, in commit order.
        self.tokens: List[int] = []
        self.stats: Dict[str, Any] = {
            "accepted_tokens": 0,
            "drafted_tokens": 0,
            "nav_calls": 0,
            "rounds": 0,
            "fallback_tokens": 0,
            "failovers": 0,
            "wall_time": 0.0,
            # Per-round NAV round-trip latencies [s, clock time] — the serving
            # benchmarks reduce these to p50/p99 (core.pipeline.RunStats).
            "nav_latencies": [],
            # Fault-recovery accounting (chaos benchmarks): run-relative times
            # of each failover, of each first-NAV-success after an offline
            # spell, and drafted tokens whose round had to be abandoned.
            "failover_times": [],
            "recovery_times": [],
            "recovery_latencies": [],
            "lost_draft_tokens": 0,
            # Control-plane observability (multi-verifier router): how often
            # this session was (re)placed or live-migrated, and re-dials.
            "routes_seen": 0,
            "migrations_seen": 0,
            "reattaches": 0,
            # Energy accounting inputs (core.pipeline.EdgeModel.edge_energy):
            # unscaled model seconds spent draft-decoding (incl. offline local
            # decode) and transmitting draft batches on the uplink radio.
            "draft_time_s": 0.0,
            "tx_time_s": 0.0,
            # Adaptive policy observability (filled on exit when attached).
            "policy_mode_switches": 0,
            "policy_retunes": 0,
        }
        self._policy_resync = False

    # ------------------------------------------------------------- drafting --
    def _seek_draft(self) -> None:
        """Align a positional draft model with the committed stream length."""
        if hasattr(self.draft, "seek"):
            self.draft.seek(len(self.tokens))

    def _draft_round(self) -> Tuple[List[int], List[float]]:
        tokens, confs = [], []
        plan = dp_schedule(
            self.cfg.window,
            CommParams(self.up.cfg.alpha, self.up.cfg.beta, self.cfg.gamma),
        )
        sizes = batch_sizes(plan.boundaries, self.cfg.window)
        sent = 0
        bi = 0
        pending: List[Tuple[int, float]] = []
        for _ in range(self.cfg.window):
            self.clock.sleep(self.cfg.gamma * self.cfg.time_scale)  # generation cost
            tok, conf = self.draft.next()
            tokens.append(tok)
            confs.append(conf)
            pending.append((tok, conf))
            fired = self.trigger.observe(conf)
            # Transmit per the DP plan; on trigger flush everything (§3.3 r.1).
            flush = fired or (bi < len(sizes) and len(pending) >= sizes[bi])
            if flush and pending:
                self._send_batch(pending)
                pending = []
                bi += 1
            if fired:
                break
        if pending:
            self._send_batch(pending)
        self.stats["drafted_tokens"] += len(tokens)
        self.stats["draft_time_s"] += self.cfg.gamma * len(tokens)
        self.monitor.observe_gamma(self.cfg.gamma)
        if self.policy is not None:
            self.policy.observe_gamma(self.cfg.gamma)
        return tokens, confs

    def _draft_round_tree(self) -> Tuple[List[int], List[float], List[int]]:
        """Draft a top-k token tree under the per-path dual threshold.

        Level by level: each frontier node spawns ``tree_width`` children (one
        draft forward per EXPANDED node → γ per expansion, not per node);
        a child with conf ≤ R2 is pruned, and a path whose cumulative C1
        drops to R1 keeps its node but stops expanding — the per-path
        analogue of the chain trigger firing.  Each level's nodes ship as one
        draft_batch carrying packed parents, so uploads overlap the next
        level's expansion exactly as the chain path pipelines batches.
        """
        tokens: List[int] = []
        confs: List[float] = []
        parents: List[int] = []
        frontier: List[Tuple[int, float]] = [(-1, 1.0)]  # (node idx, path C1)
        budget = self.cfg.window
        for _ in range(self.cfg.tree_depth):
            self.clock.sleep(self.cfg.gamma * len(frontier) * self.cfg.time_scale)
            self.stats["draft_time_s"] += self.cfg.gamma * len(frontier)
            level_start = len(tokens)
            nxt: List[Tuple[int, float]] = []
            for pidx, pconf in frontier:
                for _w in range(self.cfg.tree_width):
                    tok, conf = self.draft.next()
                    # R2 prune: hard tokens never enter the tree — except the
                    # very first node, so a round always ships ≥ 1 draft.
                    if conf <= self.cfg.r2 and tokens:
                        continue
                    if len(tokens) >= budget:
                        break
                    idx = len(tokens)
                    tokens.append(tok)
                    confs.append(conf)
                    parents.append(pidx)
                    cp = pconf * conf
                    if cp > self.cfg.r1:
                        nxt.append((idx, cp))
            if len(tokens) > level_start:
                self._send_batch(
                    list(zip(tokens[level_start:], confs[level_start:])),
                    parents=parents[level_start:],
                )
            frontier = nxt
            if not frontier or len(tokens) >= budget:
                break
        self.stats["drafted_tokens"] += len(tokens)
        self.monitor.observe_gamma(self.cfg.gamma)
        if self.policy is not None:
            self.policy.observe_gamma(self.cfg.gamma)
        return tokens, confs, parents

    def _send_batch(self, pending: List[Tuple[int, float]], parents: Optional[List[int]] = None) -> None:
        toks = [t for t, _ in pending]
        cfs = [c for _, c in pending]
        self.seq += 1
        t_send = self.clock.monotonic() if self.tracer.enabled else 0.0
        link_cost = self.up.send(
            DraftFragment(
                session=self.session,
                seq=self.seq,
                round=self.round,
                tokens=tuple(toks),
                confs=tuple(cfs),
                parents=tuple(parents) if parents is not None else (),
            )
        )
        if self.tracer.enabled:
            # The upload span covers the link's estimated occupancy window —
            # pipelined uploads overlapping later drafting is the §3.2 win
            # the bubble analyzer measures.
            self.tracer.add(
                "upload", t_send, t_send + (link_cost or 0.0),
                session=self.session, round=self.round, tokens=len(toks),
            )
        cost = self.up.cfg.alpha + self.up.cfg.beta * len(toks)
        self.monitor.observe_batch(len(toks), cost)
        self.stats["tx_time_s"] += cost
        if self.policy is not None:
            self.policy.observe_link(len(toks), cost)

    # ----------------------------------------------------------- fallback --
    def _local_decode_one(self) -> int:
        """One offline token: full-model local decode when the draft supports
        it, otherwise the legacy draft-as-fallback behaviour."""
        if hasattr(self.draft, "local_decode"):
            return int(self.draft.local_decode())
        return int(self.draft.next()[0])

    def _commit(self, toks: List[int]) -> None:
        self.tokens.extend(int(t) for t in toks)
        self.stats["accepted_tokens"] += len(toks)

    # --------------------------------------------------------------- policy --
    def _apply_policy(self, decision) -> None:
        """Retarget the live config/trigger to a PolicyDecision (not 'local')."""
        cfg = self.cfg
        if decision.mode in ("chain", "tree"):
            cfg.variant = decision.mode
        cfg.r1, cfg.r2 = decision.r1, decision.r2
        cfg.tree_width, cfg.tree_depth = decision.tree_width, decision.tree_depth
        cfg.window = decision.window
        trig = self.trigger
        if hasattr(trig, "set_window"):
            trig.set_window(decision.window)
        inner = getattr(trig, "inner", trig)
        if hasattr(inner, "set_thresholds"):
            inner.set_thresholds(decision.r1, decision.r2)

    def _policy_local_block(self, n_tokens: int) -> None:
        """Policy-forced local-only round: decode up to one window offline."""
        self._seek_draft()
        local_gamma = self.cfg.local_gamma if self.cfg.local_gamma is not None else self.cfg.gamma
        for _ in range(max(self.cfg.window, 1)):
            if self.stats["accepted_tokens"] >= n_tokens:
                break
            self.clock.sleep(local_gamma * self.cfg.time_scale)
            self.stats["draft_time_s"] += local_gamma
            self._commit([self._local_decode_one()])
            self.stats["fallback_tokens"] += 1
        # The verifier's KV fork is now behind: re-sync before the next NAV.
        self._policy_resync = True

    # ---------------------------------------------------------------- runs --
    def run(self, n_tokens: int) -> dict:
        """Generate until n_tokens accepted; returns stats (incl. failovers)."""
        t0 = self.clock.monotonic()
        backoff = self.cfg.backoff_init
        cloud_ok = True
        offline_since: Optional[float] = None
        while self.stats["accepted_tokens"] < n_tokens:
            if not cloud_ok:
                # Offline mode: local autoregressive decoding (no NAV).
                self._seek_draft()
                deadline = self.clock.monotonic() + backoff * self.cfg.time_scale * 10
                local_gamma = (
                    self.cfg.local_gamma
                    if self.cfg.local_gamma is not None
                    else self.cfg.gamma
                )
                while (
                    self.clock.monotonic() < deadline
                    and self.stats["accepted_tokens"] < n_tokens
                ):
                    self.clock.sleep(local_gamma * self.cfg.time_scale)
                    self.stats["draft_time_s"] += local_gamma
                    self._commit([self._local_decode_one()])
                    self.stats["fallback_tokens"] += 1
                # Re-probe the cloud, announcing our committed position so the
                # verifier reconciles its KV fork (re-attach).  A permanently
                # closed link first re-dials through the reconnect hook — the
                # re-attach-to-new-verifier path when a router/verifier died.
                if self.reconnect is not None and (
                    getattr(self.up, "closed", False)
                    or getattr(self.dn, "closed", False)
                ):
                    link = self.reconnect()
                    self.up, self.dn = link if isinstance(link, tuple) else (link, link)
                    self.stats["reattaches"] += 1
                self.seq += 1
                self.up.send(
                    Reset(
                        session=self.session,
                        seq=self.seq,
                        round=self.round,
                        position=len(self.tokens),
                    )
                )
                cloud_ok = True  # optimistic; next round will confirm
                backoff = min(backoff * 2, self.cfg.backoff_max)
                continue
            if self.policy is not None:
                decision = self.policy.decide()
                if decision.mode == "local":
                    self._policy_local_block(n_tokens)
                    continue
                self._apply_policy(decision)
                if self._policy_resync:
                    self.seq += 1
                    self.up.send(
                        Reset(
                            session=self.session,
                            seq=self.seq,
                            round=self.round,
                            position=len(self.tokens),
                        )
                    )
                    self._policy_resync = False
            t_round = self.clock.monotonic()
            self.round += 1
            self._seek_draft()
            tree_mode = self.cfg.variant == "tree"
            with self.tracer.span("draft", session=self.session, round=self.round):
                if tree_mode:
                    tokens, confs, _parents = self._draft_round_tree()
                else:
                    tokens, confs = self._draft_round()
            self.seq += 1
            timeout = self.cfg.nav_timeout * max(self.cfg.time_scale, 0.05)
            t_req = self.clock.monotonic()
            # The deadline rides with the request: once it passes, this client
            # has failed over, so the server drops the work (straggler drop).
            # ``pos`` is the stream position of the round's first draft —
            # positional (oracle) backends verify against it statelessly.
            req_cls = TreeNavRequest if tree_mode else NavRequest
            self.up.send(
                req_cls(
                    session=self.session,
                    seq=self.seq,
                    round=self.round,
                    n_tokens=len(tokens),
                    deadline=t_req + timeout,
                    pos=len(self.tokens),
                )
            )
            self.stats["nav_calls"] += 1
            result = self.dn.recv(timeout=timeout)
            while result is not None and (
                not isinstance(result, NavResult) or result.seq != self.seq
            ):
                # Stale reply from a round we already failed over (or a
                # non-result control message) — discard.  Router placement /
                # migration announcements are counted on the way through.
                if isinstance(result, Route):
                    self.stats["routes_seen"] += 1
                elif isinstance(result, Migrate):
                    self.stats["migrations_seen"] += 1
                rem = t_req + timeout - self.clock.monotonic()
                result = self.dn.recv(timeout=rem) if rem > 0 else None
            if result is None or not isinstance(result, NavResult):
                # NAV lost/late → failover to local decode
                self.stats["failovers"] += 1
                self.stats["lost_draft_tokens"] += len(tokens)
                now = self.clock.monotonic()
                self.stats["failover_times"].append(now - t0)
                self.monitor.observe_failover(now - t0)
                if offline_since is None:
                    offline_since = now
                cloud_ok = False
                self.trigger.reset()
                if self.policy is not None:
                    self.policy.observe_round(len(tokens), 0, failover=True)
                continue
            now = self.clock.monotonic()
            self.stats["nav_latencies"].append(now - t_req)
            if offline_since is not None:
                # First verified round after an offline spell: recovered.
                self.stats["recovery_times"].append(now - t0)
                self.stats["recovery_latencies"].append(now - offline_since)
                self.monitor.observe_recovery(now - offline_since)
                offline_since = None
            backoff = self.cfg.backoff_init
            n_acc = result.n_accepted
            with self.tracer.span(
                "commit", session=self.session, round=self.round, n_accepted=n_acc
            ):
                if result.path is not None:  # tree round: the accepted root→leaf path
                    self._commit([tokens[i] for i in result.path])
                else:
                    self._commit(tokens[:n_acc])
                self._commit([result.correction])
            self.stats["rounds"] += 1
            self.trigger.on_verify(n_acc, len(tokens))
            if self.policy is not None:
                # Per-token round time in unscaled model seconds (δ₁ signal).
                round_s = (now - t_round) / max(self.cfg.time_scale, 1e-9)
                self.policy.observe_round(
                    len(tokens), n_acc, tpt=round_s / max(n_acc + 1, 1)
                )
        self.stats["wall_time"] = self.clock.monotonic() - t0
        if self.policy is not None:
            self.stats["policy_mode_switches"] = self.policy.mode_switches
            self.stats["policy_retunes"] = self.policy.retunes
        return dict(self.stats)
