"""Cloud-edge transport with Hockney-model latency and failure injection.

``Channel`` carries ``Message``s between threads with a simulated delivery
delay of ``(α + β·n_tokens) × time_scale`` — the same model the paper
measures (Fig. 6a) — so the threaded runtime reproduces the timing behaviour
of the FastAPI deployment at any speed (``time_scale`` ≪ 1 for tests).
Failure injection (drop probability, outage windows) drives the
fault-tolerance paths: NAV timeout → local-decode fallback → re-attach.
"""

from __future__ import annotations

import heapq
import itertools
import threading
import time
from dataclasses import dataclass, field
from typing import Any, Optional, Tuple

__all__ = ["ChannelConfig", "Message", "Channel"]


@dataclass(frozen=True)
class Message:
    kind: str  # 'draft_batch' | 'nav_request' | 'nav_result' | 'hello' | ...
    session: int
    seq: int
    n_tokens: int
    payload: Any


@dataclass
class ChannelConfig:
    alpha: float = 0.020  # startup overhead [s]
    beta: float = 0.002  # per-token serialization [s]
    time_scale: float = 1.0  # multiply all delays (tests use e.g. 0.01)
    drop_prob: float = 0.0  # random loss (failure injection)
    outage: Optional[Tuple[float, float]] = None  # (start, end) relative secs


class Channel:
    """One direction of the link; delivery is delayed per the Hockney model.

    A dedicated dispatcher thread releases messages at their delivery time, so
    transmission of consecutive batches serializes exactly like a real link
    (the next batch's delivery time starts after the previous one's).
    """

    def __init__(self, cfg: ChannelConfig, name: str = "ch"):
        self.cfg = cfg
        self.name = name
        self._heap: list = []
        self._counter = itertools.count()
        self._cv = threading.Condition()
        self._t0 = time.monotonic()
        self._link_free = 0.0  # relative time the link frees up
        self._closed = False

    # ------------------------------------------------------------- sending --
    def send(self, msg: Message) -> float:
        """Enqueue; returns the simulated delivery delay (for diagnostics)."""
        now = time.monotonic() - self._t0
        cost = (self.cfg.alpha + self.cfg.beta * msg.n_tokens) * self.cfg.time_scale
        with self._cv:
            start = max(now, self._link_free)
            deliver_at = start + cost
            self._link_free = deliver_at
            if self._dropped(start):
                self._cv.notify_all()
                return cost  # silently lost — receiver will time out
            heapq.heappush(self._heap, (deliver_at, next(self._counter), msg))
            self._cv.notify_all()
        return cost

    def _dropped(self, t_rel: float) -> bool:
        import random

        if self.cfg.outage is not None and self.cfg.outage[0] <= t_rel < self.cfg.outage[1]:
            return True
        return self.cfg.drop_prob > 0 and random.random() < self.cfg.drop_prob

    # ----------------------------------------------------------- receiving --
    def recv(self, timeout: Optional[float] = None) -> Optional[Message]:
        """Blocking receive honoring delivery times; None on timeout/close."""
        deadline = None if timeout is None else time.monotonic() + timeout
        with self._cv:
            while True:
                now = time.monotonic() - self._t0
                if self._heap and self._heap[0][0] <= now:
                    return heapq.heappop(self._heap)[2]
                if self._closed:
                    return None
                wait = None
                if self._heap:
                    wait = self._heap[0][0] - now
                if deadline is not None:
                    rem = deadline - time.monotonic()
                    if rem <= 0:
                        return None
                    wait = rem if wait is None else min(wait, rem)
                self._cv.wait(timeout=wait if wait is None or wait > 0 else 0.001)

    def qsize(self) -> int:
        """Messages in flight or awaiting pickup (for load/occupancy stats)."""
        with self._cv:
            return len(self._heap)

    def close(self) -> None:
        with self._cv:
            self._closed = True
            self._cv.notify_all()


def make_link(up_cfg: ChannelConfig, dn_cfg: ChannelConfig) -> Tuple[Channel, Channel]:
    """(uplink edge→cloud, downlink cloud→edge)."""
    return Channel(up_cfg, "up"), Channel(dn_cfg, "dn")
