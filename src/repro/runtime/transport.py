"""Pluggable cloud-edge transports carrying the typed wire protocol.

The runtime speaks :mod:`runtime.protocol` messages through a small
:class:`Transport` interface with two backends:

* :class:`InProcTransport` (= :class:`Channel`) — the simulated link: typed
  message *objects* are delivered with a Hockney-model delay of
  ``(α + β·wire_tokens(msg)) × time_scale`` (the model the paper measures,
  Fig. 6a), on either the wall clock or the deterministic ``VirtualClock``.
  Fault injection (``runtime.faults``) acts here, *below* the codec, on
  whole messages — the conformance suite is byte-independent of the codec.
* :class:`SocketTransport` — a real length-prefixed-frame TCP link between
  OS processes: ``protocol.encode``/``decode`` are the wire format, and a
  :class:`SocketListener` accepts connections with the ``Hello``/``Attach``
  version handshake, so ``CloudVerifier`` and ``EdgeClient`` deploy as
  genuinely separate processes like the paper's FastAPI testbed.

Fault injection on ``Channel`` has a single path: a pluggable ``faults``
hook (``runtime.faults.LinkFaults``) compiled from a declarative
``FaultScenario``.  The legacy ``ChannelConfig`` knobs (``drop_prob``, one
``outage`` window) are compiled into the same machinery at construction
(``faults.legacy_link_faults``), preserving their exact historical
semantics and seeded loss draws.  Both drive the fault-tolerance paths:
NAV timeout → local-decode fallback → re-attach.
"""

from __future__ import annotations

import dataclasses
import heapq
import itertools
import socket
import threading
from dataclasses import dataclass
from typing import Any, Callable, Deque, List, Optional, Tuple

from collections import deque

from repro.obs.trace import NULL_TRACER

from .faults import ComposedLinkFaults, legacy_link_faults
from .protocol import (
    PROTOCOL_VERSION,
    Attach,
    Hello,
    NavRequest,
    ProtocolError,
    ProtocolMessage,
    decode,
    encode,
    handshake_reply,
    wire_tokens,
)
from .simclock import SYSTEM_CLOCK

__all__ = [
    "ChannelConfig",
    "Transport",
    "Channel",
    "InProcTransport",
    "SocketTransport",
    "SocketListener",
    "connect_transport",
    "make_link",
]


@dataclass
class ChannelConfig:
    """Link parameters: Hockney cost model plus (legacy) fault knobs.

    ``alpha``/``beta`` also serve as *link hints* for scheduling (the DP
    batch planner reads them off the transport), so socket transports carry
    a config too even though their delivery time is the real network's.
    ``drop_prob``/``outage`` are compiled into the declarative fault layer
    at channel construction — see ``faults.legacy_link_faults``.
    """

    alpha: float = 0.020  # startup overhead [s]
    beta: float = 0.002  # per-token serialization [s]
    time_scale: float = 1.0  # multiply all delays (wall-clock tests use e.g. 0.01)
    drop_prob: float = 0.0  # legacy random loss (compiled to a fault phase)
    outage: Optional[Tuple[float, float]] = None  # legacy hard-down window
    seed: int = 0  # seeds the channel's private loss RNG


class Transport:
    """One direction (or one duplex link) carrying typed protocol messages.

    The surface the runtime codes against: blocking/timed ``recv``,
    fire-and-forget ``send`` returning a cost estimate, ``qsize`` for
    backlog stats, and ``close``.  Implementations expose ``cfg``
    (:class:`ChannelConfig` link hints), ``clock`` (the timing surface
    messages and timeouts run on), and a ``closed`` flag — True once the
    link is permanently gone, so receive loops can exit instead of polling
    a dead transport.
    """

    cfg: ChannelConfig
    clock: Any  # simclock surface (SystemClock / VirtualClock)
    closed: bool = False

    def send(self, msg: ProtocolMessage) -> float:
        """Enqueue ``msg`` for delivery; returns an estimated link cost [s]."""
        raise NotImplementedError  # pragma: no cover

    def recv(self, timeout: Optional[float] = None) -> Optional[ProtocolMessage]:
        """Blocking receive; ``None`` on timeout or transport close."""
        raise NotImplementedError  # pragma: no cover

    def qsize(self) -> int:
        """Messages in flight or awaiting pickup (for load/occupancy stats)."""
        raise NotImplementedError  # pragma: no cover

    def close(self) -> None:
        """Release the link; pending and future ``recv`` calls return None."""
        raise NotImplementedError  # pragma: no cover


class Channel(Transport):
    """In-process transport; delivery is delayed per the Hockney model.

    A dedicated dispatcher is unnecessary: delivery times live in an event
    heap keyed on the channel's clock, and ``recv`` waits (on virtual or
    wall time) until the head message's delivery time arrives.  Transmission
    of consecutive batches serializes exactly like a real link — the next
    batch's delivery time starts after the previous one frees the link —
    except for fault-injected *reordered* messages, which take an
    out-of-band path (extra delay, no link occupancy).
    """

    def __init__(
        self,
        cfg: ChannelConfig,
        name: str = "ch",
        clock=None,
        faults=None,
    ):
        self.cfg = cfg
        self.name = name
        self.clock = clock or SYSTEM_CLOCK
        # Single fault path: legacy ChannelConfig knobs compile into the same
        # declarative machinery as explicit FaultScenario schedules.
        legacy = legacy_link_faults(cfg.drop_prob, cfg.outage, cfg.seed, name)
        if faults is not None and legacy is not None:
            self.faults = ComposedLinkFaults(faults, legacy)
        else:
            self.faults = faults if faults is not None else legacy
        self._heap: list = []
        self._counter = itertools.count()
        self._cv = self.clock.condition()
        self._t0 = self.clock.monotonic()
        self._link_free = 0.0  # relative time the link frees up
        self.closed = False
        self.stats = {"sent": 0, "dropped": 0, "duplicated": 0, "reordered": 0}

    # ------------------------------------------------------------- sending --
    def send(self, msg: ProtocolMessage) -> float:
        """Enqueue; returns the simulated delivery delay (for diagnostics)."""
        now = self.clock.monotonic() - self._t0
        n_tokens = wire_tokens(msg)
        beta = self.cfg.beta
        if self.faults is not None:
            beta *= self.faults.beta_factor(now)
        cost = (self.cfg.alpha + beta * n_tokens) * self.cfg.time_scale
        with self._cv:
            self.stats["sent"] += 1
            start = max(now, self._link_free)
            deliver_at = start + cost
            self._link_free = deliver_at
            if self.faults is not None and self.faults.dropped(start):
                self.stats["dropped"] += 1
                self._cv.notify_all()
                return cost  # silently lost — receiver will time out
            extra = self.faults.reorder_delay(start) if self.faults is not None else 0.0
            if extra > 0.0:
                self.stats["reordered"] += 1
                # Out-of-band path: delayed past the link-serialized slot so
                # later messages can overtake it.
                deliver_at += extra
            heapq.heappush(self._heap, (deliver_at, next(self._counter), msg))
            if self.faults is not None and self.faults.duplicated(start):
                self.stats["duplicated"] += 1
                # The retransmitted copy re-traverses the link right behind
                # the original.
                dup_at = deliver_at + cost
                self._link_free = max(self._link_free, dup_at)
                heapq.heappush(self._heap, (dup_at, next(self._counter), msg))
            self._cv.notify_all()
        return cost

    # ----------------------------------------------------------- receiving --
    def recv(self, timeout: Optional[float] = None) -> Optional[ProtocolMessage]:
        """Blocking receive honoring delivery times; None on timeout/close."""
        deadline = None if timeout is None else self.clock.monotonic() + timeout
        with self._cv:
            while True:
                now = self.clock.monotonic() - self._t0
                # The 1ns slack absorbs float rounding between channels with
                # different time origins (a mid-run channel forwarding to a
                # t0=0 one can land a delivery time sub-ulp above ``now``,
                # which a virtual clock could otherwise never advance past).
                if self._heap and self._heap[0][0] <= now + 1e-9:
                    return heapq.heappop(self._heap)[2]
                if self.closed:
                    return None
                wait = None
                if self._heap:
                    wait = self._heap[0][0] - now
                if deadline is not None:
                    rem = deadline - self.clock.monotonic()
                    if rem <= 0:
                        return None
                    wait = rem if wait is None else min(wait, rem)
                self._cv.wait(timeout=wait if wait is None or wait > 0 else 0.001)

    def qsize(self) -> int:
        """Messages in flight or awaiting pickup (for load/occupancy stats)."""
        with self._cv:
            return len(self._heap)

    def close(self) -> None:
        """Close the link; blocked and future ``recv`` calls return None."""
        with self._cv:
            self.closed = True
            self._cv.notify_all()


#: The in-process backend under its interface name (``Channel`` predates it).
InProcTransport = Channel


def make_link(up_cfg: ChannelConfig, dn_cfg: ChannelConfig, clock=None) -> Tuple[Channel, Channel]:
    """(uplink edge→cloud, downlink cloud→edge)."""
    return Channel(up_cfg, "up", clock=clock), Channel(dn_cfg, "dn", clock=clock)


# --------------------------------------------------------------------------- #
# Socket backend: length-prefixed protocol frames over TCP
# --------------------------------------------------------------------------- #


def _recv_exact(sock: socket.socket, n: int, stop: Callable[[], bool]) -> Optional[bytes]:
    """Read exactly ``n`` bytes, polling ``stop``; None on EOF or stop."""
    chunks: List[bytes] = []
    got = 0
    while got < n:
        if stop():
            return None
        try:
            chunk = sock.recv(n - got)
        except socket.timeout:
            continue
        except OSError:
            return None
        if not chunk:  # orderly EOF
            return None
        chunks.append(chunk)
        got += len(chunk)
    return b"".join(chunks)


def _read_frame(sock: socket.socket, stop: Callable[[], bool]) -> Optional[ProtocolMessage]:
    """Read one length-prefixed frame and decode it; None on EOF/stop."""
    header = _recv_exact(sock, 4, stop)
    if header is None:
        return None
    size = int.from_bytes(header, "little")
    body = _recv_exact(sock, size, stop)
    if body is None:
        return None
    return decode(header + body)


class SocketTransport(Transport):
    """Duplex transport over one connected TCP socket (real processes).

    Frames are ``protocol.encode`` bytes; a background pump thread (spawned
    through the clock surface) decodes incoming frames into a queue that
    ``recv`` drains.  Used as BOTH the uplink and the downlink of a session:
    the server attaches the same instance twice and each side only sends its
    own direction.

    **Clock domains.**  ``NavRequest.deadline`` is an absolute timestamp on
    the sender's clock, which a peer process cannot compare against its own.
    The transport rebases it at the boundary: the wire carries the *relative*
    remaining budget, restored to an absolute receiver-clock deadline on
    arrival.  In-process transports never rebase (shared clock).

    Real sockets run on wall time only — pass no clock (or ``SYSTEM_CLOCK``);
    a ``VirtualClock`` is rejected because the network cannot block on
    virtual time.
    """

    #: Poll interval for the rx pump's socket timeout [s].
    POLL = 0.2

    def __init__(
        self,
        sock: socket.socket,
        cfg: Optional[ChannelConfig] = None,
        clock=None,
        name: str = "sock",
        session: Optional[int] = None,
        metrics=None,
        tracer=None,
    ):
        self.cfg = cfg or ChannelConfig()
        self.clock = clock or SYSTEM_CLOCK
        if getattr(self.clock, "virtual", False):
            raise ValueError("SocketTransport runs on wall time; VirtualClock is not supported")
        self.name = name
        self.session = session  # final id from the Attach handshake (if any)
        self.sock = sock
        self.sock.settimeout(self.POLL)
        self.closed = False
        self.stats = {"sent": 0, "received": 0, "bytes_sent": 0, "bytes_received": 0, "send_errors": 0}
        # Optional repro.obs.metrics.MetricRegistry: frame/byte counters are
        # mirrored into ``transport_*`` series labeled by link name.
        self.metrics = metrics
        self.tracer = tracer if tracer is not None else NULL_TRACER
        self._rx: Deque[ProtocolMessage] = deque()
        self._cv = self.clock.condition()
        self._tx_lock = threading.Lock()  # rx-loop replies + dispatch share the socket
        self._pump = self.clock.spawn(self._rx_pump, name=f"{name}-pump")

    # ------------------------------------------------------------- sending --
    def send(self, msg: ProtocolMessage) -> float:
        """Frame and write ``msg``; returns the Hockney cost *estimate*.

        A send after the peer vanished is counted in ``send_errors`` and
        otherwise behaves like a dropped message (the runtime's timeout and
        failover paths own the recovery), mirroring ``Channel`` semantics —
        transports never raise into the serving loops.
        """
        if isinstance(msg, NavRequest) and msg.deadline is not None:
            # Wire deadline = relative budget; receiver re-absolutizes.
            msg = dataclasses.replace(msg, deadline=msg.deadline - self.clock.monotonic())
        frame = encode(msg)
        cost = (self.cfg.alpha + self.cfg.beta * wire_tokens(msg)) * self.cfg.time_scale
        with self._tx_lock:
            self.stats["sent"] += 1
            if self.closed:
                self.stats["send_errors"] += 1
                return cost
            try:
                self.sock.sendall(frame)
                self.stats["bytes_sent"] += len(frame)
            except OSError:
                self.stats["send_errors"] += 1
        if self.metrics is not None:
            self.metrics.counter("transport_frames_sent", "Frames written").inc(
                link=self.name
            )
            self.metrics.counter("transport_bytes_sent", "Frame bytes written").inc(
                len(frame), link=self.name
            )
        if self.tracer.enabled:
            # Wire occupancy estimate: the Hockney cost past the write time.
            t_tx = self.clock.monotonic()
            self.tracer.add(
                "frame", t_tx, t_tx + cost, link=self.name, bytes=len(frame)
            )
        return cost

    # ----------------------------------------------------------- receiving --
    def _rx_pump(self) -> None:
        try:
            while not self.closed:
                try:
                    msg = _read_frame(self.sock, lambda: self.closed)
                except ProtocolError:  # corrupt/unknown frame: the stream is
                    break  # unrecoverable — tear the link down
                if msg is None:  # EOF or stop: the link is gone
                    break
                if isinstance(msg, NavRequest) and msg.deadline is not None:
                    msg = dataclasses.replace(
                        msg, deadline=self.clock.monotonic() + msg.deadline
                    )
                with self._cv:
                    self.stats["received"] += 1
                    self._rx.append(msg)
                    self._cv.notify_all()
                if self.metrics is not None:
                    self.metrics.counter(
                        "transport_frames_received", "Frames decoded"
                    ).inc(link=self.name)
        finally:
            # ALWAYS mark closed (even on unexpected errors) so recv() callers
            # and liveness polls see the link as gone instead of wedging.
            with self._cv:
                self.closed = True
                self._cv.notify_all()

    def recv(self, timeout: Optional[float] = None) -> Optional[ProtocolMessage]:
        """Pop the next decoded message; None on timeout or closed link."""
        deadline = None if timeout is None else self.clock.monotonic() + timeout
        with self._cv:
            while True:
                if self._rx:
                    return self._rx.popleft()
                if self.closed:
                    return None
                wait = None
                if deadline is not None:
                    wait = deadline - self.clock.monotonic()
                    if wait <= 0:
                        return None
                self._cv.wait(timeout=wait)

    def qsize(self) -> int:
        """Decoded messages awaiting pickup."""
        with self._cv:
            return len(self._rx)

    def close(self) -> None:
        """Tear down the socket; the pump exits and ``recv`` returns None."""
        with self._cv:
            if self.closed:
                return
            self.closed = True
            self._cv.notify_all()
        try:
            self.sock.shutdown(socket.SHUT_RDWR)
        except OSError:
            pass
        try:
            self.sock.close()
        except OSError:
            pass


class SocketListener:
    """Server-side accept loop with the ``Hello``/``Attach`` handshake.

    Accepts TCP connections, performs version negotiation (rejecting
    mismatched clients with a diagnostic ``Attach`` before closing them),
    remaps colliding session ids to the next free one, and hands each
    accepted session's :class:`SocketTransport` to ``on_session(session,
    transport)`` — typically ``CloudVerifier.attach(session, t, t)``.

    ``port=0`` binds an ephemeral port; read it back from ``self.port``.
    """

    def __init__(
        self,
        on_session: Callable[[int, SocketTransport], None],
        host: str = "127.0.0.1",
        port: int = 0,
        cfg: Optional[ChannelConfig] = None,
        clock=None,
        handshake_timeout: float = 5.0,
    ):
        self.on_session = on_session
        self.cfg = cfg or ChannelConfig()
        self.clock = clock or SYSTEM_CLOCK
        if getattr(self.clock, "virtual", False):
            raise ValueError("SocketListener runs on wall time; VirtualClock is not supported")
        self.handshake_timeout = handshake_timeout
        self.closed = False
        self.transports: List[SocketTransport] = []
        self.stats = {"accepted": 0, "rejected": 0}
        self._sessions: set = set()
        self._lsock = socket.socket(socket.AF_INET, socket.SOCK_STREAM)
        self._lsock.setsockopt(socket.SOL_SOCKET, socket.SO_REUSEADDR, 1)
        self._lsock.bind((host, port))
        self._lsock.listen()
        self._lsock.settimeout(SocketTransport.POLL)
        self.host, self.port = self._lsock.getsockname()[:2]
        self._acceptor = self.clock.spawn(self._accept_loop, name="socket-accept")

    def _handshake(self, conn: socket.socket) -> Optional[SocketTransport]:
        """Run Hello/Attach on a fresh connection; None when rejected."""
        conn.settimeout(SocketTransport.POLL)
        deadline = self.clock.monotonic() + self.handshake_timeout
        hello = _read_frame(
            conn, lambda: self.closed or self.clock.monotonic() > deadline
        )
        if not isinstance(hello, Hello):
            conn.close()
            self.stats["rejected"] += 1
            return None
        # Dead links release their session ids: a re-dial for the same
        # session (router migration / client re-attach) is not a collision.
        for t in [t for t in self.transports if t.closed]:
            self.transports.remove(t)
            self._sessions.discard(t.session)
        session = hello.session
        while session in self._sessions:  # collision: remap to the next free id
            session += 1
        reply = handshake_reply(hello, session=session)
        try:
            conn.sendall(encode(reply))
        except OSError:
            conn.close()
            self.stats["rejected"] += 1
            return None
        if not reply.accepted:  # version mismatch: reject and hang up
            conn.close()
            self.stats["rejected"] += 1
            return None
        self._sessions.add(session)
        self.stats["accepted"] += 1
        return SocketTransport(
            conn, cfg=self.cfg, clock=self.clock, name=f"srv-{session}", session=session
        )

    def _accept_loop(self) -> None:
        while not self.closed:
            try:
                conn, _addr = self._lsock.accept()
            except socket.timeout:
                continue
            except OSError:
                break
            try:
                transport = self._handshake(conn)
            except ProtocolError:
                conn.close()
                self.stats["rejected"] += 1
                continue
            if transport is None:
                continue
            self.transports.append(transport)
            try:
                self.on_session(transport.session, transport)
            except Exception:
                # Admission refusal (draining verifier, full fleet): hang up
                # on this client; the listener keeps serving others.
                transport.close()
                self.stats["rejected"] += 1

    def close(self) -> None:
        """Stop accepting and close every accepted transport."""
        self.closed = True
        try:
            self._lsock.close()
        except OSError:
            pass
        for t in self.transports:
            t.close()


def connect_transport(
    host: str,
    port: int,
    session: int = 0,
    cfg: Optional[ChannelConfig] = None,
    clock=None,
    timeout: float = 10.0,
    version: int = PROTOCOL_VERSION,
) -> SocketTransport:
    """Dial a :class:`SocketListener` and complete the attach handshake.

    Sends ``Hello`` and waits for the server's ``Attach``; raises
    :class:`~repro.runtime.protocol.ProtocolError` when the server rejects
    the protocol version (carrying the server's diagnostic reason).  The
    returned transport's ``session`` is the server-assigned id.
    """
    sock = socket.create_connection((host, port), timeout=timeout)
    sock.settimeout(SocketTransport.POLL)
    clk = clock or SYSTEM_CLOCK
    deadline = clk.monotonic() + timeout
    try:
        sock.sendall(encode(Hello(session=session, version=version)))
        reply = _read_frame(sock, lambda: clk.monotonic() > deadline)
    except OSError as e:
        sock.close()
        raise ProtocolError(f"attach handshake failed: {e}") from e
    if not isinstance(reply, Attach):
        sock.close()
        raise ProtocolError(f"expected Attach during handshake, got {type(reply).__name__}")
    if not reply.accepted:
        sock.close()
        raise ProtocolError(f"attach rejected: {reply.reason}")
    return SocketTransport(
        sock, cfg=cfg, clock=clock, name=f"cli-{reply.session}", session=reply.session
    )
