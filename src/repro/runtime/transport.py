"""Cloud-edge transport with Hockney-model latency and failure injection.

``Channel`` carries ``Message``s between actors with a simulated delivery
delay of ``(α + β·n_tokens) × time_scale`` — the same model the paper
measures (Fig. 6a) — so the threaded runtime reproduces the timing behaviour
of the FastAPI deployment at any speed.  All timing goes through a *clock*
object (``runtime.simclock``): the default ``SystemClock`` preserves the
historical wall-clock behaviour, while a ``VirtualClock`` runs the same
code deterministically on discrete-event time.

Failure injection has two layers:

* legacy knobs on ``ChannelConfig`` (``drop_prob``, ``outage``) — random
  loss and one hard-down window, drawn from a per-channel seeded RNG;
* a pluggable ``faults`` hook (``runtime.faults.LinkFaults``) — scripted
  drop/duplicate/reorder schedules, bandwidth-degradation phases, and
  multiple outage windows, compiled from a declarative ``FaultScenario``.

Both drive the fault-tolerance paths: NAV timeout → local-decode fallback →
re-attach.
"""

from __future__ import annotations

import heapq
import itertools
import random
from dataclasses import dataclass
from typing import Any, Optional, Tuple

from .simclock import SYSTEM_CLOCK

__all__ = ["ChannelConfig", "Message", "Channel", "make_link"]


@dataclass(frozen=True)
class Message:
    kind: str  # 'draft_batch' | 'nav_request' | 'nav_result' | 'hello' | ...
    session: int
    seq: int
    n_tokens: int
    payload: Any


@dataclass
class ChannelConfig:
    alpha: float = 0.020  # startup overhead [s]
    beta: float = 0.002  # per-token serialization [s]
    time_scale: float = 1.0  # multiply all delays (wall-clock tests use e.g. 0.01)
    drop_prob: float = 0.0  # random loss (failure injection)
    outage: Optional[Tuple[float, float]] = None  # (start, end) relative secs
    seed: int = 0  # seeds the channel's private loss RNG


class Channel:
    """One direction of the link; delivery is delayed per the Hockney model.

    A dedicated dispatcher is unnecessary: delivery times live in an event
    heap keyed on the channel's clock, and ``recv`` waits (on virtual or
    wall time) until the head message's delivery time arrives.  Transmission
    of consecutive batches serializes exactly like a real link — the next
    batch's delivery time starts after the previous one frees the link —
    except for fault-injected *reordered* messages, which take an
    out-of-band path (extra delay, no link occupancy).
    """

    def __init__(self, cfg: ChannelConfig, name: str = "ch", clock=None, faults=None):
        self.cfg = cfg
        self.name = name
        self.clock = clock or SYSTEM_CLOCK
        self.faults = faults
        self._heap: list = []
        self._counter = itertools.count()
        self._cv = self.clock.condition()
        self._t0 = self.clock.monotonic()
        self._link_free = 0.0  # relative time the link frees up
        self._closed = False
        # Per-channel seeded RNG: loss draws never touch the global RNG, so
        # seeded runs replay bit-identically under a VirtualClock.
        self._rng = random.Random(f"channel:{cfg.seed}:{name}")
        self.stats = {"sent": 0, "dropped": 0, "duplicated": 0, "reordered": 0}

    # ------------------------------------------------------------- sending --
    def send(self, msg: Message) -> float:
        """Enqueue; returns the simulated delivery delay (for diagnostics)."""
        now = self.clock.monotonic() - self._t0
        beta = self.cfg.beta
        if self.faults is not None:
            beta *= self.faults.beta_factor(now)
        cost = (self.cfg.alpha + beta * msg.n_tokens) * self.cfg.time_scale
        with self._cv:
            self.stats["sent"] += 1
            start = max(now, self._link_free)
            deliver_at = start + cost
            self._link_free = deliver_at
            if self._dropped(start):
                self.stats["dropped"] += 1
                self._cv.notify_all()
                return cost  # silently lost — receiver will time out
            extra = self.faults.reorder_delay(start) if self.faults is not None else 0.0
            if extra > 0.0:
                self.stats["reordered"] += 1
                # Out-of-band path: delayed past the link-serialized slot so
                # later messages can overtake it.
                deliver_at += extra
            heapq.heappush(self._heap, (deliver_at, next(self._counter), msg))
            if self.faults is not None and self.faults.duplicated(start):
                self.stats["duplicated"] += 1
                # The retransmitted copy re-traverses the link right behind
                # the original.
                dup_at = deliver_at + cost
                self._link_free = max(self._link_free, dup_at)
                heapq.heappush(self._heap, (dup_at, next(self._counter), msg))
            self._cv.notify_all()
        return cost

    def _dropped(self, t_rel: float) -> bool:
        if self.faults is not None and self.faults.dropped(t_rel):
            return True
        if self.cfg.outage is not None and self.cfg.outage[0] <= t_rel < self.cfg.outage[1]:
            return True
        return self.cfg.drop_prob > 0 and self._rng.random() < self.cfg.drop_prob

    # ----------------------------------------------------------- receiving --
    def recv(self, timeout: Optional[float] = None) -> Optional[Message]:
        """Blocking receive honoring delivery times; None on timeout/close."""
        deadline = None if timeout is None else self.clock.monotonic() + timeout
        with self._cv:
            while True:
                now = self.clock.monotonic() - self._t0
                if self._heap and self._heap[0][0] <= now:
                    return heapq.heappop(self._heap)[2]
                if self._closed:
                    return None
                wait = None
                if self._heap:
                    wait = self._heap[0][0] - now
                if deadline is not None:
                    rem = deadline - self.clock.monotonic()
                    if rem <= 0:
                        return None
                    wait = rem if wait is None else min(wait, rem)
                self._cv.wait(timeout=wait if wait is None or wait > 0 else 0.001)

    def qsize(self) -> int:
        """Messages in flight or awaiting pickup (for load/occupancy stats)."""
        with self._cv:
            return len(self._heap)

    def close(self) -> None:
        with self._cv:
            self._closed = True
            self._cv.notify_all()


def make_link(up_cfg: ChannelConfig, dn_cfg: ChannelConfig, clock=None) -> Tuple[Channel, Channel]:
    """(uplink edge→cloud, downlink cloud→edge)."""
    return Channel(up_cfg, "up", clock=clock), Channel(dn_cfg, "dn", clock=clock)
