"""Trace-driven network conditions for the cloud-edge serving runtime.

A :class:`NetworkTrace` is a recorded (or synthesized) bandwidth/outage
timeline for one edge↔cloud link — the kind of 4G/5G/WiFi trace the
heterogeneous-edge literature replays against speculative-decoding
serving stacks.  The trace is a piecewise-constant step function: each
:class:`TraceSegment` holds from its ``start`` until the next segment's
start (the last one until ``duration``).

``compile_trace`` lowers a trace into the declarative fault layer
(:class:`~repro.runtime.faults.FaultScenario`): every segment becomes one
contiguous :class:`~repro.runtime.faults.Phase` per direction whose
``bandwidth_factor`` is the ratio of the trace's reference bandwidth to
the segment's recorded bandwidth (so halving the recorded Mbps doubles
the per-token β cost), and outage segments become hard-down windows.
Compiled traces replay on the :class:`~repro.runtime.simclock.VirtualClock`
exactly like any other scenario, which makes trace runs bit-reproducible
and lets them join the fault-conformance matrix.

The bundled traces (:data:`BUNDLED_TRACES`) are synthesized with seeded
RNGs — ``synthesize_trace(kind, seed)`` is a pure function of its
arguments, so two compilations from the same seed are identical (a
property the test suite asserts).  Timelines are sized to the
conformance-suite timebase: ~12 virtual seconds with 1 s steps, outages
~1 s (comfortably longer than the suite's NAV timeout).
"""

from __future__ import annotations

from dataclasses import dataclass
from typing import Callable, List, Optional, Tuple

import numpy as np

from .faults import FaultScenario, Phase

__all__ = [
    "TraceSegment",
    "NetworkTrace",
    "TRACE_KINDS",
    "synthesize_trace",
    "compile_trace",
    "trace_bandwidth_fn",
    "BUNDLED_TRACES",
    "TRACE_MATRIX",
    "trace_by_name",
]


@dataclass(frozen=True)
class TraceSegment:
    """One step of a piecewise-constant bandwidth timeline.

    ``start`` is in unscaled link-relative seconds; the segment holds
    until the next segment's start (or the trace's ``duration``).
    ``up_mbps``/``dn_mbps`` are the recorded link bandwidths; ``outage``
    marks a hard-down window (bandwidth values are kept for bookkeeping
    but nothing is delivered).
    """

    start: float
    up_mbps: float
    dn_mbps: float
    outage: bool = False


@dataclass(frozen=True)
class NetworkTrace:
    """A named bandwidth/outage timeline for one edge↔cloud link.

    ``ref_up_mbps``/``ref_dn_mbps`` anchor the compilation: a segment
    recorded at the reference bandwidth compiles to ``bandwidth_factor``
    1.0 (the channel's configured Hockney β), half the reference to 2.0,
    and so on.  Frozen so value equality holds — two syntheses from the
    same seed compare equal, segment tuples included.
    """

    name: str
    kind: str  # '4g' | '5g' | 'wifi' | 'custom'
    duration: float
    segments: Tuple[TraceSegment, ...]
    ref_up_mbps: float = 20.0
    ref_dn_mbps: float = 200.0

    def __post_init__(self) -> None:
        if not self.segments:
            raise ValueError(f"trace {self.name!r} has no segments")
        if self.segments[0].start != 0.0:
            raise ValueError(f"trace {self.name!r} must start at t=0, got {self.segments[0].start}")
        starts = [s.start for s in self.segments]
        if any(b <= a for a, b in zip(starts, starts[1:])):
            raise ValueError(f"trace {self.name!r} segment starts must strictly increase")
        if starts[-1] >= self.duration:
            raise ValueError(f"trace {self.name!r} last segment starts at/after duration")
        for s in self.segments:
            if s.up_mbps <= 0 or s.dn_mbps <= 0:
                raise ValueError(f"trace {self.name!r} has non-positive bandwidth at t={s.start}")

    def segment_at(self, t: float) -> TraceSegment:
        """The segment in effect at link-relative time ``t`` (clamped)."""
        current = self.segments[0]
        for s in self.segments:
            if s.start > t:
                break
            current = s
        return current

    def outage_windows(self) -> Tuple[Tuple[float, float], ...]:
        """(start, end) of every outage segment, end-exclusive."""
        out: List[Tuple[float, float]] = []
        for seg, end in zip(self.segments, self._ends()):
            if seg.outage:
                out.append((seg.start, end))
        return tuple(out)

    def _ends(self) -> Tuple[float, ...]:
        starts = [s.start for s in self.segments[1:]] + [self.duration]
        return tuple(starts)


@dataclass(frozen=True)
class _KindProfile:
    """Synthesis profile for one access technology.

    ``up``/``dn`` bound the log-space bandwidth random walk [Mbps];
    ``outage_at`` places one deterministic outage step at that fraction
    of the timeline (None for kinds that fade but never hard-drop); the
    integer ``kind_id`` salts the RNG so kinds differ even at equal seeds.
    """

    kind_id: int
    up: Tuple[float, float]
    dn: Tuple[float, float]
    outage_at: Optional[float]


TRACE_KINDS = {
    "4g": _KindProfile(0, up=(4.0, 25.0), dn=(20.0, 120.0), outage_at=0.35),
    "5g": _KindProfile(1, up=(30.0, 150.0), dn=(150.0, 900.0), outage_at=None),
    "wifi": _KindProfile(2, up=(10.0, 60.0), dn=(60.0, 300.0), outage_at=0.55),
}


def synthesize_trace(
    kind: str,
    seed: int,
    duration: float = 12.0,
    step: float = 1.0,
    name: str = "",
) -> NetworkTrace:
    """Synthesize a seeded ``kind`` ('4g' | '5g' | 'wifi') timeline.

    Bandwidth follows a bounded log-space random walk inside the kind's
    range; 4G and WiFi additionally get one deterministic outage step at
    the kind's characteristic position (handover / AP roam).  Pure
    function of its arguments: same (kind, seed, duration, step) → equal
    :class:`NetworkTrace` values.
    """
    try:
        prof = TRACE_KINDS[kind]
    except KeyError:
        raise KeyError(f"unknown trace kind {kind!r}; have {sorted(TRACE_KINDS)}") from None
    rng = np.random.default_rng([prof.kind_id, int(seed)])
    n = max(2, int(round(duration / step)))
    outage_idx = None if prof.outage_at is None else int(n * prof.outage_at)

    def walk(lo: float, hi: float) -> List[float]:
        llo, lhi = np.log(lo), np.log(hi)
        x = rng.uniform(llo + 0.25 * (lhi - llo), lhi)  # start healthy-ish
        out = []
        for _ in range(n):
            out.append(float(np.exp(x)))
            x = float(np.clip(x + rng.normal(0.0, 0.2 * (lhi - llo)), llo, lhi))
        return out

    ups = walk(*prof.up)
    dns = walk(*prof.dn)
    segs = tuple(
        TraceSegment(start=i * step, up_mbps=ups[i], dn_mbps=dns[i], outage=(i == outage_idx))
        for i in range(n)
    )
    return NetworkTrace(
        name=name or f"{kind}_seed{seed}",
        kind=kind,
        duration=n * step,
        segments=segs,
    )


def compile_trace(trace: NetworkTrace) -> FaultScenario:
    """Lower a trace into :class:`FaultScenario` phases for both directions.

    Each segment becomes exactly one contiguous phase per direction:
    ``[seg.start, next.start)`` with ``bandwidth_factor = ref_mbps /
    seg_mbps`` (β multipliers round-trip: ``ref / factor`` recovers the
    recorded Mbps) and ``outage`` carried through.  Phases tile
    ``[0, duration)`` with no gaps or overlaps — the property tests hold
    this invariant for arbitrary generated traces.
    """
    ups: List[Phase] = []
    dns: List[Phase] = []
    ends = trace._ends()
    for seg, end in zip(trace.segments, ends):
        ups.append(
            Phase(
                seg.start,
                end,
                bandwidth_factor=trace.ref_up_mbps / seg.up_mbps,
                outage=seg.outage,
            )
        )
        dns.append(
            Phase(
                seg.start,
                end,
                bandwidth_factor=trace.ref_dn_mbps / seg.dn_mbps,
                outage=seg.outage,
            )
        )
    return FaultScenario(f"trace:{trace.name}", up=tuple(ups), dn=tuple(dns))


def trace_bandwidth_fn(trace: NetworkTrace) -> Callable[[float], Tuple[float, float]]:
    """Adapt a trace for the sim engine's ``ChannelModel.bandwidth_trace``.

    Returns ``t -> (up_mbps, dn_mbps)``; after the trace ends the last
    segment holds.  Outage segments report 1% of the recorded bandwidth
    (the sim engine has no failover path, so a hard zero would stall it —
    the serving runtime models true outages via :func:`compile_trace`).
    """

    def bw(t: float) -> Tuple[float, float]:
        seg = trace.segment_at(t)
        scale = 0.01 if seg.outage else 1.0
        return seg.up_mbps * scale, seg.dn_mbps * scale

    return bw


# --------------------------------------------------------------------------- #
# Bundled traces: one per access technology, sized to the conformance
# timebase.  These join the conformance TRACE_MATRIX — committed streams
# under trace replay must be bit-identical to the fault-free oracle run.
# --------------------------------------------------------------------------- #

BUNDLED_TRACES: Tuple[NetworkTrace, ...] = (
    synthesize_trace("4g", seed=4, name="4g_drive"),
    synthesize_trace("5g", seed=5, name="5g_urban"),
    synthesize_trace("wifi", seed=6, name="wifi_cafe"),
)

TRACE_MATRIX: Tuple[FaultScenario, ...] = tuple(compile_trace(t) for t in BUNDLED_TRACES)


def trace_by_name(name: str) -> NetworkTrace:
    """Look up a :data:`BUNDLED_TRACES` entry by its name."""
    for t in BUNDLED_TRACES:
        if t.name == name:
            return t
    raise KeyError(f"unknown trace {name!r}; have {[t.name for t in BUNDLED_TRACES]}")
