"""Multi-verifier control plane: router, live session migration, autoscaling.

PipeSD's cloud side (``runtime/server.py``) is one ``CloudVerifier``; serving
a large edge population needs a *fleet*.  The :class:`Router` fronts N
verifiers behind the same attach surface a single verifier exposes —
``attach(session, uplink, downlink)`` — so edge clients, the socket
listener, and the conformance harness are unchanged.  Internally it:

* **places** each arriving session on a verifier via a pluggable
  :class:`~repro.runtime.placement.PlacementPolicy` (default: least-loaded
  with a paged-KV free-block admission gate), refusing admission
  (:class:`FleetFullError`) when no verifier has headroom;
* **relays** traffic both ways, caching just enough per-session state to
  make migration possible: the committed stream position (from
  ``NavRequest.pos``/``Reset.position``), the current round's draft
  fragments, and the round's unanswered NAV request;
* **live-migrates** sessions: open a link on the destination, replay the
  committed position through ``Reset`` (driving the destination's
  ``_kv_reconcile`` re-attach path), replay the in-flight round's fragments
  and NAV request, and detach from the source — the client only ever sees a
  bit-identical committed stream (the conformance suite's equality check);
* **fails over**: a severed verifier link (crash) triggers migration of
  every session placed there; sessions stranded while the fleet is full are
  rescued by the control loop once capacity returns;
* **scales** the fleet from occupancy/queue-depth signals via
  :class:`~repro.runtime.scaling.AutoScaler` — up through a
  ``make_verifier`` factory, down by draining and retiring the least-loaded
  member.

Everything runs on the injectable clock (``runtime/simclock.py``): under a
``VirtualClock`` the whole control plane — crashes, migrations, restarts —
is deterministic, so failover is tested as a stream-equality check, not a
flaky timing test.  Verifier fleet members are wrapped in
:class:`LocalVerifier` (in-process, zero-cost internal links, exact load
hints) or :class:`RemoteVerifier` (socket dial-out per session).

Router restart is modelled explicitly: ``stop()`` detaches the fleet but
leaves client links untouched, ``snapshot()`` serializes per-session
positions, and a fresh router ``adopt()``s the live links — the restart
conformance scenario replays exactly this sequence.
"""

from __future__ import annotations

import threading
from dataclasses import dataclass, field, replace
from typing import Any, Callable, Dict, List, Optional, Sequence, Set, Tuple

from repro.obs.trace import NULL_TRACER

from .placement import LeastLoadedPlacement, PlacementPolicy, VerifierLoad
from .protocol import (
    Detach,
    DraftFragment,
    Drain,
    Hello,
    Migrate,
    NavRequest,
    NavResult,
    Reset,
    Route,
    TelemetryRequest,
    TelemetrySnapshot,
    handshake_reply,
)
from .scaling import AutoScaler
from .server import CloudVerifier
from .simclock import SYSTEM_CLOCK
from .transport import Channel, ChannelConfig, Transport, connect_transport

__all__ = [
    "FleetFullError",
    "VerifierClient",
    "LocalVerifier",
    "RemoteVerifier",
    "Router",
    "RouterEvent",
    "RouterScenario",
    "ROUTER_FAULT_MATRIX",
]


class FleetFullError(RuntimeError):
    """Admission refused: no verifier has session or KV-block headroom."""


# --------------------------------------------------------------------------- #
# Fleet members: router-side handles on one verifier each
# --------------------------------------------------------------------------- #


class VerifierClient:
    """Router-side handle on one verifier (in-process or remote).

    ``open_link(session)`` returns an (uplink, downlink) pair attached to the
    verifier for that session; ``load_hint()`` reports whatever load signals
    the handle can observe (the router fills gaps from its own bookkeeping).
    """

    verifier_id: int = -1
    alive: bool = True

    def open_link(self, session: int) -> Tuple[Transport, Transport]:
        """Attach ``session`` on the verifier; returns (uplink, downlink)."""
        raise NotImplementedError

    def load_hint(self) -> Dict[str, Any]:
        """Best-effort load signals (sessions/queue_depth/free_blocks/...)."""
        return {}

    def telemetry(self, seq: int = 0) -> Optional[TelemetrySnapshot]:
        """Point-in-time :class:`TelemetrySnapshot`, or None when unreachable."""
        return None

    def drain(self) -> None:
        """Ask the verifier to refuse new sessions."""

    def stop(self) -> None:
        """Shut the verifier (or our handle on it) down."""


class LocalVerifier(VerifierClient):
    """An in-process ``CloudVerifier`` fleet member.

    Links are zero-cost ``Channel``s on the shared clock (the modelled
    network hop is the CLIENT<->router link; router and verifiers are
    co-located).  Load hints are exact: live session count, verify-queue
    depth, and paged-KV free blocks straight from the verifier.
    """

    def __init__(
        self,
        verifier_id: int,
        verifier: CloudVerifier,
        clock=None,
        link_cfg: Optional[ChannelConfig] = None,
    ) -> None:
        """Wrap ``verifier`` as fleet member ``verifier_id``."""
        self.verifier_id = verifier_id
        self.verifier = verifier
        self.alive = True
        self.clock = clock or verifier.clock
        self.link_cfg = link_cfg or ChannelConfig(alpha=0.0, beta=0.0)
        self._links: List[Tuple[Transport, Transport]] = []

    def open_link(self, session: int) -> Tuple[Transport, Transport]:
        """Attach ``session`` over a fresh zero-cost channel pair."""
        vid = self.verifier_id
        up = Channel(self.link_cfg, f"r-v{vid}-up{session}", clock=self.clock)
        dn = Channel(self.link_cfg, f"r-v{vid}-dn{session}", clock=self.clock)
        self.verifier.attach(session, up, dn)
        self._links.append((up, dn))
        return up, dn

    def load_hint(self) -> Dict[str, Any]:
        """Exact in-process load: sessions, queue depth, KV free blocks."""
        v = self.verifier
        hint: Dict[str, Any] = dict(
            sessions=len(v.sessions),
            queue_depth=float(len(v._queue)),
            draining=v.draining,
        )
        if v.kv_pool is not None:
            hint["free_blocks"] = v.kv_pool.free_blocks
            hint["capacity_blocks"] = v.kv_pool.num_blocks
        return hint

    def telemetry(self, seq: int = 0) -> Optional[TelemetrySnapshot]:
        """Exact in-process snapshot straight from the wrapped verifier."""
        if not self.alive:
            return None
        snap = self.verifier.telemetry_snapshot(seq=seq)
        if snap.verifier != self.verifier_id:
            # The wrapped verifier may predate fleet ids; stamp ours on.
            snap = replace(snap, verifier=self.verifier_id)
        return snap

    def drain(self) -> None:
        """Refuse new sessions on the wrapped verifier."""
        self.verifier.drain()

    def crash(self) -> None:
        """Simulate abrupt verifier death: stop serving, sever every link.

        The router's downlink loops observe the severed links and run the
        failover-migration path exactly as they would for a remote peer
        vanishing mid-stream.
        """
        self.alive = False
        self.verifier._stop.set()
        with self.verifier._work:
            self.verifier._work.notify_all()
        for up, dn in self._links:
            up.close()
            dn.close()

    def stop(self) -> None:
        """Graceful shutdown: stop the verifier and close our links."""
        self.alive = False
        self.verifier.stop()
        for up, dn in self._links:
            up.close()
            dn.close()


class RemoteVerifier(VerifierClient):
    """A verifier process behind a ``SocketListener``, dialed per session.

    Load hints are limited to the configured ``capacity_blocks`` (the router
    estimates occupancy from its own placement bookkeeping); draining is
    requested over the wire with a ``Drain`` control message.
    """

    #: Session-id base for throwaway control links (``Drain`` delivery).
    CONTROL_SESSION_BASE = 1 << 20

    def __init__(
        self,
        verifier_id: int,
        host: str,
        port: int,
        cfg: Optional[ChannelConfig] = None,
        clock=None,
        capacity_blocks: Optional[int] = None,
    ) -> None:
        """Handle on the verifier listening at ``host:port``."""
        self.verifier_id = verifier_id
        self.alive = True
        self.host = host
        self.port = port
        self.cfg = cfg
        self.clock = clock
        self.capacity_blocks = capacity_blocks
        self._links: Dict[int, Transport] = {}

    def open_link(self, session: int) -> Tuple[Transport, Transport]:
        """Dial a duplex socket transport for ``session``."""
        t = connect_transport(
            self.host, self.port, session=session, cfg=self.cfg, clock=self.clock
        )
        self._links[session] = t
        return t, t

    def load_hint(self) -> Dict[str, Any]:
        """Only static capacity is observable from the dialing side."""
        if self.capacity_blocks is None:
            return {}
        return dict(capacity_blocks=self.capacity_blocks)

    def drain(self) -> None:
        """Deliver ``Drain`` over any live link (or a throwaway dial)."""
        msg = Drain(verifier=self.verifier_id)
        for t in self._links.values():
            if not getattr(t, "closed", False):
                t.send(msg)
                return
        t = connect_transport(
            self.host,
            self.port,
            session=self.CONTROL_SESSION_BASE + self.verifier_id,
            cfg=self.cfg,
            clock=self.clock,
        )
        t.send(msg)
        t.close()

    def telemetry(self, seq: int = 0, timeout: float = 5.0) -> Optional[TelemetrySnapshot]:
        """Fetch a snapshot over a throwaway control dial (None on timeout)."""
        sid = self.CONTROL_SESSION_BASE + self.verifier_id
        try:
            t = connect_transport(
                self.host, self.port, session=sid, cfg=self.cfg, clock=self.clock
            )
        except OSError:
            return None
        clk = t.clock
        deadline = clk.monotonic() + timeout
        snap: Optional[TelemetrySnapshot] = None
        try:
            t.send(TelemetryRequest(session=t.session, seq=seq))
            while clk.monotonic() < deadline:
                msg = t.recv(timeout=0.25)
                if isinstance(msg, TelemetrySnapshot):
                    snap = replace(msg, verifier=self.verifier_id)
                    break
        finally:
            t.send(Detach(session=t.session, seq=seq))
            t.close()
        return snap

    def stop(self) -> None:
        """Close every dialed link (the remote process outlives the handle)."""
        self.alive = False
        for t in self._links.values():
            t.close()


# --------------------------------------------------------------------------- #
# The router
# --------------------------------------------------------------------------- #


@dataclass
class _RoutedSession:
    """Router-side record of one client session (migration state included)."""

    up_c: Transport  # client -> router
    dn_c: Transport  # router -> client
    verifier: int
    v_up: Transport  # router -> verifier
    v_dn: Transport  # verifier -> router
    pos: int = 0  # committed stream position (from NavRequest.pos / Reset)
    round: int = 0  # current NAV round id
    frags: Dict[int, DraftFragment] = field(default_factory=dict)
    nav: Optional[NavRequest] = None  # in-flight, unanswered NAV request
    epoch: int = 0  # bumped per migration; stale downlink loops exit
    done: bool = False  # client detached


class Router:
    """Session router/master fronting a fleet of verifiers.

    Exposes the single-verifier attach surface (``attach(session, up, dn)``)
    so it drops in wherever a ``CloudVerifier`` does — behind a
    ``SocketListener`` (``launch/serve.py --router``) or wired directly to
    in-process ``Channel`` pairs (tests, benchmarks).

    ``need_blocks`` is the paged-KV headroom a new session must find on its
    verifier (the placement property test's budget invariant).  With a
    ``scaler`` + ``make_verifier`` the control loop grows and shrinks the
    fleet; ``rebalance_interval`` forces periodic round-robin migration
    (exercises the migration path continuously — the CI smoke uses it).
    """

    def __init__(
        self,
        verifiers: Sequence[VerifierClient] = (),
        policy: Optional[PlacementPolicy] = None,
        scaler: Optional[AutoScaler] = None,
        make_verifier: Optional[Callable[[int], VerifierClient]] = None,
        clock=None,
        need_blocks: int = 2,
        control_interval: float = 0.25,
        rebalance_interval: Optional[float] = None,
        name: str = "router",
        tracer=None,
    ) -> None:
        """Create a router over ``verifiers`` (see class docstring)."""
        self.clock = clock or SYSTEM_CLOCK
        self.tracer = tracer if tracer is not None else NULL_TRACER
        self.policy = policy or LeastLoadedPlacement()
        self.scaler = scaler
        self.make_verifier = make_verifier
        self.need_blocks = need_blocks
        self.control_interval = control_interval
        self.rebalance_interval = rebalance_interval
        self.name = name
        self.fleet: Dict[int, VerifierClient] = {
            v.verifier_id: v for v in verifiers
        }
        self.sessions: Dict[int, _RoutedSession] = {}
        self._draining: Set[int] = set()
        self._retiring: Set[int] = set()
        self._down: Set[int] = set()
        self._lock = threading.Lock()
        self._stop = threading.Event()
        self._threads: List[Any] = []
        self._ctl_seq = 0
        self.stats: Dict[str, int] = {
            "sessions_placed": 0,
            "admission_refusals": 0,
            "migrations": 0,
            "failover_migrations": 0,
            "verifier_crashes": 0,
            "scale_ups": 0,
            "scale_downs": 0,
            "drains": 0,
        }

    # ------------------------------------------------------------ lifecycle --
    def start(self) -> None:
        """Start the control loop (placement itself is demand-driven)."""
        self._threads.append(
            self.clock.spawn(self._control_loop, name=f"{self.name}-ctl")
        )

    def stop(self, detach: bool = True) -> None:
        """Stop relaying; optionally detach fleet sessions (router restart).

        Client links are left OPEN: a replacement router can ``adopt`` them
        from a ``snapshot()``.  With ``detach`` the fleet is told to drop the
        sessions (freeing KV) so the replacement re-attaches cleanly.
        """
        self._stop.set()
        with self._lock:
            live = [(sid, rs) for sid, rs in self.sessions.items() if not rs.done]
        for sid, rs in live:
            vc = self.fleet.get(rs.verifier)
            if detach and vc is not None and vc.alive:
                self._ctl_seq += 1
                rs.v_up.send(Detach(session=sid, seq=self._ctl_seq))
            rs.v_up.close()
            rs.v_dn.close()
        for t in self._threads:
            t.join(timeout=5.0)

    def snapshot(self) -> Dict[int, Tuple[int, int]]:
        """Serialize live sessions as ``{session: (position, round)}``."""
        with self._lock:
            return {
                sid: (rs.pos, rs.round)
                for sid, rs in self.sessions.items()
                if not rs.done
            }

    # ------------------------------------------------------------ placement --
    def loads(self) -> List[VerifierLoad]:
        """Snapshot the fleet for the placement/scaling policies.

        Local members report exact sessions/queue/KV; gaps (remote members)
        are filled from the router's own bookkeeping: placed-session counts
        and in-flight NAV rounds as a queue-depth proxy.
        """
        with self._lock:
            placed: Dict[int, int] = {vid: 0 for vid in self.fleet}
            inflight: Dict[int, int] = {vid: 0 for vid in self.fleet}
            for rs in self.sessions.values():
                if rs.done:
                    continue
                placed[rs.verifier] = placed.get(rs.verifier, 0) + 1
                if rs.nav is not None:
                    inflight[rs.verifier] = inflight.get(rs.verifier, 0) + 1
            members = list(self.fleet.items())
            draining = set(self._draining)
        out = []
        for vid, vc in members:
            hint = vc.load_hint()
            out.append(
                VerifierLoad(
                    verifier=vid,
                    sessions=int(hint.get("sessions", placed.get(vid, 0))),
                    queue_depth=float(
                        hint.get("queue_depth", inflight.get(vid, 0))
                    ),
                    free_blocks=hint.get("free_blocks"),
                    capacity_blocks=hint.get("capacity_blocks"),
                    draining=bool(hint.get("draining", False)) or vid in draining,
                    alive=vc.alive,
                )
            )
        return out

    def attach(self, session: int, uplink: Transport, downlink: Transport) -> int:
        """Place ``session`` on a verifier and start relaying; returns its id.

        Raises :class:`FleetFullError` when the placement policy refuses
        admission (no alive, non-draining verifier with ``need_blocks`` of
        KV headroom).
        """
        vid = self.policy.place(self.loads(), need_blocks=self.need_blocks)
        if vid is None:
            with self._lock:
                self.stats["admission_refusals"] += 1
            raise FleetFullError(f"no verifier can admit session {session}")
        v_up, v_dn = self.fleet[vid].open_link(session)
        rs = _RoutedSession(uplink, downlink, vid, v_up, v_dn)
        with self._lock:
            self.sessions[session] = rs
            self.stats["sessions_placed"] += 1
            self._ctl_seq += 1
            seq = self._ctl_seq
        downlink.send(Route(session=session, seq=seq, verifier=vid))
        self._threads.append(
            self.clock.spawn(
                lambda: self._up_loop(session, rs), name=f"{self.name}-up-{session}"
            )
        )
        self._spawn_dn_loop(session, rs, rs.epoch, v_dn)
        return vid

    def adopt(
        self, session: int, uplink: Transport, downlink: Transport,
        position: int = 0, round_id: int = 0,
    ) -> int:
        """Adopt a live client link after a router restart.

        Places the session like ``attach`` and immediately replays the
        snapshotted committed ``position`` to the verifier via ``Reset``
        (driving its ``_kv_reconcile`` re-attach path), so serving resumes
        where the previous router left off.
        """
        vid = self.attach(session, uplink, downlink)
        with self._lock:
            rs = self.sessions[session]
            rs.pos = position
            rs.round = round_id
            self._ctl_seq += 1
            seq = self._ctl_seq
        rs.v_up.send(
            Reset(session=session, seq=seq, round=round_id, position=position)
        )
        return vid

    # ------------------------------------------------------------ relaying --
    def _up_loop(self, session: int, rs: _RoutedSession) -> None:
        """Forward client->verifier, caching migration state on the way."""
        up = rs.up_c
        while not self._stop.is_set():
            msg = up.recv(timeout=0.25)
            if msg is None:
                if getattr(up, "closed", False):
                    return
                continue
            if isinstance(msg, TelemetryRequest):
                # Answer at the router with the fleet-wide aggregate; the
                # reply never reaches the verifiers.
                rs.dn_c.send(self.telemetry(seq=msg.seq, session=session)[1])
                continue
            detached = False
            hello = None
            with self._lock:
                v_up = rs.v_up
                if isinstance(msg, DraftFragment):
                    if msg.round > rs.round:
                        rs.round = msg.round
                        rs.frags.clear()
                        rs.nav = None
                    if msg.round == rs.round:
                        rs.frags[msg.seq] = msg
                elif isinstance(msg, NavRequest):  # TreeNavRequest included
                    if msg.round > rs.round:
                        rs.round = msg.round
                        rs.frags.clear()
                    if msg.round == rs.round:
                        rs.nav = msg
                    if msg.pos is not None:
                        rs.pos = max(rs.pos, msg.pos)
                elif isinstance(msg, Reset):
                    rs.pos = msg.position
                    rs.round = msg.round
                    rs.frags.clear()
                    rs.nav = None
                elif isinstance(msg, Detach):
                    rs.done = True
                    rs.frags.clear()
                    rs.nav = None
                    detached = True
                elif isinstance(msg, Hello):
                    # Answer at the router (the fleet link is attached); sent
                    # below, outside the lock — Channel.send takes link time.
                    hello = handshake_reply(msg, session=session)
            if hello is not None:
                rs.dn_c.send(hello)
                continue
            v_up.send(msg)
            if detached:
                rs.v_up.close()
                rs.v_dn.close()
                return

    def _spawn_dn_loop(
        self, session: int, rs: _RoutedSession, epoch: int, v_dn: Transport
    ) -> None:
        """Start the verifier->client forwarding loop for one epoch."""
        self._threads.append(
            self.clock.spawn(
                lambda: self._dn_loop(session, rs, epoch, v_dn),
                name=f"{self.name}-dn-{session}e{epoch}",
            )
        )

    def _dn_loop(
        self, session: int, rs: _RoutedSession, epoch: int, v_dn: Transport
    ) -> None:
        """Forward verifier->client; a severed link triggers failover."""
        while not self._stop.is_set():
            with self._lock:
                if rs.epoch != epoch or rs.done:
                    return  # migrated away or finished; a newer loop owns it
                vid = rs.verifier
            msg = v_dn.recv(timeout=0.25)
            if msg is None:
                if getattr(v_dn, "closed", False):
                    with self._lock:
                        stale = rs.epoch != epoch or rs.done
                    if not stale and not self._stop.is_set():
                        self._on_verifier_down(vid)
                    return
                continue
            if isinstance(msg, NavResult):
                with self._lock:
                    if rs.epoch != epoch or rs.done:
                        return  # stale result; the replay re-produces it
                    if rs.nav is not None and msg.seq == rs.nav.seq:
                        # Round answered: nothing in flight to replay if the
                        # session migrates from here on.
                        rs.nav = None
                        rs.frags.clear()
            rs.dn_c.send(msg)

    # ------------------------------------------------------------ migration --
    def migrate(
        self, session: int, dst: Optional[int] = None, failover: bool = False
    ) -> Optional[int]:
        """Live-migrate ``session`` to ``dst`` (or the policy's pick).

        Serializes the committed position, re-attaches on the destination
        (``Reset`` -> ``_kv_reconcile``), replays the in-flight round's
        cached fragments and NAV request, and detaches from the source.
        Returns the destination id, or ``None`` when the session is gone.
        Raises :class:`FleetFullError` when no destination can admit it.
        """
        t_mig = self.clock.monotonic() if self.tracer.enabled else 0.0
        with self._lock:
            rs = self.sessions.get(session)
            if rs is None or rs.done:
                return None
            src = rs.verifier
        if dst is None:
            candidates = [ld for ld in self.loads() if ld.verifier != src]
            dst = self.policy.place(candidates, need_blocks=self.need_blocks)
            if dst is None:
                with self._lock:
                    self.stats["admission_refusals"] += 1
                raise FleetFullError(f"no migration target for session {session}")
        nu, nd = self.fleet[dst].open_link(session)
        with self._lock:
            old_up, old_dn, old_vid = rs.v_up, rs.v_dn, rs.verifier
            rs.v_up, rs.v_dn, rs.verifier = nu, nd, dst
            rs.epoch += 1
            epoch = rs.epoch
            replay_frags = [rs.frags[s] for s in sorted(rs.frags)]
            replay_nav = rs.nav
            pos, rnd = rs.pos, rs.round
            self.stats["failover_migrations" if failover else "migrations"] += 1
            self._ctl_seq += 3
            seq = self._ctl_seq
        old_vc = self.fleet.get(old_vid)
        if old_vc is not None and old_vc.alive:
            old_up.send(Detach(session=session, seq=seq - 2))
        old_up.close()
        old_dn.close()
        # Serialize the committed position onto the destination, then replay
        # the in-flight round (fragments in seq order, then the NAV request).
        nu.send(Reset(session=session, seq=seq - 1, round=rnd, position=pos))
        for frag in replay_frags:
            nu.send(frag)
        if replay_nav is not None:
            nu.send(replay_nav)
        self._spawn_dn_loop(session, rs, epoch, nd)
        rs.dn_c.send(
            Migrate(session=session, seq=seq, src=old_vid, dst=dst, position=pos)
        )
        if self.tracer.enabled:
            self.tracer.add(
                "migrate",
                t_mig,
                self.clock.monotonic(),
                session=session,
                src=old_vid,
                dst=dst,
                failover=int(failover),
            )
        return dst

    # ------------------------------------------------------------ telemetry --
    def telemetry(
        self, seq: int = 0, session: int = -1
    ) -> Tuple[List[TelemetrySnapshot], TelemetrySnapshot]:
        """Per-verifier snapshots plus the fleet-wide aggregate.

        Polls every alive fleet member (:meth:`VerifierClient.telemetry`)
        and folds the answers into one ``verifier=-1`` aggregate via
        :func:`repro.obs.endpoint.aggregate_snapshots`, with the router's
        own control-plane counters (placements, refusals, migrations,
        crashes, scaling) appended to the aggregate's extras lanes.
        """
        from repro.obs.endpoint import aggregate_snapshots

        snaps: List[TelemetrySnapshot] = []
        with self._lock:
            members = sorted(self.fleet.items())
            router_extras = [
                (f"router_{k}", float(v)) for k, v in sorted(self.stats.items())
            ]
            migrations = self.stats["migrations"] + self.stats["failover_migrations"]
            failovers = self.stats["failover_migrations"]
        for _vid, vc in members:
            if not vc.alive:
                continue
            snap = vc.telemetry(seq=seq)
            if snap is not None:
                snaps.append(snap)
        agg = aggregate_snapshots(
            snaps,
            seq=seq,
            session=session,
            t=self.clock.monotonic(),
            migrations=migrations,
            failovers=failovers,
            extras=router_extras,
        )
        return snaps, agg

    def _on_verifier_down(self, vid: int) -> None:
        """Failover: re-place every session of a crashed verifier."""
        with self._lock:
            if vid in self._down:
                return  # another downlink loop already ran the failover
            self._down.add(vid)
            vc = self.fleet.get(vid)
            if vc is not None:
                vc.alive = False
            self.stats["verifier_crashes"] += 1
            victims = [
                sid
                for sid, rs in self.sessions.items()
                if rs.verifier == vid and not rs.done
            ]
        for sid in victims:
            try:
                self.migrate(sid, failover=True)
            except FleetFullError:
                # Stranded: the control loop rescues it once capacity
                # returns (scale-up or another verifier freeing headroom);
                # meanwhile the client makes progress decoding locally.
                pass

    def drain_verifier(self, vid: int, migrate_sessions: bool = True) -> int:
        """Drain ``vid`` (no new placements) and migrate its sessions away.

        Returns the number of sessions migrated.  The drained member stays
        in the fleet (it may be undrained operationally); scale-down retires
        it via the control loop instead.
        """
        with self._lock:
            self._draining.add(vid)
            self.stats["drains"] += 1
        vc = self.fleet.get(vid)
        if vc is not None:
            vc.drain()
        moved = 0
        if migrate_sessions:
            with self._lock:
                victims = [
                    sid
                    for sid, rs in self.sessions.items()
                    if rs.verifier == vid and not rs.done
                ]
            for sid in victims:
                try:
                    if self.migrate(sid) is not None:
                        moved += 1
                except FleetFullError:
                    break  # nowhere to put the rest; retry from the ctl loop
        return moved

    # ------------------------------------------------------------- control --
    def _control_loop(self) -> None:
        """Scaling + rescue + rebalance ticks every ``control_interval``."""
        last_rebalance = self.clock.monotonic()
        while not self._stop.is_set():
            self.clock.sleep(self.control_interval)
            if self._stop.is_set():
                return
            self._rescue_stranded()
            self._finish_retirements()
            if self.scaler is not None:
                self._autoscale_tick()
            if self.rebalance_interval is not None:
                now = self.clock.monotonic()
                if now - last_rebalance >= self.rebalance_interval:
                    last_rebalance = now
                    self._rebalance_tick()

    def _rescue_stranded(self) -> None:
        """Re-place sessions stuck on dead/retired verifiers."""
        with self._lock:
            stranded = [
                sid
                for sid, rs in self.sessions.items()
                if not rs.done
                and (
                    rs.verifier not in self.fleet
                    or not self.fleet[rs.verifier].alive
                )
            ]
        for sid in stranded:
            try:
                self.migrate(sid, failover=True)
            except FleetFullError:
                return

    def _finish_retirements(self) -> None:
        """Stop drained-for-retirement verifiers once they are empty."""
        with self._lock:
            ready = [
                vid
                for vid in self._retiring
                if not any(
                    rs.verifier == vid and not rs.done
                    for rs in self.sessions.values()
                )
            ]
        for vid in ready:
            with self._lock:
                self._retiring.discard(vid)
                self._draining.discard(vid)
                vc = self.fleet.pop(vid, None)
            if vc is not None:
                vc.stop()

    def _autoscale_tick(self) -> None:
        """One scaler decision: grow via the factory or drain-to-retire."""
        decision = self.scaler.decide(self.loads(), self.clock.monotonic())
        if decision.action == "up" and self.make_verifier is not None:
            vid = max(self.fleet, default=-1) + 1
            vc = self.make_verifier(vid)
            with self._lock:
                self.fleet[vid] = vc
                self.stats["scale_ups"] += 1
        elif decision.action == "down" and decision.drain in self.fleet:
            with self._lock:
                self.stats["scale_downs"] += 1
                self._retiring.add(decision.drain)
            self.drain_verifier(decision.drain)

    def _rebalance_tick(self) -> None:
        """Round-robin forced migration (the CI smoke's migration driver)."""
        with self._lock:
            vids = sorted(
                vid
                for vid, vc in self.fleet.items()
                if vc.alive and vid not in self._draining
            )
            live = [
                (sid, rs.verifier)
                for sid, rs in self.sessions.items()
                if not rs.done
            ]
        if len(vids) < 2:
            return
        for sid, cur in live:
            nxt = vids[(vids.index(cur) + 1) % len(vids)] if cur in vids else vids[0]
            try:
                self.migrate(sid, dst=nxt)
            except FleetFullError:
                return


# --------------------------------------------------------------------------- #
# Router-layer fault scenarios (consumed by tests/test_fault_conformance.py)
# --------------------------------------------------------------------------- #


@dataclass(frozen=True)
class RouterEvent:
    """One timed control-plane event in a :class:`RouterScenario`.

    ``kind`` is ``'crash'`` (abrupt verifier death), ``'migrate'`` (forced
    live migration of ``session`` to ``dst``, policy-picked when ``dst`` is
    -1), or ``'drain'`` (drain ``verifier`` and migrate its sessions away).
    """

    t: float
    kind: str
    verifier: int = -1
    session: int = -1
    dst: int = -1


@dataclass(frozen=True)
class RouterScenario:
    """A named, deterministic schedule of router-layer faults."""

    name: str
    events: Tuple[RouterEvent, ...] = ()
    n_verifiers: int = 2


#: Router-layer conformance matrix: under every scenario the committed
#: client streams must stay bit-identical to the fault-free oracle run.
ROUTER_FAULT_MATRIX: Tuple[RouterScenario, ...] = (
    RouterScenario("router_clean"),
    RouterScenario(
        "verifier_crash_midstream",
        events=(RouterEvent(t=1.1, kind="crash", verifier=0),),
    ),
    RouterScenario(
        "migrate_midstream",
        events=(
            RouterEvent(t=0.8, kind="migrate", session=0, dst=1),
            RouterEvent(t=1.4, kind="migrate", session=0, dst=0),
            RouterEvent(t=1.7, kind="migrate", session=1, dst=0),
        ),
    ),
    RouterScenario(
        "drain_midstream",
        events=(RouterEvent(t=1.0, kind="drain", verifier=0),),
    ),
    RouterScenario(
        "crash_then_drain",
        n_verifiers=3,
        events=(
            RouterEvent(t=0.9, kind="crash", verifier=1),
            RouterEvent(t=1.6, kind="drain", verifier=0),
        ),
    ),
)
